"""AOT export tests: manifest round-trip, HLO text validity, ABI stability."""

import json
import os
import tempfile

import numpy as np
import pytest

from compile.aot import BATCH, PREFILL_BUCKETS, export
from compile.model import ModelConfig

CFG = ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=1, d_ff=64, max_seq=32)


@pytest.fixture(scope="module")
def exported():
    with tempfile.TemporaryDirectory() as d:
        manifest = export(d, CFG, seed=0)
        files = {name: open(os.path.join(d, f)).read()
                 for name, f in manifest["files"].items()}
        params = np.fromfile(os.path.join(d, "params.bin"), dtype="<f4")
        on_disk = json.load(open(os.path.join(d, "manifest.json")))
        yield manifest, files, params, on_disk


class TestExport:
    def test_manifest_roundtrip(self, exported):
        manifest, _, _, on_disk = exported
        assert on_disk == manifest

    def test_all_buckets_exported(self, exported):
        manifest, files, _, _ = exported
        for s in PREFILL_BUCKETS:
            if s <= CFG.max_seq:
                assert f"prefill_s{s}" in files
        assert manifest["prefill_buckets"] == [
            s for s in PREFILL_BUCKETS if s <= CFG.max_seq
        ]
        assert "decode_step" in files

    def test_hlo_text_is_parseable_hlo(self, exported):
        """HLO text (not proto) is the interchange format; sanity-check the
        header and that entry computations declare parameters."""
        _, files, _, _ = exported
        for name, text in files.items():
            assert text.startswith("HloModule"), name
            assert "parameter(0)" in text, name
            assert "ROOT" in text, name

    def test_params_bin_size(self, exported):
        manifest, _, params, _ = exported
        assert params.size == manifest["model"]["num_params"]
        assert params.size == CFG.num_params()

    def test_param_count_in_hlo(self, exported):
        """Prefill entry takes len(param_specs) + 1 (tokens) parameters."""
        _, files, _, _ = exported
        n_params = len(CFG.param_specs())
        text = files[f"prefill_s{PREFILL_BUCKETS[0]}"]
        assert f"parameter({n_params})" in text  # tokens is the last param
        assert f"parameter({n_params + 1})" not in text

    def test_decode_param_count_in_hlo(self, exported):
        """Decode entry: params + token + kc + vc + pos."""
        _, files, _, _ = exported
        n = len(CFG.param_specs())
        text = files["decode_step"]
        assert f"parameter({n + 3})" in text
        assert f"parameter({n + 4})" not in text

    def test_test_vectors_present(self, exported):
        manifest, _, _, _ = exported
        tv = manifest["test_vectors"]
        assert len(tv["greedy_next_tokens"]) == 8
        assert len(tv["last_logits_row0_head"]) == 8
        assert np.isfinite(tv["last_logits_sum"])

    def test_deterministic_across_exports(self):
        with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
            m1 = export(d1, CFG, seed=0)
            m2 = export(d2, CFG, seed=0)
            assert m1["params_sha256"] == m2["params_sha256"]
            assert m1["test_vectors"] == m2["test_vectors"]

    def test_seed_changes_params_sha(self):
        with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
            m1 = export(d1, CFG, seed=0)
            m2 = export(d2, CFG, seed=1)
            assert m1["params_sha256"] != m2["params_sha256"]

    def test_batch_constant(self):
        assert BATCH >= 1

"""L2 model tests: shapes, causality, prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    decode_step,
    init_params,
    prefill,
    reference_generate,
)

CFG = ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=2, d_ff=64, max_seq=48)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


def _toks(b, s, seed=0):
    return (jnp.arange(b * s, dtype=jnp.int32).reshape(b, s) * 7 + 3 + seed) % CFG.vocab


class TestPrefill:
    def test_shapes(self, params):
        logits, kc, vc = prefill(params, _toks(2, 16), CFG)
        assert logits.shape == (2, 16, CFG.vocab)
        assert kc.shape == (CFG.n_layers, 2, CFG.n_heads, CFG.max_seq, CFG.d_head)
        assert vc.shape == kc.shape

    def test_finite(self, params):
        logits, _, _ = prefill(params, _toks(2, 16), CFG)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_causal_logits(self, params):
        """Changing a suffix token must not change logits at earlier positions."""
        t1 = _toks(1, 16)
        t2 = t1.at[0, 12].set((t1[0, 12] + 5) % CFG.vocab)
        l1, _, _ = prefill(params, t1, CFG)
        l2, _, _ = prefill(params, t2, CFG)
        np.testing.assert_allclose(l1[:, :12], l2[:, :12], rtol=1e-5, atol=1e-5)
        assert not np.allclose(l1[:, 12], l2[:, 12])

    def test_kv_padding_zero(self, params):
        _, kc, vc = prefill(params, _toks(1, 8), CFG)
        assert float(jnp.abs(kc[:, :, :, 8:, :]).max()) == 0.0
        assert float(jnp.abs(vc[:, :, :, 8:, :]).max()) == 0.0

    def test_batch_independence(self, params):
        """Row i of a batch must equal the same prompt run alone."""
        t = _toks(3, 16)
        lb, _, _ = prefill(params, t, CFG)
        l0, _, _ = prefill(params, t[1:2], CFG)
        np.testing.assert_allclose(lb[1], l0[0], rtol=1e-4, atol=1e-4)

    def test_param_specs_abi_stable(self):
        names = [n for n, _ in CFG.param_specs()]
        assert names[0] == "embed" and names[1] == "pos_embed"
        assert names[-1] == "lm_head" and names[-2] == "final_norm"
        assert len(names) == 4 + 9 * CFG.n_layers


class TestDecodeStep:
    def test_shapes(self, params):
        _, kc, vc = prefill(params, _toks(2, 16), CFG)
        tok = jnp.array([1, 2], jnp.int32)
        logits, kc2, vc2 = decode_step(params, tok, kc, vc, jnp.int32(16), CFG)
        assert logits.shape == (2, CFG.vocab)
        assert kc2.shape == kc.shape

    def test_decode_matches_prefill(self, params):
        """Teacher-forcing consistency: decode_step(t_n | prefill(t_0..t_{n-1}))
        must reproduce prefill(t_0..t_n) logits at the last position."""
        t = _toks(1, 9)
        full_logits, _, _ = prefill(params, t, CFG)
        _, kc, vc = prefill(params, t[:, :8], CFG)
        logits, _, _ = decode_step(params, t[:, 8], kc, vc, jnp.int32(8), CFG)
        np.testing.assert_allclose(
            logits, full_logits[:, 8, :], rtol=5e-4, atol=5e-4
        )

    def test_multi_step_chain(self, params):
        """3 chained decode steps == prefill over the extended sequence."""
        t = _toks(1, 12)
        full_logits, _, _ = prefill(params, t, CFG)
        _, kc, vc = prefill(params, t[:, :9], CFG)
        for i in range(9, 12):
            logits, kc, vc = decode_step(params, t[:, i], kc, vc, jnp.int32(i), CFG)
        np.testing.assert_allclose(
            logits, full_logits[:, 11, :], rtol=1e-3, atol=1e-3
        )

    def test_reference_generate_deterministic(self, params):
        g1 = reference_generate(params, CFG, [1, 2, 3, 4], n_new=6)
        g2 = reference_generate(params, CFG, [1, 2, 3, 4], n_new=6)
        assert g1 == g2
        assert all(0 <= t < CFG.vocab for t in g1)


class TestInit:
    def test_deterministic(self):
        p1 = init_params(CFG, seed=0)
        p2 = init_params(CFG, seed=0)
        for a, b in zip(p1, p2):
            np.testing.assert_array_equal(a, b)

    def test_seed_changes_params(self):
        p1 = init_params(CFG, seed=0)
        p2 = init_params(CFG, seed=1)
        assert not np.allclose(p1[0], p2[0])

    def test_num_params_matches_specs(self):
        n = sum(int(np.prod(s)) for _, s in CFG.param_specs())
        assert CFG.num_params() == n

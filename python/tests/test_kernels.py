"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the compute layer — hypothesis
sweeps shapes, block sizes and seeds; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import causal_attention
from compile.kernels.decode_attn import decode_attention
from compile.kernels.ref import causal_attention_ref, decode_attention_ref

TOL = dict(rtol=2e-5, atol=2e-5)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Prefill (causal flash) kernel
# ---------------------------------------------------------------------------

class TestCausalAttention:
    def test_matches_ref_basic(self):
        q, k, v = (_rand(i, (2, 4, 64, 32)) for i in range(3))
        np.testing.assert_allclose(
            causal_attention(q, k, v), causal_attention_ref(q, k, v), **TOL
        )

    def test_single_head_single_batch(self):
        q, k, v = (_rand(10 + i, (1, 1, 16, 8)) for i in range(3))
        np.testing.assert_allclose(
            causal_attention(q, k, v), causal_attention_ref(q, k, v), **TOL
        )

    def test_block_smaller_than_seq(self):
        q, k, v = (_rand(20 + i, (1, 2, 128, 16)) for i in range(3))
        out = causal_attention(q, k, v, block_q=32, block_k=32)
        np.testing.assert_allclose(out, causal_attention_ref(q, k, v), **TOL)

    def test_asymmetric_blocks(self):
        q, k, v = (_rand(30 + i, (1, 2, 64, 16)) for i in range(3))
        out = causal_attention(q, k, v, block_q=16, block_k=32)
        np.testing.assert_allclose(out, causal_attention_ref(q, k, v), **TOL)

    def test_causality_future_keys_ignored(self):
        """Perturbing K/V at positions > t must not change output at t."""
        q, k, v = (_rand(40 + i, (1, 1, 32, 8)) for i in range(3))
        out1 = causal_attention(q, k, v)
        k2 = k.at[:, :, 16:, :].set(99.0)
        v2 = v.at[:, :, 16:, :].set(-99.0)
        out2 = causal_attention(q, k2, v2)
        np.testing.assert_allclose(out1[:, :, :16], out2[:, :, :16], **TOL)

    def test_first_token_attends_only_itself(self):
        q, k, v = (_rand(50 + i, (1, 1, 16, 8)) for i in range(3))
        out = causal_attention(q, k, v)
        np.testing.assert_allclose(out[:, :, 0, :], v[:, :, 0, :], **TOL)

    def test_rejects_indivisible_blocks(self):
        q, k, v = (_rand(60 + i, (1, 1, 48, 8)) for i in range(3))
        with pytest.raises(ValueError):
            causal_attention(q, k, v, block_q=32, block_k=32)

    def test_scale_is_inv_sqrt_d(self):
        """Uniform V ⇒ output == V regardless of scale correctness; use
        structured Q/K to confirm softmax scaling matches the oracle."""
        q = jnp.ones((1, 1, 8, 4)) * 3.0
        k = _rand(70, (1, 1, 8, 4))
        v = _rand(71, (1, 1, 8, 4))
        np.testing.assert_allclose(
            causal_attention(q, k, v), causal_attention_ref(q, k, v), **TOL
        )

    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 4),
        s_pow=st.integers(3, 7),  # 8..128
        d_pow=st.integers(2, 5),  # 4..32
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, b, h, s_pow, d_pow, seed):
        s, d = 2 ** s_pow, 2 ** d_pow
        key = jax.random.PRNGKey(seed)
        q, k, v = (
            jax.random.normal(jax.random.fold_in(key, i), (b, h, s, d), jnp.float32)
            for i in range(3)
        )
        np.testing.assert_allclose(
            causal_attention(q, k, v), causal_attention_ref(q, k, v), **TOL
        )

    @settings(max_examples=8, deadline=None)
    @given(scale_exp=st.integers(-2, 4), seed=st.integers(0, 2**16))
    def test_hypothesis_magnitudes(self, scale_exp, seed):
        """Online softmax must be stable across input magnitudes."""
        key = jax.random.PRNGKey(seed)
        mag = 10.0 ** scale_exp
        q, k, v = (
            mag * jax.random.normal(jax.random.fold_in(key, i), (1, 2, 32, 8))
            for i in range(3)
        )
        out = causal_attention(q, k, v)
        ref = causal_attention_ref(q, k, v)
        assert bool(jnp.all(jnp.isfinite(out)))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Decode kernel
# ---------------------------------------------------------------------------

class TestDecodeAttention:
    def test_matches_ref_basic(self):
        q = _rand(0, (2, 4, 32))
        kc = _rand(1, (2, 4, 128, 32))
        vc = _rand(2, (2, 4, 128, 32))
        np.testing.assert_allclose(
            decode_attention(q, kc, vc, jnp.int32(77)),
            decode_attention_ref(q, kc, vc, 77),
            **TOL,
        )

    def test_length_one(self):
        q = _rand(10, (1, 1, 8))
        kc = _rand(11, (1, 1, 16, 8))
        vc = _rand(12, (1, 1, 16, 8))
        out = decode_attention(q, kc, vc, jnp.int32(1))
        np.testing.assert_allclose(out, vc[:, :, 0, :], **TOL)

    def test_full_cache(self):
        q = _rand(20, (2, 2, 16))
        kc = _rand(21, (2, 2, 64, 16))
        vc = _rand(22, (2, 2, 64, 16))
        np.testing.assert_allclose(
            decode_attention(q, kc, vc, jnp.int32(64)),
            decode_attention_ref(q, kc, vc, 64),
            **TOL,
        )

    def test_masked_region_ignored(self):
        """Garbage beyond `length` must not leak into the output."""
        q = _rand(30, (1, 2, 8))
        kc = _rand(31, (1, 2, 32, 8))
        vc = _rand(32, (1, 2, 32, 8))
        out1 = decode_attention(q, kc, vc, jnp.int32(10))
        kc2 = kc.at[:, :, 10:, :].set(1e4)
        vc2 = vc.at[:, :, 10:, :].set(-1e4)
        out2 = decode_attention(q, kc2, vc2, jnp.int32(10))
        np.testing.assert_allclose(out1, out2, **TOL)

    def test_non_pow2_capacity(self):
        """Capacity 160 (the model default) exercises block-size shrink."""
        q = _rand(40, (1, 2, 8))
        kc = _rand(41, (1, 2, 160, 8))
        vc = _rand(42, (1, 2, 160, 8))
        np.testing.assert_allclose(
            decode_attention(q, kc, vc, jnp.int32(100)),
            decode_attention_ref(q, kc, vc, 100),
            **TOL,
        )

    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 4),
        t_pow=st.integers(3, 7),
        d_pow=st.integers(2, 5),
        seed=st.integers(0, 2**16),
        frac=st.floats(0.05, 1.0),
    )
    def test_hypothesis_shapes_lengths(self, b, h, t_pow, d_pow, seed, frac):
        t, d = 2 ** t_pow, 2 ** d_pow
        length = max(1, int(t * frac))
        key = jax.random.PRNGKey(seed)
        q = jax.random.normal(jax.random.fold_in(key, 0), (b, h, d))
        kc = jax.random.normal(jax.random.fold_in(key, 1), (b, h, t, d))
        vc = jax.random.normal(jax.random.fold_in(key, 2), (b, h, t, d))
        np.testing.assert_allclose(
            decode_attention(q, kc, vc, jnp.int32(length)),
            decode_attention_ref(q, kc, vc, length),
            **TOL,
        )

    def test_decode_equals_prefill_last_row(self):
        """Decode over a cache == last row of causal attention over the
        same sequence (phase-consistency: the two kernels implement the
        same attention, split the GreenLLM way)."""
        b, h, s, d = 1, 2, 32, 8
        q, k, v = (_rand(50 + i, (b, h, s, d)) for i in range(3))
        full = causal_attention_ref(q, k, v)[:, :, s - 1, :]
        out = decode_attention(q[:, :, s - 1, :], k, v, jnp.int32(s))
        np.testing.assert_allclose(out, full, **TOL)

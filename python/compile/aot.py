"""AOT export: lower TinyLM prefill/decode to HLO *text* artifacts.

Build-time only — Python never runs on the request path. The Rust runtime
(`rust/src/runtime`) loads these artifacts via `HloModuleProto::from_text_file`
on the PJRT CPU client.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly.

Outputs (under --out, default ../artifacts):
  prefill_s{S}.hlo.txt     one per prefill sequence bucket
  decode_step.hlo.txt      single-token decode step
  params.bin               f32 little-endian, concatenated in ABI order
  manifest.json            config, param table, buckets, test vectors
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import ModelConfig, decode_step, init_params, prefill, reference_generate

# Prefill sequence-length buckets: requests are padded up to the nearest
# bucket by the Rust batcher (mirrors production serving engines that
# compile one executable per shape bucket).
PREFILL_BUCKETS = (16, 32, 64)
BATCH = 4  # static batch per executable; the batcher packs/pads to this


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(out_dir: str, cfg: ModelConfig, seed: int = 0) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    params = init_params(cfg, seed=seed)
    specs = cfg.param_specs()

    # --- params.bin: flat f32 LE in ABI order -------------------------------
    flat = np.concatenate([np.asarray(p, np.float32).reshape(-1) for p in params])
    params_path = os.path.join(out_dir, "params.bin")
    flat.astype("<f4").tofile(params_path)
    params_sha = hashlib.sha256(flat.astype("<f4").tobytes()).hexdigest()

    param_specs = [
        {"name": name, "shape": list(shape)} for (name, shape) in specs
    ]

    files = {}

    # --- prefill buckets ----------------------------------------------------
    buckets = [s for s in PREFILL_BUCKETS if s <= cfg.max_seq]
    pspecs = [jax.ShapeDtypeStruct(tuple(s), jnp.float32) for _, s in specs]
    for s_len in buckets:
        tok_spec = jax.ShapeDtypeStruct((BATCH, s_len), jnp.int32)

        def fn(params, tokens, _s=s_len):
            return prefill(params, tokens, cfg)

        lowered = jax.jit(fn).lower(pspecs, tok_spec)
        text = to_hlo_text(lowered)
        fname = f"prefill_s{s_len}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[f"prefill_s{s_len}"] = fname

    # --- decode step ---------------------------------------------------------
    kv_spec = jax.ShapeDtypeStruct(
        (cfg.n_layers, BATCH, cfg.n_heads, cfg.max_seq, cfg.d_head), jnp.float32
    )
    tok1 = jax.ShapeDtypeStruct((BATCH,), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)

    def dfn(params, token, kc, vc, pos):
        return decode_step(params, token, kc, vc, pos, cfg)

    lowered = jax.jit(dfn).lower(pspecs, tok1, kv_spec, kv_spec, pos_spec)
    with open(os.path.join(out_dir, "decode_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    files["decode_step"] = "decode_step.hlo.txt"

    # --- test vectors for the Rust integration tests -------------------------
    s0 = PREFILL_BUCKETS[0]
    toks = (np.arange(BATCH * s0, dtype=np.int32).reshape(BATCH, s0) * 7 + 3) % cfg.vocab
    logits, kc, vc = prefill(params, jnp.asarray(toks), cfg)
    last = np.asarray(logits)[:, s0 - 1, :]
    prompt = [int(x) for x in toks[0][: min(8, s0)]]
    greedy = reference_generate(params, cfg, prompt, n_new=8)

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "d_head": cfg.d_head,
            "num_params": int(flat.size),
        },
        "batch": BATCH,
        "prefill_buckets": buckets,
        "files": files,
        "params_file": "params.bin",
        "params_sha256": params_sha,
        "seed": seed,
        "test_vectors": {
            "prefill_tokens_formula": "tokens[i] = (i*7 + 3) % vocab, row-major [B,S0]",
            "prefill_bucket": s0,
            "last_logits_sum": float(np.sum(last)),
            "last_logits_absmean": float(np.mean(np.abs(last))),
            "last_logits_row0_head": [float(x) for x in last[0, :8]],
            "greedy_prompt": prompt,
            "greedy_next_tokens": greedy,
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = ModelConfig()
    m = export(args.out, cfg, seed=args.seed)
    total = m["model"]["num_params"]
    print(f"exported TinyLM ({total} params) to {args.out}: {sorted(m['files'])}")


if __name__ == "__main__":
    main()

"""Layer-2 JAX model: TinyLM, a decoder-only transformer served by the
Rust coordinator through PJRT.

The trace-scale experiments model Qwen3-14B / Qwen3-30B-MoE analytically
(rust/src/model); TinyLM is the *real* model that proves the serving code
path end-to-end: tokenize → route → prefill (flash kernel) → KV handoff →
batched decode (decode kernel) → stream. Architecture mirrors Qwen3's
block structure at toy scale: RMSNorm → causal attention → RMSNorm →
SwiGLU FFN, learned positional embeddings, weight-tied-free LM head.

Two AOT entry points (both lowered to HLO text by ``aot.py``):

  prefill(params, tokens[B,S])                -> (logits[B,S,V], K, V)
  decode_step(params, token[B], K, V, pos)    -> (logits[B,V], K', V')

K/V have layout ``[L, B, H, T, Dh]`` with static capacity T; ``pos`` is the
number of tokens already in the cache (scalar int32).
"""

import dataclasses

import jax
import jax.numpy as jnp

from .kernels.attention import causal_attention
from .kernels.decode_attn import decode_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """TinyLM hyperparameters. Defaults keep artifact build fast on 1 CPU."""

    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 256
    max_seq: int = 160  # KV-cache capacity (prefill bucket + decode budget)

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_specs(self):
        """Ordered (name, shape) list — the AOT calling convention.

        The Rust runtime feeds parameters positionally in exactly this
        order (recorded in artifacts/manifest.json), so the order is part
        of the ABI: append only.
        """
        c = self
        specs = [
            ("embed", (c.vocab, c.d_model)),
            ("pos_embed", (c.max_seq, c.d_model)),
        ]
        for i in range(c.n_layers):
            specs += [
                (f"l{i}.norm1", (c.d_model,)),
                (f"l{i}.wq", (c.d_model, c.d_model)),
                (f"l{i}.wk", (c.d_model, c.d_model)),
                (f"l{i}.wv", (c.d_model, c.d_model)),
                (f"l{i}.wo", (c.d_model, c.d_model)),
                (f"l{i}.norm2", (c.d_model,)),
                (f"l{i}.w_gate", (c.d_model, c.d_ff)),
                (f"l{i}.w_up", (c.d_model, c.d_ff)),
                (f"l{i}.w_down", (c.d_ff, c.d_model)),
            ]
        specs += [
            ("final_norm", (c.d_model,)),
            ("lm_head", (c.d_model, c.vocab)),
        ]
        return specs

    def num_params(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.param_specs())


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic init — the same seed reproduces identical artifacts."""
    key = jax.random.PRNGKey(seed)
    params = []
    for i, (name, shape) in enumerate(cfg.param_specs()):
        k = jax.random.fold_in(key, i)
        if name.endswith(("norm1", "norm2", "final_norm")):
            p = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else cfg.d_model
            p = jax.random.normal(k, shape, jnp.float32) * (fan_in ** -0.5)
        params.append(p)
    return params


def _rms_norm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _split_heads(x, n_heads):
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _layer_params(params, cfg, i):
    base = 2 + i * 9
    return params[base : base + 9]


def prefill(params, tokens, cfg: ModelConfig):
    """Process the whole prompt; return logits and the populated KV cache.

    tokens: [B, S] int32, S <= cfg.max_seq. The KV cache is returned at
    full static capacity T=cfg.max_seq (rows >= S are zero) so decode can
    append in place.
    """
    b, s = tokens.shape
    c = cfg
    x = params[0][tokens] + params[1][:s][None, :, :]

    ks, vs = [], []
    for i in range(c.n_layers):
        norm1, wq, wk, wv, wo, norm2, wg, wu, wd = _layer_params(params, c, i)
        h = _rms_norm(x, norm1)
        q = _split_heads(h @ wq, c.n_heads)
        k = _split_heads(h @ wk, c.n_heads)
        v = _split_heads(h @ wv, c.n_heads)
        attn = causal_attention(q, k, v)  # L1 Pallas flash kernel
        x = x + _merge_heads(attn) @ wo

        h2 = _rms_norm(x, norm2)
        x = x + (jax.nn.silu(h2 @ wg) * (h2 @ wu)) @ wd

        pad = c.max_seq - s
        ks.append(jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))))
        vs.append(jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))))

    logits = _rms_norm(x, params[-2]) @ params[-1]
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode_step(params, token, k_cache, v_cache, pos, cfg: ModelConfig):
    """One autoregressive step for a batch of streams sharing position pos.

    token: [B] int32; k/v_cache: [L, B, H, T, Dh]; pos: scalar int32 =
    number of valid cache rows (the new token is written at index pos).
    Returns (logits[B, V], k_cache', v_cache').
    """
    c = cfg
    b = token.shape[0]
    x = params[0][token] + jax.lax.dynamic_index_in_dim(params[1], pos, 0, keepdims=False)
    x = x[:, None, :]  # [B, 1, D]

    new_ks, new_vs = [], []
    for i in range(c.n_layers):
        norm1, wq, wk, wv, wo, norm2, wg, wu, wd = _layer_params(params, c, i)
        h = _rms_norm(x, norm1)
        q = _split_heads(h @ wq, c.n_heads)[:, :, 0, :]  # [B, H, Dh]
        k_new = _split_heads(h @ wk, c.n_heads)[:, :, 0, :]
        v_new = _split_heads(h @ wv, c.n_heads)[:, :, 0, :]

        # Append at index pos, then attend over pos+1 valid rows.
        k_l = jax.lax.dynamic_update_slice(
            k_cache[i], k_new[:, :, None, :], (0, 0, pos, 0)
        )
        v_l = jax.lax.dynamic_update_slice(
            v_cache[i], v_new[:, :, None, :], (0, 0, pos, 0)
        )
        attn = decode_attention(q, k_l, v_l, pos + 1)  # L1 Pallas decode kernel
        x = x + (attn.reshape(b, 1, c.d_model)) @ wo

        h2 = _rms_norm(x, norm2)
        x = x + (jax.nn.silu(h2 @ wg) * (h2 @ wu)) @ wd
        new_ks.append(k_l)
        new_vs.append(v_l)

    logits = (_rms_norm(x, params[-2]) @ params[-1])[:, 0, :]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def reference_generate(params, cfg: ModelConfig, prompt, n_new: int):
    """Greedy generation oracle used by python tests (prefill+decode loop)."""
    tokens = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, kc, vc = prefill(params, tokens, cfg)
    out = []
    nxt = jnp.argmax(logits[:, tokens.shape[1] - 1, :], axis=-1).astype(jnp.int32)
    pos = tokens.shape[1]
    for _ in range(n_new):
        out.append(int(nxt[0]))
        logits, kc, vc = decode_step(params, nxt, kc, vc, jnp.int32(pos), cfg)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos += 1
    return out

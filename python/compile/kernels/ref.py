"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: the Pallas kernels in
``attention.py`` / ``decode_attn.py`` must match these to float32
tolerance (pytest + hypothesis sweep shapes and seeds).
"""

import jax.numpy as jnp


def causal_attention_ref(q, k, v, scale=None):
    """Reference causal attention (the prefill hot-spot).

    Args:
      q, k, v: ``[B, H, S, D]`` float32.
      scale: optional softmax scale; defaults to ``1/sqrt(D)``.

    Returns:
      ``[B, H, S, D]`` attention output.
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    seq = q.shape[2]
    mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
    s = jnp.where(mask[None, None, :, :], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def decode_attention_ref(q, k_cache, v_cache, length, scale=None):
    """Reference single-token decode attention (the decode hot-spot).

    Args:
      q: ``[B, H, D]`` query for the new token.
      k_cache, v_cache: ``[B, H, T, D]`` KV cache (capacity T).
      length: number of valid cache entries (positions >= length are masked).
      scale: optional softmax scale.

    Returns:
      ``[B, H, D]`` attention output.
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    s = jnp.einsum("bhd,bhtd->bht", q, k_cache) * scale
    t = k_cache.shape[2]
    valid = jnp.arange(t) < length
    s = jnp.where(valid[None, None, :], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bht,bhtd->bhd", p, v_cache)

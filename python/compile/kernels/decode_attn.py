"""Layer-1 Pallas kernel: single-token decode attention over a KV cache.

The decode phase generates one token at a time: a single query row per
(batch, head) attends over the whole KV cache. Arithmetic intensity is
O(1) FLOP per byte of cache streamed from HBM — this is the memory-bound
phase whose latency saturates with SM clock (GreenLLM §2.2.2, Takeaway #2)
and therefore wants a *lower* energy-optimal frequency than prefill.

TPU adaptation: the cache is streamed HBM→VMEM in ``block_t`` chunks via
the BlockSpec/dslice schedule; there is no MXU-shaped matmul here, just
VPU dot-products — which is exactly the structural reason the phase is
clock-insensitive. ``interpret=True`` as everywhere (CPU PJRT).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_t: int, scale: float):
    """One (batch*head,) program: q [1, D] against cache [T, D].

    len_ref is a scalar-prefetch style operand: number of valid cache rows.
    """
    t, d = k_ref.shape
    length = len_ref[0]
    q = q_ref[...].astype(jnp.float32) * scale  # [1, D]

    m0 = jnp.full((1,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((1,), dtype=jnp.float32)
    acc0 = jnp.zeros((1, d), dtype=jnp.float32)

    num_blocks = t // block_t

    def body(tb, carry):
        m_prev, l_prev, acc_prev = carry
        t_start = tb * block_t
        k = pl.load(k_ref, (pl.dslice(t_start, block_t), slice(None)))
        v = pl.load(v_ref, (pl.dslice(t_start, block_t), slice(None)))
        s = q @ k.astype(jnp.float32).T  # [1, BT]
        cols = t_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_t), 1)
        s = jnp.where(cols < length, s, NEG_INF)

        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_new = acc_prev * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_blocks, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t",))
def decode_attention(q, k_cache, v_cache, length, block_t: int = 64):
    """Decode attention: ``q [B,H,D]`` over ``k/v_cache [B,H,T,D]``.

    ``length`` (scalar int32) masks cache rows >= length. T must be a
    multiple of ``block_t`` (cache capacity is allocated in blocks by the
    Rust KV-cache manager, so this holds by construction).
    """
    b, h, d = q.shape
    t = k_cache.shape[2]
    block_t = min(block_t, t)
    while t % block_t != 0:  # shrink to the largest divisor (cache capacities
        block_t //= 2        # are block-allocated, so this terminates fast)
    if block_t == 0:
        raise ValueError(f"cannot tile cache capacity {t}")
    scale = 1.0 / (d ** 0.5)

    qf = q.reshape(b * h, 1, d)
    kf = k_cache.reshape(b * h, t, d)
    vf = v_cache.reshape(b * h, t, d)
    length_arr = jnp.reshape(length, (1,)).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_t=block_t, scale=scale),
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1,), lambda bh: (0,)),
            pl.BlockSpec((None, 1, d), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((None, t, d), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((None, t, d), lambda bh: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, 1, d), lambda bh: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, 1, d), q.dtype),
        interpret=True,
    )(length_arr, qf, kf, vf)
    return out.reshape(b, h, d)

"""Layer-1 Pallas kernel: blockwise causal flash-attention (prefill).

This is the compute hot-spot of the *prefill* phase — the ``C n^2`` causal
attention term of Eq. (1) in the GreenLLM paper. Prefill is compute-bound,
which is precisely why its energy-optimal SM clock sits high (Takeaway #1);
the decode kernel (``decode_attn.py``) is memory-bound and sits low.

TPU hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's
testbed runs TensorRT fused attention on A100s. Here the same computation
is expressed as a Pallas kernel tiled for the MXU:

  * grid over (batch*heads, Q blocks): each program owns one ``[BQ, D]``
    query tile resident in VMEM,
  * K/V are streamed block-by-block from HBM with an online-softmax
    running (max, sum) pair — the standard flash recurrence — so VMEM
    holds only O(BQ*D + BK*D) at any time,
  * the causal triangle is exploited by stopping the K loop at the last
    block that intersects the query tile (the ``alpha ~ 1/2`` factor in
    Eq. (1)).

MUST run ``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO so the whole model
AOT-exports to something the Rust runtime can load.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    """One (batch*head, q-block) program: stream K/V blocks, online softmax.

    Shapes as seen by the kernel (leading grid dims already sliced away):
      q_ref: [BQ, D]   query tile for this program
      k_ref: [S,  D]   full K for this (b, h)
      v_ref: [S,  D]   full V for this (b, h)
      o_ref: [BQ, D]   output tile
    """
    block_q, d = q_ref.shape
    seq = k_ref.shape[0]
    q_blk = pl.program_id(1)
    q_start = q_blk * block_q

    q = q_ref[...].astype(jnp.float32) * scale

    # Online-softmax state: running max m, running denom l, accumulator acc.
    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)

    # Causal: Q rows [q_start, q_start+BQ) attend keys <= row index, so only
    # K blocks up to and including the diagonal block contribute. This is
    # the alpha≈1/2 triangle saving of Eq. (1).
    num_k_blocks = (q_start + block_q + block_k - 1) // block_k

    def body(kb, carry):
        m_prev, l_prev, acc_prev = carry
        k_start = kb * block_k
        k = pl.load(k_ref, (pl.dslice(k_start, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(k_start, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T  # [BQ, BK] — MXU tile matmul

        # Causal mask within the block.
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_new = acc_prev * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_k_blocks, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)
    del seq  # shape bookkeeping only


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def causal_attention(q, k, v, block_q: int = 64, block_k: int = 64):
    """Causal flash attention over ``[B, H, S, D]`` tensors.

    ``block_q``/``block_k`` are the VMEM tile sizes; on a real TPU these
    would be 128-aligned for the MXU — defaults shrink automatically for
    short sequences so the kernel stays exact.
    """
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q != 0 or s % block_k != 0:
        raise ValueError(f"seq {s} must be divisible by blocks {block_q}/{block_k}")
    scale = 1.0 / (d ** 0.5)

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)

    grid = (b * h, s // block_q)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, qb: (bh, qb, 0)),
            pl.BlockSpec((None, s, d), lambda bh, qb: (bh, 0, 0)),
            pl.BlockSpec((None, s, d), lambda bh, qb: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda bh, qb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)

//! Fig. 12 reproduction: sweep the SLO margin factors and show the smooth
//! energy–latency tradeoff (§5.3) — tighter margins burn energy for
//! latency, looser margins save energy while drifting toward the deadline.
//!
//! Run: `cargo run --release --example margin_sweep`

use greenllm::bench::figures::{fig12a, fig12b};

fn main() {
    let duration = 240.0;
    let a = fig12a(duration, 42);
    let b = fig12b(duration, 42);

    // Sanity narrative: energy should fall (weakly) as margins loosen.
    let first = &a[0];
    let last = &a[a.len() - 1];
    println!(
        "prefill: margin {:.2} -> {:.2}: energy {:.1} -> {:.1} kJ, P90 TTFT {:.0} -> {:.0} ms",
        first.margin,
        last.margin,
        first.energy_j / 1e3,
        last.energy_j / 1e3,
        first.p90_ms,
        last.p90_ms
    );
    let first = &b[0];
    let last = &b[b.len() - 1];
    println!(
        "decode:  margin {:.2} -> {:.2}: energy {:.1} -> {:.1} kJ, P90 TBT {:.1} -> {:.1} ms",
        first.margin,
        last.margin,
        first.energy_j / 1e3,
        last.energy_j / 1e3,
        first.p90_ms,
        last.p90_ms
    );
}

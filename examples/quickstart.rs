//! Quickstart: the GreenLLM public API in ~40 lines.
//!
//! Generates a small chat workload, replays it under NVIDIA's default
//! governor and under GreenLLM's phase-aware DVFS, and prints the
//! energy/SLO comparison — the paper's headline claim in miniature.
//!
//! Run: `cargo run --release --example quickstart`

use greenllm::config::{Config, Method};
use greenllm::coordinator::engine::{run, RunOptions};
use greenllm::workload::alibaba::{generate, ChatParams};

fn main() {
    // 1. A workload: 3 QPS of chat traffic for five simulated minutes.
    let trace = generate(&ChatParams::new(3.0, 300.0), 42);
    println!(
        "workload: {} requests, {:.0} prefill tok/s, {:.0} decode tok/s\n",
        trace.requests.len(),
        trace.prefill_tps(),
        trace.decode_tps()
    );

    // 2. Replay under both policies on the simulated DGX-A100 node.
    let mut results = Vec::new();
    for method in [Method::DefaultNv, Method::GreenLlm] {
        let cfg = Config {
            method,
            seed: 42,
            ..Config::default()
        };
        let r = run(&cfg, &trace, &RunOptions::default());
        println!(
            "{:<10} energy {:7.1} kJ | TTFT pass {:5.1}% | TBT pass {:5.1}% | {:.0} tok/s",
            method.name(),
            r.total_energy_j / 1e3,
            r.slo.ttft_pass_rate() * 100.0,
            r.slo.tbt_pass_rate() * 100.0,
            r.throughput_tps()
        );
        results.push(r);
    }

    // 3. The headline number.
    let saving = 1.0 - results[1].total_energy_j / results[0].total_energy_j;
    println!(
        "\nGreenLLM saves {:.1}% node energy at equal throughput (paper: 10-34%).",
        saving * 100.0
    );
    println!("Next: `cargo run --release -- help` for every table/figure driver.");
}

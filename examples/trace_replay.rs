//! Replay one production-style trace under all three methods and print a
//! Table-3-style comparison row.
//!
//! Run: `cargo run --release --example trace_replay [-- qps]`

use greenllm::bench::{compare_methods, tables::render_rows};
use greenllm::workload::alibaba::{generate, ChatParams};

fn main() {
    let qps: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(5.0);
    let trace = generate(&ChatParams::new(qps, 300.0), 42);
    println!(
        "trace {}: {} requests over {:.0}s ({:.0} decode tok/s demand)\n",
        trace.name,
        trace.requests.len(),
        trace.duration_s,
        trace.decode_tps()
    );
    let rows = compare_methods("qwen3-14b", &trace, 42);
    render_rows(&format!("Table-3 row: {}", trace.name), &rows);
    let green = &rows[2];
    println!(
        "GreenLLM: {:.1}% total energy saving, decode at {:.3}x defaultNV",
        green.delta_energy_pct, green.rel_decode
    );
}

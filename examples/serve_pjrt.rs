//! End-to-end REAL serving driver (the DESIGN.md §4 validation run).
//!
//! Loads the AOT-compiled TinyLM artifacts through the PJRT CPU client and
//! serves a batch of real prompts through the full stack — tokenize →
//! length-batch → prefill (Pallas flash-attention kernel, lowered to HLO)
//! → KV cache → batched decode (Pallas decode kernel) → detokenize —
//! reporting latency and throughput percentiles. Python is not involved;
//! the artifacts were built once by `make artifacts`.
//!
//! Run: `make artifacts && cargo run --release --example serve_pjrt`

use greenllm::server::{ServerConfig, ServerHandle};
use std::time::Instant;

const PROMPTS: &[&str] = &[
    "How do I reduce GPU power draw while serving an LLM?",
    "Summarize the prefill/decode asymmetry in one sentence.",
    "Why is decode memory-bound on modern accelerators?",
    "Explain dynamic voltage and frequency scaling briefly.",
    "What is head-of-line blocking in request queues?",
    "Give me a haiku about energy-efficient inference.",
    "What does TTFT measure and why do users care?",
    "When should a governor lower the SM clock?",
    "Describe a dual-loop feedback controller.",
    "What is a service-level objective?",
    "How does continuous batching improve utilization?",
    "Name one way to exploit SLO slack for energy.",
    "What happens past the energy knee frequency?",
    "Why pin memory clocks during SM frequency sweeps?",
    "How large is a KV cache per token, roughly?",
    "What makes long prompts expensive in prefill?",
];

fn main() -> greenllm::util::error::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("loading + compiling artifacts from {dir}/ (PJRT CPU)...");
    let t_load = Instant::now();
    let server = ServerHandle::start(ServerConfig {
        artifacts_dir: dir.into(),
        ..Default::default()
    })?;
    println!("engine ready in {:.2}s\n", t_load.elapsed().as_secs_f64());

    let max_new = 24;
    let t0 = Instant::now();
    let rxs: Vec<_> = PROMPTS.iter().map(|p| server.submit(p, max_new)).collect();

    let mut ttfts = Vec::new();
    let mut tbts = Vec::new();
    let mut total_tokens = 0usize;
    for rx in rxs {
        let c = rx.recv()?;
        total_tokens += c.tokens.len();
        ttfts.push(c.ttft_s * 1e3);
        tbts.extend(c.tbts.iter().map(|t| t * 1e3));
        let preview: String = c.prompt.chars().take(44).collect();
        println!(
            "  #{:<3} ttft {:7.1} ms | {} tok | {preview}",
            c.id,
            c.ttft_s * 1e3,
            c.tokens.len()
        );
    }
    let wall = t0.elapsed().as_secs_f64();

    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    tbts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |v: &[f64], q: f64| v[((q * v.len() as f64) as usize).min(v.len() - 1)];
    println!(
        "\nserved {} requests / {} tokens in {:.2}s  →  {:.0} tok/s",
        PROMPTS.len(),
        total_tokens,
        wall,
        total_tokens as f64 / wall
    );
    println!(
        "TTFT  p50 {:7.1} ms   p90 {:7.1} ms   max {:7.1} ms",
        pct(&ttfts, 0.50),
        pct(&ttfts, 0.90),
        ttfts.last().unwrap()
    );
    println!(
        "TBT   p50 {:7.2} ms   p95 {:7.2} ms   max {:7.2} ms",
        pct(&tbts, 0.50),
        pct(&tbts, 0.95),
        tbts.last().unwrap()
    );
    let stats = server.shutdown()?;
    println!(
        "batches {} | batched requests {} | mean batch {:.2}",
        stats.batches,
        stats.batched_requests,
        stats.batched_requests as f64 / stats.batches.max(1) as f64
    );
    println!("\n(all three layers composed: Pallas kernels → JAX model → HLO → PJRT → Rust coordinator)");
    Ok(())
}

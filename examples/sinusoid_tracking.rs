//! Fig. 1 reproduction: drive decoding with a sinusoidal TPS target and
//! dump a CSV of (time, decode TPS, defaultNV clock, GreenLLM clock) —
//! defaultNV sits in a narrow high band while GreenLLM tracks demand.
//!
//! Run: `cargo run --release --example sinusoid_tracking > fig1.csv`

use greenllm::bench::figures::fig1;

fn main() {
    let out = fig1(360.0, 42);
    eprintln!("--- CSV on stdout ---");
    println!("t_s,decode_tps,defaultnv_mhz,greenllm_mhz");
    let n = out.series[0].1.len().min(out.series[1].1.len());
    for i in 0..n {
        let (t, tps, f_nv) = out.series[0].1[i];
        let (_, _, f_g) = out.series[1].1[i];
        println!("{t:.1},{tps:.0},{f_nv},{f_g}");
    }
}

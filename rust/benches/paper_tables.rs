//! `cargo bench` target: regenerate Tables 3 & 4 end-to-end (shortened
//! horizon) and report wall-clock per table. criterion is not in the
//! offline mirror, so this is a `harness = false` timing main.

use greenllm::bench::tables;
use std::time::Instant;

fn main() {
    let duration_s = arg_f64("--duration", 180.0);
    let seed = 42;

    println!("# paper_tables bench: {duration_s}s trace horizon per workload\n");

    let t0 = Instant::now();
    let rows3 = tables::table3(duration_s, seed);
    let t3 = t0.elapsed();

    let t0 = Instant::now();
    let rows4 = tables::table4(duration_s, seed);
    let t4 = t0.elapsed();

    // Headline assertions (shape, not absolutes — see EXPERIMENTS.md).
    let green_rows3: Vec<_> = rows3
        .iter()
        .filter(|r| r.method == greenllm::config::Method::GreenLlm)
        .collect();
    let max_saving = green_rows3
        .iter()
        .map(|r| r.delta_energy_pct)
        .fold(f64::MIN, f64::max);
    let min_saving = green_rows3
        .iter()
        .map(|r| r.delta_energy_pct)
        .fold(f64::MAX, f64::min);
    println!(
        "table3: {} rows in {:.1}s | GreenLLM dEn range {:.1}%..{:.1}% (paper: 6.8%..34.1%)",
        rows3.len(),
        t3.as_secs_f64(),
        min_saving,
        max_saving
    );
    println!(
        "table4: {} rows in {:.1}s",
        rows4.len(),
        t4.as_secs_f64()
    );
    assert!(max_saving > 15.0, "headline savings collapsed: {max_saving}");
}

fn arg_f64(name: &str, default: f64) -> f64 {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

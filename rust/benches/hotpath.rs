//! `cargo bench` target: L3 hot-path microbenchmarks (harness = false;
//! warmup + median-of-runs, no criterion offline).
//!
//! Targets (DESIGN.md §6): replay ≥ 1 M sim-events/s; controller fine tick
//! < 1 µs; router+queue op < 200 ns; histogram record ~ns.

use greenllm::config::{Config, DecodeCtlConfig, Method};
use greenllm::coordinator::engine::{run, RunOptions};
use greenllm::coordinator::router::Router;
use greenllm::dvfs::decode_ctl::DecodeController;
use greenllm::dvfs::prefill_opt::{PrefillJobView, PrefillOptimizer};
use greenllm::dvfs::profiler::Profiler;
use greenllm::gpu::perf::PerfModel;
use greenllm::gpu::power::PowerModel;
use greenllm::metrics::Histogram;
use greenllm::model::ModelSpec;
use greenllm::sim::EventQueue;
use greenllm::util::rng::Pcg64;
use greenllm::workload::alibaba::{generate, ChatParams};
use greenllm::workload::request::Request;
use std::time::Instant;

/// Median wall time of `runs` timed executions of `f(iter_count)`.
fn bench(name: &str, iters: u64, runs: usize, mut f: impl FnMut(u64)) -> f64 {
    f(iters.min(1000)); // warmup
    let mut times: Vec<f64> = (0..runs)
        .map(|_| {
            let t0 = Instant::now();
            f(iters);
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let per_op = times[runs / 2] / iters as f64;
    let (val, unit) = if per_op < 1e-6 {
        (per_op * 1e9, "ns")
    } else if per_op < 1e-3 {
        (per_op * 1e6, "us")
    } else {
        (per_op * 1e3, "ms")
    };
    println!("{name:<40} {val:>9.1} {unit}/op   ({iters} iters x {runs} runs)");
    per_op
}

fn main() {
    println!("# hotpath microbenchmarks (median of 5)\n");

    // --- event queue -------------------------------------------------------
    bench("event_queue schedule+pop", 1_000_000, 5, |n| {
        let mut q = EventQueue::new();
        let mut acc = 0u64;
        for i in 0..n {
            q.schedule(i as f64 * 1e-3, i);
            if i % 4 == 3 {
                for _ in 0..4 {
                    acc += q.pop().map(|(_, e)| e).unwrap_or(0);
                }
            }
        }
        std::hint::black_box(acc);
    });

    // Replay shape: tens of thousands of arrivals pre-scheduled up
    // front, then drained with completions layered in — the calendar
    // backend's home turf (the heap paid O(log n) sifts here).
    bench("event_queue prescheduled drain", 50_000, 5, |n| {
        let mut q = EventQueue::new();
        for i in 0..n {
            q.schedule_priority(((i * 7919) % n) as f64 * 1e-3, i);
        }
        let mut acc = 0u64;
        while let Some((t, e)) = q.pop() {
            acc += e;
            // Only original arrivals spawn a follow-up (completions do
            // not re-spawn — the drain terminates).
            if e < n && e % 8 == 0 {
                q.schedule(t + 0.05, e + 1_000_000);
            }
        }
        std::hint::black_box(acc);
    });

    // --- router ------------------------------------------------------------
    let router = Router::new(true, 2);
    let reqs: Vec<Request> = (0..1024)
        .map(|i| Request {
            id: i,
            arrival_s: 0.0,
            prompt_len: ((i * 37) % 4096) as u32 + 1,
            output_len: 10,
        })
        .collect();
    bench("router queue_for", 10_000_000, 5, |n| {
        let mut acc = 0usize;
        for i in 0..n {
            acc += router.queue_for(&reqs[(i % 1024) as usize]);
        }
        std::hint::black_box(acc);
    });

    // --- decode controller fine tick ----------------------------------------
    let mut profiler = Profiler::new(
        PerfModel::new(ModelSpec::qwen3_14b()),
        PowerModel::a100(),
        0.02,
        1,
    );
    let table = profiler.build_band_table(1600.0, 100.0, 600.0, 0.095, 200);
    let mut ctl = DecodeController::new(DecodeCtlConfig::default(), table, 0.095);
    let mut rng = Pcg64::new(1, 1);
    for i in 0..256 {
        ctl.on_tokens(i as f64 * 0.01, 8);
        ctl.on_tbt(0.05 + 0.04 * rng.f64());
    }
    bench("decode_ctl fine_tick", 2_000_000, 5, |n| {
        let mut acc = 0u64;
        for i in 0..n {
            acc += ctl.fine_tick(i as f64 * 0.02) as u64;
        }
        std::hint::black_box(acc);
    });
    bench("decode_ctl on_tbt+coarse_tick", 1_000_000, 5, |n| {
        let mut acc = 0u64;
        for i in 0..n {
            ctl.on_tbt(0.05 + (i % 50) as f64 * 1e-3);
            if i % 10 == 0 {
                acc += ctl.coarse_tick(i as f64 * 0.02).is_some() as u64;
            }
        }
        std::hint::black_box(acc);
    });

    // --- prefill optimizer ---------------------------------------------------
    let fitted = profiler.fit(1);
    let mut opt = PrefillOptimizer::new(fitted, 210);
    let jobs: Vec<PrefillJobView> = (0..16)
        .map(|i| PrefillJobView {
            prompt_len: 200 + i * 50,
            deadline_s: 0.4 + i as f64 * 0.05,
        })
        .collect();
    bench("prefill_opt optimal_clock (16 jobs)", 200_000, 5, |n| {
        let mut acc = 0u64;
        for i in 0..n {
            acc += opt.optimal_clock(i as f64 * 1e-4, &jobs) as u64;
        }
        std::hint::black_box(acc);
    });

    // --- histogram ------------------------------------------------------------
    let mut h = Histogram::latency();
    bench("histogram record", 10_000_000, 5, |n| {
        for i in 0..n {
            h.record(1e-3 + (i % 1000) as f64 * 1e-5);
        }
        std::hint::black_box(h.count());
    });

    // --- end-to-end replay throughput ----------------------------------------
    let trace = generate(&ChatParams::new(8.0, 120.0), 7);
    let cfg = Config {
        method: Method::GreenLlm,
        seed: 7,
        ..Config::default()
    };
    println!();
    let mut events = 0u64;
    let mut best_evps = 0.0f64;
    for _ in 0..3 {
        let t0 = Instant::now();
        let r = run(&cfg, &trace, &RunOptions::default());
        let dt = t0.elapsed().as_secs_f64();
        events = r.events_processed;
        best_evps = best_evps.max(events as f64 / dt);
    }
    println!(
        "replay GreenLLM chat8qps/120s: {events} events, best {:.2} M events/s",
        best_evps / 1e6
    );
    let cfg_nv = Config {
        method: Method::DefaultNv,
        seed: 7,
        ..Config::default()
    };
    let t0 = Instant::now();
    let r = run(&cfg_nv, &trace, &RunOptions::default());
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "replay defaultNV chat8qps/120s: {} events, {:.2} M events/s",
        r.events_processed,
        r.events_processed as f64 / dt / 1e6
    );
}

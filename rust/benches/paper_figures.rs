//! `cargo bench` target: regenerate every paper figure (shortened horizon)
//! and report wall-clock per figure. `harness = false` (no criterion in
//! the offline mirror).

use greenllm::bench::figures;
use std::time::Instant;

fn timed<T>(name: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    println!(">>> {name}: {:.2}s wall\n", t0.elapsed().as_secs_f64());
    out
}

fn main() {
    let seed = 42;

    timed("fig1", || figures::fig1(240.0, seed));
    let f3a = timed("fig3a", || figures::fig3a(40.0, seed));
    let f3b = timed("fig3b", || figures::fig3b(40.0, seed));
    let f3c = timed("fig3c", || figures::fig3c(90.0, seed));
    let f5 = timed("fig5", || figures::fig5(180.0, seed));
    let f7 = timed("fig7", || figures::fig7(seed));
    let f8 = timed("fig8", || figures::fig8(seed));
    timed("fig10", || figures::fig10(60.0, seed));
    let f11 = timed("fig11", || figures::fig11(60.0, seed));
    timed("fig12a", || figures::fig12a(120.0, seed));
    timed("fig12b", || figures::fig12b(120.0, seed));

    // Shape assertions mirroring the paper's takeaways.
    assert!(f7.r2 > 0.98, "fig7 fit degraded");
    assert!(f8.r2 > 0.98, "fig8 fit degraded");
    let pre_knee = f3a[1].knee_mhz;
    let dec_knee = f3b[1].knee_mhz;
    assert!(dec_knee < pre_knee, "takeaway #2 violated");
    assert!((400..=1100).contains(&f3c.knee_mhz), "fig3c knee drifted");
    assert!(f5.slo_pct[1].1 >= f5.slo_pct[0].1 - 0.5, "routing stopped helping");
    assert!(
        f11[0].energy_saving_pct > f11.last().unwrap().energy_saving_pct,
        "fig11 savings-vs-load shape broken"
    );
    println!("all figure shape-checks passed");
}

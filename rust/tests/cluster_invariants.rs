//! Cluster coordinator invariants: conservation under every ingress
//! policy (with and without node churn), bit-exact degeneration to a
//! single node, bit-exact replay of fault schedules, power-arbiter budget
//! guarantees under both strategies, and determinism of the interleaved
//! event loop.

use greenllm::config::{Config, Method};
use greenllm::coordinator::cluster::{
    run_cluster, ArbiterStrategy, CapacityConfig, ClusterConfig, DisaggConfig, FaultPlan,
    FaultSpec, KvLinkModel, LbPolicy, NodeSpec, PoolRatio, ShedConfig,
};
use greenllm::coordinator::engine::{run, RunOptions};
use greenllm::workload::alibaba::{generate, ChatParams};
use greenllm::workload::request::Trace;
use greenllm::workload::synthetic;

fn node_cfg(method: Method, seed: u64) -> Config {
    Config {
        method,
        seed,
        ..Config::default()
    }
}

fn chat(qps: f64, duration: f64, seed: u64) -> Trace {
    generate(&ChatParams::new(qps, duration), seed)
}

#[test]
fn every_lb_policy_conserves_requests_and_tokens() {
    let trace = chat(12.0, 45.0, 3);
    let expect_tokens: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
    for lb in LbPolicy::all() {
        for nodes in [2, 3] {
            let ccfg = ClusterConfig::new(nodes, lb, node_cfg(Method::GreenLlm, 9));
            let r = run_cluster(&ccfg, &trace, &RunOptions::default());
            assert_eq!(
                r.completed as usize,
                trace.requests.len(),
                "{lb:?} x{nodes}: lost requests"
            );
            assert_eq!(
                r.generated_tokens, expect_tokens,
                "{lb:?} x{nodes}: token conservation"
            );
            assert_eq!(
                r.assignment.iter().sum::<usize>(),
                trace.requests.len(),
                "{lb:?} x{nodes}: assignment accounting"
            );
            // Per-node completions add up too.
            let per: u64 = r.per_node.iter().map(|n| n.completed).sum();
            assert_eq!(per, r.completed, "{lb:?} x{nodes}");
        }
    }
}

#[test]
fn multi_tenant_trace_conserves_under_phase_aware() {
    let trace = synthetic::multi_tenant(6.0, 1.5, 45.0, 5);
    let ccfg = ClusterConfig::new(4, LbPolicy::PhaseAware, node_cfg(Method::GreenLlm, 1));
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len());
    // The dedicated long pool (last node) must actually receive traffic on
    // a long-prompt-heavy tenant mix.
    assert!(r.assignment[3] > 0, "long pool starved: {:?}", r.assignment);
}

#[test]
fn single_node_cluster_bit_exact_with_plain_run_per_method() {
    // The interleaved event loop with online injection must reproduce the
    // pre-scheduled replay exactly when there is nothing to balance.
    let trace = chat(5.0, 40.0, 11);
    for method in [Method::DefaultNv, Method::GreenLlm, Method::Agft] {
        for lb in LbPolicy::all() {
            let ccfg = ClusterConfig::new(1, lb, node_cfg(method, 23));
            let c = run_cluster(&ccfg, &trace, &RunOptions::default());
            let plain = run(&node_cfg(method, 23), &trace, &RunOptions::default());
            assert_eq!(
                c.total_energy_j.to_bits(),
                plain.total_energy_j.to_bits(),
                "{method:?}/{lb:?}: energy drifted"
            );
            assert_eq!(
                c.per_node[0].events_processed, plain.events_processed,
                "{method:?}/{lb:?}: event count drifted"
            );
            assert_eq!(c.generated_tokens, plain.generated_tokens);
            assert_eq!(
                c.ttft_pass_rate.to_bits(),
                plain.slo.ttft_pass_rate().to_bits()
            );
        }
    }
}

#[test]
fn interleaved_loop_is_deterministic_under_fixed_seed() {
    let trace = chat(10.0, 40.0, 17);
    for lb in [LbPolicy::JoinShortestQueue, LbPolicy::PhaseAware] {
        let mk = || {
            let ccfg = ClusterConfig::new(3, lb, node_cfg(Method::GreenLlm, 7));
            run_cluster(&ccfg, &trace, &RunOptions::default())
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
        assert_eq!(a.assignment, b.assignment);
        for (x, y) in a.per_node.iter().zip(&b.per_node) {
            assert_eq!(x.events_processed, y.events_processed, "{lb:?}");
            assert_eq!(x.total_energy_j.to_bits(), y.total_energy_j.to_bits());
        }
    }
}

#[test]
fn power_arbiter_grants_never_exceed_cap() {
    let trace = chat(10.0, 40.0, 29);
    let cap_w = 4200.0; // 2 nodes × 8 GPUs: feasible but binding
    let ccfg = ClusterConfig::new(
        2,
        LbPolicy::JoinShortestQueue,
        node_cfg(Method::DefaultNv, 3),
    )
    .with_power_cap(cap_w, 1.0);
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len());
    let p = r.power.as_ref().expect("capped run has a power report");
    assert!(!p.epochs.is_empty());
    assert!(!p.had_infeasible_epoch, "cap should be feasible");
    for e in &p.epochs {
        // The arbiter's own invariant: worst-case grants fit the budget.
        assert!(
            e.total_granted_w() <= cap_w + 1e-6,
            "granted {} W > cap {cap_w} W at t={}",
            e.total_granted_w(),
            e.t_s
        );
        // Shares are a split of the cap.
        assert!(e.share_w.iter().sum::<f64>() <= cap_w + 1e-6);
        // And the measured consequence: the cluster never drew more than
        // its budget in any control epoch.
        assert!(
            e.total_measured_w() <= cap_w + 1e-6,
            "measured {} W > cap {cap_w} W at t={}",
            e.total_measured_w(),
            e.t_s
        );
        // Grants are real ladder clamps.
        for &c in &e.clamp_mhz {
            assert!((210..=1410).contains(&c) && (c - 210) % 15 == 0);
        }
    }
    // The cap binds: defaultNV would boost to 1410 MHz without it.
    assert!(
        p.epochs.iter().any(|e| e.clamp_mhz.iter().any(|&c| c < 1410)),
        "cap never clamped anything"
    );
}

#[test]
fn power_capped_greenllm_still_completes_with_sane_slos() {
    let trace = chat(6.0, 40.0, 31);
    let ccfg = ClusterConfig::new(2, LbPolicy::PhaseAware, node_cfg(Method::GreenLlm, 5))
        .with_power_cap(5000.0, 1.0);
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len());
    // A loose cap shouldn't wreck SLOs at light per-node load.
    assert!(r.ttft_pass_rate > 0.8, "ttft {}", r.ttft_pass_rate);
    let p = r.power.unwrap();
    assert!(p.peak_measured_w <= 5000.0 + 1e-6);
}

#[test]
fn capped_cluster_is_deterministic() {
    let trace = chat(8.0, 30.0, 37);
    let mk = || {
        let ccfg = ClusterConfig::new(
            2,
            LbPolicy::JoinShortestQueue,
            node_cfg(Method::GreenLlm, 2),
        )
        .with_power_cap(4200.0, 0.5);
        run_cluster(&ccfg, &trace, &RunOptions::default())
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    let (pa, pb) = (a.power.unwrap(), b.power.unwrap());
    assert_eq!(pa.epochs.len(), pb.epochs.len());
    for (x, y) in pa.epochs.iter().zip(&pb.epochs) {
        assert_eq!(x.clamp_mhz, y.clamp_mhz);
        assert_eq!(
            x.total_measured_w().to_bits(),
            y.total_measured_w().to_bits()
        );
    }
}

// ---------------------------------------------------------------------------
// Chaos & heterogeneity invariants
// ---------------------------------------------------------------------------

#[test]
fn node_loss_conserves_requests_and_tokens_per_balancer() {
    // Kill node `nodes-1` a third of the way in: every balancer must
    // re-home the drained work with zero dropped requests and exact
    // useful-token totals.
    let trace = chat(12.0, 45.0, 3);
    let expect_tokens: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
    for lb in LbPolicy::all() {
        for nodes in [2, 3] {
            let ccfg = ClusterConfig::new(nodes, lb, node_cfg(Method::GreenLlm, 9))
                .with_faults(FaultSpec::OneDown.plan(nodes, trace.duration_s));
            let r = run_cluster(&ccfg, &trace, &RunOptions::default());
            assert_eq!(
                r.completed as usize,
                trace.requests.len(),
                "{lb:?} x{nodes}: dropped requests under node loss"
            );
            assert_eq!(
                r.generated_tokens, expect_tokens,
                "{lb:?} x{nodes}: token conservation under node loss"
            );
            assert_eq!(
                r.assignment.iter().sum::<usize>(),
                trace.requests.len(),
                "{lb:?} x{nodes}: assignment accounting under node loss"
            );
            assert_eq!(r.fault_events, 1, "{lb:?} x{nodes}");
            // The victim had 15 s of traffic at 4+ QPS/node: losing it
            // must strand at least something.
            assert!(r.rerouted > 0, "{lb:?} x{nodes}: nothing re-routed");
        }
    }
}

#[test]
fn node_recovery_rejoins_and_serves_again() {
    // Flap node 2 (down at 15 s, back at 30 s of 45 s): it must complete
    // requests both before the loss and after the rejoin.
    let trace = chat(12.0, 45.0, 7);
    let ccfg = ClusterConfig::new(3, LbPolicy::RoundRobin, node_cfg(Method::GreenLlm, 9))
        .with_faults(FaultSpec::Flap.plan(3, trace.duration_s));
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len());
    assert_eq!(r.fault_events, 2);
    assert!(r.rerouted > 0);
    // Round-robin keeps cycling through the recovered node, so it ends
    // with a healthy share of completions despite the dark window.
    assert!(
        r.per_node[2].completed > 0,
        "recovered node never served again: {:?}",
        r.assignment
    );
    // The dark window shows up as strictly less energy than its peers
    // (same ingress share otherwise, 15 s of zero draw).
    assert!(
        r.per_node[2].total_energy_j < r.per_node[0].total_energy_j,
        "downed node should have spent less energy"
    );
}

#[test]
fn fault_schedule_replay_is_bit_exact() {
    let trace = chat(10.0, 40.0, 17);
    for lb in [LbPolicy::JoinShortestQueue, LbPolicy::PowerGrant] {
        let mk = || {
            let ccfg = ClusterConfig::new(3, lb, node_cfg(Method::GreenLlm, 7))
                .with_faults(FaultPlan::parse("down@13:1,up@26:1").unwrap());
            run_cluster(&ccfg, &trace, &RunOptions::default())
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits(), "{lb:?}");
        assert_eq!(a.assignment, b.assignment, "{lb:?}");
        assert_eq!(a.rerouted, b.rerouted, "{lb:?}");
        assert_eq!(a.wasted_tokens, b.wasted_tokens, "{lb:?}");
        for (x, y) in a.per_node.iter().zip(&b.per_node) {
            assert_eq!(x.events_processed, y.events_processed, "{lb:?}");
            assert_eq!(x.total_energy_j.to_bits(), y.total_energy_j.to_bits(), "{lb:?}");
        }
    }
}

#[test]
fn empty_fault_plan_is_bit_exact_with_no_chaos_layer() {
    // The inert plan must not perturb the event loop in any way: same
    // bits as the plain cluster config (PR 2 behavior).
    let trace = chat(8.0, 40.0, 23);
    let base = ClusterConfig::new(2, LbPolicy::JoinShortestQueue, node_cfg(Method::GreenLlm, 5));
    let with_empty_plan = base.clone().with_faults(FaultPlan::default());
    let a = run_cluster(&base, &trace, &RunOptions::default());
    let b = run_cluster(&with_empty_plan, &trace, &RunOptions::default());
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    assert_eq!(a.assignment, b.assignment);
    assert_eq!(a.rerouted, 0);
    assert_eq!(b.rerouted, 0);
    assert_eq!(b.wasted_tokens, 0);
    for (x, y) in a.per_node.iter().zip(&b.per_node) {
        assert_eq!(x.events_processed, y.events_processed);
        assert_eq!(x.total_energy_j.to_bits(), y.total_energy_j.to_bits());
    }
}

#[test]
fn heterogeneous_cluster_conserves_and_reflects_hardware() {
    // eff (0.7× envelope) vs legacy (1.25× envelope, 1200 MHz cap) under
    // round-robin: equal request shares, so the legacy node must burn
    // measurably more energy.
    let trace = chat(8.0, 40.0, 29);
    let ccfg = ClusterConfig::new(2, LbPolicy::RoundRobin, node_cfg(Method::DefaultNv, 3))
        .with_node_specs(vec![NodeSpec::eff(), NodeSpec::legacy()]);
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len());
    assert!(
        r.per_node[1].total_energy_j > 1.2 * r.per_node[0].total_energy_j,
        "legacy {} J vs eff {} J",
        r.per_node[1].total_energy_j,
        r.per_node[0].total_energy_j
    );
}

#[test]
fn acceptance_three_node_heterogeneous_loss_zero_drops() {
    // The PR's headline chaos criterion: a 3-node heterogeneous cluster
    // with a mid-trace node loss completes with zero dropped requests.
    let trace = chat(12.0, 60.0, 31);
    let expect_tokens: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
    let ccfg = ClusterConfig::new(3, LbPolicy::JoinShortestQueue, node_cfg(Method::GreenLlm, 5))
        .with_node_specs(vec![NodeSpec::dgx(), NodeSpec::eff(), NodeSpec::legacy()])
        .with_faults(FaultSpec::OneDown.plan(3, trace.duration_s));
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len(), "dropped requests");
    assert_eq!(r.generated_tokens, expect_tokens, "token conservation");
    assert!(r.rerouted > 0);
}

#[test]
fn slo_pressure_arbiter_respects_cap_and_conserves() {
    let trace = chat(10.0, 40.0, 37);
    let cap_w = 4200.0;
    let ccfg = ClusterConfig::new(
        2,
        LbPolicy::JoinShortestQueue,
        node_cfg(Method::DefaultNv, 3),
    )
    .with_power_cap(cap_w, 1.0)
    .with_arbiter(ArbiterStrategy::SloPressure);
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len());
    let p = r.power.as_ref().expect("capped run has a power report");
    assert!(!p.epochs.is_empty());
    for e in &p.epochs {
        assert!(
            e.total_granted_w() <= cap_w + 1e-6,
            "slo-pressure granted {} W > cap {cap_w} W at t={}",
            e.total_granted_w(),
            e.t_s
        );
        assert!(e.total_measured_w() <= cap_w + 1e-6);
    }
}

#[test]
fn tight_cap_survives_node_recovery() {
    // Regression: a recovering node's clamp is cleared by Engine::recover,
    // so without the fault-transition re-arbitration the survivors (still
    // holding grants summing to ~cap) plus the rejoined node at boost
    // would exceed the budget until the next epoch. The cap here sits
    // just above the 3-node floor, so any such window is visible.
    let trace = chat(10.0, 45.0, 43);
    let cap_w = 5200.0; // 3 nodes x 8 GPUs: floors ≈ 4636 W, tight but feasible
    let ccfg = ClusterConfig::new(
        3,
        LbPolicy::JoinShortestQueue,
        node_cfg(Method::DefaultNv, 3),
    )
    .with_power_cap(cap_w, 1.0)
    .with_faults(FaultSpec::Flap.plan(3, trace.duration_s));
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len());
    let p = r.power.unwrap();
    assert!(!p.had_infeasible_epoch, "cap should stay feasible");
    for e in &p.epochs {
        assert!(
            e.total_granted_w() <= cap_w + 1e-6,
            "granted {} W > cap at t={}",
            e.total_granted_w(),
            e.t_s
        );
        assert!(
            e.total_measured_w() <= cap_w + 1e-6,
            "budget blown across recovery: measured {} W at t={}",
            e.total_measured_w(),
            e.t_s
        );
    }
}

#[test]
fn powergrant_balancer_conserves_under_cap_and_churn() {
    let trace = chat(10.0, 45.0, 41);
    let ccfg = ClusterConfig::new(3, LbPolicy::PowerGrant, node_cfg(Method::GreenLlm, 5))
        .with_power_cap(9000.0, 1.0)
        .with_arbiter(ArbiterStrategy::SloPressure)
        .with_faults(FaultSpec::Flap.plan(3, trace.duration_s));
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len());
    let expect_tokens: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
    assert_eq!(r.generated_tokens, expect_tokens);
    let p = r.power.unwrap();
    assert!(p.peak_measured_w <= 9000.0 + 1e-6);
}

#[test]
fn cluster_acceptance_greenllm_beats_defaultnv_at_equal_nodes() {
    // The PR's headline criterion: ≥15 % cluster energy saving vs
    // defaultNV at equal node count with pass rates > 0.9.
    let trace = chat(10.0, 60.0, 41);
    for lb in [LbPolicy::JoinShortestQueue, LbPolicy::PhaseAware] {
        let nv = run_cluster(
            &ClusterConfig::new(2, lb, node_cfg(Method::DefaultNv, 5)),
            &trace,
            &RunOptions::default(),
        );
        let green = run_cluster(
            &ClusterConfig::new(2, lb, node_cfg(Method::GreenLlm, 5)),
            &trace,
            &RunOptions::default(),
        );
        let saving = 1.0 - green.total_energy_j / nv.total_energy_j;
        assert!(saving > 0.15, "{lb:?}: saving {saving:.3}");
        assert!(green.ttft_pass_rate > 0.9, "{lb:?}");
        assert!(green.tbt_pass_rate > 0.9, "{lb:?}");
    }
}

// ---------------------------------------------------------------------------
// PR 5: the O(log N) cross-engine scheduler vs the kept-verbatim
// linear-scan oracle.
// ---------------------------------------------------------------------------

#[test]
fn heap_scheduler_bit_equal_with_scan_oracle_property() {
    // The production cluster loop picks the next engine from a SourceHeap
    // re-keyed incrementally (O(log N) per event); the oracle loop re-reads
    // every engine and linearly scans, exactly like pre-PR5. Random
    // cluster shapes — balancers, node counts, fault plans, power caps,
    // arbiters — must interleave BIT-identically: same event order implies
    // the same energy bits, event counts, assignment and chaos totals. A
    // divergence here means an engine's next-event key was not refreshed
    // after something mutated its queue (inject/fail/recover/epoch).
    use greenllm::coordinator::cluster::events::run_cluster_scan_oracle;
    use greenllm::util::ptest::check;
    use greenllm::util::rng::Pcg64;

    let lbs = LbPolicy::all();
    check("heap_sched_vs_scan_oracle", 10, |g: &mut Pcg64| {
        let nodes = 2 + g.index(3); // 2..=4
        let lb = lbs[g.index(lbs.len())];
        let qps = 4.0 + g.f64() * 8.0;
        let duration = 20.0 + g.f64() * 15.0;
        let trace = chat(qps, duration, g.next_u64());
        let method = if g.chance(0.5) {
            Method::GreenLlm
        } else {
            Method::DefaultNv
        };
        let mut ccfg = ClusterConfig::new(nodes, lb, node_cfg(method, g.next_u64()));
        if g.chance(0.5) {
            // Binding-ish cap, sometimes SLO-pressure split.
            ccfg = ccfg.with_power_cap(nodes as f64 * (1800.0 + g.f64() * 1500.0), 0.5);
            if g.chance(0.5) {
                ccfg = ccfg.with_arbiter(ArbiterStrategy::SloPressure);
            }
        }
        if g.chance(0.5) {
            let spec = if g.chance(0.5) {
                FaultSpec::OneDown
            } else {
                FaultSpec::Flap
            };
            ccfg = ccfg.with_faults(spec.plan(nodes, duration));
        }
        if g.chance(0.3) {
            ccfg = ccfg.with_node_specs(vec![NodeSpec::dgx(), NodeSpec::eff()]);
        }
        let a = run_cluster(&ccfg, &trace, &RunOptions::default());
        let b = run_cluster_scan_oracle(&ccfg, &trace, &RunOptions::default());
        greenllm::prop_assert!(
            a.total_energy_j.to_bits() == b.total_energy_j.to_bits(),
            "energy diverged: {} vs {} ({lb:?} x{nodes})",
            a.total_energy_j,
            b.total_energy_j
        );
        greenllm::prop_assert!(
            a.events_processed == b.events_processed,
            "event counts diverged: {} vs {} ({lb:?} x{nodes})",
            a.events_processed,
            b.events_processed
        );
        greenllm::prop_assert!(a.assignment == b.assignment, "assignment diverged");
        greenllm::prop_assert!(
            a.rerouted == b.rerouted && a.wasted_tokens == b.wasted_tokens,
            "chaos totals diverged"
        );
        for (x, y) in a.per_node.iter().zip(&b.per_node) {
            greenllm::prop_assert!(
                x.total_energy_j.to_bits() == y.total_energy_j.to_bits()
                    && x.events_processed == y.events_processed
                    && x.completed == y.completed,
                "per-node results diverged"
            );
        }
        Ok(())
    });
}

#[test]
fn heap_scheduler_matches_scan_oracle_at_32_nodes() {
    // The frontier shape the PR exists for: heterogeneous 32-node capped
    // cluster, short horizon. One fixed case (the property test above
    // covers the shape space; this pins the scale) — bit-equal with the
    // linear-scan oracle, all work conserved.
    use greenllm::coordinator::cluster::events::run_cluster_scan_oracle;
    let trace = chat(64.0, 12.0, 51);
    let ccfg = ClusterConfig::new(
        32,
        LbPolicy::JoinShortestQueue,
        node_cfg(Method::GreenLlm, 13),
    )
    .with_node_specs(vec![NodeSpec::dgx(), NodeSpec::eff(), NodeSpec::legacy()])
    .with_power_cap(32.0 * 2500.0, 1.0);
    let a = run_cluster(&ccfg, &trace, &RunOptions::default());
    let b = run_cluster_scan_oracle(&ccfg, &trace, &RunOptions::default());
    assert_eq!(a.completed as usize, trace.requests.len());
    let expect_tokens: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
    assert_eq!(a.generated_tokens, expect_tokens);
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.assignment, b.assignment);
    assert_eq!(a.per_node.len(), 32);
}

// ---------------------------------------------------------------------------
// PR 6: prefill/decode disaggregation — stream-migration conservation,
// colocated bit-exactness, and fault tolerance of in-flight handoffs.
// ---------------------------------------------------------------------------

/// A disaggregated cluster config: JSQ ingress over the prefill pool,
/// default KV link, per-pool policies inherited from the node config.
fn disagg_cfg(nodes: usize, ratio: &str) -> ClusterConfig {
    ClusterConfig::new(
        nodes,
        LbPolicy::JoinShortestQueue,
        node_cfg(Method::GreenLlm, 9),
    )
    .with_pool_ratio(PoolRatio::parse(ratio).unwrap())
    .with_disagg(DisaggConfig::default())
}

#[test]
fn disagg_cluster_conserves_requests_and_tokens_across_handoffs() {
    // Every multi-token request prefills in the prefill pool and decodes
    // in the decode pool: the handoff must lose nothing — exact request,
    // token, and assignment conservation, with a live migration ledger.
    let trace = chat(12.0, 45.0, 3);
    let expect_tokens: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
    for ratio in ["1:1", "1:3"] {
        for nodes in [2, 4] {
            let r = run_cluster(&disagg_cfg(nodes, ratio), &trace, &RunOptions::default());
            assert_eq!(
                r.completed as usize,
                trace.requests.len(),
                "{ratio} x{nodes}: lost requests across migration"
            );
            assert_eq!(
                r.generated_tokens, expect_tokens,
                "{ratio} x{nodes}: token conservation across migration"
            );
            assert_eq!(
                r.assignment.iter().sum::<usize>(),
                trace.requests.len(),
                "{ratio} x{nodes}: assignment ownership-move accounting"
            );
            let m = r.migration.expect("split cluster reports migrations");
            assert!(m.count > 0, "{ratio} x{nodes}: no streams migrated");
            assert!(m.count <= r.completed, "{ratio} x{nodes}");
            assert!(m.kv_bytes > 0.0, "{ratio} x{nodes}: KV bytes not metered");
            assert!(m.transfer_j > 0.0, "{ratio} x{nodes}: link energy not metered");
            assert_eq!(m.relays, 0, "{ratio} x{nodes}: relays without faults");
        }
    }
}

#[test]
fn disagg_off_ignores_pool_ratio_and_reports_no_migration() {
    // The colocated path must be byte-for-byte untouched by this PR:
    // setting a pool ratio WITHOUT enabling disagg changes nothing for a
    // frontend-only balancer, and no migration ledger appears.
    let trace = chat(10.0, 40.0, 11);
    let base = ClusterConfig::new(3, LbPolicy::JoinShortestQueue, node_cfg(Method::GreenLlm, 7));
    let ratioed = base.clone().with_pool_ratio(PoolRatio::parse("1:1").unwrap());
    let a = run_cluster(&base, &trace, &RunOptions::default());
    let b = run_cluster(&ratioed, &trace, &RunOptions::default());
    assert!(a.migration.is_none(), "colocated run grew a migration ledger");
    assert!(b.migration.is_none());
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    assert_eq!(a.assignment, b.assignment);
    for (x, y) in a.per_node.iter().zip(&b.per_node) {
        assert_eq!(x.events_processed, y.events_processed);
        assert_eq!(x.total_energy_j.to_bits(), y.total_energy_j.to_bits());
    }
}

#[test]
fn one_node_disagg_collapses_to_colocated_bit_exact() {
    // A 1-node cluster cannot split (prefill_count == 0), so `--disagg`
    // there must degrade to the plain colocated loop: same bits, no
    // migration section.
    let trace = chat(5.0, 40.0, 11);
    let plain = ClusterConfig::new(1, LbPolicy::JoinShortestQueue, node_cfg(Method::GreenLlm, 23));
    let split = plain
        .clone()
        .with_pool_ratio(PoolRatio::parse("1:1").unwrap())
        .with_disagg(DisaggConfig::default());
    assert_eq!(split.prefill_pool(), 0);
    let a = run_cluster(&plain, &trace, &RunOptions::default());
    let b = run_cluster(&split, &trace, &RunOptions::default());
    assert!(b.migration.is_none());
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    assert_eq!(a.generated_tokens, b.generated_tokens);
    assert_eq!(a.per_node[0].events_processed, b.per_node[0].events_processed);
}

#[test]
fn mid_migration_target_failure_relays_and_conserves() {
    // Slow the KV link to 2 s per handoff, then kill decode node 3 a
    // third of the way in: handoffs on the wire at the fault must relay
    // to a surviving decode node with both ends re-charged, and streams
    // already resident on the victim re-prefill through ingress. Nothing
    // may be lost either way.
    let trace = chat(12.0, 45.0, 3);
    let expect_tokens: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
    let slow = DisaggConfig {
        link: KvLinkModel {
            latency_s: 2.0,
            ..KvLinkModel::default()
        },
        ..DisaggConfig::default()
    };
    let ccfg = ClusterConfig::new(
        4,
        LbPolicy::JoinShortestQueue,
        node_cfg(Method::GreenLlm, 9),
    )
    .with_pool_ratio(PoolRatio::parse("1:1").unwrap())
    .with_disagg(slow)
    .with_faults(FaultPlan::parse("down@15:3,up@30:3").unwrap());
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len(), "dropped requests");
    assert_eq!(r.generated_tokens, expect_tokens, "token conservation");
    assert_eq!(r.assignment.iter().sum::<usize>(), trace.requests.len());
    let m = r.migration.expect("split cluster reports migrations");
    assert!(m.count > 0);
    assert!(
        m.relays > 0,
        "a 2 s link with a mid-trace decode loss must catch handoffs in flight"
    );
}

#[test]
fn mid_migration_sender_failure_reprefills_and_conserves() {
    // Same slow link, but kill prefill node 0: the KV of its in-flight
    // handoffs died with it, so those streams must take the full
    // re-prefill path through ingress (rerouted), not a relay.
    let trace = chat(12.0, 45.0, 7);
    let expect_tokens: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
    let slow = DisaggConfig {
        link: KvLinkModel {
            latency_s: 2.0,
            ..KvLinkModel::default()
        },
        ..DisaggConfig::default()
    };
    let ccfg = ClusterConfig::new(
        4,
        LbPolicy::JoinShortestQueue,
        node_cfg(Method::GreenLlm, 9),
    )
    .with_pool_ratio(PoolRatio::parse("1:1").unwrap())
    .with_disagg(slow)
    .with_faults(FaultPlan::parse("down@15:0,up@30:0").unwrap());
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len(), "dropped requests");
    assert_eq!(r.generated_tokens, expect_tokens, "token conservation");
    assert_eq!(r.assignment.iter().sum::<usize>(), trace.requests.len());
    assert!(r.rerouted > 0, "dead-sender handoffs must re-prefill via ingress");
}

#[test]
fn disagg_heap_scheduler_bit_equal_with_scan_oracle() {
    // Migration events ride the cluster queue; the O(log N) selector and
    // the linear-scan oracle must interleave them identically — including
    // across a flap of the last decode node.
    use greenllm::coordinator::cluster::events::run_cluster_scan_oracle;
    let trace = chat(10.0, 40.0, 17);
    let ccfg = disagg_cfg(4, "1:1").with_faults(FaultSpec::Flap.plan(4, trace.duration_s));
    let a = run_cluster(&ccfg, &trace, &RunOptions::default());
    let b = run_cluster_scan_oracle(&ccfg, &trace, &RunOptions::default());
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.assignment, b.assignment);
    let (ma, mb) = (a.migration.unwrap(), b.migration.unwrap());
    assert_eq!(ma.count, mb.count);
    assert_eq!(ma.relays, mb.relays);
    assert_eq!(ma.kv_bytes.to_bits(), mb.kv_bytes.to_bits());
    assert_eq!(ma.transfer_j.to_bits(), mb.transfer_j.to_bits());
}

#[test]
fn disagg_property_conserves_over_ratios_faults_and_arbiters() {
    // Random pool ratios x balancers x fault plans x arbiters: every
    // shape conserves requests, tokens, and assignment ownership, and
    // the heap scheduler stays bit-equal with the scan oracle.
    use greenllm::coordinator::cluster::events::run_cluster_scan_oracle;
    use greenllm::util::ptest::check;
    use greenllm::util::rng::Pcg64;

    let lbs = LbPolicy::all();
    let ratios = ["1:1", "1:2", "1:3", "1:4"];
    check("disagg_conservation", 10, |g: &mut Pcg64| {
        let nodes = 2 + g.index(4); // 2..=5
        let ratio = PoolRatio::parse(ratios[g.index(ratios.len())]).unwrap();
        let lb = lbs[g.index(lbs.len())];
        let qps = 4.0 + g.f64() * 8.0;
        let duration = 20.0 + g.f64() * 15.0;
        let trace = chat(qps, duration, g.next_u64());
        let expect_tokens: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
        let mut ccfg = ClusterConfig::new(nodes, lb, node_cfg(Method::GreenLlm, g.next_u64()))
            .with_pool_ratio(ratio)
            .with_disagg(DisaggConfig::default());
        if g.chance(0.5) {
            ccfg = ccfg.with_power_cap(nodes as f64 * (1800.0 + g.f64() * 1500.0), 0.5);
            if g.chance(0.5) {
                ccfg = ccfg.with_arbiter(ArbiterStrategy::SloPressure);
            }
        }
        if g.chance(0.5) {
            let spec = if g.chance(0.5) {
                FaultSpec::OneDown
            } else {
                FaultSpec::Flap
            };
            ccfg = ccfg.with_faults(spec.plan(nodes, duration));
        }
        let a = run_cluster(&ccfg, &trace, &RunOptions::default());
        greenllm::prop_assert!(
            a.completed as usize == trace.requests.len(),
            "lost requests ({lb:?} x{nodes} {})",
            ratio.name()
        );
        greenllm::prop_assert!(
            a.generated_tokens == expect_tokens,
            "token conservation broke ({lb:?} x{nodes} {})",
            ratio.name()
        );
        greenllm::prop_assert!(
            a.assignment.iter().sum::<usize>() == trace.requests.len(),
            "assignment accounting broke ({lb:?} x{nodes} {})",
            ratio.name()
        );
        let m = a.migration.expect("split cluster reports migrations");
        greenllm::prop_assert!(m.count > 0, "no migrations ({lb:?} x{nodes})");
        let b = run_cluster_scan_oracle(&ccfg, &trace, &RunOptions::default());
        greenllm::prop_assert!(
            a.total_energy_j.to_bits() == b.total_energy_j.to_bits(),
            "energy diverged from scan oracle under disagg"
        );
        greenllm::prop_assert!(
            a.events_processed == b.events_processed && a.assignment == b.assignment,
            "interleaving diverged from scan oracle under disagg"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// PR 7: flight-recorder observability — recording must be a pure observer.
// ---------------------------------------------------------------------------

#[test]
fn recorded_run_bit_exact_with_recorder_off_property() {
    // The flight recorder is statically compiled out of `run_cluster`
    // (NoopRecorder) and fully live in `run_cluster_recorded`. Recording
    // must be a pure observer: across random balancers x node counts x
    // fault plans x power caps x disagg splits, the recorded run's
    // results are BIT-identical to the recorder-off run (itself already
    // property-checked against the kept-verbatim scan oracle above).
    use greenllm::coordinator::cluster::run_cluster_recorded;
    use greenllm::obs::FlightRecorder;
    use greenllm::util::ptest::check;
    use greenllm::util::rng::Pcg64;
    use std::cell::RefCell;

    let lbs = LbPolicy::all();
    check("recorded_vs_recorder_off", 10, |g: &mut Pcg64| {
        let nodes = 2 + g.index(3); // 2..=4
        let lb = lbs[g.index(lbs.len())];
        let qps = 4.0 + g.f64() * 8.0;
        let duration = 20.0 + g.f64() * 15.0;
        let trace = chat(qps, duration, g.next_u64());
        let mut ccfg = ClusterConfig::new(nodes, lb, node_cfg(Method::GreenLlm, g.next_u64()));
        if g.chance(0.4) {
            ccfg = ccfg
                .with_pool_ratio(PoolRatio::parse("1:1").unwrap())
                .with_disagg(DisaggConfig::default());
        }
        if g.chance(0.5) {
            ccfg = ccfg.with_power_cap(nodes as f64 * (1800.0 + g.f64() * 1500.0), 0.5);
        }
        if g.chance(0.5) {
            let spec = if g.chance(0.5) {
                FaultSpec::OneDown
            } else {
                FaultSpec::Flap
            };
            ccfg = ccfg.with_faults(spec.plan(nodes, duration));
        }
        let off = run_cluster(&ccfg, &trace, &RunOptions::default());
        let rec = RefCell::new(FlightRecorder::new(nodes, 4096));
        let on = run_cluster_recorded(&ccfg, &trace, &RunOptions::default(), &rec);
        greenllm::prop_assert!(
            off.total_energy_j.to_bits() == on.total_energy_j.to_bits(),
            "recording perturbed energy: {} vs {} ({lb:?} x{nodes})",
            off.total_energy_j,
            on.total_energy_j
        );
        greenllm::prop_assert!(
            off.events_processed == on.events_processed,
            "event counts diverged under recording"
        );
        greenllm::prop_assert!(off.assignment == on.assignment, "assignment diverged");
        greenllm::prop_assert!(
            off.rerouted == on.rerouted && off.wasted_tokens == on.wasted_tokens,
            "chaos totals diverged under recording"
        );
        for (x, y) in off.per_node.iter().zip(&on.per_node) {
            greenllm::prop_assert!(
                x.total_energy_j.to_bits() == y.total_energy_j.to_bits()
                    && x.events_processed == y.events_processed
                    && x.completed == y.completed,
                "per-node results diverged under recording"
            );
        }
        // And the recorder actually observed the run: spans well-formed,
        // one record per completed request.
        let rec = rec.into_inner();
        greenllm::prop_assert!(
            rec.span_check(false).is_ok(),
            "span invariants broke: {:?}",
            rec.span_check(false)
        );
        greenllm::prop_assert!(
            rec.requests().count() as u64 >= on.completed,
            "recorder missed requests: {} records < {} completed",
            rec.requests().count(),
            on.completed
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// PR 9: elastic capacity under correlated failure — autoscaler, spot
// preemption, stragglers, and graceful overload shedding.
// ---------------------------------------------------------------------------

#[test]
fn inert_elasticity_knobs_are_bit_exact_with_no_elasticity_layer() {
    // The inert spellings of both new subsystems — a shed gate that never
    // trips (infinite depth) and a capacity controller with nothing to
    // park (warm 0, watermarks it can never cross) — must reproduce the
    // pre-PR event loop bit-for-bit: the controller's check events fire
    // but mutate nothing, and the gate admits every arrival untouched.
    let trace = chat(10.0, 40.0, 19);
    let base = ClusterConfig::new(3, LbPolicy::JoinShortestQueue, node_cfg(Method::GreenLlm, 7))
        .with_faults(FaultSpec::Flap.plan(3, trace.duration_s));
    let inert = base
        .clone()
        .with_capacity(CapacityConfig {
            warm: 0,
            up_backlog: f64::INFINITY,
            down_backlog: 0.0,
            ..CapacityConfig::default()
        })
        .with_shed(ShedConfig {
            queue_depth: f64::INFINITY,
            ..ShedConfig::default()
        });
    let a = run_cluster(&base, &trace, &RunOptions::default());
    let b = run_cluster(&inert, &trace, &RunOptions::default());
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.assignment, b.assignment);
    assert_eq!(a.rerouted, b.rerouted);
    assert_eq!(b.shed, 0);
    assert_eq!(b.shed_retries, 0);
    assert_eq!(b.capacity_provisions, 0);
    assert_eq!(b.capacity_parks, 0);
    assert_eq!(b.warm_energy_j.to_bits(), 0f64.to_bits());
    for (x, y) in a.per_node.iter().zip(&b.per_node) {
        assert_eq!(x.events_processed, y.events_processed);
        assert_eq!(x.total_energy_j.to_bits(), y.total_energy_j.to_bits());
    }
}

#[test]
fn spot_preemption_drains_before_the_kill_and_conserves() {
    // The spot preset issues a drain notice, then the preemption, then a
    // later recovery. Everything the victim was serving must finish
    // somewhere: zero dropped requests, exact token totals.
    let trace = chat(12.0, 60.0, 23);
    let expect_tokens: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
    for nodes in [2, 3] {
        let ccfg = ClusterConfig::new(
            nodes,
            LbPolicy::JoinShortestQueue,
            node_cfg(Method::GreenLlm, 9),
        )
        .with_faults(FaultSpec::Spot.plan(nodes, trace.duration_s));
        let r = run_cluster(&ccfg, &trace, &RunOptions::default());
        assert_eq!(
            r.completed as usize,
            trace.requests.len(),
            "x{nodes}: dropped requests under spot preemption"
        );
        assert_eq!(r.generated_tokens, expect_tokens, "x{nodes}");
        assert!(r.fault_events >= 2, "x{nodes}: drain + down must fire");
    }
}

#[test]
fn straggler_node_keeps_serving_and_is_reported() {
    // A straggler is degraded, not dead: it must stay routable, keep
    // completing requests, and be named in the run's straggler ledger.
    let trace = chat(9.0, 60.0, 27);
    let ccfg = ClusterConfig::new(3, LbPolicy::RoundRobin, node_cfg(Method::GreenLlm, 9))
        .with_faults(FaultSpec::Straggler.plan(3, trace.duration_s));
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len());
    assert!(
        !r.straggler_nodes.is_empty(),
        "straggler plan must report its victims"
    );
    for &n in &r.straggler_nodes {
        assert!(
            r.per_node[n].completed > 0,
            "degraded node {n} stopped serving: {:?}",
            r.assignment
        );
    }
    assert_eq!(r.rerouted, 0, "degradation must not re-home anything");
}

#[test]
fn capacity_controller_provisions_under_load_and_meters_warm_energy() {
    // One warm spare on a 3-node cluster under heavy load: the backlog
    // crosses the high watermark, the controller boots the spare, and the
    // spare's parked time is metered as warm-pool energy. The spare must
    // actually serve after joining.
    let trace = chat(30.0, 60.0, 31);
    let ccfg = ClusterConfig::new(
        3,
        LbPolicy::JoinShortestQueue,
        node_cfg(Method::GreenLlm, 9),
    )
    .with_capacity(CapacityConfig {
        warm: 1,
        min_live: 1,
        boot_s: 3.0,
        check_epoch_s: 1.0,
        up_backlog: 1.0,
        down_backlog: 0.0,
        down_idle_epochs: 3,
        warm_idle_w: 350.0,
    });
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len(), "dropped requests");
    assert!(r.capacity_provisions >= 1, "spare never booted");
    assert!(r.warm_energy_j > 0.0, "parked time must cost warm energy");
    assert!(
        r.per_node[2].completed > 0,
        "booted spare never served: {:?}",
        r.assignment
    );
    // Warm energy is part of the cluster total, not a side ledger.
    let node_sum: f64 = r.per_node.iter().map(|n| n.total_energy_j).sum();
    assert!(r.total_energy_j >= node_sum, "warm energy missing from total");
}

#[test]
fn capacity_controller_parks_idle_nodes_with_hysteresis() {
    // A trickle of load on 3 nodes: after the idle streak the controller
    // parks surplus nodes (never below min_live) and their idle time
    // accrues warm-pool energy until the horizon.
    let trace = chat(1.0, 60.0, 37);
    let ccfg = ClusterConfig::new(3, LbPolicy::JoinShortestQueue, node_cfg(Method::GreenLlm, 9))
        .with_capacity(CapacityConfig {
            warm: 0,
            min_live: 1,
            boot_s: 5.0,
            check_epoch_s: 2.0,
            up_backlog: 50.0,
            down_backlog: 0.5,
            down_idle_epochs: 2,
            warm_idle_w: 350.0,
        });
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len(), "park lost work");
    assert!(r.capacity_parks >= 1, "idle fleet never scaled down");
    assert!(r.warm_energy_j > 0.0, "parked nodes must meter idle draw");
}

#[test]
fn overload_shedding_is_bounded_and_counts_are_conserved() {
    // Sustained overload on a small fleet with a shallow gate: some
    // arrivals are deferred and retried, some shed permanently — but
    // every arrival lands in exactly one terminal bucket.
    let trace = chat(60.0, 25.0, 41);
    let total = trace.requests.len() as u64;
    let ccfg = ClusterConfig::new(
        2,
        LbPolicy::JoinShortestQueue,
        node_cfg(Method::GreenLlm, 9),
    )
    .with_shed(ShedConfig {
        queue_depth: 2.0,
        backoff_s: 1.0,
        max_retries: 2,
    });
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed + r.shed, total, "an arrival vanished");
    assert!(r.shed > 0, "gate never shed under 30 QPS/node");
    assert!(r.shed_retries > 0, "shed without any re-offer attempts");
    assert!(r.completed > 0, "gate shed everything");
    assert_eq!(
        r.assignment.iter().sum::<usize>() as u64,
        r.completed,
        "assignment must count only admitted requests"
    );
    let per: u64 = r.per_node.iter().map(|n| n.completed).sum();
    assert_eq!(per, r.completed);
}

#[test]
fn combined_churn_property_conserves_and_matches_scan_oracle() {
    // The PR's headline property: spot preemption + stragglers +
    // rack-correlated loss + power-cap churn + disaggregation + the
    // autoscaler + the shed gate, over random balancers and arbiters —
    // counts stay conserved (`completed + shed == arrived`, zero silent
    // drops) and the O(log N) heap scheduler stays BIT-equal with the
    // kept-verbatim linear-scan oracle, elasticity counters included.
    use greenllm::coordinator::cluster::events::run_cluster_scan_oracle;
    use greenllm::util::ptest::check;
    use greenllm::util::rng::Pcg64;

    let lbs = LbPolicy::all();
    check("elastic_chaos_conservation", 10, |g: &mut Pcg64| {
        let nodes = 3 + g.index(3); // 3..=5
        let lb = lbs[g.index(lbs.len())];
        let qps = 6.0 + g.f64() * 10.0;
        let duration = 25.0 + g.f64() * 15.0;
        let trace = chat(qps, duration, g.next_u64());
        let total = trace.requests.len() as u64;
        let expect_tokens: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
        let mut ccfg = ClusterConfig::new(nodes, lb, node_cfg(Method::GreenLlm, g.next_u64()));
        // Chaos axis: spot churn, stragglers, or a rack-correlated loss.
        let fault = match g.index(4) {
            0 => FaultSpec::Spot.plan(nodes, duration),
            1 => FaultSpec::Straggler.plan(nodes, duration),
            2 => FaultPlan::parse("rackdown@12:0-1,rackup@24:0-1").unwrap(),
            _ => FaultSpec::Flap.plan(nodes, duration),
        };
        ccfg = ccfg.with_faults(fault);
        if g.chance(0.5) {
            ccfg = ccfg.with_power_cap(nodes as f64 * (1800.0 + g.f64() * 1500.0), 0.5);
            if g.chance(0.5) {
                ccfg = ccfg.with_arbiter(ArbiterStrategy::SloPressure);
            }
        }
        if g.chance(0.3) {
            ccfg = ccfg
                .with_pool_ratio(PoolRatio::parse("1:1").unwrap())
                .with_disagg(DisaggConfig::default());
        }
        if g.chance(0.5) {
            ccfg = ccfg.with_capacity(CapacityConfig {
                warm: g.index(2), // 0 or 1; nodes >= 3 keeps min_live feasible
                min_live: 1,
                boot_s: 2.0 + g.f64() * 8.0,
                check_epoch_s: 1.0 + g.f64() * 3.0,
                up_backlog: 2.0 + g.f64() * 4.0,
                down_backlog: 0.1 + g.f64() * 0.3,
                down_idle_epochs: 2,
                warm_idle_w: 350.0,
            });
        }
        if g.chance(0.5) {
            ccfg = ccfg.with_shed(ShedConfig {
                queue_depth: 4.0 + g.f64() * 8.0,
                backoff_s: 0.5 + g.f64() * 2.0,
                max_retries: 1 + g.index(3) as u32,
            });
        }
        let a = run_cluster(&ccfg, &trace, &RunOptions::default());
        greenllm::prop_assert!(
            a.completed + a.shed == total,
            "count conservation broke: {} completed + {} shed != {total} \
             ({lb:?} x{nodes})",
            a.completed,
            a.shed
        );
        greenllm::prop_assert!(
            a.assignment.iter().sum::<usize>() as u64 == a.completed,
            "assignment accounting broke ({lb:?} x{nodes})"
        );
        let per: u64 = a.per_node.iter().map(|n| n.completed).sum();
        greenllm::prop_assert!(per == a.completed, "per-node completion accounting broke");
        if a.shed == 0 {
            greenllm::prop_assert!(
                a.generated_tokens == expect_tokens,
                "token conservation broke with nothing shed ({lb:?} x{nodes})"
            );
        } else {
            greenllm::prop_assert!(
                a.generated_tokens < expect_tokens,
                "shed requests must not have generated their tokens"
            );
        }
        let b = run_cluster_scan_oracle(&ccfg, &trace, &RunOptions::default());
        greenllm::prop_assert!(
            a.total_energy_j.to_bits() == b.total_energy_j.to_bits(),
            "energy diverged from scan oracle under elastic chaos ({lb:?} x{nodes})"
        );
        greenllm::prop_assert!(
            a.events_processed == b.events_processed && a.assignment == b.assignment,
            "interleaving diverged from scan oracle under elastic chaos"
        );
        greenllm::prop_assert!(
            a.shed == b.shed
                && a.shed_retries == b.shed_retries
                && a.deferred_arrivals == b.deferred_arrivals
                && a.capacity_provisions == b.capacity_provisions
                && a.capacity_parks == b.capacity_parks
                && a.warm_energy_j.to_bits() == b.warm_energy_j.to_bits()
                && a.straggler_nodes == b.straggler_nodes,
            "elasticity counters diverged from scan oracle"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// PR 10: control-plane robustness — faultable actuation/telemetry and the
// governor supervisor.
// ---------------------------------------------------------------------------

#[test]
fn inert_ctl_section_is_bit_exact_with_default_control_plane() {
    // Every `[ctl]` knob set but nothing armed (noise off, supervisor
    // off): the control plane must be pure plumbing — same bits as the
    // default config, zero interference counters, no RNG draws.
    let trace = chat(10.0, 40.0, 53);
    let mut armed_cfg = node_cfg(Method::GreenLlm, 7);
    armed_cfg.ctl.delay_s = 0.5;
    armed_cfg.ctl.drop_prob = 0.9;
    armed_cfg.ctl.misstep_prob = 0.9;
    armed_cfg.ctl.quantize = 50.0;
    armed_cfg.ctl.stale_s = 0.2;
    armed_cfg.ctl.breach_streak = 2;
    let base = ClusterConfig::new(2, LbPolicy::JoinShortestQueue, node_cfg(Method::GreenLlm, 7));
    let armed = ClusterConfig::new(2, LbPolicy::JoinShortestQueue, armed_cfg);
    let a = run_cluster(&base, &trace, &RunOptions::default());
    let b = run_cluster(&armed, &trace, &RunOptions::default());
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.assignment, b.assignment);
    assert_eq!(b.supervisor_fallbacks, 0);
    assert_eq!(b.supervisor_reengages, 0);
    assert_eq!(
        b.ctl_dropped_writes + b.ctl_delayed_writes + b.ctl_missteps + b.ctl_suppressed_samples,
        0
    );
    for (x, y) in a.per_node.iter().zip(&b.per_node) {
        assert_eq!(x.events_processed, y.events_processed);
        assert_eq!(x.total_energy_j.to_bits(), y.total_energy_j.to_bits());
    }
}

#[test]
fn acceptance_blackout_blind_policy_degrades_and_supervisor_fails_safe() {
    // The PR's headline robustness criterion. A 30 s telemetry blackout
    // on every node of a busy 2-node cluster: an unsupervised GreenLLM's
    // TPS window drains to zero, the coarse loop collapses to the lowest
    // band, and decode crawls — well past the closure band of extra TBT
    // violations. The same blackout under the supervisor trips the
    // staleness detector, pins the fail-safe clock, and stays inside the
    // band, re-engaging after telemetry returns.
    let trace = chat(10.0, 60.0, 47);
    let plan = || FaultPlan::parse("ctlblackout@10-40:0,ctlblackout@10-40:1").unwrap();
    let clean = run_cluster(
        &ClusterConfig::new(2, LbPolicy::JoinShortestQueue, node_cfg(Method::GreenLlm, 5)),
        &trace,
        &RunOptions::default(),
    );
    let blind = run_cluster(
        &ClusterConfig::new(2, LbPolicy::JoinShortestQueue, node_cfg(Method::GreenLlm, 5))
            .with_faults(plan()),
        &trace,
        &RunOptions::default(),
    );
    let mut safe_cfg = node_cfg(Method::GreenLlm, 5);
    safe_cfg.ctl.supervisor = true;
    let safe = run_cluster(
        &ClusterConfig::new(2, LbPolicy::JoinShortestQueue, safe_cfg).with_faults(plan()),
        &trace,
        &RunOptions::default(),
    );
    // A blackout perturbs clocks and telemetry, never request flow.
    for r in [&clean, &blind, &safe] {
        assert_eq!(r.completed as usize, trace.requests.len());
    }
    assert!(
        blind.ctl_suppressed_samples > 0,
        "blackout never suppressed feedback"
    );
    let blind_extra_pp = (clean.tbt_pass_rate - blind.tbt_pass_rate) * 100.0;
    assert!(
        blind_extra_pp > 3.5,
        "a 30 s blind window must cost more than the closure band: \
         clean {:.3} vs blind {:.3}",
        clean.tbt_pass_rate,
        blind.tbt_pass_rate
    );
    let safe_extra_pp = (clean.tbt_pass_rate - safe.tbt_pass_rate) * 100.0;
    assert!(
        safe_extra_pp <= 3.5,
        "the supervisor must hold the blackout inside the closure band: \
         clean {:.3} vs safe {:.3} ({} fallbacks)",
        clean.tbt_pass_rate,
        safe.tbt_pass_rate,
        safe.supervisor_fallbacks
    );
    assert!(
        safe.supervisor_fallbacks >= 1,
        "staleness on a busy pool must trip the supervisor"
    );
    assert!(
        safe.supervisor_reengages >= 1,
        "the supervisor must re-engage after telemetry returns"
    );
    assert!(
        safe.ctl_suppressed_samples > 0,
        "supervised blackout still suppresses the inner policy's feedback"
    );
}

#[test]
fn ctl_chaos_property_heap_matches_scan_oracle() {
    // Random control-plane fault schedules (actuation noise windows,
    // telemetry blackouts) composed with random capacity faults, caps and
    // supervision: request flow stays conserved and the O(log N) heap
    // scheduler stays BIT-equal with the kept-verbatim linear-scan
    // oracle, control-plane counters included. A divergence means the
    // control plane consumed randomness or time it shouldn't have.
    use greenllm::coordinator::cluster::events::run_cluster_scan_oracle;
    use greenllm::util::ptest::check;
    use greenllm::util::rng::Pcg64;

    let lbs = LbPolicy::all();
    check("ctl_chaos_heap_vs_scan_oracle", 10, |g: &mut Pcg64| {
        let nodes = 2 + g.index(3); // 2..=4
        let lb = lbs[g.index(lbs.len())];
        let qps = 4.0 + g.f64() * 8.0;
        let duration = 25.0 + g.f64() * 15.0;
        let trace = chat(qps, duration, g.next_u64());
        let mut node_config = node_cfg(Method::GreenLlm, g.next_u64());
        node_config.ctl.supervisor = g.chance(0.5);
        // Compose a random control-plane schedule: at most one noise
        // window and one blackout window, each on a random node (the
        // validate state machine forbids double-arming a node).
        let mut verbs: Vec<String> = Vec::new();
        if g.chance(0.7) {
            let node = g.index(nodes);
            let t0 = 2.0 + g.f64() * duration * 0.3;
            verbs.push(format!(
                "ctlnoise@{:.2}:{}:{:.3}:{:.2}:{:.2}",
                t0,
                node,
                0.01 + g.f64() * 0.1,
                g.f64() * 0.4,
                g.f64() * 0.2
            ));
            if g.chance(0.5) {
                verbs.push(format!("ctlquiet@{:.2}:{}", t0 + 5.0, node));
            }
        }
        if g.chance(0.7) {
            let node = g.index(nodes);
            let t0 = 2.0 + g.f64() * duration * 0.4;
            let t1 = t0 + 3.0 + g.f64() * 8.0;
            verbs.push(format!("ctlblackout@{:.2}-{:.2}:{}", t0, t1, node));
        }
        let ctl_plan = if verbs.is_empty() {
            FaultPlan::default()
        } else {
            FaultPlan::parse(&verbs.join(",")).unwrap()
        };
        let mut ccfg = ClusterConfig::new(nodes, lb, node_config);
        if g.chance(0.4) {
            // Capacity churn on a node the ctl schedule never touches
            // would be ideal, but the merged-plan validator is the real
            // contract: ctl verbs compose with node loss only when the
            // state machine allows it, so keep churn off the ctl nodes
            // by using the always-safe straggler preset.
            ccfg = ccfg.with_faults(
                FaultSpec::Straggler.plan(nodes, duration).merged(ctl_plan),
            );
        } else {
            ccfg = ccfg.with_faults(ctl_plan);
        }
        if g.chance(0.4) {
            ccfg = ccfg.with_power_cap(nodes as f64 * (1800.0 + g.f64() * 1500.0), 0.5);
            if g.chance(0.5) {
                ccfg = ccfg.with_arbiter(ArbiterStrategy::SloPressure);
            }
        }
        ccfg.faults.validate(nodes).expect("generated plan valid");
        let expect_tokens: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
        let a = run_cluster(&ccfg, &trace, &RunOptions::default());
        greenllm::prop_assert!(
            a.completed as usize == trace.requests.len(),
            "control chaos dropped requests ({lb:?} x{nodes})"
        );
        greenllm::prop_assert!(
            a.generated_tokens == expect_tokens,
            "control chaos broke token conservation ({lb:?} x{nodes})"
        );
        let b = run_cluster_scan_oracle(&ccfg, &trace, &RunOptions::default());
        greenllm::prop_assert!(
            a.total_energy_j.to_bits() == b.total_energy_j.to_bits(),
            "energy diverged from scan oracle under control chaos \
             ({lb:?} x{nodes}): {} vs {}",
            a.total_energy_j,
            b.total_energy_j
        );
        greenllm::prop_assert!(
            a.events_processed == b.events_processed && a.assignment == b.assignment,
            "interleaving diverged from scan oracle under control chaos"
        );
        greenllm::prop_assert!(
            a.supervisor_fallbacks == b.supervisor_fallbacks
                && a.supervisor_reengages == b.supervisor_reengages
                && a.ctl_dropped_writes == b.ctl_dropped_writes
                && a.ctl_delayed_writes == b.ctl_delayed_writes
                && a.ctl_missteps == b.ctl_missteps
                && a.ctl_suppressed_samples == b.ctl_suppressed_samples,
            "control-plane counters diverged from scan oracle"
        );
        Ok(())
    });
}

//! Cluster coordinator invariants: conservation under every ingress
//! policy, bit-exact degeneration to a single node, power-arbiter budget
//! guarantees, and determinism of the interleaved event loop.

use greenllm::config::{Config, Method};
use greenllm::coordinator::cluster::{run_cluster, ClusterConfig, LbPolicy};
use greenllm::coordinator::engine::{run, RunOptions};
use greenllm::workload::alibaba::{generate, ChatParams};
use greenllm::workload::request::Trace;
use greenllm::workload::synthetic;

fn node_cfg(method: Method, seed: u64) -> Config {
    Config {
        method,
        seed,
        ..Config::default()
    }
}

fn chat(qps: f64, duration: f64, seed: u64) -> Trace {
    generate(&ChatParams::new(qps, duration), seed)
}

#[test]
fn every_lb_policy_conserves_requests_and_tokens() {
    let trace = chat(12.0, 45.0, 3);
    let expect_tokens: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
    for lb in LbPolicy::all() {
        for nodes in [2, 3] {
            let ccfg = ClusterConfig::new(nodes, lb, node_cfg(Method::GreenLlm, 9));
            let r = run_cluster(&ccfg, &trace, &RunOptions::default());
            assert_eq!(
                r.completed as usize,
                trace.requests.len(),
                "{lb:?} x{nodes}: lost requests"
            );
            assert_eq!(
                r.generated_tokens, expect_tokens,
                "{lb:?} x{nodes}: token conservation"
            );
            assert_eq!(
                r.assignment.iter().sum::<usize>(),
                trace.requests.len(),
                "{lb:?} x{nodes}: assignment accounting"
            );
            // Per-node completions add up too.
            let per: u64 = r.per_node.iter().map(|n| n.completed).sum();
            assert_eq!(per, r.completed, "{lb:?} x{nodes}");
        }
    }
}

#[test]
fn multi_tenant_trace_conserves_under_phase_aware() {
    let trace = synthetic::multi_tenant(6.0, 1.5, 45.0, 5);
    let ccfg = ClusterConfig::new(4, LbPolicy::PhaseAware, node_cfg(Method::GreenLlm, 1));
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len());
    // The dedicated long pool (last node) must actually receive traffic on
    // a long-prompt-heavy tenant mix.
    assert!(r.assignment[3] > 0, "long pool starved: {:?}", r.assignment);
}

#[test]
fn single_node_cluster_bit_exact_with_plain_run_per_method() {
    // The interleaved event loop with online injection must reproduce the
    // pre-scheduled replay exactly when there is nothing to balance.
    let trace = chat(5.0, 40.0, 11);
    for method in [Method::DefaultNv, Method::GreenLlm, Method::Agft] {
        for lb in LbPolicy::all() {
            let ccfg = ClusterConfig::new(1, lb, node_cfg(method, 23));
            let c = run_cluster(&ccfg, &trace, &RunOptions::default());
            let plain = run(&node_cfg(method, 23), &trace, &RunOptions::default());
            assert_eq!(
                c.total_energy_j.to_bits(),
                plain.total_energy_j.to_bits(),
                "{method:?}/{lb:?}: energy drifted"
            );
            assert_eq!(
                c.per_node[0].events_processed, plain.events_processed,
                "{method:?}/{lb:?}: event count drifted"
            );
            assert_eq!(c.generated_tokens, plain.generated_tokens);
            assert_eq!(
                c.ttft_pass_rate.to_bits(),
                plain.slo.ttft_pass_rate().to_bits()
            );
        }
    }
}

#[test]
fn interleaved_loop_is_deterministic_under_fixed_seed() {
    let trace = chat(10.0, 40.0, 17);
    for lb in [LbPolicy::JoinShortestQueue, LbPolicy::PhaseAware] {
        let mk = || {
            let ccfg = ClusterConfig::new(3, lb, node_cfg(Method::GreenLlm, 7));
            run_cluster(&ccfg, &trace, &RunOptions::default())
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
        assert_eq!(a.assignment, b.assignment);
        for (x, y) in a.per_node.iter().zip(&b.per_node) {
            assert_eq!(x.events_processed, y.events_processed, "{lb:?}");
            assert_eq!(x.total_energy_j.to_bits(), y.total_energy_j.to_bits());
        }
    }
}

#[test]
fn power_arbiter_grants_never_exceed_cap() {
    let trace = chat(10.0, 40.0, 29);
    let cap_w = 4200.0; // 2 nodes × 8 GPUs: feasible but binding
    let ccfg = ClusterConfig::new(
        2,
        LbPolicy::JoinShortestQueue,
        node_cfg(Method::DefaultNv, 3),
    )
    .with_power_cap(cap_w, 1.0);
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len());
    let p = r.power.as_ref().expect("capped run has a power report");
    assert!(!p.epochs.is_empty());
    assert!(!p.had_infeasible_epoch, "cap should be feasible");
    for e in &p.epochs {
        // The arbiter's own invariant: worst-case grants fit the budget.
        assert!(
            e.total_granted_w() <= cap_w + 1e-6,
            "granted {} W > cap {cap_w} W at t={}",
            e.total_granted_w(),
            e.t_s
        );
        // Shares are a split of the cap.
        assert!(e.share_w.iter().sum::<f64>() <= cap_w + 1e-6);
        // And the measured consequence: the cluster never drew more than
        // its budget in any control epoch.
        assert!(
            e.total_measured_w() <= cap_w + 1e-6,
            "measured {} W > cap {cap_w} W at t={}",
            e.total_measured_w(),
            e.t_s
        );
        // Grants are real ladder clamps.
        for &c in &e.clamp_mhz {
            assert!((210..=1410).contains(&c) && (c - 210) % 15 == 0);
        }
    }
    // The cap binds: defaultNV would boost to 1410 MHz without it.
    assert!(
        p.epochs.iter().any(|e| e.clamp_mhz.iter().any(|&c| c < 1410)),
        "cap never clamped anything"
    );
}

#[test]
fn power_capped_greenllm_still_completes_with_sane_slos() {
    let trace = chat(6.0, 40.0, 31);
    let ccfg = ClusterConfig::new(2, LbPolicy::PhaseAware, node_cfg(Method::GreenLlm, 5))
        .with_power_cap(5000.0, 1.0);
    let r = run_cluster(&ccfg, &trace, &RunOptions::default());
    assert_eq!(r.completed as usize, trace.requests.len());
    // A loose cap shouldn't wreck SLOs at light per-node load.
    assert!(r.ttft_pass_rate > 0.8, "ttft {}", r.ttft_pass_rate);
    let p = r.power.unwrap();
    assert!(p.peak_measured_w <= 5000.0 + 1e-6);
}

#[test]
fn capped_cluster_is_deterministic() {
    let trace = chat(8.0, 30.0, 37);
    let mk = || {
        let ccfg = ClusterConfig::new(
            2,
            LbPolicy::JoinShortestQueue,
            node_cfg(Method::GreenLlm, 2),
        )
        .with_power_cap(4200.0, 0.5);
        run_cluster(&ccfg, &trace, &RunOptions::default())
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    let (pa, pb) = (a.power.unwrap(), b.power.unwrap());
    assert_eq!(pa.epochs.len(), pb.epochs.len());
    for (x, y) in pa.epochs.iter().zip(&pb.epochs) {
        assert_eq!(x.clamp_mhz, y.clamp_mhz);
        assert_eq!(
            x.total_measured_w().to_bits(),
            y.total_measured_w().to_bits()
        );
    }
}

#[test]
fn cluster_acceptance_greenllm_beats_defaultnv_at_equal_nodes() {
    // The PR's headline criterion: ≥15 % cluster energy saving vs
    // defaultNV at equal node count with pass rates > 0.9.
    let trace = chat(10.0, 60.0, 41);
    for lb in [LbPolicy::JoinShortestQueue, LbPolicy::PhaseAware] {
        let nv = run_cluster(
            &ClusterConfig::new(2, lb, node_cfg(Method::DefaultNv, 5)),
            &trace,
            &RunOptions::default(),
        );
        let green = run_cluster(
            &ClusterConfig::new(2, lb, node_cfg(Method::GreenLlm, 5)),
            &trace,
            &RunOptions::default(),
        );
        let saving = 1.0 - green.total_energy_j / nv.total_energy_j;
        assert!(saving > 0.15, "{lb:?}: saving {saving:.3}");
        assert!(green.ttft_pass_rate > 0.9, "{lb:?}");
        assert!(green.tbt_pass_rate > 0.9, "{lb:?}");
    }
}

//! Conditioning properties of `util::polyfit` on the inputs the GPU
//! calibration layer actually feeds it: frequency-response curves over
//! the A100 application-clock ladder, both in the normalized
//! `x = f_ref/f` basis (calibration fits) and in raw MHz (worst-case
//! conditioning — values up to 1410 cubed inside the normal matrix).
//!
//! The fitter must stay well-behaved on every physically plausible
//! monotone latency curve: finite coefficients, high R², bounded
//! residuals. A silent conditioning failure here would poison every
//! calibrated part downstream (`gpu::calibrate` trusts these fits after
//! its own gates).

use greenllm::gpu::FreqLadder;
use greenllm::util::polyfit::{polyfit, polyval};
use greenllm::util::rng::Pcg64;
use greenllm::util::stats::{max_rel_err, r_squared};

/// A random monotone-decreasing latency curve over the A100 ladder,
/// shaped like a real frequency response: t(f) = t_mem + t_cmp·f_ref/f
/// plus bounded multiplicative measurement noise.
fn random_latency_curve(rng: &mut Pcg64, noise: f64) -> (Vec<f64>, Vec<f64>) {
    let ladder = FreqLadder::a100();
    let f_ref = ladder.max_mhz as f64;
    let t_cmp = rng.range_f64(0.01, 2.0);
    let t_mem = rng.range_f64(0.0, 1.5) * t_cmp;
    let freqs: Vec<f64> = ladder.iter().map(|m| m as f64).collect();
    let ys: Vec<f64> = freqs
        .iter()
        .map(|f| (t_mem + t_cmp * f_ref / f) * (1.0 + rng.range_f64(-noise, noise)))
        .collect();
    (freqs, ys)
}

#[test]
fn calibration_basis_fits_recover_random_frequency_responses() {
    // 200 random curves in the x = f_ref/f basis (what gpu::calibrate
    // uses): the line fit must explain essentially all variance and
    // leave residuals bounded by the injected noise.
    let mut rng = Pcg64::new(0xF17, 1);
    for trial in 0..200 {
        let noise = 0.002;
        let (freqs, ys) = random_latency_curve(&mut rng, noise);
        let f_ref = 1410.0;
        let xs: Vec<f64> = freqs.iter().map(|f| f_ref / f).collect();
        let c = polyfit(&xs, &ys, 1);
        assert!(c.iter().all(|v| v.is_finite()), "trial {trial}: coeffs {c:?}");
        assert!(c[1] > 0.0, "trial {trial}: slope {} not positive", c[1]);
        let yh: Vec<f64> = xs.iter().map(|&x| polyval(&c, x)).collect();
        let r2 = r_squared(&ys, &yh);
        assert!(r2 > 0.99, "trial {trial}: r2={r2}");
        // Residuals bounded by a small multiple of the noise floor.
        let resid = max_rel_err(&yh, &ys);
        assert!(resid < 5.0 * noise + 1e-9, "trial {trial}: resid={resid}");
    }
}

#[test]
fn raw_mhz_cubics_stay_conditioned_across_the_ladder() {
    // Power-style cubics fitted in raw MHz: the normal matrix holds
    // values up to 1410^6 ≈ 8e18 before normalization — exactly where a
    // naive implementation loses the fit. The internal x-normalization
    // must keep coefficients finite and the curve faithful.
    let mut rng = Pcg64::new(0xF18, 2);
    let ladder = FreqLadder::a100();
    let freqs: Vec<f64> = ladder.iter().map(|m| m as f64).collect();
    for trial in 0..100 {
        let k0 = rng.range_f64(50.0, 250.0);
        let k3 = rng.range_f64(1e-8, 1e-7);
        let ys: Vec<f64> = freqs.iter().map(|&f| k0 + k3 * f * f * f).collect();
        let c = polyfit(&freqs, &ys, 3);
        assert!(c.iter().all(|v| v.is_finite()), "trial {trial}: {c:?}");
        let yh: Vec<f64> = freqs.iter().map(|&f| polyval(&c, f)).collect();
        assert!(
            max_rel_err(&yh, &ys) < 1e-6,
            "trial {trial}: noiseless cubic not recovered"
        );
    }
}

#[test]
fn near_degenerate_ladder_spacing_regression() {
    // Four points spanning only three 15 MHz steps at the bottom of the
    // ladder (210..255 MHz): x-spacing is ~2% of magnitude, the classic
    // near-singular Vandermonde. A cubic through 4 points must still
    // interpolate them exactly (up to conditioning slack), not blow up.
    let xs = [210.0, 225.0, 240.0, 255.0];
    let ys = [195.8, 196.1, 196.5, 197.0];
    let c = polyfit(&xs, &ys, 3);
    assert!(c.iter().all(|v| v.is_finite()), "{c:?}");
    for (&x, &y) in xs.iter().zip(&ys) {
        let yh = polyval(&c, x);
        assert!(
            (yh - y).abs() / y < 1e-6,
            "interpolation drift at {x} MHz: {yh} vs {y}"
        );
    }
}

#[test]
fn constant_and_linear_curves_survive_overfitting_degrees() {
    // Fitting a cubic to data that is actually constant or linear must
    // return (near-)zero high-order coefficients, not noise amplified by
    // the near-singular system.
    let ladder = FreqLadder::a100();
    let freqs: Vec<f64> = ladder.iter().map(|m| m as f64).collect();
    let flat: Vec<f64> = freqs.iter().map(|_| 42.0).collect();
    let c = polyfit(&freqs, &flat, 3);
    for &f in &freqs {
        assert!((polyval(&c, f) - 42.0).abs() < 1e-6);
    }
    let lin: Vec<f64> = freqs.iter().map(|f| 3.0 + 0.25 * f).collect();
    let c = polyfit(&freqs, &lin, 3);
    for &f in &freqs {
        let y = 3.0 + 0.25 * f;
        assert!((polyval(&c, f) - y).abs() / y < 1e-9, "f={f}");
    }
}

#[test]
fn noisy_monotone_curves_never_produce_nonfinite_fits() {
    // Heavier noise (5%): the fit quality degrades, but finiteness and
    // slope sign must hold — gpu::calibrate relies on these to give a
    // *descriptive* rejection rather than a NaN-poisoned model.
    let mut rng = Pcg64::new(0xF19, 3);
    for trial in 0..100 {
        let (freqs, ys) = random_latency_curve(&mut rng, 0.05);
        let xs: Vec<f64> = freqs.iter().map(|f| 1410.0 / f).collect();
        let c = polyfit(&xs, &ys, 1);
        assert!(c.iter().all(|v| v.is_finite()), "trial {trial}: {c:?}");
        assert!(c[1] > 0.0, "trial {trial}: 5% noise flipped the slope");
    }
}

//! Property tests over the DVFS policy layer: every governor, fed
//! arbitrary telemetry, only ever emits clocks from the GPU's supported
//! ladder; and the decode controller's hysteresis never flips coarse
//! bands in opposite directions within one hold window.

use greenllm::config::{Config, DecodeCtlConfig, Method};
use greenllm::coordinator::engine::{run, RunOptions};
use greenllm::coordinator::policy::{build, DvfsPolicy};
use greenllm::coordinator::telemetry::{ClockPlan, DecodeWorkerView, PoolView, PrefillWorkerView};
use greenllm::dvfs::decode_ctl::DecodeController;
use greenllm::dvfs::prefill_opt::PrefillJobView;
use greenllm::dvfs::profiler::BandTable;
use greenllm::gpu::freq::FreqLadder;
use greenllm::gpu::perf::PerfModel;
use greenllm::gpu::power::PowerModel;
use greenllm::model::ModelSpec;
use greenllm::prop_assert;
use greenllm::util::ptest::check;
use greenllm::util::rng::Pcg64;
use greenllm::workload::alibaba::{generate, ChatParams};

fn random_method(g: &mut Pcg64) -> Method {
    match g.index(7) {
        0 => Method::DefaultNv,
        1 => Method::PrefillSplit,
        2 => Method::GreenLlm,
        3 => Method::Throttle,
        4 => Method::Agft,
        5 => Method::PiTbt,
        _ => Method::Fixed(FreqLadder::a100().snap(g.range_f64(210.0, 1410.0))),
    }
}

fn random_view(g: &mut Pcg64, now: f64, prefill_n: usize, decode_n: usize) -> PoolView {
    let prefill = (0..prefill_n)
        .map(|_| {
            let depth = g.index(6);
            PrefillWorkerView {
                busy: g.chance(0.5),
                jobs: (0..depth)
                    .map(|_| PrefillJobView {
                        prompt_len: 1 + g.index(8192) as u32,
                        deadline_s: now + g.range_f64(-0.2, 2.0),
                    })
                    .collect(),
            }
        })
        .collect();
    let decode = (0..decode_n)
        .map(|_| {
            let batch = g.index(64);
            DecodeWorkerView {
                batch,
                avg_ctx: if batch == 0 {
                    0.0
                } else {
                    g.range_f64(40.0, 4000.0)
                },
            }
        })
        .collect();
    PoolView {
        now,
        prefill,
        decode,
    }
}

/// Every `DvfsPolicy` only ever emits clocks within the GPU's supported
/// set, no matter what telemetry it is fed.
#[test]
fn policies_only_emit_supported_clocks() {
    let ladder = FreqLadder::a100();
    let perf = PerfModel::new(ModelSpec::qwen3_14b());
    let power = PowerModel::a100();
    check("policy_clocks_on_ladder", 20, |g| {
        let method = random_method(g);
        let cfg = Config {
            method,
            seed: g.next_u64(),
            sim_noise: 0.0,
            ..Config::default()
        };
        let mut policy = build(&cfg, &perf, &power);
        let assert_clock = |mhz: Option<u32>, what: &str| -> Result<(), String> {
            if let Some(f) = mhz {
                prop_assert!(
                    ladder.contains(f),
                    "{method:?}: off-ladder {f} MHz from {what}"
                );
            }
            Ok(())
        };
        assert_clock(policy.initial_clock_mhz(), "initial_clock")?;

        let ticks = policy.ticks();
        let prefill_n = cfg.pools.prefill_workers;
        let decode_n = cfg.pools.decode_workers;
        let mut plan = ClockPlan::default();
        let mut now = 0.0;
        for step in 0..60 {
            now += g.range_f64(0.001, 0.5);
            // Random event-driven feedback.
            for w in 0..decode_n {
                if g.chance(0.7) {
                    policy.on_decode_tbt(w, g.range_f64(0.0005, 0.5));
                }
                if g.chance(0.7) {
                    policy.on_decode_tbt_weighted(w, g.range_f64(0.0005, 0.5), g.index(64) as u32);
                }
                if g.chance(0.7) {
                    policy.on_decode_tokens(w, now, g.index(256) as u32);
                }
            }
            // Random prefill boundaries.
            let w = g.index(prefill_n);
            let jobs: Vec<PrefillJobView> = (0..g.index(5))
                .map(|_| PrefillJobView {
                    prompt_len: 1 + g.index(8192) as u32,
                    deadline_s: now + g.range_f64(-0.1, 1.0),
                })
                .collect();
            assert_clock(policy.on_prefill_dispatch(now, w, &jobs), "dispatch")?;
            assert_clock(policy.on_prefill_idle(now, w), "idle")?;
            if policy.wants_backlog_updates() {
                assert_clock(policy.on_prefill_backlog(now, w, &jobs), "backlog")?;
            }
            // Periodic ticks.
            if !ticks.is_empty() {
                let kind = step % ticks.len();
                let view = random_view(g, now, prefill_n, decode_n);
                plan.reset(prefill_n, decode_n);
                policy.on_tick(kind, now, &view, &mut plan);
                for mhz in plan.prefill_mhz.iter().chain(plan.decode_mhz.iter()) {
                    assert_clock(*mhz, "tick plan")?;
                }
            }
        }
        Ok(())
    });
}

fn test_table() -> BandTable {
    // 0..1000 TPS in 100-TPS buckets, 300 → 1200 MHz, ladder-aligned.
    BandTable {
        bucket_width: 100.0,
        freqs: (0..11).map(|i| 300 + i * 90).map(|f| f / 15 * 15).collect(),
    }
}

/// The decode controller's coarse hysteresis never emits opposite band
/// switches within one hold window: after a switch, another switch (in
/// either direction, and in particular the opposite one) requires at
/// least `hysteresis_ticks` further coarse intervals of consistent
/// evidence.
#[test]
fn hysteresis_never_flips_within_hold_window() {
    check("hysteresis_hold_window", 30, |g| {
        let cfg = DecodeCtlConfig {
            hysteresis_ticks: 2 + g.index(4) as u32,
            ..DecodeCtlConfig::default()
        };
        let hold = cfg.hysteresis_ticks as i64;
        let mut ctl = DecodeController::new(cfg, test_table(), 0.100);
        let mut switches: Vec<(i64, i64)> = Vec::new(); // (tick index, direction)
        let mut prev_bucket: i64 = 0;
        for tick in 0..400i64 {
            let now = tick as f64 * 0.2;
            // Adversarial TPS feed: random bursts and droughts.
            let tokens = match g.index(4) {
                0 => 0,
                1 => g.index(40) as u32,
                2 => g.index(120) as u32,
                _ => g.index(250) as u32,
            };
            ctl.on_tokens(now, tokens);
            if ctl.coarse_tick(now + 0.01).is_some() {
                let bucket = ctl.table.bucket_of(ctl.current_tps(now + 0.01)) as i64;
                let dir = if bucket >= prev_bucket { 1 } else { -1 };
                switches.push((tick, dir));
                prev_bucket = bucket;
            }
        }
        for pair in switches.windows(2) {
            let (t1, d1) = pair[0];
            let (t2, d2) = pair[1];
            prop_assert!(
                t2 - t1 >= hold,
                "switches at ticks {t1} and {t2} closer than hold window {hold}"
            );
            if d1 != d2 {
                prop_assert!(
                    t2 - t1 >= hold,
                    "opposite switches at {t1}/{t2} within hold window {hold}"
                );
            }
        }
        Ok(())
    });
}

/// Randomized fine-loop drive: the emitted clock always stays on the
/// ladder and inside the controller's current band.
#[test]
fn fine_loop_clock_always_in_band_under_random_drive() {
    let ladder = FreqLadder::a100();
    check("fine_loop_in_band", 25, |g| {
        let mut ctl = DecodeController::new(DecodeCtlConfig::default(), test_table(), 0.100);
        for i in 0..500 {
            let now = i as f64 * 0.02;
            if g.chance(0.4) {
                ctl.on_tokens(now, g.index(200) as u32);
            }
            if g.chance(0.8) {
                ctl.on_tbt(g.range_f64(0.001, 0.400));
            }
            if i % 10 == 0 {
                ctl.coarse_tick(now);
            }
            if i % 300 == 299 {
                ctl.adapt_tick(now);
            }
            let f = ctl.fine_tick(now);
            let band = ctl.current_band();
            prop_assert!(ladder.contains(f), "off-ladder {f}");
            prop_assert!(
                f >= band.lo && f <= band.hi,
                "clock {f} outside band [{}, {}]",
                band.lo,
                band.hi
            );
        }
        Ok(())
    });
}

/// End-to-end: a full replay under the learned policies keeps every
/// recorded decode clock on the ladder (the engine applies plans
/// verbatim, so this pins the whole pipeline).
#[test]
fn engine_applies_only_ladder_clocks_for_new_policies() {
    let ladder = FreqLadder::a100();
    for method in [Method::Agft, Method::PiTbt] {
        let trace = generate(&ChatParams::new(4.0, 40.0), 3);
        let cfg = Config {
            method,
            seed: 3,
            ..Config::default()
        };
        let opts = RunOptions {
            record_freq_trace: true,
            ..Default::default()
        };
        let r = run(&cfg, &trace, &opts);
        assert_eq!(r.completed as usize, trace.requests.len(), "{method:?}");
        for &(_, f) in r.decode_freq_trace.iter().chain(&r.prefill_freq_trace) {
            assert!(ladder.contains(f), "{method:?}: off-ladder {f}");
        }
    }
}

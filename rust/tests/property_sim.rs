//! Property tests for the PR 5 scheduling structures, each pitted
//! against its kept-verbatim oracle:
//!
//! * the calendar/bucket [`EventQueue`] vs the pre-PR5 binary-heap queue
//!   ([`OracleEventQueue`]) — bit-equal pop sequences under adversarial
//!   time distributions (same-timestamp bursts, denormal gaps, huge
//!   spans, priority-lane mixes, interleaved drains and clears);
//! * the [`SourceHeap`] cross-engine scheduler vs the linear-scan
//!   [`earliest`] — identical minima under random insert / re-key /
//!   remove interleavings.

use greenllm::prop_assert;
use greenllm::sim::oracle::OracleEventQueue;
use greenllm::sim::{earliest, EventQueue, SourceHeap};
use greenllm::util::ptest::check;
use greenllm::util::rng::Pcg64;

/// Draw the next event time offset under one of several adversarial
/// distributions (chosen per case, not per event, so each case commits
/// to a shape the calendar must survive).
fn next_dt(g: &mut Pcg64, shape: usize) -> f64 {
    match shape {
        // Spread: the common Poisson-ish replay shape.
        0 => g.exponential(2.0),
        // Same-timestamp bursts: mostly zero gaps.
        1 => {
            if g.chance(0.9) {
                0.0
            } else {
                g.f64() * 0.5
            }
        }
        // Huge span: sparse events across many orders of magnitude.
        2 => g.f64() * 10f64.powi(g.index(9) as i32 - 2),
        // Denormal-adjacent gaps around a big base offset.
        3 => {
            if g.chance(0.5) {
                0.0
            } else {
                g.f64() * 1e-12
            }
        }
        // Clustered: bursts separated by long idle gaps (years apart in
        // calendar terms — exercises far-heap migration).
        _ => {
            if g.chance(0.95) {
                g.f64() * 0.01
            } else {
                10.0 + g.f64() * 1000.0
            }
        }
    }
}

#[test]
fn calendar_queue_bit_equal_with_heap_oracle() {
    check("calendar_vs_heap_oracle", 40, |g| {
        let shape = g.index(5);
        let ops = 200 + g.index(2000);
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut o: OracleEventQueue<u64> = OracleEventQueue::new();
        let mut payload = 0u64;
        let mut horizon = 0.0f64; // schedule at/after both queues' `now`
        for _ in 0..ops {
            let r = g.f64();
            if r < 0.55 {
                // Schedule 1..4 events at the same drawn time (FIFO ties).
                let t = horizon + next_dt(g, shape);
                let n = 1 + g.index(3);
                for _ in 0..n {
                    if g.chance(0.3) {
                        q.schedule_priority(t, payload);
                        o.schedule_priority(t, payload);
                    } else {
                        q.schedule(t, payload);
                        o.schedule(t, payload);
                    }
                    payload += 1;
                }
            } else if r < 0.95 {
                let a = q.pop();
                let b = o.pop();
                match (a, b) {
                    (None, None) => {}
                    (Some((ta, ea)), Some((tb, eb))) => {
                        prop_assert!(
                            ta.to_bits() == tb.to_bits() && ea == eb,
                            "pop diverged: calendar ({ta}, {ea}) vs oracle ({tb}, {eb})"
                        );
                        horizon = ta;
                    }
                    (a, b) => {
                        return Err(format!("pop presence diverged: {a:?} vs {b:?}"));
                    }
                }
                prop_assert!(
                    q.now().to_bits() == o.now().to_bits(),
                    "now diverged: {} vs {}",
                    q.now(),
                    o.now()
                );
            } else if g.chance(0.5) {
                // Rare: drain both in claimed pop order and re-fill later.
                let a = q.drain_sorted();
                let b = o.drain_sorted();
                prop_assert!(a.len() == b.len(), "drain len {} vs {}", a.len(), b.len());
                for (x, y) in a.iter().zip(&b) {
                    prop_assert!(
                        x.0.to_bits() == y.0.to_bits() && x.1 == y.1,
                        "drain order diverged: {x:?} vs {y:?}"
                    );
                }
            } else {
                q.clear();
                o.clear();
            }
            prop_assert!(q.len() == o.len(), "len diverged: {} vs {}", q.len(), o.len());
            let (pa, pb) = (q.peek_time(), o.peek_time());
            prop_assert!(
                pa.map(f64::to_bits) == pb.map(f64::to_bits),
                "peek diverged: {pa:?} vs {pb:?}"
            );
        }
        // Final full drain must agree too.
        loop {
            match (q.pop(), o.pop()) {
                (None, None) => break,
                (Some((ta, ea)), Some((tb, eb))) => {
                    prop_assert!(
                        ta.to_bits() == tb.to_bits() && ea == eb,
                        "final drain diverged: ({ta}, {ea}) vs ({tb}, {eb})"
                    );
                }
                (a, b) => return Err(format!("final drain presence: {a:?} vs {b:?}")),
            }
        }
        Ok(())
    });
}

#[test]
fn fault_drain_order_unchanged_vs_oracle() {
    // Regression for the drain path `Engine::fail_into` salvages arrivals
    // through: the calendar queue's bucket-order drain must visit the
    // exact sequence the old sort-based drain produced, priority lane
    // included, at sizes that force the calendar (not heap) backend.
    check("fault_drain_order", 25, |g| {
        let shape = g.index(5);
        let n = 100 + g.index(3000);
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut o: OracleEventQueue<u64> = OracleEventQueue::new();
        let mut t = 0.0;
        for i in 0..n as u64 {
            t += next_dt(g, shape);
            if g.chance(0.25) {
                q.schedule_priority(t, i);
                o.schedule_priority(t, i);
            } else {
                q.schedule(t, i);
                o.schedule(t, i);
            }
        }
        let mut drained = Vec::with_capacity(n);
        q.drain_each(|t, ev| drained.push((t.to_bits(), ev)));
        let oracle: Vec<(u64, u64)> = o
            .drain_sorted()
            .into_iter()
            .map(|(t, ev)| (t.to_bits(), ev))
            .collect();
        prop_assert!(
            drained == oracle,
            "drain order diverged at {} events (first diff at {:?})",
            n,
            drained
                .iter()
                .zip(&oracle)
                .position(|(a, b)| a != b)
        );
        prop_assert!(q.now() == 0.0, "drain advanced time");
        prop_assert!(q.popped == 0, "drain counted as processing");
        Ok(())
    });
}

#[test]
fn source_heap_bit_equal_with_linear_scan() {
    check("source_heap_vs_earliest", 60, |g| {
        let n = 1 + g.index(48);
        let mut h = SourceHeap::new(n);
        let mut mirror: Vec<Option<f64>> = vec![None; n];
        let ops = 50 + g.index(500);
        for _ in 0..ops {
            let i = g.index(n);
            // Skewed toward Some: a live cluster mostly re-keys.
            let t = if g.chance(0.8) {
                // Coarse grid so equal keys (index tie-breaks) are common.
                Some((g.index(40) as f64) * 0.25)
            } else {
                None
            };
            h.set(i, t);
            mirror[i] = t;
            let want = earliest(&mirror);
            let got = h.min().map(|(i, _)| i);
            prop_assert!(
                got == want,
                "min diverged: heap {got:?} vs earliest {want:?} over {mirror:?}"
            );
            if let (Some((gi, gt)), Some(wi)) = (h.min(), want) {
                prop_assert!(
                    gt.to_bits() == mirror[wi].unwrap().to_bits() && gi == wi,
                    "key diverged at {gi}: {gt} vs {:?}",
                    mirror[wi]
                );
            }
            prop_assert!(
                h.len() == mirror.iter().filter(|m| m.is_some()).count(),
                "len diverged"
            );
        }
        Ok(())
    });
}

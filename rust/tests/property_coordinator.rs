//! Property tests over coordinator invariants: random workloads, random
//! pool shapes, random methods — nothing lost, nothing duplicated, energy
//! conserved, controllers always on the ladder.

use greenllm::config::{Config, Method, PoolConfig};
use greenllm::coordinator::engine::{run, RunOptions};
use greenllm::coordinator::router::Router;
use greenllm::gpu::freq::FreqLadder;
use greenllm::prop_assert;
use greenllm::util::ptest::check;
use greenllm::util::rng::Pcg64;
use greenllm::workload::request::{Request, Trace};

fn random_trace(g: &mut Pcg64, max_requests: usize) -> Trace {
    let n = 1 + g.index(max_requests);
    let duration = 10.0 + g.f64() * 60.0;
    let mut t = 0.0;
    let requests: Vec<Request> = (0..n)
        .map(|i| {
            t += g.exponential(n as f64 / duration);
            Request {
                id: i as u64,
                arrival_s: t.min(duration - 0.01),
                prompt_len: 1 + g.index(5000) as u32,
                output_len: 1 + g.index(300) as u32,
            }
        })
        .collect();
    Trace {
        name: "prop".into(),
        duration_s: duration,
        requests,
    }
}

fn random_method(g: &mut Pcg64) -> Method {
    match g.index(4) {
        0 => Method::DefaultNv,
        1 => Method::PrefillSplit,
        2 => Method::GreenLlm,
        _ => Method::Fixed(FreqLadder::a100().snap(g.range_f64(210.0, 1410.0))),
    }
}

#[test]
fn no_request_lost_or_duplicated() {
    check("no_request_lost", 25, |g| {
        let trace = random_trace(g, 120);
        let method = random_method(g);
        let cfg = Config {
            method,
            seed: g.next_u64(),
            ..Config::default()
        };
        let r = run(&cfg, &trace, &RunOptions::default());
        prop_assert!(
            r.completed as usize == trace.requests.len(),
            "{method:?}: completed {} of {}",
            r.completed,
            trace.requests.len()
        );
        let expect: u64 = trace.requests.iter().map(|q| q.output_len as u64).sum();
        prop_assert!(
            r.generated_tokens == expect,
            "{method:?}: tokens {} != {}",
            r.generated_tokens,
            expect
        );
        Ok(())
    });
}

#[test]
fn energy_bounded_by_physics() {
    check("energy_bounds", 15, |g| {
        let trace = random_trace(g, 80);
        let cfg = Config {
            method: random_method(g),
            seed: g.next_u64(),
            ..Config::default()
        };
        let r = run(&cfg, &trace, &RunOptions::default());
        let n_gpus = (cfg.pools.prefill_workers * cfg.pools.gpus_per_prefill_worker
            + cfg.pools.decode_workers * cfg.pools.gpus_per_decode_worker) as f64;
        // Idle floor (40 W min-clock idle) and active ceiling (~405 W).
        let floor = n_gpus * 40.0 * r.sim_duration_s;
        let ceil = n_gpus * 410.0 * r.sim_duration_s;
        prop_assert!(
            r.total_energy_j >= floor * 0.999 && r.total_energy_j <= ceil * 1.001,
            "energy {} outside [{floor}, {ceil}]",
            r.total_energy_j
        );
        Ok(())
    });
}

#[test]
fn outcomes_sane() {
    check("outcomes_sane", 15, |g| {
        let trace = random_trace(g, 80);
        let cfg = Config {
            method: random_method(g),
            seed: g.next_u64(),
            ..Config::default()
        };
        let opts = RunOptions {
            keep_outcomes: true,
            ..Default::default()
        };
        let r = run(&cfg, &trace, &opts);
        for o in &r.slo.outcomes {
            prop_assert!(o.ttft_s > 0.0, "nonpositive ttft");
            prop_assert!(o.finish_s >= o.arrival_s + o.ttft_s - 1e-9, "finish before ttft");
            prop_assert!(o.tbt_p95_s >= 0.0);
            // A request with k output tokens cannot finish before (k-1)
            // decode rounds of > 0 duration.
            if o.output_len > 1 {
                prop_assert!(o.finish_s > o.arrival_s + o.ttft_s);
            }
        }
        // Ids unique.
        let mut ids: Vec<u64> = r.slo.outcomes.iter().map(|o| o.id).collect();
        ids.sort();
        ids.dedup();
        prop_assert!(ids.len() == r.slo.outcomes.len(), "duplicate outcomes");
        Ok(())
    });
}

#[test]
fn pool_shapes_respected() {
    check("pool_shapes", 10, |g| {
        let pools = PoolConfig {
            prefill_workers: 1 + g.index(3),
            gpus_per_prefill_worker: 1 + g.index(2),
            decode_workers: 1 + g.index(4),
            gpus_per_decode_worker: 1,
            max_streams_per_decode_worker: 8 + g.index(64),
        };
        let trace = random_trace(g, 60);
        let cfg = Config {
            method: random_method(g),
            pools,
            seed: g.next_u64(),
            ..Config::default()
        };
        let r = run(&cfg, &trace, &RunOptions::default());
        prop_assert!(r.completed as usize == trace.requests.len());
        prop_assert!(
            r.mean_decode_batch <= cfg.pools.max_streams_per_decode_worker as f64 + 1e-9,
            "batch {} exceeds cap {}",
            r.mean_decode_batch,
            cfg.pools.max_streams_per_decode_worker
        );
        Ok(())
    });
}

#[test]
fn router_fifo_within_class() {
    // Pure-router property: among same-class requests, completion order of
    // prefill follows arrival order when served by a dedicated worker.
    check("router_fifo", 20, |g| {
        let router = Router::new(true, 2);
        let mut arrivals: Vec<Request> = (0..50)
            .map(|i| Request {
                id: i,
                arrival_s: i as f64,
                prompt_len: 1 + g.index(4000) as u32,
                output_len: 1,
            })
            .collect();
        // Queue per router decision preserves class order.
        let mut queues: Vec<Vec<u64>> = vec![Vec::new(), Vec::new()];
        for r in arrivals.drain(..) {
            queues[router.queue_for(&r)].push(r.id);
        }
        for q in &queues {
            let mut sorted = q.clone();
            sorted.sort();
            prop_assert!(&sorted == q, "router reordered within class");
        }
        Ok(())
    });
}

#[test]
fn greenllm_decode_clocks_on_ladder() {
    check("clocks_on_ladder", 8, |g| {
        let trace = random_trace(g, 80);
        let cfg = Config {
            method: Method::GreenLlm,
            seed: g.next_u64(),
            ..Config::default()
        };
        let opts = RunOptions {
            record_freq_trace: true,
            ..Default::default()
        };
        let r = run(&cfg, &trace, &opts);
        let ladder = FreqLadder::a100();
        for &(_, f) in r.decode_freq_trace.iter().chain(&r.prefill_freq_trace) {
            prop_assert!(ladder.contains(f), "off-ladder clock {f}");
        }
        Ok(())
    });
}

#[test]
fn recycled_buffers_and_quickselect_bit_stable() {
    // The engine pools per-stream TBT buffers and computes per-request
    // P95 via in-place quickselect (PR 4 hot-path work). Across random
    // workloads with wildly mixed output lengths — maximal buffer
    // recycling churn — two runs must produce bit-identical per-request
    // outcomes, and every recorded P95 must be a value the stream could
    // actually have observed (positive, below the run horizon).
    check("recycled_buffers_bit_stable", 12, |g| {
        let trace = random_trace(g, 100);
        let method = random_method(g);
        let cfg = Config {
            method,
            seed: g.next_u64(),
            ..Config::default()
        };
        let opts = RunOptions {
            keep_outcomes: true,
            ..Default::default()
        };
        let a = run(&cfg, &trace, &opts);
        let b = run(&cfg, &trace, &opts);
        prop_assert!(
            a.slo.outcomes.len() == trace.requests.len(),
            "{method:?}: outcomes {} of {}",
            a.slo.outcomes.len(),
            trace.requests.len()
        );
        for (x, y) in a.slo.outcomes.iter().zip(&b.slo.outcomes) {
            prop_assert!(x.id == y.id, "completion order drifted");
            prop_assert!(
                x.tbt_p95_s.to_bits() == y.tbt_p95_s.to_bits(),
                "req {}: p95 {} vs {}",
                x.id,
                x.tbt_p95_s,
                y.tbt_p95_s
            );
            prop_assert!(
                x.ttft_s.to_bits() == y.ttft_s.to_bits()
                    && x.finish_s.to_bits() == y.finish_s.to_bits(),
                "req {}: latency drifted",
                x.id
            );
            prop_assert!(
                x.tbt_p95_s >= 0.0 && x.tbt_p95_s <= x.finish_s,
                "req {}: implausible p95 {} (dirty recycled buffer?)",
                x.id,
                x.tbt_p95_s
            );
        }
        Ok(())
    });
}

//! Flight-recorder observability invariants: byte-deterministic Perfetto
//! export, well-formed request spans on the acceptance scenario (4-node
//! faulted disaggregated cluster), total SLO-violation attribution that
//! reconciles with the per-node SLO trackers, per-node migration
//! attribution that sums back to the cluster ledger, and bounded
//! telemetry rings with finite monotone sample times.

use std::cell::RefCell;

use greenllm::config::{Config, Method};
use greenllm::coordinator::cluster::{
    run_cluster_recorded, ClusterConfig, ClusterResult, DisaggConfig, FaultPlan, LbPolicy,
    PoolRatio,
};
use greenllm::coordinator::engine::RunOptions;
use greenllm::obs::{attribute, perfetto, FlightRecorder, SegKind};
use greenllm::util::json::Json;
use greenllm::workload::alibaba::{generate, ChatParams};
use greenllm::workload::request::Trace;

fn node_cfg(seed: u64) -> Config {
    Config {
        method: Method::GreenLlm,
        seed,
        ..Config::default()
    }
}

fn chat(qps: f64, duration: f64, seed: u64) -> Trace {
    generate(&ChatParams::new(qps, duration), seed)
}

/// The PR's acceptance deployment: 4 nodes split 2 prefill + 2 decode,
/// with a mid-trace flap of decode node 3.
fn acceptance_cfg(seed: u64) -> ClusterConfig {
    ClusterConfig::new(4, LbPolicy::JoinShortestQueue, node_cfg(seed))
        .with_pool_ratio(PoolRatio::parse("1:1").unwrap())
        .with_disagg(DisaggConfig::default())
        .with_faults(FaultPlan::parse("down@15:3,up@30:3").unwrap())
}

fn record(ccfg: &ClusterConfig, trace: &Trace, series_cap: usize) -> (FlightRecorder, ClusterResult) {
    let rec = RefCell::new(FlightRecorder::new(4, series_cap));
    let r = run_cluster_recorded(ccfg, trace, &RunOptions::default(), &rec);
    (rec.into_inner(), r)
}

#[test]
fn faulted_disagg_spans_attribution_and_trace_all_reconcile() {
    let trace = chat(12.0, 45.0, 3);
    let ccfg = acceptance_cfg(9);
    let (rec, r) = record(&ccfg, &trace, 4096);

    // Span invariants hold for every request; the run is fully drained,
    // so every record must be closed (Finished) too.
    rec.span_check(true).expect("span invariants");
    assert_eq!(rec.requests().count() as u64, r.completed);

    // Every tracker-counted violation gets exactly one cause.
    let slo = &ccfg.node.slo;
    let att = attribute(&rec, slo);
    let exp_ttft: u64 = r
        .per_node
        .iter()
        .map(|n| n.slo.completed - n.slo.ttft_passes())
        .sum();
    let exp_tbt: u64 = r
        .per_node
        .iter()
        .map(|n| n.slo.tbt_eligible() - n.slo.tbt_passes())
        .sum();
    assert_eq!(att.ttft_violations, exp_ttft, "TTFT attribution incomplete");
    assert_eq!(att.tbt_violations, exp_tbt, "TBT attribution incomplete");
    assert_eq!(att.total(), exp_ttft + exp_tbt);
    assert_eq!(att.by_cause().iter().sum::<u64>(), att.total());

    // Per-node migration attribution sums back to the cluster ledger.
    let m = r.migration.expect("split cluster migrates");
    assert_eq!(r.node_migration.len(), 4);
    let sends: u64 = r.node_migration.iter().map(|n| n.sends).sum();
    let deliveries: u64 = r.node_migration.iter().map(|n| n.deliveries).sum();
    let relays: u64 = r.node_migration.iter().map(|n| n.relays).sum();
    assert_eq!(sends, m.count, "{:?}", r.node_migration);
    assert_eq!(relays, m.relays, "{:?}", r.node_migration);
    // Prefill nodes send, decode nodes receive — never the reverse.
    assert!(r.node_migration[0].sends > 0 && r.node_migration[1].sends > 0);
    assert_eq!(r.node_migration[0].deliveries, 0);
    assert_eq!(r.node_migration[2].sends, 0);
    assert!(deliveries <= sends, "more deliveries than sends");

    // The recorder saw every send and relay as a KvTransfer segment.
    let wired: u64 = rec
        .requests()
        .map(|(_, rr)| {
            rr.segs.iter().filter(|s| s.kind == SegKind::KvTransfer).count() as u64
        })
        .sum();
    assert!(wired >= m.count, "KvTransfer segments {wired} < sends {}", m.count);

    // The exported trace re-parses and validates with the in-repo parser.
    let doc = perfetto::to_perfetto(&rec);
    let reparsed = Json::parse(&doc.dump()).expect("trace round-trips through parser");
    let stats = perfetto::validate_trace(&reparsed).expect("trace validates");
    assert_eq!(stats.nodes, 4);
    assert!(stats.spans > 0 && stats.counters > 0);
    assert!(stats.instants >= 2, "fault down+up instants missing");

    // Whole-run distributions cover every completed request.
    assert_eq!(r.ttft_hist.count(), r.completed);
    assert!(r.ttft_hist.observed_min() > 0.0);
    assert!(r.ttft_hist.observed_min() <= r.ttft_hist.observed_max());
}

#[test]
fn perfetto_export_is_byte_deterministic() {
    // Two identical seeded recorded runs must serialize to the same bytes
    // — the `--trace-out` determinism contract (BTreeMap-backed JSON, no
    // wall-clock anywhere in the recorder).
    let trace = chat(10.0, 40.0, 7);
    let mk = || {
        let (rec, _) = record(&acceptance_cfg(9), &trace, 4096);
        perfetto::to_perfetto(&rec).dump()
    };
    let a = mk();
    let b = mk();
    assert!(!a.is_empty());
    assert_eq!(a, b, "trace export not byte-deterministic");

    // And write_trace puts exactly those bytes on disk.
    let path = std::env::temp_dir().join("greenllm_obs_trace_det_test.json");
    let (rec, _) = record(&acceptance_cfg(9), &trace, 4096);
    perfetto::write_trace(&rec, path.to_str().unwrap()).expect("write_trace");
    let on_disk = std::fs::read_to_string(&path).expect("trace file readable");
    let _ = std::fs::remove_file(&path);
    assert_eq!(on_disk, a);
}

#[test]
fn node_series_are_finite_monotone_and_bounded() {
    // Satellite regression: recorder sampling at power epochs and clock
    // edges (incl. epoch-boundary clock changes) must only ever produce
    // finite, non-decreasing sample times — `sim::EventQueue` panics on
    // non-finite timestamps, and `SeriesRing` debug-asserts the same
    // contract, so a capped + faulted recorded run doubles as the
    // regression test for both.
    let trace = chat(10.0, 40.0, 11);
    let ccfg = acceptance_cfg(5).with_power_cap(4.0 * 2200.0, 1.0);
    let (rec, r) = record(&ccfg, &trace, 4096);
    assert!(r.power.is_some());
    for node in 0..rec.nodes() {
        let series = rec.series(node);
        assert!(!series.is_empty(), "node {node} recorded no samples");
        let mut prev = f64::NEG_INFINITY;
        for s in series.iter() {
            assert!(s.t.is_finite() && s.power_w.is_finite(), "node {node}: {s:?}");
            assert!(s.t >= prev, "node {node}: sample times regressed");
            prev = s.t;
            assert!(s.prefill_mhz <= 1410 && s.decode_mhz <= 1410, "{s:?}");
        }
    }
    // Arbiter epochs carried their watt grants into the series.
    let granted: usize = (0..rec.nodes())
        .map(|n| rec.series(n).iter().filter(|s| s.granted_w >= 0.0).count())
        .sum();
    assert!(granted > 0, "no granted-watt samples under a binding cap");
}

#[test]
fn series_ring_capacity_bounds_memory() {
    // A tiny [obs] series_cap must bound every node ring while counting
    // what it evicted — long recorded runs cannot grow without bound.
    let trace = chat(12.0, 45.0, 3);
    let (rec, _) = record(&acceptance_cfg(9), &trace, 8);
    for node in 0..rec.nodes() {
        let series = rec.series(node);
        assert!(series.len() <= 8, "node {node}: ring exceeded cap");
        if series.dropped() > 0 {
            assert_eq!(series.len(), 8, "node {node}: dropped before full");
        }
    }
    assert!(
        (0..rec.nodes()).any(|n| rec.series(n).dropped() > 0),
        "a 45 s faulted run must overflow an 8-sample ring"
    );
}

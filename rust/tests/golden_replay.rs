//! Golden determinism suite: the regression net under the benchmark
//! trajectory.
//!
//! Two layers of pinning:
//!  1. *Replay determinism* — the same seeded trace replayed twice per
//!     method must be bit-identical (energy, SLO rates, token counts,
//!     event counts). This catches any nondeterminism introduced into the
//!     engine/policy stack, on any machine.
//!  2. *Golden snapshot* — results are compared against the committed
//!     snapshot at `tests/golden/golden_replay.txt`. Integer fields
//!     (completed, tokens) are hard-pinned. Float fields are stored as hex
//!     f64 bit patterns; a `pending` sentinel means "pin on first run":
//!     the test fills them in and passes, and subsequent runs on that
//!     checkout compare bit-exactly. Re-bless after an intentional change
//!     with `GREENLLM_BLESS=1 cargo test --test golden_replay`.

use greenllm::config::{Config, Method};
use greenllm::coordinator::cluster::{run_cluster, ClusterConfig, ClusterResult, LbPolicy};
use greenllm::coordinator::engine::{run, RunOptions, RunResult};
use greenllm::workload::request::{Request, Trace};
use std::fmt::Write as _;
use std::path::PathBuf;

const SEED: u64 = 7;

/// Every method in the comparison set, old and new.
fn methods() -> Vec<Method> {
    vec![
        Method::DefaultNv,
        Method::PrefillSplit,
        Method::GreenLlm,
        Method::Fixed(900),
        Method::Throttle,
        Method::Agft,
        Method::PiTbt,
    ]
}

/// Hand-written, RNG-free mini trace: 24 requests at 4 QPS with cycling
/// shapes (includes a long prompt for the routing path and a prefill-only
/// request). Structural totals: 24 completions, 6 × (8+24+1+16) = 294
/// generated tokens — pinned as integers below.
fn golden_trace() -> Trace {
    let prompts = [128u32, 512, 1536, 256];
    let outputs = [8u32, 24, 1, 16];
    let requests = (0..24)
        .map(|i| Request {
            id: i as u64,
            arrival_s: i as f64 * 0.25,
            prompt_len: prompts[i % 4],
            output_len: outputs[i % 4],
        })
        .collect();
    Trace {
        name: "golden-v1".into(),
        duration_s: 6.0,
        requests,
    }
}

fn run_once(method: Method) -> RunResult {
    let cfg = Config {
        method,
        seed: SEED,
        ..Config::default()
    };
    run(&cfg, &golden_trace(), &RunOptions::default())
}

/// The interleaved-cluster scenario pinned alongside the per-method rows:
/// 2 nodes, join-shortest-queue ingress, GreenLLM per node.
fn run_cluster_once() -> ClusterResult {
    let ccfg = ClusterConfig::new(
        2,
        LbPolicy::JoinShortestQueue,
        Config {
            method: Method::GreenLlm,
            seed: SEED,
            ..Config::default()
        },
    );
    run_cluster(&ccfg, &golden_trace(), &RunOptions::default())
}

#[test]
fn cluster_scenario_structural_totals_are_exact() {
    let r = run_cluster_once();
    assert_eq!(r.completed, 24);
    assert_eq!(r.generated_tokens, 294);
    assert_eq!(r.assignment.iter().sum::<usize>(), 24);
    assert!(r.total_energy_j > 0.0 && r.total_energy_j.is_finite());
}

#[test]
fn replay_twice_is_bit_identical_per_method() {
    for method in methods() {
        let a = run_once(method);
        let b = run_once(method);
        assert_eq!(
            a.total_energy_j.to_bits(),
            b.total_energy_j.to_bits(),
            "{method:?}: total energy drifted between replays"
        );
        assert_eq!(a.prefill_energy_j.to_bits(), b.prefill_energy_j.to_bits());
        assert_eq!(a.decode_energy_j.to_bits(), b.decode_energy_j.to_bits());
        assert_eq!(a.generated_tokens, b.generated_tokens, "{method:?}");
        assert_eq!(a.completed, b.completed, "{method:?}");
        assert_eq!(a.events_processed, b.events_processed, "{method:?}");
        assert_eq!(
            a.slo.ttft_pass_rate().to_bits(),
            b.slo.ttft_pass_rate().to_bits()
        );
        assert_eq!(
            a.slo.tbt_pass_rate().to_bits(),
            b.slo.tbt_pass_rate().to_bits()
        );
    }
}

#[test]
fn structural_totals_are_exact_for_every_method() {
    for method in methods() {
        let r = run_once(method);
        assert_eq!(r.completed, 24, "{method:?}");
        assert_eq!(r.generated_tokens, 294, "{method:?}");
        assert!(r.total_energy_j > 0.0 && r.total_energy_j.is_finite());
    }
}

// ---------------------------------------------------------------------------
// Snapshot plumbing
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
struct GoldenRow {
    method: String,
    completed: u64,
    tokens: u64,
    /// None = `pending` (not yet pinned on this checkout).
    events: Option<u64>,
    energy_bits: Option<u64>,
    ttft_bits: Option<u64>,
    tbt_bits: Option<u64>,
}

impl GoldenRow {
    /// Any float field not yet pinned on this checkout?
    fn pending(&self) -> bool {
        self.events.is_none()
            || self.energy_bits.is_none()
            || self.ttft_bits.is_none()
            || self.tbt_bits.is_none()
    }

    fn from_result(r: &RunResult) -> GoldenRow {
        GoldenRow {
            method: r.method.name(),
            completed: r.completed,
            tokens: r.generated_tokens,
            events: Some(r.events_processed),
            energy_bits: Some(r.total_energy_j.to_bits()),
            ttft_bits: Some(r.slo.ttft_pass_rate().to_bits()),
            tbt_bits: Some(r.slo.tbt_pass_rate().to_bits()),
        }
    }

    fn from_cluster(label: &str, r: &ClusterResult) -> GoldenRow {
        GoldenRow {
            method: label.to_string(),
            completed: r.completed,
            tokens: r.generated_tokens,
            events: Some(r.per_node.iter().map(|n| n.events_processed).sum()),
            energy_bits: Some(r.total_energy_j.to_bits()),
            ttft_bits: Some(r.ttft_pass_rate.to_bits()),
            tbt_bits: Some(r.tbt_pass_rate.to_bits()),
        }
    }

    fn parse(line: &str) -> Option<GoldenRow> {
        let mut parts = line.split_whitespace();
        let method = parts.next()?.to_string();
        let mut row = GoldenRow {
            method,
            completed: 0,
            tokens: 0,
            events: None,
            energy_bits: None,
            ttft_bits: None,
            tbt_bits: None,
        };
        for kv in parts {
            let (k, v) = kv.split_once('=')?;
            let pinned_u64 = |v: &str| -> Option<Option<u64>> {
                if v == "pending" {
                    Some(None)
                } else {
                    v.parse::<u64>().ok().map(Some)
                }
            };
            let pinned_hex = |v: &str| -> Option<Option<u64>> {
                if v == "pending" {
                    Some(None)
                } else {
                    u64::from_str_radix(v.trim_start_matches("0x"), 16)
                        .ok()
                        .map(Some)
                }
            };
            match k {
                "completed" => row.completed = v.parse().ok()?,
                "tokens" => row.tokens = v.parse().ok()?,
                "events" => row.events = pinned_u64(v)?,
                "energy" => row.energy_bits = pinned_hex(v)?,
                "ttft" => row.ttft_bits = pinned_hex(v)?,
                "tbt" => row.tbt_bits = pinned_hex(v)?,
                _ => return None,
            }
        }
        Some(row)
    }

    fn render(&self) -> String {
        let hex = |v: &Option<u64>| match v {
            Some(bits) => format!("0x{bits:016x}"),
            None => "pending".to_string(),
        };
        let num = |v: &Option<u64>| match v {
            Some(n) => n.to_string(),
            None => "pending".to_string(),
        };
        format!(
            "{} completed={} tokens={} events={} energy={} ttft={} tbt={}",
            self.method,
            self.completed,
            self.tokens,
            num(&self.events),
            hex(&self.energy_bits),
            hex(&self.ttft_bits),
            hex(&self.tbt_bits),
        )
    }
}

fn snapshot_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/golden_replay.txt")
}

fn render_snapshot(rows: &[GoldenRow]) -> String {
    // Keep this header in sync with the committed file: a pin/bless run
    // rewrites the whole snapshot, so the workflow documentation must
    // survive the rewrite.
    let mut out = String::new();
    out.push_str(
        "# GreenLLM golden replay snapshot - trace golden-v1 (24 requests, 294 tokens), seed 7.\n",
    );
    out.push_str(
        "# Workflow: integer fields (completed, tokens) are hard-pinned. Float fields are\n",
    );
    out.push_str(
        "# hex f64 bit patterns compared bit-exactly; `pending` means \"pin on first run\":\n",
    );
    out.push_str(
        "# the first `cargo test --test golden_replay` on a toolchain-equipped machine\n",
    );
    out.push_str(
        "# fills them in and passes - commit the rewritten file to lock replays.\n",
    );
    out.push_str("# After an INTENTIONAL behavior change, re-bless with\n");
    out.push_str("#   GREENLLM_BLESS=1 cargo test --test golden_replay\n");
    out.push_str(
        "# and commit the diff (integer totals should survive a pure-policy change).\n",
    );
    for row in rows {
        let _ = writeln!(out, "{}", row.render());
    }
    out
}

#[test]
fn matches_committed_golden_snapshot() {
    let path = snapshot_path();
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("golden snapshot missing at {path:?}: {e}"));
    let committed: Vec<GoldenRow> = text
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.starts_with('#'))
        .map(|l| GoldenRow::parse(l).unwrap_or_else(|| panic!("bad golden line: {l}")))
        .collect();

    let mut actual: Vec<GoldenRow> = methods()
        .iter()
        .map(|&m| GoldenRow::from_result(&run_once(m)))
        .collect();
    actual.push(GoldenRow::from_cluster(
        "cluster2-jsq-GreenLLM",
        &run_cluster_once(),
    ));
    assert_eq!(
        committed.len(),
        actual.len(),
        "method set changed; re-bless the snapshot"
    );

    let bless = std::env::var("GREENLLM_BLESS").is_ok();
    let has_pending = committed.iter().any(GoldenRow::pending);

    // Every *pinned* field is compared, even when sibling fields are still
    // pending; only unpinned fields are exempt until their first run.
    if !bless {
        for (c, a) in committed.iter().zip(&actual) {
            assert_eq!(c.method, a.method, "method order changed; re-bless");
            assert_eq!(c.completed, a.completed, "{}: completed drifted", c.method);
            assert_eq!(c.tokens, a.tokens, "{}: token count drifted", c.method);
            let pinned = [
                ("events", c.events, a.events),
                ("energy", c.energy_bits, a.energy_bits),
                ("ttft", c.ttft_bits, a.ttft_bits),
                ("tbt", c.tbt_bits, a.tbt_bits),
            ];
            for (field, committed_v, actual_v) in pinned {
                if let Some(v) = committed_v {
                    assert_eq!(
                        Some(v),
                        actual_v,
                        "{}: golden {field} mismatch.\n committed: {}\n actual:    {}\n\
                         If this change is intentional, re-bless with \
                         GREENLLM_BLESS=1 cargo test --test golden_replay",
                        c.method,
                        c.render(),
                        a.render()
                    );
                }
            }
        }
    }

    if bless || has_pending {
        std::fs::write(&path, render_snapshot(&actual))
            .unwrap_or_else(|e| panic!("cannot pin golden snapshot {path:?}: {e}"));
        eprintln!(
            "golden snapshot pinned at {path:?} ({} rows){}",
            actual.len(),
            if bless { " [blessed]" } else { " [first run]" }
        );
    }
}

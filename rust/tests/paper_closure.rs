//! Paper-closure assertions: the reproduction must land inside the
//! declared tolerance bands of the source paper's headline result on
//! *calibrated* GPU models — not just on the analytic defaults.
//!
//! Three layers:
//!   1. Closure proper: `bench::validate::run_closure` on the calibrated
//!      A100 replays the paper's Alibaba and Azure settings and must show
//!      ≥ 25% energy savings with < 3.5 pp extra SLO violations (the
//!      paper reports ≈34%; docs/VALIDATION.md documents the gap).
//!   2. Zoo contracts: every calibrated part's fitted models must keep
//!      the physics the GreenLLM policies rely on — prefill latency
//!      non-increasing and power strictly increasing in frequency, and
//!      *phase-distinct* energy-minimal clocks (the reason prefill/decode
//!      disaggregation pays at all).
//!   3. Calibration gates: a deliberately corrupted sample table must be
//!      rejected with a clear error, never silently fitted.

use greenllm::bench::validate::{closure_workloads, closure_row, run_closure};
use greenllm::config::ClosureSection;
use greenllm::gpu::calibrate::{self, CalibrationTable};
use greenllm::model::ModelSpec;

/// Closure horizon: long enough for arrival bursts and SLO tails to
/// settle, short enough for CI (two traces × two methods ≈ seconds of
/// wall time at this simulator's event rate).
const CLOSURE_DURATION_S: f64 = 240.0;
const CLOSURE_SEED: u64 = 42;

// ---------------------------------------------------------------------
// 1. Closure proper
// ---------------------------------------------------------------------

#[test]
fn greenllm_closes_the_papers_headline_on_calibrated_a100() {
    let bands = ClosureSection::default();
    assert_eq!(bands.min_energy_savings_pct, 25.0);
    assert_eq!(bands.max_extra_violations_pct, 3.5);
    let rep = run_closure("a100", "qwen3-14b", CLOSURE_DURATION_S, CLOSURE_SEED, &bands);
    assert_eq!(rep.rows.len(), 2, "alibaba + azure");
    for r in &rep.rows {
        assert!(
            r.energy_savings_pct >= bands.min_energy_savings_pct,
            "{}: savings {:.2}% below the {:.1}% closure floor \
             (paper reports ~34%; see docs/VALIDATION.md)",
            r.workload,
            r.energy_savings_pct,
            bands.min_energy_savings_pct
        );
        assert!(
            r.extra_violations_pp < bands.max_extra_violations_pct,
            "{}: {:+.2} pp extra violations exceeds the {:.1} pp band",
            r.workload,
            r.extra_violations_pp,
            bands.max_extra_violations_pct
        );
    }
    assert!(rep.pass());
}

#[test]
fn closure_report_is_seed_deterministic() {
    // The CI gate replays this exact harness; two runs at one seed must
    // agree bit-for-bit or the gate would flake.
    let bands = ClosureSection::default();
    let trace = &closure_workloads(60.0, 7)[0];
    let a = closure_row("a100", "qwen3-14b", trace, 7, &bands);
    let b = closure_row("a100", "qwen3-14b", trace, 7, &bands);
    assert_eq!(a.nv_energy_wh.to_bits(), b.nv_energy_wh.to_bits());
    assert_eq!(a.green_energy_wh.to_bits(), b.green_energy_wh.to_bits());
    assert_eq!(a.extra_violations_pp.to_bits(), b.extra_violations_pp.to_bits());
}

// ---------------------------------------------------------------------
// 2. Zoo contracts
// ---------------------------------------------------------------------

#[test]
fn every_zoo_part_keeps_prefill_latency_monotone_in_frequency() {
    let spec = ModelSpec::qwen3_14b();
    for part in calibrate::zoo() {
        let perf = part.perf_model(spec.clone());
        let mut prev = f64::INFINITY;
        for mhz in part.ladder.iter() {
            let t = perf.prefill_time(1024, mhz);
            assert!(t.is_finite() && t > 0.0, "{}: t({mhz})={t}", part.name);
            assert!(
                t <= prev + 1e-12,
                "{}: prefill latency rose {prev} -> {t} at {mhz} MHz",
                part.name
            );
            prev = t;
        }
    }
}

#[test]
fn every_zoo_part_keeps_decode_latency_monotone_in_frequency() {
    let spec = ModelSpec::qwen3_14b();
    for part in calibrate::zoo() {
        let perf = part.perf_model(spec.clone());
        let mut prev = f64::INFINITY;
        for mhz in part.ladder.iter() {
            let t = perf.decode_step_time(16, 600.0, mhz);
            assert!(t.is_finite() && t > 0.0, "{}: t({mhz})={t}", part.name);
            assert!(
                t <= prev + 1e-12,
                "{}: decode step time rose {prev} -> {t} at {mhz} MHz",
                part.name
            );
            prev = t;
        }
    }
}

#[test]
fn every_zoo_part_keeps_power_strictly_increasing_in_frequency() {
    for part in calibrate::zoo() {
        let mut prev = 0.0;
        for mhz in part.ladder.iter() {
            let w = part.power.active_w(mhz);
            assert!(w.is_finite() && w > 0.0, "{}: P({mhz})={w}", part.name);
            assert!(
                w > prev,
                "{}: active power not strictly increasing at {mhz} MHz ({prev} -> {w})",
                part.name
            );
            prev = w;
        }
    }
}

#[test]
fn every_zoo_part_passes_its_fit_quality_gates_in_release_tests_too() {
    // `calibrate()` already enforces these at zoo construction; assert
    // them independently so a loosened gate can't slip through unnoticed.
    for part in calibrate::zoo() {
        for (label, fq) in [
            ("power", &part.fit.power),
            ("prefill", &part.fit.prefill),
            ("decode", &part.fit.decode),
        ] {
            assert!(
                fq.r2 >= 0.98,
                "{} {label}: r2={} below the 0.98 gate",
                part.name,
                fq.r2
            );
            assert!(
                fq.max_rel_resid <= 0.02,
                "{} {label}: max relative residual {} above the 2% gate",
                part.name,
                fq.max_rel_resid
            );
        }
    }
}

/// Energy-minimal clock for a phase: argmin over the part's ladder of
/// active power × phase latency (energy per unit of phase work).
fn energy_min_clock(part: &calibrate::CalibratedPart, decode: bool) -> u32 {
    let perf = part.perf_model(ModelSpec::qwen3_14b());
    let mut best = (f64::INFINITY, part.ladder.min_mhz);
    for mhz in part.ladder.iter() {
        let t = if decode {
            perf.decode_step_time(16, 600.0, mhz)
        } else {
            perf.prefill_time(1024, mhz)
        };
        let e = part.power.active_w(mhz) * t;
        if e < best.0 {
            best = (e, mhz);
        }
    }
    best.1
}

#[test]
fn calibrated_parts_want_different_clocks_for_prefill_and_decode() {
    // The disaggregation premise (§4.3, DualScale): decode is memory-
    // bound, so its energy-per-token keeps improving well below the
    // prefill knee. On every calibrated part the two phases' energy-
    // minimal clocks must be far apart — at least 10 ladder steps.
    for part in calibrate::zoo() {
        let f_prefill = energy_min_clock(part, false);
        let f_decode = energy_min_clock(part, true);
        assert!(
            f_decode < f_prefill,
            "{}: decode optimum {f_decode} MHz not below prefill optimum {f_prefill} MHz",
            part.name
        );
        let gap_steps = (f_prefill - f_decode) / part.ladder.step_mhz;
        assert!(
            gap_steps >= 10,
            "{}: phase optima only {gap_steps} ladder steps apart \
             ({f_decode} vs {f_prefill} MHz)",
            part.name
        );
        // Neither optimum sits pinned at a ladder edge — that would mean
        // the fitted envelope has no interior knee and the optimizer
        // degenerates to a bang-bang policy.
        assert!(f_prefill < part.ladder.max_mhz, "{}", part.name);
        assert!(f_decode > part.ladder.min_mhz, "{}", part.name);
    }
}

// ---------------------------------------------------------------------
// 3. Calibration gates
// ---------------------------------------------------------------------

#[test]
fn corrupted_sample_tables_fail_with_a_clear_error() {
    // Shuffled power samples: breaks the monotone-power gate.
    let mut t = CalibrationTable::a100();
    t.power_w.swap(2, 12);
    let err = calibrate::calibrate(&t).unwrap_err();
    assert!(
        err.contains("residual") || err.contains("increasing") || err.contains("R²"),
        "unhelpful error: {err}"
    );

    // Latency that *improves* as the clock drops: physically impossible,
    // must be rejected by the fit gates, not absorbed into a bad model.
    let mut t = CalibrationTable::a100();
    t.prefill_s.reverse();
    let err = calibrate::calibrate(&t).unwrap_err();
    assert!(!err.is_empty());

    // A NaN sample must never reach the fitter's output.
    let mut t = CalibrationTable::a100();
    t.decode_s[4] = f64::NAN;
    let err = calibrate::calibrate(&t).unwrap_err();
    assert!(err.contains("finite") || err.contains("NaN") || err.contains("nan"), "{err}");
}

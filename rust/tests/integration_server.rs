//! Integration: the threaded serving loop over the real PJRT engine —
//! tokenize → batch → prefill → decode → stream, no Python anywhere.
//!
//! Artifact-gated tests are `#[ignore]`d (not silently vacuous): they
//! need `make artifacts` from the Python/XLA toolchain, which the
//! in-tree `runtime/xla_stub.rs` cannot substitute for. Run with
//! `-- --ignored` after exporting. `startup_error_is_synchronous` is
//! artifact-free and always runs.

use greenllm::server::{ServerConfig, ServerHandle};
use std::path::PathBuf;
use std::time::Duration;

fn config() -> Option<ServerConfig> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping server integration: run `make artifacts` first");
        return None;
    }
    Some(ServerConfig {
        artifacts_dir: dir,
        batch_window: Duration::from_millis(2),
        ..Default::default()
    })
}

#[test]
#[ignore = "needs `make artifacts` (real PJRT engine); xla_stub builds cannot serve"]
fn serves_single_request_end_to_end() {
    let Some(cfg) = config() else { return };
    let server = ServerHandle::start(cfg).expect("server start");
    let rx = server.submit("hello energy-efficient serving", 8);
    let done = rx.recv_timeout(Duration::from_secs(120)).expect("completion");
    assert_eq!(done.tokens.len(), 8);
    assert!(done.ttft_s > 0.0);
    assert_eq!(done.tbts.len(), 7);
    assert!(done.tbts.iter().all(|&t| t >= 0.0));
    let stats = server.shutdown().expect("shutdown");
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.generated_tokens, 8);
}

#[test]
#[ignore = "needs `make artifacts` (real PJRT engine); xla_stub builds cannot serve"]
fn batches_equal_length_prompts() {
    let Some(cfg) = config() else { return };
    let server = ServerHandle::start(cfg).expect("server start");
    // Same byte length ⇒ same token length ⇒ one batch.
    let rxs: Vec<_> = (0..4)
        .map(|i| server.submit(&format!("prompt {i}"), 6))
        .collect();
    let outs: Vec<_> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(120)).expect("completion"))
        .collect();
    assert!(outs.iter().all(|c| c.tokens.len() == 6));
    let stats = server.shutdown().expect("shutdown");
    assert_eq!(stats.completed, 4);
    // All four should have ridden in few batches (≤ 2 given the 2 ms window).
    assert!(stats.batches <= 2, "batches = {}", stats.batches);
}

#[test]
#[ignore = "needs `make artifacts` (real PJRT engine); xla_stub builds cannot serve"]
fn mixed_lengths_still_all_complete() {
    let Some(cfg) = config() else { return };
    let server = ServerHandle::start(cfg).expect("server start");
    let prompts = ["a", "bb", "ccc", "dddd", "ee"];
    let rxs: Vec<_> = prompts.iter().map(|p| server.submit(p, 4)).collect();
    for rx in rxs {
        let done = rx.recv_timeout(Duration::from_secs(120)).expect("completion");
        assert_eq!(done.tokens.len(), 4);
    }
    let stats = server.shutdown().expect("shutdown");
    assert_eq!(stats.completed, 5);
}

#[test]
#[ignore = "needs `make artifacts` (real PJRT engine); xla_stub builds cannot serve"]
fn deterministic_output_for_same_prompt() {
    let Some(cfg) = config() else { return };
    let server = ServerHandle::start(cfg).expect("server start");
    let a = server
        .submit("determinism", 6)
        .recv_timeout(Duration::from_secs(120))
        .unwrap();
    let b = server
        .submit("determinism", 6)
        .recv_timeout(Duration::from_secs(120))
        .unwrap();
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.text, b.text);
    server.shutdown().unwrap();
}

#[test]
fn startup_error_is_synchronous() {
    let cfg = ServerConfig {
        artifacts_dir: PathBuf::from("/nonexistent"),
        ..Default::default()
    };
    assert!(ServerHandle::start(cfg).is_err());
}

//! Integration: full trace replays across methods/models — determinism,
//! conservation, and the paper's qualitative orderings.

use greenllm::config::{Config, Method};
use greenllm::coordinator::engine::{run, RunOptions};
use greenllm::workload::alibaba::{self, ChatParams};
use greenllm::workload::azure::{self, AzureKind, AzureParams};
use greenllm::workload::synthetic;

fn cfg(model: &str, method: Method, seed: u64) -> Config {
    Config {
        model: model.into(),
        method,
        seed,
        ..Config::default()
    }
}

#[test]
fn replay_is_bit_deterministic() {
    let trace = alibaba::generate(&ChatParams::new(5.0, 120.0), 7);
    let a = run(&cfg("qwen3-14b", Method::GreenLlm, 7), &trace, &RunOptions::default());
    let b = run(&cfg("qwen3-14b", Method::GreenLlm, 7), &trace, &RunOptions::default());
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    assert_eq!(a.generated_tokens, b.generated_tokens);
    assert_eq!(a.events_processed, b.events_processed);
}

#[test]
fn different_seed_changes_run_but_not_conservation() {
    let trace = alibaba::generate(&ChatParams::new(5.0, 120.0), 7);
    let a = run(&cfg("qwen3-14b", Method::GreenLlm, 1), &trace, &RunOptions::default());
    let b = run(&cfg("qwen3-14b", Method::GreenLlm, 2), &trace, &RunOptions::default());
    assert_ne!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    // Token conservation is seed-independent.
    let expect: u64 = trace.requests.iter().map(|r| r.output_len as u64).sum();
    assert_eq!(a.generated_tokens, expect);
    assert_eq!(b.generated_tokens, expect);
}

#[test]
fn all_methods_complete_all_requests() {
    let trace = azure::generate(&AzureParams::new(AzureKind::Conv, 8, 120.0), 3);
    for method in [
        Method::DefaultNv,
        Method::PrefillSplit,
        Method::GreenLlm,
        Method::Fixed(750),
    ] {
        let r = run(&cfg("qwen3-14b", method, 3), &trace, &RunOptions::default());
        assert_eq!(r.completed as usize, trace.requests.len(), "{method:?}");
    }
}

#[test]
fn greenllm_beats_defaultnv_on_energy_at_low_load() {
    for model in ["qwen3-14b", "qwen3-30b-moe"] {
        let trace = alibaba::generate(&ChatParams::new(1.0, 180.0), 11);
        let nv = run(&cfg(model, Method::DefaultNv, 11), &trace, &RunOptions::default());
        let green = run(&cfg(model, Method::GreenLlm, 11), &trace, &RunOptions::default());
        let saving = 1.0 - green.total_energy_j / nv.total_energy_j;
        assert!(
            saving > 0.10,
            "{model}: saving {saving:.3} (paper: 20-37% at 1 QPS)"
        );
        // Throughput parity: same tokens served; the drain tail may extend
        // (the last streams decode at lower clocks) but must stay bounded.
        assert_eq!(green.generated_tokens, nv.generated_tokens);
        assert!(green.sim_duration_s < nv.sim_duration_s * 1.6);
        // SLO compliance not sacrificed.
        assert!(green.slo.ttft_pass_rate() > 0.95);
        assert!(green.slo.tbt_pass_rate() > 0.95);
    }
}

#[test]
fn savings_shrink_with_load() {
    let saving_at = |qps: f64| {
        let trace = alibaba::generate(&ChatParams::new(qps, 180.0), 5);
        let nv = run(&cfg("qwen3-14b", Method::DefaultNv, 5), &trace, &RunOptions::default());
        let green = run(&cfg("qwen3-14b", Method::GreenLlm, 5), &trace, &RunOptions::default());
        1.0 - green.total_energy_j / nv.total_energy_j
    };
    let low = saving_at(1.0);
    let high = saving_at(10.0);
    assert!(
        low > high + 0.05,
        "savings must shrink with load: {low:.3} vs {high:.3}"
    );
}

#[test]
fn prefillsplit_tightens_ttft_but_not_energy() {
    let trace = alibaba::generate(&ChatParams::new(8.0, 240.0), 9);
    let nv = run(&cfg("qwen3-14b", Method::DefaultNv, 9), &trace, &RunOptions::default());
    let split = run(
        &cfg("qwen3-14b", Method::PrefillSplit, 9),
        &trace,
        &RunOptions::default(),
    );
    // Paper Fig. 5: SLO pass rises (89.9 → 96.4 at 8 QPS).
    assert!(
        split.slo.ttft_pass_rate() >= nv.slo.ttft_pass_rate(),
        "split {} < nv {}",
        split.slo.ttft_pass_rate(),
        nv.slo.ttft_pass_rate()
    );
    // ...but energy change stays within ±5 % (paper: ≤1–3 %).
    let d = (1.0 - split.total_energy_j / nv.total_energy_j).abs();
    assert!(d < 0.05, "split energy delta {d:.3}");
}

#[test]
fn fixed_clock_sweep_is_u_shaped() {
    let trace = alibaba::generate(&ChatParams::new(5.0, 120.0), 13);
    let energy_at = |mhz: u32| {
        run(&cfg("qwen3-14b", Method::Fixed(mhz), 13), &trace, &RunOptions::default())
            .total_energy_j
    };
    let low = energy_at(300);
    let knee = energy_at(750);
    let high = energy_at(1410);
    assert!(knee < low, "knee {knee} !< low-clock {low}");
    assert!(knee < high, "knee {knee} !< max-clock {high}");
}

#[test]
fn sinusoid_greenllm_tracks_load() {
    let trace = synthetic::sinusoid_decode(400.0, 2600.0, 120.0, 240.0, 17);
    let opts = RunOptions {
        record_freq_trace: true,
        ..Default::default()
    };
    let nv = run(&cfg("qwen3-14b", Method::DefaultNv, 17), &trace, &opts);
    let green = run(&cfg("qwen3-14b", Method::GreenLlm, 17), &trace, &opts);
    // GreenLLM's decode clock must span a wide range (Fig. 1b: ~450 MHz to
    // ~1.35 GHz); defaultNV stays in its high band.
    let range = |tr: &[(f64, u32)]| {
        let lo = tr.iter().map(|&(_, f)| f).min().unwrap_or(0);
        let hi = tr.iter().map(|&(_, f)| f).max().unwrap_or(0);
        (lo, hi)
    };
    let (g_lo, g_hi) = range(&green.decode_freq_trace);
    let (n_lo, _) = range(&nv.decode_freq_trace);
    assert!(g_hi - g_lo > 400, "green range {g_lo}-{g_hi}");
    assert!(n_lo >= 1100, "defaultNV dipped to {n_lo}");
    // Both hold p99 TBT near the SLO; GreenLLM saves decode energy.
    assert!(green.slo.tbt_hist.p99() < 0.13);
    assert!(green.decode_energy_j < nv.decode_energy_j);
}

#[test]
fn moe_decode_savings_present() {
    // Table 4: MoE still saves substantially on decode.
    let trace = azure::generate(&AzureParams::new(AzureKind::Code, 8, 180.0), 21);
    let nv = run(&cfg("qwen3-30b-moe", Method::DefaultNv, 21), &trace, &RunOptions::default());
    let green = run(&cfg("qwen3-30b-moe", Method::GreenLlm, 21), &trace, &RunOptions::default());
    let rel_decode = green.decode_energy_j / nv.decode_energy_j;
    assert!(
        (0.4..0.98).contains(&rel_decode),
        "rel decode {rel_decode:.3} (paper: 0.64-0.89)"
    );
}

//! Integration: the Rust PJRT runtime must reproduce the numerics the
//! Python side exported (artifacts/manifest.json test vectors).
//!
//! These tests need `make artifacts` to have run. They are `#[ignore]`d
//! rather than silently vacuous: without artifacts they would pass while
//! testing nothing, and this container's `runtime/xla_stub.rs` can never
//! produce artifacts (the real XLA crate is not vendored). Run them with
//! `cargo test --test integration_runtime -- --ignored` after exporting
//! artifacts on a machine with the Python/XLA toolchain; the guard below
//! still skips gracefully if the manifest is absent.

use greenllm::runtime::engine::TinyLmEngine;
use greenllm::runtime::manifest::Manifest;
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime integration: run `make artifacts` first");
        None
    }
}

fn engine() -> Option<TinyLmEngine> {
    artifacts().map(|d| TinyLmEngine::load(&d).expect("engine load"))
}

/// The deterministic token pattern aot.py used for its test vectors.
fn test_tokens(m: &Manifest) -> Vec<Vec<i32>> {
    let (b, s) = (m.batch, m.test_vectors.prefill_bucket);
    (0..b)
        .map(|r| {
            (0..s)
                .map(|c| (((r * s + c) * 7 + 3) % m.vocab) as i32)
                .collect()
        })
        .collect()
}

#[test]
#[ignore = "needs `make artifacts` (real XLA AOT export); xla_stub cannot produce them"]
fn loads_and_compiles_all_artifacts() {
    let Some(e) = engine() else { return };
    assert_eq!(e.platform(), "cpu");
    assert!(!e.manifest.prefill_buckets.is_empty());
}

#[test]
#[ignore = "needs `make artifacts` (real XLA AOT export); xla_stub cannot produce them"]
fn prefill_matches_python_test_vectors() {
    let Some(e) = engine() else { return };
    let m = &e.manifest;
    let rows = test_tokens(m);
    let bucket = m.test_vectors.prefill_bucket;
    let out = e.prefill(&rows, bucket).expect("prefill");
    let v = m.vocab;
    // Sum of last-position logits across the batch.
    let mut sum = 0.0f64;
    let mut abs = 0.0f64;
    for r in 0..m.batch {
        let base = (r * bucket + bucket - 1) * v;
        for &x in &out.logits[base..base + v] {
            sum += x as f64;
            abs += (x as f64).abs();
        }
    }
    let absmean = abs / (m.batch * v) as f64;
    let tv = &m.test_vectors;
    assert!(
        (sum - tv.last_logits_sum).abs() < 1e-2 * tv.last_logits_sum.abs().max(1.0),
        "logits sum {sum} vs python {}",
        tv.last_logits_sum
    );
    assert!(
        (absmean - tv.last_logits_absmean).abs() < 1e-3 * tv.last_logits_absmean.max(1e-6),
        "absmean {absmean} vs python {}",
        tv.last_logits_absmean
    );
    // First 8 logits of row 0's last position, element-exact-ish.
    let base = (bucket - 1) * v;
    for (i, &want) in tv.last_logits_row0_head.iter().enumerate() {
        let got = out.logits[base + i] as f64;
        assert!(
            (got - want).abs() < 1e-3,
            "logit[{i}] = {got} vs python {want}"
        );
    }
}

#[test]
#[ignore = "needs `make artifacts` (real XLA AOT export); xla_stub cannot produce them"]
fn greedy_generation_matches_python() {
    let Some(e) = engine() else { return };
    let tv = e.manifest.test_vectors.clone();
    if tv.greedy_prompt.is_empty() {
        return;
    }
    let out = e
        .generate(&[tv.greedy_prompt.clone()], tv.greedy_next_tokens.len())
        .expect("generate");
    assert_eq!(
        out[0], tv.greedy_next_tokens,
        "rust greedy path diverged from the python reference"
    );
}

#[test]
#[ignore = "needs `make artifacts` (real XLA AOT export); xla_stub cannot produce them"]
fn batched_generation_rows_independent() {
    let Some(e) = engine() else { return };
    let m = &e.manifest;
    let s = m.test_vectors.prefill_bucket.min(8);
    let p1: Vec<i32> = (0..s).map(|i| ((i * 5 + 1) % m.vocab) as i32).collect();
    let p2: Vec<i32> = (0..s).map(|i| ((i * 11 + 2) % m.vocab) as i32).collect();
    // Row result must not depend on its companions in the batch.
    let solo = e.generate(&[p1.clone()], 6).unwrap();
    let duo = e.generate(&[p1.clone(), p2], 6).unwrap();
    assert_eq!(solo[0], duo[0]);
}

#[test]
#[ignore = "needs `make artifacts` (real XLA AOT export); xla_stub cannot produce them"]
fn decode_step_respects_cache_capacity() {
    let Some(e) = engine() else { return };
    let m = &e.manifest;
    let s = m.prefill_buckets[0];
    let prompt: Vec<i32> = (0..s).map(|i| (i % m.vocab) as i32).collect();
    let out = e.prefill(&[prompt], s).unwrap();
    let bad_pos = m.max_seq as i32;
    assert!(e
        .decode_step(&[1], &out.k_cache, &out.v_cache, bad_pos)
        .is_err());
}

#[test]
#[ignore = "needs `make artifacts` (real XLA AOT export); xla_stub cannot produce them"]
fn unequal_prompt_lengths_rejected() {
    let Some(e) = engine() else { return };
    let r = e.generate(&[vec![1, 2, 3], vec![1, 2]], 4);
    assert!(r.is_err());
}

#[test]
#[ignore = "needs `make artifacts` (real XLA AOT export); xla_stub cannot produce them"]
fn oversized_batch_rejected() {
    let Some(e) = engine() else { return };
    let m = &e.manifest;
    let rows: Vec<Vec<i32>> = (0..m.batch + 1).map(|_| vec![1, 2, 3, 4]).collect();
    assert!(e.prefill(&rows, m.prefill_buckets[0]).is_err());
}

//! SLO targets and per-request pass/fail accounting.
//!
//! Paper targets (§4.2.2, following DynamoLLM/Azure): TTFT < 400 ms for
//! short/medium prompts, < 2 s for long prompts; P95 TBT ≤ 100 ms during
//! decode. The trackers compute the TTFT% / TBT% pass-rate columns of
//! Tables 3–4.

use crate::metrics::Histogram;
use crate::workload::request::{PromptClass, Request, RouteClass};

/// SLO targets in seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct SloTargets {
    /// TTFT target for short/medium prompts, seconds.
    pub ttft_short_medium_s: f64,
    /// TTFT target for long prompts, seconds.
    pub ttft_long_s: f64,
    /// P95 time-between-tokens target, seconds.
    pub tbt_p95_s: f64,
}

impl Default for SloTargets {
    fn default() -> Self {
        SloTargets {
            ttft_short_medium_s: 0.400,
            ttft_long_s: 2.0,
            tbt_p95_s: 0.100,
        }
    }
}

impl SloTargets {
    /// TTFT target for a route class, seconds.
    pub fn ttft_for(&self, class: RouteClass) -> f64 {
        match class {
            RouteClass::ShortMedium => self.ttft_short_medium_s,
            RouteClass::Long => self.ttft_long_s,
        }
    }
}

/// Outcome of one completed request.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    /// Request id.
    pub id: u64,
    /// Prompt length, tokens.
    pub prompt_len: u32,
    /// Output length, tokens.
    pub output_len: u32,
    /// Arrival time, seconds.
    pub arrival_s: f64,
    /// Time to first token (prefill completion), seconds.
    pub ttft_s: f64,
    /// P95 of this request's time-between-tokens, seconds (0 if < 2 tokens).
    pub tbt_p95_s: f64,
    /// Completion time, seconds.
    pub finish_s: f64,
}

impl RequestOutcome {
    /// Three-way prompt-size class of the request.
    pub fn prompt_class(&self) -> PromptClass {
        Request {
            id: self.id,
            arrival_s: self.arrival_s,
            prompt_len: self.prompt_len,
            output_len: self.output_len,
        }
        .prompt_class()
    }

    /// Two-way routing class of the request.
    pub fn route_class(&self) -> RouteClass {
        if self.prompt_len >= crate::workload::request::LONG_MIN {
            RouteClass::Long
        } else {
            RouteClass::ShortMedium
        }
    }
}

/// Aggregated SLO statistics over a run.
#[derive(Debug, Clone)]
pub struct SloTracker {
    /// Targets being scored against.
    pub targets: SloTargets,
    /// Requests recorded.
    pub completed: u64,
    ttft_pass: u64,
    tbt_pass: u64,
    tbt_eligible: u64,
    /// TTFT histogram over all requests.
    pub ttft_hist: Histogram,
    /// TTFT histogram, short/medium prompts only.
    pub ttft_hist_sm: Histogram,
    /// TTFT histogram, long prompts only.
    pub ttft_hist_long: Histogram,
    /// Per-request P95-TBT histogram.
    pub tbt_hist: Histogram,
    /// Retained outcomes (only when `keep_outcomes`).
    pub outcomes: Vec<RequestOutcome>,
    /// Keep per-request outcomes? (Costs memory; figure runs only.)
    pub keep_outcomes: bool,
}

impl SloTracker {
    /// An empty tracker for `targets`.
    pub fn new(targets: SloTargets) -> Self {
        SloTracker {
            targets,
            completed: 0,
            ttft_pass: 0,
            tbt_pass: 0,
            tbt_eligible: 0,
            ttft_hist: Histogram::latency(),
            ttft_hist_sm: Histogram::latency(),
            ttft_hist_long: Histogram::latency(),
            tbt_hist: Histogram::latency(),
            outcomes: Vec::new(),
            keep_outcomes: false,
        }
    }

    /// Score and record one completed request.
    pub fn record(&mut self, o: RequestOutcome) {
        self.completed += 1;
        let ttft_target = self.targets.ttft_for(o.route_class());
        if o.ttft_s <= ttft_target {
            self.ttft_pass += 1;
        }
        self.ttft_hist.record(o.ttft_s);
        match o.route_class() {
            RouteClass::ShortMedium => self.ttft_hist_sm.record(o.ttft_s),
            RouteClass::Long => self.ttft_hist_long.record(o.ttft_s),
        }
        if o.output_len >= 2 {
            self.tbt_eligible += 1;
            if o.tbt_p95_s <= self.targets.tbt_p95_s {
                self.tbt_pass += 1;
            }
            self.tbt_hist.record(o.tbt_p95_s);
        }
        if self.keep_outcomes {
            self.outcomes.push(o);
        }
    }

    /// Fraction of requests meeting their TTFT target (Tables 3–4 "TTFT %").
    pub fn ttft_pass_rate(&self) -> f64 {
        if self.completed == 0 {
            return 1.0;
        }
        self.ttft_pass as f64 / self.completed as f64
    }

    /// Fraction of streaming requests meeting P95 TBT ("TBT %").
    pub fn tbt_pass_rate(&self) -> f64 {
        if self.tbt_eligible == 0 {
            return 1.0;
        }
        self.tbt_pass as f64 / self.tbt_eligible as f64
    }

    // Raw counters, for aggregating trackers across cluster nodes.
    /// Requests that met their TTFT target.
    pub fn ttft_passes(&self) -> u64 {
        self.ttft_pass
    }
    /// Streaming requests that met the P95 TBT target.
    pub fn tbt_passes(&self) -> u64 {
        self.tbt_pass
    }
    /// Requests with ≥ 2 output tokens (TBT-scoreable).
    pub fn tbt_eligible(&self) -> u64 {
        self.tbt_eligible
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(prompt: u32, ttft: f64, tbt: f64, out: u32) -> RequestOutcome {
        RequestOutcome {
            id: 0,
            prompt_len: prompt,
            output_len: out,
            arrival_s: 0.0,
            ttft_s: ttft,
            tbt_p95_s: tbt,
            finish_s: 1.0,
        }
    }

    #[test]
    fn class_specific_ttft_targets() {
        let mut t = SloTracker::new(SloTargets::default());
        t.record(outcome(100, 0.39, 0.05, 10)); // SM pass
        t.record(outcome(100, 0.41, 0.05, 10)); // SM fail
        t.record(outcome(2000, 1.9, 0.05, 10)); // Long pass (2 s target)
        t.record(outcome(2000, 2.1, 0.05, 10)); // Long fail
        assert_eq!(t.ttft_pass_rate(), 0.5);
    }

    #[test]
    fn tbt_pass_rate_only_counts_streaming() {
        let mut t = SloTracker::new(SloTargets::default());
        t.record(outcome(100, 0.1, 0.0, 1)); // single-token: TBT-exempt
        t.record(outcome(100, 0.1, 0.09, 50)); // pass
        t.record(outcome(100, 0.1, 0.12, 50)); // fail
        assert_eq!(t.tbt_pass_rate(), 0.5);
        assert_eq!(t.ttft_pass_rate(), 1.0);
    }

    #[test]
    fn empty_tracker_passes_vacuously() {
        let t = SloTracker::new(SloTargets::default());
        assert_eq!(t.ttft_pass_rate(), 1.0);
        assert_eq!(t.tbt_pass_rate(), 1.0);
    }

    #[test]
    fn histograms_populated_by_class() {
        let mut t = SloTracker::new(SloTargets::default());
        t.record(outcome(100, 0.05, 0.02, 10));
        t.record(outcome(5000, 1.0, 0.02, 10));
        assert_eq!(t.ttft_hist.count(), 2);
        assert_eq!(t.ttft_hist_sm.count(), 1);
        assert_eq!(t.ttft_hist_long.count(), 1);
    }

    #[test]
    fn outcomes_kept_only_when_requested() {
        let mut t = SloTracker::new(SloTargets::default());
        t.record(outcome(10, 0.1, 0.01, 5));
        assert!(t.outcomes.is_empty());
        t.keep_outcomes = true;
        t.record(outcome(10, 0.1, 0.01, 5));
        assert_eq!(t.outcomes.len(), 1);
    }
}

//! Model specifications and the FLOPs/bytes cost model of Eq. (1).
//!
//! The prefill FLOPs per layer are `A·n + C·n²` where the linear term comes
//! from QKV/output projections + FFN and the quadratic term from causal
//! attention (α ≈ ½ when only the causal triangle is computed). The decode
//! phase is dominated by weight streaming (dense: all parameters per step;
//! MoE: the expert subset touched by the batch) plus KV-cache reads.

/// Mixture-of-experts configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct MoeSpec {
    /// Total expert count per FFN layer.
    pub n_experts: usize,
    /// Experts activated per token.
    pub active_experts: usize,
    /// Fraction of total parameters living in expert FFNs (the rest —
    /// attention, embeddings, router — is always streamed).
    pub expert_param_frac: f64,
}

/// Architecture + derived cost coefficients for a served model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Display name.
    pub name: String,
    /// Total parameters (streamed on dense decode).
    pub params_total: f64,
    /// Parameters active per token (dense: == total).
    pub params_active: f64,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Hidden width.
    pub d_model: usize,
    /// FFN inner width.
    pub d_ff: usize,
    /// KV heads (GQA).
    pub n_kv_heads: usize,
    /// Per-head dimension.
    pub d_head: usize,
    /// Bytes per parameter (BF16 = 2).
    pub bytes_per_param: f64,
    /// Mixture-of-experts config (`None` = dense).
    pub moe: Option<MoeSpec>,
}

impl ModelSpec {
    /// Qwen3-14B (dense): 40 layers, d_model 5120, GQA 8 KV heads (Table 2).
    pub fn qwen3_14b() -> Self {
        ModelSpec {
            name: "Qwen3-14B".into(),
            params_total: 14.8e9,
            params_active: 14.8e9,
            n_layers: 40,
            d_model: 5120,
            d_ff: 17408,
            n_kv_heads: 8,
            d_head: 128,
            bytes_per_param: 2.0,
            moe: None,
        }
    }

    /// Qwen3-30B-A3B (MoE): 48 layers, 128 experts, 8 active, 3.3 B active
    /// of 30.5 B total (Table 2).
    pub fn qwen3_30b_moe() -> Self {
        ModelSpec {
            name: "Qwen3-30B-MoE".into(),
            params_total: 30.5e9,
            params_active: 3.3e9,
            n_layers: 48,
            d_model: 2048,
            d_ff: 768,
            n_kv_heads: 4,
            d_head: 128,
            bytes_per_param: 2.0,
            moe: Some(MoeSpec {
                n_experts: 128,
                active_experts: 8,
                expert_param_frac: 0.90,
            }),
        }
    }

    /// The TinyLM actually served through PJRT (matches python/compile defaults).
    pub fn tinylm() -> Self {
        ModelSpec {
            name: "TinyLM".into(),
            params_total: 479_872.0,
            params_active: 479_872.0,
            n_layers: 2,
            d_model: 128,
            d_ff: 256,
            n_kv_heads: 4,
            d_head: 32,
            bytes_per_param: 4.0,
            moe: None,
        }
    }

    /// Linear prefill coefficient `A` of Eq. (1): FLOPs per prompt token for
    /// projections + FFN ≈ 2 · active params (one fwd pass MAC = 2 FLOPs).
    pub fn prefill_flops_linear(&self) -> f64 {
        2.0 * self.params_active
    }

    /// Quadratic prefill coefficient `C` of Eq. (1): causal attention,
    /// `4·α·d_model` per layer with α = ½ (causal triangle only).
    pub fn prefill_flops_quadratic(&self) -> f64 {
        let alpha = 0.5;
        4.0 * alpha * self.d_model as f64 * self.n_layers as f64
    }

    /// Total prefill FLOPs for a prompt of n tokens (Eq. 1 summed over layers).
    pub fn prefill_flops(&self, n: usize) -> f64 {
        let n = n as f64;
        self.prefill_flops_linear() * n + self.prefill_flops_quadratic() * n * n
    }

    /// KV-cache bytes appended per token (K+V, GQA heads, all layers, BF16).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.n_kv_heads as f64
            * self.d_head as f64
            * self.bytes_per_param
            * self.n_layers as f64
    }

    /// Weight bytes streamed per decode step for a batch of `b` streams.
    ///
    /// Dense: every parameter once (batch amortizes it). MoE: the always-on
    /// share plus the expected fraction of experts touched by `b` tokens
    /// drawing `active` of `n` experts each: 1 − (1 − a/n)^b.
    pub fn decode_weight_bytes(&self, b: usize) -> f64 {
        let total = self.params_total * self.bytes_per_param;
        match &self.moe {
            None => total,
            Some(m) => {
                let dense_part = total * (1.0 - m.expert_param_frac);
                let p_active = m.active_experts as f64 / m.n_experts as f64;
                let frac_touched = 1.0 - (1.0 - p_active).powi(b.max(1) as i32);
                dense_part + total * m.expert_param_frac * frac_touched
            }
        }
    }

    /// Decode FLOPs per token (≈ 2 · active params).
    pub fn decode_flops_per_token(&self) -> f64 {
        2.0 * self.params_active
    }

    /// Look up a spec by CLI name; `None` for unknown models.
    pub fn by_name(name: &str) -> Option<ModelSpec> {
        match name {
            "qwen3-14b" | "Qwen3-14B" => Some(ModelSpec::qwen3_14b()),
            "qwen3-30b-moe" | "Qwen3-30B-MoE" | "qwen3-30b" => Some(ModelSpec::qwen3_30b_moe()),
            "tinylm" | "TinyLM" => Some(ModelSpec::tinylm()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen14b_linear_dominates_at_short_lengths() {
        let m = ModelSpec::qwen3_14b();
        // At n = 512 the linear (FFN/projection) term must dominate.
        let n = 512.0;
        let lin = m.prefill_flops_linear() * n;
        let quad = m.prefill_flops_quadratic() * n * n;
        assert!(lin > 10.0 * quad, "lin={lin:.3e} quad={quad:.3e}");
    }

    #[test]
    fn quadratic_term_grows_with_square() {
        let m = ModelSpec::qwen3_14b();
        let f1 = m.prefill_flops(1024);
        let f2 = m.prefill_flops(2048);
        // Doubling n more than doubles FLOPs (superlinear) but less than 4×
        // while the linear term dominates.
        assert!(f2 > 2.0 * f1 && f2 < 4.0 * f1);
    }

    #[test]
    fn kv_bytes_qwen14b() {
        let m = ModelSpec::qwen3_14b();
        // 2 × 8 heads × 128 dim × 2 B × 40 layers = 163 840 B/token.
        assert_eq!(m.kv_bytes_per_token(), 163_840.0);
    }

    #[test]
    fn dense_decode_streams_all_weights_regardless_of_batch() {
        let m = ModelSpec::qwen3_14b();
        assert_eq!(m.decode_weight_bytes(1), m.decode_weight_bytes(64));
        assert!((m.decode_weight_bytes(1) - 29.6e9).abs() < 1e6);
    }

    #[test]
    fn moe_decode_bytes_grow_with_batch_and_saturate() {
        let m = ModelSpec::qwen3_30b_moe();
        let b1 = m.decode_weight_bytes(1);
        let b16 = m.decode_weight_bytes(16);
        let b256 = m.decode_weight_bytes(256);
        let total = m.params_total * m.bytes_per_param;
        assert!(b1 < b16 && b16 < b256);
        assert!(b256 <= total * 1.0001);
        assert!(b256 > 0.95 * total, "b256 should approach full streaming");
        // Single stream touches ~8/128 of expert weights + dense share.
        assert!(b1 < 0.20 * total, "b1={b1:.3e} total={total:.3e}");
    }

    #[test]
    fn moe_prefill_cheaper_per_token_than_dense_14b() {
        let moe = ModelSpec::qwen3_30b_moe();
        let dense = ModelSpec::qwen3_14b();
        assert!(moe.prefill_flops_linear() < dense.prefill_flops_linear());
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(
            ModelSpec::by_name("qwen3-14b").unwrap().name,
            "Qwen3-14B"
        );
        assert!(ModelSpec::by_name("gpt-5").is_none());
    }
}

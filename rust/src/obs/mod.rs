//! Flight-recorder observability layer: request-lifecycle spans, per-node
//! DVFS/power time series, and SLO-violation attribution — zero-cost when
//! off.
//!
//! The engine and the cluster event loop are generic over a [`Recorder`].
//! The default [`NoopRecorder`] has empty `#[inline]` hooks and
//! `ENABLED == false`, so the unrecorded path monomorphizes to exactly the
//! pre-observability code (every hook call folds away and every
//! sample-construction site is guarded by `if R::ENABLED`). The live
//! implementation is [`FlightRecorder`] (usually shared between the cluster
//! loop and its engines through [`SharedRecorder`]), which feeds:
//!
//! - [`attribution`] — a post-run pass classifying every TTFT/TBT violation
//!   by dominant cause into per-cause/per-node tables;
//! - [`perfetto`] — a Chrome/Perfetto trace-event JSON exporter
//!   (`--trace-out`) with spans on node tracks and clock/power counters.
//!
//! See `docs/OBSERVABILITY.md` for the recorder contract, the trace schema,
//! and the attribution taxonomy.

pub mod attribution;
pub mod flight;
pub mod perfetto;

pub use attribution::{attribute, Attribution, Cause, ViolationKind};
pub use flight::{FlightRecorder, ReqOutcome, ReqRecord, Seg, SegKind, SeriesRing, SharedRecorder};

/// One telemetry sample for one node, taken at an arbitration epoch or a
/// clock-change event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeSample {
    /// Virtual time of the sample, seconds.
    pub t: f64,
    /// SM clock of the node's prefill workers (first prefill GPU), MHz.
    /// 0 when the node has no prefill workers or is powered off.
    pub prefill_mhz: u32,
    /// SM clock of the node's decode workers (first decode GPU), MHz.
    /// 0 when the node has no decode workers or is powered off.
    pub decode_mhz: u32,
    /// Instantaneous node power (sum over GPUs), watts.
    pub power_w: f64,
    /// Power granted by the cluster arbiter, watts. Negative when no grant
    /// is in force (uncapped run or engine-local sample).
    pub granted_w: f64,
    /// Prefill-queue depth (requests waiting for a prefill slot).
    pub queue_depth: usize,
    /// Live decode streams (batched + waiting).
    pub active_streams: usize,
    /// Streams currently batched into decode rounds (excludes waiters).
    pub batch: usize,
}

/// Static-dispatch observability sink threaded through the engine and the
/// cluster event loop.
///
/// Every hook has an empty `#[inline]` default body, so an implementation
/// only overrides what it cares about and [`NoopRecorder`] is a no-op for
/// everything. Hooks take the *cluster node index* explicitly (plain
/// single-node runs pass node 0) and virtual timestamps in seconds.
/// Timestamps passed to hooks must be finite — the sim's `EventQueue`
/// panics on non-finite times, and the recorder asserts the same contract
/// (`debug_assert!`) so a bad sample is caught at the source.
pub trait Recorder {
    /// `false` only for [`NoopRecorder`]: lets call sites skip the *work of
    /// building hook arguments* (telemetry samples) at compile time.
    const ENABLED: bool = true;

    /// A request entered the node's prefill queue (first arrival or a
    /// fault-driven re-injection — the recorder keys on the request id).
    #[inline]
    fn arrive(&mut self, _node: usize, _t: f64, _id: u64, _prompt_len: u32, _output_len: u32) {}
    /// A prefill job for the request started on `worker`.
    #[inline]
    fn prefill_start(&mut self, _node: usize, _t: f64, _id: u64, _worker: usize) {}
    /// The request's prefill finished (for colocated streams this is also
    /// the first-token instant; migrated streams emit their first token on
    /// the decode node at delivery).
    #[inline]
    fn prefill_done(&mut self, _node: usize, _t: f64, _id: u64) {}
    /// First output token emitted on `node` (colocated decode admission).
    #[inline]
    fn first_token(&mut self, _node: usize, _t: f64, _id: u64) {}
    /// The request completed; `ttft_s`/`tbt_p95_s` are its scored metrics.
    #[inline]
    fn finish(&mut self, _node: usize, _t: f64, _id: u64, _ttft_s: f64, _tbt_p95_s: f64) {}
    /// The request was drained from a failed node after emitting `emitted`
    /// tokens (they are discarded; the request re-enters elsewhere).
    #[inline]
    fn abort(&mut self, _node: usize, _t: f64, _id: u64, _emitted: u64) {}
    /// A KV handoff left `from` for `to`; the wire is busy until
    /// `deliver_t`.
    #[inline]
    fn migrate_send(
        &mut self,
        _from: usize,
        _to: usize,
        _t: f64,
        _id: u64,
        _kv_bytes: f64,
        _deliver_t: f64,
    ) {
    }
    /// A KV handoff arrived on `node`; decode starts here.
    #[inline]
    fn migrate_deliver(&mut self, _node: usize, _t: f64, _id: u64) {}
    /// An undelivered handoff was re-sent from `from` to a new target `to`
    /// (original target failed before delivery).
    #[inline]
    fn migrate_relay(&mut self, _from: usize, _to: usize, _t: f64, _id: u64) {}
    /// A handoff's KV was lost with its sender; the request restarts from
    /// prefill on `node`.
    #[inline]
    fn re_prefill(&mut self, _node: usize, _t: f64, _id: u64) {}
    /// Node fault transition (`up == false`: loss, `up == true`: recovery).
    #[inline]
    fn fault(&mut self, _node: usize, _t: f64, _up: bool) {}
    /// A DVFS action changed the SM clock of the worker pool starting at
    /// GPU `first_gpu` to `mhz` (post-ladder-snap value).
    #[inline]
    fn clock_change(&mut self, _node: usize, _t: f64, _first_gpu: usize, _mhz: u32) {}
    /// A full telemetry sample for `node` (epoch or clock-change edge).
    #[inline]
    fn sample(&mut self, _node: usize, _s: NodeSample) {}
    /// The overload gate deferred an arrival: re-offer `attempt`
    /// (1-based) was scheduled with backoff.
    #[inline]
    fn admission_retry(&mut self, _t: f64, _id: u64, _attempt: u32) {}
    /// The overload gate shed the request permanently (out of retries).
    #[inline]
    fn shed(&mut self, _t: f64, _id: u64) {}
    /// An elastic-capacity transition on `node`: `"drain"` (spot notice),
    /// `"slow"`/`"restore"` (straggler), `"park"`/`"boot"`/`"join"`
    /// (capacity controller).
    #[inline]
    fn capacity(&mut self, _node: usize, _t: f64, _what: &'static str) {}
    /// A control-plane transition on `node`: `"noise"`/`"quiet"` (actuation
    /// noise armed/cleared), `"blackout"`/`"sense"` (telemetry blackout
    /// start/end), `"fallback"`/`"probation"`/`"reengage"` (supervisor
    /// state machine).
    #[inline]
    fn ctl(&mut self, _node: usize, _t: f64, _what: &'static str) {}
}

/// The default recorder: every hook is a no-op and `ENABLED == false`, so
/// engines instantiated with it compile to the unobserved code path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NoopRecorder;

impl Recorder for NoopRecorder {
    const ENABLED: bool = false;
}

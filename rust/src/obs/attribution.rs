//! SLO-violation attribution: a post-run pass over the flight recorder that
//! classifies every TTFT/TBT violation by its dominant cause and rolls the
//! result into per-cause/per-node tables.
//!
//! The taxonomy is **total and deterministic** — every violation maps to
//! exactly one cause, so the table always sums to the violation count:
//!
//! - **TTFT** (scored against the route-class target):
//!   `admission-backoff` if the overload gate deferred the request before
//!   it was ever admitted (the system was past its shed watermark — that
//!   pressure, not clocks, is the story); else `fault-reroute` if any
//!   fault touched the request; otherwise the larger of queue-wait vs
//!   prefill-execution time decides `queueing-wait` vs `low-clock-prefill`
//!   (ties go to queueing — the scheduler owns the tie). Migration wire
//!   time never appears here because TTFT is anchored at the *sender's*
//!   prefill-done instant.
//! - **TBT** (P95 inter-token gap): `fault-reroute` if faulted; else
//!   `migration-wire-delay` when the request's KV spent longer on the wire
//!   than the TBT target (the delivery gap lands in the inter-token
//!   stream); else `decode-clock-undershoot`.
//!
//! Node attribution follows the dominant segment: the queue/prefill node
//! for TTFT causes, the decode node for TBT causes, the last-touched node
//! for fault re-routes.

use std::fmt::Write as _;

use super::flight::{FlightRecorder, ReqOutcome, SegKind};
use crate::slo::{RequestOutcome, SloTargets};
use crate::util::json::Json;

/// Dominant cause classes for an SLO violation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cause {
    /// The request sat in a prefill queue longer than it ran.
    QueueingWait,
    /// Prefill execution dominated — the prefill pool clocked too low.
    LowClockPrefill,
    /// KV handoff wire time leaked into the inter-token stream.
    MigrationWireDelay,
    /// A node fault drained/relayed/re-prefilled the request.
    FaultReroute,
    /// Decode rounds ran too slow — the decode clock undershot.
    DecodeClockUndershoot,
    /// The overload gate deferred the request with backoff before
    /// admission — shed-policy pressure, not clocks, dominated.
    AdmissionBackoff,
}

impl Cause {
    /// All causes, in table order.
    pub const ALL: [Cause; 6] = [
        Cause::QueueingWait,
        Cause::LowClockPrefill,
        Cause::MigrationWireDelay,
        Cause::FaultReroute,
        Cause::DecodeClockUndershoot,
        Cause::AdmissionBackoff,
    ];

    /// Stable kebab-case label (tables, JSON keys).
    pub fn label(self) -> &'static str {
        match self {
            Cause::QueueingWait => "queueing-wait",
            Cause::LowClockPrefill => "low-clock-prefill",
            Cause::MigrationWireDelay => "migration-wire-delay",
            Cause::FaultReroute => "fault-reroute",
            Cause::DecodeClockUndershoot => "decode-clock-undershoot",
            Cause::AdmissionBackoff => "admission-backoff",
        }
    }

    fn idx(self) -> usize {
        Cause::ALL.iter().position(|c| *c == self).unwrap()
    }
}

/// Which SLO a violation broke.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ViolationKind {
    /// Time-to-first-token target (per route class).
    Ttft,
    /// P95 time-between-tokens target.
    Tbt,
}

/// One attributed violation.
#[derive(Debug, Clone, Copy)]
pub struct Violation {
    /// Request id.
    pub id: u64,
    /// Which SLO was broken.
    pub kind: ViolationKind,
    /// Dominant cause class.
    pub cause: Cause,
    /// Node the cause is attributed to.
    pub node: usize,
    /// How far past the target the metric landed, seconds.
    pub excess_s: f64,
}

/// The rolled-up attribution result.
#[derive(Debug, Clone)]
pub struct Attribution {
    /// Every attributed violation, in request-id order (TTFT before TBT
    /// for a request that broke both).
    pub violations: Vec<Violation>,
    /// `counts[node][cause_idx]` violation counts (cause order =
    /// [`Cause::ALL`]).
    pub counts: Vec<[u64; 6]>,
    /// TTFT violations attributed.
    pub ttft_violations: u64,
    /// TBT violations attributed.
    pub tbt_violations: u64,
    /// Finished requests examined.
    pub finished: u64,
}

impl Attribution {
    /// Total violations attributed.
    pub fn total(&self) -> u64 {
        self.ttft_violations + self.tbt_violations
    }

    /// Per-cause totals across nodes, in [`Cause::ALL`] order.
    pub fn by_cause(&self) -> [u64; 6] {
        let mut out = [0u64; 6];
        for row in &self.counts {
            for (o, c) in out.iter_mut().zip(row) {
                *o += c;
            }
        }
        out
    }

    /// Render the per-cause × per-node table as aligned text.
    pub fn render_table(&self) -> String {
        let mut s = String::new();
        let _ = write!(s, "{:<24}", "cause");
        for n in 0..self.counts.len() {
            let _ = write!(s, " {:>7}", format!("node{n}"));
        }
        let _ = writeln!(s, " {:>7}", "total");
        let totals = self.by_cause();
        for cause in Cause::ALL {
            let i = cause.idx();
            let _ = write!(s, "{:<24}", cause.label());
            for row in &self.counts {
                let _ = write!(s, " {:>7}", row[i]);
            }
            let _ = writeln!(s, " {:>7}", totals[i]);
        }
        let _ = write!(s, "{:<24}", "all causes");
        for row in &self.counts {
            let _ = write!(s, " {:>7}", row.iter().sum::<u64>());
        }
        let _ = writeln!(s, " {:>7}", self.total());
        s
    }

    /// The attribution as JSON: per-cause totals plus the per-node matrix.
    pub fn to_json(&self) -> Json {
        let totals = self.by_cause();
        Json::obj([
            ("ttft_violations", Json::Num(self.ttft_violations as f64)),
            ("tbt_violations", Json::Num(self.tbt_violations as f64)),
            ("total", Json::Num(self.total() as f64)),
            (
                "by_cause",
                Json::obj(
                    Cause::ALL
                        .iter()
                        .map(|c| (c.label(), Json::Num(totals[c.idx()] as f64))),
                ),
            ),
            (
                "per_node",
                Json::Arr(
                    self.counts
                        .iter()
                        .map(|row| {
                            Json::obj(
                                Cause::ALL
                                    .iter()
                                    .map(|c| (c.label(), Json::Num(row[c.idx()] as f64))),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Classify every SLO violation among the recorder's finished requests.
///
/// Uses the same pass predicates as `slo::SloTracker::record` (TTFT fails
/// when strictly above its route-class target; TBT is scored only for
/// requests with ≥ 2 output tokens and fails strictly above the P95
/// target), so the attributed totals match the tracker's violation counts
/// exactly.
pub fn attribute(rec: &FlightRecorder, targets: &SloTargets) -> Attribution {
    let nodes = rec.nodes().max(1);
    let mut out = Attribution {
        violations: Vec::new(),
        counts: vec![[0u64; 6]; nodes],
        ttft_violations: 0,
        tbt_violations: 0,
        finished: 0,
    };
    for (&id, r) in rec.requests() {
        let (ttft_s, tbt_p95_s) = match r.outcome {
            ReqOutcome::Finished {
                ttft_s, tbt_p95_s, ..
            } => (ttft_s, tbt_p95_s),
            _ => continue,
        };
        out.finished += 1;
        // Reuse the tracker's route-class logic verbatim.
        let scored = RequestOutcome {
            id,
            prompt_len: r.prompt_len,
            output_len: r.output_len,
            arrival_s: r.arrival_s,
            ttft_s,
            tbt_p95_s,
            finish_s: 0.0,
        };
        let ttft_target = targets.ttft_for(scored.route_class());
        if ttft_s > ttft_target {
            let (cause, node) = if rec.admission_retries(id) > 0 {
                // The overload gate held this request back before it was
                // admitted: it landed on a saturated system by
                // construction, so the deferral dominates any later
                // queue/clock story.
                (
                    Cause::AdmissionBackoff,
                    r.last_node_of(SegKind::Queued).unwrap_or(0),
                )
            } else if r.faulted {
                (Cause::FaultReroute, last_touched(r))
            } else {
                let queued = r.time_in(SegKind::Queued);
                let prefill = r.time_in(SegKind::Prefill);
                if queued >= prefill {
                    (
                        Cause::QueueingWait,
                        r.last_node_of(SegKind::Queued).unwrap_or(0),
                    )
                } else {
                    (
                        Cause::LowClockPrefill,
                        r.last_node_of(SegKind::Prefill).unwrap_or(0),
                    )
                }
            };
            push(&mut out, id, ViolationKind::Ttft, cause, node, ttft_s - ttft_target);
        }
        if r.output_len >= 2 && tbt_p95_s > targets.tbt_p95_s {
            let (cause, node) = if r.faulted {
                (Cause::FaultReroute, last_touched(r))
            } else if r.time_in(SegKind::KvTransfer) > targets.tbt_p95_s {
                (
                    Cause::MigrationWireDelay,
                    r.last_node_of(SegKind::Decode).unwrap_or(0),
                )
            } else {
                (
                    Cause::DecodeClockUndershoot,
                    r.last_node_of(SegKind::Decode).unwrap_or(0),
                )
            };
            push(
                &mut out,
                id,
                ViolationKind::Tbt,
                cause,
                node,
                tbt_p95_s - targets.tbt_p95_s,
            );
        }
    }
    out
}

fn last_touched(r: &super::flight::ReqRecord) -> usize {
    r.segs.last().map(|s| s.node as usize).unwrap_or(0)
}

fn push(out: &mut Attribution, id: u64, kind: ViolationKind, cause: Cause, node: usize, ex: f64) {
    let node = node.min(out.counts.len() - 1);
    out.counts[node][cause.idx()] += 1;
    match kind {
        ViolationKind::Ttft => out.ttft_violations += 1,
        ViolationKind::Tbt => out.tbt_violations += 1,
    }
    out.violations.push(Violation {
        id,
        kind,
        cause,
        node,
        excess_s: ex,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Recorder;

    fn targets() -> SloTargets {
        SloTargets {
            ttft_short_medium_s: 0.4,
            ttft_long_s: 2.0,
            tbt_p95_s: 0.1,
        }
    }

    #[test]
    fn queue_dominated_ttft_violation_is_queueing_wait() {
        let mut fr = FlightRecorder::with_defaults(2);
        fr.arrive(1, 0.0, 1, 100, 4);
        fr.prefill_start(1, 0.5, 1, 0); // 0.5 s queued
        fr.prefill_done(1, 0.6, 1); // 0.1 s prefill
        fr.first_token(1, 0.6, 1);
        fr.finish(1, 0.8, 1, 0.6, 0.02);
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 1);
        assert_eq!(a.violations[0].cause, Cause::QueueingWait);
        assert_eq!(a.violations[0].node, 1);
        assert_eq!(a.by_cause()[Cause::QueueingWait.idx()], 1);
    }

    #[test]
    fn prefill_dominated_ttft_violation_is_low_clock() {
        let mut fr = FlightRecorder::with_defaults(1);
        fr.arrive(0, 0.0, 1, 100, 4);
        fr.prefill_start(0, 0.1, 1, 0);
        fr.prefill_done(0, 0.7, 1); // 0.6 s prefill > 0.1 s queued
        fr.first_token(0, 0.7, 1);
        fr.finish(0, 0.9, 1, 0.7, 0.02);
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 1);
        assert_eq!(a.violations[0].cause, Cause::LowClockPrefill);
    }

    #[test]
    fn faulted_request_violations_are_fault_reroute() {
        let mut fr = FlightRecorder::with_defaults(2);
        fr.arrive(0, 0.0, 1, 100, 4);
        fr.prefill_start(0, 0.1, 1, 0);
        fr.abort(0, 0.2, 1, 0);
        fr.arrive(1, 0.2, 1, 100, 4);
        fr.prefill_start(1, 0.3, 1, 0);
        fr.prefill_done(1, 0.5, 1);
        fr.first_token(1, 0.5, 1);
        fr.finish(1, 1.5, 1, 0.5, 0.3); // breaks TTFT and TBT
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 2);
        assert!(a.violations.iter().all(|v| v.cause == Cause::FaultReroute));
        assert_eq!(a.ttft_violations, 1);
        assert_eq!(a.tbt_violations, 1);
    }

    #[test]
    fn wire_dominated_tbt_violation_is_migration_wire_delay() {
        let mut fr = FlightRecorder::with_defaults(2);
        fr.arrive(0, 0.0, 1, 100, 8);
        fr.prefill_start(0, 0.0, 1, 0);
        fr.prefill_done(0, 0.2, 1);
        fr.migrate_send(0, 1, 0.2, 1, 1e6, 0.5);
        fr.migrate_deliver(1, 0.5, 1); // 0.3 s on the wire > 0.1 s target
        fr.finish(1, 1.0, 1, 0.2, 0.3);
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 1);
        assert_eq!(a.violations[0].cause, Cause::MigrationWireDelay);
        assert_eq!(a.violations[0].node, 1);
    }

    #[test]
    fn decode_undershoot_is_the_tbt_fallback_and_short_outputs_are_exempt() {
        let mut fr = FlightRecorder::with_defaults(1);
        fr.arrive(0, 0.0, 1, 100, 8);
        fr.prefill_start(0, 0.0, 1, 0);
        fr.prefill_done(0, 0.1, 1);
        fr.first_token(0, 0.1, 1);
        fr.finish(0, 2.0, 1, 0.1, 0.25);
        // Single-token request with a "bad" TBT metric: not TBT-eligible.
        fr.arrive(0, 0.0, 2, 100, 1);
        fr.prefill_start(0, 0.0, 2, 0);
        fr.prefill_done(0, 0.1, 2);
        fr.first_token(0, 0.1, 2);
        fr.finish(0, 0.1, 2, 0.1, 9.9);
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 1);
        assert_eq!(a.violations[0].cause, Cause::DecodeClockUndershoot);
    }

    #[test]
    fn retried_request_ttft_violation_is_admission_backoff() {
        let mut fr = FlightRecorder::with_defaults(2);
        // The overload gate deferred request 1 twice before admitting it.
        fr.admission_retry(0.0, 1, 1);
        fr.admission_retry(2.0, 1, 2);
        fr.arrive(1, 4.0, 1, 100, 4);
        fr.prefill_start(1, 4.5, 1, 0); // queue-dominated on its own
        fr.prefill_done(1, 4.6, 1);
        fr.first_token(1, 4.6, 1);
        fr.finish(1, 4.8, 1, 0.6, 0.02);
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 1);
        assert_eq!(a.violations[0].cause, Cause::AdmissionBackoff);
        assert_eq!(a.violations[0].node, 1);
        assert_eq!(a.by_cause()[Cause::AdmissionBackoff.idx()], 1);
        assert!(a.render_table().contains("admission-backoff"));
    }

    #[test]
    fn table_and_json_sum_to_total() {
        let mut fr = FlightRecorder::with_defaults(2);
        for (id, n) in [(1u64, 0usize), (2, 1), (3, 0)] {
            fr.arrive(n, 0.0, id, 100, 4);
            fr.prefill_start(n, 0.6, id, 0);
            fr.prefill_done(n, 0.7, id);
            fr.first_token(n, 0.7, id);
            fr.finish(n, 0.9, id, 0.7, 0.02);
        }
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 3);
        let txt = a.render_table();
        assert!(txt.contains("queueing-wait"));
        let j = a.to_json();
        assert_eq!(j.path("total").and_then(Json::as_f64), Some(3.0));
        let per_node = j.get("per_node").and_then(Json::as_arr).unwrap();
        let sum: f64 = per_node
            .iter()
            .flat_map(|row| {
                Cause::ALL
                    .iter()
                    .map(|c| row.get(c.label()).and_then(Json::as_f64).unwrap())
            })
            .sum();
        assert_eq!(sum, 3.0);
    }
}

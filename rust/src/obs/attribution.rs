//! SLO-violation attribution: a post-run pass over the flight recorder that
//! classifies every TTFT/TBT violation by its dominant cause and rolls the
//! result into per-cause/per-node tables.
//!
//! The taxonomy is **total and deterministic** — every violation maps to
//! exactly one cause, so the table always sums to the violation count:
//!
//! - **TTFT** (scored against the route-class target):
//!   `admission-backoff` if the overload gate deferred the request before
//!   it was ever admitted (the system was past its shed watermark — that
//!   pressure, not clocks, is the story); else `fault-reroute` if any
//!   fault touched the request; otherwise the larger of queue-wait vs
//!   prefill-execution time decides `queueing-wait` vs `low-clock-prefill`
//!   (ties go to queueing — the scheduler owns the tie). Migration wire
//!   time never appears here because TTFT is anchored at the *sender's*
//!   prefill-done instant.
//! - **TBT** (P95 inter-token gap): `fault-reroute` if faulted; else
//!   `migration-wire-delay` when the request's KV spent longer on the wire
//!   than the TBT target (the delivery gap lands in the inter-token
//!   stream); else `decode-clock-undershoot`.
//! - **Control-plane overrides** (both SLOs, checked after the
//!   admission/fault gates but before the generic clock causes): when the
//!   violation's lifecycle window overlaps a recorded control-plane
//!   condition on its attributed node, the condition wins —
//!   `stale-telemetry` for a telemetry blackout window, `actuation-lag`
//!   for an actuation-noise window, `supervisor-fallback` while a
//!   [`GovernorSupervisor`](crate::dvfs::GovernorSupervisor) was pinned to
//!   its fallback clock — in that priority order (a dark sensor explains
//!   more than a lossy actuator, which explains more than the deliberate
//!   fail-safe response to either).
//!
//! Node attribution follows the dominant segment: the queue/prefill node
//! for TTFT causes, the decode node for TBT causes, the last-touched node
//! for fault re-routes.

use std::fmt::Write as _;

use super::flight::{FlightRecorder, ReqOutcome, SegKind};
use crate::slo::{RequestOutcome, SloTargets};
use crate::util::json::Json;

/// Dominant cause classes for an SLO violation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cause {
    /// The request sat in a prefill queue longer than it ran.
    QueueingWait,
    /// Prefill execution dominated — the prefill pool clocked too low.
    LowClockPrefill,
    /// KV handoff wire time leaked into the inter-token stream.
    MigrationWireDelay,
    /// A node fault drained/relayed/re-prefilled the request.
    FaultReroute,
    /// Decode rounds ran too slow — the decode clock undershot.
    DecodeClockUndershoot,
    /// The overload gate deferred the request with backoff before
    /// admission — shed-policy pressure, not clocks, dominated.
    AdmissionBackoff,
    /// The node's telemetry was dark (blackout window): the governor flew
    /// blind through this request's lifecycle.
    StaleTelemetry,
    /// Control-plane actuation noise (lagged/dropped/misstepped clock
    /// writes) was active on the node during the violation.
    ActuationLag,
    /// The node's supervisor was pinned to its fail-safe fallback clock —
    /// a deliberate escalation, not a policy undershoot.
    SupervisorFallback,
}

impl Cause {
    /// All causes, in table order.
    pub const ALL: [Cause; 9] = [
        Cause::QueueingWait,
        Cause::LowClockPrefill,
        Cause::MigrationWireDelay,
        Cause::FaultReroute,
        Cause::DecodeClockUndershoot,
        Cause::AdmissionBackoff,
        Cause::StaleTelemetry,
        Cause::ActuationLag,
        Cause::SupervisorFallback,
    ];

    /// Stable kebab-case label (tables, JSON keys).
    pub fn label(self) -> &'static str {
        match self {
            Cause::QueueingWait => "queueing-wait",
            Cause::LowClockPrefill => "low-clock-prefill",
            Cause::MigrationWireDelay => "migration-wire-delay",
            Cause::FaultReroute => "fault-reroute",
            Cause::DecodeClockUndershoot => "decode-clock-undershoot",
            Cause::AdmissionBackoff => "admission-backoff",
            Cause::StaleTelemetry => "stale-telemetry",
            Cause::ActuationLag => "actuation-lag",
            Cause::SupervisorFallback => "supervisor-fallback",
        }
    }

    fn idx(self) -> usize {
        Cause::ALL.iter().position(|c| *c == self).unwrap()
    }
}

/// Which SLO a violation broke.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ViolationKind {
    /// Time-to-first-token target (per route class).
    Ttft,
    /// P95 time-between-tokens target.
    Tbt,
}

/// One attributed violation.
#[derive(Debug, Clone, Copy)]
pub struct Violation {
    /// Request id.
    pub id: u64,
    /// Which SLO was broken.
    pub kind: ViolationKind,
    /// Dominant cause class.
    pub cause: Cause,
    /// Node the cause is attributed to.
    pub node: usize,
    /// How far past the target the metric landed, seconds.
    pub excess_s: f64,
}

/// The rolled-up attribution result.
#[derive(Debug, Clone)]
pub struct Attribution {
    /// Every attributed violation, in request-id order (TTFT before TBT
    /// for a request that broke both).
    pub violations: Vec<Violation>,
    /// `counts[node][cause_idx]` violation counts (cause order =
    /// [`Cause::ALL`]).
    pub counts: Vec<[u64; 9]>,
    /// TTFT violations attributed.
    pub ttft_violations: u64,
    /// TBT violations attributed.
    pub tbt_violations: u64,
    /// Finished requests examined.
    pub finished: u64,
}

impl Attribution {
    /// Total violations attributed.
    pub fn total(&self) -> u64 {
        self.ttft_violations + self.tbt_violations
    }

    /// Per-cause totals across nodes, in [`Cause::ALL`] order.
    pub fn by_cause(&self) -> [u64; 9] {
        let mut out = [0u64; 9];
        for row in &self.counts {
            for (o, c) in out.iter_mut().zip(row) {
                *o += c;
            }
        }
        out
    }

    /// Render the per-cause × per-node table as aligned text.
    pub fn render_table(&self) -> String {
        let mut s = String::new();
        let _ = write!(s, "{:<24}", "cause");
        for n in 0..self.counts.len() {
            let _ = write!(s, " {:>7}", format!("node{n}"));
        }
        let _ = writeln!(s, " {:>7}", "total");
        let totals = self.by_cause();
        for cause in Cause::ALL {
            let i = cause.idx();
            let _ = write!(s, "{:<24}", cause.label());
            for row in &self.counts {
                let _ = write!(s, " {:>7}", row[i]);
            }
            let _ = writeln!(s, " {:>7}", totals[i]);
        }
        let _ = write!(s, "{:<24}", "all causes");
        for row in &self.counts {
            let _ = write!(s, " {:>7}", row.iter().sum::<u64>());
        }
        let _ = writeln!(s, " {:>7}", self.total());
        s
    }

    /// The attribution as JSON: per-cause totals plus the per-node matrix.
    pub fn to_json(&self) -> Json {
        let totals = self.by_cause();
        Json::obj([
            ("ttft_violations", Json::Num(self.ttft_violations as f64)),
            ("tbt_violations", Json::Num(self.tbt_violations as f64)),
            ("total", Json::Num(self.total() as f64)),
            (
                "by_cause",
                Json::obj(
                    Cause::ALL
                        .iter()
                        .map(|c| (c.label(), Json::Num(totals[c.idx()] as f64))),
                ),
            ),
            (
                "per_node",
                Json::Arr(
                    self.counts
                        .iter()
                        .map(|row| {
                            Json::obj(
                                Cause::ALL
                                    .iter()
                                    .map(|c| (c.label(), Json::Num(row[c.idx()] as f64))),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Classify every SLO violation among the recorder's finished requests.
///
/// Uses the same pass predicates as `slo::SloTracker::record` (TTFT fails
/// when strictly above its route-class target; TBT is scored only for
/// requests with ≥ 2 output tokens and fails strictly above the P95
/// target), so the attributed totals match the tracker's violation counts
/// exactly.
pub fn attribute(rec: &FlightRecorder, targets: &SloTargets) -> Attribution {
    let nodes = rec.nodes().max(1);
    let ctl = CtlWindows::build(rec, nodes);
    let mut out = Attribution {
        violations: Vec::new(),
        counts: vec![[0u64; 9]; nodes],
        ttft_violations: 0,
        tbt_violations: 0,
        finished: 0,
    };
    for (&id, r) in rec.requests() {
        let (finish_s, ttft_s, tbt_p95_s) = match r.outcome {
            ReqOutcome::Finished {
                t,
                ttft_s,
                tbt_p95_s,
            } => (t, ttft_s, tbt_p95_s),
            _ => continue,
        };
        out.finished += 1;
        // Reuse the tracker's route-class logic verbatim.
        let scored = RequestOutcome {
            id,
            prompt_len: r.prompt_len,
            output_len: r.output_len,
            arrival_s: r.arrival_s,
            ttft_s,
            tbt_p95_s,
            finish_s: 0.0,
        };
        let ttft_target = targets.ttft_for(scored.route_class());
        if ttft_s > ttft_target {
            let (cause, node) = if rec.admission_retries(id) > 0 {
                // The overload gate held this request back before it was
                // admitted: it landed on a saturated system by
                // construction, so the deferral dominates any later
                // queue/clock story.
                (
                    Cause::AdmissionBackoff,
                    r.last_node_of(SegKind::Queued).unwrap_or(0),
                )
            } else if r.faulted {
                (Cause::FaultReroute, last_touched(r))
            } else {
                let tn = r
                    .last_node_of(SegKind::Prefill)
                    .or_else(|| r.last_node_of(SegKind::Queued))
                    .unwrap_or(0);
                // The TTFT story unfolds over [arrival, first token] on
                // the queue/prefill node; a control-plane condition live
                // anywhere in that window owns the violation.
                match ctl.cause_in(tn, r.arrival_s, r.arrival_s + ttft_s) {
                    Some(cause) => (cause, tn),
                    None => {
                        let queued = r.time_in(SegKind::Queued);
                        let prefill = r.time_in(SegKind::Prefill);
                        if queued >= prefill {
                            (
                                Cause::QueueingWait,
                                r.last_node_of(SegKind::Queued).unwrap_or(0),
                            )
                        } else {
                            (
                                Cause::LowClockPrefill,
                                r.last_node_of(SegKind::Prefill).unwrap_or(0),
                            )
                        }
                    }
                }
            };
            push(&mut out, id, ViolationKind::Ttft, cause, node, ttft_s - ttft_target);
        }
        if r.output_len >= 2 && tbt_p95_s > targets.tbt_p95_s {
            let dn = r.last_node_of(SegKind::Decode).unwrap_or(0);
            // Token gaps accrue from the first decode segment to the
            // finish instant on the decode node.
            let decode_t0 = r
                .segs
                .iter()
                .find(|s| s.kind == SegKind::Decode)
                .map(|s| s.t0)
                .unwrap_or(r.arrival_s);
            let (cause, node) = if r.faulted {
                (Cause::FaultReroute, last_touched(r))
            } else if let Some(cause) = ctl.cause_in(dn, decode_t0, finish_s) {
                (cause, dn)
            } else if r.time_in(SegKind::KvTransfer) > targets.tbt_p95_s {
                (Cause::MigrationWireDelay, dn)
            } else {
                (Cause::DecodeClockUndershoot, dn)
            };
            push(
                &mut out,
                id,
                ViolationKind::Tbt,
                cause,
                node,
                tbt_p95_s - targets.tbt_p95_s,
            );
        }
    }
    out
}

/// Per-node control-plane condition windows rebuilt from the recorder's
/// `ctl` transition log. A window left open at run end extends to
/// infinity (the condition was never cleared).
struct CtlWindows {
    /// Telemetry-blackout spans: `"blackout"` → `"sense"`.
    blackout: Vec<Vec<(f64, f64)>>,
    /// Actuation-noise spans: `"noise"` → `"quiet"`.
    noise: Vec<Vec<(f64, f64)>>,
    /// Supervisor pinned-fallback spans: `"fallback"` → `"probation"` or
    /// `"reengage"` (a flap re-trip opens a fresh span).
    fallback: Vec<Vec<(f64, f64)>>,
}

impl CtlWindows {
    fn build(rec: &FlightRecorder, nodes: usize) -> Self {
        let mut blackout = vec![Vec::new(); nodes];
        let mut noise = vec![Vec::new(); nodes];
        let mut fallback = vec![Vec::new(); nodes];
        let mut open_b = vec![None; nodes];
        let mut open_n = vec![None; nodes];
        let mut open_f = vec![None; nodes];
        for &(t, node, what) in rec.ctl_log() {
            let n = node.min(nodes - 1);
            match what {
                "blackout" => open_b[n] = open_b[n].or(Some(t)),
                "sense" => {
                    if let Some(t0) = open_b[n].take() {
                        blackout[n].push((t0, t));
                    }
                }
                "noise" => open_n[n] = open_n[n].or(Some(t)),
                "quiet" => {
                    if let Some(t0) = open_n[n].take() {
                        noise[n].push((t0, t));
                    }
                }
                "fallback" => open_f[n] = open_f[n].or(Some(t)),
                "probation" | "reengage" => {
                    if let Some(t0) = open_f[n].take() {
                        fallback[n].push((t0, t));
                    }
                }
                _ => {}
            }
        }
        for n in 0..nodes {
            if let Some(t0) = open_b[n] {
                blackout[n].push((t0, f64::INFINITY));
            }
            if let Some(t0) = open_n[n] {
                noise[n].push((t0, f64::INFINITY));
            }
            if let Some(t0) = open_f[n] {
                fallback[n].push((t0, f64::INFINITY));
            }
        }
        CtlWindows {
            blackout,
            noise,
            fallback,
        }
    }

    fn hit(spans: &[(f64, f64)], a: f64, b: f64) -> bool {
        spans.iter().any(|&(t0, t1)| t0 <= b && a <= t1)
    }

    /// The control-plane cause owning a violation whose lifecycle window
    /// `[a, b]` ran on `node`, if any — blackout beats noise beats
    /// fallback.
    fn cause_in(&self, node: usize, a: f64, b: f64) -> Option<Cause> {
        let n = node.min(self.blackout.len() - 1);
        if CtlWindows::hit(&self.blackout[n], a, b) {
            Some(Cause::StaleTelemetry)
        } else if CtlWindows::hit(&self.noise[n], a, b) {
            Some(Cause::ActuationLag)
        } else if CtlWindows::hit(&self.fallback[n], a, b) {
            Some(Cause::SupervisorFallback)
        } else {
            None
        }
    }
}

fn last_touched(r: &super::flight::ReqRecord) -> usize {
    r.segs.last().map(|s| s.node as usize).unwrap_or(0)
}

fn push(out: &mut Attribution, id: u64, kind: ViolationKind, cause: Cause, node: usize, ex: f64) {
    let node = node.min(out.counts.len() - 1);
    out.counts[node][cause.idx()] += 1;
    match kind {
        ViolationKind::Ttft => out.ttft_violations += 1,
        ViolationKind::Tbt => out.tbt_violations += 1,
    }
    out.violations.push(Violation {
        id,
        kind,
        cause,
        node,
        excess_s: ex,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Recorder;

    fn targets() -> SloTargets {
        SloTargets {
            ttft_short_medium_s: 0.4,
            ttft_long_s: 2.0,
            tbt_p95_s: 0.1,
        }
    }

    #[test]
    fn queue_dominated_ttft_violation_is_queueing_wait() {
        let mut fr = FlightRecorder::with_defaults(2);
        fr.arrive(1, 0.0, 1, 100, 4);
        fr.prefill_start(1, 0.5, 1, 0); // 0.5 s queued
        fr.prefill_done(1, 0.6, 1); // 0.1 s prefill
        fr.first_token(1, 0.6, 1);
        fr.finish(1, 0.8, 1, 0.6, 0.02);
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 1);
        assert_eq!(a.violations[0].cause, Cause::QueueingWait);
        assert_eq!(a.violations[0].node, 1);
        assert_eq!(a.by_cause()[Cause::QueueingWait.idx()], 1);
    }

    #[test]
    fn prefill_dominated_ttft_violation_is_low_clock() {
        let mut fr = FlightRecorder::with_defaults(1);
        fr.arrive(0, 0.0, 1, 100, 4);
        fr.prefill_start(0, 0.1, 1, 0);
        fr.prefill_done(0, 0.7, 1); // 0.6 s prefill > 0.1 s queued
        fr.first_token(0, 0.7, 1);
        fr.finish(0, 0.9, 1, 0.7, 0.02);
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 1);
        assert_eq!(a.violations[0].cause, Cause::LowClockPrefill);
    }

    #[test]
    fn faulted_request_violations_are_fault_reroute() {
        let mut fr = FlightRecorder::with_defaults(2);
        fr.arrive(0, 0.0, 1, 100, 4);
        fr.prefill_start(0, 0.1, 1, 0);
        fr.abort(0, 0.2, 1, 0);
        fr.arrive(1, 0.2, 1, 100, 4);
        fr.prefill_start(1, 0.3, 1, 0);
        fr.prefill_done(1, 0.5, 1);
        fr.first_token(1, 0.5, 1);
        fr.finish(1, 1.5, 1, 0.5, 0.3); // breaks TTFT and TBT
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 2);
        assert!(a.violations.iter().all(|v| v.cause == Cause::FaultReroute));
        assert_eq!(a.ttft_violations, 1);
        assert_eq!(a.tbt_violations, 1);
    }

    #[test]
    fn wire_dominated_tbt_violation_is_migration_wire_delay() {
        let mut fr = FlightRecorder::with_defaults(2);
        fr.arrive(0, 0.0, 1, 100, 8);
        fr.prefill_start(0, 0.0, 1, 0);
        fr.prefill_done(0, 0.2, 1);
        fr.migrate_send(0, 1, 0.2, 1, 1e6, 0.5);
        fr.migrate_deliver(1, 0.5, 1); // 0.3 s on the wire > 0.1 s target
        fr.finish(1, 1.0, 1, 0.2, 0.3);
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 1);
        assert_eq!(a.violations[0].cause, Cause::MigrationWireDelay);
        assert_eq!(a.violations[0].node, 1);
    }

    #[test]
    fn decode_undershoot_is_the_tbt_fallback_and_short_outputs_are_exempt() {
        let mut fr = FlightRecorder::with_defaults(1);
        fr.arrive(0, 0.0, 1, 100, 8);
        fr.prefill_start(0, 0.0, 1, 0);
        fr.prefill_done(0, 0.1, 1);
        fr.first_token(0, 0.1, 1);
        fr.finish(0, 2.0, 1, 0.1, 0.25);
        // Single-token request with a "bad" TBT metric: not TBT-eligible.
        fr.arrive(0, 0.0, 2, 100, 1);
        fr.prefill_start(0, 0.0, 2, 0);
        fr.prefill_done(0, 0.1, 2);
        fr.first_token(0, 0.1, 2);
        fr.finish(0, 0.1, 2, 0.1, 9.9);
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 1);
        assert_eq!(a.violations[0].cause, Cause::DecodeClockUndershoot);
    }

    #[test]
    fn retried_request_ttft_violation_is_admission_backoff() {
        let mut fr = FlightRecorder::with_defaults(2);
        // The overload gate deferred request 1 twice before admitting it.
        fr.admission_retry(0.0, 1, 1);
        fr.admission_retry(2.0, 1, 2);
        fr.arrive(1, 4.0, 1, 100, 4);
        fr.prefill_start(1, 4.5, 1, 0); // queue-dominated on its own
        fr.prefill_done(1, 4.6, 1);
        fr.first_token(1, 4.6, 1);
        fr.finish(1, 4.8, 1, 0.6, 0.02);
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 1);
        assert_eq!(a.violations[0].cause, Cause::AdmissionBackoff);
        assert_eq!(a.violations[0].node, 1);
        assert_eq!(a.by_cause()[Cause::AdmissionBackoff.idx()], 1);
        assert!(a.render_table().contains("admission-backoff"));
    }

    #[test]
    fn ctl_windows_override_generic_clock_causes() {
        let mut fr = FlightRecorder::with_defaults(2);
        // Node 0 runs dark for the whole window; node 1 sees actuation
        // noise early, then an uncleared supervisor fallback from t=4.
        fr.ctl(0, 0.0, "blackout");
        fr.ctl(0, 9.0, "sense");
        fr.ctl(1, 0.0, "noise");
        fr.ctl(1, 3.0, "quiet");
        fr.ctl(1, 4.0, "fallback");
        for (id, node, arrive, finish) in
            [(1u64, 0usize, 0.0, 2.0), (2, 1, 0.5, 2.5), (3, 1, 5.0, 7.0)]
        {
            fr.arrive(node, arrive, id, 100, 8);
            fr.prefill_start(node, arrive, id, 0);
            fr.prefill_done(node, arrive + 0.1, id);
            fr.first_token(node, arrive + 0.1, id);
            fr.finish(node, finish, id, 0.1, 0.3); // TBT violation only
        }
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 3);
        let causes: Vec<Cause> = a.violations.iter().map(|v| v.cause).collect();
        assert_eq!(
            causes,
            vec![
                Cause::StaleTelemetry,     // blackout window owns node 0
                Cause::ActuationLag,       // decode [0.6, 2.5] overlaps noise
                Cause::SupervisorFallback, // open fallback extends to run end
            ]
        );
        assert_eq!(a.violations[0].node, 0);
        assert!(a.render_table().contains("stale-telemetry"));
    }

    #[test]
    fn table_and_json_sum_to_total() {
        let mut fr = FlightRecorder::with_defaults(2);
        for (id, n) in [(1u64, 0usize), (2, 1), (3, 0)] {
            fr.arrive(n, 0.0, id, 100, 4);
            fr.prefill_start(n, 0.6, id, 0);
            fr.prefill_done(n, 0.7, id);
            fr.first_token(n, 0.7, id);
            fr.finish(n, 0.9, id, 0.7, 0.02);
        }
        let a = attribute(&fr, &targets());
        assert_eq!(a.total(), 3);
        let txt = a.render_table();
        assert!(txt.contains("queueing-wait"));
        let j = a.to_json();
        assert_eq!(j.path("total").and_then(Json::as_f64), Some(3.0));
        let per_node = j.get("per_node").and_then(Json::as_arr).unwrap();
        let sum: f64 = per_node
            .iter()
            .flat_map(|row| {
                Cause::ALL
                    .iter()
                    .map(|c| row.get(c.label()).and_then(Json::as_f64).unwrap())
            })
            .sum();
        assert_eq!(sum, 3.0);
    }
}

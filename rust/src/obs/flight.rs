//! The in-memory flight recorder: per-request lifecycle segments keyed by
//! request id, bounded per-node telemetry rings, and fault markers.
//!
//! Determinism: requests live in a `BTreeMap` (sorted by id), segments are
//! appended in event order on one virtual clock, and ring samples are
//! iterated oldest-first — so two identical seeded runs yield identical
//! recorder state and (via `obs::perfetto`) byte-identical trace files.

use std::cell::RefCell;
use std::collections::BTreeMap;

use super::{NodeSample, Recorder};

/// What a lifecycle segment covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegKind {
    /// Waiting in a prefill queue (arrival or re-injection → prefill start).
    Queued,
    /// A prefill job in flight on a worker.
    Prefill,
    /// KV bytes on the inter-node wire (send/relay → delivery).
    KvTransfer,
    /// Decode rounds (first token / delivery → last token).
    Decode,
}

impl SegKind {
    /// Stable lowercase label (trace event names, tables).
    pub fn label(self) -> &'static str {
        match self {
            SegKind::Queued => "queued",
            SegKind::Prefill => "prefill",
            SegKind::KvTransfer => "kv-transfer",
            SegKind::Decode => "decode",
        }
    }
}

/// One time segment of a request's life on one node. `t1` is NaN while the
/// segment is still open.
#[derive(Debug, Clone, Copy)]
pub struct Seg {
    /// Segment kind.
    pub kind: SegKind,
    /// Cluster node the segment ran on (sender for `KvTransfer`).
    pub node: u32,
    /// Start time, seconds.
    pub t0: f64,
    /// End time, seconds (NaN while open).
    pub t1: f64,
}

impl Seg {
    /// Whether the segment is still open.
    pub fn is_open(&self) -> bool {
        self.t1.is_nan()
    }
    /// Segment duration (0 while open).
    pub fn dur(&self) -> f64 {
        if self.is_open() {
            0.0
        } else {
            self.t1 - self.t0
        }
    }
}

/// Terminal state of a recorded request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReqOutcome {
    /// Still in flight (or re-injected after a drain).
    Open,
    /// Completed; carries finish time and scored metrics.
    Finished {
        /// Completion time, seconds.
        t: f64,
        /// Time to first token, seconds.
        ttft_s: f64,
        /// P95 time-between-tokens, seconds.
        tbt_p95_s: f64,
    },
    /// Drained from a failed node (normally transient: conservation
    /// re-injects it and the record re-opens).
    Aborted {
        /// Drain time, seconds.
        t: f64,
        /// Tokens emitted and discarded by the drain.
        emitted: u64,
    },
}

/// Everything recorded about one request.
#[derive(Debug, Clone)]
pub struct ReqRecord {
    /// Prompt length, tokens.
    pub prompt_len: u32,
    /// Output length, tokens.
    pub output_len: u32,
    /// First arrival time, seconds.
    pub arrival_s: f64,
    /// Lifecycle segments in event order.
    pub segs: Vec<Seg>,
    /// Times the request was drained off a failed node.
    pub drains: u32,
    /// Wire re-sends after a decode-target failure.
    pub relays: u32,
    /// Full prefill restarts after the KV was lost with its sender.
    pub re_prefills: u32,
    /// Whether any fault touched this request (drain/relay/re-prefill).
    pub faulted: bool,
    /// Times `finish` fired (span invariant: exactly 1 for Finished).
    pub finishes: u32,
    /// Terminal state.
    pub outcome: ReqOutcome,
}

impl ReqRecord {
    fn new(prompt_len: u32, output_len: u32, arrival_s: f64) -> Self {
        ReqRecord {
            prompt_len,
            output_len,
            arrival_s,
            segs: Vec::new(),
            drains: 0,
            relays: 0,
            re_prefills: 0,
            faulted: false,
            finishes: 0,
            outcome: ReqOutcome::Open,
        }
    }

    fn push_seg(&mut self, kind: SegKind, node: usize, t0: f64) {
        self.segs.push(Seg {
            kind,
            node: node as u32,
            t0,
            t1: f64::NAN,
        });
    }

    /// Close the most recent open segment at `t` (no-op if none is open).
    fn close_open(&mut self, t: f64) {
        if let Some(s) = self.segs.last_mut() {
            if s.is_open() {
                s.t1 = t;
            }
        }
    }

    /// Total duration spent in segments of `kind` (closed segments only).
    pub fn time_in(&self, kind: SegKind) -> f64 {
        self.segs
            .iter()
            .filter(|s| s.kind == kind)
            .map(Seg::dur)
            .sum()
    }

    /// Node of the last segment of `kind`, if any.
    pub fn last_node_of(&self, kind: SegKind) -> Option<usize> {
        self.segs
            .iter()
            .rev()
            .find(|s| s.kind == kind)
            .map(|s| s.node as usize)
    }
}

/// Bounded ring buffer of [`NodeSample`]s: O(1) push, overwrites the oldest
/// sample once full, iterates oldest-first.
#[derive(Debug, Clone)]
pub struct SeriesRing {
    cap: usize,
    buf: Vec<NodeSample>,
    head: usize,
    dropped: u64,
}

impl SeriesRing {
    /// An empty ring holding at most `cap` samples.
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "series ring capacity must be positive");
        SeriesRing {
            cap,
            buf: Vec::new(),
            head: 0,
            dropped: 0,
        }
    }

    /// Append a sample, evicting the oldest when full. The sample time must
    /// be finite — the same contract `sim::EventQueue` enforces by panic —
    /// so a recorder path can never smuggle a NaN/inf timestamp downstream.
    pub fn push(&mut self, s: NodeSample) {
        debug_assert!(
            s.t.is_finite(),
            "non-finite sample time {} in recorder series",
            s.t
        );
        debug_assert!(
            s.power_w.is_finite() && s.granted_w.is_finite(),
            "non-finite power sample at t={}",
            s.t
        );
        if self.buf.len() < self.cap {
            self.buf.push(s);
        } else {
            self.buf[self.head] = s;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Samples currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether the ring holds no samples.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Samples evicted because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Iterate oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &NodeSample> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }
}

/// The live recorder: request records keyed by id, one telemetry ring per
/// node, a fault-transition log, and the elasticity side-ledgers
/// (admission backoffs, permanent sheds, capacity transitions). Shed
/// requests never reach a node, so they have no [`ReqRecord`] — only a
/// ledger entry.
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    reqs: BTreeMap<u64, ReqRecord>,
    series: Vec<SeriesRing>,
    faults: Vec<(f64, usize, bool)>,
    admission_retries: BTreeMap<u64, u32>,
    shed: Vec<(f64, u64)>,
    capacity_log: Vec<(f64, usize, &'static str)>,
    ctl_log: Vec<(f64, usize, &'static str)>,
    series_cap: usize,
}

impl FlightRecorder {
    /// Recorder for `nodes` nodes with per-node rings of `series_cap`
    /// samples.
    pub fn new(nodes: usize, series_cap: usize) -> Self {
        FlightRecorder {
            reqs: BTreeMap::new(),
            series: (0..nodes).map(|_| SeriesRing::new(series_cap)).collect(),
            faults: Vec::new(),
            admission_retries: BTreeMap::new(),
            shed: Vec::new(),
            capacity_log: Vec::new(),
            ctl_log: Vec::new(),
            series_cap,
        }
    }

    /// Recorder with the default ring capacity (4096 samples/node).
    pub fn with_defaults(nodes: usize) -> Self {
        FlightRecorder::new(nodes, 4096)
    }

    /// Number of node tracks.
    pub fn nodes(&self) -> usize {
        self.series.len()
    }

    /// Request records, sorted by id.
    pub fn requests(&self) -> impl Iterator<Item = (&u64, &ReqRecord)> {
        self.reqs.iter()
    }

    /// The record for one request id.
    pub fn request(&self, id: u64) -> Option<&ReqRecord> {
        self.reqs.get(&id)
    }

    /// Telemetry ring for one node.
    pub fn series(&self, node: usize) -> &SeriesRing {
        &self.series[node]
    }

    /// Fault transitions as `(t, node, up)`.
    pub fn faults(&self) -> &[(f64, usize, bool)] {
        &self.faults
    }

    /// How many times the overload gate deferred request `id` with
    /// backoff before it was admitted (or shed). 0 for the common case.
    pub fn admission_retries(&self, id: u64) -> u32 {
        self.admission_retries.get(&id).copied().unwrap_or(0)
    }

    /// Permanently shed requests as `(t, id)`, in shed order.
    pub fn shed_requests(&self) -> &[(f64, u64)] {
        &self.shed
    }

    /// Elastic-capacity transitions as `(t, node, what)`, where `what`
    /// is `"drain"`, `"slow"`, `"restore"`, `"park"`, `"boot"` or
    /// `"join"` — in event order.
    pub fn capacity_log(&self) -> &[(f64, usize, &'static str)] {
        &self.capacity_log
    }

    /// Control-plane transitions as `(t, node, what)`, where `what` is
    /// `"noise"`/`"quiet"` (actuation noise), `"blackout"`/`"sense"`
    /// (telemetry blackout) or `"fallback"`/`"probation"`/`"reengage"`
    /// (supervisor state machine) — in event order.
    pub fn ctl_log(&self) -> &[(f64, usize, &'static str)] {
        &self.ctl_log
    }

    /// `(finished, aborted, open)` request counts — the "every arrival
    /// closes in exactly one bucket" ledger.
    pub fn bucket_counts(&self) -> (u64, u64, u64) {
        let (mut fin, mut ab, mut open) = (0u64, 0u64, 0u64);
        for r in self.reqs.values() {
            match r.outcome {
                ReqOutcome::Finished { .. } => fin += 1,
                ReqOutcome::Aborted { .. } => ab += 1,
                ReqOutcome::Open => open += 1,
            }
        }
        (fin, ab, open)
    }

    fn rec(&mut self, id: u64) -> Option<&mut ReqRecord> {
        self.reqs.get_mut(&id)
    }

    /// Validate the span invariants. With `require_closed`, every request
    /// must have reached a terminal bucket (use after a completed run).
    ///
    /// Checks, per request: segments start at/after arrival and have
    /// non-decreasing start times; closed segments run forward in time with
    /// finite endpoints; a finished request has exactly one `finish`, no
    /// open segments, and every migration (`kv-transfer`) segment nested
    /// inside `[arrival, finish]`.
    pub fn span_check(&self, require_closed: bool) -> Result<(), String> {
        for (id, r) in &self.reqs {
            let e = |msg: String| Err(format!("req {id}: {msg}"));
            if r.segs.is_empty() {
                return e("no segments recorded".into());
            }
            if r.segs[0].kind != SegKind::Queued {
                return e(format!("first segment is {:?}, not Queued", r.segs[0].kind));
            }
            let mut prev_t0 = r.arrival_s;
            for (i, s) in r.segs.iter().enumerate() {
                if !s.t0.is_finite() {
                    return e(format!("segment {i} has non-finite start {}", s.t0));
                }
                if s.t0 < prev_t0 - 1e-9 {
                    return e(format!(
                        "segment {i} starts at {} before previous start {prev_t0}",
                        s.t0
                    ));
                }
                prev_t0 = s.t0;
                if !s.is_open() {
                    if !s.t1.is_finite() {
                        return e(format!("segment {i} has non-finite end {}", s.t1));
                    }
                    if s.t1 < s.t0 - 1e-9 {
                        return e(format!("segment {i} runs backwards: {}..{}", s.t0, s.t1));
                    }
                }
            }
            match r.outcome {
                ReqOutcome::Finished { t, .. } => {
                    if r.finishes != 1 {
                        return e(format!("finished {} times", r.finishes));
                    }
                    for (i, s) in r.segs.iter().enumerate() {
                        if s.is_open() {
                            return e(format!("segment {i} still open after finish"));
                        }
                        if s.kind == SegKind::KvTransfer
                            && (s.t0 < r.arrival_s - 1e-9 || s.t1 > t + 1e-9)
                        {
                            return e(format!(
                                "migration segment {i} ({}..{}) outside lifecycle {}..{t}",
                                s.t0, s.t1, r.arrival_s
                            ));
                        }
                    }
                }
                ReqOutcome::Aborted { .. } | ReqOutcome::Open => {
                    if require_closed && matches!(r.outcome, ReqOutcome::Open) {
                        return e("still open after run end".into());
                    }
                }
            }
        }
        Ok(())
    }
}

impl Recorder for FlightRecorder {
    fn arrive(&mut self, node: usize, t: f64, id: u64, prompt_len: u32, output_len: u32) {
        debug_assert!(t.is_finite(), "non-finite arrive time {t}");
        let r = self
            .reqs
            .entry(id)
            .or_insert_with(|| ReqRecord::new(prompt_len, output_len, t));
        // A re-injection after a drain re-opens the record.
        r.outcome = ReqOutcome::Open;
        r.close_open(t);
        r.push_seg(SegKind::Queued, node, t);
    }

    fn prefill_start(&mut self, node: usize, t: f64, id: u64, _worker: usize) {
        if let Some(r) = self.rec(id) {
            r.close_open(t);
            r.push_seg(SegKind::Prefill, node, t);
        }
    }

    fn prefill_done(&mut self, _node: usize, t: f64, id: u64) {
        if let Some(r) = self.rec(id) {
            r.close_open(t);
        }
    }

    fn first_token(&mut self, node: usize, t: f64, id: u64) {
        if let Some(r) = self.rec(id) {
            r.push_seg(SegKind::Decode, node, t);
        }
    }

    fn finish(&mut self, _node: usize, t: f64, id: u64, ttft_s: f64, tbt_p95_s: f64) {
        if let Some(r) = self.rec(id) {
            r.close_open(t);
            r.finishes += 1;
            r.outcome = ReqOutcome::Finished { t, ttft_s, tbt_p95_s };
        }
    }

    fn abort(&mut self, _node: usize, t: f64, id: u64, emitted: u64) {
        if let Some(r) = self.rec(id) {
            r.close_open(t);
            r.drains += 1;
            r.faulted = true;
            r.outcome = ReqOutcome::Aborted { t, emitted };
        }
    }

    fn migrate_send(&mut self, from: usize, _to: usize, t: f64, id: u64, _kv_bytes: f64, _dl: f64) {
        if let Some(r) = self.rec(id) {
            r.close_open(t);
            r.push_seg(SegKind::KvTransfer, from, t);
        }
    }

    fn migrate_deliver(&mut self, node: usize, t: f64, id: u64) {
        if let Some(r) = self.rec(id) {
            r.close_open(t);
            r.push_seg(SegKind::Decode, node, t);
        }
    }

    fn migrate_relay(&mut self, from: usize, _to: usize, t: f64, id: u64) {
        if let Some(r) = self.rec(id) {
            r.close_open(t);
            r.relays += 1;
            r.faulted = true;
            r.push_seg(SegKind::KvTransfer, from, t);
        }
    }

    fn re_prefill(&mut self, _node: usize, t: f64, id: u64) {
        if let Some(r) = self.rec(id) {
            r.close_open(t);
            r.re_prefills += 1;
            r.faulted = true;
        }
    }

    fn fault(&mut self, node: usize, t: f64, up: bool) {
        debug_assert!(t.is_finite(), "non-finite fault time {t}");
        self.faults.push((t, node, up));
    }

    fn clock_change(&mut self, _node: usize, t: f64, _first_gpu: usize, _mhz: u32) {
        debug_assert!(t.is_finite(), "non-finite clock-change time {t}");
    }

    fn sample(&mut self, node: usize, s: NodeSample) {
        if node >= self.series.len() {
            // Engines beyond the sized node count (defensive; plain runs
            // construct the recorder with nodes >= 1).
            self.series
                .extend((self.series.len()..=node).map(|_| SeriesRing::new(self.series_cap)));
        }
        self.series[node].push(s);
    }

    fn admission_retry(&mut self, t: f64, id: u64, attempt: u32) {
        debug_assert!(t.is_finite(), "non-finite retry time {t}");
        let r = self.admission_retries.entry(id).or_insert(0);
        *r = (*r).max(attempt);
    }

    fn shed(&mut self, t: f64, id: u64) {
        debug_assert!(t.is_finite(), "non-finite shed time {t}");
        self.shed.push((t, id));
    }

    fn capacity(&mut self, node: usize, t: f64, what: &'static str) {
        debug_assert!(t.is_finite(), "non-finite capacity-transition time {t}");
        self.capacity_log.push((t, node, what));
    }

    fn ctl(&mut self, node: usize, t: f64, what: &'static str) {
        debug_assert!(t.is_finite(), "non-finite ctl-transition time {t}");
        self.ctl_log.push((t, node, what));
    }
}

/// A `Copy` handle sharing one [`FlightRecorder`] between the cluster loop
/// and its engines (each engine owns its recorder by value; the handle is a
/// `&RefCell` so they all append to the same recorder).
#[derive(Debug, Clone, Copy)]
pub struct SharedRecorder<'r>(pub &'r RefCell<FlightRecorder>);

impl Recorder for SharedRecorder<'_> {
    fn arrive(&mut self, node: usize, t: f64, id: u64, prompt_len: u32, output_len: u32) {
        self.0.borrow_mut().arrive(node, t, id, prompt_len, output_len);
    }
    fn prefill_start(&mut self, node: usize, t: f64, id: u64, worker: usize) {
        self.0.borrow_mut().prefill_start(node, t, id, worker);
    }
    fn prefill_done(&mut self, node: usize, t: f64, id: u64) {
        self.0.borrow_mut().prefill_done(node, t, id);
    }
    fn first_token(&mut self, node: usize, t: f64, id: u64) {
        self.0.borrow_mut().first_token(node, t, id);
    }
    fn finish(&mut self, node: usize, t: f64, id: u64, ttft_s: f64, tbt_p95_s: f64) {
        self.0.borrow_mut().finish(node, t, id, ttft_s, tbt_p95_s);
    }
    fn abort(&mut self, node: usize, t: f64, id: u64, emitted: u64) {
        self.0.borrow_mut().abort(node, t, id, emitted);
    }
    fn migrate_send(&mut self, from: usize, to: usize, t: f64, id: u64, kv_bytes: f64, dl: f64) {
        self.0.borrow_mut().migrate_send(from, to, t, id, kv_bytes, dl);
    }
    fn migrate_deliver(&mut self, node: usize, t: f64, id: u64) {
        self.0.borrow_mut().migrate_deliver(node, t, id);
    }
    fn migrate_relay(&mut self, from: usize, to: usize, t: f64, id: u64) {
        self.0.borrow_mut().migrate_relay(from, to, t, id);
    }
    fn re_prefill(&mut self, node: usize, t: f64, id: u64) {
        self.0.borrow_mut().re_prefill(node, t, id);
    }
    fn fault(&mut self, node: usize, t: f64, up: bool) {
        self.0.borrow_mut().fault(node, t, up);
    }
    fn clock_change(&mut self, node: usize, t: f64, first_gpu: usize, mhz: u32) {
        self.0.borrow_mut().clock_change(node, t, first_gpu, mhz);
    }
    fn sample(&mut self, node: usize, s: NodeSample) {
        self.0.borrow_mut().sample(node, s);
    }
    fn admission_retry(&mut self, t: f64, id: u64, attempt: u32) {
        self.0.borrow_mut().admission_retry(t, id, attempt);
    }
    fn shed(&mut self, t: f64, id: u64) {
        self.0.borrow_mut().shed(t, id);
    }
    fn capacity(&mut self, node: usize, t: f64, what: &'static str) {
        self.0.borrow_mut().capacity(node, t, what);
    }
    fn ctl(&mut self, node: usize, t: f64, what: &'static str) {
        self.0.borrow_mut().ctl(node, t, what);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t: f64) -> NodeSample {
        NodeSample {
            t,
            prefill_mhz: 1200,
            decode_mhz: 900,
            power_w: 250.0,
            granted_w: -1.0,
            queue_depth: 1,
            active_streams: 2,
            batch: 2,
        }
    }

    #[test]
    fn ring_overwrites_oldest_when_full() {
        let mut r = SeriesRing::new(3);
        for i in 0..5 {
            r.push(sample(i as f64));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let ts: Vec<f64> = r.iter().map(|s| s.t).collect();
        assert_eq!(ts, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "non-finite sample time")]
    fn ring_rejects_non_finite_time() {
        SeriesRing::new(4).push(sample(f64::NAN));
    }

    #[test]
    fn happy_path_spans_close_in_order() {
        let mut fr = FlightRecorder::with_defaults(1);
        fr.arrive(0, 0.0, 7, 100, 4);
        fr.prefill_start(0, 0.5, 7, 0);
        fr.prefill_done(0, 0.9, 7);
        fr.first_token(0, 0.9, 7);
        fr.finish(0, 1.4, 7, 0.9, 0.05);
        fr.span_check(true).unwrap();
        let r = fr.request(7).unwrap();
        assert_eq!(r.segs.len(), 3);
        assert!((r.time_in(SegKind::Queued) - 0.5).abs() < 1e-12);
        assert!((r.time_in(SegKind::Prefill) - 0.4).abs() < 1e-12);
        assert!((r.time_in(SegKind::Decode) - 0.5).abs() < 1e-12);
        assert_eq!(fr.bucket_counts(), (1, 0, 0));
    }

    #[test]
    fn migration_spans_nest_inside_lifecycle() {
        let mut fr = FlightRecorder::with_defaults(2);
        fr.arrive(0, 0.0, 3, 2000, 8);
        fr.prefill_start(0, 0.1, 3, 0);
        fr.prefill_done(0, 1.1, 3);
        fr.migrate_send(0, 1, 1.1, 3, 8e6, 1.2);
        fr.migrate_deliver(1, 1.2, 3);
        fr.finish(1, 2.0, 3, 1.1, 0.08);
        fr.span_check(true).unwrap();
        let r = fr.request(3).unwrap();
        assert_eq!(r.last_node_of(SegKind::KvTransfer), Some(0));
        assert_eq!(r.last_node_of(SegKind::Decode), Some(1));
        assert!((r.time_in(SegKind::KvTransfer) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn drain_and_reinjection_reopens_the_record() {
        let mut fr = FlightRecorder::with_defaults(2);
        fr.arrive(0, 0.0, 5, 100, 10);
        fr.prefill_start(0, 0.2, 5, 0);
        fr.abort(0, 0.6, 5, 0);
        assert_eq!(fr.bucket_counts(), (0, 1, 0));
        fr.arrive(1, 0.6, 5, 100, 10);
        fr.prefill_start(1, 0.7, 5, 0);
        fr.prefill_done(1, 1.0, 5);
        fr.first_token(1, 1.0, 5);
        fr.finish(1, 2.0, 5, 1.0, 0.04);
        fr.span_check(true).unwrap();
        let r = fr.request(5).unwrap();
        assert!(r.faulted);
        assert_eq!(r.drains, 1);
        assert_eq!(fr.bucket_counts(), (1, 0, 0));
    }

    #[test]
    fn span_check_flags_open_requests_when_required() {
        let mut fr = FlightRecorder::with_defaults(1);
        fr.arrive(0, 0.0, 1, 50, 2);
        assert!(fr.span_check(false).is_ok());
        assert!(fr.span_check(true).is_err());
    }

    #[test]
    fn elasticity_ledgers_record_retries_sheds_and_transitions() {
        let mut fr = FlightRecorder::with_defaults(2);
        fr.admission_retry(1.0, 9, 1);
        fr.admission_retry(3.0, 9, 2);
        fr.shed(7.0, 9);
        fr.capacity(1, 5.0, "drain");
        fr.capacity(1, 6.0, "park");
        fr.ctl(0, 4.0, "blackout");
        fr.ctl(0, 4.5, "fallback");
        fr.ctl(0, 8.0, "sense");
        assert_eq!(fr.admission_retries(9), 2);
        assert_eq!(fr.admission_retries(8), 0);
        assert_eq!(fr.shed_requests(), &[(7.0, 9)]);
        assert_eq!(fr.capacity_log(), &[(5.0, 1, "drain"), (6.0, 1, "park")]);
        assert_eq!(
            fr.ctl_log(),
            &[(4.0, 0, "blackout"), (4.5, 0, "fallback"), (8.0, 0, "sense")]
        );
        // A shed request never reaches a node: no record, and the span
        // invariants stay green.
        assert!(fr.request(9).is_none());
        fr.span_check(true).unwrap();
    }

    #[test]
    fn span_check_flags_double_finish() {
        let mut fr = FlightRecorder::with_defaults(1);
        fr.arrive(0, 0.0, 1, 50, 1);
        fr.prefill_start(0, 0.1, 1, 0);
        fr.prefill_done(0, 0.2, 1);
        fr.first_token(0, 0.2, 1);
        fr.finish(0, 0.2, 1, 0.2, 0.0);
        fr.finish(0, 0.3, 1, 0.2, 0.0);
        assert!(fr.span_check(true).is_err());
    }
}

//! Chrome/Perfetto trace-event JSON exporter for the flight recorder, plus
//! the structural validator behind `greenllm trace-check`.
//!
//! Schema (load the file in <https://ui.perfetto.dev> or
//! `chrome://tracing`): one *process* per cluster node; request-lifecycle
//! segments as complete-duration `X` events (`tid` = request id, names
//! `queued`/`prefill`/`kv-transfer`/`decode`); telemetry as `C` counter
//! events (`prefill_mhz`, `decode_mhz`, `power_w`, `granted_w`,
//! `queue_depth`, `active_streams`, `batch`); fault transitions as `i`
//! instant events. Timestamps are virtual seconds scaled to microseconds.
//! Emission goes through `util::json::Json` (sorted object keys, shortest
//! round-trip floats), so identical runs produce byte-identical files.

use std::collections::BTreeMap;

use super::flight::{FlightRecorder, ReqOutcome};
use crate::util::json::Json;

const US: f64 = 1e6;

/// Serialize the recorder as a trace-event JSON document.
pub fn to_perfetto(rec: &FlightRecorder) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for n in 0..rec.nodes() {
        events.push(Json::obj([
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(n as f64)),
            ("ts", Json::Num(0.0)),
            ("name", Json::Str("process_name".into())),
            (
                "args",
                Json::obj([("name", Json::Str(format!("node{n}")))]),
            ),
        ]));
    }
    // Requests iterate in id order; an open segment (request cut off at run
    // end) is clipped to its own start so `dur` stays finite and >= 0.
    for (&id, r) in rec.requests() {
        for s in &r.segs {
            let t1 = if s.is_open() { s.t0 } else { s.t1 };
            events.push(Json::obj([
                ("ph", Json::Str("X".into())),
                ("pid", Json::Num(s.node as f64)),
                ("tid", Json::Num(id as f64)),
                ("ts", Json::Num(s.t0 * US)),
                ("dur", Json::Num(((t1 - s.t0) * US).max(0.0))),
                ("name", Json::Str(s.kind.label().into())),
                ("cat", Json::Str("request".into())),
                ("args", Json::obj([("req", Json::Num(id as f64))])),
            ]));
        }
        if let ReqOutcome::Aborted { t, .. } = r.outcome {
            events.push(instant(last_node(r), t, "drained"));
        }
    }
    for n in 0..rec.nodes() {
        for s in rec.series(n).iter() {
            let mut push = |name: &str, v: f64| {
                events.push(Json::obj([
                    ("ph", Json::Str("C".into())),
                    ("pid", Json::Num(n as f64)),
                    ("ts", Json::Num(s.t * US)),
                    ("name", Json::Str(name.into())),
                    ("args", Json::obj([("value", Json::Num(v))])),
                ]));
            };
            push("prefill_mhz", s.prefill_mhz as f64);
            push("decode_mhz", s.decode_mhz as f64);
            push("power_w", s.power_w);
            if s.granted_w >= 0.0 {
                push("granted_w", s.granted_w);
            }
            push("queue_depth", s.queue_depth as f64);
            push("active_streams", s.active_streams as f64);
            push("batch", s.batch as f64);
        }
    }
    for &(t, node, up) in rec.faults() {
        events.push(instant(node, t, if up { "fault-up" } else { "fault-down" }));
    }
    Json::obj([
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

fn last_node(r: &super::flight::ReqRecord) -> usize {
    r.segs.last().map(|s| s.node as usize).unwrap_or(0)
}

fn instant(node: usize, t: f64, name: &str) -> Json {
    Json::obj([
        ("ph", Json::Str("i".into())),
        ("s", Json::Str("p".into())),
        ("pid", Json::Num(node as f64)),
        ("ts", Json::Num(t * US)),
        ("name", Json::Str(name.into())),
        ("cat", Json::Str("fault".into())),
    ])
}

/// Write the trace to `path` (compact JSON, trailing newline).
pub fn write_trace(rec: &FlightRecorder, path: &str) -> std::io::Result<()> {
    let mut out = to_perfetto(rec).dump();
    out.push('\n');
    std::fs::write(path, out)
}

/// Counts from a validated trace document.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceStats {
    /// Distinct `pid` tracks seen.
    pub nodes: usize,
    /// Complete-duration (`X`) span events.
    pub spans: u64,
    /// Counter (`C`) sample events.
    pub counters: u64,
    /// Instant (`i`) events (faults, drains).
    pub instants: u64,
}

/// Structurally validate a parsed trace-event document.
///
/// Checks the invariants `greenllm trace-check` enforces in CI: every
/// event is an object with a `ph`/`pid`/finite non-negative `ts`; spans
/// carry a finite non-negative `dur`, a known segment name, and a `tid`;
/// counter samples carry a single finite numeric `value` and stay
/// time-ordered per `(pid, name)` track; span events stay time-ordered per
/// `(pid, tid)` lane.
pub fn validate_trace(doc: &Json) -> Result<TraceStats, String> {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing traceEvents array".to_string())?;
    let mut stats = TraceStats::default();
    let mut pids: Vec<u64> = Vec::new();
    let mut counter_clock: BTreeMap<(u64, String), f64> = BTreeMap::new();
    let mut span_clock: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    for (i, ev) in events.iter().enumerate() {
        let e = |msg: String| Err(format!("event {i}: {msg}"));
        let ph = match ev.get("ph").and_then(Json::as_str) {
            Some(p) => p,
            None => return e("missing ph".into()),
        };
        let pid = match ev.get("pid").and_then(Json::as_f64) {
            Some(p) if p >= 0.0 && p.is_finite() => p as u64,
            _ => return e("missing/invalid pid".into()),
        };
        if !pids.contains(&pid) {
            pids.push(pid);
        }
        let ts = match ev.get("ts").and_then(Json::as_f64) {
            Some(t) if t.is_finite() && t >= 0.0 => t,
            _ => return e("missing/non-finite ts".into()),
        };
        match ph {
            "X" => {
                stats.spans += 1;
                match ev.get("dur").and_then(Json::as_f64) {
                    Some(d) if d.is_finite() && d >= 0.0 => {}
                    _ => return e("span without finite dur".into()),
                }
                let name = ev.get("name").and_then(Json::as_str).unwrap_or("");
                if !matches!(name, "queued" | "prefill" | "kv-transfer" | "decode") {
                    return e(format!("unknown span name {name:?}"));
                }
                let tid = match ev.get("tid").and_then(Json::as_f64) {
                    Some(t) if t.is_finite() && t >= 0.0 => t as u64,
                    _ => return e("span without tid".into()),
                };
                let lane = span_clock.entry((pid, tid)).or_insert(f64::NEG_INFINITY);
                if ts < *lane - 1e-6 {
                    return e(format!("span lane ({pid},{tid}) goes back in time at ts={ts}"));
                }
                *lane = ts;
            }
            "C" => {
                stats.counters += 1;
                let name = match ev.get("name").and_then(Json::as_str) {
                    Some(n) if !n.is_empty() => n.to_string(),
                    _ => return e("counter without name".into()),
                };
                match ev.path("args.value").and_then(Json::as_f64) {
                    Some(v) if v.is_finite() => {}
                    _ => return e(format!("counter {name} without finite value")),
                }
                let track = counter_clock
                    .entry((pid, name.clone()))
                    .or_insert(f64::NEG_INFINITY);
                if ts < *track - 1e-6 {
                    return e(format!("counter {name} on pid {pid} goes back in time"));
                }
                *track = ts;
            }
            "i" => {
                stats.instants += 1;
                if ev.get("name").and_then(Json::as_str).unwrap_or("").is_empty() {
                    return e("instant without name".into());
                }
            }
            "M" => {}
            other => return e(format!("unknown phase {other:?}")),
        }
    }
    stats.nodes = pids.len();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{NodeSample, Recorder};

    fn recorded() -> FlightRecorder {
        let mut fr = FlightRecorder::with_defaults(2);
        fr.arrive(0, 0.0, 1, 2000, 8);
        fr.prefill_start(0, 0.1, 1, 0);
        fr.prefill_done(0, 1.0, 1);
        fr.migrate_send(0, 1, 1.0, 1, 8e6, 1.05);
        fr.migrate_deliver(1, 1.05, 1);
        fr.finish(1, 2.0, 1, 1.0, 0.05);
        fr.fault(1, 1.5, false);
        fr.fault(1, 1.8, true);
        fr.sample(
            0,
            NodeSample {
                t: 0.5,
                prefill_mhz: 1410,
                decode_mhz: 900,
                power_w: 300.0,
                granted_w: 350.0,
                queue_depth: 2,
                active_streams: 1,
                batch: 1,
            },
        );
        fr
    }

    #[test]
    fn exported_trace_validates() {
        let fr = recorded();
        let doc = to_perfetto(&fr);
        let stats = validate_trace(&doc).unwrap();
        assert_eq!(stats.nodes, 2);
        assert_eq!(stats.spans, 4); // queued, prefill, kv-transfer, decode
        assert_eq!(stats.counters, 7);
        assert_eq!(stats.instants, 2);
    }

    #[test]
    fn export_is_deterministic_and_reparses() {
        let fr = recorded();
        let a = to_perfetto(&fr).dump();
        let b = to_perfetto(&fr).dump();
        assert_eq!(a, b);
        let doc = Json::parse(&a).unwrap();
        assert!(validate_trace(&doc).is_ok());
    }

    #[test]
    fn validator_rejects_negative_duration() {
        let doc = Json::obj([(
            "traceEvents",
            Json::Arr(vec![Json::obj([
                ("ph", Json::Str("X".into())),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(1.0)),
                ("ts", Json::Num(5.0)),
                ("dur", Json::Num(-1.0)),
                ("name", Json::Str("decode".into())),
            ])]),
        )]);
        assert!(validate_trace(&doc).unwrap_err().contains("dur"));
    }

    #[test]
    fn validator_rejects_unknown_span_names() {
        let doc = Json::obj([(
            "traceEvents",
            Json::Arr(vec![Json::obj([
                ("ph", Json::Str("X".into())),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(1.0)),
                ("ts", Json::Num(5.0)),
                ("dur", Json::Num(1.0)),
                ("name", Json::Str("mystery".into())),
            ])]),
        )]);
        assert!(validate_trace(&doc).is_err());
    }
}

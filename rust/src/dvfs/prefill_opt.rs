//! Queueing-aware prefill frequency optimizer (§3.2, Eq. 12–13).
//!
//! Every tick the optimizer looks at the worker's queue, predicts per-job
//! prefill work from the fitted quadratic, and picks the ladder frequency
//! minimizing
//!
//!   E_total(f) = P(f) · busy(f) + P_idle · [D − busy(f)],
//!   busy(f)   = (f_ref / f) · Σ t_ref(L_k),
//!
//! subject to every queued job finishing by its deadline. Uniform FIFO
//! scaling makes the feasibility constraint exact:
//!
//!   f ≥ f_ref · max_k ( cumT_k / (deadline_k − now) ).

use crate::dvfs::profiler::FittedModels;
use crate::gpu::freq::FreqLadder;

/// What the optimizer sees of one queued prefill job.
#[derive(Debug, Clone, Copy)]
pub struct PrefillJobView {
    /// Prompt length, tokens.
    pub prompt_len: u32,
    /// Absolute deadline for this job's TTFT (arrival + SLO × margin).
    pub deadline_s: f64,
}

/// Per-worker prefill optimizer.
#[derive(Debug, Clone)]
pub struct PrefillOptimizer {
    /// Fitted latency/power models the optimizer plans with.
    pub models: FittedModels,
    /// Ladder the chosen clock snaps to.
    pub ladder: FreqLadder,
    /// Clock to park at when the queue is empty.
    pub idle_clock_mhz: u32,
    /// Decision log: (time, chosen clock, queue depth) for diagnostics.
    pub decisions: u64,
}

impl PrefillOptimizer {
    /// An optimizer over `models`, parking at `idle_clock_mhz` when empty.
    pub fn new(models: FittedModels, idle_clock_mhz: u32) -> Self {
        // Search the fitted hardware's own ladder (f_ref = part max; the
        // default 1410 reproduces the stock a100 grid bit-exactly).
        let ladder = FreqLadder {
            max_mhz: models.f_ref_mhz,
            ..FreqLadder::a100()
        };
        PrefillOptimizer {
            models,
            ladder,
            idle_clock_mhz,
            decisions: 0,
        }
    }

    /// Pick the clock for the current queue state (jobs in FIFO order,
    /// including the remaining work of the in-flight job as jobs[0] when
    /// applicable). Returns the idle clock for an empty queue.
    pub fn optimal_clock(&mut self, now: f64, jobs: &[PrefillJobView]) -> u32 {
        self.decisions += 1;
        if jobs.is_empty() {
            return self.idle_clock_mhz;
        }
        let f_ref = self.models.f_ref_mhz as f64;

        // Feasibility: minimum frequency meeting every cumulative deadline.
        let mut cum_t = 0.0;
        let mut f_req: f64 = self.ladder.min_mhz as f64;
        let mut horizon: f64 = 0.0;
        for j in jobs {
            cum_t += self.models.prefill_t_ref(j.prompt_len);
            let slack = (j.deadline_s - now).max(1e-3);
            f_req = f_req.max(f_ref * cum_t / slack);
            horizon = horizon.max(slack);
        }
        let t_ref_total = cum_t;
        let f_lo = self.ladder.snap_up(f_req);
        if f_req > self.ladder.max_mhz as f64 {
            // Overloaded: even max clock misses deadlines — protect latency.
            return self.ladder.max_mhz;
        }

        // Energy scan over feasible ladder points (Eq. 12). D = the SLO
        // horizon of the current backlog.
        let d = horizon.max(t_ref_total * f_ref / self.ladder.max_mhz as f64);
        let idle = self.models.idle_w;
        let mut best = (f64::INFINITY, self.ladder.max_mhz);
        let mut mhz = f_lo;
        while mhz <= self.ladder.max_mhz {
            let busy = t_ref_total * f_ref / mhz as f64;
            if busy <= d + 1e-12 {
                let e = self.models.power_w(mhz) * busy + idle * (d - busy);
                if e < best.0 {
                    best = (e, mhz);
                }
            }
            mhz += self.ladder.step_mhz;
        }
        best.1
    }

    /// Lowest ladder clock meeting every cumulative deadline, with no
    /// energy scan — the throttLL'eM-lite prefill policy (predictive
    /// latency-feasibility only). Energy-suboptimal whenever the feasible
    /// floor sits below the knee of (P(f)−P_idle)/f.
    pub fn min_feasible_clock(&mut self, now: f64, jobs: &[PrefillJobView]) -> u32 {
        if jobs.is_empty() {
            return self.idle_clock_mhz;
        }
        let f_ref = self.models.f_ref_mhz as f64;
        let mut cum_t = 0.0;
        let mut f_req: f64 = self.ladder.min_mhz as f64;
        for j in jobs {
            cum_t += self.models.prefill_t_ref(j.prompt_len);
            let slack = (j.deadline_s - now).max(1e-3);
            f_req = f_req.max(f_ref * cum_t / slack);
        }
        // Open-loop safety margin (7 %): prediction noise is not corrected
        // by any feedback loop in this policy.
        self.ladder.snap_up(f_req * 1.07)
    }

    /// The Eq.-12 objective at a given clock (exposed for tests/benches).
    pub fn energy_objective(&self, jobs: &[PrefillJobView], mhz: u32, d: f64) -> f64 {
        let f_ref = self.models.f_ref_mhz as f64;
        let t_ref: f64 = jobs
            .iter()
            .map(|j| self.models.prefill_t_ref(j.prompt_len))
            .sum();
        let busy = t_ref * f_ref / mhz as f64;
        self.models.power_w(mhz) * busy + self.models.idle_w * (d - busy).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dvfs::profiler::Profiler;
    use crate::gpu::perf::PerfModel;
    use crate::gpu::power::PowerModel;
    use crate::model::ModelSpec;

    fn optimizer() -> PrefillOptimizer {
        let mut p = Profiler::new(
            PerfModel::new(ModelSpec::qwen3_14b()),
            PowerModel::a100(),
            0.0,
            3,
        );
        PrefillOptimizer::new(p.fit(1), 210)
    }

    fn job(len: u32, deadline: f64) -> PrefillJobView {
        PrefillJobView {
            prompt_len: len,
            deadline_s: deadline,
        }
    }

    #[test]
    fn empty_queue_parks_at_idle_clock() {
        let mut o = optimizer();
        assert_eq!(o.optimal_clock(0.0, &[]), 210);
    }

    #[test]
    fn relaxed_deadline_picks_knee_not_max() {
        // One 512-token job (~60 ms at f_ref) with 380 ms of slack: plenty
        // of headroom, so the optimizer should sit near the energy knee
        // (0.9–1.1 GHz), far below max boost.
        let mut o = optimizer();
        let f = o.optimal_clock(0.0, &[job(512, 0.380)]);
        assert!((800..=1150).contains(&f), "f={f}");
    }

    #[test]
    fn tight_deadline_forces_high_clock() {
        // Same job with only 70 ms of slack needs ≈ f_ref.
        let mut o = optimizer();
        let f = o.optimal_clock(0.0, &[job(512, 0.070)]);
        assert!(f >= 1200, "f={f}");
    }

    #[test]
    fn infeasible_backlog_returns_max() {
        let mut o = optimizer();
        let jobs: Vec<_> = (0..50).map(|_| job(2048, 0.100)).collect();
        assert_eq!(o.optimal_clock(0.0, &jobs), 1410);
    }

    #[test]
    fn deeper_queue_needs_higher_clock() {
        let mut o = optimizer();
        let shallow = o.optimal_clock(0.0, &[job(512, 0.380)]);
        let deep: Vec<_> = (0..6).map(|_| job(512, 0.380)).collect();
        let deep_f = o.optimal_clock(0.0, &deep);
        assert!(deep_f > shallow, "shallow={shallow} deep={deep_f}");
    }

    #[test]
    fn cumulative_deadlines_respected() {
        // Two jobs: generous first deadline, tight second — the *cumulative*
        // constraint on job 2 must drive the clock.
        let mut o = optimizer();
        let t_ref_each = o.models.prefill_t_ref(1024);
        // A deadline with ~25 % slack over the minimum possible busy time.
        let dl2 = 2.0 * t_ref_each * 1.25;
        let f = o.optimal_clock(0.0, &[job(1024, 10.0), job(1024, dl2)]);
        let busy = 2.0 * t_ref_each * o.models.f_ref_mhz as f64 / f as f64;
        assert!(busy <= dl2 + 1e-9, "busy={busy} at f={f}");
        // The tight cumulative deadline forces a clock near max.
        assert!(f >= 1100, "f={f}");
    }

    #[test]
    fn chosen_clock_is_energy_minimal_among_feasible() {
        let mut o = optimizer();
        let jobs = [job(700, 0.5), job(300, 0.6)];
        let f = o.optimal_clock(0.0, &jobs);
        let d = 0.6;
        let e_star = o.energy_objective(&jobs, f, d);
        // No feasible ladder clock does better.
        let ladder = FreqLadder::a100();
        for mhz in ladder.iter() {
            let t_ref: f64 = jobs
                .iter()
                .map(|j| o.models.prefill_t_ref(j.prompt_len))
                .sum();
            let busy = t_ref * o.models.f_ref_mhz as f64 / mhz as f64;
            // feasibility per cumulative deadlines
            let t1 = o.models.prefill_t_ref(700) * o.models.f_ref_mhz as f64 / mhz as f64;
            if t1 <= 0.5 && busy <= 0.6 {
                assert!(
                    o.energy_objective(&jobs, mhz, d) >= e_star - 1e-9,
                    "better clock {mhz} than chosen {f}"
                );
            }
        }
    }

    #[test]
    fn margin_scaling_shifts_clock_down() {
        // Doubling every deadline (2× margin) must not raise the clock.
        let mut o = optimizer();
        let tight: Vec<_> = (0..4).map(|_| job(800, 0.250)).collect();
        let relaxed: Vec<_> = (0..4).map(|_| job(800, 0.500)).collect();
        let f_tight = o.optimal_clock(0.0, &tight);
        let f_relaxed = o.optimal_clock(0.0, &relaxed);
        assert!(f_relaxed <= f_tight, "tight={f_tight} relaxed={f_relaxed}");
    }
}

//! The dual-loop decode controller (§3.3) — GreenLLM's runtime heart.
//!
//! Coarse loop (every 200 ms): map sliding-window TPS to a bucket of the
//! profiled TPS→frequency table; switch the allowed frequency *band*
//! (table value ± a few ladder steps) only after the TPS stays in the new
//! bucket for 3 consecutive intervals (hysteresis).
//!
//! Fine loop (every 20 ms): compare the sliding P95 TBT against the SLO
//! target; margin > 1.0 ⇒ +15 MHz (≤ band top), margin < 0.65 ⇒ −15 MHz
//! (≥ band bottom), else hold.
//!
//! Adaptation loop (every 6 s): if > 80 % of the fine adjustments in the
//! window were pinned at a band bound, shift the table entry for the
//! current bucket one step in that direction (handles model drift).

use crate::config::DecodeCtlConfig;
use crate::dvfs::profiler::BandTable;
use crate::gpu::freq::FreqLadder;
use crate::metrics::{SlidingP95, TpsWindow};

/// Frequency band: [lo, hi] in MHz, ladder-aligned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Band {
    /// Band floor, MHz.
    pub lo: u32,
    /// Band ceiling, MHz.
    pub hi: u32,
}

#[derive(Debug, Clone)]
/// The §3.3 dual-loop decode controller: coarse TPS→band lookup with
/// hysteresis, fine P95-TBT steps inside the band, periodic band
/// adaptation.
pub struct DecodeController {
    /// Controller constants (§3.3).
    pub cfg: DecodeCtlConfig,
    /// Ladder the fine loop steps on.
    pub ladder: FreqLadder,
    /// TPS-bucket → frequency lookup (coarse loop).
    pub table: BandTable,
    /// TBT SLO target × margin (s).
    pub tbt_target_s: f64,
    tps_window: TpsWindow,
    tbt_window: SlidingP95,
    cur_mhz: u32,
    band: Band,
    cur_bucket: usize,
    /// (candidate bucket, consecutive intervals seen) for hysteresis.
    pending: Option<(usize, u32)>,
    // Adaptation counters over the current 6 s window.
    adjusts_total: u32,
    adjusts_pinned_hi: u32,
    adjusts_pinned_lo: u32,
    /// Counters for diagnostics/benches.
    pub fine_ticks: u64,
    /// Coarse-band switches taken.
    pub band_switches: u64,
    /// Band-table adaptations applied.
    pub adaptations: u64,
}

impl DecodeController {
    /// A controller starting in the table's lowest bucket band, on the
    /// analytic A100 ladder.
    pub fn new(cfg: DecodeCtlConfig, table: BandTable, tbt_target_s: f64) -> Self {
        DecodeController::with_ladder(cfg, table, tbt_target_s, FreqLadder::a100())
    }

    /// [`DecodeController::new`] on an explicit (calibrated or capped)
    /// ladder — band clamping and fine steps stay on the node's own grid.
    pub fn with_ladder(
        cfg: DecodeCtlConfig,
        table: BandTable,
        tbt_target_s: f64,
        ladder: FreqLadder,
    ) -> Self {
        let f0 = table.freqs[0];
        let mut ctl = DecodeController {
            tps_window: TpsWindow::new(cfg.tps_window_s),
            tbt_window: SlidingP95::new(cfg.tbt_window),
            cfg,
            ladder,
            table,
            tbt_target_s,
            cur_mhz: f0,
            band: Band { lo: f0, hi: f0 },
            cur_bucket: 0,
            pending: None,
            adjusts_total: 0,
            adjusts_pinned_hi: 0,
            adjusts_pinned_lo: 0,
            fine_ticks: 0,
            band_switches: 0,
            adaptations: 0,
        };
        ctl.band = ctl.band_for_bucket(0);
        ctl.cur_mhz = ctl.table.freqs[0];
        ctl
    }

    /// §3.3.2: the fine loop's set point is constrained to the selected
    /// band *and its two neighboring bands* — so the usable range spans
    /// from the bucket-below's center to the bucket-above's center, padded
    /// by the half-width.
    fn band_for_bucket(&self, bucket: usize) -> Band {
        let center = self.table.freqs[bucket];
        let lo_c = self.table.freqs[bucket.saturating_sub(1)].min(center);
        let hi_c = self.table.freqs[(bucket + 1).min(self.table.freqs.len() - 1)].max(center);
        let half = self.cfg.band_halfwidth_steps * self.ladder.step_mhz;
        Band {
            lo: lo_c.saturating_sub(half).max(self.ladder.min_mhz),
            hi: (hi_c + half).min(self.ladder.max_mhz),
        }
    }

    /// Feed emitted tokens (decode rounds report batch size).
    pub fn on_tokens(&mut self, now: f64, tokens: u32) {
        self.tps_window.record(now, tokens);
    }

    /// Feed one per-stream TBT sample.
    pub fn on_tbt(&mut self, tbt_s: f64) {
        self.tbt_window.record(tbt_s);
    }

    /// Feed `count` identical TBT samples at once (all steady streams of a
    /// decode round observe the same round duration — §Perf).
    pub fn on_tbt_weighted(&mut self, tbt_s: f64, count: u32) {
        self.tbt_window.record_weighted(tbt_s, count);
    }

    /// Coarse loop (§3.3.1). Returns the new band if it switched.
    pub fn coarse_tick(&mut self, now: f64) -> Option<Band> {
        let tps = self.tps_window.tps(now);
        let bucket = self.table.bucket_of(tps);
        if bucket == self.cur_bucket {
            self.pending = None;
            return None;
        }
        let count = match self.pending {
            Some((b, c)) if b == bucket => c + 1,
            _ => 1,
        };
        if count >= self.cfg.hysteresis_ticks {
            self.pending = None;
            self.cur_bucket = bucket;
            self.band = self.band_for_bucket(bucket);
            self.cur_mhz = self.cur_mhz.clamp(self.band.lo, self.band.hi);
            self.band_switches += 1;
            Some(self.band)
        } else {
            self.pending = Some((bucket, count));
            None
        }
    }

    /// Fine loop (§3.3.2). Returns the clock to apply now.
    pub fn fine_tick(&mut self, _now: f64) -> u32 {
        self.fine_ticks += 1;
        if self.tbt_window.is_empty() {
            // No tokens flowing: drop toward the band floor to save energy.
            self.cur_mhz = self.band.lo;
            return self.cur_mhz;
        }
        let margin = self.tbt_window.p95() / self.tbt_target_s;
        self.adjusts_total += 1;
        if margin > self.cfg.margin_hi {
            if self.cur_mhz >= self.band.hi {
                self.adjusts_pinned_hi += 1;
            }
            self.cur_mhz =
                self.ladder
                    .step(self.cur_mhz, true, self.band.lo, self.band.hi);
        } else if margin < self.cfg.margin_lo {
            if self.cur_mhz <= self.band.lo {
                self.adjusts_pinned_lo += 1;
            }
            self.cur_mhz =
                self.ladder
                    .step(self.cur_mhz, false, self.band.lo, self.band.hi);
        }
        self.cur_mhz
    }

    /// Adaptation loop (§3.3.3): shift the table under sustained bias.
    pub fn adapt_tick(&mut self, _now: f64) {
        if self.adjusts_total >= 10 {
            let frac_hi = self.adjusts_pinned_hi as f64 / self.adjusts_total as f64;
            let frac_lo = self.adjusts_pinned_lo as f64 / self.adjusts_total as f64;
            if frac_hi > self.cfg.adapt_bias {
                self.table.shift(self.cur_bucket, 1, &self.ladder);
                self.band = self.band_for_bucket(self.cur_bucket);
                self.adaptations += 1;
            } else if frac_lo > self.cfg.adapt_bias {
                self.table.shift(self.cur_bucket, -1, &self.ladder);
                self.band = self.band_for_bucket(self.cur_bucket);
                self.adaptations += 1;
            }
            self.cur_mhz = self.cur_mhz.clamp(self.band.lo, self.band.hi);
        }
        self.adjusts_total = 0;
        self.adjusts_pinned_hi = 0;
        self.adjusts_pinned_lo = 0;
    }

    /// Current applied clock, MHz.
    pub fn current_clock(&self) -> u32 {
        self.cur_mhz
    }

    /// Current [lo, hi] frequency band.
    pub fn current_band(&self) -> Band {
        self.band
    }

    /// Smoothed TPS estimate at `now`.
    pub fn current_tps(&mut self, now: f64) -> f64 {
        self.tps_window.tps(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> BandTable {
        // 0..1000 TPS in 100-TPS buckets, 300→1200 MHz linearly.
        BandTable {
            bucket_width: 100.0,
            freqs: (0..11).map(|i| 300 + i * 90).map(|f| f / 15 * 15).collect(),
        }
    }

    fn ctl() -> DecodeController {
        DecodeController::new(DecodeCtlConfig::default(), table(), 0.100)
    }

    #[test]
    fn band_switch_requires_hysteresis() {
        let mut c = ctl();
        // Jump TPS into bucket 5 (≈ 500 TPS): needs 3 consecutive intervals.
        for i in 0..2 {
            c.on_tokens(i as f64 * 0.2, 100);
            assert_eq!(c.coarse_tick(i as f64 * 0.2 + 0.01), None, "tick {i}");
        }
        c.on_tokens(0.4, 100);
        let band = c.coarse_tick(0.41);
        assert!(band.is_some(), "third interval must switch");
        assert_eq!(c.band_switches, 1);
    }

    #[test]
    fn tps_flapping_does_not_switch() {
        let mut c = ctl();
        // Alternate between buckets so no 3-run forms.
        for i in 0..12 {
            let t = i as f64 * 0.2;
            let tokens = if i % 2 == 0 { 100 } else { 20 };
            c.on_tokens(t, tokens);
            c.coarse_tick(t + 0.01);
        }
        assert_eq!(c.band_switches, 0, "flapping must be filtered");
    }

    #[test]
    fn fine_loop_raises_on_high_margin() {
        let mut c = ctl();
        // Force a wide band for the test.
        c.band = Band { lo: 300, hi: 600 };
        c.cur_mhz = 450;
        c.on_tbt(0.120); // margin 1.2 > 1.0
        let f = c.fine_tick(0.0);
        assert_eq!(f, 465);
        // Repeated ticks keep climbing to the band top, never past it.
        for _ in 0..20 {
            c.fine_tick(0.0);
        }
        assert_eq!(c.current_clock(), 600);
    }

    #[test]
    fn fine_loop_lowers_on_low_margin() {
        let mut c = ctl();
        c.band = Band { lo: 300, hi: 600 };
        c.cur_mhz = 450;
        c.on_tbt(0.050); // margin 0.5 < 0.65
        assert_eq!(c.fine_tick(0.0), 435);
        for _ in 0..20 {
            c.fine_tick(0.0);
        }
        assert_eq!(c.current_clock(), 300);
    }

    #[test]
    fn fine_loop_holds_in_deadband() {
        let mut c = ctl();
        c.band = Band { lo: 300, hi: 600 };
        c.cur_mhz = 450;
        c.on_tbt(0.080); // margin 0.8 ∈ [0.65, 1.0]: hold
        assert_eq!(c.fine_tick(0.0), 450);
    }

    #[test]
    fn rate_limited_to_one_step_per_tick() {
        let mut c = ctl();
        c.band = Band { lo: 300, hi: 1410 };
        c.cur_mhz = 300;
        c.on_tbt(10.0); // wildly over target
        let f1 = c.fine_tick(0.0);
        assert_eq!(f1, 315, "one 15 MHz step per tick, not a jump");
    }

    #[test]
    fn adaptation_shifts_table_up_under_sustained_hi_pin() {
        let mut c = ctl();
        let bucket = c.cur_bucket;
        let before = c.table.freqs[bucket];
        c.on_tbt(0.200); // persistent violation
        // Pin at band top for a whole adaptation window.
        for _ in 0..100 {
            c.fine_tick(0.0);
        }
        c.adapt_tick(6.0);
        assert_eq!(c.table.freqs[bucket], before + 15);
        assert_eq!(c.adaptations, 1);
    }

    #[test]
    fn adaptation_shifts_table_down_under_sustained_lo_pin() {
        let mut c = ctl();
        let bucket = c.cur_bucket;
        // Move table entry up first so there is room to shift down.
        c.table.freqs[bucket] = 600;
        c.band = c.band_for_bucket(bucket);
        c.cur_mhz = c.band.lo;
        c.on_tbt(0.010); // far below target: wants to go lower
        for _ in 0..100 {
            c.fine_tick(0.0);
        }
        c.adapt_tick(6.0);
        assert_eq!(c.table.freqs[bucket], 585);
    }

    #[test]
    fn no_adaptation_without_bias() {
        let mut c = ctl();
        c.band = Band { lo: 300, hi: 900 };
        c.cur_mhz = 600;
        c.on_tbt(0.080); // deadband: no adjustments pinned
        for _ in 0..50 {
            c.fine_tick(0.0);
        }
        let before = c.table.freqs.clone();
        c.adapt_tick(6.0);
        assert_eq!(c.table.freqs, before);
    }

    #[test]
    fn idle_worker_drops_to_band_floor() {
        let mut c = ctl();
        c.band = Band { lo: 300, hi: 900 };
        c.cur_mhz = 700;
        // No TBT samples at all.
        assert_eq!(c.fine_tick(0.0), 300);
    }

    #[test]
    fn clock_always_on_ladder_and_in_band() {
        let mut c = ctl();
        let ladder = FreqLadder::a100();
        for i in 0..500 {
            let t = i as f64 * 0.02;
            if i % 3 == 0 {
                c.on_tokens(t, (i % 40) as u32);
            }
            c.on_tbt(0.03 + 0.09 * ((i as f64 * 0.37).sin().abs()));
            if i % 10 == 0 {
                c.coarse_tick(t);
            }
            let f = c.fine_tick(t);
            assert!(ladder.contains(f), "off-ladder clock {f}");
            assert!(f >= c.current_band().lo && f <= c.current_band().hi);
        }
    }
}

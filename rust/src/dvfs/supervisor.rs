//! Fail-safe governor supervision: a watchdog wrapped around any
//! [`DvfsPolicy`].
//!
//! A DVFS governor is itself a single point of failure: fed stale or
//! blacked-out telemetry it can park a busy GPU at the ladder floor
//! (GreenLLM's TPS-keyed coarse loop does exactly that when its token
//! feed stops), and a mis-tuned learner can flap clocks hard enough to
//! burn both energy and tail latency. [`GovernorSupervisor`] watches the
//! wrapped policy from the outside and **fails safe**:
//!
//! * **Detectors** — (1) *breach streak*: `breach_streak` consecutive
//!   decode TBT samples over the SLO target; (2) *flap*: more than
//!   `flap_budget` large-amplitude clock-direction reversals (≥ 4 ladder
//!   steps) within `flap_window_s`; (3) *staleness*: a busy decode pool
//!   that has delivered no token feedback for `stale_s` seconds (the
//!   signature of a telemetry blackout).
//! * **Fallback** — on a trip the wrapped policy is taken offline and
//!   every worker is pinned at `fallback_mhz` (ladder max by default):
//!   the energy-oblivious-but-SLO-safe `defaultNV`-like posture.
//! * **Hysteresis** — fallback holds for `cooldown_s`, then a
//!   `probation_s` window re-engages the policy under watch; a trip
//!   during probation falls straight back. Every transition is
//!   timestamped and drained by the engine into the flight recorder
//!   (`supervisor-fallback` attribution windows).
//!
//! The supervisor is transparent when it never trips: inner ticks, plans
//! and feedback pass straight through, and it is only built at all when
//! `ctl.supervisor` is set (`coordinator::policy::build`).

use std::collections::VecDeque;

use crate::config::Config;
use crate::coordinator::policy::{DvfsPolicy, PolicyDiagnostics};
use crate::coordinator::telemetry::{ClockPlan, PoolView, TickSpec};
use crate::dvfs::prefill_opt::PrefillJobView;

/// Supervisor watch-tick period, seconds.
const SUP_TICK_S: f64 = 0.1;
/// Ladder steps a clock move must span to count toward flap detection
/// (GreenLLM's fine loop legitimately dithers ±1 step).
const FLAP_AMP_STEPS: u32 = 4;

#[derive(Debug, Clone, Copy, PartialEq)]
enum SupState {
    /// The wrapped policy is in control.
    Engaged,
    /// Pinned at the fallback clock until the cooldown expires.
    Fallback {
        /// Earliest time probation may begin.
        until: f64,
    },
    /// The policy is back in control but every detector re-trips
    /// immediately; survives until `until` to fully re-engage.
    Probation {
        /// Time at which the policy is considered healthy again.
        until: f64,
    },
}

/// Watchdog decorator around any [`DvfsPolicy`]; see the module docs for
/// the state machine.
pub struct GovernorSupervisor {
    inner: Box<dyn DvfsPolicy>,
    inner_ticks: usize,
    state: SupState,
    tbt_target_s: f64,
    stale_s: f64,
    breach_streak: u32,
    flap_budget: u32,
    flap_window_s: f64,
    cooldown_s: f64,
    probation_s: f64,
    fallback_mhz: u32,
    flap_amp_mhz: u32,
    breach_run: u32,
    breach_pending: bool,
    last_mhz: Vec<Option<u32>>,
    last_dir: Vec<i8>,
    reversals: VecDeque<f64>,
    last_feedback_t: f64,
    fallbacks: u64,
    reengages: u64,
    transitions: Vec<(f64, &'static str)>,
}

impl GovernorSupervisor {
    /// Wrap `inner` with the watchdog configured by `cfg.ctl`.
    pub fn new(inner: Box<dyn DvfsPolicy>, cfg: &Config) -> GovernorSupervisor {
        let ladder = cfg.gpu.ladder();
        let fallback_mhz = if cfg.ctl.fallback_mhz == 0 {
            ladder.max_mhz
        } else {
            cfg.ctl.fallback_mhz.min(ladder.max_mhz)
        };
        let inner_ticks = inner.ticks().len();
        GovernorSupervisor {
            inner,
            inner_ticks,
            state: SupState::Engaged,
            tbt_target_s: cfg.slo.tbt_p95_s,
            stale_s: cfg.ctl.stale_s,
            breach_streak: cfg.ctl.breach_streak,
            flap_budget: cfg.ctl.flap_budget,
            flap_window_s: cfg.ctl.flap_window_s,
            cooldown_s: cfg.ctl.cooldown_s,
            probation_s: cfg.ctl.probation_s,
            fallback_mhz,
            flap_amp_mhz: FLAP_AMP_STEPS * ladder.step_mhz,
            breach_run: 0,
            breach_pending: false,
            last_mhz: Vec::new(),
            last_dir: Vec::new(),
            reversals: VecDeque::new(),
            last_feedback_t: 0.0,
            fallbacks: 0,
            reengages: 0,
            transitions: Vec::new(),
        }
    }

    fn in_fallback(&self) -> bool {
        matches!(self.state, SupState::Fallback { .. })
    }

    /// Take the policy offline and pin the fallback clock. No-op while
    /// already in fallback.
    fn trip(&mut self, now: f64) {
        self.breach_pending = false;
        if self.in_fallback() {
            return;
        }
        self.state = SupState::Fallback {
            until: now + self.cooldown_s,
        };
        self.fallbacks += 1;
        self.transitions.push((now, "fallback"));
        self.breach_run = 0;
        self.reversals.clear();
        self.last_dir.iter_mut().for_each(|d| *d = 0);
        self.last_mhz.iter_mut().for_each(|m| *m = None);
    }

    /// Watch the inner policy's decode plan for large-amplitude
    /// direction reversals; trips when the windowed count exceeds the
    /// budget.
    fn observe_plan(&mut self, now: f64, plan: &ClockPlan) {
        if self.last_mhz.len() < plan.decode_mhz.len() {
            self.last_mhz.resize(plan.decode_mhz.len(), None);
            self.last_dir.resize(plan.decode_mhz.len(), 0);
        }
        for (w, m) in plan.decode_mhz.iter().enumerate() {
            let Some(m) = *m else { continue };
            if let Some(prev) = self.last_mhz[w] {
                let delta = m as i64 - prev as i64;
                if delta.unsigned_abs() >= self.flap_amp_mhz as u64 {
                    let dir: i8 = if delta > 0 { 1 } else { -1 };
                    if self.last_dir[w] == -dir {
                        self.reversals.push_back(now);
                    }
                    self.last_dir[w] = dir;
                }
            }
            self.last_mhz[w] = Some(m);
        }
        while let Some(&t0) = self.reversals.front() {
            if now - t0 > self.flap_window_s {
                self.reversals.pop_front();
            } else {
                break;
            }
        }
        if self.reversals.len() as u32 > self.flap_budget {
            self.trip(now);
        }
    }

    fn note_tbt(&mut self, tbt_s: f64, count: u32) {
        if tbt_s > self.tbt_target_s {
            self.breach_run = self.breach_run.saturating_add(count);
            if self.breach_run >= self.breach_streak {
                self.breach_pending = true;
            }
        } else {
            self.breach_run = 0;
        }
    }
}

impl DvfsPolicy for GovernorSupervisor {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn initial_clock_mhz(&self) -> Option<u32> {
        self.inner.initial_clock_mhz()
    }

    fn ticks(&self) -> Vec<TickSpec> {
        let mut specs = self.inner.ticks();
        // The watch tick reads the decode view (busy check for the
        // staleness detector); its index is `inner_ticks`.
        specs.push(TickSpec::every(SUP_TICK_S));
        specs
    }

    fn on_tick(&mut self, kind: usize, now: f64, view: &PoolView, plan: &mut ClockPlan) {
        if kind < self.inner_ticks {
            if !self.in_fallback() {
                self.inner.on_tick(kind, now, view, plan);
                self.observe_plan(now, plan);
                if self.breach_pending {
                    self.trip(now);
                }
                if self.in_fallback() {
                    // The tripping plan must not land: pin it here too.
                    plan.prefill_mhz.iter_mut().for_each(|m| *m = Some(self.fallback_mhz));
                    plan.decode_mhz.iter_mut().for_each(|m| *m = Some(self.fallback_mhz));
                }
            }
            return;
        }
        // Watch tick: advance the state machine first, then run the
        // detectors (a probation that is already stale re-trips within
        // this same tick — the policy never regains control during an
        // ongoing blackout).
        match self.state {
            SupState::Fallback { until } if now >= until => {
                self.state = SupState::Probation {
                    until: now + self.probation_s,
                };
                self.transitions.push((now, "probation"));
            }
            SupState::Probation { until } if now >= until => {
                self.state = SupState::Engaged;
                self.reengages += 1;
                self.transitions.push((now, "reengage"));
            }
            _ => {}
        }
        if !self.in_fallback() {
            let busy = view.decode.iter().any(|d| d.batch > 0);
            if !busy {
                self.last_feedback_t = now;
            } else if now - self.last_feedback_t > self.stale_s {
                self.trip(now);
            }
            if self.breach_pending {
                self.trip(now);
            }
        }
        if self.in_fallback() {
            plan.prefill_mhz.iter_mut().for_each(|m| *m = Some(self.fallback_mhz));
            plan.decode_mhz.iter_mut().for_each(|m| *m = Some(self.fallback_mhz));
        }
    }

    fn on_decode_tbt(&mut self, worker: usize, tbt_s: f64) {
        self.note_tbt(tbt_s, 1);
        self.inner.on_decode_tbt(worker, tbt_s);
    }

    fn on_decode_tbt_weighted(&mut self, worker: usize, tbt_s: f64, count: u32) {
        self.note_tbt(tbt_s, count);
        self.inner.on_decode_tbt_weighted(worker, tbt_s, count);
    }

    fn on_decode_tokens(&mut self, worker: usize, now: f64, tokens: u32) {
        self.last_feedback_t = self.last_feedback_t.max(now);
        self.inner.on_decode_tokens(worker, now, tokens);
    }

    fn wants_prefill_jobs(&self) -> bool {
        self.inner.wants_prefill_jobs()
    }

    fn wants_backlog_updates(&self) -> bool {
        self.inner.wants_backlog_updates()
    }

    fn on_prefill_dispatch(
        &mut self,
        now: f64,
        worker: usize,
        jobs: &[PrefillJobView],
    ) -> Option<u32> {
        let r = self.inner.on_prefill_dispatch(now, worker, jobs);
        if self.in_fallback() {
            Some(self.fallback_mhz)
        } else {
            r
        }
    }

    fn on_prefill_idle(&mut self, now: f64, worker: usize) -> Option<u32> {
        let r = self.inner.on_prefill_idle(now, worker);
        if self.in_fallback() {
            Some(self.fallback_mhz)
        } else {
            r
        }
    }

    fn on_prefill_backlog(
        &mut self,
        now: f64,
        worker: usize,
        jobs: &[PrefillJobView],
    ) -> Option<u32> {
        let r = self.inner.on_prefill_backlog(now, worker, jobs);
        if self.in_fallback() {
            Some(self.fallback_mhz)
        } else {
            r
        }
    }

    fn on_power_cap(&mut self, cap_mhz: u32) {
        self.inner.on_power_cap(cap_mhz);
    }

    fn diagnostics(&self) -> PolicyDiagnostics {
        let mut d = self.inner.diagnostics();
        d.supervisor_fallbacks = self.fallbacks;
        d.supervisor_reengages = self.reengages;
        d
    }

    fn ctl_transitions(&mut self) -> Vec<(f64, &'static str)> {
        std::mem::take(&mut self.transitions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::telemetry::DecodeWorkerView;

    /// Inert inner policy whose tick emits a scripted decode clock.
    struct Scripted {
        clocks: Vec<u32>,
        i: usize,
    }

    impl DvfsPolicy for Scripted {
        fn name(&self) -> String {
            "scripted".into()
        }
        fn ticks(&self) -> Vec<TickSpec> {
            vec![TickSpec::every(0.05)]
        }
        fn on_tick(&mut self, _k: usize, _now: f64, _v: &PoolView, plan: &mut ClockPlan) {
            if !self.clocks.is_empty() {
                plan.decode_mhz[0] = Some(self.clocks[self.i % self.clocks.len()]);
                self.i += 1;
            }
        }
    }

    fn sup(clocks: Vec<u32>, tweak: impl FnOnce(&mut Config)) -> GovernorSupervisor {
        let mut cfg = Config {
            sim_noise: 0.0,
            ..Config::default()
        };
        cfg.ctl.supervisor = true;
        tweak(&mut cfg);
        GovernorSupervisor::new(Box::new(Scripted { clocks, i: 0 }), &cfg)
    }

    fn busy_view(now: f64) -> PoolView {
        PoolView {
            now,
            prefill: Vec::new(),
            decode: vec![DecodeWorkerView {
                batch: 4,
                avg_ctx: 400.0,
            }],
        }
    }

    fn tick(s: &mut GovernorSupervisor, kind: usize, now: f64, busy: bool) -> ClockPlan {
        let mut plan = ClockPlan::default();
        plan.reset(1, 1);
        let mut v = busy_view(now);
        if !busy {
            v.decode[0].batch = 0;
        }
        s.on_tick(kind, now, &v, &mut plan);
        plan
    }

    #[test]
    fn staleness_trips_then_cooldown_probation_reengage() {
        let mut s = sup(vec![900], |_| {});
        // Busy but fed: no trip.
        s.on_decode_tokens(0, 0.45, 32);
        let p = tick(&mut s, 1, 0.5, true);
        assert_eq!(s.diagnostics().supervisor_fallbacks, 0);
        assert_eq!(p.decode_mhz[0], None, "engaged watch tick holds clocks");
        // 1.2 s of busy silence (> stale_s = 1.0): trip and pin.
        let p = tick(&mut s, 1, 1.7, true);
        assert_eq!(s.diagnostics().supervisor_fallbacks, 1);
        assert_eq!(p.decode_mhz[0], Some(1410));
        assert_eq!(p.prefill_mhz[0], Some(1410));
        // Inner ticks are swallowed during fallback.
        let p = tick(&mut s, 0, 1.75, true);
        assert_eq!(p.decode_mhz[0], None, "inner must be offline");
        // Cooldown arithmetic: trip at 1.7 + cooldown 5.0 → probation
        // opens at the first watch tick past 6.7 — not before.
        let p = tick(&mut s, 1, 6.6, true);
        assert_eq!(p.decode_mhz[0], Some(1410), "still inside cooldown");
        // Feedback has resumed → probation, then re-engage after
        // probation_s of clean running.
        s.on_decode_tokens(0, 6.65, 32);
        let p = tick(&mut s, 1, 6.8, true);
        assert_eq!(p.decode_mhz[0], None, "probation returns control");
        s.on_decode_tokens(0, 9.7, 32);
        tick(&mut s, 1, 9.9, true);
        let d = s.diagnostics();
        assert_eq!(d.supervisor_fallbacks, 1);
        assert_eq!(d.supervisor_reengages, 1);
        assert_eq!(
            s.ctl_transitions()
                .iter()
                .map(|(_, w)| *w)
                .collect::<Vec<_>>(),
            vec!["fallback", "probation", "reengage"]
        );
        assert!(s.ctl_transitions().is_empty(), "drain is destructive");
    }

    #[test]
    fn ongoing_staleness_retrips_probation_within_the_same_tick() {
        let mut s = sup(vec![900], |_| {});
        tick(&mut s, 1, 1.7, true); // trip at 1.7
        assert_eq!(s.diagnostics().supervisor_fallbacks, 1);
        // Cooldown expires but the feed is still silent: probation opens
        // and re-trips inside one watch tick — the pin never lifts.
        let p = tick(&mut s, 1, 6.8, true);
        assert_eq!(p.decode_mhz[0], Some(1410));
        assert_eq!(s.diagnostics().supervisor_fallbacks, 2);
        let kinds: Vec<&str> = s.ctl_transitions().iter().map(|(_, w)| *w).collect();
        assert_eq!(kinds, vec!["fallback", "probation", "fallback"]);
    }

    #[test]
    fn idle_pool_never_goes_stale() {
        let mut s = sup(vec![900], |_| {});
        for i in 0..100 {
            tick(&mut s, 1, i as f64 * 0.1, false);
        }
        assert_eq!(s.diagnostics().supervisor_fallbacks, 0);
    }

    #[test]
    fn breach_streak_boundary() {
        let mut s = sup(vec![900], |c| c.ctl.breach_streak = 4);
        // Target is slo.tbt_p95_s = 0.1. Three breaches + recovery: no trip.
        for _ in 0..3 {
            s.on_decode_tbt(0, 0.25);
        }
        s.on_decode_tbt(0, 0.05);
        s.on_decode_tokens(0, 0.95, 8);
        tick(&mut s, 1, 1.0, true);
        assert_eq!(s.diagnostics().supervisor_fallbacks, 0);
        // Four consecutive (weighted counts count): trip at the next tick.
        s.on_decode_tbt_weighted(0, 0.25, 3);
        s.on_decode_tbt(0, 0.25);
        s.on_decode_tokens(0, 1.05, 8);
        let p = tick(&mut s, 1, 1.1, true);
        assert_eq!(s.diagnostics().supervisor_fallbacks, 1);
        assert_eq!(p.decode_mhz[0], Some(1410));
    }

    #[test]
    fn flap_budget_boundary() {
        // Scripted inner flips 600↔1410 every inner tick: one reversal
        // per tick after the first two. Budget 5 in a 10 s window →
        // reversal 6 trips.
        let mut s = sup(vec![600, 1410], |c| {
            c.ctl.flap_budget = 5;
            c.ctl.flap_window_s = 10.0;
        });
        for i in 0..7 {
            s.on_decode_tokens(0, i as f64 * 0.05, 8);
            tick(&mut s, 0, i as f64 * 0.05, true);
        }
        // 7 ticks → moves at ticks 1..=6 → 5 reversals (ticks 2..=6): at
        // the budget, not over it.
        assert_eq!(s.diagnostics().supervisor_fallbacks, 0);
        s.on_decode_tokens(0, 0.35, 8);
        let p = tick(&mut s, 0, 0.35, true);
        assert_eq!(s.diagnostics().supervisor_fallbacks, 1, "budget + 1 trips");
        assert_eq!(p.decode_mhz[0], Some(1410), "tripping plan is pinned");
        // Small-amplitude dither (±1 step) never counts as flapping.
        let mut fine = sup(vec![900, 915], |c| c.ctl.flap_budget = 1);
        for i in 0..50 {
            fine.on_decode_tokens(0, i as f64 * 0.05, 8);
            tick(&mut fine, 0, i as f64 * 0.05, true);
        }
        assert_eq!(fine.diagnostics().supervisor_fallbacks, 0);
    }

    #[test]
    fn fallback_overrides_prefill_callbacks_and_respects_custom_clock() {
        let mut s = sup(vec![900], |c| c.ctl.fallback_mhz = 1200);
        assert_eq!(s.on_prefill_idle(0.1, 0), None, "engaged: inner's answer");
        tick(&mut s, 1, 1.7, true); // stale trip
        assert_eq!(s.on_prefill_idle(1.8, 0), Some(1200));
        assert_eq!(s.on_prefill_dispatch(1.9, 0, &[]), Some(1200));
        assert_eq!(s.on_prefill_backlog(2.0, 0, &[]), Some(1200));
        let p = tick(&mut s, 1, 2.1, true);
        assert_eq!(p.decode_mhz[0], Some(1200));
    }
}

//! Profiling + model fitting: the paper's "Perf–Energy Profile" block.
//!
//! GreenLLM does not trust the analytic ground truth — it *measures*:
//! short traces on the GPU node, sweeping prompt length and SM clock,
//! then fits
//!   * the prefill latency quadratic `t_ref(L) = aL² + bL + c` (Eq. 2,
//!     Fig. 7),
//!   * the active-power cubic `P(f) = k₃f³+k₂f²+k₁f+k₀` (Eq. 7, Fig. 8),
//!   * the decode TPS-bucket → lowest-SLO-feasible-frequency lookup
//!     table (§3.3.1, from the Fig. 3b-style decode sweep).
//!
//! Here "measuring" means sampling the simulated GPU's perf/power models
//! with multiplicative log-normal noise — the same closed loop, minus the
//! hardware.

use crate::gpu::freq::FreqLadder;
use crate::gpu::perf::PerfModel;
use crate::gpu::power::PowerModel;
use crate::util::polyfit::{polyfit, polyval};
use crate::util::rng::Pcg64;

/// Models fitted from profiling — everything the controllers consume.
#[derive(Debug, Clone)]
pub struct FittedModels {
    /// Prefill latency quadratic (a, b, c) at f_ref: t = aL² + bL + c.
    pub prefill_quad: (f64, f64, f64),
    /// Active power cubic, coefficients low→high over GHz.
    pub power_cubic: [f64; 4],
    /// Measured idle power (W).
    pub idle_w: f64,
    /// Reference clock (MHz).
    pub f_ref_mhz: u32,
}

impl FittedModels {
    /// Predicted prefill time of a `len`-token prompt at the reference clock.
    pub fn prefill_t_ref(&self, len: u32) -> f64 {
        let (a, b, c) = self.prefill_quad;
        let l = len as f64;
        a * l * l + b * l + c
    }

    /// Predicted active power at `mhz`, watts.
    pub fn power_w(&self, mhz: u32) -> f64 {
        polyval(&self.power_cubic, mhz as f64 / 1000.0)
    }
}

/// Decode TPS bucket → optimal frequency lookup table (§3.3.1).
#[derive(Debug, Clone)]
pub struct BandTable {
    /// TPS width of one bucket.
    pub bucket_width: f64,
    /// freqs[i] = lowest clock holding P95 TBT under target at TPS bucket i.
    pub freqs: Vec<u32>,
}

impl BandTable {
    /// Bucket index of a TPS value (clamped to the table).
    pub fn bucket_of(&self, tps: f64) -> usize {
        ((tps / self.bucket_width) as usize).min(self.freqs.len() - 1)
    }

    /// Table frequency for a TPS value, MHz.
    pub fn lookup(&self, tps: f64) -> u32 {
        self.freqs[self.bucket_of(tps)]
    }

    /// Shift one bucket's entry by `steps` ladder steps (band adaptation,
    /// §3.3.3). Positive = up.
    pub fn shift(&mut self, bucket: usize, steps: i32, ladder: &FreqLadder) {
        let cur = self.freqs[bucket] as i64;
        let next = cur + steps as i64 * ladder.step_mhz as i64;
        self.freqs[bucket] =
            (next.clamp(ladder.min_mhz as i64, ladder.max_mhz as i64)) as u32;
    }
}

/// The profiling harness.
pub struct Profiler {
    /// Ground-truth latency model being "measured".
    pub perf: PerfModel,
    /// Ground-truth power model being "measured".
    pub power: PowerModel,
    /// Ladder swept by the profiling runs.
    pub ladder: FreqLadder,
    /// Multiplicative log-normal measurement noise (σ).
    pub noise: f64,
    rng: Pcg64,
}

impl Profiler {
    /// A profiler with a deterministic per-seed noise stream.
    pub fn new(perf: PerfModel, power: PowerModel, noise: f64, seed: u64) -> Self {
        // Sweep the hardware's own ladder: f_ref is the part's max clock,
        // so a calibrated H100 profiles up to 1980 MHz (identical to the
        // stock a100 grid when f_ref is the default 1410).
        let ladder = FreqLadder {
            max_mhz: perf.hw.f_ref_mhz,
            ..FreqLadder::a100()
        };
        Profiler {
            perf,
            power,
            ladder,
            noise,
            rng: Pcg64::new(seed, 0x9801F11E),
        }
    }

    /// One noisy prefill-latency measurement (the microbenchmark of §2.2.1).
    pub fn measure_prefill(&mut self, len: u32, mhz: u32) -> f64 {
        self.perf.prefill_time(len as usize, mhz) * self.rng.noise(self.noise)
    }

    /// One noisy power measurement at saturating prefill load (Fig. 8 setup:
    /// fixed 1024-token prompts at high rate).
    pub fn measure_power(&mut self, mhz: u32) -> f64 {
        self.power.power_w(mhz, 1.0) * self.rng.noise(self.noise)
    }

    /// One noisy decode step-time measurement.
    pub fn measure_decode_step(&mut self, batch: usize, avg_ctx: f64, mhz: u32) -> f64 {
        self.perf.decode_step_time(batch, avg_ctx, mhz) * self.rng.noise(self.noise)
    }

    /// Fit Eq. (2): sweep prompt lengths at f_ref, `reps` samples each.
    pub fn fit_prefill_quad(&mut self, reps: usize) -> (f64, f64, f64) {
        let f_ref = self.perf.hw.f_ref_mhz;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut len = 64u32;
        while len <= 8192 {
            for _ in 0..reps {
                xs.push(len as f64);
                ys.push(self.measure_prefill(len, f_ref));
            }
            len = (len as f64 * 1.35) as u32;
        }
        let c = polyfit(&xs, &ys, 2);
        (c[2], c[1], c[0])
    }

    /// Fit Eq. (7): sweep the clock ladder under saturating prefill.
    pub fn fit_power_cubic(&mut self, reps: usize) -> [f64; 4] {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let freqs: Vec<u32> = self.ladder.iter().collect();
        for mhz in freqs {
            for _ in 0..reps {
                xs.push(mhz as f64 / 1000.0);
                ys.push(self.measure_power(mhz));
            }
        }
        let c = polyfit(&xs, &ys, 3);
        [c[0], c[1], c[2], c[3]]
    }

    /// Full fitting pass.
    pub fn fit(&mut self, reps: usize) -> FittedModels {
        FittedModels {
            prefill_quad: self.fit_prefill_quad(reps),
            power_cubic: self.fit_power_cubic(reps),
            idle_w: self.power.power_w(self.ladder.min_mhz, 0.0),
            f_ref_mhz: self.perf.hw.f_ref_mhz,
        }
    }

    /// Build the §3.3.1 decode lookup table: for each TPS bucket, the
    /// lowest clock whose steady-state P95 TBT stays under
    /// `tbt_target_s` (with headroom for the P95-vs-mean gap and noise).
    ///
    /// Steady state at (tps, f): the batch is the fixpoint of
    /// B = tps · t_step(B, ctx, f).
    pub fn build_band_table(
        &mut self,
        max_tps: f64,
        bucket_width: f64,
        avg_ctx: f64,
        tbt_target_s: f64,
        max_streams: usize,
    ) -> BandTable {
        let n_buckets = (max_tps / bucket_width).ceil() as usize + 1;
        // P95 of a noisy step time exceeds its mean; budget for it.
        let headroom = 1.0 + 2.0 * self.noise;
        let mut freqs = Vec::with_capacity(n_buckets);
        // The lowest feasible clock is monotone in TPS, so resume each
        // bucket's scan where the previous one stopped (two-pointer): the
        // sweep costs O(buckets + ladder) fixpoints instead of O(b × l) —
        // this dominates GreenLLM engine construction (§Perf).
        let mut start = 0usize;
        let ladder: Vec<u32> = self.ladder.iter().collect();
        for i in 0..n_buckets {
            let tps = (i as f64 + 0.5) * bucket_width; // bucket midpoint
            let mut chosen = self.ladder.max_mhz;
            while start < ladder.len() {
                let mhz = ladder[start];
                let ok = steady_state_tbt(&self.perf, tps, avg_ctx, mhz, max_streams)
                    .map(|t| t * headroom <= tbt_target_s)
                    .unwrap_or(false);
                if ok {
                    chosen = mhz;
                    break;
                }
                start += 1;
            }
            freqs.push(chosen);
        }
        BandTable {
            bucket_width,
            freqs,
        }
    }
}

/// Steady-state decode step time at a given per-worker TPS and clock, or
/// None if the worker cannot sustain that TPS at that clock.
pub fn steady_state_tbt(
    perf: &PerfModel,
    tps: f64,
    avg_ctx: f64,
    mhz: u32,
    max_streams: usize,
) -> Option<f64> {
    if tps <= 0.0 {
        return Some(perf.decode_step_time(1, avg_ctx, mhz));
    }
    let mut b = 1.0f64;
    for _ in 0..64 {
        let t = perf.decode_step_time(b.ceil() as usize, avg_ctx, mhz);
        let next = (tps * t).max(1.0);
        if (next - b).abs() < 0.01 {
            let t = perf.decode_step_time(next.ceil() as usize, avg_ctx, mhz);
            return (next.ceil() as usize <= max_streams).then_some(t);
        }
        b = next;
        if b > max_streams as f64 * 2.0 {
            return None; // diverging: demand exceeds capacity at this clock
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;

    fn profiler(noise: f64) -> Profiler {
        Profiler::new(
            PerfModel::new(ModelSpec::qwen3_14b()),
            PowerModel::a100(),
            noise,
            7,
        )
    }

    #[test]
    fn prefill_fit_recovers_ground_truth() {
        let mut p = profiler(0.02);
        let (a, b, c) = p.fit_prefill_quad(3);
        let (ta, tb, tc) = p.perf.prefill_coeffs();
        assert!((a / ta - 1.0).abs() < 0.25, "a={a:.3e} truth={ta:.3e}");
        assert!((b / tb - 1.0).abs() < 0.05, "b={b:.3e} truth={tb:.3e}");
        assert!((c - tc).abs() < 0.01, "c={c:.4} truth={tc:.4}");
    }

    #[test]
    fn power_fit_tracks_curve() {
        let mut p = profiler(0.02);
        let coeffs = p.fit_power_cubic(3);
        for mhz in [300u32, 700, 1000, 1400] {
            let fit = polyval(&coeffs, mhz as f64 / 1000.0);
            let truth = p.power.power_w(mhz, 1.0);
            assert!((fit / truth - 1.0).abs() < 0.05, "mhz={mhz} fit={fit} truth={truth}");
        }
    }

    #[test]
    fn noiseless_fit_is_nearly_exact() {
        let mut p = profiler(0.0);
        let m = p.fit(1);
        let (ta, tb, _) = p.perf.prefill_coeffs();
        assert!((m.prefill_quad.0 / ta - 1.0).abs() < 1e-6);
        assert!((m.prefill_quad.1 / tb - 1.0).abs() < 1e-6);
        let truth = p.power.power_w(1005, 1.0);
        assert!((m.power_w(1005) / truth - 1.0).abs() < 1e-6);
    }

    #[test]
    fn band_table_monotone_in_tps() {
        let mut p = profiler(0.02);
        let t = p.build_band_table(3000.0, 100.0, 600.0, 0.100, 200);
        // Higher TPS buckets need >= clocks (weakly monotone).
        for w in t.freqs.windows(2) {
            assert!(w[1] >= w[0], "table not monotone: {:?}", t.freqs);
        }
        // Light load can run at a much lower clock than heavy load.
        assert!(t.lookup(100.0) + 200 < t.lookup(900.0));
    }

    #[test]
    fn band_table_lookup_and_shift() {
        let ladder = FreqLadder::a100();
        let mut t = BandTable {
            bucket_width: 100.0,
            freqs: vec![300, 600, 900],
        };
        assert_eq!(t.lookup(0.0), 300);
        assert_eq!(t.lookup(150.0), 600);
        assert_eq!(t.lookup(10_000.0), 900); // clamped to last bucket
        t.shift(0, 2, &ladder);
        assert_eq!(t.freqs[0], 330);
        t.shift(0, -100, &ladder);
        assert_eq!(t.freqs[0], 210); // clamped to ladder min
    }

    #[test]
    fn steady_state_tbt_behaviour() {
        let perf = PerfModel::new(ModelSpec::qwen3_14b());
        // Light load converges to a small batch with TBT ≈ weight-stream time.
        let t = steady_state_tbt(&perf, 100.0, 600.0, 1410, 200).unwrap();
        assert!((0.02..0.06).contains(&t), "t={t}");
        // Demand far beyond capacity diverges.
        assert!(steady_state_tbt(&perf, 5000.0, 600.0, 1410, 200).is_none());
        // Low clock cannot sustain what max clock can.
        let hi = steady_state_tbt(&perf, 800.0, 600.0, 1410, 200);
        let lo = steady_state_tbt(&perf, 800.0, 600.0, 300, 200);
        assert!(hi.is_some());
        assert!(lo.is_none() || lo.unwrap() > hi.unwrap());
    }

    #[test]
    fn band_table_zero_bucket_uses_min_feasible() {
        let mut p = profiler(0.0);
        let t = p.build_band_table(3000.0, 100.0, 600.0, 0.100, 200);
        // Near-zero TPS: decode can idle at a very low clock yet hold TBT.
        assert!(t.freqs[0] <= 600, "idle bucket at {}", t.freqs[0]);
    }
}

//! Baseline governors: NVIDIA's default behaviour and fixed clocks.
//!
//! `defaultNV` models what the paper measures in Fig. 1a: the stock
//! governor drives the SM clock in a narrow high band (~1.1–1.4 GHz)
//! whenever there is work, with small dithering, and is completely blind
//! to token throughput. It only sags when the GPU has been idle a while.

use crate::gpu::freq::FreqLadder;
use crate::util::rng::Pcg64;

/// NVIDIA-default-like governor (per worker).
#[derive(Debug, Clone)]
pub struct DefaultNvGovernor {
    ladder: FreqLadder,
    rng: Pcg64,
    last_busy_t: f64,
    cur_mhz: u32,
    /// Busy-band low edge (boost clocks wander in [busy_lo, max]).
    busy_lo_mhz: u32,
    /// Clock after the idle-sag timeout.
    idle_mhz: u32,
    idle_timeout_s: f64,
}

impl DefaultNvGovernor {
    /// A governor with the A100 boost envelope and a per-seed dither stream.
    pub fn new(seed: u64) -> Self {
        DefaultNvGovernor::with_ladder(seed, FreqLadder::a100())
    }

    /// A governor on an arbitrary (calibrated or capped) ladder. The stock
    /// behavior generalizes by shape: the boost band spans the top 8
    /// ladder steps below max and the idle sag parks 20 steps below max
    /// (exactly 1290/1110 MHz on the stock A100 ladder, so `new` is
    /// bit-identical through this path).
    pub fn with_ladder(seed: u64, ladder: FreqLadder) -> Self {
        let busy_lo = ladder.max_mhz.saturating_sub(8 * ladder.step_mhz).max(ladder.min_mhz);
        let idle = ladder.max_mhz.saturating_sub(20 * ladder.step_mhz).max(ladder.min_mhz);
        DefaultNvGovernor {
            cur_mhz: ladder.max_mhz,
            ladder,
            rng: Pcg64::new(seed, 0xDEFA),
            last_busy_t: 0.0,
            busy_lo_mhz: busy_lo,
            idle_mhz: idle,
            idle_timeout_s: 0.5,
        }
    }

    /// Called at work boundaries and control ticks; returns the SM clock
    /// the governor wants now. `busy` = does the worker have work.
    pub fn tick(&mut self, now: f64, busy: bool) -> u32 {
        if busy {
            self.last_busy_t = now;
            // Narrow high boost band with thermal-style dither (Fig. 1a).
            let span = (self.ladder.max_mhz - self.busy_lo_mhz) / self.ladder.step_mhz;
            let dither = (self.rng.next_u64() % (span as u64 + 1)) as u32;
            self.cur_mhz = self.busy_lo_mhz + dither * self.ladder.step_mhz;
        } else if now - self.last_busy_t > self.idle_timeout_s {
            self.cur_mhz = self.idle_mhz;
        }
        self.cur_mhz
    }

    /// Current clock without ticking, MHz.
    pub fn current(&self) -> u32 {
        self.cur_mhz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_stays_in_high_band() {
        let mut g = DefaultNvGovernor::new(1);
        for i in 0..200 {
            let f = g.tick(i as f64 * 0.02, true);
            assert!((1290..=1410).contains(&f), "f={f}");
        }
    }

    #[test]
    fn sags_only_after_idle_timeout() {
        let mut g = DefaultNvGovernor::new(2);
        g.tick(10.0, true);
        // Immediately idle: still boosted.
        let f = g.tick(10.1, false);
        assert!(f >= 1290);
        // Past the timeout: sagged.
        let f = g.tick(10.8, false);
        assert_eq!(f, 1110);
    }

    #[test]
    fn blind_to_load_level() {
        // The governor gets no TPS input at all — that's the point.
        let mut g = DefaultNvGovernor::new(3);
        let light: Vec<u32> = (0..50).map(|i| g.tick(i as f64, true)).collect();
        let mut g2 = DefaultNvGovernor::new(3);
        let heavy: Vec<u32> = (0..50).map(|i| g2.tick(i as f64, true)).collect();
        assert_eq!(light, heavy);
    }

    #[test]
    fn dither_lands_on_ladder() {
        let mut g = DefaultNvGovernor::new(4);
        let l = FreqLadder::a100();
        for i in 0..100 {
            assert!(l.contains(g.tick(i as f64, true)));
        }
    }

    #[test]
    fn with_ladder_on_stock_a100_is_bit_identical_to_new() {
        let mut a = DefaultNvGovernor::new(7);
        let mut b = DefaultNvGovernor::with_ladder(7, FreqLadder::a100());
        for i in 0..300 {
            let busy = i % 17 != 0;
            assert_eq!(a.tick(i as f64 * 0.02, busy), b.tick(i as f64 * 0.02, busy));
        }
    }

    #[test]
    fn with_ladder_boosts_past_1410_on_h100() {
        let h100 = FreqLadder {
            min_mhz: 210,
            max_mhz: 1980,
            step_mhz: 15,
        };
        let mut g = DefaultNvGovernor::with_ladder(5, h100.clone());
        let mut seen_high = false;
        for i in 0..200 {
            let f = g.tick(i as f64 * 0.02, true);
            assert!((1860..=1980).contains(&f), "f={f}");
            assert!(h100.contains(f));
            seen_high |= f > 1410;
        }
        assert!(seen_high, "the NV baseline must use the part's real boost band");
        // Idle sag parks 20 steps below the part max, not at a100's 1110.
        g.tick(50.0, true);
        assert_eq!(g.tick(51.0, false), 1680);
    }

    #[test]
    fn with_ladder_survives_tiny_capped_ladders() {
        // A cap so low the band formulas would underflow past the floor.
        let tiny = FreqLadder {
            min_mhz: 210,
            max_mhz: 240,
            step_mhz: 15,
        };
        let mut g = DefaultNvGovernor::with_ladder(6, tiny);
        for i in 0..50 {
            let f = g.tick(i as f64, i % 2 == 0);
            assert!((210..=240).contains(&f), "f={f}");
        }
    }
}

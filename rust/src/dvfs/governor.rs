//! Baseline governors: NVIDIA's default behaviour and fixed clocks.
//!
//! `defaultNV` models what the paper measures in Fig. 1a: the stock
//! governor drives the SM clock in a narrow high band (~1.1–1.4 GHz)
//! whenever there is work, with small dithering, and is completely blind
//! to token throughput. It only sags when the GPU has been idle a while.

use crate::gpu::freq::FreqLadder;
use crate::util::rng::Pcg64;

/// NVIDIA-default-like governor (per worker).
#[derive(Debug, Clone)]
pub struct DefaultNvGovernor {
    ladder: FreqLadder,
    rng: Pcg64,
    last_busy_t: f64,
    cur_mhz: u32,
    /// Busy-band low edge (boost clocks wander in [busy_lo, max]).
    busy_lo_mhz: u32,
    /// Clock after the idle-sag timeout.
    idle_mhz: u32,
    idle_timeout_s: f64,
}

impl DefaultNvGovernor {
    /// A governor with the A100 boost envelope and a per-seed dither stream.
    pub fn new(seed: u64) -> Self {
        let ladder = FreqLadder::a100();
        DefaultNvGovernor {
            cur_mhz: ladder.max_mhz,
            ladder,
            rng: Pcg64::new(seed, 0xDEFA),
            last_busy_t: 0.0,
            busy_lo_mhz: 1290,
            idle_mhz: 1110,
            idle_timeout_s: 0.5,
        }
    }

    /// Called at work boundaries and control ticks; returns the SM clock
    /// the governor wants now. `busy` = does the worker have work.
    pub fn tick(&mut self, now: f64, busy: bool) -> u32 {
        if busy {
            self.last_busy_t = now;
            // Narrow high boost band with thermal-style dither (Fig. 1a).
            let span = (self.ladder.max_mhz - self.busy_lo_mhz) / self.ladder.step_mhz;
            let dither = (self.rng.next_u64() % (span as u64 + 1)) as u32;
            self.cur_mhz = self.busy_lo_mhz + dither * self.ladder.step_mhz;
        } else if now - self.last_busy_t > self.idle_timeout_s {
            self.cur_mhz = self.idle_mhz;
        }
        self.cur_mhz
    }

    /// Current clock without ticking, MHz.
    pub fn current(&self) -> u32 {
        self.cur_mhz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_stays_in_high_band() {
        let mut g = DefaultNvGovernor::new(1);
        for i in 0..200 {
            let f = g.tick(i as f64 * 0.02, true);
            assert!((1290..=1410).contains(&f), "f={f}");
        }
    }

    #[test]
    fn sags_only_after_idle_timeout() {
        let mut g = DefaultNvGovernor::new(2);
        g.tick(10.0, true);
        // Immediately idle: still boosted.
        let f = g.tick(10.1, false);
        assert!(f >= 1290);
        // Past the timeout: sagged.
        let f = g.tick(10.8, false);
        assert_eq!(f, 1110);
    }

    #[test]
    fn blind_to_load_level() {
        // The governor gets no TPS input at all — that's the point.
        let mut g = DefaultNvGovernor::new(3);
        let light: Vec<u32> = (0..50).map(|i| g.tick(i as f64, true)).collect();
        let mut g2 = DefaultNvGovernor::new(3);
        let heavy: Vec<u32> = (0..50).map(|i| g2.tick(i as f64, true)).collect();
        assert_eq!(light, heavy);
    }

    #[test]
    fn dither_lands_on_ladder() {
        let mut g = DefaultNvGovernor::new(4);
        let l = FreqLadder::a100();
        for i in 0..100 {
            assert!(l.contains(g.tick(i as f64, true)));
        }
    }
}

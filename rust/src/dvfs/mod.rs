//! Phase-specific DVFS — the paper's core contribution.
//!
//! * [`governor`] — the `defaultNV` baseline (clock pinned high while
//!   busy, blind to TPS — Fig. 1a) and fixed-clock policies.
//! * [`profiler`] — the offline/online profiling pass: sweeps prompt
//!   lengths and SM clocks against the (noisy) GPU, fits the Eq. (2)
//!   latency quadratic and the Eq. (7) power cubic, and builds the
//!   decode TPS → frequency lookup table (§3.3.1).
//! * [`prefill_opt`] — the queueing-aware prefill optimizer: pick the
//!   energy-minimal clock such that all queued prefills meet their
//!   deadlines (Eq. 12–13).
//! * [`decode_ctl`] — the dual-loop decode controller: coarse TPS band
//!   selection with hysteresis + fine ±15 MHz TBT tracking every 20 ms +
//!   6 s band adaptation (§3.3).
//! * [`supervisor`] — the fail-safe watchdog that wraps any policy and
//!   escalates to a pinned high clock when the wrapped controller
//!   misbehaves (SLO-breach streaks, clock flapping, telemetry
//!   staleness).

pub mod decode_ctl;
pub mod governor;
pub mod prefill_opt;
pub mod profiler;
pub mod supervisor;

pub use decode_ctl::DecodeController;
pub use governor::DefaultNvGovernor;
pub use prefill_opt::{PrefillJobView, PrefillOptimizer};
pub use profiler::{BandTable, FittedModels, Profiler};
pub use supervisor::GovernorSupervisor;

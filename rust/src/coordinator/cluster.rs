//! Cluster extension (paper §7, future work): GreenLLM's node-level
//! control replicated across multiple DGX nodes behind a load balancer.
//!
//! Each node runs the full per-node stack (router, pools, phase-specific
//! DVFS); the balancer assigns requests at ingress using only information
//! a front-end actually has — arrival order and prompt length. Nodes are
//! independent after assignment, so the cluster replay runs each node's
//! discrete-event simulation on its sub-trace and aggregates energy + SLO
//! counters.

use crate::config::Config;
use crate::coordinator::engine::{run, RunOptions, RunResult};
use crate::workload::request::{Request, Trace};

/// Load-balancing policy at cluster ingress.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LbPolicy {
    /// Classic round-robin.
    RoundRobin,
    /// Join-least-loaded by accumulated prompt tokens with exponential
    /// decay (a front-end's cheap proxy for outstanding prefill work).
    LeastPromptWork,
}

#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub nodes: usize,
    pub lb: LbPolicy,
    /// Per-node serving config (method, pools, SLOs...).
    pub node: Config,
}

#[derive(Debug)]
pub struct ClusterResult {
    pub per_node: Vec<RunResult>,
    pub total_energy_j: f64,
    pub generated_tokens: u64,
    pub completed: u64,
    pub ttft_pass_rate: f64,
    pub tbt_pass_rate: f64,
    /// Requests assigned per node (balance diagnostic).
    pub assignment: Vec<usize>,
}

impl ClusterResult {
    pub fn energy_per_token_j(&self) -> f64 {
        self.total_energy_j / self.generated_tokens.max(1) as f64
    }

    /// Max/min node request share — 1.0 is perfectly balanced.
    pub fn balance_ratio(&self) -> f64 {
        let max = *self.assignment.iter().max().unwrap_or(&1) as f64;
        let min = *self.assignment.iter().min().unwrap_or(&1) as f64;
        max / min.max(1.0)
    }
}

/// Assign each request to a node (returns node index per request).
pub fn assign(trace: &Trace, nodes: usize, lb: LbPolicy) -> Vec<usize> {
    assert!(nodes >= 1);
    match lb {
        LbPolicy::RoundRobin => (0..trace.requests.len()).map(|i| i % nodes).collect(),
        LbPolicy::LeastPromptWork => {
            // Decaying outstanding-work estimate per node; time constant
            // ~10 s (a prefill queue's memory).
            let mut load = vec![0.0f64; nodes];
            let mut last_t = 0.0f64;
            let tau = 10.0;
            trace
                .requests
                .iter()
                .map(|r: &Request| {
                    let dt = (r.arrival_s - last_t).max(0.0);
                    last_t = r.arrival_s;
                    let decay = (-dt / tau).exp();
                    for l in load.iter_mut() {
                        *l *= decay;
                    }
                    let (node, _) = load
                        .iter()
                        .enumerate()
                        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap();
                    load[node] += r.prompt_len as f64;
                    node
                })
                .collect()
        }
    }
}

/// Replay a trace across the cluster.
pub fn run_cluster(ccfg: &ClusterConfig, trace: &Trace, opts: &RunOptions) -> ClusterResult {
    let assignment_per_req = assign(trace, ccfg.nodes, ccfg.lb);
    let mut sub_traces: Vec<Trace> = (0..ccfg.nodes)
        .map(|n| Trace {
            name: format!("{}::node{n}", trace.name),
            duration_s: trace.duration_s,
            requests: Vec::new(),
        })
        .collect();
    for (req, &node) in trace.requests.iter().zip(&assignment_per_req) {
        sub_traces[node].requests.push(req.clone());
    }
    let per_node: Vec<RunResult> = sub_traces
        .iter()
        .enumerate()
        .map(|(n, sub)| {
            let mut cfg = ccfg.node.clone();
            cfg.seed = ccfg.node.seed.wrapping_add(n as u64);
            run(&cfg, sub, opts)
        })
        .collect();

    let total_energy_j = per_node.iter().map(|r| r.total_energy_j).sum();
    let generated_tokens = per_node.iter().map(|r| r.generated_tokens).sum();
    let completed: u64 = per_node.iter().map(|r| r.completed).sum();
    let ttft_passes: u64 = per_node.iter().map(|r| r.slo.ttft_passes()).sum();
    let tbt_passes: u64 = per_node.iter().map(|r| r.slo.tbt_passes()).sum();
    let tbt_eligible: u64 = per_node.iter().map(|r| r.slo.tbt_eligible()).sum();
    let mut assignment = vec![0usize; ccfg.nodes];
    for &n in &assignment_per_req {
        assignment[n] += 1;
    }
    ClusterResult {
        total_energy_j,
        generated_tokens,
        completed,
        ttft_pass_rate: if completed == 0 {
            1.0
        } else {
            ttft_passes as f64 / completed as f64
        },
        tbt_pass_rate: if tbt_eligible == 0 {
            1.0
        } else {
            tbt_passes as f64 / tbt_eligible as f64
        },
        per_node,
        assignment,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::workload::alibaba::{generate, ChatParams};

    fn cluster(nodes: usize, lb: LbPolicy, method: Method) -> ClusterConfig {
        ClusterConfig {
            nodes,
            lb,
            node: Config {
                method,
                seed: 5,
                ..Config::default()
            },
        }
    }

    #[test]
    fn round_robin_is_balanced() {
        let trace = generate(&ChatParams::new(8.0, 60.0), 1);
        let a = assign(&trace, 4, LbPolicy::RoundRobin);
        let mut counts = [0usize; 4];
        for &n in &a {
            counts[n] += 1;
        }
        assert!(counts.iter().max().unwrap() - counts.iter().min().unwrap() <= 1);
    }

    #[test]
    fn least_work_balances_tokens_not_requests() {
        let trace = generate(&ChatParams::new(8.0, 120.0), 1);
        let a = assign(&trace, 2, LbPolicy::LeastPromptWork);
        let mut toks = [0f64; 2];
        for (r, &n) in trace.requests.iter().zip(&a) {
            toks[n] += r.prompt_len as f64;
        }
        let ratio = toks[0].max(toks[1]) / toks[0].min(toks[1]);
        assert!(ratio < 1.25, "token imbalance {ratio}");
    }

    #[test]
    fn cluster_conserves_requests_and_tokens() {
        let trace = generate(&ChatParams::new(16.0, 60.0), 2);
        let r = run_cluster(
            &cluster(2, LbPolicy::LeastPromptWork, Method::GreenLlm),
            &trace,
            &RunOptions::default(),
        );
        assert_eq!(r.completed as usize, trace.requests.len());
        let expect: u64 = trace.requests.iter().map(|q| q.output_len as u64).sum();
        assert_eq!(r.generated_tokens, expect);
        assert_eq!(r.per_node.len(), 2);
    }

    #[test]
    fn greenllm_savings_hold_at_cluster_scale() {
        // 2 nodes at 2× the single-node load: savings comparable to the
        // single-node 5 QPS case (the paper's scaling claim).
        let trace = generate(&ChatParams::new(10.0, 90.0), 3);
        let nv = run_cluster(
            &cluster(2, LbPolicy::LeastPromptWork, Method::DefaultNv),
            &trace,
            &RunOptions::default(),
        );
        let green = run_cluster(
            &cluster(2, LbPolicy::LeastPromptWork, Method::GreenLlm),
            &trace,
            &RunOptions::default(),
        );
        let saving = 1.0 - green.total_energy_j / nv.total_energy_j;
        assert!(saving > 0.15, "cluster saving {saving:.3}");
        assert!(green.ttft_pass_rate > 0.9);
        assert!(green.tbt_pass_rate > 0.9);
    }

    #[test]
    fn single_node_cluster_matches_plain_run() {
        let trace = generate(&ChatParams::new(4.0, 60.0), 7);
        let ccfg = cluster(1, LbPolicy::RoundRobin, Method::GreenLlm);
        let c = run_cluster(&ccfg, &trace, &RunOptions::default());
        let plain = run(
            &Config {
                method: Method::GreenLlm,
                seed: 5,
                ..Config::default()
            },
            &trace,
            &RunOptions::default(),
        );
        assert_eq!(c.total_energy_j.to_bits(), plain.total_energy_j.to_bits());
    }
}

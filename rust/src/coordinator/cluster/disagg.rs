//! Prefill/decode disaggregation: pool topology, the KV-transfer cost
//! model, and the EcoRoute-style decode router.
//!
//! The cluster can run *disaggregated* (DualScale / VoltanaLLM style):
//! the first `PoolRatio::prefill_count` nodes form the prefill pool and
//! the rest the decode pool. Arrivals are balanced over the prefill pool
//! only; when a prefill finishes, the stream *migrates* — an explicit
//! cluster event — to a decode node picked by [`eco_route`] over live
//! decode telemetry (active streams, TBT-tail P95, granted watts). The
//! KV cache travels over a modeled interconnect ([`KvLinkModel`]):
//! bytes are linear in context length, the transfer has latency and an
//! energy cost charged to *both* ends. Each pool then runs its own
//! `DvfsPolicy` against its own SLO — TTFT pressure on prefill nodes,
//! TBT tail on decode nodes (see `coordinator::policy` for the per-pool
//! method overrides).
//!
//! With no [`DisaggConfig`] the cluster is colocated and every code path
//! here is dormant — the event loop is bit-exact with the pre-disagg
//! loop (§invariants in `events.rs`).

use super::balancer::NodeState;
use crate::config::Method;

/// Prefill:decode pool split, e.g. `1:3` = a quarter of the cluster
/// prefills. Shared between the `--disagg` axis and the `phase`
/// balancer's long-pool sizing (which historically hard-coded the
/// quarter split — the default ratio reproduces it exactly).
///
/// ```
/// use greenllm::coordinator::cluster::disagg::PoolRatio;
///
/// let r = PoolRatio::parse("1:3").unwrap();
/// assert_eq!(r.name(), "1:3");
/// assert_eq!(r.prefill_count(8), 2);
/// assert!(PoolRatio::parse("0:3").is_err());
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolRatio {
    /// Prefill-pool weight (≥ 1).
    pub prefill: u32,
    /// Decode-pool weight (≥ 1).
    pub decode: u32,
}

impl Default for PoolRatio {
    /// `1:3` — the quarter split the `phase` balancer has always used.
    fn default() -> Self {
        PoolRatio { prefill: 1, decode: 3 }
    }
}

impl PoolRatio {
    /// Parse a `P:D` spelling; both parts must be positive integers.
    pub fn parse(s: &str) -> Result<PoolRatio, String> {
        let (p, d) = s
            .trim()
            .split_once(':')
            .ok_or_else(|| format!("pool ratio {s:?}: expected P:D (e.g. 1:3)"))?;
        let prefill: u32 = p
            .trim()
            .parse()
            .map_err(|_| format!("pool ratio {s:?}: bad prefill part {p:?}"))?;
        let decode: u32 = d
            .trim()
            .parse()
            .map_err(|_| format!("pool ratio {s:?}: bad decode part {d:?}"))?;
        if prefill == 0 || decode == 0 {
            return Err(format!("pool ratio {s:?}: both parts must be >= 1"));
        }
        Ok(PoolRatio { prefill, decode })
    }

    /// Stable spelling (CLI / report column).
    pub fn name(&self) -> String {
        format!("{}:{}", self.prefill, self.decode)
    }

    /// Nodes in the prefill (resp. long/phase) pool for a cluster of
    /// `nodes`. At least one node lands on each side once there are two
    /// nodes to split; a single node can't disaggregate (returns 0 —
    /// colocated). At the default `1:3` this is `(nodes / 4).max(1)`,
    /// bit-compatible with the phase balancer's historical quarter split.
    pub fn prefill_count(&self, nodes: usize) -> usize {
        if nodes < 2 {
            return 0;
        }
        let total = (self.prefill + self.decode) as usize;
        (nodes * self.prefill as usize / total)
            .max(1)
            .min(nodes - 1)
    }
}

/// KV-cache transfer cost model for a prefill→decode handoff. Bytes are
/// linear in the context (prompt + first token); the wire adds a fixed
/// latency plus serialization time at the link rate, and moving the
/// bytes costs energy charged to *both* ends of the transfer (send-side
/// DMA + receive-side write). Defaults model a 200 Gb/s fabric and an
/// fp16 KV cache of a mid-size model (~0.8 MB/token).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvLinkModel {
    /// KV-cache footprint per context token, bytes.
    pub bytes_per_token: f64,
    /// Link rate, gigabits per second.
    pub gbps: f64,
    /// Fixed per-transfer latency (handshake + RDMA setup), seconds.
    pub latency_s: f64,
    /// Energy to move one byte across the link, picojoules — charged to
    /// each end.
    pub pj_per_byte: f64,
}

impl Default for KvLinkModel {
    fn default() -> Self {
        KvLinkModel {
            bytes_per_token: 819_200.0,
            gbps: 200.0,
            latency_s: 0.001,
            pj_per_byte: 100.0,
        }
    }
}

impl KvLinkModel {
    /// KV bytes for a stream with `ctx_tokens` of context.
    pub fn kv_bytes(&self, ctx_tokens: f64) -> f64 {
        ctx_tokens * self.bytes_per_token
    }

    /// Wall-clock transfer time for `bytes`, seconds.
    pub fn transfer_s(&self, bytes: f64) -> f64 {
        self.latency_s + bytes / (self.gbps * 1e9 / 8.0)
    }

    /// Energy charged to *one* end for `bytes`, joules.
    pub fn transfer_j(&self, bytes: f64) -> f64 {
        bytes * self.pj_per_byte * 1e-12
    }
}

/// Disaggregation settings beyond the pool split itself (the split lives
/// in `ClusterConfig::pool_ratio`, shared with the phase balancer).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct DisaggConfig {
    /// The KV-transfer interconnect.
    pub link: KvLinkModel,
    /// DVFS method override for prefill-pool nodes (`None` = the
    /// cluster-wide method). Prefill nodes chase TTFT.
    pub prefill_method: Option<Method>,
    /// DVFS method override for decode-pool nodes (`None` = the
    /// cluster-wide method). Decode nodes chase the TBT tail.
    pub decode_method: Option<Method>,
}

/// Migration accounting for one cluster run (the `migration{...}` JSON
/// section and the cluster report line).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MigrationReport {
    /// Streams handed prefill→decode.
    pub count: u64,
    /// KV bytes moved (relays re-count: the bytes crossed the wire again).
    pub kv_bytes: f64,
    /// Transfer energy charged across both ends, joules.
    pub transfer_j: f64,
    /// Deliveries that found their target dead and were re-sent to a
    /// fresh target (mid-migration node failure).
    pub relays: u64,
}

/// Per-node slice of the migration ledger: where handoffs were sent
/// from, delivered to, relayed from, and re-prefilled after KV loss.
/// Across a run `Σ sends == MigrationReport::count` and
/// `Σ relays == MigrationReport::relays`; deliveries lag sends by the
/// handoffs still on the wire (or parked) at the horizon. `re_prefills`
/// counts full re-prefills this node absorbed after a sender died with
/// the KV (handoffs deferred because the whole cluster was dark are not
/// attributed to any node).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NodeMigration {
    /// First sends of a KV handoff out of this (prefill) node.
    pub sends: u64,
    /// Handoffs delivered to this (decode) node.
    pub deliveries: u64,
    /// Relays re-sent from this node after the target died mid-wire.
    pub relays: u64,
    /// Full re-prefills absorbed by this node after a sender died.
    pub re_prefills: u64,
}

/// EcoRoute-style decode-pool router: among alive nodes in
/// `nodes[pool_start..]`, prefer a healthy TBT tail (≤ `tbt_target_s`),
/// then the fewest active streams per granted watt (infinite grants
/// normalize to 1 W, degrading to batch depth — the `powergrant`
/// idiom); ties break toward the lowest index. If the whole decode pool
/// is down, spill into the prefill pool — every node is a full engine,
/// so a prefill node can decode in a pinch (the KV still pays the link).
/// `None` only when every node in the cluster is dead.
pub fn eco_route(nodes: &[NodeState], pool_start: usize, tbt_target_s: f64) -> Option<usize> {
    let split = pool_start.min(nodes.len());
    pick_decode(&nodes[split..], tbt_target_s)
        .map(|i| split + i)
        .or_else(|| pick_decode(&nodes[..split], tbt_target_s))
}

fn pick_decode(nodes: &[NodeState], tbt_target_s: f64) -> Option<usize> {
    let mut best = None;
    let mut best_key = (u8::MAX, f64::INFINITY);
    for (i, n) in nodes.iter().enumerate() {
        if !n.alive {
            continue;
        }
        let unhealthy = (n.tbt_tail_p95_s > tbt_target_s) as u8;
        let grant = if n.granted_w.is_finite() {
            n.granted_w.max(1e-9)
        } else {
            1.0
        };
        let score = (n.active_streams + 1) as f64 / grant;
        // Strict `<`: ties break toward the lowest index.
        if best.is_none() || (unhealthy, score) < best_key {
            best_key = (unhealthy, score);
            best = Some(i);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_parses_and_rejects() {
        assert_eq!(PoolRatio::parse("1:3").unwrap(), PoolRatio::default());
        assert_eq!(
            PoolRatio::parse(" 2 : 1 ").unwrap(),
            PoolRatio { prefill: 2, decode: 1 }
        );
        for bad in ["", "1", "1:", ":3", "0:3", "1:0", "a:b", "1:3:5"] {
            assert!(PoolRatio::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn default_ratio_reproduces_quarter_split() {
        // The phase balancer historically used (nodes / 4).max(1) once
        // nodes >= 2; the default 1:3 ratio must match it exactly.
        let r = PoolRatio::default();
        assert_eq!(r.prefill_count(1), 0);
        for n in 2..=64 {
            assert_eq!(r.prefill_count(n), (n / 4).max(1), "nodes = {n}");
        }
    }

    #[test]
    fn ratio_splits_keep_both_pools_nonempty() {
        for (p, d) in [(1, 1), (1, 2), (1, 4), (4, 1), (3, 2)] {
            let r = PoolRatio { prefill: p, decode: d };
            for n in 2..=32 {
                let pc = r.prefill_count(n);
                assert!(pc >= 1 && pc <= n - 1, "{p}:{d} at {n} nodes -> {pc}");
            }
        }
    }

    #[test]
    fn link_model_costs_scale_with_context() {
        let link = KvLinkModel::default();
        let (small, big) = (link.kv_bytes(128.0), link.kv_bytes(4096.0));
        assert!(big > small);
        assert!(link.transfer_s(big) > link.transfer_s(small));
        assert!(link.transfer_s(small) > link.latency_s);
        assert!(link.transfer_j(big) > link.transfer_j(small));
        // 4096 tokens at ~0.8 MB/token ≈ 3.4 GB ≈ 134 ms on 200 Gb/s.
        let s = link.transfer_s(big);
        assert!(s > 0.1 && s < 0.2, "transfer_s = {s}");
    }

    #[test]
    fn eco_route_prefers_healthy_low_load() {
        let mut nodes = vec![NodeState::default(); 4];
        // Decode pool = nodes[1..]. Node 1 blown tail, node 2 busy,
        // node 3 idle → node 3.
        nodes[1].tbt_tail_p95_s = 0.5;
        nodes[2].active_streams = 6;
        assert_eq!(eco_route(&nodes, 1, 0.1), Some(3));
        // Equal depth: the bigger grant wins.
        nodes[3].active_streams = 6;
        nodes[2].granted_w = 3000.0;
        nodes[3].granted_w = 1000.0;
        assert_eq!(eco_route(&nodes, 1, 0.1), Some(2));
    }

    #[test]
    fn eco_route_spills_into_prefill_pool_then_gives_up() {
        let mut nodes = vec![NodeState::default(); 3];
        nodes[1].alive = false;
        nodes[2].alive = false;
        // Whole decode pool down: spill to the prefill node.
        assert_eq!(eco_route(&nodes, 1, 0.1), Some(0));
        nodes[0].alive = false;
        assert_eq!(eco_route(&nodes, 1, 0.1), None);
    }
}

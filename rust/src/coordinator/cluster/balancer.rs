//! Cluster ingress load balancing: online policies over live node
//! telemetry.
//!
//! A [`Balancer`] is consulted once per arriving request with a
//! [`NodeState`] snapshot per node (queue depths, outstanding prefill
//! tokens, decode TBT tail — everything the cluster event loop can read
//! off the live engines). Registering a new policy means implementing the
//! trait, adding an [`LbPolicy`] variant and wiring it in [`build`]; the
//! CLI, the scenario matrix and the invariant tests pick it up unchanged.

use super::disagg::PoolRatio;
use crate::workload::request::{Request, RouteClass};

/// Live telemetry the cluster loop snapshots per node before each
/// assignment decision.
#[derive(Debug, Clone, Copy)]
pub struct NodeState {
    /// Requests handed to this node so far.
    pub assigned: usize,
    /// Prefill jobs queued or in flight.
    pub prefill_backlog: usize,
    /// Prompt tokens queued or in prefill flight.
    pub outstanding_prompt_tokens: u64,
    /// Decode streams admitted and not yet finished.
    pub active_streams: usize,
    /// P95 of the node's recent decode TBTs (0.0 until samples exist).
    pub tbt_tail_p95_s: f64,
    /// Is the node up? Balancers must never assign to a dead node (the
    /// chaos layer flips this during node-loss windows).
    pub alive: bool,
    /// The power arbiter's current watt grant for this node
    /// (`f64::INFINITY` when the cluster is uncapped). The `powergrant`
    /// balancer routes on this signal; everything else ignores it.
    pub granted_w: f64,
}

impl Default for NodeState {
    fn default() -> Self {
        NodeState {
            assigned: 0,
            prefill_backlog: 0,
            outstanding_prompt_tokens: 0,
            active_streams: 0,
            tbt_tail_p95_s: 0.0,
            alive: true,
            granted_w: f64::INFINITY,
        }
    }
}

/// Load-balancing policy at cluster ingress.
///
/// ```
/// use greenllm::coordinator::cluster::LbPolicy;
///
/// assert_eq!(LbPolicy::parse("jsq"), Some(LbPolicy::JoinShortestQueue));
/// assert_eq!(LbPolicy::parse("powergrant"), Some(LbPolicy::PowerGrant));
/// assert_eq!(LbPolicy::parse("teleport"), None);
/// // Every registered policy's name round-trips through parse.
/// for lb in LbPolicy::all() {
///     assert_eq!(LbPolicy::parse(lb.name()), Some(lb));
/// }
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LbPolicy {
    /// Classic round-robin (front-end information only; baseline).
    RoundRobin,
    /// Join-least-loaded by accumulated prompt tokens with exponential
    /// decay — a front-end's cheap proxy for outstanding prefill work
    /// (baseline; no live telemetry).
    LeastPromptWork,
    /// Join-shortest-queue on live backlog (prefill jobs + decode streams).
    JoinShortestQueue,
    /// DualScale-style phase-aware ingress: long-prompt (prefill-heavy)
    /// requests go to a dedicated node subset, interactive traffic joins
    /// the shortest healthy queue on the rest (nodes with a blown TBT tail
    /// are deprioritized).
    PhaseAware,
    /// Power-aware routing: join the node with the most watt headroom per
    /// unit of queued work, using the power arbiter's live grants
    /// ([`NodeState::granted_w`]). Degrades to queue-depth routing when
    /// the cluster is uncapped (every grant is infinite).
    PowerGrant,
}

impl LbPolicy {
    /// Stable short name (CLI spelling, report column).
    pub fn name(&self) -> &'static str {
        match self {
            LbPolicy::RoundRobin => "rr",
            LbPolicy::LeastPromptWork => "leastwork",
            LbPolicy::JoinShortestQueue => "jsq",
            LbPolicy::PhaseAware => "phase",
            LbPolicy::PowerGrant => "powergrant",
        }
    }

    /// Parse a CLI spelling (aliases included); `None` for unknown names.
    pub fn parse(s: &str) -> Option<LbPolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "rr" | "roundrobin" | "round-robin" => Some(LbPolicy::RoundRobin),
            "leastwork" | "least-work" | "lpw" => Some(LbPolicy::LeastPromptWork),
            "jsq" | "shortestqueue" | "shortest-queue" => Some(LbPolicy::JoinShortestQueue),
            "phase" | "phaseaware" | "phase-aware" | "dualscale" => Some(LbPolicy::PhaseAware),
            "powergrant" | "power-grant" | "grant" | "pg" => Some(LbPolicy::PowerGrant),
            _ => None,
        }
    }

    /// Every registered policy, in report order.
    pub fn all() -> Vec<LbPolicy> {
        vec![
            LbPolicy::RoundRobin,
            LbPolicy::LeastPromptWork,
            LbPolicy::JoinShortestQueue,
            LbPolicy::PhaseAware,
            LbPolicy::PowerGrant,
        ]
    }

    /// Does this policy use only front-end information (arrival order,
    /// prompt length)? Such policies can also pre-assign a trace offline.
    pub fn frontend_only(&self) -> bool {
        matches!(self, LbPolicy::RoundRobin | LbPolicy::LeastPromptWork)
    }
}

/// An ingress balancer: one request + live node states in, node index out.
pub trait Balancer {
    /// Stable short name (mirrors [`LbPolicy::name`]).
    fn name(&self) -> &'static str;
    /// Pick the node for `req` arriving at `t`. `nodes` has one entry per
    /// node, index-aligned; a returned index must be `< nodes.len()` and
    /// must point at an *alive* node. `None` means no node can take the
    /// request right now (every node in the slice is down — possible
    /// transiently between a drain and a re-route); the cluster loop
    /// defers such requests and re-offers them at the next recovery
    /// instead of aborting the run.
    fn assign(&mut self, t: f64, req: &Request, nodes: &[NodeState]) -> Option<usize>;
}

/// Instantiate the balancer for a policy. `tbt_target_s` is the per-node
/// decode SLO the phase-aware policy uses to spot unhealthy tails;
/// `ratio` sizes its long-prompt pool (shared with the `--disagg` axis —
/// the default `1:3` reproduces the historical quarter split).
pub fn build(
    lb: LbPolicy,
    nodes: usize,
    tbt_target_s: f64,
    ratio: PoolRatio,
) -> Box<dyn Balancer> {
    assert!(nodes >= 1);
    match lb {
        LbPolicy::RoundRobin => Box::new(RoundRobin { next: 0, nodes }),
        LbPolicy::LeastPromptWork => Box::new(LeastPromptWork::new(nodes, 10.0)),
        LbPolicy::JoinShortestQueue => Box::new(JoinShortestQueue),
        LbPolicy::PhaseAware => Box::new(PhaseAware::new(nodes, tbt_target_s, ratio)),
        LbPolicy::PowerGrant => Box::new(PowerGrant),
    }
}

struct RoundRobin {
    next: usize,
    nodes: usize,
}

impl Balancer for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn assign(&mut self, _t: f64, _req: &Request, nodes: &[NodeState]) -> Option<usize> {
        // Cycle, skipping dead nodes; with everything alive this is the
        // classic modular counter (bit-compatible with the pre-chaos rr).
        for _ in 0..self.nodes {
            let n = self.next;
            self.next = (self.next + 1) % self.nodes;
            if nodes.get(n).map_or(true, |s| s.alive) {
                return Some(n);
            }
        }
        None
    }
}

/// Decaying outstanding-work estimate per node; time constant ~10 s (a
/// prefill queue's memory). Decay is applied lazily from a per-node
/// last-touched timestamp, so an assignment costs O(nodes) comparisons and
/// exactly one write — not O(nodes) exponentials ageing every counter.
struct LeastPromptWork {
    load: Vec<f64>,
    last_t: Vec<f64>,
    tau: f64,
}

impl LeastPromptWork {
    fn new(nodes: usize, tau: f64) -> Self {
        LeastPromptWork {
            load: vec![0.0; nodes],
            last_t: vec![0.0; nodes],
            tau,
        }
    }

    /// Continuous-decay value of node `i`'s load at time `t`.
    fn load_at(&self, i: usize, t: f64) -> f64 {
        self.load[i] * (-(t - self.last_t[i]).max(0.0) / self.tau).exp()
    }
}

impl Balancer for LeastPromptWork {
    fn name(&self) -> &'static str {
        "leastwork"
    }

    fn assign(&mut self, t: f64, req: &Request, nodes: &[NodeState]) -> Option<usize> {
        // Front-end policy, but liveness still comes from the snapshot:
        // dead nodes are skipped (strict `<` keeps the all-alive case
        // bit-compatible with the pre-chaos scan).
        let mut best = None;
        let mut best_load = f64::INFINITY;
        for i in 0..self.load.len() {
            if !nodes.get(i).map_or(true, |s| s.alive) {
                continue;
            }
            let l = self.load_at(i, t);
            if l < best_load || best.is_none() {
                best_load = l;
                best = Some(i);
            }
        }
        let best = best?;
        // Touch only the winner: fold its decay into the stored value.
        self.load[best] = best_load + req.prompt_len as f64;
        self.last_t[best] = t;
        Some(best)
    }
}

struct JoinShortestQueue;

impl JoinShortestQueue {
    fn depth(n: &NodeState) -> usize {
        n.prefill_backlog + n.active_streams
    }
}

impl Balancer for JoinShortestQueue {
    fn name(&self) -> &'static str {
        "jsq"
    }

    fn assign(&mut self, _t: f64, _req: &Request, nodes: &[NodeState]) -> Option<usize> {
        pick_min(nodes, |n| (Self::depth(n) as u64, n.outstanding_prompt_tokens))
    }
}

/// DualScale-style split: the last `long_nodes` nodes form the
/// prefill-heavy pool; everything else serves interactive traffic.
struct PhaseAware {
    long_nodes: usize,
    tbt_target_s: f64,
}

impl PhaseAware {
    fn new(nodes: usize, tbt_target_s: f64, ratio: PoolRatio) -> Self {
        // Dedicate the ratio's prefill share of the cluster (at least one
        // node each side) to long prefill once there are enough nodes to
        // split at all. The default 1:3 ratio is the historical quarter
        // split, bit-for-bit.
        PhaseAware {
            long_nodes: ratio.prefill_count(nodes),
            tbt_target_s,
        }
    }
}

impl Balancer for PhaseAware {
    fn name(&self) -> &'static str {
        "phase"
    }

    fn assign(&mut self, _t: f64, req: &Request, nodes: &[NodeState]) -> Option<usize> {
        if self.long_nodes == 0 {
            // Single node: nothing to split, but liveness still applies —
            // this used to return 0 unconditionally and route straight
            // into a dead node during its fault window.
            return pick_min(nodes, |_| 0u8);
        }
        let split = nodes.len() - self.long_nodes;
        match req.route_class() {
            RouteClass::Long => {
                // Prefill pool: least outstanding prompt work. If the
                // whole long pool is down, spill into the interactive one;
                // if *everything* is down, defer (None) — the cluster
                // loop holds the request for the next recovery.
                pick_min(&nodes[split..], |n| {
                    (n.outstanding_prompt_tokens, n.prefill_backlog as u64)
                })
                .map(|i| split + i)
                .or_else(|| {
                    pick_min(&nodes[..split], |n| {
                        (n.outstanding_prompt_tokens, n.prefill_backlog as u64)
                    })
                })
            }
            RouteClass::ShortMedium => {
                // Interactive pool: shortest queue among healthy nodes; a
                // blown decode tail pushes a node behind every healthy
                // one. If the whole interactive pool is down, spill into
                // the long pool; all dead defers as above.
                pick_min(&nodes[..split], |n| {
                    let unhealthy = (n.tbt_tail_p95_s > self.tbt_target_s) as u64;
                    (unhealthy, (n.prefill_backlog + n.active_streams) as u64)
                })
                .or_else(|| {
                    pick_min(&nodes[split..], |n| {
                        (n.prefill_backlog + n.active_streams) as u64
                    })
                    .map(|i| split + i)
                })
            }
        }
    }
}

/// Power-aware ingress: consume the arbiter's live grants. Each request
/// joins the alive node minimizing queued work per granted watt —
/// power-starved nodes (small grants after a demand or SLO-pressure
/// re-split) receive proportionally less new work, which keeps their
/// clamped clocks from turning into queue blowups. With no cap every
/// grant is infinite and the score collapses to plain queue depth.
struct PowerGrant;

impl Balancer for PowerGrant {
    fn name(&self) -> &'static str {
        "powergrant"
    }

    fn assign(&mut self, _t: f64, _req: &Request, nodes: &[NodeState]) -> Option<usize> {
        let mut best = None;
        let mut best_score = f64::INFINITY;
        for (i, n) in nodes.iter().enumerate() {
            if !n.alive {
                continue;
            }
            let depth = (n.prefill_backlog + n.active_streams + 1) as f64;
            // Finite grants scale the score; infinite grants (uncapped)
            // normalize to 1 W so the comparison degrades to queue depth.
            let grant = if n.granted_w.is_finite() {
                n.granted_w.max(1e-9)
            } else {
                1.0
            };
            let score = depth / grant;
            // Strict `<`: ties break toward the lowest index.
            if score < best_score || best.is_none() {
                best_score = score;
                best = Some(i);
            }
        }
        best
    }
}

/// Index of the minimum key among *alive* nodes; ties break toward the
/// lowest index (keeps every policy deterministic). `None` when every
/// node in the slice is dead.
fn pick_min<K: Ord>(nodes: &[NodeState], key: impl Fn(&NodeState) -> K) -> Option<usize> {
    let mut best: Option<(usize, K)> = None;
    for (i, n) in nodes.iter().enumerate() {
        if !n.alive {
            continue;
        }
        let k = key(n);
        let better = match &best {
            Some((_, bk)) => k < *bk,
            None => true,
        };
        if better {
            best = Some((i, k));
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: f64, prompt: u32) -> Request {
        Request {
            id,
            arrival_s: t,
            prompt_len: prompt,
            output_len: 32,
        }
    }

    #[test]
    fn policy_names_round_trip_through_parse() {
        for lb in LbPolicy::all() {
            assert_eq!(LbPolicy::parse(lb.name()), Some(lb), "{lb:?}");
        }
        assert_eq!(LbPolicy::parse("roundrobin"), Some(LbPolicy::RoundRobin));
        assert_eq!(LbPolicy::parse("dualscale"), Some(LbPolicy::PhaseAware));
        assert_eq!(LbPolicy::parse("bogus"), None);
    }

    #[test]
    fn round_robin_cycles() {
        let mut b = build(LbPolicy::RoundRobin, 3, 0.1, PoolRatio::default());
        let states = vec![NodeState::default(); 3];
        let picks: Vec<usize> = (0..6)
            .map(|i| b.assign(i as f64, &req(i, i as f64, 100), &states).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_work_lazy_decay_matches_continuous_decay() {
        // Two nodes; load node 0 heavily, then wait several time constants:
        // node 0 must win again once its load has decayed below node 1's.
        let mut b = LeastPromptWork::new(2, 10.0);
        let n = vec![NodeState::default(); 2];
        assert_eq!(b.assign(0.0, &req(0, 0.0, 8000), &n), Some(0));
        assert_eq!(b.assign(0.1, &req(1, 0.1, 100), &n), Some(1));
        // t=1: node0 ~ 8000*e^-0.1 >> node1 ~ 100 → node 1.
        assert_eq!(b.assign(1.0, &req(2, 1.0, 100), &n), Some(1));
        // t=60: both decayed ~e^-6; node0 8000e^-6≈19.8 < node1 200e^-59/10…
        // node1 decayed from t≈1: 200e^-5.9 ≈ 0.55 → node 1 still smaller.
        assert_eq!(b.assign(60.0, &req(3, 60.0, 100), &n), Some(1));
        // Lazy value equals the closed-form continuous decay.
        let expect = (8000.0f64) * (-(60.0f64) / 10.0).exp();
        assert!((b.load_at(0, 60.0) - expect).abs() < 1e-9);
    }

    #[test]
    fn jsq_picks_emptiest_node() {
        let mut b = build(LbPolicy::JoinShortestQueue, 3, 0.1, PoolRatio::default());
        let mut states = vec![NodeState::default(); 3];
        states[0].prefill_backlog = 4;
        states[1].active_streams = 1;
        states[2].active_streams = 9;
        assert_eq!(b.assign(0.0, &req(0, 0.0, 100), &states), Some(1));
        // Equal depths: fewer outstanding tokens wins, then lowest index.
        states[1].active_streams = 4;
        states[2].active_streams = 4;
        states[2].prefill_backlog = 0;
        states[1].outstanding_prompt_tokens = 500;
        states[2].outstanding_prompt_tokens = 100;
        assert_eq!(b.assign(0.0, &req(1, 0.0, 100), &states), Some(2));
    }

    #[test]
    fn phase_aware_routes_long_prompts_to_dedicated_pool() {
        let mut b = build(LbPolicy::PhaseAware, 4, 0.1, PoolRatio::default());
        let states = vec![NodeState::default(); 4];
        // 4 nodes → 1 long node (index 3).
        assert_eq!(b.assign(0.0, &req(0, 0.0, 4096), &states), Some(3));
        // Interactive traffic stays off the long pool.
        let pick = b.assign(0.0, &req(1, 0.0, 128), &states).unwrap();
        assert!(pick < 3, "interactive landed on the long pool: {pick}");
    }

    #[test]
    fn phase_aware_avoids_unhealthy_tails() {
        let mut b = build(LbPolicy::PhaseAware, 4, 0.1, PoolRatio::default());
        let mut states = vec![NodeState::default(); 4];
        // Node 0 empty but with a blown TBT tail; node 1 busy but healthy.
        states[0].tbt_tail_p95_s = 0.5;
        states[1].active_streams = 3;
        assert_eq!(b.assign(0.0, &req(0, 0.0, 128), &states), Some(1));
    }

    #[test]
    fn every_policy_skips_dead_nodes() {
        for lb in LbPolicy::all() {
            let mut b = build(lb, 3, 0.1, PoolRatio::default());
            let mut states = vec![NodeState::default(); 3];
            states[0].alive = false;
            states[2].alive = false;
            for i in 0..6 {
                let prompt = if i % 2 == 0 { 100 } else { 4096 };
                let pick = b.assign(i as f64, &req(i, i as f64, prompt), &states);
                assert_eq!(pick, Some(1), "{lb:?} routed to a dead node");
            }
        }
    }

    #[test]
    fn round_robin_resumes_cycle_after_recovery() {
        let mut b = build(LbPolicy::RoundRobin, 3, 0.1, PoolRatio::default());
        let mut states = vec![NodeState::default(); 3];
        states[1].alive = false;
        assert_eq!(b.assign(0.0, &req(0, 0.0, 100), &states), Some(0));
        assert_eq!(b.assign(0.0, &req(1, 0.0, 100), &states), Some(2));
        states[1].alive = true;
        assert_eq!(b.assign(0.0, &req(2, 0.0, 100), &states), Some(0));
        assert_eq!(b.assign(0.0, &req(3, 0.0, 100), &states), Some(1));
    }

    #[test]
    fn phase_aware_spills_across_dead_pools() {
        // 4 nodes: interactive pool {0,1,2}, long pool {3}.
        let mut b = build(LbPolicy::PhaseAware, 4, 0.1, PoolRatio::default());
        let mut states = vec![NodeState::default(); 4];
        // Long pool down: long prompts spill into the interactive pool.
        states[3].alive = false;
        assert!(b.assign(0.0, &req(0, 0.0, 4096), &states).unwrap() < 3);
        // Interactive pool down: short prompts spill into the long pool.
        states[3].alive = true;
        for s in states[..3].iter_mut() {
            s.alive = false;
        }
        assert_eq!(b.assign(0.0, &req(1, 0.0, 128), &states), Some(3));
    }

    #[test]
    fn powergrant_routes_by_watts_per_queued_work() {
        let mut b = build(LbPolicy::PowerGrant, 2, 0.1, PoolRatio::default());
        let mut states = vec![NodeState::default(); 2];
        // Equal depth, unequal grants: the bigger grant wins.
        states[0].granted_w = 1000.0;
        states[1].granted_w = 3000.0;
        assert_eq!(b.assign(0.0, &req(0, 0.0, 100), &states), Some(1));
        // A starved grant loses even to a deeper queue.
        states[0].granted_w = 500.0;
        states[1].granted_w = 3000.0;
        states[1].active_streams = 3;
        assert_eq!(b.assign(0.0, &req(1, 0.0, 100), &states), Some(1));
        // Uncapped (infinite grants): degrades to queue depth.
        states[0].granted_w = f64::INFINITY;
        states[1].granted_w = f64::INFINITY;
        assert_eq!(b.assign(0.0, &req(2, 0.0, 100), &states), Some(0));
    }

    #[test]
    fn phase_aware_single_node_degrades_gracefully() {
        let mut b = build(LbPolicy::PhaseAware, 1, 0.1, PoolRatio::default());
        let states = vec![NodeState::default(); 1];
        assert_eq!(b.assign(0.0, &req(0, 0.0, 4096), &states), Some(0));
        assert_eq!(b.assign(0.0, &req(1, 0.0, 64), &states), Some(0));
    }

    #[test]
    fn phase_aware_single_node_honors_liveness() {
        // Regression: the long_nodes == 0 arm used to return 0 without
        // looking at the snapshot, routing arrivals into a dead node
        // during its fault window.
        let mut b = build(LbPolicy::PhaseAware, 1, 0.1, PoolRatio::default());
        let mut states = vec![NodeState::default(); 1];
        states[0].alive = false;
        assert_eq!(b.assign(0.0, &req(0, 0.0, 4096), &states), None);
        states[0].alive = true;
        assert_eq!(b.assign(0.0, &req(1, 0.0, 4096), &states), Some(0));
    }

    #[test]
    fn phase_aware_all_dead_defers_instead_of_panicking() {
        // Regression: both spill arms used to `.expect("phase: no alive
        // nodes")` — overlapping fault windows between a drain and its
        // re-route aborted the whole run.
        let mut b = build(LbPolicy::PhaseAware, 4, 0.1, PoolRatio::default());
        let mut states = vec![NodeState::default(); 4];
        for s in states.iter_mut() {
            s.alive = false;
        }
        assert_eq!(b.assign(0.0, &req(0, 0.0, 4096), &states), None);
        assert_eq!(b.assign(0.0, &req(1, 0.0, 64), &states), None);
    }

    #[test]
    fn every_policy_defers_when_cluster_dark() {
        for lb in LbPolicy::all() {
            let mut b = build(lb, 3, 0.1, PoolRatio::default());
            let mut states = vec![NodeState::default(); 3];
            for s in states.iter_mut() {
                s.alive = false;
            }
            assert_eq!(
                b.assign(0.0, &req(0, 0.0, 100), &states),
                None,
                "{lb:?} assigned with every node down"
            );
        }
    }

    #[test]
    fn phase_aware_pool_ratio_moves_the_split() {
        // 1:1 on 4 nodes → long pool {2, 3} instead of the default {3}.
        let ratio = PoolRatio { prefill: 1, decode: 1 };
        let mut b = build(LbPolicy::PhaseAware, 4, 0.1, ratio);
        let states = vec![NodeState::default(); 4];
        let long_pick = b.assign(0.0, &req(0, 0.0, 4096), &states).unwrap();
        assert!(long_pick >= 2, "long prompt landed at {long_pick}");
        let short_pick = b.assign(0.0, &req(1, 0.0, 128), &states).unwrap();
        assert!(short_pick < 2, "interactive landed at {short_pick}");
    }
}

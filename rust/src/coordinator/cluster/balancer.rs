//! Cluster ingress load balancing: online policies over live node
//! telemetry.
//!
//! A [`Balancer`] is consulted once per arriving request with a
//! [`NodeState`] snapshot per node (queue depths, outstanding prefill
//! tokens, decode TBT tail — everything the cluster event loop can read
//! off the live engines). Registering a new policy means implementing the
//! trait, adding an [`LbPolicy`] variant and wiring it in [`build`]; the
//! CLI, the scenario matrix and the invariant tests pick it up unchanged.

use crate::workload::request::{Request, RouteClass};

/// Live telemetry the cluster loop snapshots per node before each
/// assignment decision.
#[derive(Debug, Clone, Copy, Default)]
pub struct NodeState {
    /// Requests handed to this node so far.
    pub assigned: usize,
    /// Prefill jobs queued or in flight.
    pub prefill_backlog: usize,
    /// Prompt tokens queued or in prefill flight.
    pub outstanding_prompt_tokens: u64,
    /// Decode streams admitted and not yet finished.
    pub active_streams: usize,
    /// P95 of the node's recent decode TBTs (0.0 until samples exist).
    pub tbt_tail_p95_s: f64,
}

/// Load-balancing policy at cluster ingress.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LbPolicy {
    /// Classic round-robin (front-end information only; baseline).
    RoundRobin,
    /// Join-least-loaded by accumulated prompt tokens with exponential
    /// decay — a front-end's cheap proxy for outstanding prefill work
    /// (baseline; no live telemetry).
    LeastPromptWork,
    /// Join-shortest-queue on live backlog (prefill jobs + decode streams).
    JoinShortestQueue,
    /// DualScale-style phase-aware ingress: long-prompt (prefill-heavy)
    /// requests go to a dedicated node subset, interactive traffic joins
    /// the shortest healthy queue on the rest (nodes with a blown TBT tail
    /// are deprioritized).
    PhaseAware,
}

impl LbPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            LbPolicy::RoundRobin => "rr",
            LbPolicy::LeastPromptWork => "leastwork",
            LbPolicy::JoinShortestQueue => "jsq",
            LbPolicy::PhaseAware => "phase",
        }
    }

    pub fn parse(s: &str) -> Option<LbPolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "rr" | "roundrobin" | "round-robin" => Some(LbPolicy::RoundRobin),
            "leastwork" | "least-work" | "lpw" => Some(LbPolicy::LeastPromptWork),
            "jsq" | "shortestqueue" | "shortest-queue" => Some(LbPolicy::JoinShortestQueue),
            "phase" | "phaseaware" | "phase-aware" | "dualscale" => Some(LbPolicy::PhaseAware),
            _ => None,
        }
    }

    /// Every registered policy, in report order.
    pub fn all() -> Vec<LbPolicy> {
        vec![
            LbPolicy::RoundRobin,
            LbPolicy::LeastPromptWork,
            LbPolicy::JoinShortestQueue,
            LbPolicy::PhaseAware,
        ]
    }

    /// Does this policy use only front-end information (arrival order,
    /// prompt length)? Such policies can also pre-assign a trace offline.
    pub fn frontend_only(&self) -> bool {
        matches!(self, LbPolicy::RoundRobin | LbPolicy::LeastPromptWork)
    }
}

/// An ingress balancer: one request + live node states in, node index out.
pub trait Balancer {
    fn name(&self) -> &'static str;
    /// Pick the node for `req` arriving at `t`. `nodes` has one entry per
    /// node, index-aligned; the returned index must be `< nodes.len()`.
    fn assign(&mut self, t: f64, req: &Request, nodes: &[NodeState]) -> usize;
}

/// Instantiate the balancer for a policy. `tbt_target_s` is the per-node
/// decode SLO the phase-aware policy uses to spot unhealthy tails.
pub fn build(lb: LbPolicy, nodes: usize, tbt_target_s: f64) -> Box<dyn Balancer> {
    assert!(nodes >= 1);
    match lb {
        LbPolicy::RoundRobin => Box::new(RoundRobin { next: 0, nodes }),
        LbPolicy::LeastPromptWork => Box::new(LeastPromptWork::new(nodes, 10.0)),
        LbPolicy::JoinShortestQueue => Box::new(JoinShortestQueue),
        LbPolicy::PhaseAware => Box::new(PhaseAware::new(nodes, tbt_target_s)),
    }
}

struct RoundRobin {
    next: usize,
    nodes: usize,
}

impl Balancer for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn assign(&mut self, _t: f64, _req: &Request, _nodes: &[NodeState]) -> usize {
        let n = self.next;
        self.next = (self.next + 1) % self.nodes;
        n
    }
}

/// Decaying outstanding-work estimate per node; time constant ~10 s (a
/// prefill queue's memory). Decay is applied lazily from a per-node
/// last-touched timestamp, so an assignment costs O(nodes) comparisons and
/// exactly one write — not O(nodes) exponentials ageing every counter.
struct LeastPromptWork {
    load: Vec<f64>,
    last_t: Vec<f64>,
    tau: f64,
}

impl LeastPromptWork {
    fn new(nodes: usize, tau: f64) -> Self {
        LeastPromptWork {
            load: vec![0.0; nodes],
            last_t: vec![0.0; nodes],
            tau,
        }
    }

    /// Continuous-decay value of node `i`'s load at time `t`.
    fn load_at(&self, i: usize, t: f64) -> f64 {
        self.load[i] * (-(t - self.last_t[i]).max(0.0) / self.tau).exp()
    }
}

impl Balancer for LeastPromptWork {
    fn name(&self) -> &'static str {
        "leastwork"
    }

    fn assign(&mut self, t: f64, req: &Request, _nodes: &[NodeState]) -> usize {
        let mut best = 0;
        let mut best_load = f64::INFINITY;
        for i in 0..self.load.len() {
            let l = self.load_at(i, t);
            if l < best_load {
                best_load = l;
                best = i;
            }
        }
        // Touch only the winner: fold its decay into the stored value.
        self.load[best] = best_load + req.prompt_len as f64;
        self.last_t[best] = t;
        best
    }
}

struct JoinShortestQueue;

impl JoinShortestQueue {
    fn depth(n: &NodeState) -> usize {
        n.prefill_backlog + n.active_streams
    }
}

impl Balancer for JoinShortestQueue {
    fn name(&self) -> &'static str {
        "jsq"
    }

    fn assign(&mut self, _t: f64, _req: &Request, nodes: &[NodeState]) -> usize {
        pick_min(nodes, |n| (Self::depth(n) as u64, n.outstanding_prompt_tokens))
    }
}

/// DualScale-style split: the last `long_nodes` nodes form the
/// prefill-heavy pool; everything else serves interactive traffic.
struct PhaseAware {
    long_nodes: usize,
    tbt_target_s: f64,
}

impl PhaseAware {
    fn new(nodes: usize, tbt_target_s: f64) -> Self {
        // Dedicate ~a quarter of the cluster (at least one node) to long
        // prefill once there are enough nodes to split at all.
        let long_nodes = if nodes >= 2 { (nodes / 4).max(1) } else { 0 };
        PhaseAware {
            long_nodes,
            tbt_target_s,
        }
    }
}

impl Balancer for PhaseAware {
    fn name(&self) -> &'static str {
        "phase"
    }

    fn assign(&mut self, _t: f64, req: &Request, nodes: &[NodeState]) -> usize {
        if self.long_nodes == 0 {
            return 0; // single node: nothing to split
        }
        let split = nodes.len() - self.long_nodes;
        match req.route_class() {
            RouteClass::Long => {
                // Prefill pool: least outstanding prompt work.
                split
                    + pick_min(&nodes[split..], |n| {
                        (n.outstanding_prompt_tokens, n.prefill_backlog as u64)
                    })
            }
            RouteClass::ShortMedium => {
                // Interactive pool: shortest queue among healthy nodes; a
                // blown decode tail pushes a node behind every healthy one.
                pick_min(&nodes[..split], |n| {
                    let unhealthy = (n.tbt_tail_p95_s > self.tbt_target_s) as u64;
                    (
                        unhealthy,
                        (n.prefill_backlog + n.active_streams) as u64,
                    )
                })
            }
        }
    }
}

/// Index of the minimum key; ties break toward the lowest index (keeps
/// every policy deterministic).
fn pick_min<K: Ord>(nodes: &[NodeState], key: impl Fn(&NodeState) -> K) -> usize {
    let mut best = 0;
    let mut best_key = key(&nodes[0]);
    for (i, n) in nodes.iter().enumerate().skip(1) {
        let k = key(n);
        if k < best_key {
            best_key = k;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: f64, prompt: u32) -> Request {
        Request {
            id,
            arrival_s: t,
            prompt_len: prompt,
            output_len: 32,
        }
    }

    #[test]
    fn policy_names_round_trip_through_parse() {
        for lb in LbPolicy::all() {
            assert_eq!(LbPolicy::parse(lb.name()), Some(lb), "{lb:?}");
        }
        assert_eq!(LbPolicy::parse("roundrobin"), Some(LbPolicy::RoundRobin));
        assert_eq!(LbPolicy::parse("dualscale"), Some(LbPolicy::PhaseAware));
        assert_eq!(LbPolicy::parse("bogus"), None);
    }

    #[test]
    fn round_robin_cycles() {
        let mut b = build(LbPolicy::RoundRobin, 3, 0.1);
        let states = vec![NodeState::default(); 3];
        let picks: Vec<usize> = (0..6)
            .map(|i| b.assign(i as f64, &req(i, i as f64, 100), &states))
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_work_lazy_decay_matches_continuous_decay() {
        // Two nodes; load node 0 heavily, then wait several time constants:
        // node 0 must win again once its load has decayed below node 1's.
        let mut b = LeastPromptWork::new(2, 10.0);
        let n = vec![NodeState::default(); 2];
        assert_eq!(b.assign(0.0, &req(0, 0.0, 8000), &n), 0);
        assert_eq!(b.assign(0.1, &req(1, 0.1, 100), &n), 1);
        // t=1: node0 ~ 8000*e^-0.1 >> node1 ~ 100 → node 1.
        assert_eq!(b.assign(1.0, &req(2, 1.0, 100), &n), 1);
        // t=60: both decayed ~e^-6; node0 8000e^-6≈19.8 < node1 200e^-59/10…
        // node1 decayed from t≈1: 200e^-5.9 ≈ 0.55 → node 1 still smaller.
        assert_eq!(b.assign(60.0, &req(3, 60.0, 100), &n), 1);
        // Lazy value equals the closed-form continuous decay.
        let expect = (8000.0f64) * (-(60.0f64) / 10.0).exp();
        assert!((b.load_at(0, 60.0) - expect).abs() < 1e-9);
    }

    #[test]
    fn jsq_picks_emptiest_node() {
        let mut b = build(LbPolicy::JoinShortestQueue, 3, 0.1);
        let mut states = vec![NodeState::default(); 3];
        states[0].prefill_backlog = 4;
        states[1].active_streams = 1;
        states[2].active_streams = 9;
        assert_eq!(b.assign(0.0, &req(0, 0.0, 100), &states), 1);
        // Equal depths: fewer outstanding tokens wins, then lowest index.
        states[1].active_streams = 4;
        states[2].active_streams = 4;
        states[2].prefill_backlog = 0;
        states[1].outstanding_prompt_tokens = 500;
        states[2].outstanding_prompt_tokens = 100;
        assert_eq!(b.assign(0.0, &req(1, 0.0, 100), &states), 2);
    }

    #[test]
    fn phase_aware_routes_long_prompts_to_dedicated_pool() {
        let mut b = build(LbPolicy::PhaseAware, 4, 0.1);
        let states = vec![NodeState::default(); 4];
        // 4 nodes → 1 long node (index 3).
        assert_eq!(b.assign(0.0, &req(0, 0.0, 4096), &states), 3);
        // Interactive traffic stays off the long pool.
        let pick = b.assign(0.0, &req(1, 0.0, 128), &states);
        assert!(pick < 3, "interactive landed on the long pool: {pick}");
    }

    #[test]
    fn phase_aware_avoids_unhealthy_tails() {
        let mut b = build(LbPolicy::PhaseAware, 4, 0.1);
        let mut states = vec![NodeState::default(); 4];
        // Node 0 empty but with a blown TBT tail; node 1 busy but healthy.
        states[0].tbt_tail_p95_s = 0.5;
        states[1].active_streams = 3;
        assert_eq!(b.assign(0.0, &req(0, 0.0, 128), &states), 1);
    }

    #[test]
    fn phase_aware_single_node_degrades_gracefully() {
        let mut b = build(LbPolicy::PhaseAware, 1, 0.1);
        let states = vec![NodeState::default(); 1];
        assert_eq!(b.assign(0.0, &req(0, 0.0, 4096), &states), 0);
        assert_eq!(b.assign(0.0, &req(1, 0.0, 64), &states), 0);
    }
}

//! The cluster event loop: N node engines interleaved on one virtual
//! clock.
//!
//! The loop merges the deterministic event sources:
//! * the arrival stream (the trace, pre-scheduled into a cluster queue),
//! * the power arbiter's control epochs,
//! * the fault plan's node transitions (chaos layer: loss, recovery,
//!   spot-preemption drain notices, straggler degrade/restore),
//! * stream migrations (disaggregated clusters: a finished prefill's KV
//!   landing on its decode node after the modeled link latency),
//! * the capacity controller's check epochs and node boots (elastic
//!   capacity: an endogenous autoscaler over the same clock),
//! * shed-policy retry offers (overload: deferred arrivals re-offered
//!   with backoff),
//! * each node engine's own pending events.
//!
//! At every iteration the earliest source wins; ties go cluster-first and
//! then lowest-node-first, so the whole simulation is a pure function of
//! (trace, config, fault plan, seed). Exact-equal-timestamp cluster
//! events resolve in scheduling-order: arrivals, then faults, then power
//! epochs, then capacity checks, then everything runtime-scheduled
//! (migrations, retries, boots, re-armed epochs/checks) in the order it
//! was scheduled — so they always draw the highest sequence numbers, and
//! a migration landing at the instant its target dies sees the
//! post-fault alive set and relays. An arriving request is assigned by
//! the balancer from a *live* telemetry snapshot — which carries
//! routability and the arbiter's current watt grants — and injected into
//! the chosen engine through the priority event lane, which makes a
//! 1-node cluster replay bit-identical to a plain
//! [`run`](crate::coordinator::run).
//!
//! **Disaggregation (§migration contract).** With a [`DisaggConfig`] the
//! first `pool_ratio.prefill_count(nodes)` nodes form the prefill pool:
//! the ingress balancer sees only them, their engines run in migrate-out
//! mode, and every finished prefill is routed by
//! [`disagg::eco_route`] over live decode telemetry, charged the KV
//! link's energy at *both* ends, and delivered as a `Migrate` event
//! after the transfer latency. Conservation holds the same way it does
//! for faults: the first token is counted only on the receiving node, a
//! dead target at delivery relays to a fresh one, and a node failure on
//! either side re-routes the work through ingress for a full re-prefill
//! (the KV died with the node). `assignment` tracks the node currently
//! owning each request — the sender's count moves to the receiver at
//! delivery. If every routable node is transiently down the work is
//! *deferred* — held by the loop and re-offered at the next recovery —
//! never panicked on.
//!
//! **Elastic capacity (§degradation contract).** Three liveness shades,
//! strictly ordered: *routable* (balancer-visible) ⊆ *alive* (still
//! serving its own work) ⊆ *provisioned*. A spot-preemption notice
//! (`FaultKind::Drain`) clears routable but not alive — the node drains
//! what it owns before the paired `Down` yanks it. A straggler
//! (`FaultKind::Slow`) stays both alive and routable but runs with a
//! capped ladder and a perf slowdown, so governors and the arbiter must
//! cope with a *slow* node, not just a dead one. The capacity controller
//! ([`CapacityConfig`](super::CapacityConfig)) parks idle nodes cold
//! (alive = false, warm idle watts metered into `warm_energy_j`) and
//! boots them back with a `boot_s` latency when backlog pressure crosses
//! its watermarks; a fault `Down` on a cold node wins over any pending
//! boot. The shed policy ([`ShedConfig`](super::ShedConfig)) gates
//! ingress when backlog per routable node exceeds its depth: arrivals
//! are re-offered through `Retry` events with exponential backoff, then
//! shed permanently — every request ends completed or shed, never lost
//! (`completed + shed == arrived`, property-tested). With none of these
//! knobs set the loop is bit-exact with the pre-elasticity behavior.
//!
//! **Scheduling is O(log N) per event (§Perf).** The next engine to step
//! comes from a [`SourceHeap`] keyed on each engine's next-event time;
//! the key is re-sifted only when that engine's queue can have changed —
//! after it steps, after an `inject`, after `fail`/`recover`, and after
//! every arbiter (re-)arbitration (belt-and-braces: arbitration clamps
//! clocks but schedules nothing). The pre-PR5 per-event linear scan over
//! all engines is kept verbatim behind [`run_cluster_scan_oracle`] and
//! the two paths are asserted bit-equal by the property suite in
//! `tests/cluster_invariants.rs`.
//!
//! Node loss re-homes work instead of dropping it: the failed engine is
//! drained ([`Engine::fail_into`] — into a buffer the loop reuses across
//! faults, so chaos paths allocate nothing steady-state) and every
//! incomplete request goes back through the balancer at the failure
//! instant, so request and token conservation hold under churn (partial
//! decodes are rolled back into `wasted_tokens`). Recovery
//! ([`Engine::recover`]) powers the node back on with cold telemetry and
//! lets the balancer route to it again. Under a power cap, both
//! transitions trigger an immediate out-of-band re-arbitration so the
//! budget invariant survives churn: loss frees the dead node's share to
//! the survivors, recovery clamps the rejoining node at the rejoin
//! instant instead of letting it run uncapped until the next epoch.

use std::cell::RefCell;

use crate::coordinator::cluster::balancer::{self, Balancer, NodeState};
use crate::coordinator::cluster::disagg::{self, DisaggConfig, MigrationReport, NodeMigration};
use crate::coordinator::cluster::faults::FaultKind;
use crate::coordinator::cluster::power::{ArbiterStrategy, PowerArbiter};
use crate::coordinator::cluster::{ClusterConfig, ClusterResult, PowerReport};
use crate::coordinator::engine::{Engine, MigratedStream, RunOptions, RunResult};
use crate::metrics::Histogram;
use crate::obs::{FlightRecorder, NoopRecorder, Recorder, SharedRecorder};
use crate::sim::{self, EventQueue, SourceHeap};
use crate::workload::request::{Request, RouteClass, Trace};

#[derive(Debug, Clone, Copy)]
enum ClusterEv {
    /// Index into the trace's request list.
    Arrive(usize),
    PowerEpoch,
    /// Index into the fault plan's event list.
    Fault(usize),
    /// A migrated stream's KV transfer completes: index into the run's
    /// pending-migration list (runtime-scheduled at prefill completion).
    Migrate(usize),
    /// A shed-policy re-offer of trace request `.0` (attempt `.1`,
    /// 1-based at delivery — runtime-scheduled with backoff).
    Retry(usize, u32),
    /// Capacity-controller check epoch (re-armed each firing).
    Capacity,
    /// A provisioned node finishes booting (runtime-scheduled
    /// `boot_s` after the controller's scale-up decision).
    CapacityBoot(usize),
}

/// One in-flight prefill→decode handoff (indexed by `ClusterEv::Migrate`;
/// a relay re-targets the entry and re-schedules the same index).
struct PendingMigration {
    req: Request,
    /// Prefill completion on the sender — the TTFT anchor.
    prefill_done_s: f64,
    /// Sending node (re-charged on a relay: it still holds the KV).
    from: usize,
    /// Current destination decode node.
    target: usize,
}

/// Strategy for picking the next engine to step. The production path
/// ([`HeapSelector`]) maintains an index min-heap; the oracle
/// ([`ScanSelector`]) re-reads every engine each iteration, exactly like
/// the pre-PR5 loop — property tests assert the two produce bit-equal
/// cluster results.
trait EngineSelector {
    fn new(n: usize) -> Self;
    /// Engine `i`'s event queue may have changed — re-key it.
    fn update<R: Recorder>(&mut self, i: usize, engines: &[Engine<'_, R>]);
    /// Every engine may have changed (epoch boundaries, fault churn).
    fn refresh_all<R: Recorder>(&mut self, engines: &[Engine<'_, R>]);
    /// The earliest engine and its next-event time.
    fn next<R: Recorder>(&mut self, engines: &[Engine<'_, R>]) -> Option<(usize, f64)>;
}

/// O(log N) per event: keys live in a [`SourceHeap`], only touched
/// engines re-sift.
struct HeapSelector(SourceHeap);

impl EngineSelector for HeapSelector {
    fn new(n: usize) -> Self {
        HeapSelector(SourceHeap::new(n))
    }

    fn update<R: Recorder>(&mut self, i: usize, engines: &[Engine<'_, R>]) {
        self.0.set(i, engines[i].peek_time());
    }

    fn refresh_all<R: Recorder>(&mut self, engines: &[Engine<'_, R>]) {
        for (i, e) in engines.iter().enumerate() {
            self.0.set(i, e.peek_time());
        }
    }

    fn next<R: Recorder>(&mut self, _engines: &[Engine<'_, R>]) -> Option<(usize, f64)> {
        self.0.min()
    }
}

/// The kept-verbatim pre-PR5 behavior: every `next` re-reads every
/// engine's `peek_time` and linearly scans for the minimum
/// ([`sim::earliest`]). O(N) per event — oracle/testing only.
struct ScanSelector {
    times: Vec<Option<f64>>,
}

impl EngineSelector for ScanSelector {
    fn new(n: usize) -> Self {
        ScanSelector {
            times: vec![None; n],
        }
    }

    fn update<R: Recorder>(&mut self, _i: usize, _engines: &[Engine<'_, R>]) {}

    fn refresh_all<R: Recorder>(&mut self, _engines: &[Engine<'_, R>]) {}

    fn next<R: Recorder>(&mut self, engines: &[Engine<'_, R>]) -> Option<(usize, f64)> {
        for (i, e) in engines.iter().enumerate() {
            self.times[i] = e.peek_time();
        }
        sim::earliest(&self.times).map(|i| (i, self.times[i].expect("earliest picked Some")))
    }
}

fn snapshot<R: Recorder>(e: &Engine<'_, R>, alive: bool, granted_w: f64) -> NodeState {
    NodeState {
        assigned: e.assigned(),
        prefill_backlog: e.prefill_backlog(),
        outstanding_prompt_tokens: e.outstanding_prompt_tokens(),
        active_streams: e.active_streams(),
        tbt_tail_p95_s: e.tbt_tail_p95(),
        alive,
        granted_w,
    }
}

/// Balancer-facing snapshots. `routable` — not raw liveness — feeds the
/// `alive` field, so draining (spot notice) and cold-parked nodes are
/// invisible to placement while still finishing or holding their own
/// work. Without elasticity knobs `routable == alive` and this is the
/// pre-elasticity snapshot, bit for bit.
fn snapshot_all<R: Recorder>(
    engines: &[Engine<'_, R>],
    routable: &[bool],
    granted_w: &[f64],
    states: &mut Vec<NodeState>,
) {
    states.clear();
    states.extend(
        engines
            .iter()
            .enumerate()
            .map(|(i, e)| snapshot(e, routable[i], granted_w[i])),
    );
}

/// Ingress pick: the balancer sees `states[..ingress]` (the prefill pool
/// when disaggregated, the whole cluster otherwise). If the balancer
/// defers — only legitimate when every ingress node is unroutable — fall
/// back to the lowest-index routable node anywhere: each node is a full
/// engine, so a decode node can colocate in a pinch (degraded mode). If
/// *nothing* is routable, fall back further to any node that is still
/// `alive` — a draining node serves new work rather than defer it.
/// `None` only when the entire cluster is dark; the caller then defers
/// the request until the next recovery.
fn pick_ingress(
    lb: &mut dyn Balancer,
    t: f64,
    req: &Request,
    states: &[NodeState],
    ingress: usize,
    alive: &[bool],
) -> Option<usize> {
    if let Some(node) = lb.assign(t, req, &states[..ingress]) {
        return Some(node);
    }
    debug_assert!(
        states[..ingress].iter().all(|s| !s.alive),
        "balancer deferred with a routable ingress node"
    );
    if let Some(node) = states.iter().position(|s| s.alive) {
        return Some(node);
    }
    alive.iter().position(|&a| a)
}

/// Run `trace` across the cluster as one interleaved event-driven
/// simulation, honoring the config's node specs, fault plan, capacity
/// controller, shed policy and arbiter strategy. Panics on an invalid
/// fault plan or capacity/shed config (validate at the CLI for a
/// friendly error).
pub fn run_cluster(ccfg: &ClusterConfig, trace: &Trace, opts: &RunOptions) -> ClusterResult {
    run_cluster_impl::<HeapSelector, _>(ccfg, trace, opts, NoopRecorder)
}

/// [`run_cluster`] with the flight recorder attached: every node engine
/// and the cluster loop itself record into `rec` (spans, per-node
/// samples at arbitration epochs, migration/fault markers). The
/// interleaving is identical to [`run_cluster`] — the recorder only
/// observes — and the output is deterministic, so two recorded runs of
/// the same deployment produce byte-identical exported traces.
pub fn run_cluster_recorded(
    ccfg: &ClusterConfig,
    trace: &Trace,
    opts: &RunOptions,
    rec: &RefCell<FlightRecorder>,
) -> ClusterResult {
    run_cluster_impl::<HeapSelector, _>(ccfg, trace, opts, SharedRecorder(rec))
}

/// [`run_cluster`] driven by the kept-verbatim pre-PR5 linear-scan
/// engine selection instead of the O(log N) heap. Exists solely so the
/// property suite can assert the two interleavings are bit-identical;
/// not part of the supported API.
#[doc(hidden)]
pub fn run_cluster_scan_oracle(
    ccfg: &ClusterConfig,
    trace: &Trace,
    opts: &RunOptions,
) -> ClusterResult {
    run_cluster_impl::<ScanSelector, _>(ccfg, trace, opts, NoopRecorder)
}

/// Sample every node's telemetry into the recorder (arbitration-epoch
/// cadence; ∞/uncapped grants export as "absent"). Compiles out when the
/// recorder is the no-op.
fn sample_all<R: Recorder>(engines: &mut [Engine<'_, R>], t: f64, granted_w: &[f64]) {
    if !R::ENABLED {
        return;
    }
    for (e, &g) in engines.iter_mut().zip(granted_w) {
        e.record_obs_sample(t, if g.is_finite() { g } else { -1.0 });
    }
}

fn run_cluster_impl<S: EngineSelector, R: Recorder + Clone>(
    ccfg: &ClusterConfig,
    trace: &Trace,
    opts: &RunOptions,
    rec: R,
) -> ClusterResult {
    assert!(ccfg.nodes >= 1, "cluster needs at least one node");
    ccfg.faults
        .validate(ccfg.nodes)
        .unwrap_or_else(|e| panic!("invalid fault plan: {e}"));
    if let Some(cc) = &ccfg.capacity {
        cc.validate(ccfg.nodes)
            .unwrap_or_else(|e| panic!("invalid capacity config: {e}"));
    }
    if let Some(sc) = &ccfg.shed {
        sc.validate()
            .unwrap_or_else(|e| panic!("invalid shed config: {e}"));
    }
    let capacity = ccfg.capacity;
    let shed = ccfg.shed;
    // Disaggregation: first `prefill_pool` nodes prefill + migrate out,
    // the rest decode. 0 = colocated (disagg unset, or a 1-node cluster
    // that cannot split) — every migration path below is then dormant.
    let prefill_pool = ccfg.prefill_pool();
    let link = ccfg.disagg.unwrap_or_default().link;
    let tbt_target_s = ccfg.node.slo.tbt_p95_s;
    // Telemetry-driven balancers, the SLO-pressure arbiter and the
    // migration router read the per-node TBT tail, so keep it live for
    // them; front-end-only policies (rr, leastwork) never look, so skip
    // the per-token cost. Everything else passes through.
    let wants_tail = !ccfg.lb.frontend_only()
        || (ccfg.power_cap_w.is_some() && ccfg.arbiter == ArbiterStrategy::SloPressure)
        || prefill_pool > 0;
    let node_opts = RunOptions {
        track_tbt_tail: opts.track_tbt_tail || wants_tail,
        ..opts.clone()
    };
    let node_cfgs: Vec<_> = (0..ccfg.nodes)
        .map(|n| {
            let mut cfg = ccfg.node.clone();
            cfg.seed = ccfg.node.seed.wrapping_add(n as u64);
            if !ccfg.node_specs.is_empty() {
                ccfg.node_specs[n % ccfg.node_specs.len()].apply(&mut cfg);
            }
            // Per-pool DVFS: each pool may run its own method against
            // its own SLO (TTFT on prefill nodes, TBT tail on decode).
            if prefill_pool > 0 {
                let d: DisaggConfig = ccfg.disagg.expect("prefill_pool > 0 implies disagg");
                let over = if n < prefill_pool {
                    d.prefill_method
                } else {
                    d.decode_method
                };
                if let Some(m) = over {
                    cfg.method = m;
                }
            }
            cfg
        })
        .collect();
    let mut engines: Vec<Engine<'_, R>> = node_cfgs
        .iter()
        .enumerate()
        .map(|(n, cfg)| {
            Engine::with_recorder(
                cfg,
                &node_opts,
                format!("{}::node{n}", trace.name),
                trace.duration_s,
                rec.clone(),
                n,
            )
        })
        .collect();
    for e in engines.iter_mut() {
        e.begin();
    }
    for e in engines[..prefill_pool].iter_mut() {
        e.enable_migrate_out();
    }

    // Disaggregated ingress balances over the prefill pool only.
    let ingress = if prefill_pool > 0 {
        prefill_pool
    } else {
        ccfg.nodes
    };
    let mut lb = balancer::build(ccfg.lb, ingress, tbt_target_s, ccfg.pool_ratio);
    let mut alive = vec![true; ccfg.nodes];
    // Balancer-visible liveness: alive minus draining (spot notice)
    // minus cold-parked. Maintained at every transition; feeds every
    // telemetry snapshot.
    let mut routable = vec![true; ccfg.nodes];
    // Spot-preemption notice state: alive, finishing its own work,
    // taking nothing new.
    let mut draining = vec![false; ccfg.nodes];
    // Capacity-controller state: parked-cold nodes, their park instant
    // (warm idle accrues from it), and in-flight boots.
    let mut is_cold = vec![false; ccfg.nodes];
    let mut cold_since = vec![0.0f64; ccfg.nodes];
    let mut booting = vec![false; ccfg.nodes];
    let mut warm_energy_j: f64 = 0.0;
    // Warm pool: the controller starts with the highest-index nodes
    // parked — drained cold *before* the arbiter splits its budget, so
    // the initial grants only cover live nodes. Their idle draw is
    // metered into `warm_energy_j` from t = 0.
    if let Some(cc) = &capacity {
        for n in ccfg.nodes - cc.warm..ccfg.nodes {
            let mut fresh: Vec<Request> = Vec::new();
            engines[n].fail_into(0.0, &mut fresh);
            debug_assert!(fresh.is_empty(), "fresh engine drained work");
            alive[n] = false;
            routable[n] = false;
            is_cold[n] = true;
        }
    }
    // Latest worst-case watt grant per node (∞ = uncapped); the
    // `powergrant` balancer routes on this.
    let mut granted_w = vec![f64::INFINITY; ccfg.nodes];
    let mut arbiter = ccfg.power_cap_w.map(|cap| {
        let mut a = PowerArbiter::new(
            cap,
            ccfg.power_epoch_s,
            ccfg.nodes,
            ccfg.arbiter,
            ccfg.node.slo.tbt_p95_s,
        );
        a.set_prefill_pool(prefill_pool);
        a
    });
    if let Some(a) = arbiter.as_mut() {
        a.apply_initial(&mut engines, &alive);
        if let Some(g) = a.latest_grants() {
            granted_w.copy_from_slice(g);
        }
    }
    // Cluster-level recorder handle: spans the engines can't see
    // (migrations on the wire, fault transitions) plus the epoch-cadence
    // telemetry sweep. `sample_all` seeds every counter track at t = 0.
    let mut crec = rec;
    for n in 0..ccfg.nodes {
        if is_cold[n] {
            crec.capacity(n, 0.0, "park");
        }
    }
    sample_all(&mut engines, 0.0, &granted_w);

    // Cluster-level queue. Scheduling order fixes the sequence numbers,
    // which fix exact-equal-timestamp ordering: all arrivals first, then
    // fault transitions, then power epochs, then capacity checks
    // (rescheduled epochs/checks draw ever higher sequence numbers, so a
    // fault coinciding with an epoch always resolves fault-first — the
    // epoch then sees the post-fault alive set, never granting watts to
    // a node that died at the same instant; a capacity check likewise
    // sees the instant's post-migration world).
    let mut q: EventQueue<ClusterEv> = EventQueue::new();
    for (i, r) in trace.requests.iter().enumerate() {
        q.schedule(r.arrival_s, ClusterEv::Arrive(i));
    }
    for (i, ev) in ccfg.faults.events.iter().enumerate() {
        q.schedule(ev.t_s, ClusterEv::Fault(i));
    }
    if arbiter.is_some() {
        q.schedule(ccfg.power_epoch_s, ClusterEv::PowerEpoch);
    }
    if let Some(cc) = &capacity {
        q.schedule(cc.check_epoch_s, ClusterEv::Capacity);
    }

    let total = trace.requests.len() as u64;
    let mut assignment = vec![0usize; ccfg.nodes];
    let mut states: Vec<NodeState> = Vec::with_capacity(ccfg.nodes);
    let mut rerouted: u64 = 0;
    let mut fault_events: usize = 0;
    // Reused across fault events: Engine::fail_into drains into this, so
    // node loss allocates nothing after the first fault (§Perf).
    let mut drain_buf: Vec<Request> = Vec::new();
    // Requests completed across the cluster, maintained incrementally —
    // completions only move inside Engine::step, so the pre-PR5 O(N)
    // per-event re-sum is not needed on the hot path.
    let mut done: u64 = 0;
    // Shed-policy ledger: permanently shed arrivals (terminal — they
    // count against the loop's exit condition), backoff re-offers
    // issued, and how many times work was deferred for lack of any
    // target. `done + shed_count` reaching `total` ends the run.
    let mut shed_count: u64 = 0;
    let mut shed_retries: u64 = 0;
    let mut deferred_arrivals: u64 = 0;
    // Capacity-controller ledger: completed boots, parks, and the
    // consecutive below-watermark check streak (the hysteresis counter).
    let mut provisions: u64 = 0;
    let mut parks: u64 = 0;
    let mut idle_checks: u32 = 0;
    // Disaggregation state: in-flight handoffs (`pending`, indexed by
    // `ClusterEv::Migrate`; relays re-target an entry in place), handoffs
    // with no routable target (`parked`, re-offered at the next
    // recovery), arrivals held while the cluster was dark (`deferred`),
    // the reused per-step migration drain buffer, and the run's ledger.
    let mut pending: Vec<PendingMigration> = Vec::new();
    let mut parked: Vec<usize> = Vec::new();
    let mut deferred: Vec<Request> = Vec::new();
    let mut mig_buf: Vec<MigratedStream> = Vec::new();
    let mut migration = MigrationReport::default();
    // Per-node slice of the same ledger (sends/deliveries/relays/
    // re-prefills) — the cluster report's attribution columns.
    let mut node_migration = vec![NodeMigration::default(); ccfg.nodes];

    let mut sel = S::new(ccfg.nodes);
    sel.refresh_all(&engines);

    while done + shed_count < total {
        let next_node = sel.next(&engines);
        // Cluster events win exact-time ties: an arrival at t must be
        // assigned before any node processes its own event at t (the order
        // a pre-scheduled replay would use).
        let take_cluster = match (q.peek_time(), next_node) {
            (Some(tc), Some((_, tn))) => tc <= tn,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            // Fully drained yet incomplete: only possible when the whole
            // cluster died for good with work deferred — nothing left to
            // wake it, so stop (conservation then shows up as incomplete
            // requests, not lost ones).
            (None, None) => break,
        };
        if take_cluster {
            let (t, ev) = q.pop().expect("peeked");
            // Fresh arrivals and shed-policy re-offers share one
            // admission path: normalize to (request index, attempt).
            let admission = match ev {
                ClusterEv::Arrive(i) => Some((i, 0u32)),
                ClusterEv::Retry(i, attempt) => Some((i, attempt)),
                _ => None,
            };
            if let Some((i, attempt)) = admission {
                // Overload gate: mean prefill backlog per routable node
                // against the class-aware depth (long prompts shed
                // first). No policy, no gate — the pre-elasticity path.
                let over_depth = match &shed {
                    Some(sc) => {
                        let (mut live, mut backlog) = (0usize, 0usize);
                        for (n, e) in engines.iter().enumerate() {
                            if routable[n] {
                                live += 1;
                                backlog += e.prefill_backlog();
                            }
                        }
                        let pressure = if live == 0 {
                            f64::INFINITY
                        } else {
                            backlog as f64 / live as f64
                        };
                        let interactive =
                            trace.requests[i].route_class() == RouteClass::ShortMedium;
                        pressure > sc.threshold_for(interactive)
                    }
                    None => false,
                };
                if over_depth {
                    let sc = shed.as_ref().expect("over_depth implies a shed policy");
                    let rid = trace.requests[i].id;
                    if attempt < sc.max_retries {
                        // Defer with backoff: the request re-enters
                        // through the Retry lane and faces the gate
                        // again with whatever capacity exists then.
                        shed_retries += 1;
                        crec.admission_retry(t, rid, attempt + 1);
                        q.schedule(
                            t + sc.backoff_for(attempt),
                            ClusterEv::Retry(i, attempt + 1),
                        );
                    } else {
                        // Out of retries: shed permanently. Terminal —
                        // conservation counts it next to `completed`.
                        shed_count += 1;
                        crec.shed(t, rid);
                    }
                } else {
                    snapshot_all(&engines, &routable, &granted_w, &mut states);
                    match pick_ingress(
                        lb.as_mut(),
                        t,
                        &trace.requests[i],
                        &states,
                        ingress,
                        &alive,
                    ) {
                        Some(node) => {
                            assert!(node < ccfg.nodes, "balancer returned node {node}");
                            assert!(alive[node], "balancer routed to dead node {node}");
                            engines[node].inject(t, trace.requests[i].clone());
                            assignment[node] += 1;
                            sel.update(node, &engines);
                        }
                        // Whole cluster dark: hold the request, re-offer
                        // it at the next recovery.
                        None => {
                            deferred_arrivals += 1;
                            deferred.push(trace.requests[i].clone());
                        }
                    }
                }
            } else {
                match ev {
                    ClusterEv::Arrive(..) | ClusterEv::Retry(..) => {
                        unreachable!("admission events handled above")
                    }
                    ClusterEv::PowerEpoch => {
                        if let Some(a) = arbiter.as_mut() {
                            a.epoch(t, &mut engines, &alive);
                            if let Some(g) = a.latest_grants() {
                                granted_w.copy_from_slice(g);
                            }
                            sample_all(&mut engines, t, &granted_w);
                            q.schedule_in(ccfg.power_epoch_s, ClusterEv::PowerEpoch);
                            sel.refresh_all(&engines);
                        }
                    }
                    ClusterEv::Fault(i) => {
                        let fev = &ccfg.faults.events[i];
                        fault_events += 1;
                        match fev.kind {
                            FaultKind::Down => {
                                draining[fev.node] = false;
                                routable[fev.node] = false;
                                if is_cold[fev.node] {
                                    // The capacity controller already
                                    // drained and powered this node off;
                                    // the fault just makes the loss real.
                                    // Meter its warm time and forget it
                                    // was warm — a pending boot then
                                    // no-ops (the boot handler checks
                                    // `is_cold`).
                                    if let Some(cc) = &capacity {
                                        warm_energy_j +=
                                            cc.warm_idle_w * (t - cold_since[fev.node]);
                                    }
                                    is_cold[fev.node] = false;
                                    alive[fev.node] = false;
                                    crec.fault(fev.node, t, false);
                                } else {
                                    alive[fev.node] = false;
                                    crec.fault(fev.node, t, false);
                                    debug_assert!(drain_buf.is_empty());
                                    engines[fev.node].fail_into(t, &mut drain_buf);
                                    assignment[fev.node] -= drain_buf.len();
                                    rerouted += drain_buf.len() as u64;
                                    sel.update(fev.node, &engines);
                                    // Re-split the budget over the
                                    // survivors right away (frees the dead
                                    // node's floor) so the re-routes below
                                    // see fresh grants.
                                    if let Some(a) = arbiter.as_mut() {
                                        a.rearbitrate(t, &mut engines, &alive);
                                        if let Some(g) = a.latest_grants() {
                                            granted_w.copy_from_slice(g);
                                        }
                                        sample_all(&mut engines, t, &granted_w);
                                        sel.refresh_all(&engines);
                                    }
                                    // Re-home every incomplete request
                                    // through the live balancer (states
                                    // re-snapshotted per request: earlier
                                    // re-routes shift the load the later
                                    // ones see).
                                    for req in drain_buf.drain(..) {
                                        snapshot_all(
                                            &engines, &routable, &granted_w, &mut states,
                                        );
                                        match pick_ingress(
                                            lb.as_mut(),
                                            t,
                                            &req,
                                            &states,
                                            ingress,
                                            &alive,
                                        ) {
                                            Some(node) => {
                                                assert!(
                                                    node < ccfg.nodes && alive[node],
                                                    "re-route picked dead node {node}"
                                                );
                                                engines[node].inject(t, req);
                                                assignment[node] += 1;
                                                sel.update(node, &engines);
                                            }
                                            None => {
                                                deferred_arrivals += 1;
                                                deferred.push(req);
                                            }
                                        }
                                    }
                                }
                            }
                            FaultKind::Up => {
                                alive[fev.node] = true;
                                routable[fev.node] = true;
                                draining[fev.node] = false;
                                crec.fault(fev.node, t, true);
                                engines[fev.node].recover(t);
                                sel.update(fev.node, &engines);
                                // `recover` cleared the node's clamp; under
                                // a cap that would let the cluster exceed
                                // its budget until the next epoch.
                                // Re-arbitrate at the rejoin instant (boost
                                // clocks have had zero seconds to draw
                                // anything yet).
                                if let Some(a) = arbiter.as_mut() {
                                    a.rearbitrate(t, &mut engines, &alive);
                                    if let Some(g) = a.latest_grants() {
                                        granted_w.copy_from_slice(g);
                                    }
                                    sample_all(&mut engines, t, &granted_w);
                                    sel.refresh_all(&engines);
                                }
                                // A node is back: re-offer everything held
                                // while the cluster was dark. Arrivals
                                // first (their sequence numbers predate the
                                // parked handoffs), then parked migrations.
                                for req in std::mem::take(&mut deferred) {
                                    snapshot_all(&engines, &routable, &granted_w, &mut states);
                                    match pick_ingress(
                                        lb.as_mut(),
                                        t,
                                        &req,
                                        &states,
                                        ingress,
                                        &alive,
                                    ) {
                                        Some(node) => {
                                            engines[node].inject(t, req);
                                            assignment[node] += 1;
                                            sel.update(node, &engines);
                                        }
                                        None => {
                                            deferred_arrivals += 1;
                                            deferred.push(req);
                                        }
                                    }
                                }
                                for idx in std::mem::take(&mut parked) {
                                    let from = pending[idx].from;
                                    if !alive[from] {
                                        // The KV died with the sender:
                                        // full re-prefill through ingress.
                                        let req = pending[idx].req.clone();
                                        rerouted += 1;
                                        snapshot_all(
                                            &engines, &routable, &granted_w, &mut states,
                                        );
                                        match pick_ingress(
                                            lb.as_mut(),
                                            t,
                                            &req,
                                            &states,
                                            ingress,
                                            &alive,
                                        ) {
                                            Some(node) => {
                                                crec.re_prefill(node, t, req.id);
                                                node_migration[node].re_prefills += 1;
                                                engines[node].inject(t, req);
                                                assignment[node] += 1;
                                                sel.update(node, &engines);
                                            }
                                            None => {
                                                deferred_arrivals += 1;
                                                deferred.push(req);
                                            }
                                        }
                                        continue;
                                    }
                                    snapshot_all(&engines, &routable, &granted_w, &mut states);
                                    match disagg::eco_route(&states, prefill_pool, tbt_target_s)
                                    {
                                        Some(nt) => {
                                            let bytes = link.kv_bytes(
                                                pending[idx].req.prompt_len as f64 + 1.0,
                                            );
                                            let j = link.transfer_j(bytes);
                                            engines[from].add_transfer_energy(j);
                                            engines[nt].add_transfer_energy(j);
                                            migration.kv_bytes += bytes;
                                            migration.transfer_j += 2.0 * j;
                                            let rid = pending[idx].req.id;
                                            if pending[idx].target == usize::MAX {
                                                migration.count += 1; // first send
                                                node_migration[from].sends += 1;
                                                if R::ENABLED {
                                                    let dt = link.transfer_s(bytes);
                                                    crec.migrate_send(
                                                        from,
                                                        nt,
                                                        t,
                                                        rid,
                                                        bytes,
                                                        t + dt,
                                                    );
                                                }
                                            } else {
                                                migration.relays += 1;
                                                node_migration[from].relays += 1;
                                                crec.migrate_relay(from, nt, t, rid);
                                            }
                                            pending[idx].target = nt;
                                            q.schedule(
                                                t + link.transfer_s(bytes),
                                                ClusterEv::Migrate(idx),
                                            );
                                        }
                                        None => parked.push(idx),
                                    }
                                }
                            }
                            FaultKind::Drain => {
                                // Spot-preemption notice: the node keeps
                                // serving everything it already owns but
                                // stops taking new work. The paired Down
                                // (scheduled by `preempt@`) makes the loss
                                // real later; by then the backlog has
                                // mostly drained instead of being yanked.
                                draining[fev.node] = true;
                                routable[fev.node] = false;
                                crec.capacity(fev.node, t, "drain");
                            }
                            FaultKind::Slow => {
                                // Straggler: the node keeps running,
                                // degraded. Clocks re-clamp immediately;
                                // nothing queues or unqueues, so the
                                // selector key is untouched.
                                engines[fev.node].degrade(t, fev.factor, fev.cap_mhz);
                                crec.capacity(fev.node, t, "slow");
                            }
                            FaultKind::Restore => {
                                engines[fev.node].restore_degrade(t);
                                crec.capacity(fev.node, t, "restore");
                            }
                            FaultKind::CtlNoise => {
                                // Control-plane degradation: clock writes
                                // start lagging/dropping/misstepping and
                                // telemetry quantizes. Routing, queues and
                                // the selector key are all untouched — only
                                // the actuation/sensing path gets noisy.
                                engines[fev.node].ctl_noise_on(
                                    fev.ctl_params[0],
                                    fev.ctl_params[1],
                                    fev.ctl_params[2],
                                );
                                crec.ctl(fev.node, t, "noise");
                            }
                            FaultKind::CtlQuiet => {
                                engines[fev.node].ctl_noise_off();
                                crec.ctl(fev.node, t, "quiet");
                            }
                            FaultKind::CtlBlackout => {
                                // Telemetry blackout: the policy's view of
                                // tail latency / pressure / power freezes at
                                // this instant and per-token feedback stops
                                // flowing. Ground-truth SLO accounting keeps
                                // recording throughout.
                                engines[fev.node].ctl_blackout_on();
                                crec.ctl(fev.node, t, "blackout");
                            }
                            FaultKind::CtlSense => {
                                engines[fev.node].ctl_blackout_off();
                                crec.ctl(fev.node, t, "sense");
                            }
                        }
                    }
                    ClusterEv::Capacity => {
                        let cc = capacity.expect("capacity event without a controller");
                        let (mut live, mut backlog) = (0usize, 0usize);
                        for (n, e) in engines.iter().enumerate() {
                            if routable[n] {
                                live += 1;
                                backlog += e.prefill_backlog();
                            }
                        }
                        let pressure = if live == 0 {
                            f64::INFINITY
                        } else {
                            backlog as f64 / live as f64
                        };
                        if pressure > cc.up_backlog {
                            idle_checks = 0;
                            // Scale up: boot the lowest-index cold node
                            // (determinism), one per check — the boot
                            // latency is the natural ramp limiter.
                            if let Some(n) =
                                (0..ccfg.nodes).find(|&n| is_cold[n] && !booting[n])
                            {
                                booting[n] = true;
                                crec.capacity(n, t, "boot");
                                q.schedule(t + cc.boot_s, ClusterEv::CapacityBoot(n));
                            }
                        } else if pressure < cc.down_backlog {
                            idle_checks += 1;
                            let alive_count = alive.iter().filter(|a| **a).count();
                            if idle_checks >= cc.down_idle_epochs && alive_count > cc.min_live
                            {
                                // Scale down: park the highest-index node
                                // that is verifiably idle (never a
                                // draining one — it's already leaving).
                                if let Some(n) = (0..ccfg.nodes).rev().find(|&n| {
                                    alive[n]
                                        && !draining[n]
                                        && engines[n].prefill_backlog() == 0
                                        && engines[n].active_streams() == 0
                                }) {
                                    idle_checks = 0;
                                    parks += 1;
                                    alive[n] = false;
                                    routable[n] = false;
                                    is_cold[n] = true;
                                    cold_since[n] = t;
                                    crec.capacity(n, t, "park");
                                    debug_assert!(drain_buf.is_empty());
                                    engines[n].fail_into(t, &mut drain_buf);
                                    assignment[n] -= drain_buf.len();
                                    rerouted += drain_buf.len() as u64;
                                    sel.update(n, &engines);
                                    if let Some(a) = arbiter.as_mut() {
                                        a.rearbitrate(t, &mut engines, &alive);
                                        if let Some(g) = a.latest_grants() {
                                            granted_w.copy_from_slice(g);
                                        }
                                        sample_all(&mut engines, t, &granted_w);
                                        sel.refresh_all(&engines);
                                    }
                                    // The park predicate requires an idle
                                    // node, but an arrival injected at this
                                    // exact instant could still be queued —
                                    // re-home it, never drop it.
                                    for req in drain_buf.drain(..) {
                                        snapshot_all(
                                            &engines, &routable, &granted_w, &mut states,
                                        );
                                        match pick_ingress(
                                            lb.as_mut(),
                                            t,
                                            &req,
                                            &states,
                                            ingress,
                                            &alive,
                                        ) {
                                            Some(node) => {
                                                engines[node].inject(t, req);
                                                assignment[node] += 1;
                                                sel.update(node, &engines);
                                            }
                                            None => {
                                                deferred_arrivals += 1;
                                                deferred.push(req);
                                            }
                                        }
                                    }
                                }
                            }
                        } else {
                            // Inside the hysteresis band: reset the streak
                            // so only a *sustained* lull parks capacity.
                            idle_checks = 0;
                        }
                        q.schedule_in(cc.check_epoch_s, ClusterEv::Capacity);
                    }
                    ClusterEv::CapacityBoot(n) => {
                        booting[n] = false;
                        // A fault may have downed the node mid-boot
                        // (`is_cold` cleared there); the provision then
                        // evaporates — the fault plan wins.
                        if is_cold[n] {
                            let cc = capacity.expect("boot event without a controller");
                            warm_energy_j += cc.warm_idle_w * (t - cold_since[n]);
                            is_cold[n] = false;
                            alive[n] = true;
                            routable[n] = true;
                            provisions += 1;
                            crec.capacity(n, t, "join");
                            engines[n].recover(t);
                            sel.update(n, &engines);
                            // Same contract as a fault recovery: re-clamp
                            // the rejoining node under the cap, then
                            // re-offer everything held for lack of a
                            // target.
                            if let Some(a) = arbiter.as_mut() {
                                a.rearbitrate(t, &mut engines, &alive);
                                if let Some(g) = a.latest_grants() {
                                    granted_w.copy_from_slice(g);
                                }
                                sample_all(&mut engines, t, &granted_w);
                                sel.refresh_all(&engines);
                            }
                            for req in std::mem::take(&mut deferred) {
                                snapshot_all(&engines, &routable, &granted_w, &mut states);
                                match pick_ingress(
                                    lb.as_mut(),
                                    t,
                                    &req,
                                    &states,
                                    ingress,
                                    &alive,
                                ) {
                                    Some(node) => {
                                        engines[node].inject(t, req);
                                        assignment[node] += 1;
                                        sel.update(node, &engines);
                                    }
                                    None => {
                                        deferred_arrivals += 1;
                                        deferred.push(req);
                                    }
                                }
                            }
                            for idx in std::mem::take(&mut parked) {
                                let from = pending[idx].from;
                                if !alive[from] {
                                    let req = pending[idx].req.clone();
                                    rerouted += 1;
                                    snapshot_all(&engines, &routable, &granted_w, &mut states);
                                    match pick_ingress(
                                        lb.as_mut(),
                                        t,
                                        &req,
                                        &states,
                                        ingress,
                                        &alive,
                                    ) {
                                        Some(node) => {
                                            crec.re_prefill(node, t, req.id);
                                            node_migration[node].re_prefills += 1;
                                            engines[node].inject(t, req);
                                            assignment[node] += 1;
                                            sel.update(node, &engines);
                                        }
                                        None => {
                                            deferred_arrivals += 1;
                                            deferred.push(req);
                                        }
                                    }
                                    continue;
                                }
                                snapshot_all(&engines, &routable, &granted_w, &mut states);
                                match disagg::eco_route(&states, prefill_pool, tbt_target_s) {
                                    Some(nt) => {
                                        let bytes = link.kv_bytes(
                                            pending[idx].req.prompt_len as f64 + 1.0,
                                        );
                                        let j = link.transfer_j(bytes);
                                        engines[from].add_transfer_energy(j);
                                        engines[nt].add_transfer_energy(j);
                                        migration.kv_bytes += bytes;
                                        migration.transfer_j += 2.0 * j;
                                        let rid = pending[idx].req.id;
                                        if pending[idx].target == usize::MAX {
                                            migration.count += 1; // first send
                                            node_migration[from].sends += 1;
                                            if R::ENABLED {
                                                let dt = link.transfer_s(bytes);
                                                crec.migrate_send(from, nt, t, rid, bytes, t + dt);
                                            }
                                        } else {
                                            migration.relays += 1;
                                            node_migration[from].relays += 1;
                                            crec.migrate_relay(from, nt, t, rid);
                                        }
                                        pending[idx].target = nt;
                                        q.schedule(
                                            t + link.transfer_s(bytes),
                                            ClusterEv::Migrate(idx),
                                        );
                                    }
                                    None => parked.push(idx),
                                }
                            }
                        }
                    }
                    ClusterEv::Migrate(idx) => {
                        let from = pending[idx].from;
                        let target = pending[idx].target;
                        if !alive[from] {
                            // Sender died while the KV was on the wire —
                            // the transfer never completed and the KV is
                            // gone. Full re-prefill through ingress.
                            let req = pending[idx].req.clone();
                            rerouted += 1;
                            snapshot_all(&engines, &routable, &granted_w, &mut states);
                            match pick_ingress(lb.as_mut(), t, &req, &states, ingress, &alive) {
                                Some(node) => {
                                    crec.re_prefill(node, t, req.id);
                                    node_migration[node].re_prefills += 1;
                                    engines[node].inject(t, req);
                                    assignment[node] += 1;
                                    sel.update(node, &engines);
                                }
                                None => {
                                    deferred_arrivals += 1;
                                    deferred.push(req);
                                }
                            }
                        } else if alive[target] {
                            engines[target].migrate_in(
                                t,
                                pending[idx].req.clone(),
                                pending[idx].prefill_done_s,
                            );
                            node_migration[target].deliveries += 1;
                            assignment[target] += 1;
                            sel.update(target, &engines);
                        } else {
                            // Target died while the KV was on the wire;
                            // the sender still holds it — relay to a fresh
                            // target, both ends paying the link again.
                            snapshot_all(&engines, &routable, &granted_w, &mut states);
                            match disagg::eco_route(&states, prefill_pool, tbt_target_s) {
                                Some(nt) => {
                                    let bytes =
                                        link.kv_bytes(pending[idx].req.prompt_len as f64 + 1.0);
                                    let j = link.transfer_j(bytes);
                                    engines[from].add_transfer_energy(j);
                                    engines[nt].add_transfer_energy(j);
                                    migration.kv_bytes += bytes;
                                    migration.transfer_j += 2.0 * j;
                                    migration.relays += 1;
                                    node_migration[from].relays += 1;
                                    crec.migrate_relay(from, nt, t, pending[idx].req.id);
                                    pending[idx].target = nt;
                                    q.schedule(
                                        t + link.transfer_s(bytes),
                                        ClusterEv::Migrate(idx),
                                    );
                                }
                                None => parked.push(idx),
                            }
                        }
                    }
                }
            }
        } else {
            let i = next_node.expect("node source exists").0;
            let before = engines[i].completed();
            engines[i].step();
            done += engines[i].completed() - before;
            // Prefill-pool nodes surface finished prefills here; route
            // each to a decode node and put its KV on the wire. Ownership
            // moves now (`assignment[i] -= 1`) and lands on the receiver
            // at delivery; in flight, the request is counted nowhere.
            if i < prefill_pool {
                engines[i].take_migrations(&mut mig_buf);
                for m in mig_buf.drain(..) {
                    snapshot_all(&engines, &routable, &granted_w, &mut states);
                    assignment[i] -= 1;
                    let idx = pending.len();
                    match disagg::eco_route(&states, prefill_pool, tbt_target_s) {
                        Some(target) => {
                            let bytes = link.kv_bytes(m.req.prompt_len as f64 + 1.0);
                            let j = link.transfer_j(bytes);
                            engines[i].add_transfer_energy(j);
                            engines[target].add_transfer_energy(j);
                            migration.count += 1;
                            migration.kv_bytes += bytes;
                            migration.transfer_j += 2.0 * j;
                            node_migration[i].sends += 1;
                            if R::ENABLED {
                                // KV hits the wire at prefill completion.
                                let t0 = m.prefill_done_s;
                                let t1 = t0 + link.transfer_s(bytes);
                                crec.migrate_send(i, target, t0, m.req.id, bytes, t1);
                            }
                            pending.push(PendingMigration {
                                req: m.req,
                                prefill_done_s: m.prefill_done_s,
                                from: i,
                                target,
                            });
                            q.schedule(
                                m.prefill_done_s + link.transfer_s(bytes),
                                ClusterEv::Migrate(idx),
                            );
                        }
                        // Unreachable while the sender lives (eco_route
                        // spills into the prefill pool), but kept total:
                        // park the handoff until the next recovery.
                        None => {
                            pending.push(PendingMigration {
                                req: m.req,
                                prefill_done_s: m.prefill_done_s,
                                from: i,
                                target: usize::MAX,
                            });
                            parked.push(idx);
                        }
                    }
                }
            }
            sel.update(i, &engines);
        }
    }

    // Global end: every node integrates idle energy to the same horizon.
    let end_t = engines
        .iter()
        .map(|e| e.now())
        .fold(trace.duration_s, f64::max);
    let wasted_tokens: u64 = engines.iter().map(|e| e.wasted_tokens()).sum();
    let per_node: Vec<RunResult> = engines.iter_mut().map(|e| e.finalize(end_t)).collect();

    // Nodes still parked at the end draw warm idle to the very horizon —
    // a warm pool is not free, and the energy integral must say so.
    if let Some(cc) = &capacity {
        for n in 0..ccfg.nodes {
            if is_cold[n] {
                warm_energy_j += cc.warm_idle_w * (end_t - cold_since[n]);
            }
        }
    }

    // Whole-run latency distributions: the per-node trackers all use the
    // same latency bucketing, so their histograms merge exactly.
    let mut ttft_hist = Histogram::latency();
    let mut tbt_hist = Histogram::latency();
    for r in &per_node {
        ttft_hist.merge(&r.slo.ttft_hist);
        tbt_hist.merge(&r.slo.tbt_hist);
    }

    let events_processed: u64 = per_node.iter().map(|r| r.events_processed).sum();
    // `+ 0.0` when no warm pool ever existed — bitwise identity, so the
    // off-path energy integral is unchanged.
    let total_energy_j = per_node.iter().map(|r| r.total_energy_j).sum::<f64>() + warm_energy_j;
    let generated_tokens = per_node.iter().map(|r| r.generated_tokens).sum();
    let completed: u64 = per_node.iter().map(|r| r.completed).sum();
    let ttft_passes: u64 = per_node.iter().map(|r| r.slo.ttft_passes()).sum();
    let tbt_passes: u64 = per_node.iter().map(|r| r.slo.tbt_passes()).sum();
    let tbt_eligible: u64 = per_node.iter().map(|r| r.slo.tbt_eligible()).sum();
    let supervisor_fallbacks: u64 = per_node.iter().map(|r| r.supervisor_fallbacks).sum();
    let supervisor_reengages: u64 = per_node.iter().map(|r| r.supervisor_reengages).sum();
    let ctl_dropped_writes: u64 = per_node.iter().map(|r| r.ctl_dropped_writes).sum();
    let ctl_delayed_writes: u64 = per_node.iter().map(|r| r.ctl_delayed_writes).sum();
    let ctl_missteps: u64 = per_node.iter().map(|r| r.ctl_missteps).sum();
    let ctl_suppressed_samples: u64 = per_node.iter().map(|r| r.ctl_suppressed_samples).sum();
    ClusterResult {
        total_energy_j,
        generated_tokens,
        completed,
        ttft_pass_rate: if completed == 0 {
            1.0
        } else {
            ttft_passes as f64 / completed as f64
        },
        tbt_pass_rate: if tbt_eligible == 0 {
            1.0
        } else {
            tbt_passes as f64 / tbt_eligible as f64
        },
        per_node,
        assignment,
        lb: ccfg.lb,
        power: arbiter.map(|a| PowerReport {
            cap_w: a.cap_w,
            epoch_s: a.epoch_s,
            peak_measured_w: a.peak_measured_w(),
            had_infeasible_epoch: a.had_infeasible_epoch(),
            epochs: a.epochs,
        }),
        rerouted,
        wasted_tokens,
        fault_events,
        events_processed,
        shed: shed_count,
        shed_retries,
        deferred_arrivals,
        warm_energy_j,
        capacity_provisions: provisions,
        capacity_parks: parks,
        straggler_nodes: ccfg.faults.straggler_nodes(),
        supervisor_fallbacks,
        supervisor_reengages,
        ctl_dropped_writes,
        ctl_delayed_writes,
        ctl_missteps,
        ctl_suppressed_samples,
        migration: (prefill_pool > 0).then_some(migration),
        node_migration: if prefill_pool > 0 {
            node_migration
        } else {
            Vec::new()
        },
        ttft_hist,
        tbt_hist,
    }
}

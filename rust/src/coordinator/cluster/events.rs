//! The cluster event loop: N node engines interleaved on one virtual
//! clock.
//!
//! The loop merges three deterministic event sources:
//! * the arrival stream (the trace, pre-scheduled into a cluster queue),
//! * the power arbiter's control epochs,
//! * each node engine's own pending events.
//!
//! At every iteration the earliest source wins; ties go cluster-first and
//! then lowest-node-first (`sim::earliest`), so the whole simulation is a
//! pure function of (trace, config, seed). An arriving request is assigned
//! by the balancer from a *live* telemetry snapshot and injected into the
//! chosen engine through the priority event lane, which makes a 1-node
//! cluster replay bit-identical to a plain [`run`](crate::coordinator::run).

use crate::coordinator::cluster::balancer::{self, NodeState};
use crate::coordinator::cluster::power::PowerArbiter;
use crate::coordinator::cluster::{ClusterConfig, ClusterResult, PowerReport};
use crate::coordinator::engine::{Engine, RunOptions, RunResult};
use crate::sim::{self, EventQueue};
use crate::workload::request::Trace;

#[derive(Debug, Clone, Copy)]
enum ClusterEv {
    /// Index into the trace's request list.
    Arrive(usize),
    PowerEpoch,
}

fn snapshot(e: &Engine<'_>) -> NodeState {
    NodeState {
        assigned: e.assigned(),
        prefill_backlog: e.prefill_backlog(),
        outstanding_prompt_tokens: e.outstanding_prompt_tokens(),
        active_streams: e.active_streams(),
        tbt_tail_p95_s: e.tbt_tail_p95(),
    }
}

/// Run `trace` across the cluster as one interleaved event-driven
/// simulation.
pub fn run_cluster(ccfg: &ClusterConfig, trace: &Trace, opts: &RunOptions) -> ClusterResult {
    assert!(ccfg.nodes >= 1, "cluster needs at least one node");
    // Telemetry-driven balancers read the per-node TBT tail, so keep it
    // live for them; front-end-only policies (rr, leastwork) never look,
    // so skip the per-token cost. Everything else passes through.
    let node_opts = RunOptions {
        track_tbt_tail: opts.track_tbt_tail || !ccfg.lb.frontend_only(),
        ..opts.clone()
    };
    let node_cfgs: Vec<_> = (0..ccfg.nodes)
        .map(|n| {
            let mut cfg = ccfg.node.clone();
            cfg.seed = ccfg.node.seed.wrapping_add(n as u64);
            cfg
        })
        .collect();
    let mut engines: Vec<Engine<'_>> = node_cfgs
        .iter()
        .enumerate()
        .map(|(n, cfg)| {
            Engine::new(
                cfg,
                &node_opts,
                format!("{}::node{n}", trace.name),
                trace.duration_s,
            )
        })
        .collect();
    for e in engines.iter_mut() {
        e.begin();
    }

    let mut lb = balancer::build(ccfg.lb, ccfg.nodes, ccfg.node.slo.tbt_p95_s);
    let mut arbiter = ccfg
        .power_cap_w
        .map(|cap| PowerArbiter::new(cap, ccfg.power_epoch_s, ccfg.nodes));
    if let Some(a) = arbiter.as_mut() {
        a.apply_initial(&mut engines);
    }

    // Cluster-level queue: arrivals first (priority-free here — they get
    // the lowest sequence numbers by being scheduled before the epochs).
    let mut q: EventQueue<ClusterEv> = EventQueue::new();
    for (i, r) in trace.requests.iter().enumerate() {
        q.schedule(r.arrival_s, ClusterEv::Arrive(i));
    }
    if arbiter.is_some() {
        q.schedule(ccfg.power_epoch_s, ClusterEv::PowerEpoch);
    }

    let total = trace.requests.len() as u64;
    let mut assignment = vec![0usize; ccfg.nodes];
    let mut node_times: Vec<Option<f64>> = vec![None; ccfg.nodes];
    let mut states: Vec<NodeState> = Vec::with_capacity(ccfg.nodes);

    loop {
        let done: u64 = engines.iter().map(|e| e.completed()).sum();
        if done >= total {
            break;
        }
        for (i, e) in engines.iter().enumerate() {
            node_times[i] = e.peek_time();
        }
        let next_node = sim::earliest(&node_times);
        // Cluster events win exact-time ties: an arrival at t must be
        // assigned before any node processes its own event at t (the order
        // a pre-scheduled replay would use).
        let take_cluster = match (q.peek_time(), next_node.map(|i| node_times[i].unwrap())) {
            (Some(tc), Some(tn)) => tc <= tn,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => break, // fully drained yet incomplete: impossible
        };
        if take_cluster {
            let (t, ev) = q.pop().expect("peeked");
            match ev {
                ClusterEv::Arrive(i) => {
                    states.clear();
                    states.extend(engines.iter().map(snapshot));
                    let node = lb.assign(t, &trace.requests[i], &states);
                    assert!(node < ccfg.nodes, "balancer returned node {node}");
                    engines[node].inject(t, trace.requests[i].clone());
                    assignment[node] += 1;
                }
                ClusterEv::PowerEpoch => {
                    if let Some(a) = arbiter.as_mut() {
                        a.epoch(t, &mut engines);
                        q.schedule_in(ccfg.power_epoch_s, ClusterEv::PowerEpoch);
                    }
                }
            }
        } else {
            engines[next_node.expect("node source exists")].step();
        }
    }

    // Global end: every node integrates idle energy to the same horizon.
    let end_t = engines
        .iter()
        .map(|e| e.now())
        .fold(trace.duration_s, f64::max);
    let per_node: Vec<RunResult> = engines.iter_mut().map(|e| e.finalize(end_t)).collect();

    let total_energy_j = per_node.iter().map(|r| r.total_energy_j).sum();
    let generated_tokens = per_node.iter().map(|r| r.generated_tokens).sum();
    let completed: u64 = per_node.iter().map(|r| r.completed).sum();
    let ttft_passes: u64 = per_node.iter().map(|r| r.slo.ttft_passes()).sum();
    let tbt_passes: u64 = per_node.iter().map(|r| r.slo.tbt_passes()).sum();
    let tbt_eligible: u64 = per_node.iter().map(|r| r.slo.tbt_eligible()).sum();
    ClusterResult {
        total_energy_j,
        generated_tokens,
        completed,
        ttft_pass_rate: if completed == 0 {
            1.0
        } else {
            ttft_passes as f64 / completed as f64
        },
        tbt_pass_rate: if tbt_eligible == 0 {
            1.0
        } else {
            tbt_passes as f64 / tbt_eligible as f64
        },
        per_node,
        assignment,
        lb: ccfg.lb,
        power: arbiter.map(|a| PowerReport {
            cap_w: a.cap_w,
            epoch_s: a.epoch_s,
            peak_measured_w: a.peak_measured_w(),
            had_infeasible_epoch: a.had_infeasible_epoch(),
            epochs: a.epochs,
        }),
    }
}

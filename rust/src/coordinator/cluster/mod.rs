//! Cluster extension (paper §7, future work): GreenLLM's node-level
//! control replicated across multiple DGX nodes behind an *online* load
//! balancer and a cluster-wide power-budget arbiter.
//!
//! Unlike the original post-hoc aggregator (which pre-assigned the trace
//! and replayed nodes independently), the cluster is now one event-driven
//! simulation: every node engine steps on a shared virtual clock
//! (`events`), the ingress balancer decides from live telemetry — queue
//! depths, outstanding prefill tokens, per-node decode TBT tails
//! (`balancer`) — and a power arbiter re-splits a watt cap across nodes
//! every control epoch by clamping each node's DVFS ladder (`power`).
//!
//! Contracts:
//! * Balancers implement [`balancer::Balancer`]; register in
//!   [`balancer::build`] + add an [`LbPolicy`] variant.
//! * The arbiter owns watt→clock conversion; engines only ever see a
//!   ladder-frequency ceiling, policies keep requesting clocks freely.
//! * Everything stays deterministic: a 1-node cluster is bit-identical to
//!   a plain [`run`](crate::coordinator::run) (tested).

pub mod balancer;
pub mod events;
pub mod power;

pub use balancer::{Balancer, LbPolicy, NodeState};
pub use events::run_cluster;
pub use power::{PowerArbiter, PowerEpoch};

use crate::config::Config;
use crate::coordinator::engine::RunResult;
use crate::workload::request::Trace;

/// Cluster deployment: node count, ingress policy, per-node config, and
/// the optional cluster-wide power budget.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub nodes: usize,
    pub lb: LbPolicy,
    /// Per-node serving config (method, pools, SLOs...).
    pub node: Config,
    /// Cluster-wide power budget in watts (`None` = uncapped).
    pub power_cap_w: Option<f64>,
    /// Power-arbiter control epoch, seconds.
    pub power_epoch_s: f64,
}

impl ClusterConfig {
    pub fn new(nodes: usize, lb: LbPolicy, node: Config) -> ClusterConfig {
        ClusterConfig {
            nodes,
            lb,
            node,
            power_cap_w: None,
            power_epoch_s: 1.0,
        }
    }

    pub fn with_power_cap(mut self, cap_w: f64, epoch_s: f64) -> ClusterConfig {
        self.power_cap_w = Some(cap_w);
        self.power_epoch_s = epoch_s;
        self
    }
}

/// Power-arbitration summary attached to a capped cluster run.
#[derive(Debug, Clone)]
pub struct PowerReport {
    pub cap_w: f64,
    pub epoch_s: f64,
    /// Highest measured cluster draw across epochs, watts.
    pub peak_measured_w: f64,
    /// Any epoch where a node's share fell below the ladder-floor power.
    pub had_infeasible_epoch: bool,
    pub epochs: Vec<PowerEpoch>,
}

#[derive(Debug)]
pub struct ClusterResult {
    pub per_node: Vec<RunResult>,
    pub total_energy_j: f64,
    pub generated_tokens: u64,
    pub completed: u64,
    pub ttft_pass_rate: f64,
    pub tbt_pass_rate: f64,
    /// Requests assigned per node (balance diagnostic).
    pub assignment: Vec<usize>,
    pub lb: LbPolicy,
    /// Present iff the run had a power cap.
    pub power: Option<PowerReport>,
}

impl ClusterResult {
    pub fn energy_per_token_j(&self) -> f64 {
        self.total_energy_j / self.generated_tokens.max(1) as f64
    }

    /// Max/min node request share — 1.0 is perfectly balanced. A starved
    /// node (zero requests while others got some) is reported honestly as
    /// `f64::INFINITY`, not masked by a fake denominator; pair with
    /// [`ClusterResult::starved_nodes`] for the count.
    pub fn balance_ratio(&self) -> f64 {
        let max = self.assignment.iter().max().copied().unwrap_or(0) as f64;
        let min = self.assignment.iter().min().copied().unwrap_or(0) as f64;
        if min == 0.0 {
            return if max == 0.0 { 1.0 } else { f64::INFINITY };
        }
        max / min
    }

    /// Nodes that received zero requests.
    pub fn starved_nodes(&self) -> usize {
        self.assignment.iter().filter(|&&c| c == 0).count()
    }

    /// Human-readable balance figure (shared by the CLI and reports).
    pub fn balance_label(&self) -> String {
        balance_label(self.balance_ratio(), self.starved_nodes())
    }
}

/// Render a balance ratio for display: starvation is shown as an explicit
/// starved-node count instead of a meaningless infinite ratio.
pub fn balance_label(ratio: f64, starved: usize) -> String {
    if ratio.is_infinite() {
        format!("starved:{starved}")
    } else {
        format!("{ratio:.2}")
    }
}

/// Pre-assign each request to a node (returns node index per request).
///
/// Only meaningful for front-end-only policies
/// ([`LbPolicy::frontend_only`]): telemetry-driven policies see empty node
/// states here and degrade to their no-information behavior. The live
/// cluster path ([`run_cluster`]) is the real thing — this stays as a
/// cheap offline preview of ingress decisions.
pub fn assign(trace: &Trace, nodes: usize, lb: LbPolicy) -> Vec<usize> {
    assert!(nodes >= 1);
    let mut b = balancer::build(lb, nodes, 0.1);
    let states = vec![NodeState::default(); nodes];
    trace
        .requests
        .iter()
        .map(|r| b.assign(r.arrival_s, r, &states))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::coordinator::engine::{run, RunOptions};
    use crate::workload::alibaba::{generate, ChatParams};

    fn cluster(nodes: usize, lb: LbPolicy, method: Method) -> ClusterConfig {
        ClusterConfig::new(
            nodes,
            lb,
            Config {
                method,
                seed: 5,
                ..Config::default()
            },
        )
    }

    #[test]
    fn round_robin_is_balanced() {
        let trace = generate(&ChatParams::new(8.0, 60.0), 1);
        let a = assign(&trace, 4, LbPolicy::RoundRobin);
        let mut counts = [0usize; 4];
        for &n in &a {
            counts[n] += 1;
        }
        assert!(counts.iter().max().unwrap() - counts.iter().min().unwrap() <= 1);
    }

    #[test]
    fn least_work_balances_tokens_not_requests() {
        let trace = generate(&ChatParams::new(8.0, 120.0), 1);
        let a = assign(&trace, 2, LbPolicy::LeastPromptWork);
        let mut toks = [0f64; 2];
        for (r, &n) in trace.requests.iter().zip(&a) {
            toks[n] += r.prompt_len as f64;
        }
        let ratio = toks[0].max(toks[1]) / toks[0].min(toks[1]);
        assert!(ratio < 1.25, "token imbalance {ratio}");
    }

    #[test]
    fn cluster_conserves_requests_and_tokens() {
        let trace = generate(&ChatParams::new(16.0, 60.0), 2);
        let r = run_cluster(
            &cluster(2, LbPolicy::LeastPromptWork, Method::GreenLlm),
            &trace,
            &RunOptions::default(),
        );
        assert_eq!(r.completed as usize, trace.requests.len());
        let expect: u64 = trace.requests.iter().map(|q| q.output_len as u64).sum();
        assert_eq!(r.generated_tokens, expect);
        assert_eq!(r.per_node.len(), 2);
        assert_eq!(r.assignment.iter().sum::<usize>(), trace.requests.len());
    }

    #[test]
    fn greenllm_savings_hold_at_cluster_scale() {
        // 2 nodes at 2× the single-node load: savings comparable to the
        // single-node 5 QPS case (the paper's scaling claim).
        let trace = generate(&ChatParams::new(10.0, 90.0), 3);
        let nv = run_cluster(
            &cluster(2, LbPolicy::JoinShortestQueue, Method::DefaultNv),
            &trace,
            &RunOptions::default(),
        );
        let green = run_cluster(
            &cluster(2, LbPolicy::JoinShortestQueue, Method::GreenLlm),
            &trace,
            &RunOptions::default(),
        );
        let saving = 1.0 - green.total_energy_j / nv.total_energy_j;
        assert!(saving > 0.15, "cluster saving {saving:.3}");
        assert!(green.ttft_pass_rate > 0.9);
        assert!(green.tbt_pass_rate > 0.9);
    }

    #[test]
    fn single_node_cluster_matches_plain_run() {
        let trace = generate(&ChatParams::new(4.0, 60.0), 7);
        let ccfg = cluster(1, LbPolicy::RoundRobin, Method::GreenLlm);
        let c = run_cluster(&ccfg, &trace, &RunOptions::default());
        let plain = run(
            &Config {
                method: Method::GreenLlm,
                seed: 5,
                ..Config::default()
            },
            &trace,
            &RunOptions::default(),
        );
        assert_eq!(c.total_energy_j.to_bits(), plain.total_energy_j.to_bits());
    }

    #[test]
    fn starved_node_reported_as_infinite_imbalance() {
        // 2 requests on a 4-node round-robin leaves nodes 2 and 3 starved.
        let mut trace = generate(&ChatParams::new(8.0, 60.0), 1);
        trace.requests.truncate(2);
        let r = run_cluster(
            &cluster(4, LbPolicy::RoundRobin, Method::DefaultNv),
            &trace,
            &RunOptions::default(),
        );
        assert_eq!(r.starved_nodes(), 2);
        assert!(r.balance_ratio().is_infinite());
    }
}

//! Cluster extension (paper §7, future work): GreenLLM's node-level
//! control replicated across multiple DGX nodes behind an *online* load
//! balancer and a cluster-wide power-budget arbiter.
//!
//! Unlike the original post-hoc aggregator (which pre-assigned the trace
//! and replayed nodes independently), the cluster is now one event-driven
//! simulation: every node engine steps on a shared virtual clock
//! (`events`), the ingress balancer decides from live telemetry — queue
//! depths, outstanding prefill tokens, per-node decode TBT tails
//! (`balancer`) — and a power arbiter re-splits a watt cap across nodes
//! every control epoch by clamping each node's DVFS ladder (`power`).
//!
//! Chaos & heterogeneity (the fleet-realism layer):
//! * [`faults::FaultPlan`] injects node-loss/recovery events into the
//!   shared clock; a downed node's queued and in-flight requests are
//!   drained and re-routed through the live balancer, recovered nodes
//!   rejoin with cold telemetry, and request/token conservation holds
//!   throughout (rolled-back partial work is reported as waste).
//! * [`NodeSpec`] presets give each node its own pool shape, power-model
//!   scale and clock ceiling, so balancers and the arbiter see genuinely
//!   asymmetric capacity.
//! * [`power::ArbiterStrategy`] selects how watt headroom is split:
//!   demand-proportional (default) or SLO-pressure (TBT-tail weighted);
//!   the `powergrant` balancer closes the loop by routing on live grants.
//!
//! Disaggregation (`disagg`, DualScale/VoltanaLLM style): an optional
//! prefill/decode pool split. Arrivals land on the prefill pool only;
//! each finished prefill *migrates* — a first-class cluster event with a
//! KV-transfer cost model — to a decode node picked by an EcoRoute-style
//! router over live decode telemetry. Each pool can run its own DVFS
//! method against its own SLO. With no [`DisaggConfig`] every
//! disaggregation path is dormant and the loop is bit-exact with the
//! colocated event loop.
//!
//! Contracts:
//! * Balancers implement [`balancer::Balancer`]; register in
//!   [`balancer::build`] + add an [`LbPolicy`] variant. A balancer
//!   returns `None` (defer) when every candidate node is down — it must
//!   never panic on transient all-dead windows.
//! * The arbiter owns watt→clock conversion; engines only ever see a
//!   ladder-frequency ceiling, policies keep requesting clocks freely.
//! * Everything stays deterministic: a 1-node cluster is bit-identical to
//!   a plain [`run`](crate::coordinator::run), an empty [`FaultPlan`]
//!   is bit-identical to no chaos layer at all, and a disabled
//!   [`DisaggConfig`] is bit-identical to the colocated loop (all
//!   tested).

pub mod balancer;
pub mod capacity;
pub mod disagg;
pub mod events;
pub mod faults;
pub mod power;

pub use balancer::{Balancer, LbPolicy, NodeState};
pub use capacity::{CapacityConfig, ShedConfig};
pub use disagg::{DisaggConfig, KvLinkModel, MigrationReport, NodeMigration, PoolRatio};
pub use events::{run_cluster, run_cluster_recorded};
pub use faults::{FaultEvent, FaultKind, FaultPlan, FaultSpec};
pub use power::{ArbiterStrategy, PowerArbiter, PowerEpoch};

use crate::config::{Config, PoolConfig};
use crate::coordinator::engine::RunResult;
use crate::metrics::Histogram;
use crate::workload::request::Trace;

/// Hardware/pool shape of one node — the heterogeneity unit. Presets
/// model GPU generations and SKU cuts on top of the A100 baseline:
///
/// | preset   | pools               | power × | clock cap | models      |
/// |----------|---------------------|---------|-----------|-------------|
/// | `dgx`    | 2×2 pre + 4×1 dec   | 1.00    | 1410 MHz  | analytic    |
/// | `half`   | 1×2 pre + 2×1 dec   | 1.00    | 1410 MHz  | analytic    |
/// | `big`    | 3×2 pre + 6×1 dec   | 1.00    | 1410 MHz  | analytic    |
/// | `eff`    | 2×2 pre + 4×1 dec   | 0.70    | 1410 MHz  | analytic    |
/// | `legacy` | 2×2 pre + 4×1 dec   | 1.25    | 1200 MHz  | analytic    |
/// | `a100`   | 2×2 pre + 4×1 dec   | 1.00    | 1410 MHz  | calibrated  |
/// | `h100`   | 2×2 pre + 4×1 dec   | 1.00    | 1980 MHz  | calibrated  |
///
/// The calibrated presets swap in the fitted latency/power curves of
/// [`crate::gpu::calibrate`] (cited sample tables) and the part's own
/// frequency ladder; the analytic presets keep the seed models.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    /// Preset name (stable label for reports).
    pub name: String,
    /// Worker-pool shape of the node.
    pub pools: PoolConfig,
    /// Power-envelope multiplier (see [`crate::gpu::power::PowerModel::scaled`]).
    pub power_scale: f64,
    /// Application-clock ceiling in MHz (on the node's ladder grid).
    pub max_clock_mhz: u32,
    /// Calibrated part key (`gpu::calibrate` zoo); empty = analytic
    /// models.
    pub part: String,
}

impl NodeSpec {
    /// The default DGX-A100 node (identical to `Config::default()` pools).
    pub fn dgx() -> NodeSpec {
        NodeSpec {
            name: "dgx".into(),
            pools: PoolConfig::default(),
            power_scale: 1.0,
            max_clock_mhz: 1410,
            part: String::new(),
        }
    }

    /// A half node: 1×2-GPU prefill + 2×1-GPU decode.
    pub fn half() -> NodeSpec {
        NodeSpec {
            name: "half".into(),
            pools: PoolConfig {
                prefill_workers: 1,
                decode_workers: 2,
                ..PoolConfig::default()
            },
            power_scale: 1.0,
            max_clock_mhz: 1410,
            part: String::new(),
        }
    }

    /// An oversized node: 3×2-GPU prefill + 6×1-GPU decode.
    pub fn big() -> NodeSpec {
        NodeSpec {
            name: "big".into(),
            pools: PoolConfig {
                prefill_workers: 3,
                decode_workers: 6,
                ..PoolConfig::default()
            },
            power_scale: 1.0,
            max_clock_mhz: 1410,
            part: String::new(),
        }
    }

    /// An efficiency-binned next-gen node: A100 envelope × 0.7.
    pub fn eff() -> NodeSpec {
        NodeSpec {
            name: "eff".into(),
            pools: PoolConfig::default(),
            power_scale: 0.7,
            max_clock_mhz: 1410,
            part: String::new(),
        }
    }

    /// An older-generation node: hotter (× 1.25) and capped at 1200 MHz.
    pub fn legacy() -> NodeSpec {
        NodeSpec {
            name: "legacy".into(),
            pools: PoolConfig::default(),
            power_scale: 1.25,
            max_clock_mhz: 1200,
            part: String::new(),
        }
    }

    /// A *calibrated* A100-SXM4 node: fitted latency/power curves from
    /// the cited sample tables (`gpu::calibrate`), stock DGX pools.
    pub fn a100() -> NodeSpec {
        NodeSpec {
            name: "a100".into(),
            pools: PoolConfig::default(),
            power_scale: 1.0,
            max_clock_mhz: 1410,
            part: "a100".into(),
        }
    }

    /// A *calibrated* H100-SXM5 node: fitted curves, 210–1980 MHz
    /// ladder, HBM3 bandwidth.
    pub fn h100() -> NodeSpec {
        NodeSpec {
            name: "h100".into(),
            pools: PoolConfig::default(),
            power_scale: 1.0,
            max_clock_mhz: 1980,
            part: "h100".into(),
        }
    }

    /// Look up a preset by name. `a100`/`h100` are the calibrated-zoo
    /// nodes; `dgx`/`default` keep the analytic seed models.
    pub fn parse(s: &str) -> Option<NodeSpec> {
        match s.trim().to_ascii_lowercase().as_str() {
            "dgx" | "default" => Some(NodeSpec::dgx()),
            "half" => Some(NodeSpec::half()),
            "big" => Some(NodeSpec::big()),
            "eff" | "efficient" => Some(NodeSpec::eff()),
            "legacy" | "old" => Some(NodeSpec::legacy()),
            "a100" => Some(NodeSpec::a100()),
            "h100" | "hopper" => Some(NodeSpec::h100()),
            _ => None,
        }
    }

    /// Parse a node-shape list: preset names separated by `,` or `+`
    /// (the matrix CLI uses `+` inside its comma-separated axis).
    /// `"uniform"` (or empty) is the homogeneous cluster: an empty spec
    /// list, meaning every node keeps the base `Config` untouched.
    pub fn parse_list(s: &str) -> Result<Vec<NodeSpec>, String> {
        let s = s.trim();
        if s.is_empty() || s.eq_ignore_ascii_case("uniform") {
            return Ok(Vec::new());
        }
        s.split(|c| c == ',' || c == '+')
            .map(|tok| {
                NodeSpec::parse(tok).ok_or_else(|| format!("unknown node spec {tok:?}"))
            })
            .collect()
    }

    /// Stamp this spec onto a node's serving config.
    pub fn apply(&self, cfg: &mut Config) {
        cfg.pools = self.pools.clone();
        cfg.gpu.power_scale = self.power_scale;
        cfg.gpu.max_clock_mhz = self.max_clock_mhz;
        cfg.gpu.part = self.part.clone();
    }
}

/// Cluster deployment: node count, ingress policy, per-node config, the
/// optional cluster-wide power budget, per-node heterogeneity specs and
/// the fault schedule.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of simulated nodes.
    pub nodes: usize,
    /// Ingress load-balancing policy.
    pub lb: LbPolicy,
    /// Per-node serving config (method, pools, SLOs...).
    pub node: Config,
    /// Per-node shape overrides, cycled over the node count (node `i`
    /// gets `node_specs[i % len]`). Empty = homogeneous `node` config.
    pub node_specs: Vec<NodeSpec>,
    /// Cluster-wide power budget in watts (`None` = uncapped).
    pub power_cap_w: Option<f64>,
    /// Power-arbiter control epoch, seconds.
    pub power_epoch_s: f64,
    /// How the arbiter splits watt headroom across nodes.
    pub arbiter: ArbiterStrategy,
    /// Node-loss/recovery schedule (empty = no chaos, bit-identical to
    /// the pre-chaos event loop).
    pub faults: FaultPlan,
    /// Prefill:decode pool split. Sizes the `phase` balancer's long pool
    /// always, and the disaggregated prefill pool when `disagg` is set.
    /// The default `1:3` reproduces the historical quarter split.
    pub pool_ratio: PoolRatio,
    /// Prefill/decode disaggregation (`None` = colocated, bit-identical
    /// to the pre-disagg event loop). Requires `nodes >= 2` to actually
    /// split; a 1-node cluster degrades to colocated.
    pub disagg: Option<DisaggConfig>,
    /// Endogenous autoscaler (`None` = fixed fleet, bit-identical to the
    /// pre-capacity event loop).
    pub capacity: Option<CapacityConfig>,
    /// Graceful overload shedding at ingress (`None` = admit everything,
    /// bit-identical to the pre-shed event loop).
    pub shed: Option<ShedConfig>,
}

impl ClusterConfig {
    /// A homogeneous, uncapped, fault-free deployment.
    pub fn new(nodes: usize, lb: LbPolicy, node: Config) -> ClusterConfig {
        ClusterConfig {
            nodes,
            lb,
            node,
            node_specs: Vec::new(),
            power_cap_w: None,
            power_epoch_s: 1.0,
            arbiter: ArbiterStrategy::DemandProportional,
            faults: FaultPlan::default(),
            pool_ratio: PoolRatio::default(),
            disagg: None,
            capacity: None,
            shed: None,
        }
    }

    /// Add a cluster-wide watt budget arbitrated every `epoch_s` seconds.
    pub fn with_power_cap(mut self, cap_w: f64, epoch_s: f64) -> ClusterConfig {
        self.power_cap_w = Some(cap_w);
        self.power_epoch_s = epoch_s;
        self
    }

    /// Select the arbiter's headroom-split strategy.
    pub fn with_arbiter(mut self, strategy: ArbiterStrategy) -> ClusterConfig {
        self.arbiter = strategy;
        self
    }

    /// Attach per-node shape presets (cycled over the node count).
    pub fn with_node_specs(mut self, specs: Vec<NodeSpec>) -> ClusterConfig {
        self.node_specs = specs;
        self
    }

    /// Attach a fault schedule (validated against the node count when the
    /// cluster runs).
    pub fn with_faults(mut self, faults: FaultPlan) -> ClusterConfig {
        self.faults = faults;
        self
    }

    /// Set the prefill:decode pool split (phase balancer + disagg pools).
    pub fn with_pool_ratio(mut self, ratio: PoolRatio) -> ClusterConfig {
        self.pool_ratio = ratio;
        self
    }

    /// Enable prefill/decode disaggregation (pool split per
    /// `pool_ratio`, stream migration at prefill completion).
    pub fn with_disagg(mut self, disagg: DisaggConfig) -> ClusterConfig {
        self.disagg = Some(disagg);
        self
    }

    /// Enable the endogenous capacity controller (validated against the
    /// node count when the cluster runs).
    pub fn with_capacity(mut self, capacity: CapacityConfig) -> ClusterConfig {
        self.capacity = Some(capacity);
        self
    }

    /// Enable graceful overload shedding at ingress.
    pub fn with_shed(mut self, shed: ShedConfig) -> ClusterConfig {
        self.shed = Some(shed);
        self
    }

    /// Nodes in the prefill pool when disaggregated (0 = colocated:
    /// disagg unset, or a 1-node cluster that cannot split).
    pub fn prefill_pool(&self) -> usize {
        if self.disagg.is_some() {
            self.pool_ratio.prefill_count(self.nodes)
        } else {
            0
        }
    }

    /// Resolved spec name of node `i` (`"dgx"` when homogeneous —
    /// the base-config shape).
    pub fn node_spec_name(&self, i: usize) -> String {
        if self.node_specs.is_empty() {
            "dgx".into()
        } else {
            self.node_specs[i % self.node_specs.len()].name.clone()
        }
    }
}

/// Power-arbitration summary attached to a capped cluster run.
#[derive(Debug, Clone)]
pub struct PowerReport {
    /// The cluster-wide watt budget.
    pub cap_w: f64,
    /// Arbitration epoch length, seconds.
    pub epoch_s: f64,
    /// Highest measured cluster draw across epochs, watts.
    pub peak_measured_w: f64,
    /// Any epoch where a node's share fell below the ladder-floor power.
    pub had_infeasible_epoch: bool,
    /// Every arbitration decision, in order (diagnostics + tests).
    pub epochs: Vec<PowerEpoch>,
}

/// Results of one cluster run: aggregate energy/SLO totals, the per-node
/// breakdown, and chaos diagnostics when a fault plan was active.
#[derive(Debug)]
pub struct ClusterResult {
    /// One engine result per node, index-aligned with the deployment.
    pub per_node: Vec<RunResult>,
    /// Cluster-wide energy, joules.
    pub total_energy_j: f64,
    /// Useful (delivered) tokens across the cluster. Conserved even under
    /// node loss: partial work on a failed node is rolled back and
    /// re-generated at the adoptive node.
    pub generated_tokens: u64,
    /// Requests completed (every request completes exactly once).
    pub completed: u64,
    /// Fraction of completed requests meeting their TTFT target.
    pub ttft_pass_rate: f64,
    /// Fraction of TBT-eligible requests meeting the P95 TBT target.
    pub tbt_pass_rate: f64,
    /// Requests assigned per node (balance diagnostic). A re-routed
    /// request counts toward the node that finally served it.
    pub assignment: Vec<usize>,
    /// The ingress policy the run used.
    pub lb: LbPolicy,
    /// Present iff the run had a power cap.
    pub power: Option<PowerReport>,
    /// Requests drained from failed nodes and re-routed elsewhere.
    pub rerouted: u64,
    /// Tokens generated on failed nodes and rolled back at the drain
    /// (energy already spent on them is kept — it is the waste of churn).
    pub wasted_tokens: u64,
    /// Fault transitions that actually fired during the run.
    pub fault_events: usize,
    /// Discrete events processed across every node's loop (the cluster
    /// analogue of [`RunResult::events_processed`]; perf-bench metric).
    pub events_processed: u64,
    /// Arrivals shed permanently by the overload policy after exhausting
    /// their retries (0 when shedding is disabled). Conservation:
    /// `completed + shed` equals the arrivals of a finished run.
    pub shed: u64,
    /// Shed-policy re-offers scheduled (an arrival deferred `k` times
    /// before admission or shed contributes `k`).
    pub shed_retries: u64,
    /// Arrivals deferred because no node was routable at offer time
    /// (re-offered at the next recovery/boot; 0 on fault-free runs).
    pub deferred_arrivals: u64,
    /// Warm-pool idle energy metered for capacity-parked nodes, joules
    /// (already included in `total_energy_j`).
    pub warm_energy_j: f64,
    /// Cold nodes booted into service by the capacity controller.
    pub capacity_provisions: u64,
    /// Idle nodes parked by the capacity controller (beyond the initial
    /// warm pool).
    pub capacity_parks: u64,
    /// Nodes the fault plan degraded (straggler `slow` events) at any
    /// point, ascending.
    pub straggler_nodes: Vec<usize>,
    /// Times any node's [`GovernorSupervisor`](crate::dvfs::GovernorSupervisor)
    /// tripped to its pinned-clock fallback.
    pub supervisor_fallbacks: u64,
    /// Times a supervisor survived probation and re-engaged its inner
    /// policy.
    pub supervisor_reengages: u64,
    /// Clock writes the control plane dropped (never reached a GPU).
    pub ctl_dropped_writes: u64,
    /// Clock writes that landed late through the actuation-latency path.
    pub ctl_delayed_writes: u64,
    /// Clock writes snapped to a neighboring ladder step by control noise.
    pub ctl_missteps: u64,
    /// Telemetry samples suppressed from policies during blackout windows.
    pub ctl_suppressed_samples: u64,
    /// Prefill→decode handoff accounting; present iff the run was
    /// disaggregated. (`assignment` tracks the node currently *owning*
    /// each request, so a migrated request counts at its decode home.)
    pub migration: Option<MigrationReport>,
    /// Per-node slice of the migration ledger, index-aligned with the
    /// deployment; non-empty iff the run was disaggregated.
    pub node_migration: Vec<NodeMigration>,
    /// Whole-run TTFT distribution, merged across every node's tracker
    /// (same log-spaced bucketing as [`Histogram::latency`]).
    pub ttft_hist: Histogram,
    /// Whole-run P95-TBT distribution (one sample per TBT-eligible
    /// request), merged across every node's tracker.
    pub tbt_hist: Histogram,
}

impl ClusterResult {
    /// Cluster-wide joules per delivered token.
    pub fn energy_per_token_j(&self) -> f64 {
        self.total_energy_j / self.generated_tokens.max(1) as f64
    }

    /// Max/min node request share — 1.0 is perfectly balanced. A starved
    /// node (zero requests while others got some) is reported honestly as
    /// `f64::INFINITY`, not masked by a fake denominator; pair with
    /// [`ClusterResult::starved_nodes`] for the count.
    pub fn balance_ratio(&self) -> f64 {
        let max = self.assignment.iter().max().copied().unwrap_or(0) as f64;
        let min = self.assignment.iter().min().copied().unwrap_or(0) as f64;
        if min == 0.0 {
            return if max == 0.0 { 1.0 } else { f64::INFINITY };
        }
        max / min
    }

    /// Nodes that received zero requests.
    pub fn starved_nodes(&self) -> usize {
        self.assignment.iter().filter(|&&c| c == 0).count()
    }

    /// Human-readable balance figure (shared by the CLI and reports).
    pub fn balance_label(&self) -> String {
        balance_label(self.balance_ratio(), self.starved_nodes())
    }
}

/// Render a balance ratio for display: starvation is shown as an explicit
/// starved-node count instead of a meaningless infinite ratio.
pub fn balance_label(ratio: f64, starved: usize) -> String {
    if ratio.is_infinite() {
        format!("starved:{starved}")
    } else {
        format!("{ratio:.2}")
    }
}

/// Pre-assign each request to a node (returns node index per request).
///
/// Only meaningful for front-end-only policies
/// ([`LbPolicy::frontend_only`]): telemetry-driven policies see empty node
/// states here and degrade to their no-information behavior. The live
/// cluster path ([`run_cluster`]) is the real thing — this stays as a
/// cheap offline preview of ingress decisions.
pub fn assign(trace: &Trace, nodes: usize, lb: LbPolicy) -> Vec<usize> {
    assert!(nodes >= 1);
    let mut b = balancer::build(lb, nodes, 0.1, PoolRatio::default());
    let states = vec![NodeState::default(); nodes];
    trace
        .requests
        .iter()
        .map(|r| {
            b.assign(r.arrival_s, r, &states)
                .expect("offline assign: every node is alive")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::coordinator::engine::{run, RunOptions};
    use crate::workload::alibaba::{generate, ChatParams};

    fn cluster(nodes: usize, lb: LbPolicy, method: Method) -> ClusterConfig {
        ClusterConfig::new(
            nodes,
            lb,
            Config {
                method,
                seed: 5,
                ..Config::default()
            },
        )
    }

    #[test]
    fn round_robin_is_balanced() {
        let trace = generate(&ChatParams::new(8.0, 60.0), 1);
        let a = assign(&trace, 4, LbPolicy::RoundRobin);
        let mut counts = [0usize; 4];
        for &n in &a {
            counts[n] += 1;
        }
        assert!(counts.iter().max().unwrap() - counts.iter().min().unwrap() <= 1);
    }

    #[test]
    fn least_work_balances_tokens_not_requests() {
        let trace = generate(&ChatParams::new(8.0, 120.0), 1);
        let a = assign(&trace, 2, LbPolicy::LeastPromptWork);
        let mut toks = [0f64; 2];
        for (r, &n) in trace.requests.iter().zip(&a) {
            toks[n] += r.prompt_len as f64;
        }
        let ratio = toks[0].max(toks[1]) / toks[0].min(toks[1]);
        assert!(ratio < 1.25, "token imbalance {ratio}");
    }

    #[test]
    fn cluster_conserves_requests_and_tokens() {
        let trace = generate(&ChatParams::new(16.0, 60.0), 2);
        let r = run_cluster(
            &cluster(2, LbPolicy::LeastPromptWork, Method::GreenLlm),
            &trace,
            &RunOptions::default(),
        );
        assert_eq!(r.completed as usize, trace.requests.len());
        let expect: u64 = trace.requests.iter().map(|q| q.output_len as u64).sum();
        assert_eq!(r.generated_tokens, expect);
        assert_eq!(r.per_node.len(), 2);
        assert_eq!(r.assignment.iter().sum::<usize>(), trace.requests.len());
    }

    #[test]
    fn greenllm_savings_hold_at_cluster_scale() {
        // 2 nodes at 2× the single-node load: savings comparable to the
        // single-node 5 QPS case (the paper's scaling claim).
        let trace = generate(&ChatParams::new(10.0, 90.0), 3);
        let nv = run_cluster(
            &cluster(2, LbPolicy::JoinShortestQueue, Method::DefaultNv),
            &trace,
            &RunOptions::default(),
        );
        let green = run_cluster(
            &cluster(2, LbPolicy::JoinShortestQueue, Method::GreenLlm),
            &trace,
            &RunOptions::default(),
        );
        let saving = 1.0 - green.total_energy_j / nv.total_energy_j;
        assert!(saving > 0.15, "cluster saving {saving:.3}");
        assert!(green.ttft_pass_rate > 0.9);
        assert!(green.tbt_pass_rate > 0.9);
    }

    #[test]
    fn single_node_cluster_matches_plain_run() {
        let trace = generate(&ChatParams::new(4.0, 60.0), 7);
        let ccfg = cluster(1, LbPolicy::RoundRobin, Method::GreenLlm);
        let c = run_cluster(&ccfg, &trace, &RunOptions::default());
        let plain = run(
            &Config {
                method: Method::GreenLlm,
                seed: 5,
                ..Config::default()
            },
            &trace,
            &RunOptions::default(),
        );
        assert_eq!(c.total_energy_j.to_bits(), plain.total_energy_j.to_bits());
    }

    #[test]
    fn node_spec_presets_parse_and_apply() {
        for name in ["dgx", "half", "big", "eff", "legacy", "a100", "h100"] {
            let spec = NodeSpec::parse(name).unwrap();
            assert_eq!(spec.name, name);
            let mut cfg = Config::default();
            spec.apply(&mut cfg);
            cfg.validate().unwrap();
            assert_eq!(cfg.pools, spec.pools);
            assert_eq!(cfg.gpu.power_scale, spec.power_scale);
            assert_eq!(cfg.gpu.max_clock_mhz, spec.max_clock_mhz);
            assert_eq!(cfg.gpu.part, spec.part);
        }
        // Calibrated presets carry their zoo key; analytic ones don't.
        assert_eq!(NodeSpec::parse("a100").unwrap().part, "a100");
        assert_eq!(NodeSpec::parse("hopper").unwrap().max_clock_mhz, 1980);
        assert!(NodeSpec::parse("dgx").unwrap().part.is_empty());
        assert!(NodeSpec::parse("h200").is_none());
        // List grammar: `,` and `+` both separate; uniform/empty = none.
        let specs = NodeSpec::parse_list("dgx+eff,legacy").unwrap();
        assert_eq!(
            specs.iter().map(|s| s.name.as_str()).collect::<Vec<_>>(),
            vec!["dgx", "eff", "legacy"]
        );
        assert!(NodeSpec::parse_list("uniform").unwrap().is_empty());
        assert!(NodeSpec::parse_list("").unwrap().is_empty());
        assert!(NodeSpec::parse_list("dgx,bogus").is_err());
    }

    #[test]
    fn cluster_config_builders_compose() {
        let ccfg = ClusterConfig::new(3, LbPolicy::PowerGrant, Config::default())
            .with_power_cap(9000.0, 0.5)
            .with_arbiter(ArbiterStrategy::SloPressure)
            .with_node_specs(vec![NodeSpec::eff(), NodeSpec::legacy()])
            .with_faults(FaultPlan::parse("down@10:1,up@20:1").unwrap())
            .with_pool_ratio(PoolRatio { prefill: 1, decode: 2 })
            .with_disagg(DisaggConfig::default());
        assert_eq!(ccfg.power_cap_w, Some(9000.0));
        assert_eq!(ccfg.arbiter, ArbiterStrategy::SloPressure);
        assert_eq!(ccfg.faults.events.len(), 2);
        // 3 nodes at 1:2 → 1 prefill node; unset disagg = colocated (0).
        assert_eq!(ccfg.prefill_pool(), 1);
        assert_eq!(
            ClusterConfig::new(3, LbPolicy::PowerGrant, Config::default()).prefill_pool(),
            0
        );
        // Specs cycle over the node count.
        assert_eq!(ccfg.node_spec_name(0), "eff");
        assert_eq!(ccfg.node_spec_name(1), "legacy");
        assert_eq!(ccfg.node_spec_name(2), "eff");
        assert_eq!(
            ClusterConfig::new(1, LbPolicy::RoundRobin, Config::default()).node_spec_name(0),
            "dgx"
        );
    }

    #[test]
    fn starved_node_reported_as_infinite_imbalance() {
        // 2 requests on a 4-node round-robin leaves nodes 2 and 3 starved.
        let mut trace = generate(&ChatParams::new(8.0, 60.0), 1);
        trace.requests.truncate(2);
        let r = run_cluster(
            &cluster(4, LbPolicy::RoundRobin, Method::DefaultNv),
            &trace,
            &RunOptions::default(),
        );
        assert_eq!(r.starved_nodes(), 2);
        assert!(r.balance_ratio().is_infinite());
    }
}

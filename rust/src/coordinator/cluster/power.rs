//! Cluster power-budget arbitration: one watt cap, many (possibly
//! heterogeneous) nodes.
//!
//! Each control epoch the arbiter measures every node's mean power over
//! the last epoch (exact, from the simulated GPUs' energy integrals) and
//! splits the cluster cap into per-node watt shares: every *alive* node
//! is first guaranteed its *floor* (worst-case power at its own ladder's
//! minimum clock — no grant can go below the physical lower bound), and
//! the remaining headroom is distributed by the selected
//! [`ArbiterStrategy`]:
//!
//! * [`ArbiterStrategy::DemandProportional`] — headroom follows measured
//!   draw (the PR 2 default, unchanged bit-for-bit).
//! * [`ArbiterStrategy::SloPressure`] — headroom follows each node's
//!   TBT-tail pressure (recent decode P95 ÷ target): a node burning its
//!   latency budget gets watts even while its measured draw is still
//!   low, which is what lets clamped clusters protect tails instead of
//!   rewarding whoever already burns the most.
//!
//! Each share is then converted into a *clock grant*: the highest ladder
//! frequency whose worst-case node power (every GPU fully active, on that
//! node's own power envelope) fits the share. Policies keep requesting
//! whatever clocks they want — the engine clamps every request to the
//! granted ceiling
//! ([`crate::coordinator::engine::Engine::set_clock_cap`]).
//!
//! Because grants are sized against worst-case active power and every
//! share is at least the floor whenever the cap covers the cluster-wide
//! floor, the measured cluster draw can never exceed a feasible cap in
//! any epoch. A cap below the summed floors is *physically* infeasible:
//! nodes are clamped to their ladder minimum and the epoch is flagged.
//! Dead nodes (chaos layer) draw nothing, get share 0 and free their
//! floor for the survivors.

use crate::coordinator::engine::Engine;
use crate::obs::Recorder;
use crate::gpu::freq::FreqLadder;
use crate::gpu::power::PowerModel;

/// How the arbiter splits watt headroom above the per-node floors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArbiterStrategy {
    /// Headroom proportional to each node's measured draw over the last
    /// epoch (equal split before any demand exists). The default.
    DemandProportional,
    /// Headroom proportional to each node's TBT-tail pressure (recent
    /// decode P95 ÷ the SLO target, clamped to [0, 8]): SLO-burning nodes
    /// win watts. Falls back to measured demand while every tail is still
    /// empty (cold start), then to an equal split.
    SloPressure,
}

impl ArbiterStrategy {
    /// Stable short name (CLI spelling, report column).
    pub fn name(&self) -> &'static str {
        match self {
            ArbiterStrategy::DemandProportional => "demand",
            ArbiterStrategy::SloPressure => "slo-pressure",
        }
    }

    /// Parse a CLI spelling; `None` for unknown names.
    pub fn parse(s: &str) -> Option<ArbiterStrategy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "demand" | "demand-proportional" | "proportional" => {
                Some(ArbiterStrategy::DemandProportional)
            }
            "slo-pressure" | "slopressure" | "slo" | "pressure" => {
                Some(ArbiterStrategy::SloPressure)
            }
            _ => None,
        }
    }

    /// Every registered strategy, in report order.
    pub fn all() -> Vec<ArbiterStrategy> {
        vec![
            ArbiterStrategy::DemandProportional,
            ArbiterStrategy::SloPressure,
        ]
    }
}

/// Upper clamp on a node's TBT pressure weight: one deeply blown tail may
/// dominate, but never starve the rest to a zero-headroom share.
const MAX_PRESSURE: f64 = 8.0;

/// One arbitration decision (diagnostics + invariant tests).
#[derive(Debug, Clone)]
pub struct PowerEpoch {
    /// Epoch end time (the decision instant).
    pub t_s: f64,
    /// Per-node mean power over the finished epoch, watts.
    pub measured_w: Vec<f64>,
    /// Per-node share of the cap the arbiter allotted, watts (0 for dead
    /// nodes).
    pub share_w: Vec<f64>,
    /// Per-node clock ceiling granted, MHz (0 for dead nodes).
    pub clamp_mhz: Vec<u32>,
    /// Worst-case power of each grant (GPUs fully active), watts.
    pub granted_w: Vec<f64>,
    /// Alive nodes whose share fell below their min-clock worst case
    /// (grant clamped to the ladder floor; budget not guaranteeable).
    pub infeasible_nodes: usize,
}

impl PowerEpoch {
    /// Summed measured cluster draw, watts.
    pub fn total_measured_w(&self) -> f64 {
        self.measured_w.iter().sum()
    }

    /// Summed worst-case granted draw, watts.
    pub fn total_granted_w(&self) -> f64 {
        self.granted_w.iter().sum()
    }
}

/// Highest ladder clock whose worst-case node power (`gpus` fully active
/// on `power`'s envelope) fits `share_w`; `None` if even the ladder floor
/// exceeds the share. Heterogeneous nodes pass their own ladder/envelope.
pub fn grant_for_share(
    ladder: &FreqLadder,
    power: &PowerModel,
    gpus: usize,
    share_w: f64,
) -> Option<u32> {
    let mut granted = None;
    for f in ladder.iter() {
        if gpus as f64 * power.active_w(f) <= share_w {
            granted = Some(f);
        } else {
            break; // active power is monotone in frequency
        }
    }
    granted
}

/// The cluster-wide arbiter. Drive with [`PowerArbiter::apply_initial`]
/// once at t = 0 and [`PowerArbiter::epoch`] at every epoch boundary.
pub struct PowerArbiter {
    /// The cluster-wide watt budget.
    pub cap_w: f64,
    /// Arbitration epoch length, seconds.
    pub epoch_s: f64,
    /// Headroom-split strategy.
    pub strategy: ArbiterStrategy,
    /// Decode P95 TBT target the SLO-pressure strategy normalizes by.
    tbt_target_s: f64,
    /// Disaggregated clusters: nodes `< prefill_pool` chase TTFT, not the
    /// TBT tail — the SLO-pressure strategy weighs them by prefill
    /// backlog pressure instead. 0 = colocated (every node decodes).
    prefill_pool: usize,
    last_energy_j: Vec<f64>,
    last_t: f64,
    /// Every decision taken so far, in order.
    pub epochs: Vec<PowerEpoch>,
}

impl PowerArbiter {
    /// A fresh arbiter for `nodes` nodes under `cap_w` watts.
    pub fn new(
        cap_w: f64,
        epoch_s: f64,
        nodes: usize,
        strategy: ArbiterStrategy,
        tbt_target_s: f64,
    ) -> Self {
        assert!(cap_w > 0.0, "power cap must be positive");
        assert!(epoch_s > 0.0, "power epoch must be positive");
        assert!(tbt_target_s > 0.0, "tbt target must be positive");
        PowerArbiter {
            cap_w,
            epoch_s,
            strategy,
            tbt_target_s,
            prefill_pool: 0,
            last_energy_j: vec![0.0; nodes],
            last_t: 0.0,
            epochs: Vec::new(),
        }
    }

    /// Mark the first `prefill_pool` nodes as prefill-pool members (call
    /// before the first arbitration; disaggregated clusters only). Their
    /// SLO-pressure weight becomes TTFT backlog pressure
    /// ([`Engine::prefill_pressure`]) — same normalized scale as the
    /// decode nodes' tail ÷ target, so the two pools compete fairly for
    /// headroom.
    pub fn set_prefill_pool(&mut self, prefill_pool: usize) {
        self.prefill_pool = prefill_pool;
    }

    /// Headroom weights per node under the active strategy; `None` means
    /// "no information yet — fall back to an equal split among the
    /// alive". Dead nodes always weigh zero.
    fn headroom_weights<R: Recorder>(
        &self,
        measured: &[f64],
        engines: &[Engine<'_, R>],
        alive: &[bool],
    ) -> Option<Vec<f64>> {
        let masked = |v: Vec<f64>| -> Option<Vec<f64>> {
            if v.iter().sum::<f64>() > 0.0 {
                Some(v)
            } else {
                None
            }
        };
        let demand = || {
            masked(
                measured
                    .iter()
                    .zip(alive)
                    .map(|(m, &a)| if a { *m } else { 0.0 })
                    .collect(),
            )
        };
        match self.strategy {
            ArbiterStrategy::DemandProportional => demand(),
            ArbiterStrategy::SloPressure => masked(
                engines
                    .iter()
                    .enumerate()
                    .zip(alive)
                    .map(|((i, e), &a)| {
                        if !a {
                            0.0
                        } else if i < self.prefill_pool {
                            // Prefill nodes have no decode tail; their SLO
                            // is TTFT — weigh by prompt-backlog pressure.
                            // Read through the node's control plane: under
                            // a telemetry blackout the arbiter sees the
                            // frozen snapshot, not the live value.
                            e.sensed_prefill_pressure().clamp(0.0, MAX_PRESSURE)
                        } else {
                            (e.sensed_tbt_tail_p95() / self.tbt_target_s).clamp(0.0, MAX_PRESSURE)
                        }
                    })
                    .collect(),
            )
            .or_else(demand),
        }
    }

    fn arbitrate<R: Recorder>(
        &mut self,
        t: f64,
        measured: Vec<f64>,
        engines: &mut [Engine<'_, R>],
        alive: &[bool],
    ) {
        let n_alive = alive.iter().filter(|a| **a).count().max(1) as f64;
        // Physical lower bound per alive node: worst-case power at that
        // node's own ladder floor. Shares never drop below it (a grant
        // below min clock does not exist), so with a feasible cap every
        // epoch stays feasible even when one node idles while another
        // burns. Dead nodes draw nothing and need no floor.
        let floors: Vec<f64> = engines
            .iter()
            .zip(alive)
            .map(|(e, &a)| {
                if a {
                    e.node_active_w(e.ladder().min_mhz)
                } else {
                    0.0
                }
            })
            .collect();
        let total_floor: f64 = floors.iter().sum();
        let weights = self.headroom_weights(&measured, engines, alive);
        let share_w: Vec<f64> = if self.cap_w >= total_floor {
            // Floor-guaranteed, headroom split by the strategy's weights
            // (equal among the alive before any signal exists).
            let headroom = self.cap_w - total_floor;
            let (w, total_w) = match &weights {
                Some(w) => (Some(w), w.iter().sum::<f64>()),
                None => (None, 0.0),
            };
            floors
                .iter()
                .enumerate()
                .map(|(i, f)| {
                    if !alive[i] {
                        return 0.0;
                    }
                    let frac = match w {
                        Some(w) => w[i] / total_w,
                        None => 1.0 / n_alive,
                    };
                    f + headroom * frac
                })
                .collect()
        } else {
            // Infeasible cap: best effort, pure weighted split (nodes
            // clamp to their ladder floor below their share anyway).
            match &weights {
                Some(w) => {
                    let total_w: f64 = w.iter().sum();
                    w.iter().map(|wi| self.cap_w * wi / total_w).collect()
                }
                None => alive
                    .iter()
                    .map(|&a| if a { self.cap_w / n_alive } else { 0.0 })
                    .collect(),
            }
        };
        let mut clamp_mhz = Vec::with_capacity(engines.len());
        let mut granted_w = Vec::with_capacity(engines.len());
        let mut infeasible = 0;
        for (i, (e, &share)) in engines.iter_mut().zip(&share_w).enumerate() {
            if !alive[i] {
                clamp_mhz.push(0);
                granted_w.push(0.0);
                continue;
            }
            let clamp = match grant_for_share(e.ladder(), e.power_model(), e.num_gpus(), share) {
                Some(f) => f,
                None => {
                    infeasible += 1;
                    e.ladder().min_mhz
                }
            };
            e.set_clock_cap(t, clamp);
            granted_w.push(e.node_active_w(clamp));
            clamp_mhz.push(clamp);
        }
        self.epochs.push(PowerEpoch {
            t_s: t,
            measured_w: measured,
            share_w,
            clamp_mhz,
            granted_w,
            infeasible_nodes: infeasible,
        });
    }

    /// First grant, before any demand exists: equal shares.
    pub fn apply_initial<R: Recorder>(&mut self, engines: &mut [Engine<'_, R>], alive: &[bool]) {
        let measured = vec![0.0; engines.len()];
        self.arbitrate(0.0, measured, engines, alive);
        // The t=0 record has no measurement; keep it for the clamp trail.
    }

    /// Out-of-band re-arbitration at a fault transition: re-split the cap
    /// across the *current* alive set using the last epoch's measurements,
    /// without advancing the measurement window. Without this, a node
    /// rejoining mid-epoch would run uncapped (its `recover` clears the
    /// clamp) while the survivors still hold grants summing to the full
    /// cap — the one way a feasible budget could be exceeded; and a freed
    /// node's budget would stay stranded until the next epoch boundary.
    pub fn rearbitrate<R: Recorder>(&mut self, t: f64, engines: &mut [Engine<'_, R>], alive: &[bool]) {
        let measured = self
            .epochs
            .last()
            .map(|e| e.measured_w.clone())
            .unwrap_or_else(|| vec![0.0; engines.len()]);
        self.arbitrate(t, measured, engines, alive);
    }

    /// Epoch boundary at `t`: measure, re-split, re-grant.
    pub fn epoch<R: Recorder>(&mut self, t: f64, engines: &mut [Engine<'_, R>], alive: &[bool]) {
        let dt = t - self.last_t;
        if dt <= 0.0 {
            return;
        }
        let measured: Vec<f64> = engines
            .iter_mut()
            .enumerate()
            .map(|(i, e)| {
                // The energy meter itself is ground truth (it anchors the
                // *next* epoch's delta exactly), but the per-epoch power
                // reading the arbiter acts on goes through the node's
                // sensing path — stuck or quantized under control faults,
                // bit-identical to the raw value otherwise.
                let now = e.energy_now_j(t);
                let p = (now - self.last_energy_j[i]) / dt;
                self.last_energy_j[i] = now;
                e.ctl_sense_power(p)
            })
            .collect();
        self.last_t = t;
        self.arbitrate(t, measured, engines, alive);
    }

    /// Worst-case watt grant per node from the latest decision
    /// (`f64::INFINITY` per node before any epoch ran — i.e. never, since
    /// [`PowerArbiter::apply_initial`] records the t=0 grant). The
    /// `powergrant` balancer consumes this.
    pub fn latest_grants(&self) -> Option<&[f64]> {
        self.epochs.last().map(|e| e.granted_w.as_slice())
    }

    /// Highest measured cluster draw across completed epochs (W).
    pub fn peak_measured_w(&self) -> f64 {
        self.epochs
            .iter()
            .map(|e| e.total_measured_w())
            .fold(0.0, f64::max)
    }

    /// Did any epoch have a share below the min-clock worst case?
    pub fn had_infeasible_epoch(&self) -> bool {
        self.epochs.iter().any(|e| e.infeasible_nodes > 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grant_fits_share_and_is_maximal() {
        let (ladder, power) = (FreqLadder::a100(), PowerModel::a100());
        // 8-GPU node, 2000 W share → some mid-ladder clock.
        let f = grant_for_share(&ladder, &power, 8, 2000.0).unwrap();
        assert!(8.0 * power.active_w(f) <= 2000.0);
        // One step up must overflow the share (maximality).
        let up = f + ladder.step_mhz;
        assert!(up > ladder.max_mhz || 8.0 * power.active_w(up) > 2000.0);
        // Generous share → full boost; starvation share → None.
        assert_eq!(
            grant_for_share(&ladder, &power, 8, 1e9),
            Some(ladder.max_mhz)
        );
        assert_eq!(grant_for_share(&ladder, &power, 8, 100.0), None);
    }

    #[test]
    fn grant_respects_heterogeneous_hardware() {
        let ladder = FreqLadder::a100();
        let base = PowerModel::a100();
        let eff = base.clone().scaled(0.7);
        let share = 2000.0;
        let f_base = grant_for_share(&ladder, &base, 8, share).unwrap();
        let f_eff = grant_for_share(&ladder, &eff, 8, share).unwrap();
        // An efficient node buys a higher clock for the same share.
        assert!(f_eff > f_base, "eff {f_eff} <= base {f_base}");
        // A capped ladder never grants above its ceiling.
        let capped = FreqLadder {
            max_mhz: 1200,
            ..FreqLadder::a100()
        };
        assert_eq!(
            grant_for_share(&capped, &base, 8, 1e9),
            Some(1200)
        );
    }

    #[test]
    fn epoch_report_shares_sum_to_cap() {
        // Shares are proportional splits of the cap, so they always sum to
        // it (within float error) whenever total demand is positive.
        let cap_w = 3000.0;
        let measured = [900.0, 600.0, 300.0];
        let total: f64 = measured.iter().sum();
        let shares: Vec<f64> = measured.iter().map(|m| cap_w * m / total).collect();
        assert!((shares.iter().sum::<f64>() - cap_w).abs() < 1e-9);
    }

    #[test]
    fn strategy_names_round_trip_through_parse() {
        for s in ArbiterStrategy::all() {
            assert_eq!(ArbiterStrategy::parse(s.name()), Some(s), "{s:?}");
        }
        assert_eq!(
            ArbiterStrategy::parse("slo"),
            Some(ArbiterStrategy::SloPressure)
        );
        assert_eq!(ArbiterStrategy::parse("bogus"), None);
    }
}

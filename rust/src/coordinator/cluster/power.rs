//! Cluster power-budget arbitration: one watt cap, many nodes.
//!
//! Each control epoch the arbiter measures every node's mean power over
//! the last epoch (exact, from the simulated GPUs' energy integrals) and
//! splits the cluster cap into per-node watt shares: every node is first
//! guaranteed its *floor* (worst-case power at the ladder's minimum
//! clock — no grant can go below the physical lower bound), and the
//! remaining headroom is distributed proportionally to measured demand.
//! Each share is then converted into a *clock grant*: the highest ladder
//! frequency whose worst-case node power (every GPU fully active) fits
//! the share. Policies keep requesting whatever clocks they want — the
//! engine clamps every request to the granted ceiling
//! ([`crate::coordinator::engine::Engine::set_clock_cap`]).
//!
//! Because grants are sized against worst-case active power and every
//! share is at least the floor whenever the cap covers the cluster-wide
//! floor, the measured cluster draw can never exceed a feasible cap in
//! any epoch. A cap below the summed floors is *physically* infeasible:
//! nodes are clamped to the ladder minimum and the epoch is flagged.

use crate::coordinator::engine::Engine;
use crate::gpu::freq::FreqLadder;
use crate::gpu::power::PowerModel;

/// One arbitration decision (diagnostics + invariant tests).
#[derive(Debug, Clone)]
pub struct PowerEpoch {
    /// Epoch end time (the decision instant).
    pub t_s: f64,
    /// Per-node mean power over the finished epoch, watts.
    pub measured_w: Vec<f64>,
    /// Per-node share of the cap the arbiter allotted, watts.
    pub share_w: Vec<f64>,
    /// Per-node clock ceiling granted, MHz.
    pub clamp_mhz: Vec<u32>,
    /// Worst-case power of each grant (GPUs fully active), watts.
    pub granted_w: Vec<f64>,
    /// Nodes whose share fell below the min-clock worst case (grant
    /// clamped to the ladder floor; budget not guaranteeable).
    pub infeasible_nodes: usize,
}

impl PowerEpoch {
    pub fn total_measured_w(&self) -> f64 {
        self.measured_w.iter().sum()
    }

    pub fn total_granted_w(&self) -> f64 {
        self.granted_w.iter().sum()
    }
}

/// The cluster-wide arbiter. Drive with [`PowerArbiter::apply_initial`]
/// once at t = 0 and [`PowerArbiter::epoch`] at every epoch boundary.
pub struct PowerArbiter {
    pub cap_w: f64,
    pub epoch_s: f64,
    power: PowerModel,
    ladder: FreqLadder,
    last_energy_j: Vec<f64>,
    last_t: f64,
    pub epochs: Vec<PowerEpoch>,
}

impl PowerArbiter {
    pub fn new(cap_w: f64, epoch_s: f64, nodes: usize) -> Self {
        assert!(cap_w > 0.0, "power cap must be positive");
        assert!(epoch_s > 0.0, "power epoch must be positive");
        PowerArbiter {
            cap_w,
            epoch_s,
            power: PowerModel::a100(),
            ladder: FreqLadder::a100(),
            last_energy_j: vec![0.0; nodes],
            last_t: 0.0,
            epochs: Vec::new(),
        }
    }

    /// Highest ladder clock whose worst-case node power (`gpus` fully
    /// active) fits `share_w`; `None` if even the floor exceeds the share.
    fn grant_for_share(&self, gpus: usize, share_w: f64) -> Option<u32> {
        let mut granted = None;
        for f in self.ladder.iter() {
            if gpus as f64 * self.power.active_w(f) <= share_w {
                granted = Some(f);
            } else {
                break; // active power is monotone in frequency
            }
        }
        granted
    }

    fn arbitrate(&mut self, t: f64, measured: Vec<f64>, engines: &mut [Engine<'_>]) {
        let n = engines.len() as f64;
        // Physical lower bound per node: worst-case power at the ladder
        // floor. Shares never drop below it (a grant below min clock does
        // not exist), so with a feasible cap every epoch stays feasible
        // even when one node idles while another burns.
        let floors: Vec<f64> = engines
            .iter()
            .map(|e| e.num_gpus() as f64 * self.power.active_w(self.ladder.min_mhz))
            .collect();
        let total_floor: f64 = floors.iter().sum();
        let total_m: f64 = measured.iter().sum();
        let share_w: Vec<f64> = if self.cap_w >= total_floor {
            // Floor-guaranteed, headroom proportional to measured demand
            // (equal split before any demand exists).
            let headroom = self.cap_w - total_floor;
            floors
                .iter()
                .zip(&measured)
                .map(|(f, m)| {
                    f + headroom * if total_m > 0.0 { m / total_m } else { 1.0 / n }
                })
                .collect()
        } else if total_m > 0.0 {
            // Infeasible cap: best effort, pure proportional (nodes clamp
            // to the ladder floor below their share anyway).
            measured.iter().map(|m| self.cap_w * m / total_m).collect()
        } else {
            engines.iter().map(|_| self.cap_w / n).collect()
        };
        let mut clamp_mhz = Vec::with_capacity(engines.len());
        let mut granted_w = Vec::with_capacity(engines.len());
        let mut infeasible = 0;
        for (e, &share) in engines.iter_mut().zip(&share_w) {
            let gpus = e.num_gpus();
            let clamp = match self.grant_for_share(gpus, share) {
                Some(f) => f,
                None => {
                    infeasible += 1;
                    self.ladder.min_mhz
                }
            };
            e.set_clock_cap(t, clamp);
            granted_w.push(gpus as f64 * self.power.active_w(clamp));
            clamp_mhz.push(clamp);
        }
        self.epochs.push(PowerEpoch {
            t_s: t,
            measured_w: measured,
            share_w,
            clamp_mhz,
            granted_w,
            infeasible_nodes: infeasible,
        });
    }

    /// First grant, before any demand exists: equal shares.
    pub fn apply_initial(&mut self, engines: &mut [Engine<'_>]) {
        let measured = vec![0.0; engines.len()];
        self.arbitrate(0.0, measured, engines);
        // The t=0 record has no measurement; keep it for the clamp trail.
    }

    /// Epoch boundary at `t`: measure, re-split, re-grant.
    pub fn epoch(&mut self, t: f64, engines: &mut [Engine<'_>]) {
        let dt = t - self.last_t;
        if dt <= 0.0 {
            return;
        }
        let measured: Vec<f64> = engines
            .iter_mut()
            .enumerate()
            .map(|(i, e)| {
                let now = e.energy_now_j(t);
                let p = (now - self.last_energy_j[i]) / dt;
                self.last_energy_j[i] = now;
                p
            })
            .collect();
        self.last_t = t;
        self.arbitrate(t, measured, engines);
    }

    /// Highest measured cluster draw across completed epochs (W).
    pub fn peak_measured_w(&self) -> f64 {
        self.epochs
            .iter()
            .map(|e| e.total_measured_w())
            .fold(0.0, f64::max)
    }

    /// Did any epoch have a share below the min-clock worst case?
    pub fn had_infeasible_epoch(&self) -> bool {
        self.epochs.iter().any(|e| e.infeasible_nodes > 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grant_fits_share_and_is_maximal() {
        let a = PowerArbiter::new(4000.0, 1.0, 2);
        // 8-GPU node, 2000 W share → some mid-ladder clock.
        let f = a.grant_for_share(8, 2000.0).unwrap();
        assert!(8.0 * a.power.active_w(f) <= 2000.0);
        // One step up must overflow the share (maximality).
        let up = f + a.ladder.step_mhz;
        assert!(up > a.ladder.max_mhz || 8.0 * a.power.active_w(up) > 2000.0);
        // Generous share → full boost; starvation share → None.
        assert_eq!(a.grant_for_share(8, 1e9), Some(a.ladder.max_mhz));
        assert_eq!(a.grant_for_share(8, 100.0), None);
    }

    #[test]
    fn epoch_report_shares_sum_to_cap() {
        // Shares are proportional splits of the cap, so they always sum to
        // it (within float error) whenever total demand is positive.
        let a = PowerArbiter::new(3000.0, 1.0, 3);
        // Synthesized split (no engines needed for the math check).
        let measured = [900.0, 600.0, 300.0];
        let total: f64 = measured.iter().sum();
        let shares: Vec<f64> = measured.iter().map(|m| a.cap_w * m / total).collect();
        assert!((shares.iter().sum::<f64>() - a.cap_w).abs() < 1e-9);
    }
}

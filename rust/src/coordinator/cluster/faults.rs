//! Chaos layer: deterministic node-loss / node-recovery schedules for the
//! cluster event loop.
//!
//! A [`FaultPlan`] is an ordered list of [`FaultEvent`]s injected into the
//! shared cluster clock alongside arrivals and power epochs. When a node
//! goes down, the event loop drains its queued and in-flight requests
//! ([`Engine::fail`](crate::coordinator::engine::Engine::fail)) and
//! re-routes them through the live balancer, so conservation invariants
//! (every request completes exactly once, every output token is generated
//! exactly once) hold under churn; the energy the node already spent on
//! aborted work is kept and the rolled-back tokens are reported as
//! `wasted_tokens`. When a node comes back up it rejoins with cold
//! telemetry (empty queues, reset TBT tail) and starts receiving traffic
//! again.
//!
//! Schedules come in two spellings, both deterministic:
//! * **Presets** ([`FaultSpec`]): `none`, `onedown` (highest-index node
//!   lost at ⅓ of the trace), `flap` (same node lost at ⅓, recovered at
//!   ⅔). Presets resolve against a concrete node count and duration, so
//!   the scenario matrix can sweep them as an axis.
//! * **Explicit events**: `"down@40:1,up@80:1"` — node 1 fails at t=40 s
//!   and recovers at t=80 s.
//!
//! ```
//! use greenllm::coordinator::cluster::faults::{FaultKind, FaultPlan};
//!
//! let plan = FaultPlan::parse("down@40:1,up@80:1").unwrap();
//! assert_eq!(plan.events.len(), 2);
//! assert_eq!(plan.events[0].kind, FaultKind::Down);
//! plan.validate(3).unwrap();           // fine on a 3-node cluster
//! assert!(plan.validate(1).is_err());  // would kill the only node
//! ```

/// Direction of one fault transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Node loss: drain + power off + re-route.
    Down,
    /// Node recovery: power on + rejoin with cold telemetry.
    Up,
}

/// One scheduled fault transition.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    /// Virtual time of the transition, seconds (must be > 0).
    pub t_s: f64,
    /// Target node index.
    pub node: usize,
    /// Loss or recovery.
    pub kind: FaultKind,
}

/// A deterministic fault schedule: time-ordered loss/recovery events.
/// The default (empty) plan is inert — a cluster run with it is
/// bit-identical to one without any chaos layer at all (tested).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Events sorted by time (ties in spell order).
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// No events scheduled?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse an explicit event list: comma-separated `down@<t>:<node>` /
    /// `up@<t>:<node>` entries. Events are sorted by time (stable, so
    /// equal-time events keep their spelled order). An empty string is
    /// the empty plan.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (kind, rest) = if let Some(r) = tok.strip_prefix("down@") {
                (FaultKind::Down, r)
            } else if let Some(r) = tok.strip_prefix("up@") {
                (FaultKind::Up, r)
            } else {
                return Err(format!(
                    "bad fault event {tok:?}: expected down@<t>:<node> or up@<t>:<node>"
                ));
            };
            let (t, node) = rest
                .split_once(':')
                .ok_or_else(|| format!("bad fault event {tok:?}: missing ':<node>'"))?;
            let t_s: f64 = t
                .parse()
                .map_err(|_| format!("bad fault time {t:?} in {tok:?}"))?;
            if !t_s.is_finite() || t_s <= 0.0 {
                return Err(format!("fault time must be finite and > 0, got {t_s}"));
            }
            let node: usize = node
                .parse()
                .map_err(|_| format!("bad fault node {node:?} in {tok:?}"))?;
            events.push(FaultEvent { t_s, node, kind });
        }
        let mut plan = FaultPlan { events };
        plan.sort();
        Ok(plan)
    }

    /// Sort events by time (stable: equal-time events keep insert order).
    fn sort(&mut self) {
        self.events.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));
    }

    /// Check the schedule against a node count: every event targets a real
    /// node, a node only goes down while up (and vice versa), and at least
    /// one node stays alive at every instant (a fully dark cluster cannot
    /// re-route its drained requests anywhere).
    pub fn validate(&self, nodes: usize) -> Result<(), String> {
        let mut down = vec![false; nodes];
        let mut down_count = 0usize;
        for ev in &self.events {
            if ev.node >= nodes {
                return Err(format!(
                    "fault targets node {} but the cluster has {nodes} nodes",
                    ev.node
                ));
            }
            match ev.kind {
                FaultKind::Down => {
                    if down[ev.node] {
                        return Err(format!("node {} downed twice (t={})", ev.node, ev.t_s));
                    }
                    if down_count + 1 >= nodes {
                        return Err(format!(
                            "fault plan would leave zero alive nodes at t={}",
                            ev.t_s
                        ));
                    }
                    down[ev.node] = true;
                    down_count += 1;
                }
                FaultKind::Up => {
                    if !down[ev.node] {
                        return Err(format!(
                            "node {} recovered while already up (t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    down[ev.node] = false;
                    down_count -= 1;
                }
            }
        }
        Ok(())
    }

    /// Render back to the explicit `down@t:node,...` spelling.
    pub fn render(&self) -> String {
        self.events
            .iter()
            .map(|e| {
                let k = match e.kind {
                    FaultKind::Down => "down",
                    FaultKind::Up => "up",
                };
                format!("{k}@{}:{}", e.t_s, e.node)
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// A named fault scenario, resolvable against a concrete cluster shape.
/// This is the matrix-axis form: presets keep a stable label per cell
/// while the actual event times scale with the cell's trace duration.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultSpec {
    /// No chaos (the empty plan).
    None,
    /// The highest-index node fails at ⅓ of the trace and never returns.
    OneDown,
    /// The highest-index node fails at ⅓ and recovers at ⅔ of the trace.
    Flap,
    /// An explicit event list (see [`FaultPlan::parse`]).
    Explicit(FaultPlan),
}

impl FaultSpec {
    /// Stable label (also the CLI spelling; explicit plans render their
    /// event list).
    pub fn name(&self) -> String {
        match self {
            FaultSpec::None => "none".into(),
            FaultSpec::OneDown => "onedown".into(),
            FaultSpec::Flap => "flap".into(),
            FaultSpec::Explicit(p) => p.render(),
        }
    }

    /// Parse a preset name or an explicit event list.
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "none" | "" => Ok(FaultSpec::None),
            "onedown" | "one-down" | "nodeloss" => Ok(FaultSpec::OneDown),
            "flap" => Ok(FaultSpec::Flap),
            _ => FaultPlan::parse(s).map(FaultSpec::Explicit),
        }
    }

    /// Resolve to a concrete plan. Presets that would down the only node
    /// of a 1-node cluster resolve to the empty plan (there is nowhere to
    /// re-route, so chaos is a no-op there by construction).
    pub fn plan(&self, nodes: usize, duration_s: f64) -> FaultPlan {
        let victim = nodes.saturating_sub(1);
        match self {
            FaultSpec::None => FaultPlan::default(),
            FaultSpec::OneDown if nodes >= 2 => FaultPlan {
                events: vec![FaultEvent {
                    t_s: duration_s / 3.0,
                    node: victim,
                    kind: FaultKind::Down,
                }],
            },
            FaultSpec::Flap if nodes >= 2 => FaultPlan {
                events: vec![
                    FaultEvent {
                        t_s: duration_s / 3.0,
                        node: victim,
                        kind: FaultKind::Down,
                    },
                    FaultEvent {
                        t_s: duration_s * 2.0 / 3.0,
                        node: victim,
                        kind: FaultKind::Up,
                    },
                ],
            },
            FaultSpec::OneDown | FaultSpec::Flap => FaultPlan::default(),
            FaultSpec::Explicit(p) => p.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_render_round_trip() {
        let plan = FaultPlan::parse("down@40:1,up@80:1,down@100:0").unwrap();
        assert_eq!(plan.events.len(), 3);
        assert_eq!(plan.render(), "down@40:1,up@80:1,down@100:0");
        assert_eq!(FaultPlan::parse(&plan.render()).unwrap(), plan);
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn parse_sorts_by_time() {
        let plan = FaultPlan::parse("up@80:1,down@40:1").unwrap();
        assert_eq!(plan.events[0].kind, FaultKind::Down);
        assert_eq!(plan.events[1].kind, FaultKind::Up);
    }

    #[test]
    fn parse_rejects_malformed_events() {
        assert!(FaultPlan::parse("sideways@40:1").is_err());
        assert!(FaultPlan::parse("down@40").is_err());
        assert!(FaultPlan::parse("down@-1:0").is_err());
        assert!(FaultPlan::parse("down@0:0").is_err());
        assert!(FaultPlan::parse("down@nan:0").is_err());
        assert!(FaultPlan::parse("down@40:x").is_err());
    }

    #[test]
    fn validate_enforces_liveness_and_state() {
        let plan = FaultPlan::parse("down@40:1,up@80:1").unwrap();
        plan.validate(2).unwrap();
        // Bad node index.
        assert!(FaultPlan::parse("down@40:5").unwrap().validate(2).is_err());
        // Double down.
        assert!(FaultPlan::parse("down@40:1,down@50:1")
            .unwrap()
            .validate(3)
            .is_err());
        // Up of an alive node.
        assert!(FaultPlan::parse("up@40:1").unwrap().validate(2).is_err());
        // All nodes dark.
        assert!(FaultPlan::parse("down@40:0,down@50:1")
            .unwrap()
            .validate(2)
            .is_err());
        // ... but fine with a third node alive.
        FaultPlan::parse("down@40:0,down@50:1")
            .unwrap()
            .validate(3)
            .unwrap();
    }

    #[test]
    fn spec_names_round_trip_through_parse() {
        for spec in [FaultSpec::None, FaultSpec::OneDown, FaultSpec::Flap] {
            assert_eq!(FaultSpec::parse(&spec.name()).unwrap(), spec);
        }
        let explicit = FaultSpec::parse("down@40:1,up@80:1").unwrap();
        assert_eq!(FaultSpec::parse(&explicit.name()).unwrap(), explicit);
        assert!(FaultSpec::parse("meteor").is_err());
    }

    #[test]
    fn presets_resolve_against_shape_and_duration() {
        let p = FaultSpec::OneDown.plan(3, 90.0);
        assert_eq!(p.events.len(), 1);
        assert_eq!(p.events[0].node, 2);
        assert!((p.events[0].t_s - 30.0).abs() < 1e-12);
        let f = FaultSpec::Flap.plan(2, 90.0);
        assert_eq!(f.events.len(), 2);
        assert!((f.events[1].t_s - 60.0).abs() < 1e-12);
        f.validate(2).unwrap();
        // Presets are inert on a single node and for `none`.
        assert!(FaultSpec::OneDown.plan(1, 90.0).is_empty());
        assert!(FaultSpec::Flap.plan(1, 90.0).is_empty());
        assert!(FaultSpec::None.plan(4, 90.0).is_empty());
    }
}

//! Chaos layer: deterministic capacity-degradation schedules for the
//! cluster event loop.
//!
//! A [`FaultPlan`] is an ordered list of [`FaultEvent`]s injected into the
//! shared cluster clock alongside arrivals and power epochs. When a node
//! goes down, the event loop drains its queued and in-flight requests
//! ([`Engine::fail`](crate::coordinator::engine::Engine::fail)) and
//! re-routes them through the live balancer, so conservation invariants
//! (every request completes exactly once, every output token is generated
//! exactly once) hold under churn; the energy the node already spent on
//! aborted work is kept and the rolled-back tokens are reported as
//! `wasted_tokens`. When a node comes back up it rejoins with cold
//! telemetry (empty queues, reset TBT tail) and starts receiving traffic
//! again.
//!
//! Beyond binary up/down, the grammar covers the realistic degradation
//! modes a fleet sees:
//! * **Drain** (`drain@t:n`): the node stops taking *new* ingress but
//!   keeps serving what it has — the administrative half of a spot
//!   preemption notice.
//! * **Spot preemption** (`preempt@t:n[:notice]`): expands at parse time
//!   to `drain@t:n` + `down@(t+notice):n` (default notice 30 s), so the
//!   cluster proactively empties the node instead of losing its in-flight
//!   work at the kill instant.
//! * **Straggler** (`slow@t:n:factor[:cap_mhz]` / `restore@t:n`): the
//!   node *keeps running* but every prefill/decode step takes `factor`×
//!   longer and (optionally) its DVFS ladder is thermally capped at
//!   `cap_mhz` — governors and the power arbiter must cope with a slow
//!   node, not just a dead one.
//! * **Rack-correlated loss** (`rackdown@t:a-b` / `rackup@t:a-b`):
//!   expands at parse time to per-node `down`/`up` events on the whole
//!   inclusive node range — one switch or PDU takes out a node *group*.
//! * **Control-plane noise** (`ctlnoise@t:n[:delay[:drop[:misstep]]]` /
//!   `ctlquiet@t:n`): the node's *actuation path* degrades — DVFS writes
//!   gain latency and are probabilistically dropped or snapped one
//!   ladder rung off — while the node itself keeps serving at full
//!   health. Composes freely with `slow` (a degraded node can also have
//!   a flaky NVML daemon).
//! * **Telemetry blackout** (`ctlblackout@t0-t1:n`, or `ctlblackout@t:n`
//!   + `ctlsense@t:n`): the node's sensors freeze and event-driven
//!   policy feedback is suppressed for the window — the failure mode the
//!   [`GovernorSupervisor`](crate::dvfs::GovernorSupervisor) exists for.
//!   The range spelling expands at parse time to the blackout/sense
//!   primitive pair.
//!
//! Schedules come in two spellings, both deterministic:
//! * **Presets** ([`FaultSpec`]): `none`, `onedown` (highest-index node
//!   lost at ⅓ of the trace), `flap` (same node lost at ⅓, recovered at
//!   ⅔), `spot` (drain at ⅓, kill at ½, back at ⅔ — preemption with
//!   notice), `straggler` (highest-index node runs 2× slow, thermally
//!   capped, between ⅓ and ⅔). Presets resolve against a concrete node
//!   count and duration, so the scenario matrix can sweep them as an
//!   axis.
//! * **Explicit events**: `"down@40:1,up@80:1"` — node 1 fails at t=40 s
//!   and recovers at t=80 s.
//!
//! ```
//! use greenllm::coordinator::cluster::faults::{FaultKind, FaultPlan};
//!
//! let plan = FaultPlan::parse("down@40:1,up@80:1").unwrap();
//! assert_eq!(plan.events.len(), 2);
//! assert_eq!(plan.events[0].kind, FaultKind::Down);
//! plan.validate(3).unwrap();           // fine on a 3-node cluster
//! assert!(plan.validate(1).is_err());  // would kill the only node
//!
//! // Spot preemption expands to its drain + kill pair.
//! let spot = FaultPlan::parse("preempt@40:1:20").unwrap();
//! assert_eq!(spot.render(), "drain@40:1,down@60:1");
//! ```

/// Spot-preemption notice window used when `preempt@t:n` omits one, s.
pub const DEFAULT_PREEMPT_NOTICE_S: f64 = 30.0;

/// Actuation latency used when `ctlnoise@t:n` omits the delay field, s.
pub const DEFAULT_CTL_DELAY_S: f64 = 0.05;
/// Write-drop probability used when `ctlnoise@t:n` omits it.
pub const DEFAULT_CTL_DROP_P: f64 = 0.1;
/// Write-misstep probability used when `ctlnoise@t:n` omits it.
pub const DEFAULT_CTL_MISSTEP_P: f64 = 0.05;

/// Direction of one fault transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Node loss: drain + power off + re-route.
    Down,
    /// Node recovery: power on + rejoin with cold telemetry.
    Up,
    /// Stop routing new ingress to the node; it keeps serving in-flight
    /// work (spot-preemption notice, administrative drain).
    Drain,
    /// Straggler onset: the node keeps serving but every step runs
    /// `factor`× slower, optionally under a thermal clock cap.
    Slow,
    /// Straggler recovery: slowdown and thermal cap lifted.
    Restore,
    /// Control-plane noise onset: the node's DVFS writes gain latency
    /// and are probabilistically dropped/misstepped (see
    /// [`FaultEvent::ctl_params`]); sensor quantization arms.
    CtlNoise,
    /// Control-plane noise lifted: actuation is instant and exact again.
    CtlQuiet,
    /// Telemetry blackout onset: sensed values freeze, event-driven
    /// policy feedback is suppressed.
    CtlBlackout,
    /// Telemetry blackout lifted: sensors come back live.
    CtlSense,
}

/// One scheduled fault transition.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    /// Virtual time of the transition, seconds (must be > 0).
    pub t_s: f64,
    /// Target node index.
    pub node: usize,
    /// Transition kind.
    pub kind: FaultKind,
    /// Performance slowdown multiplier ([`FaultKind::Slow`] only;
    /// 1.0 otherwise). Every prefill/decode step on the node takes
    /// `factor`× its nominal time while degraded.
    pub factor: f64,
    /// Thermal clock cap in MHz ([`FaultKind::Slow`] only; `u32::MAX`
    /// = no cap). Snapped down to the node's ladder grid when applied.
    pub cap_mhz: u32,
    /// Control-noise payload `[delay_s, drop_prob, misstep_prob]`
    /// ([`FaultKind::CtlNoise`] only; zeros otherwise).
    pub ctl_params: [f64; 3],
}

impl FaultEvent {
    /// An event with no straggler or control payload (factor 1, uncapped,
    /// zero noise).
    pub fn new(t_s: f64, node: usize, kind: FaultKind) -> FaultEvent {
        FaultEvent {
            t_s,
            node,
            kind,
            factor: 1.0,
            cap_mhz: u32::MAX,
            ctl_params: [0.0; 3],
        }
    }
}

/// A deterministic fault schedule: time-ordered degradation events.
/// The default (empty) plan is inert — a cluster run with it is
/// bit-identical to one without any chaos layer at all (tested).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Events sorted by time (ties in spell order).
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// No events scheduled?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Node indices scheduled to run degraded (straggler) at any point,
    /// ascending and deduplicated — reported in cluster results so a
    /// straggler run is flaggable from JSON.
    pub fn straggler_nodes(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .events
            .iter()
            .filter(|e| e.kind == FaultKind::Slow)
            .map(|e| e.node)
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Parse an explicit event list: comma-separated entries of
    ///
    /// * `down@<t>:<node>` / `up@<t>:<node>` — binary loss/recovery;
    /// * `drain@<t>:<node>` — stop new ingress, keep serving;
    /// * `preempt@<t>:<node>[:<notice_s>]` — expands to a drain at `t`
    ///   and a down at `t + notice_s` (default 30 s);
    /// * `slow@<t>:<node>:<factor>[:<cap_mhz>]` / `restore@<t>:<node>` —
    ///   straggler onset/recovery;
    /// * `rackdown@<t>:<a>-<b>` / `rackup@<t>:<a>-<b>` — expands to one
    ///   down/up per node of the inclusive range (correlated rack loss);
    /// * `ctlnoise@<t>:<node>[:<delay_s>[:<drop_p>[:<misstep_p>]]]` /
    ///   `ctlquiet@<t>:<node>` — control-plane actuation noise
    ///   onset/recovery (defaults: 0.05 s delay, 0.1 drop, 0.05 misstep);
    /// * `ctlblackout@<t0>-<t1>:<node>` — telemetry blackout over the
    ///   window, expanding to a `ctlblackout` at `t0` and a `ctlsense`
    ///   at `t1`; the single-time spellings `ctlblackout@<t>:<node>` /
    ///   `ctlsense@<t>:<node>` schedule the primitives directly (a
    ///   blackout with no later sense lasts to the end of the run).
    ///
    /// Events are sorted by time (stable, so equal-time events keep their
    /// spelled order; expansions keep ascending node order). An empty
    /// string is the empty plan.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (verb, rest) = tok.split_once('@').ok_or_else(|| {
                format!("bad fault event {tok:?}: expected <kind>@<t>:<node>")
            })?;
            let mut parts = rest.split(':');
            let t = parts.next().unwrap_or("");
            // Window spelling: `ctlblackout@<t0>-<t1>:<node>` expands to
            // the blackout/sense primitive pair before scalar-time parsing.
            if verb == "ctlblackout" {
                if let Some((a, b)) = t.split_once('-') {
                    let t0: f64 = a
                        .parse()
                        .map_err(|_| format!("bad blackout window {t:?} in {tok:?}"))?;
                    let t1: f64 = b
                        .parse()
                        .map_err(|_| format!("bad blackout window {t:?} in {tok:?}"))?;
                    if !t0.is_finite() || !t1.is_finite() || t0 <= 0.0 || t1 <= t0 {
                        return Err(format!(
                            "blackout window must satisfy 0 < t0 < t1, got {t:?} in {tok:?}"
                        ));
                    }
                    let target = parts
                        .next()
                        .ok_or_else(|| format!("bad fault event {tok:?}: missing ':<node>'"))?;
                    if parts.next().is_some() {
                        return Err(format!("bad fault event {tok:?}: trailing fields"));
                    }
                    let node: usize = target
                        .parse()
                        .map_err(|_| format!("bad fault node {target:?} in {tok:?}"))?;
                    events.push(FaultEvent::new(t0, node, FaultKind::CtlBlackout));
                    events.push(FaultEvent::new(t1, node, FaultKind::CtlSense));
                    continue;
                }
            }
            let t_s: f64 = t
                .parse()
                .map_err(|_| format!("bad fault time {t:?} in {tok:?}"))?;
            if !t_s.is_finite() || t_s <= 0.0 {
                return Err(format!("fault time must be finite and > 0, got {t_s}"));
            }
            let target = parts
                .next()
                .ok_or_else(|| format!("bad fault event {tok:?}: missing ':<node>'"))?;
            let extra: Vec<&str> = parts.collect();
            let parse_node = |node: &str| -> Result<usize, String> {
                node.parse()
                    .map_err(|_| format!("bad fault node {node:?} in {tok:?}"))
            };
            match verb {
                "down" | "up" => {
                    if !extra.is_empty() {
                        return Err(format!("bad fault event {tok:?}: trailing fields"));
                    }
                    let kind = if verb == "down" { FaultKind::Down } else { FaultKind::Up };
                    events.push(FaultEvent::new(t_s, parse_node(target)?, kind));
                }
                "drain" => {
                    if !extra.is_empty() {
                        return Err(format!("bad fault event {tok:?}: trailing fields"));
                    }
                    events.push(FaultEvent::new(t_s, parse_node(target)?, FaultKind::Drain));
                }
                "preempt" => {
                    if extra.len() > 1 {
                        return Err(format!("bad fault event {tok:?}: trailing fields"));
                    }
                    let notice: f64 = match extra.first() {
                        Some(n) => n
                            .parse()
                            .map_err(|_| format!("bad preemption notice {n:?} in {tok:?}"))?,
                        None => DEFAULT_PREEMPT_NOTICE_S,
                    };
                    if !notice.is_finite() || notice <= 0.0 {
                        return Err(format!(
                            "preemption notice must be finite and > 0, got {notice}"
                        ));
                    }
                    let node = parse_node(target)?;
                    events.push(FaultEvent::new(t_s, node, FaultKind::Drain));
                    events.push(FaultEvent::new(t_s + notice, node, FaultKind::Down));
                }
                "slow" => {
                    if extra.is_empty() || extra.len() > 2 {
                        return Err(format!(
                            "bad fault event {tok:?}: expected slow@<t>:<node>:<factor>[:<cap_mhz>]"
                        ));
                    }
                    let factor: f64 = extra[0]
                        .parse()
                        .map_err(|_| format!("bad slowdown factor {:?} in {tok:?}", extra[0]))?;
                    if !factor.is_finite() || factor < 1.0 {
                        return Err(format!(
                            "slowdown factor must be finite and >= 1, got {factor}"
                        ));
                    }
                    let cap_mhz: u32 = match extra.get(1) {
                        Some(c) => c
                            .parse()
                            .map_err(|_| format!("bad clock cap {c:?} in {tok:?}"))?,
                        None => u32::MAX,
                    };
                    if cap_mhz == 0 {
                        return Err(format!("clock cap must be > 0 in {tok:?}"));
                    }
                    events.push(FaultEvent {
                        factor,
                        cap_mhz,
                        ..FaultEvent::new(t_s, parse_node(target)?, FaultKind::Slow)
                    });
                }
                "restore" => {
                    if !extra.is_empty() {
                        return Err(format!("bad fault event {tok:?}: trailing fields"));
                    }
                    events.push(FaultEvent::new(t_s, parse_node(target)?, FaultKind::Restore));
                }
                "ctlnoise" => {
                    if extra.len() > 3 {
                        return Err(format!(
                            "bad fault event {tok:?}: expected \
                             ctlnoise@<t>:<node>[:<delay_s>[:<drop_p>[:<misstep_p>]]]"
                        ));
                    }
                    let defaults = [
                        DEFAULT_CTL_DELAY_S,
                        DEFAULT_CTL_DROP_P,
                        DEFAULT_CTL_MISSTEP_P,
                    ];
                    let mut ctl_params = defaults;
                    for (i, field) in extra.iter().enumerate() {
                        ctl_params[i] = field.parse().map_err(|_| {
                            format!("bad control-noise field {field:?} in {tok:?}")
                        })?;
                    }
                    if !ctl_params[0].is_finite() || ctl_params[0] < 0.0 {
                        return Err(format!(
                            "actuation delay must be finite and >= 0, got {} in {tok:?}",
                            ctl_params[0]
                        ));
                    }
                    for p in &ctl_params[1..] {
                        if !(0.0..=1.0).contains(p) {
                            return Err(format!(
                                "control-noise probability must be in [0, 1], got {p} in {tok:?}"
                            ));
                        }
                    }
                    events.push(FaultEvent {
                        ctl_params,
                        ..FaultEvent::new(t_s, parse_node(target)?, FaultKind::CtlNoise)
                    });
                }
                "ctlquiet" => {
                    if !extra.is_empty() {
                        return Err(format!("bad fault event {tok:?}: trailing fields"));
                    }
                    events.push(FaultEvent::new(t_s, parse_node(target)?, FaultKind::CtlQuiet));
                }
                "ctlsense" => {
                    if !extra.is_empty() {
                        return Err(format!("bad fault event {tok:?}: trailing fields"));
                    }
                    events.push(FaultEvent::new(t_s, parse_node(target)?, FaultKind::CtlSense));
                }
                "ctlblackout" => {
                    if !extra.is_empty() {
                        return Err(format!("bad fault event {tok:?}: trailing fields"));
                    }
                    let node = parse_node(target)?;
                    // `t` was already parsed above for the single-time
                    // spelling; a `t0-t1` window fails that parse and is
                    // handled here instead.
                    events.push(FaultEvent::new(t_s, node, FaultKind::CtlBlackout));
                }
                "rackdown" | "rackup" => {
                    if !extra.is_empty() {
                        return Err(format!("bad fault event {tok:?}: trailing fields"));
                    }
                    let (a, b) = target.split_once('-').ok_or_else(|| {
                        format!("bad rack range {target:?} in {tok:?}: expected <a>-<b>")
                    })?;
                    let a: usize = a
                        .parse()
                        .map_err(|_| format!("bad rack range {target:?} in {tok:?}"))?;
                    let b: usize = b
                        .parse()
                        .map_err(|_| format!("bad rack range {target:?} in {tok:?}"))?;
                    if a > b {
                        return Err(format!(
                            "bad rack range {target:?} in {tok:?}: start exceeds end"
                        ));
                    }
                    let kind = if verb == "rackdown" { FaultKind::Down } else { FaultKind::Up };
                    for node in a..=b {
                        events.push(FaultEvent::new(t_s, node, kind));
                    }
                }
                _ => {
                    return Err(format!(
                        "bad fault event {tok:?}: unknown kind {verb:?} (expected down, up, \
                         drain, preempt, slow, restore, rackdown, rackup, ctlnoise, ctlquiet, \
                         ctlblackout or ctlsense)"
                    ));
                }
            }
        }
        let mut plan = FaultPlan { events };
        plan.sort();
        Ok(plan)
    }

    /// Sort events by time (stable: equal-time events keep insert order).
    fn sort(&mut self) {
        self.events.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));
    }

    /// Merge another plan's events into this one, re-sorting by time
    /// (stable, so equal-time events keep `self`-before-`other` order).
    /// Used by the matrix `--ctl-faults` axis to compose a control-plane
    /// schedule with a capacity fault schedule; the merged plan goes
    /// through [`FaultPlan::validate`] like any other.
    pub fn merged(mut self, other: FaultPlan) -> FaultPlan {
        self.events.extend(other.events);
        self.sort();
        self
    }

    /// Check the schedule against a node count. Every event must target a
    /// real node; the per-node state machine must stay consistent (a node
    /// only goes down while up or draining, only recovers while down,
    /// drains once per up-period, slows only while alive and not already
    /// slow, restores only while slow); and at least one node stays alive
    /// at every instant (a fully dark cluster cannot re-route its drained
    /// requests anywhere). Straggler payloads are re-checked here so
    /// programmatically built plans get the same errors as parsed ones.
    pub fn validate(&self, nodes: usize) -> Result<(), String> {
        let mut down = vec![false; nodes];
        let mut draining = vec![false; nodes];
        let mut slow = vec![false; nodes];
        let mut noisy = vec![false; nodes];
        let mut dark = vec![false; nodes];
        let mut down_count = 0usize;
        for ev in &self.events {
            if ev.node >= nodes {
                return Err(format!(
                    "fault targets node {} but the cluster has {nodes} nodes",
                    ev.node
                ));
            }
            if !ev.t_s.is_finite() || ev.t_s <= 0.0 {
                return Err(format!(
                    "fault time must be finite and > 0, got {} (node {})",
                    ev.t_s, ev.node
                ));
            }
            match ev.kind {
                FaultKind::Down => {
                    if down[ev.node] {
                        return Err(format!("node {} downed twice (t={})", ev.node, ev.t_s));
                    }
                    if down_count + 1 >= nodes {
                        return Err(format!(
                            "fault plan would leave zero alive nodes at t={}",
                            ev.t_s
                        ));
                    }
                    down[ev.node] = true;
                    // Death clears the administrative, straggler and
                    // control-plane state; recovery brings the node back
                    // clean (the engine resets its control plane to the
                    // config baseline at the power cycle).
                    draining[ev.node] = false;
                    slow[ev.node] = false;
                    noisy[ev.node] = false;
                    dark[ev.node] = false;
                    down_count += 1;
                }
                FaultKind::Up => {
                    if !down[ev.node] {
                        return Err(format!(
                            "node {} recovered while already up (t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    down[ev.node] = false;
                    down_count -= 1;
                }
                FaultKind::Drain => {
                    if down[ev.node] {
                        return Err(format!(
                            "node {} drained while down (t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    if draining[ev.node] {
                        return Err(format!(
                            "node {} drained twice without going down (t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    draining[ev.node] = true;
                }
                FaultKind::Slow => {
                    if down[ev.node] {
                        return Err(format!(
                            "node {} slowed while down (t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    if slow[ev.node] {
                        return Err(format!(
                            "node {} slowed twice without a restore (t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    if !ev.factor.is_finite() || ev.factor < 1.0 {
                        return Err(format!(
                            "slowdown factor must be finite and >= 1, got {} (node {}, t={})",
                            ev.factor, ev.node, ev.t_s
                        ));
                    }
                    if ev.cap_mhz == 0 {
                        return Err(format!(
                            "straggler clock cap must be > 0 (node {}, t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    slow[ev.node] = true;
                }
                FaultKind::Restore => {
                    if !slow[ev.node] {
                        return Err(format!(
                            "node {} restored while not degraded (t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    slow[ev.node] = false;
                }
                FaultKind::CtlNoise => {
                    if down[ev.node] {
                        return Err(format!(
                            "node {} control-noised while down (t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    if noisy[ev.node] {
                        return Err(format!(
                            "node {} control-noised twice without a ctlquiet (t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    let [delay, drop, misstep] = ev.ctl_params;
                    if !delay.is_finite() || delay < 0.0 {
                        return Err(format!(
                            "actuation delay must be finite and >= 0, got {delay} \
                             (node {}, t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    for p in [drop, misstep] {
                        if !(0.0..=1.0).contains(&p) {
                            return Err(format!(
                                "control-noise probability must be in [0, 1], got {p} \
                                 (node {}, t={})",
                                ev.node, ev.t_s
                            ));
                        }
                    }
                    noisy[ev.node] = true;
                }
                FaultKind::CtlQuiet => {
                    if !noisy[ev.node] {
                        return Err(format!(
                            "node {} ctlquiet while its control plane is clean (t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    noisy[ev.node] = false;
                }
                FaultKind::CtlBlackout => {
                    if down[ev.node] {
                        return Err(format!(
                            "node {} blacked out while down (t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    if dark[ev.node] {
                        return Err(format!(
                            "node {} blacked out twice without a ctlsense (t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    dark[ev.node] = true;
                }
                FaultKind::CtlSense => {
                    if !dark[ev.node] {
                        return Err(format!(
                            "node {} ctlsense while its telemetry is live (t={})",
                            ev.node, ev.t_s
                        ));
                    }
                    dark[ev.node] = false;
                }
            }
        }
        Ok(())
    }

    /// Render back to the explicit event-list spelling. `preempt` and
    /// `rackdown`/`rackup` spellings render as their expansions (the plan
    /// only stores primitive events), so render → parse round-trips.
    pub fn render(&self) -> String {
        self.events
            .iter()
            .map(|e| match e.kind {
                FaultKind::Down => format!("down@{}:{}", e.t_s, e.node),
                FaultKind::Up => format!("up@{}:{}", e.t_s, e.node),
                FaultKind::Drain => format!("drain@{}:{}", e.t_s, e.node),
                FaultKind::Slow => {
                    if e.cap_mhz == u32::MAX {
                        format!("slow@{}:{}:{}", e.t_s, e.node, e.factor)
                    } else {
                        format!("slow@{}:{}:{}:{}", e.t_s, e.node, e.factor, e.cap_mhz)
                    }
                }
                FaultKind::Restore => format!("restore@{}:{}", e.t_s, e.node),
                FaultKind::CtlNoise => format!(
                    "ctlnoise@{}:{}:{}:{}:{}",
                    e.t_s, e.node, e.ctl_params[0], e.ctl_params[1], e.ctl_params[2]
                ),
                FaultKind::CtlQuiet => format!("ctlquiet@{}:{}", e.t_s, e.node),
                FaultKind::CtlBlackout => format!("ctlblackout@{}:{}", e.t_s, e.node),
                FaultKind::CtlSense => format!("ctlsense@{}:{}", e.t_s, e.node),
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// A named fault scenario, resolvable against a concrete cluster shape.
/// This is the matrix-axis form: presets keep a stable label per cell
/// while the actual event times scale with the cell's trace duration.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultSpec {
    /// No chaos (the empty plan).
    None,
    /// The highest-index node fails at ⅓ of the trace and never returns.
    OneDown,
    /// The highest-index node fails at ⅓ and recovers at ⅔ of the trace.
    Flap,
    /// Spot preemption of the highest-index node: drain notice at ⅓,
    /// kill at ½, capacity back at ⅔.
    Spot,
    /// The highest-index node runs as a 2× straggler (thermally capped
    /// near the ladder floor region) between ⅓ and ⅔ of the trace.
    Straggler,
    /// An explicit event list (see [`FaultPlan::parse`]).
    Explicit(FaultPlan),
}

impl FaultSpec {
    /// Stable label (also the CLI spelling; explicit plans render their
    /// event list).
    pub fn name(&self) -> String {
        match self {
            FaultSpec::None => "none".into(),
            FaultSpec::OneDown => "onedown".into(),
            FaultSpec::Flap => "flap".into(),
            FaultSpec::Spot => "spot".into(),
            FaultSpec::Straggler => "straggler".into(),
            FaultSpec::Explicit(p) => p.render(),
        }
    }

    /// Parse a preset name or an explicit event list.
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "none" | "" => Ok(FaultSpec::None),
            "onedown" | "one-down" | "nodeloss" => Ok(FaultSpec::OneDown),
            "flap" => Ok(FaultSpec::Flap),
            "spot" | "preempt" => Ok(FaultSpec::Spot),
            "straggler" | "slow" => Ok(FaultSpec::Straggler),
            _ => FaultPlan::parse(s).map(FaultSpec::Explicit),
        }
    }

    /// Resolve to a concrete plan. Presets that would down the only node
    /// of a 1-node cluster resolve to the empty plan (there is nowhere to
    /// re-route, so chaos is a no-op there by construction); the
    /// straggler preset stays active on one node — a slow node still
    /// serves.
    pub fn plan(&self, nodes: usize, duration_s: f64) -> FaultPlan {
        let victim = nodes.saturating_sub(1);
        match self {
            FaultSpec::None => FaultPlan::default(),
            FaultSpec::OneDown if nodes >= 2 => FaultPlan {
                events: vec![FaultEvent::new(duration_s / 3.0, victim, FaultKind::Down)],
            },
            FaultSpec::Flap if nodes >= 2 => FaultPlan {
                events: vec![
                    FaultEvent::new(duration_s / 3.0, victim, FaultKind::Down),
                    FaultEvent::new(duration_s * 2.0 / 3.0, victim, FaultKind::Up),
                ],
            },
            FaultSpec::Spot if nodes >= 2 => FaultPlan {
                events: vec![
                    FaultEvent::new(duration_s / 3.0, victim, FaultKind::Drain),
                    FaultEvent::new(duration_s / 2.0, victim, FaultKind::Down),
                    FaultEvent::new(duration_s * 2.0 / 3.0, victim, FaultKind::Up),
                ],
            },
            FaultSpec::Straggler => FaultPlan {
                events: vec![
                    FaultEvent {
                        factor: 2.0,
                        cap_mhz: 600,
                        ..FaultEvent::new(duration_s / 3.0, victim, FaultKind::Slow)
                    },
                    FaultEvent::new(duration_s * 2.0 / 3.0, victim, FaultKind::Restore),
                ],
            },
            FaultSpec::OneDown | FaultSpec::Flap | FaultSpec::Spot => FaultPlan::default(),
            FaultSpec::Explicit(p) => p.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_render_round_trip() {
        let plan = FaultPlan::parse("down@40:1,up@80:1,down@100:0").unwrap();
        assert_eq!(plan.events.len(), 3);
        assert_eq!(plan.render(), "down@40:1,up@80:1,down@100:0");
        assert_eq!(FaultPlan::parse(&plan.render()).unwrap(), plan);
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn parse_sorts_by_time() {
        let plan = FaultPlan::parse("up@80:1,down@40:1").unwrap();
        assert_eq!(plan.events[0].kind, FaultKind::Down);
        assert_eq!(plan.events[1].kind, FaultKind::Up);
    }

    #[test]
    fn parse_rejects_malformed_events() {
        assert!(FaultPlan::parse("sideways@40:1").is_err());
        assert!(FaultPlan::parse("down@40").is_err());
        assert!(FaultPlan::parse("down@-1:0").is_err());
        assert!(FaultPlan::parse("down@0:0").is_err());
        assert!(FaultPlan::parse("down@nan:0").is_err());
        assert!(FaultPlan::parse("down@40:x").is_err());
        assert!(FaultPlan::parse("down@40:1:9").is_err());
        assert!(FaultPlan::parse("40:1").is_err());
    }

    #[test]
    fn preempt_expands_to_drain_plus_down() {
        let plan = FaultPlan::parse("preempt@40:1:20").unwrap();
        assert_eq!(plan.render(), "drain@40:1,down@60:1");
        // Default notice window.
        let plan = FaultPlan::parse("preempt@40:2").unwrap();
        assert_eq!(plan.events[1].t_s, 40.0 + DEFAULT_PREEMPT_NOTICE_S);
        assert_eq!(plan.events[0].kind, FaultKind::Drain);
        assert_eq!(plan.events[1].kind, FaultKind::Down);
        // Bad notice windows.
        assert!(FaultPlan::parse("preempt@40:1:0").is_err());
        assert!(FaultPlan::parse("preempt@40:1:-5").is_err());
        assert!(FaultPlan::parse("preempt@40:1:nan").is_err());
        assert!(FaultPlan::parse("preempt@40:1:20:9").is_err());
    }

    #[test]
    fn rack_events_expand_to_node_ranges() {
        let plan = FaultPlan::parse("rackdown@40:1-3,rackup@80:1-3").unwrap();
        assert_eq!(
            plan.render(),
            "down@40:1,down@40:2,down@40:3,up@80:1,up@80:2,up@80:3"
        );
        plan.validate(5).unwrap();
        // The whole rack counts against liveness.
        assert!(FaultPlan::parse("rackdown@40:0-3").unwrap().validate(4).is_err());
        // Degenerate single-node rack.
        assert_eq!(FaultPlan::parse("rackdown@40:2-2").unwrap().events.len(), 1);
        // Malformed ranges.
        assert!(FaultPlan::parse("rackdown@40:3-1").is_err());
        assert!(FaultPlan::parse("rackdown@40:3").is_err());
        assert!(FaultPlan::parse("rackdown@40:a-b").is_err());
    }

    #[test]
    fn straggler_grammar_round_trips_and_validates() {
        let plan = FaultPlan::parse("slow@40:1:2.5:600,restore@80:1").unwrap();
        assert_eq!(plan.events[0].kind, FaultKind::Slow);
        assert_eq!(plan.events[0].factor, 2.5);
        assert_eq!(plan.events[0].cap_mhz, 600);
        assert_eq!(plan.render(), "slow@40:1:2.5:600,restore@80:1");
        assert_eq!(FaultPlan::parse(&plan.render()).unwrap(), plan);
        plan.validate(2).unwrap();
        assert_eq!(plan.straggler_nodes(), vec![1]);
        // Uncapped spelling omits the cap field on render.
        let free = FaultPlan::parse("slow@40:0:3").unwrap();
        assert_eq!(free.render(), "slow@40:0:3");
        assert_eq!(free.events[0].cap_mhz, u32::MAX);
        // Bad payloads.
        assert!(FaultPlan::parse("slow@40:1").is_err());
        assert!(FaultPlan::parse("slow@40:1:0.5").is_err());
        assert!(FaultPlan::parse("slow@40:1:nan").is_err());
        assert!(FaultPlan::parse("slow@40:1:2:0").is_err());
        assert!(FaultPlan::parse("restore@40:1:2").is_err());
    }

    #[test]
    fn ctl_noise_grammar_round_trips_and_validates() {
        // Full spelling round-trips exactly.
        let plan = FaultPlan::parse("ctlnoise@40:1:0.1:0.2:0.3,ctlquiet@80:1").unwrap();
        assert_eq!(plan.events[0].kind, FaultKind::CtlNoise);
        assert_eq!(plan.events[0].ctl_params, [0.1, 0.2, 0.3]);
        assert_eq!(plan.render(), "ctlnoise@40:1:0.1:0.2:0.3,ctlquiet@80:1");
        assert_eq!(FaultPlan::parse(&plan.render()).unwrap(), plan);
        plan.validate(2).unwrap();
        // Omitted fields take the documented defaults.
        let d = FaultPlan::parse("ctlnoise@40:0").unwrap();
        assert_eq!(
            d.events[0].ctl_params,
            [DEFAULT_CTL_DELAY_S, DEFAULT_CTL_DROP_P, DEFAULT_CTL_MISSTEP_P]
        );
        let partial = FaultPlan::parse("ctlnoise@40:0:0.2").unwrap();
        assert_eq!(
            partial.events[0].ctl_params,
            [0.2, DEFAULT_CTL_DROP_P, DEFAULT_CTL_MISSTEP_P]
        );
        // Bad payloads.
        assert!(FaultPlan::parse("ctlnoise@40:0:nan").is_err());
        assert!(FaultPlan::parse("ctlnoise@40:0:-0.1").is_err());
        assert!(FaultPlan::parse("ctlnoise@40:0:0.1:1.5").is_err());
        assert!(FaultPlan::parse("ctlnoise@40:0:0.1:0.2:-1").is_err());
        assert!(FaultPlan::parse("ctlnoise@40:0:1:2:3:4").is_err());
        assert!(FaultPlan::parse("ctlquiet@40:0:9").is_err());
    }

    #[test]
    fn ctl_blackout_window_expands_to_primitive_pair() {
        let plan = FaultPlan::parse("ctlblackout@40-60:1").unwrap();
        assert_eq!(plan.render(), "ctlblackout@40:1,ctlsense@60:1");
        assert_eq!(FaultPlan::parse(&plan.render()).unwrap(), plan);
        plan.validate(2).unwrap();
        // Open-ended blackout (no sense until the end of the run).
        let open = FaultPlan::parse("ctlblackout@40:2").unwrap();
        assert_eq!(open.events.len(), 1);
        open.validate(3).unwrap();
        // Malformed windows.
        assert!(FaultPlan::parse("ctlblackout@60-40:1").is_err());
        assert!(FaultPlan::parse("ctlblackout@40-40:1").is_err());
        assert!(FaultPlan::parse("ctlblackout@a-b:1").is_err());
        assert!(FaultPlan::parse("ctlblackout@40-60:1:9").is_err());
        assert!(FaultPlan::parse("ctlblackout@40-60").is_err());
        assert!(FaultPlan::parse("ctlsense@40:1:9").is_err());
    }

    #[test]
    fn validate_enforces_ctl_state_machine() {
        // Strict on/off pairing per node.
        assert!(FaultPlan::parse("ctlnoise@40:1,ctlnoise@50:1")
            .unwrap()
            .validate(2)
            .is_err());
        assert!(FaultPlan::parse("ctlquiet@40:1").unwrap().validate(2).is_err());
        assert!(FaultPlan::parse("ctlblackout@40:1,ctlblackout@50:1")
            .unwrap()
            .validate(2)
            .is_err());
        assert!(FaultPlan::parse("ctlsense@40:1").unwrap().validate(2).is_err());
        // Control faults on a dead node are rejected.
        assert!(FaultPlan::parse("down@40:1,ctlnoise@50:1")
            .unwrap()
            .validate(3)
            .is_err());
        assert!(FaultPlan::parse("down@40:1,ctlblackout@50:1")
            .unwrap()
            .validate(3)
            .is_err());
        // Down clears both flags: the off verb after recovery is stale.
        assert!(
            FaultPlan::parse("ctlnoise@30:1,down@40:1,up@50:1,ctlquiet@60:1")
                .unwrap()
                .validate(3)
                .is_err()
        );
        // Control faults compose with straggler state on one node.
        FaultPlan::parse("slow@30:1:2:900,ctlnoise@40:1,ctlblackout@50-70:1,ctlquiet@80:1,restore@90:1")
            .unwrap()
            .validate(2)
            .unwrap();
        // Programmatic plans get payloads re-checked.
        let bad = FaultPlan {
            events: vec![FaultEvent {
                ctl_params: [0.05, 2.0, 0.0],
                ..FaultEvent::new(10.0, 0, FaultKind::CtlNoise)
            }],
        };
        assert!(bad.validate(2).is_err());
    }

    #[test]
    fn validate_enforces_liveness_and_state() {
        let plan = FaultPlan::parse("down@40:1,up@80:1").unwrap();
        plan.validate(2).unwrap();
        // Bad node index.
        assert!(FaultPlan::parse("down@40:5").unwrap().validate(2).is_err());
        // Double down.
        assert!(FaultPlan::parse("down@40:1,down@50:1")
            .unwrap()
            .validate(3)
            .is_err());
        // Recovery preceding the failure (sorted order puts up first).
        assert!(FaultPlan::parse("down@80:1,up@40:1")
            .unwrap()
            .validate(3)
            .is_err());
        // Up of an alive node.
        assert!(FaultPlan::parse("up@40:1").unwrap().validate(2).is_err());
        // All nodes dark.
        assert!(FaultPlan::parse("down@40:0,down@50:1")
            .unwrap()
            .validate(2)
            .is_err());
        // ... but fine with a third node alive.
        FaultPlan::parse("down@40:0,down@50:1")
            .unwrap()
            .validate(3)
            .unwrap();
    }

    #[test]
    fn validate_enforces_degradation_state_machine() {
        // Drain → down → up is the canonical preemption cycle.
        FaultPlan::parse("drain@40:1,down@60:1,up@80:1")
            .unwrap()
            .validate(2)
            .unwrap();
        // A second drain without an intervening down is a spec bug.
        assert!(FaultPlan::parse("drain@40:1,drain@50:1")
            .unwrap()
            .validate(2)
            .is_err());
        // ... but drain → down → up → drain is fine (new up-period).
        FaultPlan::parse("drain@40:1,down@50:1,up@60:1,drain@70:1")
            .unwrap()
            .validate(2)
            .unwrap();
        // Draining or slowing a dead node is rejected.
        assert!(FaultPlan::parse("down@40:1,drain@50:1")
            .unwrap()
            .validate(3)
            .is_err());
        assert!(FaultPlan::parse("down@40:1,slow@50:1:2")
            .unwrap()
            .validate(3)
            .is_err());
        // Double slow / restore-without-slow are rejected.
        assert!(FaultPlan::parse("slow@40:1:2,slow@50:1:3")
            .unwrap()
            .validate(2)
            .is_err());
        assert!(FaultPlan::parse("restore@40:1").unwrap().validate(2).is_err());
        // Down clears the slow flag: a restore after recovery is stale.
        assert!(FaultPlan::parse("slow@30:1:2,down@40:1,up@50:1,restore@60:1")
            .unwrap()
            .validate(3)
            .is_err());
        // Programmatic plans get payloads re-checked.
        let bad = FaultPlan {
            events: vec![FaultEvent {
                factor: 0.25,
                ..FaultEvent::new(10.0, 0, FaultKind::Slow)
            }],
        };
        assert!(bad.validate(2).is_err());
    }

    #[test]
    fn spec_names_round_trip_through_parse() {
        for spec in [
            FaultSpec::None,
            FaultSpec::OneDown,
            FaultSpec::Flap,
            FaultSpec::Spot,
            FaultSpec::Straggler,
        ] {
            assert_eq!(FaultSpec::parse(&spec.name()).unwrap(), spec);
        }
        let explicit = FaultSpec::parse("down@40:1,up@80:1").unwrap();
        assert_eq!(FaultSpec::parse(&explicit.name()).unwrap(), explicit);
        assert!(FaultSpec::parse("meteor").is_err());
    }

    #[test]
    fn presets_resolve_against_shape_and_duration() {
        let p = FaultSpec::OneDown.plan(3, 90.0);
        assert_eq!(p.events.len(), 1);
        assert_eq!(p.events[0].node, 2);
        assert!((p.events[0].t_s - 30.0).abs() < 1e-12);
        let f = FaultSpec::Flap.plan(2, 90.0);
        assert_eq!(f.events.len(), 2);
        assert!((f.events[1].t_s - 60.0).abs() < 1e-12);
        f.validate(2).unwrap();
        // Spot: drain notice, kill, recovery — validates as a cycle.
        let s = FaultSpec::Spot.plan(2, 90.0);
        assert_eq!(
            s.events.iter().map(|e| e.kind).collect::<Vec<_>>(),
            vec![FaultKind::Drain, FaultKind::Down, FaultKind::Up]
        );
        s.validate(2).unwrap();
        // Straggler: slow then restore, active even on one node.
        let g = FaultSpec::Straggler.plan(1, 90.0);
        assert_eq!(g.events[0].kind, FaultKind::Slow);
        assert_eq!(g.events[0].factor, 2.0);
        g.validate(1).unwrap();
        assert_eq!(g.straggler_nodes(), vec![0]);
        // Loss presets are inert on a single node and for `none`.
        assert!(FaultSpec::OneDown.plan(1, 90.0).is_empty());
        assert!(FaultSpec::Flap.plan(1, 90.0).is_empty());
        assert!(FaultSpec::Spot.plan(1, 90.0).is_empty());
        assert!(FaultSpec::None.plan(4, 90.0).is_empty());
    }
}

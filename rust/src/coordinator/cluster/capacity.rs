//! Elastic-capacity knobs: the endogenous autoscaler
//! ([`CapacityConfig`]) and graceful overload shedding at ingress
//! ([`ShedConfig`]).
//!
//! Both are *off by default* — a [`ClusterConfig`](super::ClusterConfig)
//! without them runs the event loop bit-exactly as before (tested). When
//! enabled:
//!
//! * The **capacity controller** is a deterministic event source on the
//!   shared cluster clock (ordered after migrations at equal timestamps).
//!   Every `check_epoch_s` it compares mean prefill backlog per routable
//!   node against `up_backlog`/`down_backlog` watermarks: above the high
//!   watermark it boots one cold node (joining `boot_s` later, cold
//!   telemetry); below the low watermark for `down_idle_epochs`
//!   *consecutive* checks it parks one idle node (never below
//!   `min_live`). The watermark gap plus the consecutive-epoch
//!   requirement is the hysteresis that keeps it from flapping against
//!   the power arbiter's epoch-by-epoch re-splits. Parked nodes draw
//!   `warm_idle_w` each, metered into the cluster energy integral as
//!   `warm_energy_j` — a warm pool is not free.
//! * The **shed policy** gates admission when the same backlog signal
//!   exceeds `queue_depth`: the arrival is deferred with exponential
//!   backoff (`backoff_s`, doubling per attempt) and re-offered through
//!   the retry event lane; after `max_retries` failed offers it is shed
//!   permanently. Interactive (short/medium-prompt) requests get a 2×
//!   deeper threshold, so batch-class long prompts shed first. Every
//!   arrival lands in exactly one terminal bucket:
//!   `completed + shed == arrived` (property-tested).

/// Autoscaler configuration (`[capacity]` / `--capacity`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CapacityConfig {
    /// Nodes that start *parked* (the highest-index ones): warm spares
    /// the controller can boot under load. Must leave at least
    /// `min_live` nodes live at t=0.
    pub warm: usize,
    /// Never park below this many live nodes.
    pub min_live: usize,
    /// Boot latency of a provisioned node, seconds (cold → serving).
    pub boot_s: f64,
    /// Controller check interval, seconds.
    pub check_epoch_s: f64,
    /// Scale up when mean prefill backlog per routable node exceeds this.
    pub up_backlog: f64,
    /// Scale down only while the same signal is below this (with
    /// `up_backlog > down_backlog` the gap is the hysteresis band).
    pub down_backlog: f64,
    /// Consecutive below-watermark checks required before a park.
    pub down_idle_epochs: u32,
    /// Idle draw of one parked (warm) node, watts — metered into the
    /// cluster energy integral as `warm_energy_j`.
    pub warm_idle_w: f64,
}

impl Default for CapacityConfig {
    fn default() -> Self {
        CapacityConfig {
            warm: 0,
            min_live: 1,
            boot_s: 15.0,
            check_epoch_s: 5.0,
            up_backlog: 4.0,
            down_backlog: 0.25,
            down_idle_epochs: 3,
            warm_idle_w: 350.0,
        }
    }
}

impl CapacityConfig {
    /// Reject shapes the controller cannot run against `nodes`.
    pub fn validate(&self, nodes: usize) -> Result<(), String> {
        if self.min_live == 0 {
            return Err("capacity.min_live must be >= 1".into());
        }
        if self.min_live > nodes {
            return Err(format!(
                "capacity.min_live {} exceeds the cluster's {nodes} nodes",
                self.min_live
            ));
        }
        if self.warm + self.min_live > nodes {
            return Err(format!(
                "capacity.warm {} would park below min_live {} on a {nodes}-node cluster",
                self.warm, self.min_live
            ));
        }
        if !(self.boot_s.is_finite() && self.boot_s > 0.0) {
            return Err("capacity.boot_s must be finite and > 0".into());
        }
        if !(self.check_epoch_s.is_finite() && self.check_epoch_s > 0.0) {
            return Err("capacity.check_epoch_s must be finite and > 0".into());
        }
        if self.up_backlog.is_nan() || self.down_backlog.is_nan() {
            return Err("capacity watermarks must not be NaN".into());
        }
        if self.down_backlog > self.up_backlog {
            return Err(format!(
                "capacity.down_backlog {} must not exceed up_backlog {} \
                 (the gap is the hysteresis band)",
                self.down_backlog, self.up_backlog
            ));
        }
        if self.down_idle_epochs == 0 {
            return Err("capacity.down_idle_epochs must be >= 1".into());
        }
        if !(self.warm_idle_w.is_finite() && self.warm_idle_w >= 0.0) {
            return Err("capacity.warm_idle_w must be finite and >= 0".into());
        }
        Ok(())
    }
}

/// Overload-shedding configuration (`[shed]` / `--shed`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShedConfig {
    /// Mean prefill backlog per live node beyond which arrivals are
    /// deferred/shed. `f64::INFINITY` = never shed (inert).
    pub queue_depth: f64,
    /// Base retry backoff, seconds (doubles per attempt).
    pub backoff_s: f64,
    /// Re-offers before a request is shed permanently.
    pub max_retries: u32,
}

impl Default for ShedConfig {
    fn default() -> Self {
        ShedConfig {
            queue_depth: 12.0,
            backoff_s: 2.0,
            max_retries: 3,
        }
    }
}

impl ShedConfig {
    /// Reject nonsensical shed policies.
    pub fn validate(&self) -> Result<(), String> {
        if self.queue_depth.is_nan() || self.queue_depth <= 0.0 {
            return Err("shed.queue_depth must be > 0 (inf = never shed)".into());
        }
        if !(self.backoff_s.is_finite() && self.backoff_s > 0.0) {
            return Err("shed.backoff_s must be finite and > 0".into());
        }
        Ok(())
    }

    /// Admission threshold for one request: interactive (short/medium
    /// prompt) classes get twice the depth, so batch-class long prompts
    /// shed first under pressure.
    pub fn threshold_for(&self, interactive: bool) -> f64 {
        if interactive {
            self.queue_depth * 2.0
        } else {
            self.queue_depth
        }
    }

    /// Backoff before re-offer `attempt` (0-based): exponential,
    /// `backoff_s × 2^attempt`, capped at 2¹⁶× to stay finite.
    pub fn backoff_for(&self, attempt: u32) -> f64 {
        self.backoff_s * (1u64 << attempt.min(16)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_defaults_validate_and_hysteresis_band_is_enforced() {
        let c = CapacityConfig::default();
        c.validate(2).unwrap();
        assert!(CapacityConfig { min_live: 0, ..c }.validate(2).is_err());
        assert!(CapacityConfig { min_live: 3, ..c }.validate(2).is_err());
        assert!(CapacityConfig { warm: 2, ..c }.validate(2).is_err());
        CapacityConfig { warm: 1, ..c }.validate(2).unwrap();
        assert!(CapacityConfig { boot_s: 0.0, ..c }.validate(2).is_err());
        assert!(CapacityConfig {
            check_epoch_s: f64::NAN,
            ..c
        }
        .validate(2)
        .is_err());
        assert!(CapacityConfig {
            up_backlog: 1.0,
            down_backlog: 2.0,
            ..c
        }
        .validate(2)
        .is_err());
        assert!(CapacityConfig {
            down_idle_epochs: 0,
            ..c
        }
        .validate(2)
        .is_err());
        assert!(CapacityConfig {
            warm_idle_w: -1.0,
            ..c
        }
        .validate(2)
        .is_err());
    }

    #[test]
    fn shed_thresholds_and_backoff() {
        let s = ShedConfig::default();
        s.validate().unwrap();
        assert_eq!(s.threshold_for(false), 12.0);
        assert_eq!(s.threshold_for(true), 24.0);
        assert_eq!(s.backoff_for(0), 2.0);
        assert_eq!(s.backoff_for(1), 4.0);
        assert_eq!(s.backoff_for(3), 16.0);
        assert!(s.backoff_for(64).is_finite());
        // Infinite depth is the inert spelling and validates.
        ShedConfig {
            queue_depth: f64::INFINITY,
            ..s
        }
        .validate()
        .unwrap();
        assert!(ShedConfig {
            queue_depth: 0.0,
            ..s
        }
        .validate()
        .is_err());
        assert!(ShedConfig {
            backoff_s: 0.0,
            ..s
        }
        .validate()
        .is_err());
    }
}

//! The Layer-3 coordinator: adaptive prompt routing, per-class queues,
//! continuous-batching decode pools, and the discrete-event serving engine
//! that binds workers, governors and telemetry together.
//!
//! The same routing/queue/controller logic drives both the simulated
//! DGX-A100 node (trace experiments, `engine`) and the real PJRT serving
//! path (`crate::server`).

pub mod cluster;
pub mod engine;
pub mod policy;
pub mod router;
pub mod telemetry;

pub use cluster::{
    run_cluster, ArbiterStrategy, ClusterConfig, ClusterResult, FaultPlan, FaultSpec, LbPolicy,
    NodeSpec,
};
pub use engine::{run, Engine, RunOptions, RunResult};
pub use policy::{DvfsPolicy, PolicyDiagnostics};
pub use router::Router;
pub use telemetry::{ClockPlan, PoolView, TickSpec};

//! The pluggable DVFS policy layer.
//!
//! [`DvfsPolicy`] is the contract between the serving engine and a
//! frequency governor: the engine delivers telemetry (periodic
//! [`PoolView`] snapshots plus event-driven TBT/token feedback and
//! prefill queue boundaries) and the policy answers with ladder clocks.
//! Policies never touch queues, GPUs or the event loop, so adding a
//! governor means implementing this trait and registering it in
//! [`build`] — the event loop does not change.
//!
//! Shipped implementations:
//! * [`GreenLlmPolicy`] — the paper's phase-specific stack: queueing-aware
//!   prefill optimizer + dual-loop decode controller (§3.2–3.3).
//! * [`DefaultNvPolicy`] — the stock-NVIDIA-governor baseline.
//! * [`FixedPolicy`] — one static application clock everywhere.
//! * [`ThrottlePolicy`] — throttLL'eM-lite 1 Hz predictive throttling.
//! * [`AgftPolicy`] — AGFT-style online adaptive tuner (arXiv:2508.01744):
//!   per-worker ε-greedy Q-learning over ladder moves with an SLO
//!   guardrail.
//! * [`PiTbtPolicy`] — a plain PI feedback controller on P95 TBT, the
//!   simplest dynamic baseline.
//!
//! **Per-pool policies under disaggregation.** A disaggregated cluster
//! (`[disagg]` / `--disagg`) may override the method per pool —
//! `prefill_method` / `decode_method` in
//! [`DisaggConfig`](crate::coordinator::cluster::disagg::DisaggConfig) —
//! so each pool runs the governor suited to its own SLO: prefill nodes
//! chase TTFT (their decode pool sits empty except for fault/spill
//! traffic), decode nodes chase the TBT tail. Nothing here changes: the
//! cluster loop simply builds each node's engine with its pool's method,
//! and the policy sees an ordinary engine.

use crate::config::{Config, Method};
use crate::coordinator::telemetry::{ClockPlan, PoolView, TickSpec};
use crate::dvfs::decode_ctl::DecodeController;
use crate::dvfs::governor::DefaultNvGovernor;
use crate::dvfs::prefill_opt::{PrefillJobView, PrefillOptimizer};
use crate::dvfs::profiler::Profiler;
use crate::gpu::freq::FreqLadder;
use crate::gpu::perf::PerfModel;
use crate::gpu::power::PowerModel;
use crate::metrics::{SlidingP95, TpsWindow};
use crate::util::rng::Pcg64;

/// Mean context length assumed when building the decode band table.
pub const TABLE_AVG_CTX: f64 = 600.0;

/// Counters a policy may expose for benches/diagnostics.
#[derive(Debug, Clone, Copy, Default)]
pub struct PolicyDiagnostics {
    /// Decode coarse-band switches.
    pub band_switches: u64,
    /// Decode band-table adaptations.
    pub adaptations: u64,
    /// Fine-loop ticks across the decode pool.
    pub fine_ticks: u64,
    /// Supervisor trips into the pinned fallback state (0 unless the
    /// policy is wrapped by a `GovernorSupervisor`).
    pub supervisor_fallbacks: u64,
    /// Supervisor probation completions (wrapped policy re-engaged).
    pub supervisor_reengages: u64,
}

/// A frequency governor: telemetry in → per-GPU clock decisions out.
///
/// All methods default to no-ops so a policy only implements the signals
/// it consumes. Invariant every implementation must uphold (property
/// tested): every returned clock lies on the GPU's supported ladder.
pub trait DvfsPolicy {
    /// Human-readable policy name (reports, matrix rows).
    fn name(&self) -> String;

    /// Clock applied to every GPU at t = 0 (`None` keeps boost default).
    fn initial_clock_mhz(&self) -> Option<u32> {
        None
    }

    /// Periodic callbacks this policy wants; the index of a spec is the
    /// `kind` passed back to [`DvfsPolicy::on_tick`].
    ///
    /// **View contract (§Perf):** each [`TickSpec`] declares which parts
    /// of the [`PoolView`] the tick actually consumes (`prefill_view`,
    /// `prefill_jobs`, `decode_view`). The engine only builds the
    /// declared parts — undeclared parts arrive *empty or stale* and must
    /// not be read by that tick. Tickless policies (e.g. `Fixed`) return
    /// no specs at all and the engine never builds a view for them. A
    /// high-rate tick (GreenLLM's 50 Hz fine loop) should declare the
    /// bare minimum: view construction is on the simulator's hot path.
    fn ticks(&self) -> Vec<TickSpec> {
        Vec::new()
    }

    /// Periodic decision point: read `view`, write clock decisions into
    /// `plan` (pre-sized, all `None`).
    fn on_tick(&mut self, _kind: usize, _now: f64, _view: &PoolView, _plan: &mut ClockPlan) {}

    /// One fresh-joiner TBT sample observed on a decode worker.
    fn on_decode_tbt(&mut self, _worker: usize, _tbt_s: f64) {}

    /// `count` steady streams of one decode round all observed `tbt_s`.
    fn on_decode_tbt_weighted(&mut self, _worker: usize, _tbt_s: f64, _count: u32) {}

    /// Tokens emitted by one decode round on `worker`.
    fn on_decode_tokens(&mut self, _worker: usize, _now: f64, _tokens: u32) {}

    /// Build prefill queue views for dispatch decisions?
    fn wants_prefill_jobs(&self) -> bool {
        false
    }

    /// React to arrivals that merely deepen a busy worker's queue?
    fn wants_backlog_updates(&self) -> bool {
        false
    }

    /// A prefill worker just took a job; `jobs` = in-flight head + backlog
    /// (empty unless [`DvfsPolicy::wants_prefill_jobs`]). Returned clock is
    /// applied before the job's duration is computed.
    fn on_prefill_dispatch(
        &mut self,
        _now: f64,
        _worker: usize,
        _jobs: &[PrefillJobView],
    ) -> Option<u32> {
        None
    }

    /// A prefill worker parked with an empty queue.
    fn on_prefill_idle(&mut self, _now: f64, _worker: usize) -> Option<u32> {
        None
    }

    /// An arrival deepened `worker`'s queue while it was busy (only when
    /// [`DvfsPolicy::wants_backlog_updates`]).
    fn on_prefill_backlog(
        &mut self,
        _now: f64,
        _worker: usize,
        _jobs: &[PrefillJobView],
    ) -> Option<u32> {
        None
    }

    /// The cluster power arbiter changed this node's clock ceiling: every
    /// requested clock above `cap_mhz` will be clamped by the engine until
    /// the next grant. Default no-op — clamping is enforced regardless;
    /// learning policies may use the signal to avoid wasting exploration
    /// on unreachable ladder rungs.
    fn on_power_cap(&mut self, _cap_mhz: u32) {}

    fn diagnostics(&self) -> PolicyDiagnostics {
        PolicyDiagnostics::default()
    }

    /// Drain supervisor/control state transitions recorded since the last
    /// poll (time, `"fallback"`/`"probation"`/`"reengage"`). The engine
    /// forwards them to the flight recorder so attribution can build
    /// `supervisor-fallback` windows. Default: none — only the
    /// [`GovernorSupervisor`](crate::dvfs::supervisor::GovernorSupervisor)
    /// decorator produces transitions.
    fn ctl_transitions(&mut self) -> Vec<(f64, &'static str)> {
        Vec::new()
    }
}

/// Instantiate the policy for `cfg.method`. This is the single registry:
/// new governors plug in here and become available to the engine, the CLI
/// and the scenario matrix at once.
pub fn build(cfg: &Config, perf: &PerfModel, power: &PowerModel) -> Box<dyn DvfsPolicy> {
    let inner: Box<dyn DvfsPolicy> = match cfg.method {
        Method::GreenLlm => Box::new(GreenLlmPolicy::new(cfg, perf, power)),
        Method::DefaultNv | Method::PrefillSplit => Box::new(DefaultNvPolicy::new(cfg)),
        Method::Fixed(mhz) => Box::new(FixedPolicy { mhz }),
        Method::Throttle => Box::new(ThrottlePolicy::new(cfg, perf, power)),
        Method::Agft => Box::new(AgftPolicy::new(cfg)),
        Method::PiTbt => Box::new(PiTbtPolicy::new(cfg)),
    };
    if cfg.ctl.supervisor {
        Box::new(crate::dvfs::supervisor::GovernorSupervisor::new(inner, cfg))
    } else {
        inner
    }
}

// ---------------------------------------------------------------------------
// GreenLLM (paper §3)
// ---------------------------------------------------------------------------

/// The paper's phase-specific stack behind the policy interface: one
/// prefill optimizer per prefill worker, one dual-loop controller per
/// decode worker. Tick kinds: 0 = fine, 1 = coarse, 2 = adapt, 3 = prefill.
pub struct GreenLlmPolicy {
    prefill_opts: Vec<PrefillOptimizer>,
    decode_ctls: Vec<DecodeController>,
    fine_tick_s: f64,
    coarse_tick_s: f64,
    adapt_interval_s: f64,
    prefill_tick_s: f64,
}

impl GreenLlmPolicy {
    /// Build the full stack for `cfg`: profile, fit, band tables, one
    /// controller per worker.
    pub fn new(cfg: &Config, perf: &PerfModel, power: &PowerModel) -> GreenLlmPolicy {
        let mut profiler =
            Profiler::new(perf.clone(), power.clone(), cfg.sim_noise, cfg.seed ^ 0xF17);
        let fitted = profiler.fit(3);
        let table = profiler.build_band_table(
            1600.0,
            cfg.decode_ctl.tps_bucket,
            TABLE_AVG_CTX,
            cfg.slo.tbt_p95_s * cfg.decode_margin,
            cfg.pools.max_streams_per_decode_worker,
        );
        let mut prefill_opts = Vec::new();
        for _ in 0..cfg.pools.prefill_workers {
            prefill_opts.push(PrefillOptimizer::new(
                fitted.clone(),
                cfg.prefill_opt.idle_clock_mhz,
            ));
        }
        let mut decode_ctls = Vec::new();
        for _ in 0..cfg.pools.decode_workers {
            decode_ctls.push(DecodeController::with_ladder(
                cfg.decode_ctl.clone(),
                table.clone(),
                cfg.slo.tbt_p95_s * cfg.decode_margin,
                cfg.gpu.ladder(),
            ));
        }
        GreenLlmPolicy {
            prefill_opts,
            decode_ctls,
            fine_tick_s: cfg.decode_ctl.fine_tick_s,
            coarse_tick_s: cfg.decode_ctl.coarse_tick_s,
            adapt_interval_s: cfg.decode_ctl.adapt_interval_s,
            prefill_tick_s: cfg.prefill_opt.tick_s,
        }
    }
}

impl DvfsPolicy for GreenLlmPolicy {
    fn name(&self) -> String {
        "GreenLLM".into()
    }

    fn ticks(&self) -> Vec<TickSpec> {
        // None of these read the decode view (the dual-loop controllers own
        // their telemetry), so skip its O(streams) construction — the fine
        // tick runs at 50 Hz. The three controller-state ticks never read
        // the prefill view either, so they skip that refresh too; only the
        // prefill-optimizer tick (kind 3) pays for queue views.
        vec![
            TickSpec::every(self.fine_tick_s)
                .without_decode_view()
                .without_prefill_view(),
            TickSpec::every(self.coarse_tick_s)
                .without_decode_view()
                .without_prefill_view(),
            TickSpec::every(self.adapt_interval_s)
                .without_decode_view()
                .without_prefill_view(),
            TickSpec::with_prefill_jobs(self.prefill_tick_s).without_decode_view(),
        ]
    }

    fn on_tick(&mut self, kind: usize, now: f64, view: &PoolView, plan: &mut ClockPlan) {
        match kind {
            0 => {
                for (w, ctl) in self.decode_ctls.iter_mut().enumerate() {
                    plan.decode_mhz[w] = Some(ctl.fine_tick(now));
                }
            }
            1 => {
                for ctl in self.decode_ctls.iter_mut() {
                    ctl.coarse_tick(now);
                }
            }
            2 => {
                for ctl in self.decode_ctls.iter_mut() {
                    ctl.adapt_tick(now);
                }
            }
            _ => {
                for (w, pv) in view.prefill.iter().enumerate() {
                    plan.prefill_mhz[w] = Some(self.prefill_opts[w].optimal_clock(now, &pv.jobs));
                }
            }
        }
    }

    fn on_decode_tbt(&mut self, worker: usize, tbt_s: f64) {
        self.decode_ctls[worker].on_tbt(tbt_s);
    }

    fn on_decode_tbt_weighted(&mut self, worker: usize, tbt_s: f64, count: u32) {
        self.decode_ctls[worker].on_tbt_weighted(tbt_s, count);
    }

    fn on_decode_tokens(&mut self, worker: usize, now: f64, tokens: u32) {
        self.decode_ctls[worker].on_tokens(now, tokens);
    }

    fn wants_prefill_jobs(&self) -> bool {
        true
    }

    fn wants_backlog_updates(&self) -> bool {
        true
    }

    fn on_prefill_dispatch(
        &mut self,
        now: f64,
        worker: usize,
        jobs: &[PrefillJobView],
    ) -> Option<u32> {
        Some(self.prefill_opts[worker].optimal_clock(now, jobs))
    }

    fn on_prefill_idle(&mut self, now: f64, worker: usize) -> Option<u32> {
        Some(self.prefill_opts[worker].optimal_clock(now, &[]))
    }

    fn on_prefill_backlog(
        &mut self,
        now: f64,
        worker: usize,
        jobs: &[PrefillJobView],
    ) -> Option<u32> {
        Some(self.prefill_opts[worker].optimal_clock(now, jobs))
    }

    fn diagnostics(&self) -> PolicyDiagnostics {
        PolicyDiagnostics {
            band_switches: self.decode_ctls.iter().map(|c| c.band_switches).sum(),
            adaptations: self.decode_ctls.iter().map(|c| c.adaptations).sum(),
            fine_ticks: self.decode_ctls.iter().map(|c| c.fine_ticks).sum(),
            ..Default::default()
        }
    }
}

// ---------------------------------------------------------------------------
// defaultNV baseline
// ---------------------------------------------------------------------------

/// Stock-governor baseline: one [`DefaultNvGovernor`] per worker, ticked
/// every 200 ms plus at prefill dispatch boundaries.
pub struct DefaultNvPolicy {
    nv_prefill: Vec<DefaultNvGovernor>,
    nv_decode: Vec<DefaultNvGovernor>,
    method: Method,
}

impl DefaultNvPolicy {
    /// One stock governor per worker, seeded per worker index.
    pub fn new(cfg: &Config) -> DefaultNvPolicy {
        let ladder = cfg.gpu.ladder();
        let nv_prefill = (0..cfg.pools.prefill_workers)
            .map(|w| DefaultNvGovernor::with_ladder(cfg.seed ^ (w as u64), ladder.clone()))
            .collect();
        let nv_decode = (0..cfg.pools.decode_workers)
            .map(|w| {
                DefaultNvGovernor::with_ladder(cfg.seed ^ (0x100 + w as u64), ladder.clone())
            })
            .collect();
        DefaultNvPolicy {
            nv_prefill,
            nv_decode,
            method: cfg.method,
        }
    }
}

impl DvfsPolicy for DefaultNvPolicy {
    fn name(&self) -> String {
        self.method.name()
    }

    fn ticks(&self) -> Vec<TickSpec> {
        vec![TickSpec::every(0.2)]
    }

    fn on_tick(&mut self, _kind: usize, now: f64, view: &PoolView, plan: &mut ClockPlan) {
        for (w, pv) in view.prefill.iter().enumerate() {
            plan.prefill_mhz[w] = Some(self.nv_prefill[w].tick(now, pv.busy));
        }
        for (w, dv) in view.decode.iter().enumerate() {
            plan.decode_mhz[w] = Some(self.nv_decode[w].tick(now, dv.batch > 0));
        }
    }

    fn on_prefill_dispatch(
        &mut self,
        now: f64,
        worker: usize,
        _jobs: &[PrefillJobView],
    ) -> Option<u32> {
        Some(self.nv_prefill[worker].tick(now, true))
    }
}

// ---------------------------------------------------------------------------
// Fixed clock
// ---------------------------------------------------------------------------

/// Pin every GPU to one application clock for the whole run (Fig. 3c).
pub struct FixedPolicy {
    /// The pinned application clock, MHz.
    pub mhz: u32,
}

impl DvfsPolicy for FixedPolicy {
    fn name(&self) -> String {
        format!("Fixed{}", self.mhz)
    }

    fn initial_clock_mhz(&self) -> Option<u32> {
        Some(self.mhz)
    }
}

// ---------------------------------------------------------------------------
// throttLL'eM-lite
// ---------------------------------------------------------------------------

/// Coarse 1 Hz predictive throttling (Kakolyris et al.): lowest
/// *predicted-feasible* clock per pool, no phase-aware energy objective,
/// no feedback loop — a fixed 7 % safety margin stands in for feedback.
pub struct ThrottlePolicy {
    opt: PrefillOptimizer,
    perf: PerfModel,
    ladder: FreqLadder,
    decode_target_s: f64,
}

impl ThrottlePolicy {
    /// Profile and fit the latency model the predictor throttles against.
    pub fn new(cfg: &Config, perf: &PerfModel, power: &PowerModel) -> ThrottlePolicy {
        let mut profiler =
            Profiler::new(perf.clone(), power.clone(), cfg.sim_noise, cfg.seed ^ 0x7417);
        let fitted = profiler.fit(3);
        ThrottlePolicy {
            opt: PrefillOptimizer::new(fitted, cfg.prefill_opt.idle_clock_mhz),
            perf: perf.clone(),
            ladder: cfg.gpu.ladder(),
            decode_target_s: cfg.slo.tbt_p95_s * cfg.decode_margin / 1.07,
        }
    }
}

impl DvfsPolicy for ThrottlePolicy {
    fn name(&self) -> String {
        "Throttle".into()
    }

    fn ticks(&self) -> Vec<TickSpec> {
        vec![TickSpec::with_prefill_jobs(1.0)]
    }

    fn on_tick(&mut self, _kind: usize, now: f64, view: &PoolView, plan: &mut ClockPlan) {
        for (w, pv) in view.prefill.iter().enumerate() {
            plan.prefill_mhz[w] = Some(self.opt.min_feasible_clock(now, &pv.jobs));
        }
        // Decode: predict the step time for the current batch and pick the
        // lowest clock that holds the TBT target (open loop).
        for (w, dv) in view.decode.iter().enumerate() {
            if dv.batch == 0 {
                continue;
            }
            let mut chosen = self.ladder.max_mhz;
            for mhz in self.ladder.iter() {
                if self.perf.decode_step_time(dv.batch, dv.avg_ctx, mhz) <= self.decode_target_s {
                    chosen = mhz;
                    break;
                }
            }
            plan.decode_mhz[w] = Some(chosen);
        }
    }

    fn wants_prefill_jobs(&self) -> bool {
        true
    }

    fn on_prefill_dispatch(
        &mut self,
        now: f64,
        _worker: usize,
        jobs: &[PrefillJobView],
    ) -> Option<u32> {
        Some(self.opt.min_feasible_clock(now, jobs))
    }
}

// ---------------------------------------------------------------------------
// AGFT-style online adaptive tuner
// ---------------------------------------------------------------------------

const AGFT_ACTIONS: [i64; 5] = [-3, -1, 0, 1, 3]; // ladder steps per move
const AGFT_TPS_BUCKET: f64 = 250.0;
const AGFT_STATES: usize = 16;
const AGFT_ALPHA: f64 = 0.2;
const AGFT_GAMMA: f64 = 0.9;

struct AgftAgent {
    q: Vec<[f64; AGFT_ACTIONS.len()]>,
    tps: TpsWindow,
    tbt: SlidingP95,
    rng: Pcg64,
    eps: f64,
    cur_mhz: u32,
    prev: Option<(usize, usize)>,
}

impl AgftAgent {
    fn new(seed: u64, stream: u64, ladder: &FreqLadder) -> AgftAgent {
        AgftAgent {
            q: vec![[0.0; AGFT_ACTIONS.len()]; AGFT_STATES],
            tps: TpsWindow::new(1.0),
            tbt: SlidingP95::new(128),
            rng: Pcg64::new(seed, stream),
            eps: 0.2,
            cur_mhz: ladder.max_mhz,
            prev: None,
        }
    }

    fn tick(&mut self, now: f64, ladder: &FreqLadder, target_s: f64, batch: usize) -> u32 {
        if batch == 0 {
            // Idle worker: park toward the floor and freeze learning. The
            // TBT window is count-bounded and never drains, so a stale P95
            // from the last burst must not keep the guardrail (or the
            // Q-update) firing on an empty GPU.
            self.prev = None;
            let stepped = self.cur_mhz as i64 - 3 * ladder.step_mhz as i64;
            self.cur_mhz = ladder.snap(stepped as f64);
            return self.cur_mhz;
        }
        let tps = self.tps.tps(now);
        let state = ((tps / AGFT_TPS_BUCKET) as usize).min(AGFT_STATES - 1);
        // Reward for the previous action: energy proxy (cubic in clock)
        // plus a latency penalty when P95 TBT exceeds the target.
        let p95 = self.tbt.p95();
        let f_norm = self.cur_mhz as f64 / ladder.max_mhz as f64;
        let violation = (p95 / target_s - 1.0).max(0.0);
        let reward = -(f_norm * f_norm * f_norm) - 4.0 * violation;
        let max_next = self.q[state]
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max);
        if let Some((ps, pa)) = self.prev {
            let old = self.q[ps][pa];
            self.q[ps][pa] = old + AGFT_ALPHA * (reward + AGFT_GAMMA * max_next - old);
        }
        // ε-greedy action selection (ε decays toward 2 %).
        let action = if self.rng.f64() < self.eps {
            self.rng.index(AGFT_ACTIONS.len())
        } else {
            let mut best = 0;
            for (i, v) in self.q[state].iter().enumerate() {
                if *v > self.q[state][best] {
                    best = i;
                }
            }
            best
        };
        self.eps = (self.eps * 0.995).max(0.02);
        self.prev = Some((state, action));
        // SLO guardrail: a deep violation overrides learning with max boost.
        if violation > 0.5 {
            self.cur_mhz = ladder.max_mhz;
            return self.cur_mhz;
        }
        let stepped = self.cur_mhz as i64 + AGFT_ACTIONS[action] * ladder.step_mhz as i64;
        self.cur_mhz = ladder.snap(stepped as f64);
        self.cur_mhz
    }
}

/// AGFT-style adaptive real-time tuner (arXiv:2508.01744): per-decode-worker
/// ε-greedy Q-learning over ladder moves, rewarded for low clocks and
/// penalized for TBT violations, with a hard SLO guardrail. Prefill runs a
/// simple busy-boost/idle-park heuristic so TTFT stays governed while the
/// learner owns the decode pool.
pub struct AgftPolicy {
    agents: Vec<AgftAgent>,
    ladder: FreqLadder,
    target_s: f64,
    idle_clock_mhz: u32,
    ticks_seen: u64,
}

impl AgftPolicy {
    /// One Q-learning agent per decode worker, seeded deterministically.
    pub fn new(cfg: &Config) -> AgftPolicy {
        let ladder = cfg.gpu.ladder();
        let agents = (0..cfg.pools.decode_workers)
            .map(|w| AgftAgent::new(cfg.seed ^ 0xA6F7, w as u64, &ladder))
            .collect();
        AgftPolicy {
            agents,
            ladder,
            target_s: cfg.slo.tbt_p95_s * cfg.decode_margin,
            idle_clock_mhz: cfg.prefill_opt.idle_clock_mhz,
            ticks_seen: 0,
        }
    }
}

impl DvfsPolicy for AgftPolicy {
    fn name(&self) -> String {
        "AGFT".into()
    }

    fn ticks(&self) -> Vec<TickSpec> {
        vec![TickSpec::every(0.25)]
    }

    fn on_tick(&mut self, _kind: usize, now: f64, view: &PoolView, plan: &mut ClockPlan) {
        self.ticks_seen += 1;
        for (w, pv) in view.prefill.iter().enumerate() {
            plan.prefill_mhz[w] = Some(if pv.busy {
                self.ladder.max_mhz
            } else {
                self.idle_clock_mhz
            });
        }
        for (w, agent) in self.agents.iter_mut().enumerate() {
            let batch = view.decode.get(w).map_or(0, |d| d.batch);
            plan.decode_mhz[w] = Some(agent.tick(now, &self.ladder, self.target_s, batch));
        }
    }

    fn on_decode_tbt(&mut self, worker: usize, tbt_s: f64) {
        self.agents[worker].tbt.record(tbt_s);
    }

    fn on_decode_tbt_weighted(&mut self, worker: usize, tbt_s: f64, count: u32) {
        self.agents[worker].tbt.record_weighted(tbt_s, count);
    }

    fn on_decode_tokens(&mut self, worker: usize, now: f64, tokens: u32) {
        self.agents[worker].tps.record(now, tokens);
    }

    fn on_prefill_dispatch(
        &mut self,
        _now: f64,
        _worker: usize,
        _jobs: &[PrefillJobView],
    ) -> Option<u32> {
        Some(self.ladder.max_mhz)
    }

    fn on_prefill_idle(&mut self, _now: f64, _worker: usize) -> Option<u32> {
        Some(self.idle_clock_mhz)
    }

    fn diagnostics(&self) -> PolicyDiagnostics {
        PolicyDiagnostics {
            fine_ticks: self.ticks_seen,
            ..Default::default()
        }
    }
}

// ---------------------------------------------------------------------------
// PI-on-TBT feedback controller
// ---------------------------------------------------------------------------

const PI_TICK_S: f64 = 0.1;
const PI_SETPOINT: f64 = 0.85; // run at 85 % of the TBT budget
const PI_KP: f64 = 1200.0; // MHz per unit error per second
const PI_KI: f64 = 300.0;
const PI_INTEG_CLAMP: f64 = 3.0;
const PI_IDLE_DECAY_MHZ_S: f64 = 1500.0;

struct PiWorker {
    tbt: SlidingP95,
    integ: f64,
    cur_f: f64,
}

/// The simplest dynamic baseline: one PI loop per decode worker tracking
/// P95 TBT to a setpoint at 85 % of the SLO budget. No profiling, no
/// tables, no learning — what a practitioner would wire up in an
/// afternoon. Prefill boosts while busy and parks while idle.
pub struct PiTbtPolicy {
    workers: Vec<PiWorker>,
    ladder: FreqLadder,
    target_s: f64,
    idle_clock_mhz: u32,
}

impl PiTbtPolicy {
    /// One PI loop per decode worker at boost clocks.
    pub fn new(cfg: &Config) -> PiTbtPolicy {
        let ladder = cfg.gpu.ladder();
        let workers = (0..cfg.pools.decode_workers)
            .map(|_| PiWorker {
                tbt: SlidingP95::new(cfg.decode_ctl.tbt_window),
                integ: 0.0,
                cur_f: ladder.max_mhz as f64,
            })
            .collect();
        PiTbtPolicy {
            workers,
            ladder,
            target_s: cfg.slo.tbt_p95_s * cfg.decode_margin,
            idle_clock_mhz: cfg.prefill_opt.idle_clock_mhz,
        }
    }
}

impl DvfsPolicy for PiTbtPolicy {
    fn name(&self) -> String {
        "PI-TBT".into()
    }

    fn ticks(&self) -> Vec<TickSpec> {
        vec![TickSpec::every(PI_TICK_S)]
    }

    fn on_tick(&mut self, _kind: usize, _now: f64, view: &PoolView, plan: &mut ClockPlan) {
        for (w, pv) in view.prefill.iter().enumerate() {
            plan.prefill_mhz[w] = Some(if pv.busy {
                self.ladder.max_mhz
            } else {
                self.idle_clock_mhz
            });
        }
        for (w, st) in self.workers.iter_mut().enumerate() {
            let batch = view.decode.get(w).map_or(0, |d| d.batch);
            if batch == 0 || st.tbt.is_empty() {
                // Idle worker (or no samples yet): decay toward the ladder
                // floor. Keying on the batch matters — the TBT window is
                // count-bounded and never drains, so a stale P95 from the
                // last burst would otherwise hold (or wind up) the clock on
                // an empty GPU.
                st.cur_f =
                    (st.cur_f - PI_IDLE_DECAY_MHZ_S * PI_TICK_S).max(self.ladder.min_mhz as f64);
                st.integ = 0.0;
            } else {
                // err > 0: TBT above setpoint → raise the clock.
                let err = st.tbt.p95() / self.target_s - PI_SETPOINT;
                st.integ = (st.integ + err * PI_TICK_S).clamp(-PI_INTEG_CLAMP, PI_INTEG_CLAMP);
                let u = PI_KP * err + PI_KI * st.integ;
                st.cur_f = (st.cur_f + u * PI_TICK_S)
                    .clamp(self.ladder.min_mhz as f64, self.ladder.max_mhz as f64);
            }
            plan.decode_mhz[w] = Some(self.ladder.snap(st.cur_f));
        }
    }

    fn on_decode_tbt(&mut self, worker: usize, tbt_s: f64) {
        self.workers[worker].tbt.record(tbt_s);
    }

    fn on_decode_tbt_weighted(&mut self, worker: usize, tbt_s: f64, count: u32) {
        self.workers[worker].tbt.record_weighted(tbt_s, count);
    }

    fn on_prefill_dispatch(
        &mut self,
        _now: f64,
        _worker: usize,
        _jobs: &[PrefillJobView],
    ) -> Option<u32> {
        Some(self.ladder.max_mhz)
    }

    fn on_prefill_idle(&mut self, _now: f64, _worker: usize) -> Option<u32> {
        Some(self.idle_clock_mhz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::telemetry::{DecodeWorkerView, PrefillWorkerView};
    use crate::gpu::perf::PerfModel;
    use crate::model::ModelSpec;

    fn cfg(method: Method) -> Config {
        Config {
            method,
            sim_noise: 0.0,
            ..Config::default()
        }
    }

    fn view(prefill_busy: &[bool], decode_batch: &[usize]) -> PoolView {
        PoolView {
            now: 1.0,
            prefill: prefill_busy
                .iter()
                .map(|&busy| PrefillWorkerView {
                    busy,
                    jobs: Vec::new(),
                })
                .collect(),
            decode: decode_batch
                .iter()
                .map(|&batch| DecodeWorkerView {
                    batch,
                    avg_ctx: if batch == 0 { 0.0 } else { 400.0 },
                })
                .collect(),
        }
    }

    fn drive(policy: &mut dyn DvfsPolicy, v: &PoolView) -> ClockPlan {
        let mut plan = ClockPlan::default();
        plan.reset(v.prefill.len(), v.decode.len());
        let specs = policy.ticks();
        for kind in 0..specs.len() {
            policy.on_tick(kind, v.now, v, &mut plan);
        }
        plan
    }

    fn build_all() -> Vec<Box<dyn DvfsPolicy>> {
        let perf = PerfModel::new(ModelSpec::qwen3_14b());
        let power = PowerModel::a100();
        [
            Method::DefaultNv,
            Method::PrefillSplit,
            Method::GreenLlm,
            Method::Fixed(900),
            Method::Throttle,
            Method::Agft,
            Method::PiTbt,
        ]
        .into_iter()
        .map(|m| build(&cfg(m), &perf, &power))
        .collect()
    }

    #[test]
    fn registry_builds_every_method() {
        let names: Vec<String> = build_all().iter().map(|p| p.name()).collect();
        assert_eq!(
            names,
            vec![
                "defaultNV",
                "PrefillSplit",
                "GreenLLM",
                "Fixed900",
                "Throttle",
                "AGFT",
                "PI-TBT"
            ]
        );
    }

    #[test]
    fn supervised_build_wraps_transparently() {
        let perf = PerfModel::new(ModelSpec::qwen3_14b());
        let power = PowerModel::a100();
        let mut c = cfg(Method::GreenLlm);
        c.ctl.supervisor = true;
        let mut p = build(&c, &perf, &power);
        assert_eq!(p.name(), "GreenLLM", "wrapper passes the inner name");
        assert_eq!(p.ticks().len(), 5, "4 GreenLLM ticks + 1 watch tick");
        assert!(p.wants_prefill_jobs() && p.wants_backlog_updates());
        assert!(p.ctl_transitions().is_empty());
        assert_eq!(p.diagnostics().supervisor_fallbacks, 0);
    }

    #[test]
    fn all_planned_clocks_on_ladder() {
        let ladder = FreqLadder::a100();
        let v = view(&[true, false], &[3, 0, 12, 1]);
        for policy in build_all().iter_mut() {
            let plan = drive(policy.as_mut(), &v);
            for mhz in plan
                .prefill_mhz
                .iter()
                .chain(plan.decode_mhz.iter())
                .flatten()
            {
                assert!(ladder.contains(*mhz), "{}: off-ladder {mhz}", policy.name());
            }
            if let Some(f) = policy.initial_clock_mhz() {
                assert!(ladder.contains(f));
            }
        }
    }

    #[test]
    fn fixed_policy_only_sets_initial_clock() {
        let perf = PerfModel::new(ModelSpec::qwen3_14b());
        let power = PowerModel::a100();
        let mut p = build(&cfg(Method::Fixed(750)), &perf, &power);
        assert_eq!(p.initial_clock_mhz(), Some(750));
        assert!(p.ticks().is_empty());
        assert_eq!(p.on_prefill_dispatch(0.0, 0, &[]), None);
    }

    #[test]
    fn agft_guardrail_boosts_on_deep_violation() {
        let mut p = AgftPolicy::new(&cfg(Method::Agft));
        // Saturate the TBT window far above target (target = 95 ms).
        p.on_decode_tbt_weighted(0, 0.400, 64);
        let v = view(&[false, false], &[8, 8, 8, 8]);
        let plan = drive(&mut p, &v);
        assert_eq!(plan.decode_mhz[0], Some(1410));
    }

    #[test]
    fn agft_learns_downward_under_slack() {
        let mut p = AgftPolicy::new(&cfg(Method::Agft));
        let v = view(&[false, false], &[4, 4, 4, 4]);
        let mut plan = ClockPlan::default();
        for i in 0..400 {
            // Persistent slack: tiny TBTs, light token flow.
            p.on_decode_tbt_weighted(0, 0.010, 4);
            p.on_decode_tokens(0, i as f64 * 0.25, 40);
            plan.reset(2, 4);
            p.on_tick(0, i as f64 * 0.25, &v, &mut plan);
        }
        let f = plan.decode_mhz[0].unwrap();
        assert!(f < 1200, "agft should have learned to lower the clock: {f}");
    }

    #[test]
    fn agft_is_deterministic_for_a_seed() {
        let run = || {
            let mut p = AgftPolicy::new(&cfg(Method::Agft));
            let v = view(&[true, false], &[6, 6, 6, 6]);
            let mut plan = ClockPlan::default();
            let mut out = Vec::new();
            for i in 0..100 {
                p.on_decode_tbt_weighted(0, 0.05 + 0.001 * (i % 7) as f64, 6);
                p.on_decode_tokens(0, i as f64 * 0.25, 30);
                plan.reset(2, 4);
                p.on_tick(0, i as f64 * 0.25, &v, &mut plan);
                out.push(plan.decode_mhz[0].unwrap());
            }
            out
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn pi_raises_on_violation_and_lowers_on_slack() {
        let mut p = PiTbtPolicy::new(&cfg(Method::PiTbt));
        let v = view(&[false, false], &[4, 4, 4, 4]);
        let mut plan = ClockPlan::default();
        // Slack: P95 far under the setpoint → clock falls from boost.
        for _ in 0..40 {
            p.on_decode_tbt(0, 0.010);
            plan.reset(2, 4);
            p.on_tick(0, 1.0, &v, &mut plan);
        }
        let low = plan.decode_mhz[0].unwrap();
        assert!(low < 1410, "slack must lower the clock: {low}");
        // Violation: P95 over budget → clock climbs back up.
        let mut p2 = PiTbtPolicy::new(&cfg(Method::PiTbt));
        for st in p2.workers.iter_mut() {
            st.cur_f = 600.0;
        }
        for _ in 0..40 {
            p2.on_decode_tbt(0, 0.200);
            plan.reset(2, 4);
            p2.on_tick(0, 1.0, &v, &mut plan);
        }
        let high = plan.decode_mhz[0].unwrap();
        assert!(high > 900, "violation must raise the clock: {high}");
    }

    #[test]
    fn pi_idle_decays_to_floor() {
        let mut p = PiTbtPolicy::new(&cfg(Method::PiTbt));
        let v = view(&[false, false], &[0, 0, 0, 0]);
        let mut plan = ClockPlan::default();
        for _ in 0..20 {
            plan.reset(2, 4);
            p.on_tick(0, 1.0, &v, &mut plan);
        }
        assert_eq!(plan.decode_mhz[0], Some(210));
    }

    #[test]
    fn pi_drained_worker_decays_despite_stale_violations() {
        // Regression: the TBT window never drains, so a worker whose last
        // rounds were congested must still park once its batch empties.
        let mut p = PiTbtPolicy::new(&cfg(Method::PiTbt));
        let busy = view(&[false, false], &[4, 4, 4, 4]);
        let mut plan = ClockPlan::default();
        for _ in 0..30 {
            p.on_decode_tbt(0, 0.300); // deep violation
            plan.reset(2, 4);
            p.on_tick(0, 1.0, &busy, &mut plan);
        }
        assert_eq!(plan.decode_mhz[0], Some(1410));
        let idle = view(&[false, false], &[0, 4, 4, 4]);
        for _ in 0..20 {
            plan.reset(2, 4);
            p.on_tick(0, 2.0, &idle, &mut plan);
        }
        assert_eq!(plan.decode_mhz[0], Some(210), "stale P95 held an idle GPU hot");
    }

    #[test]
    fn agft_drained_worker_parks_despite_stale_violations() {
        // Same regression for the learner: a stale violation window must
        // not keep the guardrail pinning an idle GPU at max boost.
        let mut p = AgftPolicy::new(&cfg(Method::Agft));
        p.on_decode_tbt_weighted(0, 0.400, 64); // violation episode
        let busy = view(&[false, false], &[8, 8, 8, 8]);
        let mut plan = ClockPlan::default();
        plan.reset(2, 4);
        p.on_tick(0, 0.25, &busy, &mut plan);
        assert_eq!(plan.decode_mhz[0], Some(1410));
        let idle = view(&[false, false], &[0, 8, 8, 8]);
        for i in 0..40 {
            plan.reset(2, 4);
            p.on_tick(0, 0.5 + i as f64 * 0.25, &idle, &mut plan);
        }
        assert_eq!(plan.decode_mhz[0], Some(210), "guardrail pinned an idle GPU");
    }

    #[test]
    fn nv_policy_prefill_dispatch_draws_like_governor() {
        let c = cfg(Method::DefaultNv);
        let mut p = DefaultNvPolicy::new(&c);
        let f = p.on_prefill_dispatch(0.5, 0, &[]).unwrap();
        assert!((1290..=1410).contains(&f));
    }

    #[test]
    fn throttle_decode_skips_idle_workers() {
        let perf = PerfModel::new(ModelSpec::qwen3_14b());
        let power = PowerModel::a100();
        let mut p = ThrottlePolicy::new(&cfg(Method::Throttle), &perf, &power);
        let v = view(&[false, false], &[0, 5, 0, 0]);
        let plan = drive(&mut p, &v);
        assert_eq!(plan.decode_mhz[0], None);
        assert!(plan.decode_mhz[1].is_some());
    }
}

//! Telemetry snapshots and clock plans — the data contract between the
//! serving engine and a [`DvfsPolicy`](crate::coordinator::policy::DvfsPolicy).
//!
//! The engine owns the queues, workers and simulated GPUs; a policy only
//! ever sees an immutable [`PoolView`] and answers with a [`ClockPlan`]
//! (telemetry in → per-GPU clock decisions out). Keeping policies pure
//! this way is what lets the scenario matrix swap governors without
//! touching the event loop, and what makes the policy layer
//! property-testable in isolation.
//!
//! These views are *inbound* telemetry — what policies consume to make
//! clock decisions. The *outbound* direction (what the run emits about
//! itself: request-lifecycle spans, per-node clock/power time series,
//! SLO-violation attribution) lives in [`crate::obs`]; the engine applies
//! a [`ClockPlan`] and reports the resulting clock edges to the flight
//! recorder, so an exported trace shows exactly what a policy's plans did
//! to the hardware over time. See `docs/OBSERVABILITY.md`.

use crate::dvfs::prefill_opt::PrefillJobView;

/// What a policy sees of one prefill worker at a tick.
#[derive(Debug, Clone, Default)]
pub struct PrefillWorkerView {
    /// Does the worker have an in-flight prefill job?
    pub busy: bool,
    /// FIFO queue view: the in-flight job heads the list (its remaining
    /// work over-approximated by its full reference time), followed by the
    /// backlog; each entry carries its absolute TTFT deadline. Populated
    /// only for ticks that request it ([`TickSpec::prefill_jobs`]) — the
    /// walk costs O(queue) per worker.
    pub jobs: Vec<PrefillJobView>,
}

/// What a policy sees of one decode worker at a tick.
#[derive(Debug, Clone, Copy, Default)]
pub struct DecodeWorkerView {
    /// Streams currently batched on the worker.
    pub batch: usize,
    /// Mean context length across those streams (0 when idle).
    pub avg_ctx: f64,
}

/// Snapshot of both pools at one instant of virtual time.
#[derive(Debug, Clone, Default)]
pub struct PoolView {
    /// Virtual time of the snapshot.
    pub now: f64,
    /// One view per prefill worker.
    pub prefill: Vec<PrefillWorkerView>,
    /// One view per decode worker (empty unless [`TickSpec::decode_view`]).
    pub decode: Vec<DecodeWorkerView>,
}

/// Per-worker clock decisions returned from a policy tick. `None` holds
/// the worker's current application clock.
///
/// ```
/// use greenllm::coordinator::telemetry::ClockPlan;
///
/// let mut plan = ClockPlan::default();
/// plan.reset(2, 4); // 2 prefill workers, 4 decode workers, all `None`
/// plan.decode_mhz[0] = Some(1410);
/// plan.clamp_to(900); // pre-shape against a known power-cap ceiling
/// assert_eq!(plan.decode_mhz[0], Some(900));
/// assert_eq!(plan.decode_mhz[1], None); // holds stay holds
/// ```
#[derive(Debug, Clone, Default)]
pub struct ClockPlan {
    /// Per-prefill-worker decisions, MHz.
    pub prefill_mhz: Vec<Option<u32>>,
    /// Per-decode-worker decisions, MHz.
    pub decode_mhz: Vec<Option<u32>>,
}

impl ClockPlan {
    /// Clear all decisions and size the plan to the pool shapes.
    pub fn reset(&mut self, prefill_workers: usize, decode_workers: usize) {
        self.prefill_mhz.clear();
        self.prefill_mhz.resize(prefill_workers, None);
        self.decode_mhz.clear();
        self.decode_mhz.resize(decode_workers, None);
    }

    /// Clamp every decision to a clock ceiling. Note the engine enforces
    /// the power arbiter's cap itself (recording the pre-clamp request so
    /// a raised cap can restore it); this helper is for policies that
    /// want to pre-shape a plan against a known ceiling, e.g. after an
    /// [`on_power_cap`](crate::coordinator::policy::DvfsPolicy::on_power_cap)
    /// notification.
    pub fn clamp_to(&mut self, cap_mhz: u32) {
        for m in self.prefill_mhz.iter_mut().chain(self.decode_mhz.iter_mut()) {
            if let Some(v) = m {
                *v = (*v).min(cap_mhz);
            }
        }
    }

    /// [`ClockPlan::clamp_to`], but ladder-aware: every capped decision is
    /// snapped *down* to the highest ladder clock not above `cap_mhz`, so
    /// an off-grid cap (e.g. a power arbiter's 1000 MHz ceiling on the
    /// 15 MHz grid) never leaves an off-ladder request in the plan, and
    /// never rounds above the cap. A cap below the ladder floor pins to
    /// the floor — the lowest clock the part can actually run.
    pub fn clamp_to_ladder(&mut self, cap_mhz: u32, ladder: &crate::gpu::FreqLadder) {
        let cap = ladder.snap_down(cap_mhz as f64);
        for m in self.prefill_mhz.iter_mut().chain(self.decode_mhz.iter_mut()) {
            if let Some(v) = m {
                if *v > cap {
                    *v = cap;
                } else {
                    *v = ladder.snap_down(*v as f64);
                }
            }
        }
    }
}

/// One periodic callback a policy asks the engine to schedule. The index
/// of a spec in [`DvfsPolicy::ticks`](crate::coordinator::policy::DvfsPolicy::ticks)
/// is the `kind` passed back to `on_tick`.
#[derive(Debug, Clone, Copy)]
pub struct TickSpec {
    /// Callback period, seconds.
    pub interval_s: f64,
    /// Fill [`PrefillWorkerView::jobs`] for this tick.
    pub prefill_jobs: bool,
    /// Fill [`PoolView::decode`] for this tick (costs an O(streams) scan
    /// per decode worker; policies whose tick never reads the decode view
    /// — e.g. GreenLLM's controller-state ticks — opt out).
    pub decode_view: bool,
    /// Refresh [`PoolView::prefill`] (busy flags) for this tick. Ticks
    /// that never read the prefill view — e.g. GreenLLM's fine decode
    /// loop at 50 Hz — opt out and must treat `view.prefill` as stale
    /// (it holds whatever the last refreshing tick wrote). See the view
    /// contract in [`crate::coordinator::policy`].
    pub prefill_view: bool,
}

impl TickSpec {
    /// A plain periodic tick (prefill + decode views on, queue jobs off).
    pub fn every(interval_s: f64) -> TickSpec {
        TickSpec {
            interval_s,
            prefill_jobs: false,
            decode_view: true,
            prefill_view: true,
        }
    }

    /// A periodic tick that also builds prefill queue views.
    pub fn with_prefill_jobs(interval_s: f64) -> TickSpec {
        TickSpec {
            interval_s,
            prefill_jobs: true,
            decode_view: true,
            prefill_view: true,
        }
    }

    /// Skip decode-view construction for this tick.
    pub fn without_decode_view(mut self) -> TickSpec {
        self.decode_view = false;
        self
    }

    /// Skip prefill-view refresh for this tick (implies no queue jobs —
    /// the tick must not read `view.prefill` at all).
    pub fn without_prefill_view(mut self) -> TickSpec {
        self.prefill_view = false;
        self.prefill_jobs = false;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_plan_reset_sizes_and_clears() {
        let mut p = ClockPlan::default();
        p.reset(2, 4);
        assert_eq!(p.prefill_mhz, vec![None, None]);
        assert_eq!(p.decode_mhz.len(), 4);
        p.decode_mhz[1] = Some(900);
        p.reset(2, 4);
        assert_eq!(p.decode_mhz[1], None);
    }

    #[test]
    fn clamp_to_caps_only_set_decisions() {
        let mut p = ClockPlan::default();
        p.reset(2, 2);
        p.prefill_mhz[0] = Some(1410);
        p.decode_mhz[1] = Some(600);
        p.clamp_to(900);
        assert_eq!(p.prefill_mhz[0], Some(900));
        assert_eq!(p.prefill_mhz[1], None); // untouched holds stay None
        assert_eq!(p.decode_mhz[1], Some(600)); // under the cap: unchanged
    }

    #[test]
    fn clamp_to_ladder_snaps_down_and_respects_boundaries() {
        let ladder = crate::gpu::FreqLadder::a100();
        let mut p = ClockPlan::default();
        p.reset(2, 3);
        p.prefill_mhz[0] = Some(1410);
        p.decode_mhz[0] = Some(997); // off-grid decision under the cap
        p.decode_mhz[1] = Some(600);
        // Off-grid cap: 1000 snaps DOWN to 990, never up to 1005.
        p.clamp_to_ladder(1000, &ladder);
        assert_eq!(p.prefill_mhz[0], Some(990));
        assert_eq!(p.prefill_mhz[1], None, "holds stay holds");
        assert_eq!(p.decode_mhz[0], Some(990), "off-grid survivors snap down too");
        assert_eq!(p.decode_mhz[1], Some(600));
        // Cap below the ladder floor: pin at the floor, not below it.
        p.clamp_to_ladder(100, &ladder);
        assert_eq!(p.prefill_mhz[0], Some(210));
        assert_eq!(p.decode_mhz[1], Some(210));
        // Exact-boundary cap is a fixed point.
        let mut q = ClockPlan::default();
        q.reset(1, 0);
        q.prefill_mhz[0] = Some(1410);
        q.clamp_to_ladder(1410, &ladder);
        assert_eq!(q.prefill_mhz[0], Some(1410));
    }

    #[test]
    fn tick_spec_constructors() {
        assert!(!TickSpec::every(0.2).prefill_jobs);
        assert!(TickSpec::with_prefill_jobs(0.1).prefill_jobs);
        assert_eq!(TickSpec::every(0.2).interval_s, 0.2);
        assert!(TickSpec::every(0.2).decode_view);
        let slim = TickSpec::every(0.02).without_decode_view();
        assert!(!slim.decode_view);
        assert!(slim.prefill_view);
        assert_eq!(slim.interval_s, 0.02);
        let bare = TickSpec::with_prefill_jobs(0.02).without_prefill_view();
        assert!(!bare.prefill_view);
        assert!(!bare.prefill_jobs, "no prefill view implies no job views");
    }
}

//! The discrete-event serving engine: replays a trace against the
//! simulated DGX-A100 node under a pluggable [`DvfsPolicy`] and produces
//! energy + SLO results.
//!
//! Topology (paper Fig. 4): requests arrive → router → per-class prefill
//! queues → prefill pool (default 2 workers × 2 GPUs, one job at a time per
//! worker) → decode pool (default 4 workers × 1 GPU, continuous batching) →
//! token stream. The engine owns queues, workers and GPUs; every frequency
//! decision flows through the policy layer (`coordinator::policy`), which
//! receives telemetry snapshots and event-driven TBT/token feedback and
//! answers with NVML-style application clocks. Adding a governor therefore
//! never touches this event loop.

use crate::config::{Config, Method};
use crate::coordinator::policy::{self, DvfsPolicy};
use crate::coordinator::router::Router;
use crate::coordinator::telemetry::{ClockPlan, DecodeWorkerView, PoolView, TickSpec};
use crate::dvfs::prefill_opt::PrefillJobView;
use crate::gpu::device::SimGpu;
use crate::gpu::perf::PerfModel;
use crate::gpu::power::PowerModel;
use crate::metrics::TpsWindow;
use crate::model::ModelSpec;
use crate::sim::EventQueue;
use crate::slo::{RequestOutcome, SloTracker};
use crate::util::rng::Pcg64;
use crate::util::stats::percentile_exact;
use crate::workload::request::Trace;

use std::collections::VecDeque;

/// Run options (figure-specific recording).
#[derive(Debug, Clone, Default)]
pub struct RunOptions {
    /// Record (t, MHz) for decode worker 0's GPU and prefill worker 0's GPU.
    pub record_freq_trace: bool,
    /// Record aggregate decode TPS every 200 ms.
    pub record_tps_series: bool,
    /// Keep per-request outcomes (Fig. 5 distributions).
    pub keep_outcomes: bool,
}

/// Results of one replay.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub trace_name: String,
    pub method: Method,
    pub slo: SloTracker,
    pub prefill_energy_j: f64,
    pub decode_energy_j: f64,
    pub total_energy_j: f64,
    pub generated_tokens: u64,
    pub completed: u64,
    pub sim_duration_s: f64,
    pub events_processed: u64,
    pub decode_freq_trace: Vec<(f64, u32)>,
    pub prefill_freq_trace: Vec<(f64, u32)>,
    pub decode_tps_series: Vec<(f64, f64)>,
    /// Mean decode batch occupancy (diagnostics).
    pub mean_decode_batch: f64,
    /// Controller diagnostics (GreenLLM only; zeros otherwise): coarse-band
    /// switches, table adaptations, fine ticks across the decode pool.
    pub band_switches: u64,
    pub adaptations: u64,
    pub fine_ticks: u64,
}

impl RunResult {
    /// Throughput in generated tokens/s over the run.
    pub fn throughput_tps(&self) -> f64 {
        if self.sim_duration_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.sim_duration_s
    }

    pub fn total_energy_wh(&self) -> f64 {
        self.total_energy_j / 3600.0
    }
}

// ---------------------------------------------------------------------------
// Internal state
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrive(usize),
    PrefillDone { worker: usize, seq: u64 },
    DecodeRound { worker: usize, seq: u64 },
    /// A policy-requested periodic callback (index into the tick specs).
    PolicyTick(usize),
    SampleTick,
}

#[derive(Debug)]
struct QueuedJob {
    req_idx: usize,
}

#[derive(Debug)]
struct PrefillWorker {
    gpus: Vec<usize>,
    queue: usize,
    /// (req_idx, completion event seq) of the in-flight job.
    current: Option<(usize, u64)>,
    seq: u64,
}

#[derive(Debug)]
struct Stream {
    req_idx: usize,
    remaining: u32,
    ctx: f64,
    last_token_t: f64,
    joined_t: f64,
    tbts: Vec<f64>,
}

#[derive(Debug)]
struct DecodeWorker {
    gpu: usize,
    streams: Vec<Stream>,
    round_active: bool,
    round_start: f64,
    seq: u64,
    batch_samples: u64,
    batch_sum: u64,
}

struct Engine<'a> {
    cfg: &'a Config,
    trace: &'a Trace,
    opts: &'a RunOptions,
    perf: PerfModel,
    router: Router,
    q: EventQueue<Ev>,
    gpus: Vec<SimGpu>,
    prefill_queues: Vec<VecDeque<QueuedJob>>,
    prefill_workers: Vec<PrefillWorker>,
    decode_workers: Vec<DecodeWorker>,
    decode_wait: VecDeque<Stream>,
    /// The frequency governor under test — the only source of clock
    /// decisions in the whole loop.
    policy: Box<dyn DvfsPolicy>,
    tick_specs: Vec<TickSpec>,
    slo: SloTracker,
    rng: Pcg64,
    completed: u64,
    generated_tokens: u64,
    global_tps: TpsWindow,
    tps_series: Vec<(f64, f64)>,
    /// Reusable buffers for policy telemetry (hot path: every policy tick
    /// and prefill boundary — §Perf).
    jobs_scratch: Vec<PrefillJobView>,
    view_scratch: PoolView,
    plan_scratch: ClockPlan,
    /// Prefill deadline target per route class (SLO × margin).
    ttft_target_sm: f64,
    ttft_target_long: f64,
}

/// Replay `trace` under `cfg`.
pub fn run(cfg: &Config, trace: &Trace, opts: &RunOptions) -> RunResult {
    let spec = ModelSpec::by_name(&cfg.model)
        .unwrap_or_else(|| panic!("unknown model {:?}", cfg.model));
    let perf = PerfModel::new(spec);
    let power = PowerModel::a100();
    let router = Router::new(cfg.method.routing(), cfg.pools.prefill_workers);

    // --- GPUs -------------------------------------------------------------
    let n_prefill_gpus = cfg.pools.prefill_workers * cfg.pools.gpus_per_prefill_worker;
    let n_gpus = n_prefill_gpus + cfg.pools.decode_workers * cfg.pools.gpus_per_decode_worker;
    let mut gpus: Vec<SimGpu> = (0..n_gpus).map(SimGpu::new).collect();
    if opts.record_freq_trace {
        gpus[0].record_trace = true; // prefill worker 0, gpu 0
        gpus[n_prefill_gpus].record_trace = true; // decode worker 0
    }

    // --- Workers ------------------------------------------------------------
    let prefill_workers: Vec<PrefillWorker> = (0..cfg.pools.prefill_workers)
        .map(|w| PrefillWorker {
            gpus: (0..cfg.pools.gpus_per_prefill_worker)
                .map(|g| w * cfg.pools.gpus_per_prefill_worker + g)
                .collect(),
            queue: router.queue_of_worker(w),
            current: None,
            seq: 0,
        })
        .collect();
    let decode_workers: Vec<DecodeWorker> = (0..cfg.pools.decode_workers)
        .map(|w| DecodeWorker {
            gpu: n_prefill_gpus + w * cfg.pools.gpus_per_decode_worker,
            streams: Vec::new(),
            round_active: false,
            round_start: 0.0,
            seq: 0,
            batch_samples: 0,
            batch_sum: 0,
        })
        .collect();

    // --- Policy (the pluggable governor) -------------------------------------
    let policy = policy::build(cfg, &perf, &power);
    if let Some(mhz) = policy.initial_clock_mhz() {
        for g in gpus.iter_mut() {
            g.set_app_clock(0.0, mhz);
        }
    }
    let tick_specs = policy.ticks();

    let mut engine = Engine {
        cfg,
        trace,
        opts,
        perf,
        router,
        q: EventQueue::new(),
        gpus,
        prefill_queues: vec![VecDeque::new(), VecDeque::new()],
        prefill_workers,
        decode_workers,
        decode_wait: VecDeque::new(),
        policy,
        tick_specs,
        slo: {
            let mut t = SloTracker::new(cfg.slo.clone());
            t.keep_outcomes = opts.keep_outcomes;
            t
        },
        rng: Pcg64::new(cfg.seed, 0xE2617E),
        completed: 0,
        generated_tokens: 0,
        global_tps: TpsWindow::new(0.2),
        tps_series: Vec::new(),
        jobs_scratch: Vec::new(),
        view_scratch: PoolView::default(),
        plan_scratch: ClockPlan::default(),
        ttft_target_sm: cfg.slo.ttft_short_medium_s * cfg.prefill_margin,
        ttft_target_long: cfg.slo.ttft_long_s * cfg.prefill_margin,
    };
    engine.run_loop()
}

impl<'a> Engine<'a> {
    fn run_loop(&mut self) -> RunResult {
        // Seed arrivals + policy ticks (in declaration order so replays of
        // the pre-refactor method wiring stay bit-identical).
        let trace = self.trace;
        for (i, req) in trace.requests.iter().enumerate() {
            self.q.schedule(req.arrival_s, Ev::Arrive(i));
        }
        let specs = self.tick_specs.clone();
        for (kind, spec) in specs.iter().enumerate() {
            self.q.schedule(spec.interval_s, Ev::PolicyTick(kind));
        }
        if self.opts.record_tps_series {
            self.q.schedule(0.2, Ev::SampleTick);
        }

        let total = self.trace.requests.len() as u64;
        while self.completed < total {
            let Some((t, ev)) = self.q.pop() else { break };
            match ev {
                Ev::Arrive(i) => self.on_arrive(t, i),
                Ev::PrefillDone { worker, seq } => self.on_prefill_done(t, worker, seq),
                Ev::DecodeRound { worker, seq } => self.on_decode_round(t, worker, seq),
                Ev::PolicyTick(kind) => {
                    self.policy_tick(t, kind);
                    if self.completed < total {
                        let dt = self.tick_specs[kind].interval_s;
                        self.q.schedule_in(dt, Ev::PolicyTick(kind));
                    }
                }
                Ev::SampleTick => {
                    let tps = self.global_tps.tps(t);
                    self.tps_series.push((t, tps));
                    if self.completed < total {
                        self.q.schedule_in(0.2, Ev::SampleTick);
                    }
                }
            }
        }

        // Final energy integration.
        let end_t = self.q.now().max(self.trace.duration_s);
        for g in self.gpus.iter_mut() {
            g.advance(end_t);
        }
        let n_prefill_gpus =
            self.cfg.pools.prefill_workers * self.cfg.pools.gpus_per_prefill_worker;
        let prefill_energy: f64 = self.gpus[..n_prefill_gpus]
            .iter()
            .map(|g| g.energy_j())
            .sum();
        let decode_energy: f64 = self.gpus[n_prefill_gpus..]
            .iter()
            .map(|g| g.energy_j())
            .sum();
        let (bsum, bsamp) = self
            .decode_workers
            .iter()
            .fold((0u64, 0u64), |(s, n), w| (s + w.batch_sum, n + w.batch_samples));
        let diag = self.policy.diagnostics();

        RunResult {
            trace_name: self.trace.name.clone(),
            method: self.cfg.method,
            slo: std::mem::replace(&mut self.slo, SloTracker::new(self.cfg.slo.clone())),
            prefill_energy_j: prefill_energy,
            decode_energy_j: decode_energy,
            total_energy_j: prefill_energy + decode_energy,
            generated_tokens: self.generated_tokens,
            completed: self.completed,
            sim_duration_s: end_t,
            events_processed: self.q.popped,
            decode_freq_trace: self.gpus[n_prefill_gpus].freq_trace.clone(),
            prefill_freq_trace: self.gpus[0].freq_trace.clone(),
            decode_tps_series: std::mem::take(&mut self.tps_series),
            mean_decode_batch: if bsamp == 0 {
                0.0
            } else {
                bsum as f64 / bsamp as f64
            },
            band_switches: diag.band_switches,
            adaptations: diag.adaptations,
            fine_ticks: diag.fine_ticks,
        }
    }

    // -- helpers -------------------------------------------------------------

    fn set_worker_clock(&mut self, t: f64, first_gpu: usize, n: usize, mhz: u32) {
        for g in first_gpu..first_gpu + n {
            self.gpus[g].set_app_clock(t, mhz);
        }
    }

    fn prefill_clock(&self, worker: usize) -> u32 {
        self.gpus[self.prefill_workers[worker].gpus[0]].sm_clock()
    }

    fn set_prefill_worker_clock(&mut self, t: f64, worker: usize, mhz: u32) {
        let (g0, n) = (
            self.prefill_workers[worker].gpus[0],
            self.prefill_workers[worker].gpus.len(),
        );
        self.set_worker_clock(t, g0, n, mhz);
    }

    /// Deadline for a request's first token under the controller margin.
    fn deadline_of(&self, req_idx: usize) -> f64 {
        let r = &self.trace.requests[req_idx];
        let slo = match r.route_class() {
            crate::workload::request::RouteClass::Long => self.ttft_target_long,
            _ => self.ttft_target_sm,
        };
        r.arrival_s + slo
    }

    /// Append `worker`'s queue view: the in-flight job heads the FIFO (its
    /// remaining work over-approximated by its full t_ref — conservative),
    /// then the backlog.
    fn fill_jobs(&self, worker: usize, out: &mut Vec<PrefillJobView>) {
        let queue = self.prefill_workers[worker].queue;
        if let Some((req_idx, _)) = self.prefill_workers[worker].current {
            out.push(PrefillJobView {
                prompt_len: self.trace.requests[req_idx].prompt_len,
                deadline_s: self.deadline_of(req_idx),
            });
        }
        out.extend(self.prefill_queues[queue].iter().map(|j| PrefillJobView {
            prompt_len: self.trace.requests[j.req_idx].prompt_len,
            deadline_s: self.deadline_of(j.req_idx),
        }));
    }

    /// One periodic policy callback: snapshot telemetry, collect the clock
    /// plan, apply it (prefill pool first, then decode — the order the
    /// pre-refactor governors used).
    fn policy_tick(&mut self, t: f64, kind: usize) {
        let spec = self.tick_specs[kind];
        let mut view = std::mem::take(&mut self.view_scratch);
        view.now = t;
        view.prefill.resize_with(self.prefill_workers.len(), Default::default);
        for (w, pv) in view.prefill.iter_mut().enumerate() {
            pv.busy = self.prefill_workers[w].current.is_some();
            pv.jobs.clear();
            if spec.prefill_jobs {
                self.fill_jobs(w, &mut pv.jobs);
            }
        }
        view.decode.clear();
        if spec.decode_view {
            for w in &self.decode_workers {
                let batch = w.streams.len();
                let avg_ctx = if batch == 0 {
                    0.0
                } else {
                    w.streams.iter().map(|s| s.ctx).sum::<f64>() / batch as f64
                };
                view.decode.push(DecodeWorkerView { batch, avg_ctx });
            }
        }

        let mut plan = std::mem::take(&mut self.plan_scratch);
        plan.reset(self.prefill_workers.len(), self.decode_workers.len());
        self.policy.on_tick(kind, t, &view, &mut plan);

        for (w, mhz) in plan.prefill_mhz.iter().enumerate() {
            if let Some(mhz) = mhz {
                self.set_prefill_worker_clock(t, w, *mhz);
            }
        }
        for (w, mhz) in plan.decode_mhz.iter().enumerate() {
            if let Some(mhz) = mhz {
                let gpu = self.decode_workers[w].gpu;
                self.set_worker_clock(t, gpu, 1, *mhz);
            }
        }
        self.view_scratch = view;
        self.plan_scratch = plan;
    }

    // -- prefill -------------------------------------------------------------

    fn on_arrive(&mut self, t: f64, req_idx: usize) {
        let queue = self.router.queue_for(&self.trace.requests[req_idx]);
        self.prefill_queues[queue].push_back(QueuedJob { req_idx });
        // Kick an idle worker serving (or allowed to steal from) this queue.
        let workers = self.router.candidate_workers(queue);
        if let Some(&w) = workers
            .iter()
            .find(|&&w| self.prefill_workers[w].current.is_none())
        {
            self.dispatch_prefill(t, w);
        } else if self.policy.wants_backlog_updates() {
            // Queue grew: let the policy react immediately for busy
            // workers too (clock applies to subsequent jobs).
            for w in workers {
                let mut jobs = std::mem::take(&mut self.jobs_scratch);
                jobs.clear();
                self.fill_jobs(w, &mut jobs);
                let decision = self.policy.on_prefill_backlog(t, w, &jobs);
                self.jobs_scratch = jobs;
                if let Some(mhz) = decision {
                    self.set_prefill_worker_clock(t, w, mhz);
                }
            }
        }
    }

    fn dispatch_prefill(&mut self, t: f64, worker: usize) {
        let queue = self.prefill_workers[worker].queue;
        let job = self.prefill_queues[queue].pop_front().or_else(|| {
            // Own queue drained: steal if the router allows it.
            self.router
                .steal_queue_of_worker(worker)
                .and_then(|q| self.prefill_queues[q].pop_front())
        });
        let Some(job) = job else {
            // Nothing to do: park util at 0 (and clock, if the policy says).
            let (g0, n) = (
                self.prefill_workers[worker].gpus[0],
                self.prefill_workers[worker].gpus.len(),
            );
            for g in g0..g0 + n {
                self.gpus[g].set_util(t, 0.0);
            }
            if let Some(mhz) = self.policy.on_prefill_idle(t, worker) {
                self.set_worker_clock(t, g0, n, mhz);
            }
            return;
        };
        // Mark the job in flight *before* the clock decision so the
        // policy accounts for its work.
        self.prefill_workers[worker].seq += 1;
        let seq = self.prefill_workers[worker].seq;
        self.prefill_workers[worker].current = Some((job.req_idx, seq));
        // Refresh the clock decision at the dispatch boundary.
        let mut jobs = std::mem::take(&mut self.jobs_scratch);
        jobs.clear();
        if self.policy.wants_prefill_jobs() {
            self.fill_jobs(worker, &mut jobs);
        }
        let decision = self.policy.on_prefill_dispatch(t, worker, &jobs);
        self.jobs_scratch = jobs;
        if let Some(mhz) = decision {
            self.set_prefill_worker_clock(t, worker, mhz);
        }
        let mhz = self.prefill_clock(worker);
        let len = self.trace.requests[job.req_idx].prompt_len;
        let dt = self.perf.prefill_time(len as usize, mhz) * self.rng.noise(self.cfg.sim_noise);
        let (g0, n) = (
            self.prefill_workers[worker].gpus[0],
            self.prefill_workers[worker].gpus.len(),
        );
        for g in g0..g0 + n {
            self.gpus[g].set_util(t, 1.0);
        }
        self.q.schedule(t + dt, Ev::PrefillDone { worker, seq });
    }

    fn on_prefill_done(&mut self, t: f64, worker: usize, seq: u64) {
        let Some((req_idx, cur_seq)) = self.prefill_workers[worker].current else {
            return;
        };
        if cur_seq != seq {
            return; // stale event
        }
        self.prefill_workers[worker].current = None;
        let req = &self.trace.requests[req_idx];
        let ttft = t - req.arrival_s;
        self.generated_tokens += 1; // prefill emits the first token
        self.global_tps.record(t, 1);

        if req.output_len <= 1 {
            // Prefill-only request (microbenchmarks): complete now.
            let outcome = RequestOutcome {
                id: req.id,
                prompt_len: req.prompt_len,
                output_len: req.output_len,
                arrival_s: req.arrival_s,
                ttft_s: ttft,
                tbt_p95_s: 0.0,
                finish_s: t,
            };
            self.slo.record(outcome);
            self.completed += 1;
        } else {
            let stream = Stream {
                req_idx,
                remaining: req.output_len - 1,
                ctx: req.prompt_len as f64 + 1.0,
                last_token_t: t,
                joined_t: t,
                tbts: Vec::with_capacity(req.output_len as usize),
            };
            self.admit_stream(t, stream, ttft);
        }
        // Next job (or park).
        self.dispatch_prefill(t, worker);
    }

    // -- decode ----------------------------------------------------------------

    fn admit_stream(&mut self, t: f64, stream: Stream, _ttft: f64) {
        // TTFT is recorded at completion together with TBT stats; stash it
        // via the stream's joined_t (= prefill done time).
        let cap = self.cfg.pools.max_streams_per_decode_worker;
        let best = (0..self.decode_workers.len())
            .filter(|&w| self.decode_workers[w].streams.len() < cap)
            .min_by_key(|&w| self.decode_workers[w].streams.len());
        match best {
            Some(w) => {
                self.decode_workers[w].streams.push(stream);
                if !self.decode_workers[w].round_active {
                    self.start_round(t, w);
                }
            }
            None => self.decode_wait.push_back(stream),
        }
    }

    fn start_round(&mut self, t: f64, worker: usize) {
        let w = &mut self.decode_workers[worker];
        if w.streams.is_empty() {
            w.round_active = false;
            let gpu = w.gpu;
            self.gpus[gpu].set_util(t, 0.0);
            return;
        }
        w.round_active = true;
        w.round_start = t;
        w.seq += 1;
        let seq = w.seq;
        let batch = w.streams.len();
        let avg_ctx = w.streams.iter().map(|s| s.ctx).sum::<f64>() / batch as f64;
        w.batch_samples += 1;
        w.batch_sum += batch as u64;
        let gpu = w.gpu;
        let mhz = self.gpus[gpu].sm_clock();
        let util = self.perf.decode_util(batch);
        self.gpus[gpu].set_util(t, util);
        let dt =
            self.perf.decode_step_time(batch, avg_ctx, mhz) * self.rng.noise(self.cfg.sim_noise);
        self.q.schedule(t + dt, Ev::DecodeRound { worker, seq });
    }

    fn on_decode_round(&mut self, t: f64, worker: usize, seq: u64) {
        if self.decode_workers[worker].seq != seq || !self.decode_workers[worker].round_active {
            return; // stale
        }
        let round_start = self.decode_workers[worker].round_start;
        let mut emitted: u32 = 0;
        let mut finished: Vec<Stream> = Vec::new();
        let mut steady: u32 = 0;
        {
            // Single fused pass: emit tokens AND feed the policy's TBT
            // telemetry (split borrows keep this allocation-free). Steady
            // streams (last token at round start) all observe the same
            // round-duration TBT, fed as ONE weighted sample below — §Perf.
            let w = &mut self.decode_workers[worker];
            let policy = &mut self.policy;
            let mut i = 0;
            while i < w.streams.len() {
                // Streams that joined mid-round wait for the next one.
                if w.streams[i].joined_t > round_start {
                    i += 1;
                    continue;
                }
                let s = &mut w.streams[i];
                let tbt = t - s.last_token_t;
                s.tbts.push(tbt);
                if s.last_token_t == round_start {
                    steady += 1;
                } else {
                    policy.on_decode_tbt(worker, tbt); // fresh joiner
                }
                s.last_token_t = t;
                s.ctx += 1.0;
                s.remaining -= 1;
                emitted += 1;
                if s.remaining == 0 {
                    finished.push(w.streams.swap_remove(i));
                } else {
                    i += 1;
                }
            }
        }
        self.generated_tokens += emitted as u64;
        self.global_tps.record(t, emitted);
        self.policy.on_decode_tbt_weighted(worker, t - round_start, steady);
        self.policy.on_decode_tokens(worker, t, emitted);
        for s in finished {
            self.finish_stream(t, s);
        }
        // Backfill from the wait queue.
        let cap = self.cfg.pools.max_streams_per_decode_worker;
        while self.decode_workers[worker].streams.len() < cap {
            match self.decode_wait.pop_front() {
                Some(s) => self.decode_workers[worker].streams.push(s),
                None => break,
            }
        }
        self.start_round(t, worker);
    }

    fn finish_stream(&mut self, t: f64, s: Stream) {
        let req = &self.trace.requests[s.req_idx];
        let ttft = s.joined_t - req.arrival_s;
        let tbt_p95 = percentile_exact(&s.tbts, 0.95);
        self.slo.record(RequestOutcome {
            id: req.id,
            prompt_len: req.prompt_len,
            output_len: req.output_len,
            arrival_s: req.arrival_s,
            ttft_s: ttft,
            tbt_p95_s: tbt_p95,
            finish_s: t,
        });
        self.completed += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::request::Request;

    fn tiny_trace(n: usize, qps: f64, prompt: u32, output: u32) -> Trace {
        Trace {
            name: "test".into(),
            duration_s: n as f64 / qps,
            requests: (0..n)
                .map(|i| Request {
                    id: i as u64,
                    arrival_s: i as f64 / qps,
                    prompt_len: prompt,
                    output_len: output,
                })
                .collect(),
        }
    }

    fn cfg(method: Method) -> Config {
        Config {
            method,
            sim_noise: 0.0,
            ..Config::default()
        }
    }

    #[test]
    fn all_requests_complete_exactly_once() {
        for method in [Method::DefaultNv, Method::GreenLlm, Method::Fixed(900)] {
            let trace = tiny_trace(50, 5.0, 400, 20);
            let r = run(&cfg(method), &trace, &RunOptions::default());
            assert_eq!(r.completed, 50, "{method:?}");
            assert_eq!(r.slo.completed, 50);
        }
    }

    #[test]
    fn new_policies_complete_all_requests() {
        for method in [Method::Agft, Method::PiTbt, Method::Throttle] {
            let trace = tiny_trace(50, 5.0, 400, 20);
            let r = run(&cfg(method), &trace, &RunOptions::default());
            assert_eq!(r.completed, 50, "{method:?}");
            assert_eq!(r.generated_tokens, 50 * 20, "{method:?}");
        }
    }

    #[test]
    fn token_accounting_exact() {
        let trace = tiny_trace(20, 4.0, 300, 16);
        let r = run(&cfg(Method::GreenLlm), &trace, &RunOptions::default());
        assert_eq!(r.generated_tokens, 20 * 16);
    }

    #[test]
    fn prefill_only_requests_complete_at_prefill() {
        let trace = tiny_trace(10, 2.0, 512, 1);
        let r = run(&cfg(Method::DefaultNv), &trace, &RunOptions::default());
        assert_eq!(r.completed, 10);
        assert_eq!(r.generated_tokens, 10);
        // TTFT ≈ prefill time at boost clocks (~60 ms), way under SLO.
        assert_eq!(r.slo.ttft_pass_rate(), 1.0);
    }

    #[test]
    fn deterministic_replay() {
        let trace = tiny_trace(40, 5.0, 400, 30);
        let a = run(&cfg(Method::GreenLlm), &trace, &RunOptions::default());
        let b = run(&cfg(Method::GreenLlm), &trace, &RunOptions::default());
        assert_eq!(a.total_energy_j, b.total_energy_j);
        assert_eq!(a.generated_tokens, b.generated_tokens);
        assert_eq!(a.slo.ttft_pass_rate(), b.slo.ttft_pass_rate());
    }

    #[test]
    fn deterministic_replay_new_policies() {
        for method in [Method::Agft, Method::PiTbt] {
            let trace = tiny_trace(40, 5.0, 400, 30);
            let a = run(&cfg(method), &trace, &RunOptions::default());
            let b = run(&cfg(method), &trace, &RunOptions::default());
            assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
            assert_eq!(a.events_processed, b.events_processed);
        }
    }

    #[test]
    fn energy_positive_and_split_by_pool() {
        let trace = tiny_trace(20, 4.0, 400, 20);
        let r = run(&cfg(Method::DefaultNv), &trace, &RunOptions::default());
        assert!(r.prefill_energy_j > 0.0);
        assert!(r.decode_energy_j > 0.0);
        assert!((r.total_energy_j - r.prefill_energy_j - r.decode_energy_j).abs() < 1e-9);
        // Lower bound: every GPU at least idles for the duration.
        let idle_floor = 8.0 * 40.0 * r.sim_duration_s;
        assert!(r.total_energy_j > idle_floor);
    }

    #[test]
    fn greenllm_saves_energy_at_light_load() {
        let trace = tiny_trace(60, 2.0, 400, 60);
        let nv = run(&cfg(Method::DefaultNv), &trace, &RunOptions::default());
        let green = run(&cfg(Method::GreenLlm), &trace, &RunOptions::default());
        assert!(
            green.total_energy_j < 0.95 * nv.total_energy_j,
            "green={} nv={}",
            green.total_energy_j,
            nv.total_energy_j
        );
        // ... without tanking SLOs.
        assert!(green.slo.ttft_pass_rate() > 0.9);
        assert!(green.slo.tbt_pass_rate() > 0.9);
    }

    #[test]
    fn pi_controller_saves_energy_at_light_load() {
        let trace = tiny_trace(60, 2.0, 400, 60);
        let nv = run(&cfg(Method::DefaultNv), &trace, &RunOptions::default());
        let pi = run(&cfg(Method::PiTbt), &trace, &RunOptions::default());
        assert!(
            pi.total_energy_j < nv.total_energy_j,
            "pi={} nv={}",
            pi.total_energy_j,
            nv.total_energy_j
        );
    }

    #[test]
    fn slo_pass_rates_high_at_moderate_load() {
        let trace = tiny_trace(100, 5.0, 400, 40);
        let r = run(&cfg(Method::GreenLlm), &trace, &RunOptions::default());
        assert!(r.slo.ttft_pass_rate() > 0.95, "{}", r.slo.ttft_pass_rate());
        assert!(r.slo.tbt_pass_rate() > 0.9, "{}", r.slo.tbt_pass_rate());
    }

    #[test]
    fn freq_trace_recorded_when_requested() {
        let trace = tiny_trace(30, 5.0, 400, 30);
        let opts = RunOptions {
            record_freq_trace: true,
            record_tps_series: true,
            ..Default::default()
        };
        let r = run(&cfg(Method::GreenLlm), &trace, &opts);
        assert!(!r.decode_freq_trace.is_empty());
        assert!(!r.decode_tps_series.is_empty());
    }

    #[test]
    fn decode_batch_occupancy_reported() {
        let trace = tiny_trace(40, 8.0, 300, 50);
        let r = run(&cfg(Method::DefaultNv), &trace, &RunOptions::default());
        assert!(r.mean_decode_batch >= 1.0);
    }
}

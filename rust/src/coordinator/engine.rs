//! The discrete-event serving engine: replays a trace against the
//! simulated DGX-A100 node under a pluggable [`DvfsPolicy`] and produces
//! energy + SLO results.
//!
//! Topology (paper Fig. 4): requests arrive → router → per-class prefill
//! queues → prefill pool (default 2 workers × 2 GPUs, one job at a time per
//! worker) → decode pool (default 4 workers × 1 GPU, continuous batching) →
//! token stream. The engine owns queues, workers and GPUs; every frequency
//! decision flows through the policy layer (`coordinator::policy`), which
//! receives telemetry snapshots and event-driven TBT/token feedback and
//! answers with NVML-style application clocks. Adding a governor therefore
//! never touches this event loop.
//!
//! The engine runs in two modes sharing one code path:
//! * *Replay* ([`run`]): the whole trace is pre-scheduled and the loop is
//!   driven to completion internally — the single-node experiments.
//! * *Stepped* (cluster): [`Engine::new`] + [`Engine::begin`] build an
//!   engine with no arrivals; the cluster event loop injects requests
//!   online ([`Engine::inject`]) and advances the node one event at a time
//!   ([`Engine::step`]) so many nodes interleave on one virtual clock.
//!   Live telemetry accessors (queue depths, outstanding prefill tokens,
//!   decode TBT tail) feed the cluster load balancer, and
//!   [`Engine::set_clock_cap`] lets the power arbiter clamp every clock
//!   the policy requests. The chaos layer drives two more hooks:
//!   [`Engine::fail`] (drain all incomplete requests for re-routing,
//!   power off, cancel pending events) and [`Engine::recover`] (power
//!   on with cold telemetry and re-armed ticks).

use crate::config::{Config, Method};
use crate::coordinator::policy::{self, DvfsPolicy};
use crate::coordinator::router::Router;
use crate::coordinator::telemetry::{ClockPlan, DecodeWorkerView, PoolView, TickSpec};
use crate::dvfs::prefill_opt::PrefillJobView;
use crate::gpu::control::{ControlPlane, WriteAction};
use crate::gpu::device::SimGpu;
use crate::gpu::freq::FreqLadder;
use crate::gpu::perf::PerfModel;
use crate::gpu::power::PowerModel;
use crate::metrics::{SlidingP95, TpsWindow};
use crate::model::ModelSpec;
use crate::obs::{NodeSample, NoopRecorder, Recorder};
use crate::sim::EventQueue;
use crate::slo::{RequestOutcome, SloTracker};
use crate::util::rng::Pcg64;
use crate::util::stats::percentile_in_place;
use crate::workload::request::{Request, Trace};

use std::collections::VecDeque;
use std::ops::Index;

/// Recent-TBT window used for the cluster balancer's per-node tail signal.
const TBT_TAIL_WINDOW: usize = 256;

/// Run options (figure-specific recording).
#[derive(Debug, Clone, Default)]
pub struct RunOptions {
    /// Record (t, MHz) for decode worker 0's GPU and prefill worker 0's GPU.
    pub record_freq_trace: bool,
    /// Record aggregate decode TPS every 200 ms.
    pub record_tps_series: bool,
    /// Keep per-request outcomes (Fig. 5 distributions).
    pub keep_outcomes: bool,
    /// Maintain a sliding P95 over recent decode TBTs (cluster balancer
    /// telemetry). Off by default: single-node replays skip the cost.
    pub track_tbt_tail: bool,
}

/// Results of one replay.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Name of the replayed trace.
    pub trace_name: String,
    /// Serving policy the node ran.
    pub method: Method,
    /// SLO accounting (TTFT/TBT pass rates, latency histograms).
    pub slo: SloTracker,
    /// Prefill-pool energy, joules.
    pub prefill_energy_j: f64,
    /// Decode-pool energy, joules.
    pub decode_energy_j: f64,
    /// Whole-node energy, joules.
    pub total_energy_j: f64,
    /// Useful (delivered) tokens; excludes tokens rolled back at a node
    /// failure (see `wasted_tokens`).
    pub generated_tokens: u64,
    /// Requests completed on this node.
    pub completed: u64,
    /// Virtual end time of the run, seconds.
    pub sim_duration_s: f64,
    /// Discrete events processed by the node's loop.
    pub events_processed: u64,
    /// (t, MHz) trace of decode worker 0's GPU (when recorded).
    pub decode_freq_trace: Vec<(f64, u32)>,
    /// (t, MHz) trace of prefill worker 0's GPU (when recorded).
    pub prefill_freq_trace: Vec<(f64, u32)>,
    /// (t, tokens/s) aggregate decode throughput samples (when recorded).
    pub decode_tps_series: Vec<(f64, f64)>,
    /// Mean decode batch occupancy (diagnostics).
    pub mean_decode_batch: f64,
    /// Tokens generated then rolled back because the node failed
    /// mid-stream (chaos layer); the energy spent on them is kept.
    pub wasted_tokens: u64,
    /// Coarse-band switches across the decode pool (GreenLLM only;
    /// zero otherwise).
    pub band_switches: u64,
    /// Band-table adaptations (GreenLLM only; zero otherwise).
    pub adaptations: u64,
    /// Fine-loop ticks across the decode pool (GreenLLM only; zero
    /// otherwise).
    pub fine_ticks: u64,
    /// Times the governor supervisor failed safe to its pinned fallback
    /// clock (zero when the supervisor is off).
    pub supervisor_fallbacks: u64,
    /// Times the supervisor handed control back to the wrapped policy
    /// after a clean probation.
    pub supervisor_reengages: u64,
    /// Policy clock writes silently lost by the control plane.
    pub ctl_dropped_writes: u64,
    /// Policy clock writes deferred by actuation latency.
    pub ctl_delayed_writes: u64,
    /// Policy clock writes that landed one ladder rung off target.
    pub ctl_missteps: u64,
    /// Policy feedback deliveries suppressed by telemetry blackouts.
    pub ctl_suppressed_samples: u64,
}

impl RunResult {
    /// Throughput in generated tokens/s over the run.
    pub fn throughput_tps(&self) -> f64 {
        if self.sim_duration_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.sim_duration_s
    }

    /// Whole-node energy in watt-hours.
    pub fn total_energy_wh(&self) -> f64 {
        self.total_energy_j / 3600.0
    }
}

// ---------------------------------------------------------------------------
// Internal state
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrive(usize),
    PrefillDone { worker: usize, seq: u64 },
    DecodeRound { worker: usize, seq: u64 },
    /// A policy-requested periodic callback (index into the tick specs).
    PolicyTick(usize),
    SampleTick,
    /// A clock write deferred by control-plane actuation latency; lands
    /// only if `seq` is still the worker's latest write ticket.
    CtlApply {
        first_gpu: usize,
        n: usize,
        mhz: u32,
        seq: u64,
    },
}

/// Request storage behind the engine's two modes (§Perf): replay *borrows*
/// the trace's request list — matrix cells share one generated trace with
/// zero per-run copying — while stepped mode grows an owned list online
/// through [`Engine::inject`].
#[derive(Debug)]
enum RequestStore<'a> {
    /// Replay mode: the whole trace, borrowed for the engine's lifetime.
    Borrowed(&'a [Request]),
    /// Stepped mode: requests handed to this node so far.
    Owned(Vec<Request>),
}

impl RequestStore<'_> {
    fn len(&self) -> usize {
        match self {
            RequestStore::Borrowed(s) => s.len(),
            RequestStore::Owned(v) => v.len(),
        }
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn push(&mut self, r: Request) {
        match self {
            RequestStore::Owned(v) => v.push(r),
            RequestStore::Borrowed(_) => panic!("inject into a replay-mode engine"),
        }
    }
}

impl Index<usize> for RequestStore<'_> {
    type Output = Request;

    fn index(&self, i: usize) -> &Request {
        match self {
            RequestStore::Borrowed(s) => &s[i],
            RequestStore::Owned(v) => &v[i],
        }
    }
}

#[derive(Debug)]
struct QueuedJob {
    req_idx: usize,
}

/// A stream whose prefill finished on a migrate-out node (P/D
/// disaggregation) and now needs a decode home. The cluster loop drains
/// these via [`Engine::take_migrations`], routes each one, charges the
/// KV-transfer cost to both ends and delivers it with
/// [`Engine::migrate_in`] after the modeled link latency.
#[derive(Debug, Clone)]
pub struct MigratedStream {
    /// The request (re-injected into the receiving node's store).
    pub req: Request,
    /// When the prefill (and so the first token) finished on the sender —
    /// the receiver's TTFT anchor, unaffected by transfer latency.
    pub prefill_done_s: f64,
}

#[derive(Debug)]
struct PrefillWorker {
    gpus: Vec<usize>,
    queue: usize,
    /// (req_idx, completion event seq) of the in-flight job.
    current: Option<(usize, u64)>,
    seq: u64,
}

/// Generational handle into the engine's [`StreamArena`] (§Perf). Copy
/// + 8 bytes: batches and the wait queue move ids, never stream state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct StreamId {
    slot: u32,
    gen: u32,
}

/// Slab arena for decode streams, split structure-of-arrays (§Perf).
///
/// Pre-PR5, `Stream` structs lived inside each worker's batch `Vec` and
/// moved between batch / wait-queue / scratch on every transition, and
/// their TBT buffers recycled through a separate engine-level free list.
/// Now every stream occupies one *slot* for its whole life: the
/// decode-round hot fields (`ctx`, `remaining`, `last_token_t`) sit in
/// their own dense arrays so `on_decode_round` walks contiguous memory,
/// admission/abort/finish move only 8-byte ids, and the per-stream TBT
/// buffer lives *in the slot* — freeing a slot clears the buffer in
/// place and the next stream allocated there reuses it, which subsumes
/// the old `tbt_pool` free list. Slot reuse is guarded by a generation
/// counter (stale-id access is a debug panic, not a silent corruption).
#[derive(Debug, Default)]
struct StreamArena {
    // Hot fields, touched every decode round:
    ctx: Vec<f64>,
    remaining: Vec<u32>,
    last_token_t: Vec<f64>,
    // Cold fields, touched at admit/finish/abort:
    joined_t: Vec<f64>,
    req_idx: Vec<usize>,
    /// TTFT recorded when the stream's prefill finished — locally or, for
    /// a migrated-in stream, on the *sending* node (`joined_t` is the
    /// local admission time, which for a migration is later by the KV
    /// transfer; the TTFT must not include that).
    ttft_s: Vec<f64>,
    /// Per-slot TBT buffer; cleared (capacity kept) when the slot frees.
    tbts: Vec<Vec<f64>>,
    /// Per-slot generation, bumped at free.
    gen: Vec<u32>,
    /// Free slot list (LIFO: the hottest slot is reused first).
    free: Vec<u32>,
    /// Live streams (== admitted and not yet finished/aborted).
    live: usize,
}

impl StreamArena {
    /// Claim a slot for a fresh stream; `tbt_capacity` pre-sizes the
    /// slot's (possibly recycled) TBT buffer, `ttft_s` is the stream's
    /// already-final first-token latency (see the field doc).
    fn alloc(
        &mut self,
        req_idx: usize,
        remaining: u32,
        ctx: f64,
        t: f64,
        tbt_capacity: usize,
        ttft_s: f64,
    ) -> StreamId {
        let slot = match self.free.pop() {
            Some(s) => s as usize,
            None => {
                let s = self.ctx.len();
                self.ctx.push(0.0);
                self.remaining.push(0);
                self.last_token_t.push(0.0);
                self.joined_t.push(0.0);
                self.req_idx.push(0);
                self.ttft_s.push(0.0);
                self.tbts.push(Vec::new());
                self.gen.push(0);
                s
            }
        };
        self.ctx[slot] = ctx;
        self.remaining[slot] = remaining;
        self.last_token_t[slot] = t;
        self.joined_t[slot] = t;
        self.req_idx[slot] = req_idx;
        self.ttft_s[slot] = ttft_s;
        debug_assert!(self.tbts[slot].is_empty(), "recycled TBT buffer not cleared");
        self.tbts[slot].reserve(tbt_capacity);
        self.live += 1;
        StreamId {
            slot: slot as u32,
            gen: self.gen[slot],
        }
    }

    /// Mean context length across a batch of ids (0.0 when empty) —
    /// shared by round sizing and the decode telemetry view.
    fn avg_ctx(&self, ids: &[StreamId]) -> f64 {
        if ids.is_empty() {
            return 0.0;
        }
        let mut sum = 0.0;
        for &id in ids {
            sum += self.ctx[self.slot(id)];
        }
        sum / ids.len() as f64
    }

    /// Validated slot index of a live id.
    #[inline]
    fn slot(&self, id: StreamId) -> usize {
        debug_assert_eq!(
            self.gen[id.slot as usize], id.gen,
            "stale stream id {id:?}"
        );
        id.slot as usize
    }

    /// Release a slot: the TBT buffer clears in place (capacity kept for
    /// the next occupant) and the generation advances so stale ids trap.
    fn release(&mut self, id: StreamId) {
        let slot = self.slot(id);
        self.tbts[slot].clear();
        self.gen[slot] = self.gen[slot].wrapping_add(1);
        self.free.push(slot as u32);
        self.live -= 1;
    }
}

#[derive(Debug)]
struct DecodeWorker {
    gpu: usize,
    /// Ids of the streams in this worker's continuous batch.
    streams: Vec<StreamId>,
    round_active: bool,
    round_start: f64,
    seq: u64,
    batch_samples: u64,
    batch_sum: u64,
}

/// One simulated node. See the module docs for the replay vs stepped modes.
///
/// Generic over an observability [`Recorder`] (static dispatch). The
/// default [`NoopRecorder`] compiles every hook away — the unrecorded
/// engine is bit-exact with (and monomorphizes to) the pre-observability
/// code. A live recorder sees every lifecycle transition with this node's
/// cluster index attached.
pub struct Engine<'a, R: Recorder = NoopRecorder> {
    cfg: &'a Config,
    opts: &'a RunOptions,
    /// Requests this node has seen. In replay mode the full trace is
    /// borrowed up front (zero-copy); in stepped mode [`Engine::inject`]
    /// grows an owned list online.
    requests: RequestStore<'a>,
    trace_name: String,
    trace_duration_s: f64,
    /// `Some(n)` in replay mode: ticks stop rescheduling once `n` requests
    /// completed (the pre-refactor loop-exit condition, bit-for-bit).
    /// `None` in stepped mode: the cluster loop decides when to stop.
    replay_total: Option<u64>,
    perf: PerfModel,
    router: Router,
    q: EventQueue<Ev>,
    gpus: Vec<SimGpu>,
    prefill_queues: Vec<VecDeque<QueuedJob>>,
    prefill_workers: Vec<PrefillWorker>,
    decode_workers: Vec<DecodeWorker>,
    decode_wait: VecDeque<StreamId>,
    /// All decode-stream state, slab-allocated (§Perf): hot per-round
    /// fields in SoA arrays, TBT buffers recycled in place per slot.
    arena: StreamArena,
    /// The frequency governor under test — the only source of clock
    /// decisions in the whole loop.
    policy: Box<dyn DvfsPolicy>,
    tick_specs: Vec<TickSpec>,
    slo: SloTracker,
    rng: Pcg64,
    completed: u64,
    generated_tokens: u64,
    global_tps: TpsWindow,
    tps_series: Vec<(f64, f64)>,
    /// Reusable buffers for policy telemetry (hot path: every policy tick
    /// and prefill boundary — §Perf).
    jobs_scratch: Vec<PrefillJobView>,
    view_scratch: PoolView,
    plan_scratch: ClockPlan,
    /// Prefill deadline target per route class (SLO × margin).
    ttft_target_sm: f64,
    ttft_target_long: f64,
    /// Power-arbiter clock ceiling: every requested clock is clamped to
    /// this before reaching a GPU. `u32::MAX` = uncapped (no-op min).
    clock_cap_mhz: u32,
    /// Last clock each GPU's policy *requested* (pre-clamp). When the
    /// arbiter raises the cap, clamped GPUs return to their requested
    /// clock — tickless policies (Fixed) would otherwise ratchet down.
    requested_mhz: Vec<u32>,
    /// Straggler clock cap (chaos `slow` events): composes with the
    /// arbiter cap by min — a degraded node obeys whichever ceiling is
    /// lower. `u32::MAX` = not degraded.
    degraded_cap_mhz: u32,
    /// Straggler step-time multiplier (chaos `slow` events): every
    /// prefill/decode step takes this factor × its nominal time. 1.0 =
    /// not degraded; `x * 1.0` is a bitwise identity for finite positive
    /// step times, so the healthy path stays bit-exact.
    perf_slowdown: f64,
    /// Prompt tokens queued or in prefill flight (O(1) balancer signal).
    outstanding_prompt_tok: u64,
    /// Recent decode-TBT tail (only when `opts.track_tbt_tail`).
    tbt_tail: Option<SlidingP95>,
    /// Tokens emitted then rolled back by a node failure (chaos layer).
    wasted_tokens: u64,
    /// Reusable scratch for streams finishing within one decode round
    /// (§Perf: `on_decode_round` used to allocate this per round).
    finished_scratch: Vec<StreamId>,
    /// Reusable scratch for the chaos drain (§Perf: `Engine::fail_into`
    /// collects batched + waiting stream ids here before aborting them,
    /// so node loss moves ids instead of collecting `Stream` structs).
    ids_scratch: Vec<StreamId>,
    /// Disaggregation (prefill pool): finished prefills are handed out
    /// for decode-pool migration instead of admitted locally.
    migrate_out: bool,
    /// Streams awaiting pickup by the cluster loop (`migrate_out` only;
    /// drained by [`Engine::take_migrations`] right after each step).
    migrations: Vec<MigratedStream>,
    /// KV-transfer energy charged to this node (both ends of every
    /// migration pay; joules). Metered outside the GPU power integral —
    /// it reaches the energy totals at [`Engine::finalize`], not the
    /// arbiter's [`Engine::energy_now_j`] measurements.
    transfer_energy_j: f64,
    /// Faultable actuation/sensing boundary between the policy layer and
    /// the GPUs. Transparent (and RNG-silent) unless `[ctl]` noise is
    /// configured or a `ctl*` fault verb arms it at runtime.
    ctl: ControlPlane,
    /// Observability sink (zero-sized no-op by default).
    rec: R,
    /// This node's index in its cluster (0 for single-node runs); stamped
    /// on every recorder hook.
    node_id: usize,
}

/// Replay `trace` under `cfg`.
pub fn run(cfg: &Config, trace: &Trace, opts: &RunOptions) -> RunResult {
    run_with(cfg, trace, opts, NoopRecorder, 0)
}

/// Replay `trace` under `cfg` with a live [`Recorder`] attached as node
/// `node_id` ([`run`] is the zero-cost default).
pub fn run_with<R: Recorder>(
    cfg: &Config,
    trace: &Trace,
    opts: &RunOptions,
    rec: R,
    node_id: usize,
) -> RunResult {
    let mut engine =
        Engine::with_recorder(cfg, opts, trace.name.clone(), trace.duration_s, rec, node_id);
    engine.load_trace(&trace.requests);
    engine.begin();
    engine.run_loop()
}

impl<'a> Engine<'a> {
    /// Build a node engine with no scheduled arrivals. Call
    /// [`Engine::load_trace`] (replay) or [`Engine::inject`] (stepped) to
    /// feed it requests, and [`Engine::begin`] to arm the policy ticks.
    pub fn new(cfg: &'a Config, opts: &'a RunOptions, trace_name: String, duration_s: f64) -> Self {
        Engine::with_recorder(cfg, opts, trace_name, duration_s, NoopRecorder, 0)
    }
}

impl<'a, R: Recorder> Engine<'a, R> {
    /// [`Engine::new`] with an observability [`Recorder`] and this node's
    /// cluster index attached (the flight-recorder entry point).
    pub fn with_recorder(
        cfg: &'a Config,
        opts: &'a RunOptions,
        trace_name: String,
        duration_s: f64,
        rec: R,
        node_id: usize,
    ) -> Self {
        let spec = ModelSpec::by_name(&cfg.model)
            .unwrap_or_else(|| panic!("unknown model {:?}", cfg.model));
        // Per-node hardware (heterogeneity knobs): either the analytic
        // A100 envelope (default — bit-identical to all pre-zoo behavior)
        // or a calibrated part from `gpu::calibrate`, in both cases with a
        // possibly capped ladder and a scaled power envelope.
        let (perf, power) = if cfg.gpu.part.is_empty() {
            (PerfModel::new(spec), PowerModel::a100().scaled(cfg.gpu.power_scale))
        } else {
            let part = crate::gpu::calibrate::part(&cfg.gpu.part)
                .unwrap_or_else(|| panic!("unknown gpu.part {:?}", cfg.gpu.part));
            (part.perf_model(spec), part.power.clone().scaled(cfg.gpu.power_scale))
        };
        let ladder = cfg.gpu.ladder();
        let router = Router::new(cfg.method.routing(), cfg.pools.prefill_workers);

        // --- GPUs -------------------------------------------------------------
        let n_prefill_gpus = cfg.pools.prefill_workers * cfg.pools.gpus_per_prefill_worker;
        let n_gpus = n_prefill_gpus + cfg.pools.decode_workers * cfg.pools.gpus_per_decode_worker;
        let mut gpus: Vec<SimGpu> = (0..n_gpus)
            .map(|i| SimGpu::with_hardware(i, ladder.clone(), power.clone()))
            .collect();
        if opts.record_freq_trace {
            gpus[0].record_trace = true; // prefill worker 0, gpu 0
            gpus[n_prefill_gpus].record_trace = true; // decode worker 0
        }

        // --- Workers ----------------------------------------------------------
        let prefill_workers: Vec<PrefillWorker> = (0..cfg.pools.prefill_workers)
            .map(|w| PrefillWorker {
                gpus: (0..cfg.pools.gpus_per_prefill_worker)
                    .map(|g| w * cfg.pools.gpus_per_prefill_worker + g)
                    .collect(),
                queue: router.queue_of_worker(w),
                current: None,
                seq: 0,
            })
            .collect();
        let decode_workers: Vec<DecodeWorker> = (0..cfg.pools.decode_workers)
            .map(|w| DecodeWorker {
                gpu: n_prefill_gpus + w * cfg.pools.gpus_per_decode_worker,
                streams: Vec::new(),
                round_active: false,
                round_start: 0.0,
                seq: 0,
                batch_samples: 0,
                batch_sum: 0,
            })
            .collect();

        // --- Policy (the pluggable governor) ----------------------------------
        let policy = policy::build(cfg, &perf, &power);
        if let Some(mhz) = policy.initial_clock_mhz() {
            for g in gpus.iter_mut() {
                g.set_app_clock(0.0, mhz);
            }
        }
        let requested_mhz = vec![gpus[0].sm_clock(); n_gpus];
        let tick_specs = policy.ticks();

        Engine {
            cfg,
            opts,
            requests: RequestStore::Owned(Vec::new()),
            trace_name,
            trace_duration_s: duration_s,
            replay_total: None,
            perf,
            router,
            q: EventQueue::new(),
            gpus,
            prefill_queues: vec![VecDeque::new(), VecDeque::new()],
            prefill_workers,
            decode_workers,
            decode_wait: VecDeque::new(),
            arena: StreamArena::default(),
            policy,
            tick_specs,
            slo: {
                let mut t = SloTracker::new(cfg.slo.clone());
                t.keep_outcomes = opts.keep_outcomes;
                t
            },
            rng: Pcg64::new(cfg.seed, 0xE2617E),
            completed: 0,
            generated_tokens: 0,
            global_tps: TpsWindow::new(0.2),
            tps_series: Vec::new(),
            jobs_scratch: Vec::new(),
            view_scratch: PoolView::default(),
            plan_scratch: ClockPlan::default(),
            ttft_target_sm: cfg.slo.ttft_short_medium_s * cfg.prefill_margin,
            ttft_target_long: cfg.slo.ttft_long_s * cfg.prefill_margin,
            clock_cap_mhz: u32::MAX,
            requested_mhz,
            degraded_cap_mhz: u32::MAX,
            perf_slowdown: 1.0,
            outstanding_prompt_tok: 0,
            tbt_tail: opts
                .track_tbt_tail
                .then(|| SlidingP95::new(TBT_TAIL_WINDOW)),
            wasted_tokens: 0,
            finished_scratch: Vec::new(),
            ids_scratch: Vec::new(),
            migrate_out: false,
            migrations: Vec::new(),
            transfer_energy_j: 0.0,
            ctl: ControlPlane::new(&cfg.ctl, cfg.seed, n_gpus),
            rec,
            node_id,
        }
    }

    /// Pre-schedule a whole trace (replay mode). Arrivals get the lowest
    /// event sequence numbers, which keeps equal-time ordering identical to
    /// the pre-refactor loop. The request list is *borrowed*, not copied:
    /// matrix cells replaying the same cached trace share one allocation.
    pub fn load_trace(&mut self, requests: &'a [Request]) {
        debug_assert!(self.requests.is_empty(), "load_trace on a seeded engine");
        for (i, r) in requests.iter().enumerate() {
            self.q.schedule_priority(r.arrival_s, Ev::Arrive(i));
        }
        self.requests = RequestStore::Borrowed(requests);
        self.replay_total = Some(requests.len() as u64);
    }

    /// Arm policy ticks (and the TPS sampler). Call exactly once, after
    /// [`Engine::load_trace`] in replay mode.
    pub fn begin(&mut self) {
        let specs = self.tick_specs.clone();
        for (kind, spec) in specs.iter().enumerate() {
            self.q.schedule(spec.interval_s, Ev::PolicyTick(kind));
        }
        if self.opts.record_tps_series {
            self.q.schedule(0.2, Ev::SampleTick);
        }
    }

    /// Hand one request to this node at time `t` (stepped mode only).
    pub fn inject(&mut self, t: f64, req: Request) {
        debug_assert!(
            self.replay_total.is_none(),
            "inject into a replay-mode engine"
        );
        let idx = self.requests.len();
        self.requests.push(req);
        // Priority lane: an injected arrival orders exactly like a
        // pre-scheduled one at the same timestamp (see `sim`).
        self.q.schedule_priority(t, Ev::Arrive(idx));
    }

    /// Ticks keep rescheduling while the run is live. In replay mode that
    /// is "not all trace requests completed" (pre-refactor semantics); in
    /// stepped mode the cluster loop simply stops stepping when done.
    fn keep_ticking(&self) -> bool {
        match self.replay_total {
            Some(total) => self.completed < total,
            None => true,
        }
    }

    /// Process the next event. Returns false when the queue is empty.
    pub fn step(&mut self) -> bool {
        let Some((t, ev)) = self.q.pop() else {
            return false;
        };
        match ev {
            Ev::Arrive(i) => self.on_arrive(t, i),
            Ev::PrefillDone { worker, seq } => self.on_prefill_done(t, worker, seq),
            Ev::DecodeRound { worker, seq } => self.on_decode_round(t, worker, seq),
            Ev::PolicyTick(kind) => {
                self.policy_tick(t, kind);
                if self.keep_ticking() {
                    let dt = self.tick_specs[kind].interval_s;
                    self.q.schedule_in(dt, Ev::PolicyTick(kind));
                }
            }
            Ev::SampleTick => {
                let tps = self.global_tps.tps(t);
                self.tps_series.push((t, tps));
                if self.keep_ticking() {
                    self.q.schedule_in(0.2, Ev::SampleTick);
                }
            }
            Ev::CtlApply {
                first_gpu,
                n,
                mhz,
                seq,
            } => {
                // A newer write to the same worker supersedes this one;
                // clamping happens at apply time against the *current*
                // caps, so an arbiter or thermal ceiling imposed during
                // the actuation latency still wins.
                if self.ctl.write_is_current(first_gpu, seq) {
                    self.apply_worker_clock(t, first_gpu, n, mhz);
                }
            }
        }
        true
    }

    /// Drive a replay to completion (private: [`run`] is the public entry).
    fn run_loop(&mut self) -> RunResult {
        let total = self.replay_total.expect("run_loop requires load_trace");
        while self.completed < total {
            if !self.step() {
                break;
            }
        }
        self.finalize(self.trace_duration_s)
    }

    /// Final energy integration and result assembly. `end_floor` is the
    /// earliest admissible end time (the trace duration for a replay, the
    /// global cluster end otherwise).
    pub fn finalize(&mut self, end_floor: f64) -> RunResult {
        let end_t = self.q.now().max(end_floor);
        for g in self.gpus.iter_mut() {
            g.advance(end_t);
        }
        let n_prefill_gpus =
            self.cfg.pools.prefill_workers * self.cfg.pools.gpus_per_prefill_worker;
        let prefill_energy: f64 = self.gpus[..n_prefill_gpus]
            .iter()
            .map(|g| g.energy_j())
            .sum();
        let decode_energy: f64 = self.gpus[n_prefill_gpus..]
            .iter()
            .map(|g| g.energy_j())
            .sum();
        let (bsum, bsamp) = self
            .decode_workers
            .iter()
            .fold((0u64, 0u64), |(s, n), w| (s + w.batch_sum, n + w.batch_samples));
        let diag = self.policy.diagnostics();

        RunResult {
            trace_name: self.trace_name.clone(),
            method: self.cfg.method,
            slo: std::mem::replace(&mut self.slo, SloTracker::new(self.cfg.slo.clone())),
            prefill_energy_j: prefill_energy,
            decode_energy_j: decode_energy,
            // Whole node = both GPU pools plus this node's share of any
            // KV-transfer energy (0.0 outside disaggregated clusters, so
            // colocated totals are bit-identical).
            total_energy_j: prefill_energy + decode_energy + self.transfer_energy_j,
            generated_tokens: self.generated_tokens,
            completed: self.completed,
            sim_duration_s: end_t,
            events_processed: self.q.popped,
            decode_freq_trace: self.gpus[n_prefill_gpus].freq_trace.clone(),
            prefill_freq_trace: self.gpus[0].freq_trace.clone(),
            decode_tps_series: std::mem::take(&mut self.tps_series),
            mean_decode_batch: if bsamp == 0 {
                0.0
            } else {
                bsum as f64 / bsamp as f64
            },
            wasted_tokens: self.wasted_tokens,
            band_switches: diag.band_switches,
            adaptations: diag.adaptations,
            fine_ticks: diag.fine_ticks,
            supervisor_fallbacks: diag.supervisor_fallbacks,
            supervisor_reengages: diag.supervisor_reengages,
            ctl_dropped_writes: self.ctl.dropped_writes,
            ctl_delayed_writes: self.ctl.delayed_writes,
            ctl_missteps: self.ctl.missteps,
            ctl_suppressed_samples: self.ctl.suppressed_samples,
        }
    }

    // -- cluster-facing telemetry -------------------------------------------

    /// Virtual time of this node's last processed event.
    pub fn now(&self) -> f64 {
        self.q.now()
    }

    /// Time of the next pending event, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.q.peek_time()
    }

    /// Requests completed on this node so far.
    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Requests this node has been handed so far (drained requests stay
    /// counted — they were handed to this node, then re-homed).
    pub fn assigned(&self) -> usize {
        self.requests.len()
    }

    /// Prefill jobs queued or in flight.
    pub fn prefill_backlog(&self) -> usize {
        self.prefill_queues.iter().map(|q| q.len()).sum::<usize>()
            + self
                .prefill_workers
                .iter()
                .filter(|w| w.current.is_some())
                .count()
    }

    /// Prompt tokens queued or in prefill flight (maintained O(1)).
    pub fn outstanding_prompt_tokens(&self) -> u64 {
        self.outstanding_prompt_tok
    }

    /// Streams admitted to decode (batched or waiting) and not yet done.
    pub fn active_streams(&self) -> usize {
        self.arena.live
    }

    /// P95 of recent decode TBTs (0.0 until tracked samples exist; requires
    /// [`RunOptions::track_tbt_tail`]).
    pub fn tbt_tail_p95(&self) -> f64 {
        self.tbt_tail.as_ref().map(|t| t.p95()).unwrap_or(0.0)
    }

    /// Total GPUs on this node (prefill + decode pools).
    pub fn num_gpus(&self) -> usize {
        self.gpus.len()
    }

    /// This node's application-clock ladder (heterogeneous nodes may cap
    /// below the stock A100's 1410 MHz).
    pub fn ladder(&self) -> &FreqLadder {
        &self.gpus[0].ladder
    }

    /// This node's power envelope (heterogeneous nodes scale the A100
    /// curve).
    pub fn power_model(&self) -> &PowerModel {
        &self.gpus[0].power
    }

    /// Worst-case node draw with every GPU fully active at `mhz`, watts.
    /// The power arbiter sizes grants against this bound.
    pub fn node_active_w(&self, mhz: u32) -> f64 {
        self.num_gpus() as f64 * self.power_model().active_w(mhz)
    }

    /// Tokens emitted then rolled back by a node failure.
    pub fn wasted_tokens(&self) -> u64 {
        self.wasted_tokens
    }

    /// Cumulative node energy integrated up to `t` (power-arbiter
    /// measurement; `t` must be ≥ every GPU's last state change).
    pub fn energy_now_j(&mut self, t: f64) -> f64 {
        for g in self.gpus.iter_mut() {
            g.advance(t);
        }
        self.gpus.iter().map(|g| g.energy_j()).sum()
    }

    /// Current arbiter clock ceiling (`u32::MAX` = uncapped).
    pub fn clock_cap_mhz(&self) -> u32 {
        self.clock_cap_mhz
    }

    /// Push a full telemetry sample to the recorder (no-op for
    /// [`NoopRecorder`] engines — the sample is never even built).
    /// `granted_w` is the arbiter's current power grant, negative when no
    /// grant is in force (uncapped runs, engine-local clock edges). The
    /// cluster loop calls this at every arbitration epoch; the engine
    /// calls it itself at clock-change edges.
    pub fn record_obs_sample(&mut self, t: f64, granted_w: f64) {
        if !R::ENABLED {
            return;
        }
        let n_prefill_gpus =
            self.cfg.pools.prefill_workers * self.cfg.pools.gpus_per_prefill_worker;
        let clock_of = |g: &SimGpu| if g.is_off() { 0 } else { g.sm_clock() };
        let prefill_mhz = if n_prefill_gpus > 0 {
            clock_of(&self.gpus[0])
        } else {
            0
        };
        let decode_mhz = if self.gpus.len() > n_prefill_gpus {
            clock_of(&self.gpus[n_prefill_gpus])
        } else {
            0
        };
        let s = NodeSample {
            t,
            prefill_mhz,
            decode_mhz,
            power_w: self.gpus.iter().map(SimGpu::power_w).sum(),
            granted_w,
            queue_depth: self.prefill_backlog(),
            active_streams: self.arena.live,
            batch: self.decode_workers.iter().map(|w| w.streams.len()).sum(),
        };
        self.rec.sample(self.node_id, s);
    }

    /// Clamp this node's clock ceiling (power arbiter grant). Any GPU
    /// above the cap is pulled down immediately; when a later grant
    /// raises the cap, previously clamped GPUs return to their policy's
    /// last *requested* clock (tickless policies never re-request, so the
    /// engine restores for them). Future requests are clamped at the
    /// engine boundary. `cap_mhz` must be a ladder frequency.
    pub fn set_clock_cap(&mut self, t: f64, cap_mhz: u32) {
        debug_assert!(
            self.gpus[0].ladder.contains(cap_mhz),
            "arbiter cap {cap_mhz} MHz off-ladder"
        );
        self.clock_cap_mhz = cap_mhz;
        let eff = cap_mhz.min(self.degraded_cap_mhz);
        let before = if R::ENABLED {
            self.gpus[0].sm_clock()
        } else {
            0
        };
        for (g, gpu) in self.gpus.iter_mut().enumerate() {
            let want = self.requested_mhz[g].min(eff);
            if gpu.sm_clock() != want {
                gpu.set_app_clock(t, want);
            }
        }
        if R::ENABLED {
            let after = self.gpus[0].sm_clock();
            if after != before {
                self.rec.clock_change(self.node_id, t, 0, after);
                self.record_obs_sample(t, -1.0);
            }
        }
        self.policy.on_power_cap(cap_mhz);
    }

    /// Straggler onset (chaos `slow` events): every subsequent
    /// prefill/decode step runs `factor`× slower, and the node's clocks
    /// are pinned under `cap_mhz` (snapped down to the ladder grid;
    /// `u32::MAX` = no thermal cap). The degraded cap composes with the
    /// arbiter cap by min — the arbiter keeps granting watts, the node
    /// just cannot use clocks above its thermal ceiling.
    pub fn degrade(&mut self, t: f64, factor: f64, cap_mhz: u32) {
        debug_assert!(factor.is_finite() && factor >= 1.0, "bad slowdown {factor}");
        self.perf_slowdown = factor;
        self.degraded_cap_mhz = if cap_mhz == u32::MAX {
            u32::MAX
        } else {
            self.gpus[0].ladder.snap_down(cap_mhz as f64)
        };
        self.reclamp_clocks(t);
    }

    /// Straggler recovery (chaos `restore` events): lift the slowdown and
    /// the thermal cap; clocks return to the policy's last requested
    /// values under the arbiter cap alone.
    pub fn restore_degrade(&mut self, t: f64) {
        self.perf_slowdown = 1.0;
        self.degraded_cap_mhz = u32::MAX;
        self.reclamp_clocks(t);
    }

    /// Re-apply every GPU's requested clock under the current effective
    /// ceiling (arbiter cap ∧ thermal cap), recording actual edges.
    fn reclamp_clocks(&mut self, t: f64) {
        let eff = self.clock_cap_mhz.min(self.degraded_cap_mhz);
        let before = if R::ENABLED {
            self.gpus[0].sm_clock()
        } else {
            0
        };
        for (g, gpu) in self.gpus.iter_mut().enumerate() {
            let want = self.requested_mhz[g].min(eff);
            if gpu.sm_clock() != want {
                gpu.set_app_clock(t, want);
            }
        }
        if R::ENABLED {
            let after = self.gpus[0].sm_clock();
            if after != before {
                self.rec.clock_change(self.node_id, t, 0, after);
                self.record_obs_sample(t, -1.0);
            }
        }
    }

    /// Current straggler step-time multiplier (1.0 = healthy).
    pub fn perf_slowdown(&self) -> f64 {
        self.perf_slowdown
    }

    // -- chaos hooks (node loss / recovery) -----------------------------------

    /// Node failure at `t` (chaos layer, stepped mode only): power every
    /// GPU off, cancel all pending events, and drain every incomplete
    /// request — queued prefill jobs, in-flight prefills, batched and
    /// waiting decode streams — in a canonical deterministic order into
    /// `drained` for re-routing by the cluster loop (the caller reuses
    /// the buffer across faults, so chaos paths allocate nothing
    /// steady-state — §Perf). Tokens already emitted by drained streams
    /// are rolled back from `generated_tokens` (the retry re-generates
    /// them, keeping cluster-wide token conservation exact) and surface
    /// as [`Engine::wasted_tokens`]; the energy they cost stays on this
    /// node's meter. Telemetry goes cold: the TBT-tail and TPS windows
    /// reset so balancer and arbiter see a fresh node on recovery.
    pub fn fail_into(&mut self, t: f64, drained: &mut Vec<Request>) {
        debug_assert!(
            self.replay_total.is_none(),
            "fail() on a replay-mode engine"
        );
        // Queued prefill jobs, per queue in FIFO order.
        let node_id = self.node_id;
        for queue in self.prefill_queues.iter_mut() {
            while let Some(job) = queue.pop_front() {
                let req = self.requests[job.req_idx].clone();
                if R::ENABLED {
                    self.rec.abort(node_id, t, req.id, 0);
                }
                drained.push(req);
            }
        }
        // In-flight prefill jobs, worker order (their PrefillDone events
        // die with the queue below).
        for worker in self.prefill_workers.iter_mut() {
            if let Some((req_idx, _)) = worker.current.take() {
                let req = self.requests[req_idx].clone();
                if R::ENABLED {
                    self.rec.abort(node_id, t, req.id, 0);
                }
                drained.push(req);
            }
        }
        // Batched decode streams (worker order, batch order), then
        // waiters — collected as ids into the engine-owned scratch (the
        // `finished_scratch` pattern: no per-fault Vec).
        let mut ids = std::mem::take(&mut self.ids_scratch);
        debug_assert!(ids.is_empty());
        for w in self.decode_workers.iter_mut() {
            w.round_active = false;
            ids.append(&mut w.streams);
        }
        ids.extend(self.decode_wait.drain(..));
        for id in ids.drain(..) {
            self.abort_stream(t, id, drained);
        }
        self.ids_scratch = ids;
        // Salvage arrivals the node was handed but had not yet processed
        // (a same-timestamp fault can beat an injected arrival); all other
        // pending events — in-flight completions, ticks — die with the
        // node. The drain walks the calendar queue's bucket order
        // directly: no sorted intermediate Vec (§Perf).
        let requests = &self.requests;
        let rec = &mut self.rec;
        self.q.drain_each(|_, ev| {
            if let Ev::Arrive(req_idx) = ev {
                let req = requests[req_idx].clone();
                if R::ENABLED {
                    rec.abort(node_id, t, req.id, 0);
                }
                drained.push(req);
            }
        });
        // Undelivered migrations die with the node's KV cache: re-route
        // for a full re-prefill elsewhere. No token rollback — the
        // migrate-out path never counted one (the receiver would have).
        for m in self.migrations.drain(..) {
            if R::ENABLED {
                self.rec.abort(node_id, t, m.req.id, 0);
            }
            drained.push(m.req);
        }
        self.outstanding_prompt_tok = 0;
        if self.tbt_tail.is_some() {
            self.tbt_tail = Some(SlidingP95::new(TBT_TAIL_WINDOW));
        }
        self.global_tps = TpsWindow::new(0.2);
        // A power cycle resets the control plane to its config baseline
        // (runtime fault overlays cleared) and invalidates any in-flight
        // delayed clock write — it must not land on the recovered node.
        self.ctl.reset_to_config();
        for g in self.gpus.iter_mut() {
            g.power_off(t);
        }
    }

    /// [`Engine::fail_into`] with a freshly allocated buffer (unit-test
    /// convenience; the cluster loop reuses one buffer across faults).
    pub fn fail(&mut self, t: f64) -> Vec<Request> {
        let mut drained = Vec::new();
        self.fail_into(t, &mut drained);
        drained
    }

    /// Roll back one incomplete stream at a node failure: un-count its
    /// emitted tokens (the prefill's first token + decode tokens so far)
    /// and queue its request for re-routing. The slot (and its TBT
    /// buffer, cleared in place) returns to the arena's free list.
    fn abort_stream(&mut self, t: f64, id: StreamId, drained: &mut Vec<Request>) {
        let slot = self.arena.slot(id);
        let req = self.requests[self.arena.req_idx[slot]].clone();
        let emitted = (req.output_len - self.arena.remaining[slot]) as u64;
        self.generated_tokens -= emitted;
        self.wasted_tokens += emitted;
        if R::ENABLED {
            self.rec.abort(self.node_id, t, req.id, emitted);
        }
        drained.push(req);
        self.arena.release(id);
    }

    /// Node recovery at `t` (chaos layer): power the GPUs back on at the
    /// policy's initial clock (boost when the policy sets none), clear
    /// any stale arbiter cap, and re-arm the policy's periodic ticks from
    /// the rejoin instant. Queues are empty (drained at failure) and
    /// telemetry is cold; the cluster loop starts routing here again.
    pub fn recover(&mut self, t: f64) {
        let init = self.policy.initial_clock_mhz();
        self.clock_cap_mhz = u32::MAX;
        // A power cycle clears any straggler degradation with the caps.
        self.degraded_cap_mhz = u32::MAX;
        self.perf_slowdown = 1.0;
        for (g, gpu) in self.gpus.iter_mut().enumerate() {
            gpu.power_on(t);
            let mhz = init.unwrap_or(gpu.ladder.max_mhz);
            gpu.set_app_clock(t, mhz);
            self.requested_mhz[g] = gpu.sm_clock();
        }
        let specs = self.tick_specs.clone();
        for (kind, spec) in specs.iter().enumerate() {
            self.q.schedule(t + spec.interval_s, Ev::PolicyTick(kind));
        }
        if self.opts.record_tps_series {
            self.q.schedule(t + 0.2, Ev::SampleTick);
        }
    }

    // -- disaggregation hooks (P/D pools) -------------------------------------

    /// Mark this node as a disaggregated *prefill* node: finished
    /// prefills queue for cluster migration instead of joining the local
    /// decode pool. Cluster loop only, set before any event runs.
    pub fn enable_migrate_out(&mut self) {
        self.migrate_out = true;
    }

    /// Drain the streams whose prefill just finished into `out`
    /// (migrate-out nodes; a no-op otherwise). The cluster loop calls
    /// this right after every step of a prefill-pool node.
    pub fn take_migrations(&mut self, out: &mut Vec<MigratedStream>) {
        out.append(&mut self.migrations);
    }

    /// Charge one end of a KV transfer to this node's energy meter
    /// (both the sender and the receiver pay; see `cluster::disagg`).
    pub fn add_transfer_energy(&mut self, j: f64) {
        self.transfer_energy_j += j;
    }

    /// KV-transfer energy charged to this node so far, joules.
    pub fn transfer_energy_j(&self) -> f64 {
        self.transfer_energy_j
    }

    /// Adopt a migrated stream at `t` (decode node, stepped mode): the
    /// sender finished its prefill at `prefill_done_s` and the KV cache
    /// has just landed here. The first token is counted *here* — the
    /// sender skipped it — so an abort on this node rolls back exactly
    /// the tokens this node counted. TTFT stays anchored at the sender's
    /// prefill completion (the user saw the first token then), while the
    /// transfer gap surfaces in the first decode TBT: `last_token_t`
    /// starts at `prefill_done_s`, not at delivery.
    pub fn migrate_in(&mut self, t: f64, req: Request, prefill_done_s: f64) {
        debug_assert!(
            self.replay_total.is_none(),
            "migrate_in on a replay-mode engine"
        );
        debug_assert!(req.output_len > 1, "prefill-only requests never migrate");
        let req_idx = self.requests.len();
        self.requests.push(req.clone());
        self.generated_tokens += 1; // the sender's first token, owned here
        self.global_tps.record(t, 1);
        if R::ENABLED {
            self.rec.migrate_deliver(self.node_id, t, req.id);
        }
        let id = self.arena.alloc(
            req_idx,
            req.output_len - 1,
            req.prompt_len as f64 + 1.0,
            t,
            req.output_len as usize,
            prefill_done_s - req.arrival_s,
        );
        let slot = self.arena.slot(id);
        self.arena.last_token_t[slot] = prefill_done_s;
        self.admit_stream(t, id);
    }

    /// Prefill-side SLO pressure for the power arbiter (a disaggregated
    /// prefill node has no decode tail to weigh): estimated backlog
    /// seconds — outstanding prompt tokens at this node's max-clock
    /// prefill rate, split across its workers — over the short-prompt
    /// TTFT budget. 0.0 when idle; same scale as the decode pools'
    /// tail-over-target ratio.
    pub fn prefill_pressure(&self) -> f64 {
        if self.outstanding_prompt_tok == 0 {
            return 0.0;
        }
        let per_tok_s = self.perf.prefill_time(512, self.ladder().max_mhz) / 512.0;
        let backlog_s = self.outstanding_prompt_tok as f64 * per_tok_s
            / self.prefill_workers.len().max(1) as f64;
        backlog_s / self.ttft_target_sm
    }

    // -- control-plane fault hooks (`ctl*` chaos verbs) -----------------------

    /// `ctlnoise` verb: degrade this node's actuation path — writes gain
    /// `delay_s` of latency and are dropped / misstepped with the given
    /// probabilities — and arm sensor quantization.
    pub fn ctl_noise_on(&mut self, delay_s: f64, drop_prob: f64, misstep_prob: f64) {
        self.ctl.noise_on(delay_s, drop_prob, misstep_prob);
    }

    /// `ctlquiet` verb: actuation returns to the ideal instant path (any
    /// still-pending delayed write keeps its ticket and may yet land).
    pub fn ctl_noise_off(&mut self) {
        self.ctl.noise_off();
    }

    /// `ctlblackout` verb: telemetry goes dark — the cluster-facing
    /// sensed values freeze at their current readings and event-driven
    /// policy feedback (TBT, token, backlog callbacks) is suppressed
    /// until [`Engine::ctl_blackout_off`]. The physics (queues, rounds,
    /// energy) runs on untouched.
    pub fn ctl_blackout_on(&mut self) {
        let tail = self.tbt_tail_p95();
        let pressure = self.prefill_pressure();
        self.ctl.blackout_on(tail, pressure);
    }

    /// `ctlsense` verb: sensors come back; feedback flows again.
    pub fn ctl_blackout_off(&mut self) {
        self.ctl.blackout_off();
    }

    /// Is a telemetry blackout in force on this node right now?
    pub fn ctl_blackout(&self) -> bool {
        self.ctl.blackout()
    }

    /// Decode-tail P95 as the cluster control plane *senses* it: frozen
    /// during blackouts, quantized under noise, bit-identical to
    /// [`Engine::tbt_tail_p95`] otherwise. The power arbiter reads this.
    pub fn sensed_tbt_tail_p95(&self) -> f64 {
        self.ctl.sense_tail(self.tbt_tail_p95())
    }

    /// Prefill backlog pressure as sensed through the control plane
    /// (see [`Engine::sensed_tbt_tail_p95`]; raw value:
    /// [`Engine::prefill_pressure`]).
    pub fn sensed_prefill_pressure(&self) -> f64 {
        self.ctl.sense_pressure(self.prefill_pressure())
    }

    /// Route one arbiter power measurement (watts) through this node's
    /// sensing path: stuck at its first in-blackout reading during a
    /// blackout, gridded under noise, exact otherwise.
    pub fn ctl_sense_power(&mut self, raw_w: f64) -> f64 {
        self.ctl.sense_power(raw_w)
    }

    // -- helpers -------------------------------------------------------------

    /// Route one policy clock write through the control plane. With noise
    /// off this is exactly the pre-control-plane apply; under noise the
    /// write can be dropped, misstepped one rung, or deferred (the
    /// deferred apply lands via [`Ev::CtlApply`] unless superseded).
    fn set_worker_clock(&mut self, t: f64, first_gpu: usize, n: usize, mhz: u32) {
        let action = self
            .ctl
            .gate_write(t, first_gpu, mhz, &self.gpus[first_gpu].ladder);
        match action {
            WriteAction::Apply(mhz) => self.apply_worker_clock(t, first_gpu, n, mhz),
            WriteAction::Drop => {}
            WriteAction::Delay { mhz, apply_at, seq } => {
                self.q.schedule(
                    apply_at,
                    Ev::CtlApply {
                        first_gpu,
                        n,
                        mhz,
                        seq,
                    },
                );
            }
        }
    }

    /// Land a (gated) clock write on a worker's GPU span: record the
    /// request pre-clamp, apply under the arbiter ∧ thermal ceiling.
    fn apply_worker_clock(&mut self, t: f64, first_gpu: usize, n: usize, mhz: u32) {
        let clamped = mhz.min(self.clock_cap_mhz).min(self.degraded_cap_mhz);
        let before = if R::ENABLED {
            self.gpus[first_gpu].sm_clock()
        } else {
            0
        };
        for g in first_gpu..first_gpu + n {
            self.requested_mhz[g] = mhz;
            self.gpus[g].set_app_clock(t, clamped);
        }
        if R::ENABLED {
            // Record only actual edges (set_app_clock snaps to the
            // ladder, so the applied clock can equal the old one).
            let after = self.gpus[first_gpu].sm_clock();
            if after != before {
                self.rec.clock_change(self.node_id, t, first_gpu, after);
                self.record_obs_sample(t, -1.0);
            }
        }
    }

    fn prefill_clock(&self, worker: usize) -> u32 {
        self.gpus[self.prefill_workers[worker].gpus[0]].sm_clock()
    }

    fn set_prefill_worker_clock(&mut self, t: f64, worker: usize, mhz: u32) {
        let (g0, n) = (
            self.prefill_workers[worker].gpus[0],
            self.prefill_workers[worker].gpus.len(),
        );
        self.set_worker_clock(t, g0, n, mhz);
    }

    /// Deadline for a request's first token under the controller margin.
    fn deadline_of(&self, req_idx: usize) -> f64 {
        let r = &self.requests[req_idx];
        let slo = match r.route_class() {
            crate::workload::request::RouteClass::Long => self.ttft_target_long,
            _ => self.ttft_target_sm,
        };
        r.arrival_s + slo
    }

    /// Append `worker`'s queue view: the in-flight job heads the FIFO (its
    /// remaining work over-approximated by its full t_ref — conservative),
    /// then the backlog.
    fn fill_jobs(&self, worker: usize, out: &mut Vec<PrefillJobView>) {
        let queue = self.prefill_workers[worker].queue;
        if let Some((req_idx, _)) = self.prefill_workers[worker].current {
            out.push(PrefillJobView {
                prompt_len: self.requests[req_idx].prompt_len,
                deadline_s: self.deadline_of(req_idx),
            });
        }
        out.extend(self.prefill_queues[queue].iter().map(|j| PrefillJobView {
            prompt_len: self.requests[j.req_idx].prompt_len,
            deadline_s: self.deadline_of(j.req_idx),
        }));
    }

    /// One periodic policy callback: snapshot telemetry, collect the clock
    /// plan, apply it (prefill pool first, then decode — the order the
    /// pre-refactor governors used).
    fn policy_tick(&mut self, t: f64, kind: usize) {
        let spec = self.tick_specs[kind];
        let mut view = std::mem::take(&mut self.view_scratch);
        view.now = t;
        // Only build what this tick's spec declares (§Perf — the view
        // contract in `coordinator::policy`): a 50 Hz fine tick that
        // consumes neither pool view skips both refreshes entirely.
        // Undeclared parts are left stale and must not be read.
        if spec.prefill_view {
            view.prefill.resize_with(self.prefill_workers.len(), Default::default);
            for (w, pv) in view.prefill.iter_mut().enumerate() {
                pv.busy = self.prefill_workers[w].current.is_some();
                pv.jobs.clear();
                if spec.prefill_jobs {
                    self.fill_jobs(w, &mut pv.jobs);
                }
            }
        }
        view.decode.clear();
        if spec.decode_view {
            for w in &self.decode_workers {
                view.decode.push(DecodeWorkerView {
                    batch: w.streams.len(),
                    avg_ctx: self.arena.avg_ctx(&w.streams),
                });
            }
        }

        let mut plan = std::mem::take(&mut self.plan_scratch);
        plan.reset(self.prefill_workers.len(), self.decode_workers.len());
        self.policy.on_tick(kind, t, &view, &mut plan);
        // No clamping here: set_worker_clock records the *pre-clamp*
        // request (so a raised power cap can restore it) and applies the
        // cap itself.

        for (w, mhz) in plan.prefill_mhz.iter().enumerate() {
            if let Some(mhz) = mhz {
                self.set_prefill_worker_clock(t, w, *mhz);
            }
        }
        for (w, mhz) in plan.decode_mhz.iter().enumerate() {
            if let Some(mhz) = mhz {
                let gpu = self.decode_workers[w].gpu;
                self.set_worker_clock(t, gpu, 1, *mhz);
            }
        }
        self.view_scratch = view;
        self.plan_scratch = plan;
        if R::ENABLED {
            // Drain supervisor state transitions (fallback / probation /
            // reengage) into the flight recorder with their original
            // timestamps; empty for unsupervised policies.
            for (tt, what) in self.policy.ctl_transitions() {
                self.rec.ctl(self.node_id, tt, what);
            }
        }
    }

    // -- prefill -------------------------------------------------------------

    fn on_arrive(&mut self, t: f64, req_idx: usize) {
        if R::ENABLED {
            let r = &self.requests[req_idx];
            let (id, pl, ol) = (r.id, r.prompt_len, r.output_len);
            self.rec.arrive(self.node_id, t, id, pl, ol);
        }
        self.outstanding_prompt_tok += self.requests[req_idx].prompt_len as u64;
        let queue = self.router.queue_for(&self.requests[req_idx]);
        self.prefill_queues[queue].push_back(QueuedJob { req_idx });
        // Kick an idle worker serving (or allowed to steal from) this queue.
        let workers = self.router.candidate_workers(queue);
        if let Some(&w) = workers
            .iter()
            .find(|&&w| self.prefill_workers[w].current.is_none())
        {
            self.dispatch_prefill(t, w);
        } else if self.policy.wants_backlog_updates() {
            if self.ctl.blackout() {
                // Telemetry dark: the backlog update never reaches the
                // policy (the queue still grew — the physics is intact).
                self.ctl.note_suppressed();
                return;
            }
            // Queue grew: let the policy react immediately for busy
            // workers too (clock applies to subsequent jobs).
            for w in workers {
                let mut jobs = std::mem::take(&mut self.jobs_scratch);
                jobs.clear();
                self.fill_jobs(w, &mut jobs);
                let decision = self.policy.on_prefill_backlog(t, w, &jobs);
                self.jobs_scratch = jobs;
                if let Some(mhz) = decision {
                    self.set_prefill_worker_clock(t, w, mhz);
                }
            }
        }
    }

    fn dispatch_prefill(&mut self, t: f64, worker: usize) {
        let queue = self.prefill_workers[worker].queue;
        let job = self.prefill_queues[queue].pop_front().or_else(|| {
            // Own queue drained: steal if the router allows it.
            self.router
                .steal_queue_of_worker(worker)
                .and_then(|q| self.prefill_queues[q].pop_front())
        });
        let Some(job) = job else {
            // Nothing to do: park util at 0 (and clock, if the policy says).
            let (g0, n) = (
                self.prefill_workers[worker].gpus[0],
                self.prefill_workers[worker].gpus.len(),
            );
            for g in g0..g0 + n {
                self.gpus[g].set_util(t, 0.0);
            }
            if let Some(mhz) = self.policy.on_prefill_idle(t, worker) {
                self.set_worker_clock(t, g0, n, mhz);
            }
            return;
        };
        // Mark the job in flight *before* the clock decision so the
        // policy accounts for its work.
        self.prefill_workers[worker].seq += 1;
        let seq = self.prefill_workers[worker].seq;
        self.prefill_workers[worker].current = Some((job.req_idx, seq));
        // Refresh the clock decision at the dispatch boundary.
        let mut jobs = std::mem::take(&mut self.jobs_scratch);
        jobs.clear();
        if self.policy.wants_prefill_jobs() {
            self.fill_jobs(worker, &mut jobs);
        }
        let decision = self.policy.on_prefill_dispatch(t, worker, &jobs);
        self.jobs_scratch = jobs;
        if let Some(mhz) = decision {
            self.set_prefill_worker_clock(t, worker, mhz);
        }
        let mhz = self.prefill_clock(worker);
        let len = self.requests[job.req_idx].prompt_len;
        let dt = self.perf.prefill_time(len as usize, mhz)
            * self.rng.noise(self.cfg.sim_noise)
            * self.perf_slowdown;
        let (g0, n) = (
            self.prefill_workers[worker].gpus[0],
            self.prefill_workers[worker].gpus.len(),
        );
        for g in g0..g0 + n {
            self.gpus[g].set_util(t, 1.0);
        }
        if R::ENABLED {
            let id = self.requests[job.req_idx].id;
            self.rec.prefill_start(self.node_id, t, id, worker);
        }
        self.q.schedule(t + dt, Ev::PrefillDone { worker, seq });
    }

    fn on_prefill_done(&mut self, t: f64, worker: usize, seq: u64) {
        let Some((req_idx, cur_seq)) = self.prefill_workers[worker].current else {
            return;
        };
        if cur_seq != seq {
            return; // stale event
        }
        self.prefill_workers[worker].current = None;
        let req = self.requests[req_idx].clone();
        if R::ENABLED {
            self.rec.prefill_done(self.node_id, t, req.id);
        }
        self.outstanding_prompt_tok = self
            .outstanding_prompt_tok
            .saturating_sub(req.prompt_len as u64);
        if self.migrate_out && req.output_len > 1 {
            // Disaggregated prefill node: hand the stream to the cluster
            // loop for decode-pool migration. No token is counted here —
            // the receiving node counts the first token at
            // [`Engine::migrate_in`], so a later abort rolls back exactly
            // the tokens one node counted (§migration contract). Prefill-
            // only requests (output_len <= 1) never migrate: there is no
            // decode work to hand over, so they complete below as in the
            // colocated path.
            self.migrations.push(MigratedStream {
                req,
                prefill_done_s: t,
            });
            self.dispatch_prefill(t, worker);
            return;
        }
        let ttft = t - req.arrival_s;
        self.generated_tokens += 1; // prefill emits the first token
        self.global_tps.record(t, 1);
        if R::ENABLED {
            self.rec.first_token(self.node_id, t, req.id);
        }

        if req.output_len <= 1 {
            // Prefill-only request (microbenchmarks): complete now.
            let outcome = RequestOutcome {
                id: req.id,
                prompt_len: req.prompt_len,
                output_len: req.output_len,
                arrival_s: req.arrival_s,
                ttft_s: ttft,
                tbt_p95_s: 0.0,
                finish_s: t,
            };
            self.slo.record(outcome);
            self.completed += 1;
            if R::ENABLED {
                self.rec.finish(self.node_id, t, req.id, ttft, 0.0);
            }
        } else {
            // Claim an arena slot (§Perf): a recycled slot's TBT buffer
            // comes back cleared-in-place, so steady traffic runs
            // allocation-free once the arena matches peak concurrency.
            let id = self.arena.alloc(
                req_idx,
                req.output_len - 1,
                req.prompt_len as f64 + 1.0,
                t,
                req.output_len as usize,
                ttft,
            );
            self.admit_stream(t, id);
        }
        // Next job (or park).
        self.dispatch_prefill(t, worker);
    }

    // -- decode ----------------------------------------------------------------

    fn admit_stream(&mut self, t: f64, stream: StreamId) {
        // TTFT is recorded at completion together with TBT stats; it was
        // stashed in the stream's arena slot at prefill completion.
        let cap = self.cfg.pools.max_streams_per_decode_worker;
        // Argmin with the same first-minimum tie-breaking as the old
        // `filter(..).min_by_key(..)` scan, but short-circuiting on the
        // first empty worker — nothing beats a zero-stream batch, and at
        // light load (the common case) that is worker 0 (§Perf).
        let mut best: Option<usize> = None;
        let mut best_len = usize::MAX;
        for (w, dw) in self.decode_workers.iter().enumerate() {
            let len = dw.streams.len();
            if len < cap && len < best_len {
                best = Some(w);
                best_len = len;
                if len == 0 {
                    break;
                }
            }
        }
        match best {
            Some(w) => {
                self.decode_workers[w].streams.push(stream);
                if !self.decode_workers[w].round_active {
                    self.start_round(t, w);
                }
            }
            None => self.decode_wait.push_back(stream),
        }
    }

    fn start_round(&mut self, t: f64, worker: usize) {
        let w = &mut self.decode_workers[worker];
        if w.streams.is_empty() {
            w.round_active = false;
            let gpu = w.gpu;
            self.gpus[gpu].set_util(t, 0.0);
            return;
        }
        w.round_active = true;
        w.round_start = t;
        w.seq += 1;
        let seq = w.seq;
        let batch = w.streams.len();
        let avg_ctx = self.arena.avg_ctx(&w.streams);
        w.batch_samples += 1;
        w.batch_sum += batch as u64;
        let gpu = w.gpu;
        let mhz = self.gpus[gpu].sm_clock();
        let util = self.perf.decode_util(batch);
        self.gpus[gpu].set_util(t, util);
        let dt = self.perf.decode_step_time(batch, avg_ctx, mhz)
            * self.rng.noise(self.cfg.sim_noise)
            * self.perf_slowdown;
        self.q.schedule(t + dt, Ev::DecodeRound { worker, seq });
    }

    fn on_decode_round(&mut self, t: f64, worker: usize, seq: u64) {
        if self.decode_workers[worker].seq != seq || !self.decode_workers[worker].round_active {
            return; // stale
        }
        let round_start = self.decode_workers[worker].round_start;
        let mut emitted: u32 = 0;
        // Reused round scratch (§Perf): this used to allocate a fresh Vec
        // per decode round — the single hottest allocation site.
        let mut finished = std::mem::take(&mut self.finished_scratch);
        debug_assert!(finished.is_empty());
        let mut steady: u32 = 0;
        {
            // Single fused pass: emit tokens AND feed the policy's TBT
            // telemetry (split borrows keep this allocation-free). Steady
            // streams (last token at round start) all observe the same
            // round-duration TBT, fed as ONE weighted sample below — §Perf.
            // Stream state reads/writes go through the arena's SoA arrays
            // (ctx / remaining / last_token_t are each dense), so the
            // pass touches contiguous hot memory instead of chasing
            // per-stream structs.
            let w = &mut self.decode_workers[worker];
            let arena = &mut self.arena;
            let policy = &mut self.policy;
            let tail = &mut self.tbt_tail;
            let ctl = &mut self.ctl;
            let mut i = 0;
            while i < w.streams.len() {
                let slot = arena.slot(w.streams[i]);
                // Streams that joined mid-round wait for the next one.
                if arena.joined_t[slot] > round_start {
                    i += 1;
                    continue;
                }
                let tbt = t - arena.last_token_t[slot];
                arena.tbts[slot].push(tbt);
                if arena.last_token_t[slot] == round_start {
                    steady += 1;
                } else {
                    if ctl.blackout() {
                        ctl.note_suppressed();
                    } else {
                        policy.on_decode_tbt(worker, tbt); // fresh joiner
                    }
                    // The tail window is ground truth (it feeds SLO
                    // attribution and the post-blackout sensed value);
                    // only the policy's *view* of it goes dark.
                    if let Some(tt) = tail.as_mut() {
                        tt.record(tbt);
                    }
                }
                arena.last_token_t[slot] = t;
                arena.ctx[slot] += 1.0;
                arena.remaining[slot] -= 1;
                emitted += 1;
                if arena.remaining[slot] == 0 {
                    finished.push(w.streams.swap_remove(i));
                } else {
                    i += 1;
                }
            }
        }
        self.generated_tokens += emitted as u64;
        self.global_tps.record(t, emitted);
        if self.ctl.blackout() {
            // Both end-of-round feedback deliveries are lost; the
            // supervisor's staleness detector is what notices this.
            self.ctl.note_suppressed();
            self.ctl.note_suppressed();
        } else {
            self.policy.on_decode_tbt_weighted(worker, t - round_start, steady);
            self.policy.on_decode_tokens(worker, t, emitted);
        }
        if let Some(tt) = self.tbt_tail.as_mut() {
            tt.record_weighted(t - round_start, steady);
        }
        for s in finished.drain(..) {
            self.finish_stream(t, s);
        }
        self.finished_scratch = finished;
        // Backfill from the wait queue: promotion is O(promoted) — the
        // free-slot count is computed once, each promotion is one
        // pop_front + push, and no worker scan happens here (a finishing
        // worker adopts waiters directly).
        let cap = self.cfg.pools.max_streams_per_decode_worker;
        let free = cap.saturating_sub(self.decode_workers[worker].streams.len());
        for _ in 0..free {
            match self.decode_wait.pop_front() {
                Some(s) => self.decode_workers[worker].streams.push(s),
                None => break,
            }
        }
        self.start_round(t, worker);
    }

    fn finish_stream(&mut self, t: f64, id: StreamId) {
        let slot = self.arena.slot(id);
        let req = self.requests[self.arena.req_idx[slot]].clone();
        let ttft = self.arena.ttft_s[slot];
        // Quickselect, not clone+sort: bit-identical nearest-rank P95
        // (see `percentile_in_place`), and the slot's buffer is cleared
        // in place on release so its reordering is irrelevant.
        let tbt_p95 = percentile_in_place(&mut self.arena.tbts[slot], 0.95);
        self.slo.record(RequestOutcome {
            id: req.id,
            prompt_len: req.prompt_len,
            output_len: req.output_len,
            arrival_s: req.arrival_s,
            ttft_s: ttft,
            tbt_p95_s: tbt_p95,
            finish_s: t,
        });
        self.completed += 1;
        if R::ENABLED {
            self.rec.finish(self.node_id, t, req.id, ttft, tbt_p95);
        }
        self.arena.release(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::request::Request;

    fn tiny_trace(n: usize, qps: f64, prompt: u32, output: u32) -> Trace {
        Trace {
            name: "test".into(),
            duration_s: n as f64 / qps,
            requests: (0..n)
                .map(|i| Request {
                    id: i as u64,
                    arrival_s: i as f64 / qps,
                    prompt_len: prompt,
                    output_len: output,
                })
                .collect(),
        }
    }

    fn cfg(method: Method) -> Config {
        Config {
            method,
            sim_noise: 0.0,
            ..Config::default()
        }
    }

    #[test]
    fn all_requests_complete_exactly_once() {
        for method in [Method::DefaultNv, Method::GreenLlm, Method::Fixed(900)] {
            let trace = tiny_trace(50, 5.0, 400, 20);
            let r = run(&cfg(method), &trace, &RunOptions::default());
            assert_eq!(r.completed, 50, "{method:?}");
            assert_eq!(r.slo.completed, 50);
        }
    }

    #[test]
    fn new_policies_complete_all_requests() {
        for method in [Method::Agft, Method::PiTbt, Method::Throttle] {
            let trace = tiny_trace(50, 5.0, 400, 20);
            let r = run(&cfg(method), &trace, &RunOptions::default());
            assert_eq!(r.completed, 50, "{method:?}");
            assert_eq!(r.generated_tokens, 50 * 20, "{method:?}");
        }
    }

    #[test]
    fn token_accounting_exact() {
        let trace = tiny_trace(20, 4.0, 300, 16);
        let r = run(&cfg(Method::GreenLlm), &trace, &RunOptions::default());
        assert_eq!(r.generated_tokens, 20 * 16);
    }

    #[test]
    fn prefill_only_requests_complete_at_prefill() {
        let trace = tiny_trace(10, 2.0, 512, 1);
        let r = run(&cfg(Method::DefaultNv), &trace, &RunOptions::default());
        assert_eq!(r.completed, 10);
        assert_eq!(r.generated_tokens, 10);
        // TTFT ≈ prefill time at boost clocks (~60 ms), way under SLO.
        assert_eq!(r.slo.ttft_pass_rate(), 1.0);
    }

    #[test]
    fn deterministic_replay() {
        let trace = tiny_trace(40, 5.0, 400, 30);
        let a = run(&cfg(Method::GreenLlm), &trace, &RunOptions::default());
        let b = run(&cfg(Method::GreenLlm), &trace, &RunOptions::default());
        assert_eq!(a.total_energy_j, b.total_energy_j);
        assert_eq!(a.generated_tokens, b.generated_tokens);
        assert_eq!(a.slo.ttft_pass_rate(), b.slo.ttft_pass_rate());
    }

    #[test]
    fn pooled_tbt_buffers_keep_outcomes_bit_identical() {
        // Wildly varying output lengths force heavy recycling of the
        // per-stream TBT free list (a long stream's buffer is reused by
        // later short streams and vice versa). Every per-request outcome
        // — TTFT, nearest-rank TBT P95, finish time — must stay
        // bit-identical run to run; a dirty or mis-sized recycled buffer
        // would corrupt a later stream's percentile.
        let trace = Trace {
            name: "pool".into(),
            duration_s: 20.0,
            requests: (0..80)
                .map(|i| Request {
                    id: i as u64,
                    arrival_s: i as f64 * 0.25,
                    prompt_len: 200 + (i as u32 * 37) % 900,
                    output_len: 2 + (i as u32 * 53) % 120,
                })
                .collect(),
        };
        let opts = RunOptions {
            keep_outcomes: true,
            ..Default::default()
        };
        let a = run(&cfg(Method::GreenLlm), &trace, &opts);
        let b = run(&cfg(Method::GreenLlm), &trace, &opts);
        assert_eq!(a.slo.outcomes.len(), 80);
        assert_eq!(a.slo.outcomes.len(), b.slo.outcomes.len());
        for (x, y) in a.slo.outcomes.iter().zip(&b.slo.outcomes) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.ttft_s.to_bits(), y.ttft_s.to_bits());
            assert_eq!(x.tbt_p95_s.to_bits(), y.tbt_p95_s.to_bits());
            assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
        }
    }

    #[test]
    fn pooled_tbt_buffers_match_analytic_fresh_alloc_oracle() {
        // True fresh-alloc oracle (not self-comparison): under a Fixed
        // clock with zero noise, a solo stream's TBTs are analytically
        // reproducible outside the engine — each round lasts
        // decode_step_time(1, ctx, mhz), ctx growing by one per token,
        // timestamps accumulating in the same f64 order. Requests are
        // spaced far enough apart that streams never overlap, and output
        // lengths alternate long/short so every short stream reuses a
        // recycled long-stream buffer from the pool: a dirty or mis-sized
        // recycled buffer shifts that stream's nearest-rank P95 away from
        // the oracle computed over a fresh Vec with a plain clone+sort.
        let mhz = 900;
        let prompts: [u32; 6] = [400, 200, 800, 150, 600, 100];
        let outputs: [u32; 6] = [40, 3, 33, 2, 25, 5];
        let trace = Trace {
            name: "oracle".into(),
            duration_s: 60.0,
            requests: (0..6)
                .map(|i| Request {
                    id: i as u64,
                    arrival_s: i as f64 * 8.0,
                    prompt_len: prompts[i],
                    output_len: outputs[i],
                })
                .collect(),
        };
        let opts = RunOptions {
            keep_outcomes: true,
            ..Default::default()
        };
        let r = run(&cfg(Method::Fixed(mhz)), &trace, &opts);
        assert_eq!(r.slo.outcomes.len(), 6);
        let perf = PerfModel::new(ModelSpec::by_name("qwen3-14b").unwrap());
        for (i, o) in r.slo.outcomes.iter().enumerate() {
            assert_eq!(o.id, i as u64, "solo streams complete in order");
            // Replay the stream's clock analytically with fresh buffers.
            let mut t = trace.requests[i].arrival_s
                + perf.prefill_time(prompts[i] as usize, mhz);
            let mut ctx = prompts[i] as f64 + 1.0;
            let mut tbts: Vec<f64> = Vec::new();
            for _ in 0..outputs[i] - 1 {
                let t_next = t + perf.decode_step_time(1, ctx, mhz);
                tbts.push(t_next - t);
                t = t_next;
                ctx += 1.0;
            }
            // Clone+sort nearest-rank — the pre-quickselect oracle.
            tbts.sort_by(f64::total_cmp);
            let rank = ((0.95 * tbts.len() as f64).ceil() as usize).clamp(1, tbts.len());
            let want = tbts[rank - 1];
            assert_eq!(
                o.tbt_p95_s.to_bits(),
                want.to_bits(),
                "req {i}: engine p95 {} != analytic fresh-alloc oracle {}",
                o.tbt_p95_s,
                want
            );
        }
    }

    #[test]
    fn deterministic_replay_new_policies() {
        for method in [Method::Agft, Method::PiTbt] {
            let trace = tiny_trace(40, 5.0, 400, 30);
            let a = run(&cfg(method), &trace, &RunOptions::default());
            let b = run(&cfg(method), &trace, &RunOptions::default());
            assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
            assert_eq!(a.events_processed, b.events_processed);
        }
    }

    #[test]
    fn energy_positive_and_split_by_pool() {
        let trace = tiny_trace(20, 4.0, 400, 20);
        let r = run(&cfg(Method::DefaultNv), &trace, &RunOptions::default());
        assert!(r.prefill_energy_j > 0.0);
        assert!(r.decode_energy_j > 0.0);
        assert!((r.total_energy_j - r.prefill_energy_j - r.decode_energy_j).abs() < 1e-9);
        // Lower bound: every GPU at least idles for the duration.
        let idle_floor = 8.0 * 40.0 * r.sim_duration_s;
        assert!(r.total_energy_j > idle_floor);
    }

    #[test]
    fn greenllm_saves_energy_at_light_load() {
        let trace = tiny_trace(60, 2.0, 400, 60);
        let nv = run(&cfg(Method::DefaultNv), &trace, &RunOptions::default());
        let green = run(&cfg(Method::GreenLlm), &trace, &RunOptions::default());
        assert!(
            green.total_energy_j < 0.95 * nv.total_energy_j,
            "green={} nv={}",
            green.total_energy_j,
            nv.total_energy_j
        );
        // ... without tanking SLOs.
        assert!(green.slo.ttft_pass_rate() > 0.9);
        assert!(green.slo.tbt_pass_rate() > 0.9);
    }

    #[test]
    fn pi_controller_saves_energy_at_light_load() {
        let trace = tiny_trace(60, 2.0, 400, 60);
        let nv = run(&cfg(Method::DefaultNv), &trace, &RunOptions::default());
        let pi = run(&cfg(Method::PiTbt), &trace, &RunOptions::default());
        assert!(
            pi.total_energy_j < nv.total_energy_j,
            "pi={} nv={}",
            pi.total_energy_j,
            nv.total_energy_j
        );
    }

    #[test]
    fn slo_pass_rates_high_at_moderate_load() {
        let trace = tiny_trace(100, 5.0, 400, 40);
        let r = run(&cfg(Method::GreenLlm), &trace, &RunOptions::default());
        assert!(r.slo.ttft_pass_rate() > 0.95, "{}", r.slo.ttft_pass_rate());
        assert!(r.slo.tbt_pass_rate() > 0.9, "{}", r.slo.tbt_pass_rate());
    }

    #[test]
    fn freq_trace_recorded_when_requested() {
        let trace = tiny_trace(30, 5.0, 400, 30);
        let opts = RunOptions {
            record_freq_trace: true,
            record_tps_series: true,
            ..Default::default()
        };
        let r = run(&cfg(Method::GreenLlm), &trace, &opts);
        assert!(!r.decode_freq_trace.is_empty());
        assert!(!r.decode_tps_series.is_empty());
    }

    #[test]
    fn decode_batch_occupancy_reported() {
        let trace = tiny_trace(40, 8.0, 300, 50);
        let r = run(&cfg(Method::DefaultNv), &trace, &RunOptions::default());
        assert!(r.mean_decode_batch >= 1.0);
    }

    #[test]
    fn tbt_tail_tracked_only_on_request() {
        let trace = tiny_trace(30, 5.0, 300, 30);
        let cfg = cfg(Method::DefaultNv);
        // Plain options: tail stays 0 (not tracked).
        let plain_opts = RunOptions::default();
        let mut e = Engine::new(&cfg, &plain_opts, "t".into(), trace.duration_s);
        e.load_trace(&trace.requests);
        e.begin();
        while e.completed() < 30 {
            assert!(e.step());
        }
        assert_eq!(e.tbt_tail_p95(), 0.0);
        // Tracked options: a positive tail emerges.
        let opts = RunOptions {
            track_tbt_tail: true,
            ..Default::default()
        };
        let mut e = Engine::new(&cfg, &opts, "t".into(), trace.duration_s);
        e.load_trace(&trace.requests);
        e.begin();
        while e.completed() < 30 {
            assert!(e.step());
        }
        assert!(e.tbt_tail_p95() > 0.0);
    }

    #[test]
    fn stepped_mode_matches_replay_bit_exactly() {
        let trace = tiny_trace(40, 5.0, 400, 24);
        let cfg = cfg(Method::GreenLlm);
        let replay = run(&cfg, &trace, &RunOptions::default());
        // Drive the identical engine through the stepped interface, with
        // arrivals injected online one at a time.
        let opts = RunOptions::default();
        let mut e = Engine::new(&cfg, &opts, trace.name.clone(), trace.duration_s);
        e.begin();
        let mut next = 0;
        while e.completed() < trace.requests.len() as u64 {
            let arrival = trace.requests.get(next).map(|r| r.arrival_s);
            let take_arrival = match (arrival, e.peek_time()) {
                (Some(ta), Some(tn)) => ta <= tn,
                (Some(_), None) => true,
                (None, _) => false,
            };
            if take_arrival {
                e.inject(arrival.unwrap(), trace.requests[next].clone());
                next += 1;
            } else if !e.step() {
                break;
            }
        }
        let stepped = e.finalize(trace.duration_s);
        assert_eq!(replay.total_energy_j.to_bits(), stepped.total_energy_j.to_bits());
        assert_eq!(replay.generated_tokens, stepped.generated_tokens);
        assert_eq!(replay.completed, stepped.completed);
    }

    #[test]
    fn fail_drains_incomplete_work_and_conserves_after_retry() {
        // Drive a stepped engine partway, fail it, then hand the drained
        // requests back to the same (recovered) engine: every request
        // must still complete exactly once with exact token totals.
        let trace = tiny_trace(30, 10.0, 400, 20);
        let cfg = cfg(Method::GreenLlm);
        let opts = RunOptions::default();
        let mut e = Engine::new(&cfg, &opts, "chaos".into(), trace.duration_s);
        e.begin();
        for r in &trace.requests {
            e.inject(r.arrival_s, r.clone());
        }
        // Step until roughly half the requests completed.
        while e.completed() < 15 {
            assert!(e.step());
        }
        let t_fail = e.now();
        let energy_at_fail = e.energy_now_j(t_fail);
        let done_before = e.completed();
        let drained = e.fail(t_fail);
        assert!(!drained.is_empty(), "mid-run failure must drain work");
        assert_eq!(
            done_before as usize + drained.len(),
            trace.requests.len(),
            "drained + completed must cover every injected request"
        );
        // Dark window: no events pending, no energy accrues.
        assert_eq!(e.peek_time(), None);
        assert_eq!(e.energy_now_j(t_fail + 5.0), energy_at_fail);
        // Recover and retry the drained requests on the same node.
        let t_up = t_fail + 5.0;
        e.recover(t_up);
        for r in drained {
            e.inject(t_up, r);
        }
        while e.completed() < trace.requests.len() as u64 {
            assert!(e.step(), "engine stalled after recovery");
        }
        let r = e.finalize(trace.duration_s);
        assert_eq!(r.completed, 30);
        // Useful tokens are conserved exactly; the rolled-back partial
        // streams show up as waste instead.
        assert_eq!(r.generated_tokens, 30 * 20);
        if done_before < 30 {
            // At least the in-flight streams at the failure instant were
            // partially decoded.
            assert!(r.wasted_tokens > 0 || r.generated_tokens == 30 * 20);
        }
    }

    #[test]
    fn raising_the_cap_restores_requested_clocks() {
        // Fixed policies never re-request a clock, so the engine itself
        // must restore them when the arbiter's grant goes back up.
        let cfg = cfg(Method::Fixed(1200));
        let opts = RunOptions::default();
        let mut e = Engine::new(&cfg, &opts, "cap-cycle".into(), 10.0);
        e.begin();
        e.set_clock_cap(1.0, 900);
        assert!(e.gpus.iter().all(|g| g.sm_clock() == 900));
        e.set_clock_cap(2.0, 1410);
        assert!(
            e.gpus.iter().all(|g| g.sm_clock() == 1200),
            "clamped GPUs must return to the policy's requested clock"
        );
    }

    #[test]
    fn supervisor_fallback_stays_under_straggler_cap() {
        // Precedence: caps always win. A telemetry blackout on a busy
        // node starves the supervisor's token feed, so it trips and pins
        // its fallback clock (ladder max) — but the straggler thermal cap
        // at 600 MHz must clamp that pin like any other policy request.
        let mut c = cfg(Method::GreenLlm);
        c.ctl.supervisor = true;
        let opts = RunOptions::default();
        let mut e: Engine = Engine::new(&c, &opts, "prec".into(), 60.0);
        e.begin();
        for i in 0..60u64 {
            e.inject(
                i as f64 * 0.1,
                Request {
                    id: i,
                    arrival_s: i as f64 * 0.1,
                    prompt_len: 300,
                    output_len: 400,
                },
            );
        }
        e.degrade(0.0, 1.0, 600);
        e.ctl_blackout_on();
        while e.peek_time().map_or(false, |tt| tt < 30.0) {
            assert!(e.step());
            for g in &e.gpus {
                assert!(
                    g.sm_clock() <= 600,
                    "thermal cap violated at t={}: {} MHz",
                    e.now(),
                    g.sm_clock()
                );
            }
        }
        assert!(e.ctl_blackout());
        let r = e.finalize(30.0);
        assert!(
            r.supervisor_fallbacks >= 1,
            "blackout on a busy pool must trip the supervisor"
        );
        assert!(
            r.ctl_suppressed_samples > 0,
            "blackout must have suppressed policy feedback"
        );
    }

    #[test]
    fn control_plane_defaults_keep_replay_bit_exact() {
        // An armed-but-trivial control section (supervisor off, noise
        // off, parameters set) must not perturb a replay by one bit.
        let trace = tiny_trace(40, 5.0, 400, 30);
        let base = run(&cfg(Method::GreenLlm), &trace, &RunOptions::default());
        let mut c = cfg(Method::GreenLlm);
        c.ctl.delay_s = 0.5;
        c.ctl.drop_prob = 0.9;
        c.ctl.misstep_prob = 0.9;
        c.ctl.quantize = 50.0;
        let armed = run(&c, &trace, &RunOptions::default());
        assert_eq!(base.total_energy_j.to_bits(), armed.total_energy_j.to_bits());
        assert_eq!(base.events_processed, armed.events_processed);
        assert_eq!(
            armed.ctl_dropped_writes + armed.ctl_delayed_writes + armed.ctl_missteps,
            0
        );
    }

    #[test]
    fn ctl_noise_drops_and_delays_policy_writes() {
        // With heavy actuation noise the control plane visibly interferes
        // with the policy's writes, and the run still completes with
        // exact token accounting (drop/delay only moves clocks, never
        // tokens).
        let trace = tiny_trace(30, 5.0, 400, 20);
        let c = cfg(Method::GreenLlm);
        let opts = RunOptions::default();
        let mut e: Engine = Engine::new(&c, &opts, "noisy".into(), trace.duration_s);
        e.begin();
        e.ctl_noise_on(0.05, 0.3, 0.3);
        for r in &trace.requests {
            e.inject(r.arrival_s, r.clone());
        }
        while e.completed() < 30 {
            assert!(e.step());
        }
        let r = e.finalize(trace.duration_s);
        assert_eq!(r.completed, 30);
        assert_eq!(r.generated_tokens, 30 * 20);
        assert!(r.ctl_dropped_writes > 0, "no writes dropped");
        assert!(r.ctl_delayed_writes > 0, "no writes delayed");
    }

    #[test]
    fn clock_cap_clamps_all_requests() {
        let trace = tiny_trace(30, 5.0, 400, 20);
        let cfg = cfg(Method::DefaultNv);
        let opts = RunOptions::default();
        let mut e = Engine::new(&cfg, &opts, "capped".into(), trace.duration_s);
        e.begin();
        e.set_clock_cap(0.0, 600);
        for r in &trace.requests {
            e.inject(r.arrival_s, r.clone());
        }
        while e.completed() < 30 {
            assert!(e.step());
        }
        let r = e.finalize(trace.duration_s);
        assert_eq!(r.completed, 30);
        // Capped defaultNV burns less energy than uncapped boost clocks.
        let uncapped = run(&cfg, &trace, &RunOptions::default());
        assert!(r.total_energy_j < uncapped.total_energy_j);
    }
}

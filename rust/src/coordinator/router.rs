//! Adaptive prompt routing (§3.1): length-based queue selection.
//!
//! With routing enabled, short/medium prompts (< 1024 tokens) go to the
//! short-context queue and long prompts to the long-context queue, so a
//! rare long prefill can never head-of-line-block the common short ones.
//! Without routing (the defaultNV baseline) everything shares one mixed
//! queue and any idle prefill worker serves it.

use crate::workload::request::{Request, RouteClass};

/// Queue index constants.
pub const Q_SHORT_MEDIUM: usize = 0;
/// Long-prompt queue index.
pub const Q_LONG: usize = 1;

#[derive(Debug, Clone)]
/// Length-based prefill router (one mixed queue when disabled).
pub struct Router {
    /// Routing enabled? (defaultNV baselines run one mixed queue).
    pub routing: bool,
    /// Prefill worker count being routed across.
    pub prefill_workers: usize,
}

impl Router {
    /// A router over `prefill_workers` workers.
    pub fn new(routing: bool, prefill_workers: usize) -> Self {
        assert!(prefill_workers >= 1);
        Router {
            routing,
            prefill_workers,
        }
    }

    /// Number of prefill queues (2 with routing, 1 mixed without).
    pub fn num_queues(&self) -> usize {
        if self.routing && self.prefill_workers >= 2 {
            2
        } else {
            1
        }
    }

    /// Queue a request is routed to.
    pub fn queue_for(&self, req: &Request) -> usize {
        if self.num_queues() == 1 {
            return Q_SHORT_MEDIUM;
        }
        match req.route_class() {
            RouteClass::ShortMedium => Q_SHORT_MEDIUM,
            RouteClass::Long => Q_LONG,
        }
    }

    /// Queue served by a given prefill worker. With routing, the *last*
    /// worker is the long-context worker (§3.1: dedicated heavy track) and
    /// all others serve the short queue; without routing all workers share
    /// the mixed queue.
    pub fn queue_of_worker(&self, worker: usize) -> usize {
        debug_assert!(worker < self.prefill_workers);
        if self.num_queues() == 1 {
            return Q_SHORT_MEDIUM;
        }
        if worker == self.prefill_workers - 1 {
            Q_LONG
        } else {
            Q_SHORT_MEDIUM
        }
    }

    /// Workers serving a given queue (used when work arrives).
    pub fn workers_of_queue(&self, queue: usize) -> Vec<usize> {
        (0..self.prefill_workers)
            .filter(|&w| self.queue_of_worker(w) == queue)
            .collect()
    }

    /// Work stealing: a worker whose own queue is empty may take the head
    /// of the other queue. Stealing only-when-idle keeps §3.1's HoL
    /// protection in expectation: the dedicated short worker still serves
    /// shorts first, and a stolen long job can delay at most the shorts
    /// arriving during its execution (rare, bounded) — matching the
    /// paper's small PrefillSplit TTFT dip on long-heavy Azure code
    /// slices, while avoiding a stranded half-pool when one class
    /// dominates.
    pub fn steal_queue_of_worker(&self, worker: usize) -> Option<usize> {
        if self.num_queues() != 2 {
            return None;
        }
        match self.queue_of_worker(worker) {
            Q_LONG => Some(Q_SHORT_MEDIUM),
            _ => Some(Q_LONG),
        }
    }

    /// Candidate workers for newly arrived work on `queue`: its dedicated
    /// workers plus any worker allowed to steal from it.
    pub fn candidate_workers(&self, queue: usize) -> Vec<usize> {
        (0..self.prefill_workers)
            .filter(|&w| {
                self.queue_of_worker(w) == queue || self.steal_queue_of_worker(w) == Some(queue)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(len: u32) -> Request {
        Request {
            id: 0,
            arrival_s: 0.0,
            prompt_len: len,
            output_len: 1,
        }
    }

    #[test]
    fn mixed_queue_without_routing() {
        let r = Router::new(false, 2);
        assert_eq!(r.num_queues(), 1);
        assert_eq!(r.queue_for(&req(5000)), Q_SHORT_MEDIUM);
        assert_eq!(r.queue_of_worker(0), Q_SHORT_MEDIUM);
        assert_eq!(r.queue_of_worker(1), Q_SHORT_MEDIUM);
        assert_eq!(r.workers_of_queue(Q_SHORT_MEDIUM), vec![0, 1]);
    }

    #[test]
    fn split_queues_with_routing() {
        let r = Router::new(true, 2);
        assert_eq!(r.num_queues(), 2);
        assert_eq!(r.queue_for(&req(100)), Q_SHORT_MEDIUM);
        assert_eq!(r.queue_for(&req(1023)), Q_SHORT_MEDIUM);
        assert_eq!(r.queue_for(&req(1024)), Q_LONG);
        assert_eq!(r.queue_of_worker(0), Q_SHORT_MEDIUM);
        assert_eq!(r.queue_of_worker(1), Q_LONG);
    }

    #[test]
    fn routing_with_single_worker_degrades_to_mixed() {
        let r = Router::new(true, 1);
        assert_eq!(r.num_queues(), 1);
        assert_eq!(r.queue_for(&req(4096)), Q_SHORT_MEDIUM);
    }

    #[test]
    fn extra_workers_join_short_queue() {
        let r = Router::new(true, 3);
        assert_eq!(r.workers_of_queue(Q_SHORT_MEDIUM), vec![0, 1]);
        assert_eq!(r.workers_of_queue(Q_LONG), vec![2]);
    }

    #[test]
    fn symmetric_stealing_when_idle() {
        let r = Router::new(true, 2);
        assert_eq!(r.steal_queue_of_worker(0), Some(Q_LONG));
        assert_eq!(r.steal_queue_of_worker(1), Some(Q_SHORT_MEDIUM));
        // Arrivals of either class may wake either worker.
        assert_eq!(r.candidate_workers(Q_SHORT_MEDIUM), vec![0, 1]);
        assert_eq!(r.candidate_workers(Q_LONG), vec![0, 1]);
    }

    #[test]
    fn no_stealing_without_routing() {
        let r = Router::new(false, 2);
        assert_eq!(r.steal_queue_of_worker(0), None);
        assert_eq!(r.steal_queue_of_worker(1), None);
    }

    #[test]
    fn every_worker_serves_exactly_one_queue() {
        for routing in [false, true] {
            for n in 1..5 {
                let r = Router::new(routing, n);
                let mut covered = vec![];
                for q in 0..r.num_queues() {
                    covered.extend(r.workers_of_queue(q));
                }
                covered.sort();
                assert_eq!(covered, (0..n).collect::<Vec<_>>());
            }
        }
    }
}

//! Offline stand-in for the `xla` crate (PJRT C API bindings).
//!
//! The container image used for CI has no crates.io access and no PJRT
//! plugin, so the runtime engine compiles against this API-compatible stub
//! instead. Every entry point that would reach the real PJRT runtime
//! returns [`XlaError`]; the pure-Rust surface (`Literal` packing) works,
//! which keeps the engine's shape/ABI logic compilable and testable.
//!
//! To run against real PJRT, vendor the actual `xla` crate and replace the
//! `use crate::runtime::xla_stub as xla;` alias in `runtime::engine` with
//! the extern crate — the engine code itself needs no changes.

use std::path::Path;

/// Error type mirroring `xla::Error` closely enough for `{e:?}` formatting.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT backend not available in this build (offline xla stub)"
    ))
}

/// Host literal: flat data + shape. Only the packing/reshaping surface the
/// engine uses on the host side is implemented.
#[derive(Debug, Clone)]
pub struct Literal {
    data_f32: Vec<f32>,
    data_i32: Vec<i32>,
    dims: Vec<i64>,
}

impl Literal {
    fn from_parts(data_f32: Vec<f32>, data_i32: Vec<i32>, dims: Vec<i64>) -> Literal {
        Literal {
            data_f32,
            data_i32,
            dims,
        }
    }

    /// Rank-1 literal from a slice (f32 or i32 via the `LiteralElem` impls).
    pub fn vec1<T: LiteralElem>(v: &[T]) -> Literal {
        T::vec1(v)
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let n: i64 = dims.iter().product();
        let have = self.data_f32.len().max(self.data_i32.len()) as i64;
        if n != have {
            return Err(XlaError(format!("reshape {dims:?}: have {have} elements")));
        }
        Ok(Literal::from_parts(
            self.data_f32.clone(),
            self.data_i32.clone(),
            dims.to_vec(),
        ))
    }

    /// Tensor dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Decompose a tuple literal — tuples only exist device-side, so the
    /// stub can never produce one.
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable("to_tuple"))
    }

    /// Copy out typed host data.
    pub fn to_vec<T: LiteralElem>(&self) -> Result<Vec<T>, XlaError> {
        T::to_vec(self)
    }
}

impl From<i32> for Literal {
    fn from(v: i32) -> Literal {
        Literal::from_parts(Vec::new(), vec![v], Vec::new())
    }
}

/// Element types a [`Literal`] can carry in the stub.
pub trait LiteralElem: Sized {
    /// Build a rank-1 literal from a slice.
    fn vec1(v: &[Self]) -> Literal;
    /// Extract the literal's data as a vector of this element type.
    fn to_vec(lit: &Literal) -> Result<Vec<Self>, XlaError>;
}

impl LiteralElem for f32 {
    fn vec1(v: &[f32]) -> Literal {
        Literal::from_parts(v.to_vec(), Vec::new(), vec![v.len() as i64])
    }
    fn to_vec(lit: &Literal) -> Result<Vec<f32>, XlaError> {
        Ok(lit.data_f32.clone())
    }
}

impl LiteralElem for i32 {
    fn vec1(v: &[i32]) -> Literal {
        Literal::from_parts(Vec::new(), v.to_vec(), vec![v.len() as i64])
    }
    fn to_vec(lit: &Literal) -> Result<Vec<i32>, XlaError> {
        Ok(lit.data_i32.clone())
    }
}

/// Parsed HLO module handle (never constructible offline).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO text file (always unavailable offline).
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto, XlaError> {
        Err(unavailable(&format!("parse HLO {path:?}")))
    }
}

/// Computation wrapper.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle (construction always fails offline).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Build a CPU client (always unavailable offline).
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Platform name of the stub.
    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    /// Compile a computation (always unavailable offline).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("compile"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with the given arguments (always unavailable offline).
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("execute"))
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Copy the buffer host-side (always unavailable offline).
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_pack_and_reshape() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(l.reshape(&[4, 4]).is_err());
    }

    #[test]
    fn f32_roundtrip() {
        let l = Literal::vec1(&[1.5f32, -2.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.5, -2.0]);
    }

    #[test]
    fn device_paths_fail_offline() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file(Path::new("x.hlo.txt")).is_err());
        let lit = Literal::from(3);
        assert!(lit.to_tuple().is_err());
    }
}

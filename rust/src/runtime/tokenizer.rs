//! Byte-level tokenizer for the TinyLM serving path.
//!
//! Vocabulary layout (matches TinyLM's vocab=512 default): ids 0–255 are
//! raw bytes, 256 = BOS, 257 = EOS, the rest unused. Lossless on arbitrary
//! UTF-8, zero external files — exactly enough to prove the tokenize →
//! route → serve path end-to-end.

/// Beginning-of-sequence token id.
pub const BOS: i32 = 256;
/// End-of-sequence token id.
pub const EOS: i32 = 257;

#[derive(Debug, Clone)]
/// Lossless byte-level tokenizer (ids 0–255 = raw bytes).
pub struct ByteTokenizer {
    /// Vocabulary size (≥ 258).
    pub vocab: usize,
}

impl ByteTokenizer {
    /// A tokenizer for a `vocab`-sized model.
    pub fn new(vocab: usize) -> Self {
        assert!(vocab >= 258, "byte tokenizer needs vocab >= 258");
        ByteTokenizer { vocab }
    }

    /// Encode text → BOS + bytes.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut ids = Vec::with_capacity(text.len() + 1);
        ids.push(BOS);
        ids.extend(text.bytes().map(|b| b as i32));
        ids
    }

    /// Decode ids → text (specials skipped, invalid UTF-8 lossy-replaced).
    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new(512);
        let ids = t.encode("hello, GreenLLM");
        assert_eq!(ids[0], BOS);
        assert_eq!(t.decode(&ids), "hello, GreenLLM");
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer::new(512);
        let s = "énergie ⚡ 省エネ";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn specials_skipped_on_decode() {
        let t = ByteTokenizer::new(512);
        assert_eq!(t.decode(&[BOS, 104, 105, EOS]), "hi");
    }

    #[test]
    fn all_ids_in_vocab() {
        let t = ByteTokenizer::new(512);
        for id in t.encode("any\u{00ff}text") {
            assert!((0..512).contains(&id));
        }
    }

    #[test]
    #[should_panic]
    fn tiny_vocab_rejected() {
        ByteTokenizer::new(100);
    }
}

//! The PJRT artifact engine: compile-once, execute-many TinyLM.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): loads the HLO-text
//! artifacts (`HloModuleProto::from_text_file` — text, not serialized
//! proto; see aot.py), compiles one executable per prefill shape bucket
//! plus the decode step, and feeds parameters positionally per the
//! manifest ABI.

use crate::runtime::manifest::Manifest;
// Offline builds compile against the API-compatible stub; swap this alias
// for the real `xla` crate to run on actual PJRT (see xla_stub docs).
use crate::runtime::xla_stub as xla;
use crate::util::error::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Logits + KV state returned by prefill / decode steps. KV stays as
/// opaque `xla::Literal`s threaded back into the next decode call.
pub struct StepOutput {
    /// Row-major logits; prefill: [B, S, V] flattened, decode: [B, V].
    pub logits: Vec<f32>,
    /// Key-cache literal threaded into the next decode call.
    pub k_cache: xla::Literal,
    /// Value-cache literal threaded into the next decode call.
    pub v_cache: xla::Literal,
}

/// PJRT-backed TinyLM engine: loads exported HLO artifacts and serves
/// prefill/decode steps (compiles against the offline stub by default).
pub struct TinyLmEngine {
    /// The loaded artifact manifest.
    pub manifest: Manifest,
    client: xla::PjRtClient,
    params: Vec<xla::Literal>,
    prefill_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode_exe: xla::PjRtLoadedExecutable,
    /// Executions since load (telemetry).
    pub prefill_calls: std::cell::Cell<u64>,
    /// Decode executions since load (telemetry).
    pub decode_calls: std::cell::Cell<u64>,
}

impl TinyLmEngine {
    /// Load artifacts from a directory (default `artifacts/`).
    pub fn load(dir: &Path) -> Result<TinyLmEngine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;

        // Parameters: one literal per tensor, ABI order.
        let flat = manifest.load_params_f32()?;
        let mut params = Vec::new();
        let mut off = 0usize;
        for spec in manifest.param_specs() {
            let n = spec.numel();
            let lit = xla::Literal::vec1(&flat[off..off + n]);
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            params.push(
                lit.reshape(&dims)
                    .map_err(|e| anyhow!("reshape {}: {e:?}", spec.name))?,
            );
            off += n;
        }
        debug_assert_eq!(off, flat.len());

        let compile = |path: &Path| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {path:?}: {e:?}"))
        };

        let mut prefill_exes = BTreeMap::new();
        for (bucket, path) in &manifest.prefill_files {
            prefill_exes.insert(*bucket, compile(path).context("prefill executable")?);
        }
        let decode_exe = compile(&manifest.decode_file).context("decode executable")?;

        Ok(TinyLmEngine {
            manifest,
            client,
            params,
            prefill_exes,
            decode_exe,
            prefill_calls: std::cell::Cell::new(0),
            decode_calls: std::cell::Cell::new(0),
        })
    }

    /// PJRT platform name of the backing client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Pad a batch of token rows up to (manifest.batch, bucket); rows
    /// beyond the real batch repeat row 0 (results discarded).
    fn pack_tokens(&self, rows: &[Vec<i32>], bucket: usize) -> Result<xla::Literal> {
        let b = self.manifest.batch;
        if rows.is_empty() || rows.len() > b {
            return Err(anyhow!("batch must be 1..={b}, got {}", rows.len()));
        }
        let mut flat = vec![0i32; b * bucket];
        for (r, row) in rows.iter().enumerate() {
            if row.len() > bucket {
                return Err(anyhow!("row {r} length {} exceeds bucket {bucket}", row.len()));
            }
            // Left-pad? No: right-pad with the last token (attention is
            // causal, the padded tail never influences earlier positions).
            for (c, &tok) in row.iter().enumerate() {
                flat[r * bucket + c] = tok;
            }
            let last = *row.last().unwrap_or(&0);
            for c in row.len()..bucket {
                flat[r * bucket + c] = last;
            }
        }
        for r in rows.len()..b {
            for c in 0..bucket {
                flat[r * bucket + c] = flat[c];
            }
        }
        xla::Literal::vec1(&flat)
            .reshape(&[b as i64, bucket as i64])
            .map_err(|e| anyhow!("tokens reshape: {e:?}"))
    }

    /// Run prefill for up to `manifest.batch` prompts (each ≤ bucket).
    /// Returns logits [B, bucket, V] plus the KV caches.
    pub fn prefill(&self, rows: &[Vec<i32>], bucket: usize) -> Result<StepOutput> {
        let exe = self
            .prefill_exes
            .get(&bucket)
            .ok_or_else(|| anyhow!("no prefill executable for bucket {bucket}"))?;
        let tokens = self.pack_tokens(rows, bucket)?;
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.push(&tokens);
        let result = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("prefill execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("prefill fetch: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("prefill tuple: {e:?}"))?;
        let [logits, k, v]: [xla::Literal; 3] = parts
            .try_into()
            .map_err(|_| anyhow!("prefill must return 3 outputs"))?;
        self.prefill_calls.set(self.prefill_calls.get() + 1);
        Ok(StepOutput {
            logits: logits
                .to_vec::<f32>()
                .map_err(|e| anyhow!("logits: {e:?}"))?,
            k_cache: k,
            v_cache: v,
        })
    }

    /// One decode step: `tokens` (≤ batch, padded with token 0), shared
    /// position `pos`. Returns logits [B, V] and updated caches.
    pub fn decode_step(
        &self,
        tokens: &[i32],
        k_cache: &xla::Literal,
        v_cache: &xla::Literal,
        pos: i32,
    ) -> Result<StepOutput> {
        let b = self.manifest.batch;
        if tokens.is_empty() || tokens.len() > b {
            return Err(anyhow!("decode batch must be 1..={b}"));
        }
        if !(0..self.manifest.max_seq as i32).contains(&pos) {
            return Err(anyhow!("pos {pos} out of cache capacity"));
        }
        let mut padded = tokens.to_vec();
        padded.resize(b, tokens[0]);
        let tok_lit = xla::Literal::vec1(&padded);
        let pos_lit = xla::Literal::from(pos);
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.push(&tok_lit);
        args.push(k_cache);
        args.push(v_cache);
        args.push(&pos_lit);
        let result = self
            .decode_exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("decode execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("decode fetch: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("decode tuple: {e:?}"))?;
        let [logits, k, v]: [xla::Literal; 3] = parts
            .try_into()
            .map_err(|_| anyhow!("decode must return 3 outputs"))?;
        self.decode_calls.set(self.decode_calls.get() + 1);
        Ok(StepOutput {
            logits: logits
                .to_vec::<f32>()
                .map_err(|e| anyhow!("logits: {e:?}"))?,
            k_cache: k,
            v_cache: v,
        })
    }

    /// Greedy argmax over a logits row.
    pub fn argmax_row(&self, logits: &[f32], row: usize) -> i32 {
        let v = self.manifest.vocab;
        let slice = &logits[row * v..(row + 1) * v];
        let mut best = 0usize;
        for (i, &x) in slice.iter().enumerate() {
            if x > slice[best] {
                best = i;
            }
        }
        best as i32
    }

    /// Greedy generation for a batch of prompts (teacher path for tests and
    /// the quickstart). The decode executable shares `pos` across the
    /// batch, so all prompts in one call must have equal token length —
    /// the server batches by exact length; here it is an error.
    pub fn generate(&self, prompts: &[Vec<i32>], max_new: usize) -> Result<Vec<Vec<i32>>> {
        let len0 = prompts.first().map(Vec::len).unwrap_or(0);
        if len0 == 0 || prompts.iter().any(|p| p.len() != len0) {
            return Err(anyhow!("generate needs equal-length, non-empty prompts"));
        }
        let bucket = self
            .manifest
            .bucket_for(len0)
            .ok_or_else(|| anyhow!("prompt length {len0} exceeds largest bucket"))?;
        let out = self.prefill(prompts, bucket)?;
        let v = self.manifest.vocab;
        let mut results: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        // Next token per row from the last real prompt position. Positions
        // beyond len0 hold bucket padding, but decode masks the cache at
        // `pos`, so they are never attended.
        let mut next: Vec<i32> = (0..prompts.len())
            .map(|r| {
                let pos = len0 - 1;
                let row = &out.logits[(r * bucket + pos) * v..(r * bucket + pos + 1) * v];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as i32
            })
            .collect();
        let mut k = out.k_cache;
        let mut v_cache = out.v_cache;
        let mut pos = len0 as i32;
        for _ in 0..max_new {
            if pos as usize >= self.manifest.max_seq {
                break;
            }
            for (r, n) in next.iter().enumerate() {
                results[r].push(*n);
            }
            let step = self.decode_step(&next, &k, &v_cache, pos)?;
            for (r, n) in next.iter_mut().enumerate().take(prompts.len()) {
                *n = self.argmax_row(&step.logits, r);
            }
            k = step.k_cache;
            v_cache = step.v_cache;
            pos += 1;
        }
        Ok(results)
    }
}

//! The PJRT runtime: loads the AOT artifacts exported by
//! `python/compile/aot.py` and executes them on the PJRT CPU client.
//! Python never runs here — the Rust binary is self-contained once
//! `make artifacts` has produced `artifacts/*.hlo.txt` + `params.bin`.

pub mod engine;
pub mod kv_cache;
pub mod manifest;
pub mod tokenizer;
pub mod xla_stub;

pub use engine::TinyLmEngine;
pub use kv_cache::KvBlockAllocator;
pub use manifest::Manifest;
pub use tokenizer::ByteTokenizer;

//! KV-cache block allocator — the memory-management substrate every
//! serving engine needs (vLLM-style paged blocks, minus the paging).
//!
//! The decode pool admits streams only while blocks remain; the sim uses
//! a stream cap derived from this and the real server uses it directly to
//! bound concurrent batches. Reference counting supports prefix sharing
//! (fork) so a future speculative/beam path can reuse prompt blocks.

use std::collections::HashMap;

/// Fixed-size block allocator with refcounts.
#[derive(Debug)]
pub struct KvBlockAllocator {
    /// Tokens per block.
    pub block_tokens: usize,
    /// Total blocks in the pool.
    pub total_blocks: usize,
    free: Vec<usize>,
    refcounts: HashMap<usize, u32>,
    /// stream id → blocks held.
    allocations: HashMap<u64, Vec<usize>>,
}

impl KvBlockAllocator {
    /// A pool of `total_blocks` blocks of `block_tokens` tokens each.
    pub fn new(total_blocks: usize, block_tokens: usize) -> Self {
        assert!(total_blocks > 0 && block_tokens > 0);
        KvBlockAllocator {
            block_tokens,
            total_blocks,
            free: (0..total_blocks).rev().collect(),
            refcounts: HashMap::new(),
            allocations: HashMap::new(),
        }
    }

    /// Blocks needed to hold `tokens` tokens (ceiling division).
    pub fn blocks_for_tokens(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Blocks currently free.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently allocated.
    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Can a stream of `tokens` context be admitted?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for_tokens(tokens) <= self.free.len()
    }

    /// Allocate blocks for a new stream. Returns false (no change) if the
    /// cache cannot hold it.
    pub fn admit(&mut self, stream: u64, tokens: usize) -> bool {
        let need = self.blocks_for_tokens(tokens);
        if need > self.free.len() || self.allocations.contains_key(&stream) {
            return false;
        }
        let blocks: Vec<usize> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        for &b in &blocks {
            self.refcounts.insert(b, 1);
        }
        self.allocations.insert(stream, blocks);
        true
    }

    /// Extend a stream by one token; allocates a new block on a boundary.
    /// Returns false if the cache is full (caller must preempt or wait).
    pub fn append_token(&mut self, stream: u64, new_len: usize) -> bool {
        let need = self.blocks_for_tokens(new_len);
        let Some(blocks) = self.allocations.get(&stream) else {
            return false;
        };
        if blocks.len() >= need {
            return true;
        }
        if self.free.is_empty() {
            return false;
        }
        let b = self.free.pop().unwrap();
        self.refcounts.insert(b, 1);
        self.allocations.get_mut(&stream).unwrap().push(b);
        true
    }

    /// Fork: a child stream sharing the parent's blocks (copy-on-write
    /// refcounting; prefix sharing).
    pub fn fork(&mut self, parent: u64, child: u64) -> bool {
        if self.allocations.contains_key(&child) {
            return false;
        }
        let Some(blocks) = self.allocations.get(&parent).cloned() else {
            return false;
        };
        for &b in &blocks {
            *self.refcounts.get_mut(&b).unwrap() += 1;
        }
        self.allocations.insert(child, blocks);
        true
    }

    /// Release a stream's blocks (decrement refcounts; free at zero).
    pub fn release(&mut self, stream: u64) {
        if let Some(blocks) = self.allocations.remove(&stream) {
            for b in blocks {
                let rc = self.refcounts.get_mut(&b).unwrap();
                *rc -= 1;
                if *rc == 0 {
                    self.refcounts.remove(&b);
                    self.free.push(b);
                }
            }
        }
    }

    /// Invariant check (used by property tests): every block is either
    /// free or referenced, never both, never neither.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.total_blocks];
        for &b in &self.free {
            if seen[b] {
                return Err(format!("block {b} double-freed"));
            }
            seen[b] = true;
            if self.refcounts.contains_key(&b) {
                return Err(format!("block {b} free but refcounted"));
            }
        }
        for (&b, &rc) in &self.refcounts {
            if rc == 0 {
                return Err(format!("block {b} with zero refcount"));
            }
            if seen[b] {
                return Err(format!("block {b} both free and allocated"));
            }
            seen[b] = true;
        }
        if !seen.iter().all(|&s| s) {
            return Err("leaked block".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::check;

    #[test]
    fn admit_and_release_roundtrip() {
        let mut a = KvBlockAllocator::new(10, 16);
        assert!(a.admit(1, 33)); // 3 blocks
        assert_eq!(a.used_blocks(), 3);
        a.release(1);
        assert_eq!(a.free_blocks(), 10);
        a.check_invariants().unwrap();
    }

    #[test]
    fn rejects_when_full() {
        let mut a = KvBlockAllocator::new(4, 16);
        assert!(a.admit(1, 64)); // 4 blocks
        assert!(!a.admit(2, 1));
        assert!(!a.can_admit(1));
        a.release(1);
        assert!(a.admit(2, 1));
    }

    #[test]
    fn append_allocates_on_boundary() {
        let mut a = KvBlockAllocator::new(3, 4);
        assert!(a.admit(1, 4)); // exactly 1 block
        assert!(a.append_token(1, 5)); // crosses boundary → 2nd block
        assert_eq!(a.used_blocks(), 2);
        assert!(a.append_token(1, 6)); // same block
        assert_eq!(a.used_blocks(), 2);
    }

    #[test]
    fn append_fails_when_exhausted() {
        let mut a = KvBlockAllocator::new(1, 4);
        assert!(a.admit(1, 4));
        assert!(!a.append_token(1, 5));
    }

    #[test]
    fn fork_shares_blocks_release_frees_at_zero() {
        let mut a = KvBlockAllocator::new(4, 8);
        assert!(a.admit(1, 16)); // 2 blocks
        assert!(a.fork(1, 2));
        assert_eq!(a.used_blocks(), 2); // shared, not copied
        a.release(1);
        assert_eq!(a.used_blocks(), 2); // child still holds them
        a.release(2);
        assert_eq!(a.free_blocks(), 4);
        a.check_invariants().unwrap();
    }

    #[test]
    fn double_admit_rejected() {
        let mut a = KvBlockAllocator::new(4, 8);
        assert!(a.admit(1, 8));
        assert!(!a.admit(1, 8));
    }

    #[test]
    fn property_random_workload_keeps_invariants() {
        check("kv_allocator_invariants", 30, |g| {
            let mut a = KvBlockAllocator::new(1 + g.index(32), 1 + g.index(32));
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            let mut lens: std::collections::HashMap<u64, usize> = Default::default();
            for _ in 0..200 {
                match g.index(4) {
                    0 => {
                        let tokens = 1 + g.index(64);
                        if a.admit(next_id, tokens) {
                            live.push(next_id);
                            lens.insert(next_id, tokens);
                        }
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let s = live[g.index(live.len())];
                        let l = lens.get_mut(&s).unwrap();
                        if a.append_token(s, *l + 1) {
                            *l += 1;
                        }
                    }
                    2 if !live.is_empty() => {
                        let i = g.index(live.len());
                        let s = live.swap_remove(i);
                        lens.remove(&s);
                        a.release(s);
                    }
                    3 if !live.is_empty() => {
                        let parent = live[g.index(live.len())];
                        if a.fork(parent, next_id) {
                            live.push(next_id);
                            lens.insert(next_id, lens[&parent]);
                        }
                        next_id += 1;
                    }
                    _ => {}
                }
                a.check_invariants()?;
            }
            for s in live {
                a.release(s);
            }
            a.check_invariants()?;
            crate::prop_assert!(
                a.free_blocks() == a.total_blocks,
                "leak: {} free of {}",
                a.free_blocks(),
                a.total_blocks
            );
            Ok(())
        });
    }
}

//! `artifacts/manifest.json` — the contract between the Python AOT export
//! and the Rust runtime: model shape, parameter ABI order, shape buckets,
//! file names and numeric test vectors.

use crate::util::error::{anyhow, Context, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
/// One parameter tensor in ABI order.
pub struct ParamSpec {
    /// Parameter name.
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
}

impl ParamSpec {
    /// Element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
/// Numeric ground truth exported alongside the artifacts.
pub struct TestVectors {
    /// Prefill bucket the vectors were computed at.
    pub prefill_bucket: usize,
    /// Sum of the last-position logits.
    pub last_logits_sum: f64,
    /// Mean absolute value of the last-position logits.
    pub last_logits_absmean: f64,
    /// Head of logits row 0 (spot check).
    pub last_logits_row0_head: Vec<f64>,
    /// Prompt used for the greedy-decode check.
    pub greedy_prompt: Vec<i32>,
    /// Expected greedy continuation tokens.
    pub greedy_next_tokens: Vec<i32>,
}

#[derive(Debug, Clone)]
/// Parsed `manifest.json`: model shape, ABI, buckets, file map.
pub struct Manifest {
    /// Artifact directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Vocabulary size.
    pub vocab: usize,
    /// Hidden width.
    pub d_model: usize,
    /// Attention head count.
    pub n_heads: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// FFN inner width.
    pub d_ff: usize,
    /// Maximum sequence length.
    pub max_seq: usize,
    /// Per-head dimension.
    pub d_head: usize,
    /// Total parameter element count.
    pub num_params: usize,
    /// Compiled batch size.
    pub batch: usize,
    /// Prefill sequence-length buckets, ascending.
    pub prefill_buckets: Vec<usize>,
    /// Path to the flat parameter file.
    pub params_file: PathBuf,
    /// (bucket, path) per compiled prefill executable.
    pub prefill_files: Vec<(usize, PathBuf)>,
    /// Path to the compiled decode executable.
    pub decode_file: PathBuf,
    /// Numeric ground truth for the loaded artifacts.
    pub test_vectors: TestVectors,
}

impl Manifest {
    /// Parse and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let usize_at = |p: &str| -> Result<usize> {
            j.path(p)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest missing {p}"))
        };
        let buckets: Vec<usize> = j
            .get("prefill_buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing prefill_buckets"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let files = j
            .get("files")
            .ok_or_else(|| anyhow!("manifest missing files"))?;
        let mut prefill_files = Vec::new();
        for &b in &buckets {
            let f = files
                .get(&format!("prefill_s{b}"))
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest missing prefill_s{b}"))?;
            prefill_files.push((b, dir.join(f)));
        }
        let decode_file = dir.join(
            files
                .get("decode_step")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest missing decode_step"))?,
        );
        let tv = j
            .get("test_vectors")
            .ok_or_else(|| anyhow!("manifest missing test_vectors"))?;
        let f64s = |node: &Json| -> Vec<f64> {
            node.as_arr()
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default()
        };
        let i32s = |node: &Json| -> Vec<i32> {
            node.as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_f64().map(|f| f as i32)).collect())
                .unwrap_or_default()
        };
        let test_vectors = TestVectors {
            prefill_bucket: tv
                .get("prefill_bucket")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("test_vectors.prefill_bucket"))?,
            last_logits_sum: tv
                .get("last_logits_sum")
                .and_then(Json::as_f64)
                .unwrap_or(f64::NAN),
            last_logits_absmean: tv
                .get("last_logits_absmean")
                .and_then(Json::as_f64)
                .unwrap_or(f64::NAN),
            last_logits_row0_head: tv
                .get("last_logits_row0_head")
                .map(f64s)
                .unwrap_or_default(),
            greedy_prompt: tv.get("greedy_prompt").map(i32s).unwrap_or_default(),
            greedy_next_tokens: tv.get("greedy_next_tokens").map(i32s).unwrap_or_default(),
        };

        Ok(Manifest {
            dir: dir.to_path_buf(),
            vocab: usize_at("model.vocab")?,
            d_model: usize_at("model.d_model")?,
            n_heads: usize_at("model.n_heads")?,
            n_layers: usize_at("model.n_layers")?,
            d_ff: usize_at("model.d_ff")?,
            max_seq: usize_at("model.max_seq")?,
            d_head: usize_at("model.d_head")?,
            num_params: usize_at("model.num_params")?,
            batch: usize_at("batch")?,
            prefill_buckets: buckets,
            params_file: dir.join(
                j.get("params_file")
                    .and_then(Json::as_str)
                    .unwrap_or("params.bin"),
            ),
            prefill_files,
            decode_file,
            test_vectors,
        })
    }

    /// Parameter tensor specs in ABI order (mirrors ModelConfig.param_specs
    /// in python/compile/model.py — the orders must match exactly).
    pub fn param_specs(&self) -> Vec<ParamSpec> {
        let (v, d, ff, t) = (self.vocab, self.d_model, self.d_ff, self.max_seq);
        let mut specs = vec![
            ParamSpec {
                name: "embed".into(),
                shape: vec![v, d],
            },
            ParamSpec {
                name: "pos_embed".into(),
                shape: vec![t, d],
            },
        ];
        for i in 0..self.n_layers {
            let layer = |n: &str, shape: Vec<usize>| ParamSpec {
                name: format!("l{i}.{n}"),
                shape,
            };
            specs.extend([
                layer("norm1", vec![d]),
                layer("wq", vec![d, d]),
                layer("wk", vec![d, d]),
                layer("wv", vec![d, d]),
                layer("wo", vec![d, d]),
                layer("norm2", vec![d]),
                layer("w_gate", vec![d, ff]),
                layer("w_up", vec![d, ff]),
                layer("w_down", vec![ff, d]),
            ]);
        }
        specs.push(ParamSpec {
            name: "final_norm".into(),
            shape: vec![d],
        });
        specs.push(ParamSpec {
            name: "lm_head".into(),
            shape: vec![d, v],
        });
        specs
    }

    /// Smallest bucket that fits a prompt of `len` tokens.
    pub fn bucket_for(&self, len: usize) -> Option<usize> {
        self.prefill_buckets.iter().copied().find(|&b| b >= len)
    }

    /// Read the flat f32 parameter file (validates the byte count).
    pub fn load_params_f32(&self) -> Result<Vec<f32>> {
        let bytes = std::fs::read(&self.params_file)
            .with_context(|| format!("reading {:?}", self.params_file))?;
        if bytes.len() != self.num_params * 4 {
            return Err(anyhow!(
                "params.bin has {} bytes, expected {}",
                bytes.len(),
                self.num_params * 4
            ));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.vocab >= 2);
        assert!(!m.prefill_buckets.is_empty());
        assert_eq!(m.prefill_files.len(), m.prefill_buckets.len());
        // ABI: total elements of the spec list must equal num_params.
        let total: usize = m.param_specs().iter().map(|s| s.numel()).sum();
        assert_eq!(total, m.num_params);
        // Params file round-trips.
        let p = m.load_params_f32().unwrap();
        assert_eq!(p.len(), m.num_params);
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn bucket_selection() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let smallest = m.prefill_buckets[0];
        let largest = *m.prefill_buckets.last().unwrap();
        assert_eq!(m.bucket_for(1), Some(smallest));
        assert_eq!(m.bucket_for(smallest), Some(smallest));
        assert_eq!(m.bucket_for(largest + 1), None);
    }

    #[test]
    fn missing_dir_is_error_with_hint() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}

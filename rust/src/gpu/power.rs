//! GPU power model: cubic in SM frequency (Eq. 7 of the paper).
//!
//! CMOS dynamic power grows ~f·V² with joint voltage-frequency scaling ⇒
//! roughly cubic in f. The paper fits `P(f) = k₃f³ + k₂f² + k₁f + k₀` to
//! measured prefill power on the A100 (Fig. 8); we *define* the simulated
//! GPU with such a polynomial (calibrated to the A100 envelope) and let the
//! controllers re-fit it from noisy "measurements" — exactly the paper's
//! online-modeling loop, closed in simulation.

use crate::gpu::freq::ghz;

/// Cubic active-power model + idle floor. Frequencies in MHz at the API,
/// GHz inside the polynomial.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerModel {
    /// Coefficients low→high: P(f_ghz) = k0 + k1 f + k2 f² + k3 f³ (watts),
    /// at full (prefill-saturating) utilization.
    pub coeffs: [f64; 4],
    /// Idle power at the lowest clock, watts.
    pub idle_base_w: f64,
    /// Idle power slope with clock (W/GHz): an A100 parked at max clocks
    /// idles noticeably hotter than at 210 MHz. This is why parking idle
    /// workers at low clocks (which GreenLLM does and defaultNV does not)
    /// saves real energy on low-utilization traces.
    pub idle_slope_w_per_ghz: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel::a100()
    }
}

impl PowerModel {
    /// Calibrated to the A100-SXM4-40GB envelope: ~193 W active floor at
    /// 210 MHz, ~400 W at 1410 MHz, idle ≈ 55 W. The coefficients satisfy
    /// d/df[(P(f) − P_idle)/f] = 0 at ≈ 1.0 GHz, which is what puts the
    /// prefill energy knee at 0.95–1.05 GHz (Takeaway #1) — see the
    /// calibration test below and DESIGN.md §7.
    pub fn a100() -> Self {
        PowerModel {
            coeffs: [188.6, 20.0, -6.4, 70.0],
            idle_base_w: 40.0,
            idle_slope_w_per_ghz: 25.0,
        }
    }

    /// Uniformly scale the whole envelope (active curve, idle floor and
    /// idle slope) by `factor`. The heterogeneity layer uses this as a
    /// GPU-generation proxy: an efficiency-binned next-gen part is the
    /// A100 curve × 0.7, an older-generation node × 1.25. Scaling by
    /// exactly 1.0 is a bit-exact identity (`x * 1.0 == x` in IEEE 754),
    /// so homogeneous clusters reproduce pre-heterogeneity results.
    pub fn scaled(mut self, factor: f64) -> PowerModel {
        assert!(factor > 0.0, "power scale must be positive");
        for c in self.coeffs.iter_mut() {
            *c *= factor;
        }
        self.idle_base_w *= factor;
        self.idle_slope_w_per_ghz *= factor;
        self
    }

    /// Idle power at a given (parked) clock: ≈45 W at 210 MHz, ≈75 W at
    /// 1410 MHz on the A100.
    pub fn idle_w(&self, mhz: u32) -> f64 {
        self.idle_base_w + self.idle_slope_w_per_ghz * ghz(mhz)
    }

    /// Power at frequency `mhz` and utilization `util` ∈ [0, 1]. `util`
    /// interpolates between clocked-idle and full active power: decode
    /// workers run at lower SM toggling rates than saturated prefill.
    pub fn power_w(&self, mhz: u32, util: f64) -> f64 {
        let u = util.clamp(0.0, 1.0);
        let idle = self.idle_w(mhz);
        if u == 0.0 {
            return idle;
        }
        idle + u * (self.active_w(mhz) - idle)
    }

    /// Full-utilization active power (the fitted curve of Fig. 8).
    pub fn active_w(&self, mhz: u32) -> f64 {
        let f = ghz(mhz);
        let [k0, k1, k2, k3] = self.coeffs;
        (k0 + k1 * f + k2 * f * f + k3 * f * f * f).max(self.idle_w(mhz))
    }

    /// Energy (J) over a duration at fixed frequency/util.
    pub fn energy_j(&self, mhz: u32, util: f64, dt_s: f64) -> f64 {
        self.power_w(mhz, util) * dt_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::freq::FreqLadder;

    #[test]
    fn envelope_matches_a100() {
        let p = PowerModel::a100();
        let peak = p.active_w(1410);
        assert!((395.0..410.0).contains(&peak), "peak={peak}");
        let floor = p.active_w(210);
        assert!((150.0..220.0).contains(&floor), "floor={floor}");
        // Clocked-idle: hotter at high clocks.
        let idle_lo = p.power_w(210, 0.0);
        let idle_hi = p.power_w(1410, 0.0);
        assert!((40.0..50.0).contains(&idle_lo), "idle_lo={idle_lo}");
        assert!((70.0..80.0).contains(&idle_hi), "idle_hi={idle_hi}");
    }

    #[test]
    fn monotone_increasing_in_frequency() {
        let p = PowerModel::a100();
        let l = FreqLadder::a100();
        let mut prev = 0.0;
        for f in l.iter() {
            let w = p.active_w(f);
            assert!(w > prev, "power not monotone at {f} MHz");
            prev = w;
        }
    }

    #[test]
    fn util_interpolates_between_idle_and_active() {
        let p = PowerModel::a100();
        let idle = p.power_w(1200, 0.0);
        let half = p.power_w(1200, 0.5);
        let full = p.power_w(1200, 1.0);
        assert!((half - (idle + 0.5 * (full - idle))).abs() < 1e-9);
        assert!(p.power_w(1200, 2.0) <= full + 1e-12); // clamped
    }

    /// Takeaway #1 calibration: the energy-per-work knee (min of
    /// (P(f)−P_idle)/f) sits in the 0.90–1.10 GHz band, ≈70–80 % of max.
    #[test]
    fn prefill_energy_knee_in_paper_band() {
        let p = PowerModel::a100();
        let l = FreqLadder::a100();
        let knee = l
            .iter()
            .min_by(|&a, &b| {
                let ea = (p.active_w(a) - p.idle_w(a)) / a as f64;
                let eb = (p.active_w(b) - p.idle_w(b)) / b as f64;
                ea.partial_cmp(&eb).unwrap()
            })
            .unwrap();
        assert!(
            (900..=1100).contains(&knee),
            "prefill energy knee at {knee} MHz, expected 900–1100"
        );
    }

    #[test]
    fn scaled_model_scales_every_term() {
        let base = PowerModel::a100();
        let eff = base.clone().scaled(0.7);
        for f in [210, 900, 1410] {
            assert!((eff.active_w(f) - 0.7 * base.active_w(f)).abs() < 1e-9);
            assert!((eff.idle_w(f) - 0.7 * base.idle_w(f)).abs() < 1e-9);
        }
        // Unit scale is a bit-exact identity.
        let same = base.clone().scaled(1.0);
        assert_eq!(same, base);
    }

    #[test]
    fn energy_is_power_times_time() {
        let p = PowerModel::a100();
        let e = p.energy_j(1005, 1.0, 2.0);
        assert!((e - 2.0 * p.power_w(1005, 1.0)).abs() < 1e-12);
    }
}

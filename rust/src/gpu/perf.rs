//! Frequency-dependent latency models for prefill and decode — the
//! simulated "ground truth" the controllers act against.
//!
//! Structure follows the paper's own validated assumptions:
//!   * prefill is compute-bound: `t(L, f) = t_ref(L) · f_ref/f` (Eq. 3)
//!     with `t_ref(L) = aL² + bL + c` (Eq. 2 / Fig. 7), a and b derived
//!     from the Eq.-1 FLOPs model and the worker's effective FLOP/s;
//!   * decode is memory-bound: `t_step(f) = t_mem + t_cmp · f_ref/f`, so
//!     latency saturates at high clocks while power keeps growing —
//!     that asymmetry is the whole point of phase-specific DVFS
//!     (§2.2.2, Takeaways #1/#2).

use crate::model::ModelSpec;

/// Hardware constants of one A100-class accelerator (per GPU).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuHardware {
    /// Peak dense BF16 throughput, FLOP/s (A100: 312e12).
    pub peak_flops: f64,
    /// HBM bandwidth, B/s (A100-40GB: 1.555e12).
    pub hbm_bw: f64,
    /// Reference (max) SM clock in MHz.
    pub f_ref_mhz: u32,
}

impl Default for GpuHardware {
    fn default() -> Self {
        GpuHardware {
            peak_flops: 312e12,
            hbm_bw: 1.555e12,
            f_ref_mhz: 1410,
        }
    }
}

/// Per-phase latency model for a (model, worker shape) pair.
#[derive(Debug, Clone)]
pub struct PerfModel {
    /// Hardware envelope (peak FLOPs, HBM bandwidth, reference clock).
    pub hw: GpuHardware,
    /// Served model architecture + cost coefficients.
    pub spec: ModelSpec,
    /// GPUs per prefill worker (paper: 2) and TP efficiency.
    pub prefill_gpus: usize,
    /// Tensor-parallel scaling efficiency across the worker's GPUs.
    pub tp_efficiency: f64,
    /// Model FLOPs utilization achieved by the serving kernels.
    pub prefill_mfu: f64,
    /// Fixed prefill overhead (tokenization, launch, scheduling) seconds.
    pub prefill_overhead_s: f64,
    /// Fixed per-decode-step overhead (framework + kernel launches) seconds.
    pub decode_overhead_s: f64,
    /// Per-stream per-step overhead (sampling, batching bookkeeping) seconds.
    pub decode_per_stream_s: f64,
    /// MFU of the small decode GEMVs.
    pub decode_mfu: f64,
    /// Clock-scaling fraction of the fixed step overhead (scheduling and
    /// launches are mostly memory/host-bound).
    pub overhead_cmp_frac: f64,
    /// Clock-scaling fraction of the per-stream cost (attention/sampling
    /// per stream is mostly SM compute). Making the per-stream term
    /// compute-heavy is what shrinks GreenLLM's clock slack as batch grows
    /// — the paper's savings-vs-load falloff (Fig. 11).
    pub per_stream_cmp_frac: f64,
    /// Memory-bound fraction of prefill: the share of `t_ref` that does
    /// NOT scale with clock. 0.0 (the analytic default) is the paper's
    /// pure-compute Eq. 3; calibrated parts fit a small positive value
    /// from measured sweeps ([`crate::gpu::calibrate`]).
    pub prefill_mem_frac: f64,
    /// Calibration scale on the decode memory-bound component (1.0 =
    /// analytic).
    pub decode_mem_scale: f64,
    /// Calibration scale on the decode compute-bound component (1.0 =
    /// analytic).
    pub decode_cmp_scale: f64,
}

impl PerfModel {
    /// Calibrated model for `spec` on the paper's A100 worker shapes.
    pub fn new(spec: ModelSpec) -> Self {
        // Calibrated so the node saturates where the paper's does: prefill
        // pool nears saturation at Alibaba-chat 10 QPS (TTFT% dips to ~88,
        // Table 3) while the decode pool still holds P95 TBT ≈ 70–90 ms at
        // ~3000 aggregate TPS (Fig. 11). MFU numbers are serving-stack
        // effective values (TensorRT-LLM TP-2 prefill, batched GEMV decode),
        // not kernel peaks.
        PerfModel {
            hw: GpuHardware::default(),
            spec,
            prefill_gpus: 2,
            tp_efficiency: 0.90,
            prefill_mfu: 0.28,
            prefill_overhead_s: 0.008,
            decode_overhead_s: 0.010,
            decode_per_stream_s: 0.00058,
            decode_mfu: 0.36,
            overhead_cmp_frac: 0.3,
            per_stream_cmp_frac: 0.8,
            prefill_mem_frac: 0.0,
            decode_mem_scale: 1.0,
            decode_cmp_scale: 1.0,
        }
    }

    /// Effective prefill FLOP/s of one worker at the reference clock.
    pub fn prefill_effective_flops(&self) -> f64 {
        self.hw.peak_flops * self.prefill_gpus as f64 * self.tp_efficiency * self.prefill_mfu
    }

    /// Quadratic-model coefficients (a, b, c) of Eq. (2) at f_ref: these are
    /// the ground truth the online profiler re-fits from noisy samples.
    pub fn prefill_coeffs(&self) -> (f64, f64, f64) {
        let eff = self.prefill_effective_flops();
        let a = self.spec.prefill_flops_quadratic() / eff;
        let b = self.spec.prefill_flops_linear() / eff;
        (a, b, self.prefill_overhead_s)
    }

    /// Prefill latency for a prompt of `len` tokens at SM clock `mhz`
    /// (Eq. 3, generalized with the calibrated memory-bound fraction `m`:
    /// `t(f) = t_ref · (m + (1−m) · f_ref/f)`; `m = 0` is exactly Eq. 3).
    pub fn prefill_time(&self, len: usize, mhz: u32) -> f64 {
        let (a, b, c) = self.prefill_coeffs();
        let l = len as f64;
        let t_ref = a * l * l + b * l + c;
        t_ref * (self.prefill_mem_frac + (1.0 - self.prefill_mem_frac) * self.freq_slowdown(mhz))
    }

    #[inline]
    /// Latency multiplier of running at `mhz` vs the reference clock.
    pub fn freq_slowdown(&self, mhz: u32) -> f64 {
        self.hw.f_ref_mhz as f64 / (mhz.max(1) as f64)
    }

    /// Decode step time at f_ref, split into (memory-bound, compute-bound)
    /// components. `batch` = concurrent streams, `avg_ctx` = mean context.
    pub fn decode_step_components(&self, batch: usize, avg_ctx: f64) -> (f64, f64) {
        let b = batch.max(1) as f64;
        // Memory: weight streaming + KV reads + the memory shares of the
        // fixed and per-stream overheads.
        let weights = self.spec.decode_weight_bytes(batch) / self.hw.hbm_bw;
        let kv = b * avg_ctx * self.spec.kv_bytes_per_token() / self.hw.hbm_bw;
        let mem_over = (1.0 - self.overhead_cmp_frac) * self.decode_overhead_s
            + (1.0 - self.per_stream_cmp_frac) * b * self.decode_per_stream_s;
        // Compute: batched GEMVs + the clocked shares of the overheads.
        let flops = b * self.spec.decode_flops_per_token()
            / (self.hw.peak_flops * self.decode_mfu);
        let cmp_over = self.overhead_cmp_frac * self.decode_overhead_s
            + self.per_stream_cmp_frac * b * self.decode_per_stream_s;
        (
            (weights + kv + mem_over) * self.decode_mem_scale,
            (flops + cmp_over) * self.decode_cmp_scale,
        )
    }

    /// Decode step latency at SM clock `mhz`: t_mem + t_cmp · f_ref/f.
    pub fn decode_step_time(&self, batch: usize, avg_ctx: f64, mhz: u32) -> f64 {
        let (t_mem, t_cmp) = self.decode_step_components(batch, avg_ctx);
        t_mem + t_cmp * self.freq_slowdown(mhz)
    }

    /// Memory-bound fraction β at f_ref (diagnostic; paper's ~0.55–0.7).
    pub fn decode_beta(&self, batch: usize, avg_ctx: f64) -> f64 {
        let (m, c) = self.decode_step_components(batch, avg_ctx);
        m / (m + c)
    }

    /// Decode SM utilization (for the power model): stalled-on-HBM SMs
    /// toggle less than saturated prefill SMs, and bigger batches raise
    /// occupancy.
    pub fn decode_util(&self, batch: usize) -> f64 {
        0.75 + 0.15 * (batch as f64 / 32.0).min(1.0)
    }

    /// Max sustainable aggregate tokens/s of one decode worker at `mhz`
    /// subject to a TBT bound (used for capacity planning in benches).
    pub fn decode_capacity_tps(&self, avg_ctx: f64, mhz: u32, tbt_bound_s: f64) -> f64 {
        let mut best = 0.0;
        let mut b = 1usize;
        while b <= 512 {
            let t = self.decode_step_time(b, avg_ctx, mhz);
            if t <= tbt_bound_s {
                best = b as f64 / t;
            }
            b += 1;
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qwen14b() -> PerfModel {
        PerfModel::new(ModelSpec::qwen3_14b())
    }

    #[test]
    fn prefill_coeffs_in_expected_range() {
        let m = qwen14b();
        let (a, b, c) = m.prefill_coeffs();
        // b ≈ 2·14.8e9 / (2 × 312e12 × 0.9 × 0.28) ≈ 1.9e-4 s/token.
        assert!((1.5e-4..2.3e-4).contains(&b), "b={b:.3e}");
        assert!((1.5e-9..4.0e-9).contains(&a), "a={a:.3e}");
        assert_eq!(c, 0.008);
    }

    #[test]
    fn prefill_moderate_prompt_leaves_slo_slack() {
        // Paper §5.1.1: a moderate request well under the 400 ms SLO at
        // boost clocks, leaving slack the optimizer can trade for energy.
        let m = qwen14b();
        let t = m.prefill_time(512, 1410);
        assert!((0.05..0.20).contains(&t), "t={t}");
    }

    #[test]
    fn prefill_long_prompt_within_2s_slo() {
        let m = qwen14b();
        let t = m.prefill_time(8192, 1410);
        assert!((0.5..2.0).contains(&t), "t={t}");
    }

    #[test]
    fn prefill_scales_inverse_with_frequency() {
        let m = qwen14b();
        let t_full = m.prefill_time(1024, 1410);
        let t_half = m.prefill_time(1024, 705);
        assert!((t_half / t_full - 2.0).abs() < 1e-9);
    }

    #[test]
    fn decode_step_envelope_matches_fig11() {
        // Fig. 11: ~40 ms TBT at light load, ~85 ms near 750 TPS/worker.
        let m = qwen14b();
        let light = m.decode_step_time(2, 600.0, 1410);
        assert!((0.025..0.05).contains(&light), "light={light}");
        let heavy = m.decode_step_time(64, 600.0, 1410);
        assert!((0.06..0.1).contains(&heavy), "heavy={heavy}");
    }

    #[test]
    fn decode_latency_saturates_with_frequency() {
        // Halving the clock must *less* than double decode latency
        // (memory-bound), in contrast to prefill.
        let m = qwen14b();
        let t_full = m.decode_step_time(16, 600.0, 1410);
        let t_half = m.decode_step_time(16, 600.0, 705);
        let ratio = t_half / t_full;
        assert!(ratio < 1.6, "ratio={ratio}");
        assert!(ratio > 1.1);
    }

    #[test]
    fn decode_beta_memory_bound() {
        let m = qwen14b();
        let beta = m.decode_beta(16, 600.0);
        assert!((0.5..0.85).contains(&beta), "beta={beta}");
    }

    #[test]
    fn moe_decode_more_memory_bound_than_dense() {
        let dense = qwen14b();
        let moe = PerfModel::new(ModelSpec::qwen3_30b_moe());
        assert!(moe.decode_beta(16, 600.0) > dense.decode_beta(16, 600.0));
    }

    #[test]
    fn decode_capacity_near_1000_tps_per_worker() {
        let m = qwen14b();
        let cap = m.decode_capacity_tps(600.0, 1410, 0.100);
        assert!((600.0..1400.0).contains(&cap), "cap={cap}");
        // Lower clock lowers capacity.
        assert!(m.decode_capacity_tps(600.0, 705, 0.100) < cap);
    }

    #[test]
    fn calibration_knobs_default_to_bit_exact_identity() {
        // prefill_mem_frac 0.0 and unit decode scales must leave every
        // latency unchanged to the last bit — the analytic model is the
        // oracle for all pre-calibration tests and goldens.
        let m = qwen14b();
        assert_eq!(m.prefill_mem_frac, 0.0);
        assert_eq!((m.decode_mem_scale, m.decode_cmp_scale), (1.0, 1.0));
        for mhz in [210, 705, 997, 1410] {
            let (a, b, c) = m.prefill_coeffs();
            let l = 1024.0;
            let legacy = (a * l * l + b * l + c) * m.freq_slowdown(mhz);
            assert_eq!(m.prefill_time(1024, mhz), legacy);
        }
        // Calibrated shape: a positive mem fraction flattens the response.
        let mut cal = qwen14b();
        cal.prefill_mem_frac = 0.25;
        let ratio = cal.prefill_time(1024, 705) / cal.prefill_time(1024, 1410);
        assert!(ratio < 2.0 && ratio > 1.5, "ratio={ratio}");
    }

    #[test]
    fn decode_util_batch_dependence() {
        let m = qwen14b();
        assert!(m.decode_util(1) < m.decode_util(32));
        assert_eq!(m.decode_util(32), m.decode_util(64));
    }
}

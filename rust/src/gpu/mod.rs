//! The GPU substrate: the simulated DGX-A100 node the coordinator drives.
//!
//! The paper's testbed (8× A100, NVML app clocks) is not available here, so
//! `SimGpu` reproduces the *interface and the physics the controllers see*:
//! the 210–1410 MHz/15 MHz ladder, a cubic power curve, compute-bound
//! prefill latency and memory-bound decode latency (DESIGN.md §1).

pub mod calibrate;
pub mod control;
pub mod device;
pub mod freq;
pub mod perf;
pub mod power;

pub use calibrate::{CalibratedPart, CalibrationTable};
pub use control::{ControlPlane, WriteAction};
pub use device::SimGpu;
pub use freq::{ghz, FreqLadder};
pub use perf::{GpuHardware, PerfModel};
pub use power::PowerModel;

//! Simulated GPU device: NVML-like clock control + energy integration.
//!
//! The controllers see exactly the interface they would get from NVML
//! application clocks: `set_app_clock()` / `sm_clock()`, plus telemetry
//! (power, energy, busy time). Energy is integrated piecewise between
//! state changes, so any set_clock / set_util ordering yields exact totals.

use crate::gpu::freq::FreqLadder;
use crate::gpu::power::PowerModel;

/// One simulated GPU (an A100 by default; the heterogeneity layer builds
/// nodes from scaled power envelopes and capped frequency ladders).
#[derive(Debug, Clone)]
pub struct SimGpu {
    /// Device index within its node.
    pub id: usize,
    /// Supported application-clock ladder.
    pub ladder: FreqLadder,
    /// Power envelope (active curve + clocked-idle floor).
    pub power: PowerModel,
    freq_mhz: u32,
    util: f64,
    last_t: f64,
    energy_j: f64,
    busy_s: f64,
    /// Powered off (node failure): draws zero watts until powered on.
    off: bool,
    /// Optional (time, freq) trace for Fig. 1-style plots.
    pub record_trace: bool,
    /// The recorded (time, MHz) clock-change trace (see `record_trace`).
    pub freq_trace: Vec<(f64, u32)>,
}

impl SimGpu {
    /// A stock A100 at boost clocks.
    pub fn new(id: usize) -> Self {
        SimGpu::with_hardware(id, FreqLadder::a100(), PowerModel::a100())
    }

    /// A GPU with an explicit ladder and power envelope (heterogeneous
    /// cluster nodes). Starts at the ladder's maximum clock, idle.
    pub fn with_hardware(id: usize, ladder: FreqLadder, power: PowerModel) -> Self {
        SimGpu {
            id,
            freq_mhz: ladder.max_mhz,
            ladder,
            power,
            util: 0.0,
            last_t: 0.0,
            energy_j: 0.0,
            busy_s: 0.0,
            off: false,
            record_trace: false,
            freq_trace: Vec::new(),
        }
    }

    /// Integrate energy up to `now` under the current (freq, util, off)
    /// state. A powered-off GPU integrates zero watts.
    pub fn advance(&mut self, now: f64) {
        debug_assert!(now + 1e-9 >= self.last_t, "time went backwards");
        let dt = (now - self.last_t).max(0.0);
        if dt > 0.0 {
            if !self.off {
                self.energy_j += self.power.power_w(self.freq_mhz, self.util) * dt;
                if self.util > 0.0 {
                    self.busy_s += dt;
                }
            }
            self.last_t = now;
        }
    }

    /// Node failure at `now`: integrate up to the instant, then draw zero
    /// watts (and accumulate no busy time) until [`SimGpu::power_on`].
    pub fn power_off(&mut self, now: f64) {
        self.advance(now);
        self.off = true;
        self.util = 0.0;
    }

    /// Node recovery at `now`: resume drawing power under the current
    /// (freq, util) state from this instant.
    pub fn power_on(&mut self, now: f64) {
        self.advance(now);
        self.off = false;
    }

    /// Is the GPU powered off (its node failed)?
    pub fn is_off(&self) -> bool {
        self.off
    }

    /// NVML-style application-clock set. Every legitimate writer (the
    /// policy layer, the power arbiter, the control-plane misstep path)
    /// produces ladder clocks, so an off-ladder request here is a caller
    /// bug, caught in debug builds; release builds still snap defensively.
    pub fn set_app_clock(&mut self, now: f64, mhz: u32) {
        debug_assert!(
            self.ladder.contains(mhz),
            "off-ladder clock write: {mhz} MHz (ladder {}\u{2013}{} step {})",
            self.ladder.min_mhz,
            self.ladder.max_mhz,
            self.ladder.step_mhz
        );
        self.advance(now);
        let snapped = self.ladder.snap(mhz as f64);
        if snapped != self.freq_mhz {
            self.freq_mhz = snapped;
            if self.record_trace {
                self.freq_trace.push((now, snapped));
            }
        }
    }

    /// Set current utilization (0 = idle; prefill saturates at 1.0, decode
    /// runs lower — see `PerfModel::decode_util`).
    pub fn set_util(&mut self, now: f64, util: f64) {
        self.advance(now);
        self.util = util.clamp(0.0, 1.0);
    }

    /// Current SM application clock in MHz.
    pub fn sm_clock(&self) -> u32 {
        self.freq_mhz
    }

    /// Current utilization in [0, 1].
    pub fn util(&self) -> f64 {
        self.util
    }

    /// Instantaneous power draw in watts (zero while powered off).
    pub fn power_w(&self) -> f64 {
        if self.off {
            return 0.0;
        }
        self.power.power_w(self.freq_mhz, self.util)
    }

    /// Energy integrated since construction, joules.
    pub fn energy_j(&self) -> f64 {
        self.energy_j
    }

    /// Total time spent at non-zero utilization, seconds.
    pub fn busy_s(&self) -> f64 {
        self.busy_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_gpu_draws_idle_power() {
        let mut g = SimGpu::new(0);
        let idle = g.power.power_w(g.sm_clock(), 0.0);
        g.advance(10.0);
        assert!((g.energy_j() - idle * 10.0).abs() < 1e-9);
        assert_eq!(g.busy_s(), 0.0);
    }

    #[test]
    fn busy_interval_integrates_active_power() {
        let mut g = SimGpu::new(0);
        g.set_app_clock(0.0, 1005);
        let idle = g.power.power_w(1005, 0.0);
        g.set_util(1.0, 1.0);
        g.set_util(3.0, 0.0);
        g.advance(4.0);
        let expect = idle * 1.0 + g.power.power_w(1005, 1.0) * 2.0 + idle * 1.0;
        assert!((g.energy_j() - expect).abs() < 1e-9, "{}", g.energy_j());
        assert!((g.busy_s() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn clock_changes_mid_interval_split_energy() {
        let mut g = SimGpu::new(0);
        g.set_util(0.0, 1.0);
        g.set_app_clock(1.0, 600);
        g.advance(2.0);
        let expect = g.power.power_w(1410, 1.0) + g.power.power_w(600, 1.0);
        assert!((g.energy_j() - expect).abs() < 1e-9);
    }

    #[test]
    fn on_ladder_clock_writes_land_exactly() {
        let mut g = SimGpu::new(0);
        for mhz in [1005, 210, 1410, 615] {
            g.set_app_clock(0.0, mhz);
            assert_eq!(g.sm_clock(), mhz);
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "off-ladder clock write")]
    fn off_ladder_clock_write_is_a_caller_bug() {
        SimGpu::new(0).set_app_clock(0.0, 1000);
    }

    #[test]
    fn trace_records_changes_only() {
        let mut g = SimGpu::new(0);
        g.record_trace = true;
        g.set_app_clock(1.0, 900);
        g.set_app_clock(2.0, 900); // no-op
        g.set_app_clock(3.0, 915);
        assert_eq!(g.freq_trace, vec![(1.0, 900), (3.0, 915)]);
    }

    #[test]
    fn powered_off_gpu_draws_nothing() {
        let mut g = SimGpu::new(0);
        g.set_util(0.0, 1.0);
        g.power_off(1.0); // 1 s active at boost
        let at_failure = {
            g.advance(5.0); // 4 s dark
            g.energy_j()
        };
        assert!((at_failure - g.power.power_w(1410, 1.0)).abs() < 1e-9);
        assert_eq!(g.power_w(), 0.0);
        assert!(g.is_off());
        // Recovery resumes idle integration from the power-on instant.
        g.power_on(5.0);
        g.advance(6.0);
        let idle = g.power.power_w(1410, 0.0);
        assert!((g.energy_j() - at_failure - idle).abs() < 1e-9);
        assert!((g.busy_s() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn custom_hardware_scales_energy() {
        let eff = PowerModel::a100().scaled(0.5);
        let mut g = SimGpu::with_hardware(0, FreqLadder::a100(), eff);
        let mut base = SimGpu::new(1);
        g.set_util(0.0, 1.0);
        base.set_util(0.0, 1.0);
        g.advance(2.0);
        base.advance(2.0);
        assert!((g.energy_j() - 0.5 * base.energy_j()).abs() < 1e-9);
    }

    #[test]
    fn zero_dt_advance_is_noop() {
        let mut g = SimGpu::new(0);
        g.advance(5.0);
        let e = g.energy_j();
        g.advance(5.0);
        assert_eq!(e, g.energy_j());
    }
}

//! Simulated GPU device: NVML-like clock control + energy integration.
//!
//! The controllers see exactly the interface they would get from NVML
//! application clocks: `set_app_clock()` / `sm_clock()`, plus telemetry
//! (power, energy, busy time). Energy is integrated piecewise between
//! state changes, so any set_clock / set_util ordering yields exact totals.

use crate::gpu::freq::FreqLadder;
use crate::gpu::power::PowerModel;

/// One simulated A100.
#[derive(Debug, Clone)]
pub struct SimGpu {
    pub id: usize,
    pub ladder: FreqLadder,
    pub power: PowerModel,
    freq_mhz: u32,
    util: f64,
    last_t: f64,
    energy_j: f64,
    busy_s: f64,
    /// Optional (time, freq) trace for Fig. 1-style plots.
    pub record_trace: bool,
    pub freq_trace: Vec<(f64, u32)>,
}

impl SimGpu {
    pub fn new(id: usize) -> Self {
        let ladder = FreqLadder::a100();
        SimGpu {
            id,
            freq_mhz: ladder.max_mhz,
            ladder,
            power: PowerModel::a100(),
            util: 0.0,
            last_t: 0.0,
            energy_j: 0.0,
            busy_s: 0.0,
            record_trace: false,
            freq_trace: Vec::new(),
        }
    }

    /// Integrate energy up to `now` under the current (freq, util) state.
    pub fn advance(&mut self, now: f64) {
        debug_assert!(now + 1e-9 >= self.last_t, "time went backwards");
        let dt = (now - self.last_t).max(0.0);
        if dt > 0.0 {
            self.energy_j += self.power.power_w(self.freq_mhz, self.util) * dt;
            if self.util > 0.0 {
                self.busy_s += dt;
            }
            self.last_t = now;
        }
    }

    /// NVML-style application-clock set (snapped to the ladder).
    pub fn set_app_clock(&mut self, now: f64, mhz: u32) {
        self.advance(now);
        let snapped = self.ladder.snap(mhz as f64);
        if snapped != self.freq_mhz {
            self.freq_mhz = snapped;
            if self.record_trace {
                self.freq_trace.push((now, snapped));
            }
        }
    }

    /// Set current utilization (0 = idle; prefill saturates at 1.0, decode
    /// runs lower — see `PerfModel::decode_util`).
    pub fn set_util(&mut self, now: f64, util: f64) {
        self.advance(now);
        self.util = util.clamp(0.0, 1.0);
    }

    pub fn sm_clock(&self) -> u32 {
        self.freq_mhz
    }

    pub fn util(&self) -> f64 {
        self.util
    }

    pub fn power_w(&self) -> f64 {
        self.power.power_w(self.freq_mhz, self.util)
    }

    pub fn energy_j(&self) -> f64 {
        self.energy_j
    }

    pub fn busy_s(&self) -> f64 {
        self.busy_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_gpu_draws_idle_power() {
        let mut g = SimGpu::new(0);
        let idle = g.power.power_w(g.sm_clock(), 0.0);
        g.advance(10.0);
        assert!((g.energy_j() - idle * 10.0).abs() < 1e-9);
        assert_eq!(g.busy_s(), 0.0);
    }

    #[test]
    fn busy_interval_integrates_active_power() {
        let mut g = SimGpu::new(0);
        g.set_app_clock(0.0, 1005);
        let idle = g.power.power_w(1005, 0.0);
        g.set_util(1.0, 1.0);
        g.set_util(3.0, 0.0);
        g.advance(4.0);
        let expect = idle * 1.0 + g.power.power_w(1005, 1.0) * 2.0 + idle * 1.0;
        assert!((g.energy_j() - expect).abs() < 1e-9, "{}", g.energy_j());
        assert!((g.busy_s() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn clock_changes_mid_interval_split_energy() {
        let mut g = SimGpu::new(0);
        g.set_util(0.0, 1.0);
        g.set_app_clock(1.0, 600);
        g.advance(2.0);
        let expect = g.power.power_w(1410, 1.0) + g.power.power_w(600, 1.0);
        assert!((g.energy_j() - expect).abs() < 1e-9);
    }

    #[test]
    fn clock_snaps_to_ladder() {
        let mut g = SimGpu::new(0);
        g.set_app_clock(0.0, 1000);
        assert_eq!(g.sm_clock(), 1005);
        g.set_app_clock(0.0, 100);
        assert_eq!(g.sm_clock(), 210);
    }

    #[test]
    fn trace_records_changes_only() {
        let mut g = SimGpu::new(0);
        g.record_trace = true;
        g.set_app_clock(1.0, 900);
        g.set_app_clock(2.0, 900); // no-op
        g.set_app_clock(3.0, 915);
        assert_eq!(g.freq_trace, vec![(1.0, 900), (3.0, 915)]);
    }

    #[test]
    fn zero_dt_advance_is_noop() {
        let mut g = SimGpu::new(0);
        g.advance(5.0);
        let e = g.energy_j();
        g.advance(5.0);
        assert_eq!(e, g.energy_j());
    }
}

//! The SM frequency ladder — A100 application clocks.
//!
//! NVML application clocks on the A100 expose SM frequencies from 210 MHz
//! to 1410 MHz in 15 MHz steps (81 points); GreenLLM's controllers only
//! ever request ladder frequencies (the paper's fine loop moves in exactly
//! one 15 MHz step per 20 ms tick).

/// Discrete SM frequency ladder in MHz.
#[derive(Debug, Clone, PartialEq)]
pub struct FreqLadder {
    /// Lowest application clock, MHz.
    pub min_mhz: u32,
    /// Highest application clock, MHz.
    pub max_mhz: u32,
    /// Ladder step, MHz.
    pub step_mhz: u32,
}

impl Default for FreqLadder {
    fn default() -> Self {
        FreqLadder::a100()
    }
}

impl FreqLadder {
    /// A100-SXM4: 210–1410 MHz, 15 MHz application-clock steps.
    pub fn a100() -> Self {
        FreqLadder {
            min_mhz: 210,
            max_mhz: 1410,
            step_mhz: 15,
        }
    }

    /// Number of ladder points.
    pub fn len(&self) -> usize {
        ((self.max_mhz - self.min_mhz) / self.step_mhz) as usize + 1
    }

    /// A ladder always has at least one point.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Snap an arbitrary frequency to the nearest ladder point (clamped).
    pub fn snap(&self, mhz: f64) -> u32 {
        let clamped = mhz.clamp(self.min_mhz as f64, self.max_mhz as f64);
        let steps = ((clamped - self.min_mhz as f64) / self.step_mhz as f64).round() as u32;
        self.min_mhz + steps * self.step_mhz
    }

    /// Snap *up*: smallest ladder frequency >= mhz (clamped to max).
    pub fn snap_up(&self, mhz: f64) -> u32 {
        let clamped = mhz.clamp(self.min_mhz as f64, self.max_mhz as f64);
        let steps = ((clamped - self.min_mhz as f64) / self.step_mhz as f64).ceil() as u32;
        self.min_mhz + steps * self.step_mhz
    }

    /// Snap *down*: largest ladder frequency <= mhz (clamped to min).
    /// This is the safe direction for power caps: the snapped clock never
    /// exceeds the requested ceiling.
    pub fn snap_down(&self, mhz: f64) -> u32 {
        let clamped = mhz.clamp(self.min_mhz as f64, self.max_mhz as f64);
        let steps = ((clamped - self.min_mhz as f64) / self.step_mhz as f64).floor() as u32;
        self.min_mhz + steps * self.step_mhz
    }

    /// One fine step up/down from `mhz`, clamped to [lo, hi] band bounds.
    /// A band that is empty after intersecting the ladder (lo > hi, e.g. a
    /// cap below 210 MHz on a calibrated part) pins to the band ceiling —
    /// never above the cap — raised to the ladder floor, rather than
    /// panicking in `clamp`.
    pub fn step(&self, mhz: u32, up: bool, lo: u32, hi: u32) -> u32 {
        let lo_b = lo.max(self.min_mhz);
        let hi_b = hi.min(self.max_mhz);
        if lo_b > hi_b {
            return self.min_mhz.max(hi_b);
        }
        let next = if up {
            mhz.saturating_add(self.step_mhz)
        } else {
            mhz.saturating_sub(self.step_mhz)
        };
        next.clamp(lo_b, hi_b)
    }

    /// Iterate every ladder frequency (profiling sweeps).
    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        (0..self.len() as u32).map(move |i| self.min_mhz + i * self.step_mhz)
    }

    /// Index of a ladder frequency (None if off-ladder).
    pub fn index_of(&self, mhz: u32) -> Option<usize> {
        if mhz < self.min_mhz || mhz > self.max_mhz {
            return None;
        }
        let off = mhz - self.min_mhz;
        (off % self.step_mhz == 0).then(|| (off / self.step_mhz) as usize)
    }

    /// Is `mhz` exactly on the ladder?
    pub fn contains(&self, mhz: u32) -> bool {
        self.index_of(mhz).is_some()
    }
}

/// MHz → GHz (the power polynomial is parameterized in GHz).
#[inline]
pub fn ghz(mhz: u32) -> f64 {
    mhz as f64 / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_ladder_has_81_points() {
        let l = FreqLadder::a100();
        assert_eq!(l.len(), 81);
        assert_eq!(l.iter().next(), Some(210));
        assert_eq!(l.iter().last(), Some(1410));
    }

    #[test]
    fn snap_rounds_and_clamps() {
        let l = FreqLadder::a100();
        assert_eq!(l.snap(0.0), 210);
        assert_eq!(l.snap(5000.0), 1410);
        assert_eq!(l.snap(1000.0), 1005);
        assert_eq!(l.snap(997.0), 990);
        assert_eq!(l.snap(998.0), 1005);
    }

    #[test]
    fn snap_up_never_below_target() {
        let l = FreqLadder::a100();
        for f in [211.0, 970.2, 1409.9, 250.0] {
            let s = l.snap_up(f);
            assert!(s as f64 >= f, "snap_up({f}) = {s}");
            assert!(l.contains(s));
        }
        assert_eq!(l.snap_up(2000.0), 1410);
    }

    #[test]
    fn step_respects_band_bounds() {
        let l = FreqLadder::a100();
        assert_eq!(l.step(900, true, 600, 915), 915);
        assert_eq!(l.step(915, true, 600, 915), 915); // pinned at hi
        assert_eq!(l.step(615, false, 600, 915), 600);
        assert_eq!(l.step(600, false, 600, 915), 600); // pinned at lo
    }

    #[test]
    fn index_roundtrip() {
        let l = FreqLadder::a100();
        for (i, f) in l.iter().enumerate() {
            assert_eq!(l.index_of(f), Some(i));
        }
        assert_eq!(l.index_of(1000), None);
        assert_eq!(l.index_of(209), None);
        assert_eq!(l.index_of(1425), None);
    }

    #[test]
    fn all_ladder_points_are_snap_fixed_points() {
        let l = FreqLadder::a100();
        for f in l.iter() {
            assert_eq!(l.snap(f as f64), f);
            assert_eq!(l.snap_up(f as f64), f);
            assert_eq!(l.snap_down(f as f64), f);
        }
    }

    #[test]
    fn snap_ties_round_up_pinned() {
        // Exactly halfway between two rungs: `round()` is half-away-from-
        // zero, and the normalized step count is always positive, so ties
        // go UP. Pinned so calibrated ladders can rely on the direction.
        let l = FreqLadder::a100();
        assert_eq!(l.snap(997.5), 1005);
        assert_eq!(l.snap(217.5), 225);
        assert_eq!(l.snap(1402.5), 1410);
    }

    #[test]
    fn sub_floor_and_over_ceiling_requests_clamp() {
        // Sub-210 MHz requests (an aggressive governor on a calibrated
        // part) clamp to the floor in every snap direction; over-ceiling
        // requests clamp to the part's own max, not a100's.
        for l in [
            FreqLadder::a100(),
            FreqLadder {
                min_mhz: 210,
                max_mhz: 1980,
                step_mhz: 15,
            },
        ] {
            for f in [-50.0, 0.0, 150.0, 209.9] {
                assert_eq!(l.snap(f), 210);
                assert_eq!(l.snap_up(f), 210);
                assert_eq!(l.snap_down(f), 210);
            }
            let over = l.max_mhz as f64 + 100.0;
            assert_eq!(l.snap(over), l.max_mhz);
            assert_eq!(l.snap_down(over), l.max_mhz);
        }
    }

    #[test]
    fn snap_down_never_above_target() {
        let l = FreqLadder::a100();
        for f in [211.0, 970.2, 1409.9, 250.0, 1004.99] {
            let s = l.snap_down(f);
            assert!(s as f64 <= f, "snap_down({f}) = {s}");
            assert!(l.contains(s));
        }
    }

    #[test]
    fn step_survives_degenerate_bands() {
        let l = FreqLadder::a100();
        // Cap entirely below the ladder floor: pin at the floor.
        assert_eq!(l.step(210, false, 0, 100), 210);
        assert_eq!(l.step(210, true, 0, 100), 210);
        // Inverted band (lo > hi): pin at the band ceiling.
        assert_eq!(l.step(900, false, 900, 600), 600);
        // Band entirely above the ladder: pin at the ladder max.
        assert_eq!(l.step(1410, true, 2000, 3000), 1410);
    }

    #[test]
    fn h100_ladder_has_119_points() {
        let l = FreqLadder {
            min_mhz: 210,
            max_mhz: 1980,
            step_mhz: 15,
        };
        assert_eq!(l.len(), 119);
        assert_eq!(l.iter().last(), Some(1980));
        assert_eq!(l.snap(1500.0), 1500);
        assert!(l.contains(1980) && !l.contains(1981));
    }
}

//! The faultable control-plane boundary between governors and GPUs.
//!
//! Real deployments drive DVFS through an NVML-shaped interface whose
//! writes are neither instant nor reliable, and whose sensors are neither
//! fresh nor exact (Maliakel et al., arXiv 2501.08219 measure both on
//! A100/H100 parts; AGFT, arXiv 2508.01744, shows how sensitive feedback
//! governors are to exactly this). [`ControlPlane`] models that boundary
//! for one node:
//!
//! * **Actuation** — every policy clock write passes through
//!   [`ControlPlane::gate_write`], which can silently drop it, snap it to
//!   an adjacent ladder rung (misstep), or defer it by a configured
//!   latency (the engine schedules the deferred apply; a newer write to
//!   the same worker supersedes it via a per-GPU sequence number).
//! * **Sensing** — the cluster power arbiter and supervisor read
//!   telemetry through `sense_*` adapters that can quantize values or
//!   freeze them at their blackout-entry snapshot while a scheduled
//!   telemetry blackout is in force. Event-driven policy feedback
//!   (TBT/token/backlog callbacks) is suppressed entirely during a
//!   blackout — the engine counts each suppressed delivery here.
//!
//! With `noise` off and no blackout the plane is transparent: writes pass
//! through untouched, senses return their raw argument, and the RNG is
//! never consumed — the engine's behaviour is bit-exact with the
//! pre-control-plane loop (property-tested in `cluster_invariants`).

use crate::config::CtlSection;
use crate::gpu::freq::FreqLadder;
use crate::util::rng::Pcg64;

/// What the control plane decided about one clock write.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WriteAction {
    /// Apply this (possibly misstepped) clock now.
    Apply(u32),
    /// The write was silently lost.
    Drop,
    /// Apply `mhz` at `apply_at`; the engine must schedule it and check
    /// `seq` against [`ControlPlane::write_is_current`] on delivery so a
    /// newer write to the same worker supersedes the stale one.
    Delay {
        /// The clock to land (post-misstep), MHz.
        mhz: u32,
        /// Virtual time at which the write takes effect.
        apply_at: f64,
        /// Supersession ticket for this worker's write stream.
        seq: u64,
    },
}

/// Per-node faultable actuation/sensing boundary. Owned by the serving
/// engine; the cluster fault layer toggles its runtime state through the
/// `ctlnoise`/`ctlquiet`/`ctlblackout`/`ctlsense` verbs.
#[derive(Debug, Clone)]
pub struct ControlPlane {
    /// Config baseline, restored on node recovery.
    cfg: CtlSection,
    noise: bool,
    blackout: bool,
    delay_s: f64,
    drop_prob: f64,
    misstep_prob: f64,
    quantize: f64,
    rng: Pcg64,
    /// Monotone write ticket per first-GPU index; a delayed write applies
    /// only if its ticket is still the latest for that worker.
    seq: Vec<u64>,
    frozen_tail_s: f64,
    frozen_pressure: f64,
    frozen_power_w: Option<f64>,
    /// Writes silently dropped by the noise path.
    pub dropped_writes: u64,
    /// Writes deferred by actuation latency.
    pub delayed_writes: u64,
    /// Writes that landed on an adjacent ladder rung.
    pub missteps: u64,
    /// Policy feedback deliveries suppressed during blackouts.
    pub suppressed_samples: u64,
}

impl ControlPlane {
    /// A plane for a node with `gpus` GPUs, seeded deterministically.
    pub fn new(cfg: &CtlSection, seed: u64, gpus: usize) -> ControlPlane {
        ControlPlane {
            noise: cfg.noise,
            blackout: false,
            delay_s: cfg.delay_s,
            drop_prob: cfg.drop_prob,
            misstep_prob: cfg.misstep_prob,
            quantize: cfg.quantize,
            cfg: cfg.clone(),
            rng: Pcg64::new(seed, 0xC712),
            seq: vec![0; gpus],
            frozen_tail_s: 0.0,
            frozen_pressure: 0.0,
            frozen_power_w: None,
            dropped_writes: 0,
            delayed_writes: 0,
            missteps: 0,
            suppressed_samples: 0,
        }
    }

    /// Is the actuation noise path active right now?
    pub fn noise_active(&self) -> bool {
        self.noise
    }

    /// Is a telemetry blackout in force right now?
    pub fn blackout(&self) -> bool {
        self.blackout
    }

    /// Gate one clock write for the worker span starting at `first_gpu`.
    /// Always bumps the worker's write ticket (so any pending delayed
    /// write is superseded), but consumes RNG only while noise is on.
    pub fn gate_write(
        &mut self,
        t: f64,
        first_gpu: usize,
        mhz: u32,
        ladder: &FreqLadder,
    ) -> WriteAction {
        self.seq[first_gpu] = self.seq[first_gpu].wrapping_add(1);
        if !self.noise {
            return WriteAction::Apply(mhz);
        }
        if self.drop_prob > 0.0 && self.rng.f64() < self.drop_prob {
            self.dropped_writes += 1;
            return WriteAction::Drop;
        }
        let mut out = mhz;
        if self.misstep_prob > 0.0 && self.rng.f64() < self.misstep_prob {
            let up = self.rng.f64() < 0.5;
            out = ladder.step(mhz, up, ladder.min_mhz, ladder.max_mhz);
            if out != mhz {
                self.missteps += 1;
            }
        }
        if self.delay_s > 0.0 {
            self.delayed_writes += 1;
            WriteAction::Delay {
                mhz: out,
                apply_at: t + self.delay_s,
                seq: self.seq[first_gpu],
            }
        } else {
            WriteAction::Apply(out)
        }
    }

    /// Is a delayed write's ticket still the latest for its worker?
    pub fn write_is_current(&self, first_gpu: usize, seq: u64) -> bool {
        self.seq[first_gpu] == seq
    }

    /// Invalidate every in-flight delayed write (node failure: the queue
    /// is rebuilt, pending applies must not land on the recovered node).
    pub fn invalidate_pending(&mut self) {
        for s in self.seq.iter_mut() {
            *s = s.wrapping_add(1);
        }
    }

    /// `ctlnoise` verb: switch actuation noise on with these parameters.
    pub fn noise_on(&mut self, delay_s: f64, drop_prob: f64, misstep_prob: f64) {
        self.noise = true;
        self.delay_s = delay_s;
        self.drop_prob = drop_prob;
        self.misstep_prob = misstep_prob;
    }

    /// `ctlquiet` verb: actuation returns to the ideal instant path.
    pub fn noise_off(&mut self) {
        self.noise = false;
    }

    /// `ctlblackout` verb: freeze sensed telemetry at the values sampled
    /// now and suppress event-driven policy feedback until
    /// [`ControlPlane::blackout_off`].
    pub fn blackout_on(&mut self, tail_s: f64, pressure: f64) {
        self.blackout = true;
        self.frozen_tail_s = tail_s;
        self.frozen_pressure = pressure;
        self.frozen_power_w = None;
    }

    /// `ctlsense` verb: sensors come back; feedback flows again.
    pub fn blackout_off(&mut self) {
        self.blackout = false;
        self.frozen_power_w = None;
    }

    /// Node recovery: back to the config baseline (runtime verb overlays
    /// cleared, cumulative counters kept).
    pub fn reset_to_config(&mut self) {
        self.noise = self.cfg.noise;
        self.delay_s = self.cfg.delay_s;
        self.drop_prob = self.cfg.drop_prob;
        self.misstep_prob = self.cfg.misstep_prob;
        self.blackout = false;
        self.frozen_power_w = None;
        self.invalidate_pending();
    }

    /// Count one policy feedback delivery suppressed by a blackout.
    pub fn note_suppressed(&mut self) {
        self.suppressed_samples += 1;
    }

    /// Sensed decode-tail P95 (seconds): frozen during blackouts,
    /// quantized to the `quantize`-millisecond grid under noise, exact
    /// otherwise.
    pub fn sense_tail(&self, raw_s: f64) -> f64 {
        if self.blackout {
            self.frozen_tail_s
        } else {
            self.quantized(raw_s, self.quantize * 1e-3)
        }
    }

    /// Sensed prefill backlog pressure (seconds of backlog): frozen
    /// during blackouts, quantized like a latency sensor under noise.
    pub fn sense_pressure(&self, raw: f64) -> f64 {
        if self.blackout {
            self.frozen_pressure
        } else {
            self.quantized(raw, self.quantize * 1e-3)
        }
    }

    /// Sensed node power (watts): during a blackout the first reading is
    /// frozen and repeated (a stuck sensor), otherwise quantized to the
    /// `quantize`-watt grid under noise, exact without it.
    pub fn sense_power(&mut self, raw_w: f64) -> f64 {
        if self.blackout {
            let q = self.quantized(raw_w, self.quantize);
            *self.frozen_power_w.get_or_insert(q)
        } else {
            self.quantized(raw_w, self.quantize)
        }
    }

    fn quantized(&self, v: f64, step: f64) -> f64 {
        if self.noise && step > 0.0 {
            (v / step).round() * step
        } else {
            v
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plane(section: impl FnOnce(&mut CtlSection)) -> ControlPlane {
        let mut s = CtlSection::default();
        section(&mut s);
        ControlPlane::new(&s, 7, 8)
    }

    #[test]
    fn transparent_when_noise_off() {
        let mut p = plane(|_| {});
        let ladder = FreqLadder::a100();
        for (i, mhz) in [900, 1410, 210, 615].into_iter().enumerate() {
            assert_eq!(
                p.gate_write(i as f64, 0, mhz, &ladder),
                WriteAction::Apply(mhz)
            );
        }
        assert_eq!(p.sense_tail(0.1234), 0.1234);
        assert_eq!(p.sense_power(417.3), 417.3);
        assert_eq!(p.dropped_writes + p.delayed_writes + p.missteps, 0);
        // No RNG consumed: a twin plane that went through noise draws has
        // diverged, the quiet one has not.
        let mut q = plane(|_| {});
        for (i, mhz) in [900, 1410, 210, 615].into_iter().enumerate() {
            q.gate_write(i as f64, 0, mhz, &ladder);
        }
        assert_eq!(p.rng.next_u64(), q.rng.next_u64());
    }

    #[test]
    fn zero_prob_noise_is_also_transparent() {
        // noise=true with all-zero parameters must behave identically to
        // noise=false (and consume no RNG) — the verbs can arm the path
        // with trivial parameters.
        let mut p = plane(|s| s.noise = true);
        let ladder = FreqLadder::a100();
        assert_eq!(p.gate_write(1.0, 2, 990, &ladder), WriteAction::Apply(990));
        assert_eq!(p.sense_tail(0.05), 0.05);
        let mut q = plane(|_| {});
        q.gate_write(1.0, 2, 990, &ladder);
        assert_eq!(p.rng.next_u64(), q.rng.next_u64());
    }

    #[test]
    fn drops_and_delays_are_deterministic_per_seed() {
        let run = || {
            let mut p = plane(|s| {
                s.noise = true;
                s.delay_s = 0.05;
                s.drop_prob = 0.3;
                s.misstep_prob = 0.3;
            });
            let ladder = FreqLadder::a100();
            (0..200)
                .map(|i| p.gate_write(i as f64 * 0.02, i % 8, 900, &ladder))
                .collect::<Vec<_>>()
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b);
        assert!(a.iter().any(|w| *w == WriteAction::Drop));
        assert!(a
            .iter()
            .any(|w| matches!(w, WriteAction::Delay { mhz, .. } if *mhz != 900)));
    }

    #[test]
    fn delayed_writes_land_on_ladder_at_t_plus_delay() {
        let mut p = plane(|s| {
            s.noise = true;
            s.delay_s = 0.1;
            s.misstep_prob = 1.0;
        });
        let ladder = FreqLadder::a100();
        for i in 0..50 {
            match p.gate_write(2.0, i % 8, 900, &ladder) {
                WriteAction::Delay { mhz, apply_at, .. } => {
                    assert!(ladder.contains(mhz), "off-ladder misstep {mhz}");
                    assert!((mhz as i64 - 900i64).unsigned_abs() as u32 <= ladder.step_mhz);
                    assert_eq!(apply_at, 2.1);
                }
                other => panic!("expected a delayed write, got {other:?}"),
            }
        }
        assert_eq!(p.delayed_writes, 50);
        assert!(p.missteps > 0);
    }

    #[test]
    fn newer_write_supersedes_pending_delayed_write() {
        let mut p = plane(|s| {
            s.noise = true;
            s.delay_s = 0.2;
        });
        let ladder = FreqLadder::a100();
        let first = p.gate_write(1.0, 3, 600, &ladder);
        let WriteAction::Delay { seq: s1, .. } = first else {
            panic!("expected delay")
        };
        assert!(p.write_is_current(3, s1));
        let WriteAction::Delay { seq: s2, .. } = p.gate_write(1.05, 3, 900, &ladder) else {
            panic!("expected delay")
        };
        assert!(!p.write_is_current(3, s1), "stale write must be superseded");
        assert!(p.write_is_current(3, s2));
        // Other workers' tickets are untouched.
        let WriteAction::Delay { seq: s0, .. } = p.gate_write(1.1, 0, 900, &ladder) else {
            panic!("expected delay")
        };
        assert!(p.write_is_current(0, s0));
        p.invalidate_pending();
        assert!(!p.write_is_current(0, s0) && !p.write_is_current(3, s2));
    }

    #[test]
    fn blackout_freezes_senses_and_reset_restores_config() {
        let mut p = plane(|s| s.noise = true);
        p.blackout_on(0.150, 2.5);
        assert!(p.blackout());
        assert_eq!(p.sense_tail(0.010), 0.150);
        assert_eq!(p.sense_pressure(0.0), 2.5);
        // Stuck power sensor: first in-blackout reading repeats.
        assert_eq!(p.sense_power(400.0), 400.0);
        assert_eq!(p.sense_power(900.0), 400.0);
        p.blackout_off();
        assert_eq!(p.sense_tail(0.010), 0.010);
        assert_eq!(p.sense_power(900.0), 900.0);
        // Recovery restores the config baseline (noise off here).
        let mut q = plane(|_| {});
        q.noise_on(0.1, 0.5, 0.5);
        q.blackout_on(1.0, 1.0);
        q.reset_to_config();
        assert!(!q.noise_active() && !q.blackout());
    }

    #[test]
    fn quantize_grids_power_and_latency_senses() {
        let mut p = plane(|s| {
            s.noise = true;
            s.quantize = 25.0; // 25 W / 25 ms grids
        });
        assert_eq!(p.sense_power(417.3), 425.0);
        assert_eq!(p.sense_tail(0.0171), 0.025);
        assert_eq!(p.sense_pressure(0.004), 0.0);
        // Quantization is part of the noise path: off → exact.
        p.noise_off();
        assert_eq!(p.sense_power(417.3), 417.3);
    }
}

//! Calibrated GPU model zoo: published latency/power-vs-SM-frequency
//! sample tables for real parts, fitted into the simulator's compact
//! per-phase models at startup.
//!
//! The seed `PerfModel`/`PowerModel` curves are analytic guesses; this
//! module replaces them with models *fitted to cited characterization
//! data* through the same [`crate::util::polyfit`] machinery GreenLLM
//! uses online (Eq. 2 / Eq. 7):
//!
//! * **power** — cubic `P(f) = k₀ + k₁f + k₂f² + k₃f³` over GHz, fitted
//!   to measured full-utilization power samples (Fig. 8 method);
//! * **prefill** — the compute-bound frequency response
//!   `t(f) = t_ref · (m + (1−m) · f_ref/f)`, fitted as a line in
//!   `x = f_ref/f`; the intercept share `m` is the phase's memory-bound
//!   fraction (≈0 for prefill);
//! * **decode** — the same line at a reference `(batch, context)` point;
//!   its much larger intercept share is what makes decode memory-bound
//!   and phase-specific DVFS worthwhile (DualScale, arXiv 2602.18755).
//!
//! Sample tables follow the energy-performance characterization of
//! Maliakel et al. (arXiv 2501.08219), which sweeps A100/H100 application
//! clocks and reports the latency/power envelopes these tables reproduce
//! (rounded to measurement precision: 0.1 W, 10 µs).
//!
//! Every fit is gated by hard quality checks — R² ≥ [`R2_MIN`], max
//! relative residual ≤ [`RESID_MAX`], strict monotonicity across the
//! part's full frequency ladder, finite coefficients — and a table that
//! fails any check refuses to calibrate with a descriptive error. The
//! process-wide [`zoo`] panics on a bad embedded table: a silently
//! mis-calibrated part would invalidate every downstream result.

use crate::gpu::freq::{ghz, FreqLadder};
use crate::gpu::perf::{GpuHardware, PerfModel};
use crate::gpu::power::PowerModel;
use crate::model::ModelSpec;
use crate::util::polyfit::{polyfit, polyval};
use crate::util::stats::{max_rel_err, r_squared};
use std::sync::OnceLock;

/// Minimum coefficient of determination a calibration fit must reach.
pub const R2_MIN: f64 = 0.98;
/// Maximum relative residual |fit − sample| / sample a fit may leave.
pub const RESID_MAX: f64 = 0.02;

// ---------------------------------------------------------------------------
// Embedded sample tables (arXiv 2501.08219 envelopes, rounded to
// measurement precision). Frequencies lie on each part's ladder grid.
// ---------------------------------------------------------------------------

const A100_FREQ_MHZ: [f64; 17] = [
    210.0, 285.0, 360.0, 435.0, 510.0, 585.0, 660.0, 735.0, 810.0, 885.0, 960.0, 1035.0, 1110.0,
    1185.0, 1260.0, 1335.0, 1410.0,
];
const A100_POWER_W: [f64; 17] = [
    195.8, 198.4, 201.7, 205.9, 211.0, 217.3, 225.1, 234.4, 245.5, 258.6, 273.9, 291.5, 311.7,
    334.6, 360.5, 389.5, 421.8,
];
const A100_PREFILL_S: [f64; 17] = [
    1.31976, 0.97459, 0.77325, 0.64133, 0.54822, 0.47898, 0.42547, 0.38289, 0.34819, 0.31937,
    0.29506, 0.27426, 0.25628, 0.24058, 0.22674, 0.21446, 0.20349,
];
const A100_DECODE_S: [f64; 17] = [
    0.11819, 0.09511, 0.08164, 0.07282, 0.06660, 0.06197, 0.05839, 0.05554, 0.05322, 0.05129,
    0.04967, 0.04828, 0.04707, 0.04602, 0.04510, 0.04428, 0.04354,
];

const H100_FREQ_MHZ: [f64; 13] = [
    210.0, 360.0, 510.0, 660.0, 810.0, 960.0, 1110.0, 1260.0, 1410.0, 1560.0, 1710.0, 1860.0,
    1980.0,
];
const H100_POWER_W: [f64; 13] = [
    161.9, 171.0, 182.1, 196.7, 216.1, 241.9, 275.4, 318.0, 371.2, 436.3, 514.9, 608.3, 694.6,
];
const H100_PREFILL_S: [f64; 13] = [
    0.56713, 0.33241, 0.23577, 0.18305, 0.14986, 0.12704, 0.11039, 0.09770, 0.08771, 0.07964,
    0.07299, 0.06741, 0.06356,
];
const H100_DECODE_S: [f64; 13] = [
    0.10507, 0.06934, 0.05463, 0.04661, 0.04156, 0.03808, 0.03555, 0.03362, 0.03210, 0.03087,
    0.02986, 0.02901, 0.02842,
];

/// One published characterization table for a real GPU part: the raw
/// samples the zoo fits its compact models from. All three sample series
/// are indexed by `freqs_mhz` and measured on the repo's 14B-class
/// reference workload (see [`CalibrationTable::a100`]).
#[derive(Debug, Clone)]
pub struct CalibrationTable {
    /// Zoo key and `NodeSpec` preset name (`"a100"`, `"h100"`).
    pub part: String,
    /// Source of the sample data.
    pub citation: String,
    /// Lowest application clock of the part, MHz.
    pub min_mhz: u32,
    /// Highest application clock (and model reference clock `f_ref`), MHz.
    pub max_mhz: u32,
    /// Application-clock ladder step, MHz.
    pub step_mhz: u32,
    /// Peak dense BF16 throughput, FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, B/s.
    pub hbm_bw: f64,
    /// Measured idle power at the lowest clock, watts.
    pub idle_base_w: f64,
    /// Measured idle-power slope with clock, W/GHz.
    pub idle_slope_w_per_ghz: f64,
    /// Sampled SM frequencies, MHz (ascending, on the ladder grid).
    pub freqs_mhz: Vec<f64>,
    /// Full-utilization (saturating prefill) power at each frequency, W.
    pub power_w: Vec<f64>,
    /// Prefill latency of a `prefill_ref_len`-token prompt at each
    /// frequency, seconds.
    pub prefill_s: Vec<f64>,
    /// Prompt length of the prefill samples, tokens.
    pub prefill_ref_len: usize,
    /// Decode step time at `(decode_ref_batch, decode_ref_ctx)` at each
    /// frequency, seconds.
    pub decode_s: Vec<f64>,
    /// Batch size of the decode samples, streams.
    pub decode_ref_batch: usize,
    /// Mean context length of the decode samples, tokens.
    pub decode_ref_ctx: f64,
}

impl CalibrationTable {
    /// A100-SXM4-40GB: the paper's testbed part. 210–1410 MHz ladder in
    /// 15 MHz steps; samples follow the arXiv 2501.08219 A100 envelope
    /// (~196 W active floor, ~422 W at boost, idle spreading ~53→101 W
    /// across the ladder) on the 14B-class reference workload.
    pub fn a100() -> CalibrationTable {
        CalibrationTable {
            part: "a100".into(),
            citation: "Maliakel et al., arXiv 2501.08219 (A100 SM-frequency sweep)".into(),
            min_mhz: 210,
            max_mhz: 1410,
            step_mhz: 15,
            peak_flops: 312e12,
            hbm_bw: 1.555e12,
            idle_base_w: 45.0,
            idle_slope_w_per_ghz: 40.0,
            freqs_mhz: A100_FREQ_MHZ.to_vec(),
            power_w: A100_POWER_W.to_vec(),
            prefill_s: A100_PREFILL_S.to_vec(),
            prefill_ref_len: 1024,
            decode_s: A100_DECODE_S.to_vec(),
            decode_ref_batch: 16,
            decode_ref_ctx: 600.0,
        }
    }

    /// H100-SXM5-80GB: 210–1980 MHz ladder in 15 MHz steps, HBM3 at
    /// 3.35 TB/s, ~695 W at boost. The sample grid is non-uniform (150 MHz
    /// spacing plus the 1980 MHz boost point) — the fits do not require
    /// uniform spacing, only on-ladder ascending frequencies.
    pub fn h100() -> CalibrationTable {
        CalibrationTable {
            part: "h100".into(),
            citation: "Maliakel et al., arXiv 2501.08219 (H100 SM-frequency sweep)".into(),
            min_mhz: 210,
            max_mhz: 1980,
            step_mhz: 15,
            peak_flops: 989e12,
            hbm_bw: 3.35e12,
            idle_base_w: 55.0,
            idle_slope_w_per_ghz: 45.0,
            freqs_mhz: H100_FREQ_MHZ.to_vec(),
            power_w: H100_POWER_W.to_vec(),
            prefill_s: H100_PREFILL_S.to_vec(),
            prefill_ref_len: 1024,
            decode_s: H100_DECODE_S.to_vec(),
            decode_ref_batch: 16,
            decode_ref_ctx: 600.0,
        }
    }

    /// Every embedded table, in zoo order.
    pub fn all() -> Vec<CalibrationTable> {
        vec![CalibrationTable::a100(), CalibrationTable::h100()]
    }

    /// The part's full frequency ladder.
    pub fn ladder(&self) -> FreqLadder {
        FreqLadder {
            min_mhz: self.min_mhz,
            max_mhz: self.max_mhz,
            step_mhz: self.step_mhz,
        }
    }
}

/// Quality metrics of one calibration fit (reported per phase so tests
/// and `greenllm validate --json` can surface them).
#[derive(Debug, Clone, Copy)]
pub struct FitQuality {
    /// Coefficient of determination against the samples.
    pub r2: f64,
    /// Max relative residual |fit − sample| / sample.
    pub max_rel_resid: f64,
}

/// Fit quality of all three calibrated curves.
#[derive(Debug, Clone, Copy)]
pub struct FitReport {
    /// Active-power cubic fit.
    pub power: FitQuality,
    /// Prefill frequency-response fit.
    pub prefill: FitQuality,
    /// Decode frequency-response fit.
    pub decode: FitQuality,
}

/// A zoo part with its fitted models: everything the engine needs to
/// stand up a node on calibrated hardware.
#[derive(Debug, Clone)]
pub struct CalibratedPart {
    /// Zoo key (`"a100"`, `"h100"`).
    pub name: String,
    /// Source of the sample data.
    pub citation: String,
    /// The part's application-clock ladder.
    pub ladder: FreqLadder,
    /// Hardware envelope (peak FLOPs, HBM bandwidth, reference clock).
    pub hw: GpuHardware,
    /// Fitted power model (active cubic + measured idle floor).
    pub power: PowerModel,
    /// Fitted prefill memory-bound fraction `m` (intercept share).
    pub prefill_mem_frac: f64,
    /// Measured reference-prompt prefill latency at `f_ref`, seconds.
    pub prefill_t_ref_s: f64,
    /// Prompt length of the prefill reference, tokens.
    pub prefill_ref_len: usize,
    /// Level factor applied to the analytic prefill MFU so the calibrated
    /// model reproduces `prefill_t_ref_s` on the reference spec.
    pub prefill_mfu_factor: f64,
    /// Fitted decode memory-bound fraction at the reference point.
    pub decode_mem_frac: f64,
    /// Scale on the analytic decode memory-bound component.
    pub decode_mem_scale: f64,
    /// Scale on the analytic decode compute-bound component.
    pub decode_cmp_scale: f64,
    /// Fit quality of the three calibrated curves.
    pub fit: FitReport,
}

impl CalibratedPart {
    /// Build the per-phase latency model for `spec` on this part: the
    /// analytic batch/length scaling of [`PerfModel`], re-leveled and
    /// re-shaped by the calibration (hardware envelope, prefill MFU
    /// factor and memory fraction, decode component scales). The level
    /// factors are derived against the 14B-class reference spec the
    /// tables were measured on and applied uniformly to other specs.
    pub fn perf_model(&self, spec: ModelSpec) -> PerfModel {
        let mut m = PerfModel::new(spec);
        m.hw = self.hw.clone();
        m.prefill_mfu *= self.prefill_mfu_factor;
        m.prefill_mem_frac = self.prefill_mem_frac;
        m.decode_mem_scale = self.decode_mem_scale;
        m.decode_cmp_scale = self.decode_cmp_scale;
        m
    }
}

// ---------------------------------------------------------------------------
// Fitting
// ---------------------------------------------------------------------------

fn check_fit(what: &str, part: &str, coeffs: &[f64], q: FitQuality) -> Result<(), String> {
    if coeffs.iter().any(|c| !c.is_finite()) {
        return Err(format!("{part}: {what} fit produced non-finite coefficients {coeffs:?}"));
    }
    if !(q.r2.is_finite() && q.r2 >= R2_MIN) {
        return Err(format!("{part}: {what} fit R² {:.4} below the {R2_MIN} gate", q.r2));
    }
    if !(q.max_rel_resid.is_finite() && q.max_rel_resid <= RESID_MAX) {
        return Err(format!(
            "{part}: {what} fit max relative residual {:.4} above the {RESID_MAX} gate",
            q.max_rel_resid
        ));
    }
    Ok(())
}

fn quality(xs: &[f64], ys: &[f64], coeffs: &[f64]) -> FitQuality {
    let yh: Vec<f64> = xs.iter().map(|&x| polyval(coeffs, x)).collect();
    FitQuality {
        r2: r_squared(ys, &yh),
        max_rel_resid: max_rel_err(&yh, ys),
    }
}

/// Fit a table into a [`CalibratedPart`], enforcing every fit-quality and
/// physical-sanity gate. Errors are descriptive: they name the part, the
/// failing curve and the violated gate, so a corrupted table is diagnosed
/// from the message alone.
pub fn calibrate(table: &CalibrationTable) -> Result<CalibratedPart, String> {
    let part = table.part.as_str();
    let ladder = table.ladder();
    // --- table sanity ------------------------------------------------------
    if table.min_mhz >= table.max_mhz
        || table.step_mhz == 0
        || (table.max_mhz - table.min_mhz) % table.step_mhz != 0
    {
        return Err(format!(
            "{part}: ladder {}-{} MHz step {} is not a valid grid",
            table.min_mhz, table.max_mhz, table.step_mhz
        ));
    }
    let n = table.freqs_mhz.len();
    if table.power_w.len() != n || table.prefill_s.len() != n || table.decode_s.len() != n {
        return Err(format!(
            "{part}: sample series lengths differ (freqs {n}, power {}, prefill {}, decode {})",
            table.power_w.len(),
            table.prefill_s.len(),
            table.decode_s.len()
        ));
    }
    if n < 6 {
        return Err(format!("{part}: need at least 6 sample frequencies, got {n}"));
    }
    for (i, &f) in table.freqs_mhz.iter().enumerate() {
        if !f.is_finite() || f.fract() != 0.0 || !ladder.contains(f as u32) {
            return Err(format!("{part}: sample frequency {f} MHz is off the ladder grid"));
        }
        if i > 0 && f <= table.freqs_mhz[i - 1] {
            return Err(format!("{part}: sample frequencies not strictly ascending at {f} MHz"));
        }
    }
    for (series, name) in [
        (&table.power_w, "power"),
        (&table.prefill_s, "prefill"),
        (&table.decode_s, "decode"),
    ] {
        if series.iter().any(|v| !v.is_finite() || *v <= 0.0) {
            return Err(format!("{part}: {name} samples must be finite and positive"));
        }
    }
    if table.peak_flops <= 0.0 || table.hbm_bw <= 0.0 {
        return Err(format!("{part}: hardware envelope must be positive"));
    }
    if table.idle_base_w <= 0.0 || table.idle_slope_w_per_ghz < 0.0 {
        return Err(format!("{part}: idle power must be positive with non-negative slope"));
    }

    let f_ref = table.max_mhz as f64;

    // --- power: cubic over GHz (Eq. 7) ------------------------------------
    let ghzs: Vec<f64> = table.freqs_mhz.iter().map(|f| f / 1000.0).collect();
    let pc = polyfit(&ghzs, &table.power_w, 3);
    let power_q = quality(&ghzs, &table.power_w, &pc);
    check_fit("power", part, &pc, power_q)?;
    let mut prev = f64::NEG_INFINITY;
    for f in ladder.iter() {
        let w = polyval(&pc, ghz(f));
        if w <= prev {
            return Err(format!(
                "{part}: fitted power not strictly increasing at {f} MHz \
                 ({w:.1} W after {prev:.1} W)"
            ));
        }
        prev = w;
    }

    // --- prefill: line in x = f_ref/f --------------------------------------
    let xs: Vec<f64> = table.freqs_mhz.iter().map(|f| f_ref / f).collect();
    let fc = polyfit(&xs, &table.prefill_s, 1);
    let prefill_q = quality(&xs, &table.prefill_s, &fc);
    check_fit("prefill", part, &fc, prefill_q)?;
    let (pf_alpha, pf_beta) = (fc[0], fc[1]);
    if pf_beta <= 0.0 {
        return Err(format!(
            "{part}: prefill latency must decrease with frequency (beta {pf_beta:.3e})"
        ));
    }
    let prefill_t_ref = pf_alpha + pf_beta;
    let prefill_mem_frac = pf_alpha / prefill_t_ref;
    if !(0.0..0.5).contains(&prefill_mem_frac) {
        return Err(format!(
            "{part}: prefill memory fraction {prefill_mem_frac:.3} outside [0, 0.5) — \
             prefill must be compute-bound"
        ));
    }

    // --- decode: line in x = f_ref/f ---------------------------------------
    let dc = polyfit(&xs, &table.decode_s, 1);
    let decode_q = quality(&xs, &table.decode_s, &dc);
    check_fit("decode", part, &dc, decode_q)?;
    let (dec_alpha, dec_beta) = (dc[0], dc[1]);
    if dec_beta <= 0.0 || dec_alpha <= 0.0 {
        return Err(format!(
            "{part}: decode fit components must be positive (mem {dec_alpha:.3e}, \
             cmp {dec_beta:.3e})"
        ));
    }
    let decode_mem_frac = dec_alpha / (dec_alpha + dec_beta);
    if decode_mem_frac <= prefill_mem_frac {
        return Err(format!(
            "{part}: decode memory fraction {decode_mem_frac:.3} must exceed prefill's \
             {prefill_mem_frac:.3} (phase asymmetry, §2.2.2)"
        ));
    }

    // --- level factors vs the analytic reference spec ----------------------
    let hw = GpuHardware {
        peak_flops: table.peak_flops,
        hbm_bw: table.hbm_bw,
        f_ref_mhz: table.max_mhz,
    };
    let mut base = PerfModel::new(ModelSpec::qwen3_14b());
    base.hw = hw.clone();
    let (a, b, c) = base.prefill_coeffs();
    let l = table.prefill_ref_len as f64;
    let t_ana = a * l * l + b * l + c;
    if prefill_t_ref <= c {
        return Err(format!(
            "{part}: measured prefill {prefill_t_ref:.4} s not above the {c:.4} s overhead"
        ));
    }
    let prefill_mfu_factor = (t_ana - c) / (prefill_t_ref - c);
    let (m_ana, c_ana) = base.decode_step_components(table.decode_ref_batch, table.decode_ref_ctx);
    let decode_mem_scale = dec_alpha / m_ana;
    let decode_cmp_scale = dec_beta / c_ana;
    for (what, v) in [
        ("prefill MFU factor", prefill_mfu_factor),
        ("decode memory scale", decode_mem_scale),
        ("decode compute scale", decode_cmp_scale),
    ] {
        if !v.is_finite() || !(0.2..=5.0).contains(&v) {
            return Err(format!(
                "{part}: {what} {v:.3} outside the plausible [0.2, 5] band — \
                 samples inconsistent with the analytic envelope"
            ));
        }
    }

    Ok(CalibratedPart {
        name: table.part.clone(),
        citation: table.citation.clone(),
        ladder,
        hw,
        power: PowerModel {
            coeffs: [pc[0], pc[1], pc[2], pc[3]],
            idle_base_w: table.idle_base_w,
            idle_slope_w_per_ghz: table.idle_slope_w_per_ghz,
        },
        prefill_mem_frac,
        prefill_t_ref_s: prefill_t_ref,
        prefill_ref_len: table.prefill_ref_len,
        prefill_mfu_factor,
        decode_mem_frac,
        decode_mem_scale,
        decode_cmp_scale,
        fit: FitReport {
            power: power_q,
            prefill: prefill_q,
            decode: decode_q,
        },
    })
}

// ---------------------------------------------------------------------------
// The zoo
// ---------------------------------------------------------------------------

static ZOO: OnceLock<Vec<CalibratedPart>> = OnceLock::new();

/// Every calibrated part, fitted once per process. Panics with the
/// calibration error if an embedded table fails its quality gates — a bad
/// zoo must never serve silently.
pub fn zoo() -> &'static [CalibratedPart] {
    ZOO.get_or_init(|| {
        CalibrationTable::all()
            .iter()
            .map(|t| {
                calibrate(t).unwrap_or_else(|e| panic!("embedded GPU calibration failed: {e}"))
            })
            .collect()
    })
}

/// Look up a calibrated part by zoo key (case-insensitive).
pub fn part(name: &str) -> Option<&'static CalibratedPart> {
    zoo().iter().find(|p| p.name.eq_ignore_ascii_case(name.trim()))
}

/// The zoo's part names (CLI help, error messages).
pub fn part_names() -> Vec<String> {
    zoo().iter().map(|p| p.name.clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_calibrates_and_exposes_both_parts() {
        let names = part_names();
        assert_eq!(names, vec!["a100".to_string(), "h100".to_string()]);
        assert!(part("A100").is_some(), "lookup is case-insensitive");
        assert!(part("b200").is_none());
    }

    #[test]
    fn a100_matches_the_cited_envelope() {
        let p = part("a100").unwrap();
        assert_eq!(p.ladder, FreqLadder::a100());
        assert_eq!(p.hw.f_ref_mhz, 1410);
        let peak = p.power.active_w(1410);
        assert!((415.0..430.0).contains(&peak), "peak={peak}");
        let floor = p.power.active_w(210);
        assert!((190.0..205.0).contains(&floor), "floor={floor}");
        // Idle spread across the ladder ~2x (the defaultNV-parks-hot waste).
        assert!(p.power.idle_w(1410) > 1.8 * p.power.idle_w(210));
        // Shape: prefill nearly compute-bound, decode clearly memory-bound.
        assert!(p.prefill_mem_frac < 0.10, "m={}", p.prefill_mem_frac);
        assert!(p.decode_mem_frac > 0.60, "beta={}", p.decode_mem_frac);
    }

    #[test]
    fn h100_ladder_and_envelope() {
        let p = part("h100").unwrap();
        assert_eq!((p.ladder.min_mhz, p.ladder.max_mhz, p.ladder.step_mhz), (210, 1980, 15));
        assert_eq!(p.ladder.len(), 119);
        let peak = p.power.active_w(1980);
        assert!((680.0..710.0).contains(&peak), "peak={peak}");
        assert!(p.hw.hbm_bw > 3e12);
    }

    #[test]
    fn fit_quality_beats_the_gates_with_margin() {
        for p in zoo() {
            for q in [p.fit.power, p.fit.prefill, p.fit.decode] {
                assert!(q.r2 > 0.999, "{}: r2={}", p.name, q.r2);
                assert!(q.max_rel_resid < 0.005, "{}: resid={}", p.name, q.max_rel_resid);
            }
        }
    }

    #[test]
    fn calibrated_a100_perf_model_stays_near_the_analytic_seed() {
        // The closure harness compares methods on the calibrated a100; its
        // latency level must stay close to the analytic model every other
        // test exercises (same reference workload, same saturation points).
        let p = part("a100").unwrap();
        let cal = p.perf_model(ModelSpec::qwen3_14b());
        let ana = PerfModel::new(ModelSpec::qwen3_14b());
        let rel = (cal.prefill_time(1024, 1410) - ana.prefill_time(1024, 1410)).abs()
            / ana.prefill_time(1024, 1410);
        assert!(rel < 0.01, "prefill level drifted {rel:.4}");
        let td = cal.decode_step_time(16, 600.0, 1410);
        let ta = ana.decode_step_time(16, 600.0, 1410);
        assert!((td / ta - 1.0).abs() < 0.01, "decode level {td} vs {ta}");
    }

    #[test]
    fn corrupted_power_table_fails_with_clear_error() {
        let mut t = CalibrationTable::a100();
        // Swap two power samples: breaks fitted monotonicity/residuals.
        t.power_w.swap(3, 13);
        let err = calibrate(&t).unwrap_err();
        assert!(
            err.contains("a100") && (err.contains("residual") || err.contains("increasing")),
            "unhelpful error: {err}"
        );
    }

    #[test]
    fn corrupted_latency_table_fails_with_clear_error() {
        let mut t = CalibrationTable::a100();
        t.prefill_s.reverse(); // latency increasing with frequency
        let err = calibrate(&t).unwrap_err();
        assert!(err.contains("a100"), "unhelpful error: {err}");
        let mut t = CalibrationTable::h100();
        t.decode_s[5] = f64::NAN;
        assert!(calibrate(&t).unwrap_err().contains("finite"));
    }

    #[test]
    fn off_grid_and_misshapen_tables_rejected() {
        let mut t = CalibrationTable::a100();
        t.freqs_mhz[2] = 361.0; // off the 15 MHz grid
        assert!(calibrate(&t).unwrap_err().contains("grid"));
        let mut t = CalibrationTable::a100();
        t.power_w.pop();
        assert!(calibrate(&t).unwrap_err().contains("lengths"));
        let mut t = CalibrationTable::a100();
        t.freqs_mhz.truncate(4);
        t.power_w.truncate(4);
        t.prefill_s.truncate(4);
        t.decode_s.truncate(4);
        assert!(calibrate(&t).unwrap_err().contains("at least 6"));
    }
}

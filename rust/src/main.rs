//! GreenLLM CLI — the launcher.
//!
//! ```text
//! greenllm replay    --trace alibaba --qps 5 --method greenllm [--model qwen3-14b]
//! greenllm compare   --trace azure_code5            # 3-method Table-3 row
//! greenllm microbench --phase decode --tps 1000 --method greenllm
//! greenllm profile                                   # Fig. 7 + Fig. 8 fits
//! greenllm fig1|fig3a|fig3b|fig3c|fig5|fig7|fig8|fig10|fig11|fig12a|fig12b
//! greenllm table3|table4
//! greenllm serve     --prompts 16 --max-new 24       # real PJRT serving demo
//! greenllm bench     --quick --baseline BENCH_pr4.json  # perf gate
//! ```
//!
//! Common flags: --duration <s> --seed <n> --model <name> --config <toml>.

use std::cell::RefCell;

use greenllm::bench::matrix::TraceSpec;
use greenllm::bench::{self, figures, tables};
use greenllm::config::{Config, Method};
use greenllm::coordinator::cluster::{
    run_cluster, run_cluster_recorded, ArbiterStrategy, CapacityConfig, ClusterConfig,
    DisaggConfig, FaultPlan, FaultSpec, KvLinkModel, LbPolicy, NodeMigration, NodeSpec, PoolRatio,
    ShedConfig,
};
use greenllm::coordinator::engine::{run, RunOptions};
use greenllm::metrics::Histogram;
use greenllm::obs::{self, FlightRecorder};
use greenllm::server::{ServerConfig, ServerHandle};
use greenllm::util::cli::Args;
use greenllm::util::error::{anyhow, Result};
use greenllm::util::fsx::ensure_writable;
use greenllm::workload::alibaba::{self, ChatParams};
use greenllm::workload::request::Trace;
use greenllm::workload::synthetic;

/// `--features count-alloc` installs the counting global allocator so
/// `greenllm bench --mem` can report allocation counts and peak live
/// bytes. Never enabled for wall-time benching: counting costs a few
/// percent of wall time and must not contaminate the gated numbers.
#[cfg(feature = "count-alloc")]
#[global_allocator]
static COUNTING_ALLOC: greenllm::util::count_alloc::CountingAlloc =
    greenllm::util::count_alloc::CountingAlloc;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    let duration = args.f64_or("duration", 300.0)?;
    let seed = args.u64_or("seed", 42)?;
    match args.command.as_str() {
        "replay" => replay(args, duration, seed),
        "compare" => compare(args, duration, seed),
        "microbench" => microbench(args, duration, seed),
        "profile" => {
            figures::fig7(seed);
            figures::fig8(seed);
            Ok(())
        }
        "fig1" => {
            figures::fig1(duration.min(360.0), seed);
            Ok(())
        }
        "fig3a" => {
            figures::fig3a(duration.min(120.0), seed);
            Ok(())
        }
        "fig3b" => {
            figures::fig3b(duration.min(120.0), seed);
            Ok(())
        }
        "fig3c" => {
            figures::fig3c(duration.min(300.0), seed);
            Ok(())
        }
        "fig5" => {
            figures::fig5(duration, seed);
            Ok(())
        }
        "fig7" => {
            figures::fig7(seed);
            Ok(())
        }
        "fig8" => {
            figures::fig8(seed);
            Ok(())
        }
        "fig10" => {
            figures::fig10(duration.min(120.0), seed);
            Ok(())
        }
        "fig11" => {
            figures::fig11(duration.min(120.0), seed);
            Ok(())
        }
        "fig12a" => {
            figures::fig12a(duration, seed);
            Ok(())
        }
        "fig12b" => {
            figures::fig12b(duration, seed);
            Ok(())
        }
        "table3" => {
            tables::table3(duration, seed);
            Ok(())
        }
        "table4" => {
            tables::table4(duration, seed);
            Ok(())
        }
        "ablations" => {
            bench::ablations::ablations(duration, seed);
            Ok(())
        }
        "baselines" => {
            bench::baselines::baselines(duration, seed);
            Ok(())
        }
        "validate" => validate_cmd(args, seed),
        "matrix" => matrix_cmd(args, duration, seed),
        "cluster" => cluster_cmd(args, duration, seed),
        "report" => report_cmd(args, duration, seed),
        "trace-check" => trace_check_cmd(args),
        "bench" => bench_cmd(args),
        "serve" => serve(args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!("unknown command {other:?}; try `greenllm help`")),
    }
}

fn base_config(args: &Args, seed: u64) -> Result<Config> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::load(path).map_err(|e| anyhow!(e))?,
        None => Config::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(m) = args.get("method") {
        cfg.method = Method::parse(m).ok_or_else(|| anyhow!("bad --method {m:?}"))?;
    }
    cfg.prefill_margin = args.f64_or("prefill-margin", cfg.prefill_margin)?;
    cfg.decode_margin = args.f64_or("decode-margin", cfg.decode_margin)?;
    // --supervisor wraps whichever policy runs in the fail-safe watchdog
    // ([ctl] supervisor = true); the flag only ever turns it ON so a
    // config that enables it stays enabled.
    if args.flag("supervisor") {
        cfg.ctl.supervisor = true;
    }
    cfg.seed = seed;
    cfg.validate().map_err(|e| anyhow!(e))?;
    Ok(cfg)
}

fn trace_from_args(args: &Args, duration: f64, seed: u64) -> Result<Trace> {
    let name = args.get_or("trace", "alibaba");
    let qps = args.f64_or("qps", 5.0)?;
    // `alibaba`/`chat` honour --qps; everything else resolves through the
    // scenario-matrix registry so `replay --trace X` and `matrix --traces X`
    // can never drift apart.
    Ok(match name {
        "alibaba" | "chat" => alibaba::generate(&ChatParams::new(qps, duration), seed),
        other => match TraceSpec::parse(other) {
            Some(spec) => spec.generate(duration, seed),
            None => return Err(anyhow!("unknown trace {other:?}")),
        },
    })
}

fn replay(args: &Args, duration: f64, seed: u64) -> Result<()> {
    let cfg = base_config(args, seed)?;
    let trace = trace_from_args(args, duration, seed)?;
    println!(
        "replaying {} ({} requests, {:.0}s) with {} on {}",
        trace.name,
        trace.requests.len(),
        trace.duration_s,
        cfg.method.name(),
        cfg.model
    );
    let t0 = std::time::Instant::now();
    let r = run(&cfg, &trace, &RunOptions::default());
    println!(
        "completed {} requests | tokens {} | throughput {:.0} tok/s",
        r.completed,
        r.generated_tokens,
        r.throughput_tps()
    );
    println!(
        "energy: prefill {:.1} kJ + decode {:.1} kJ = {:.1} kJ ({:.1} Wh)",
        r.prefill_energy_j / 1e3,
        r.decode_energy_j / 1e3,
        r.total_energy_j / 1e3,
        r.total_energy_wh()
    );
    println!(
        "SLO: TTFT {:.1}% (p50 {:.0} ms, p99 {:.0} ms) | TBT {:.1}% (p95-of-p95 {:.0} ms)",
        r.slo.ttft_pass_rate() * 100.0,
        r.slo.ttft_hist.p50() * 1000.0,
        r.slo.ttft_hist.p99() * 1000.0,
        r.slo.tbt_pass_rate() * 100.0,
        r.slo.tbt_hist.p95() * 1000.0
    );
    println!(
        "sim: {} events in {:.1} ms wall ({:.2} Mev/s)",
        r.events_processed,
        t0.elapsed().as_secs_f64() * 1e3,
        r.events_processed as f64 / t0.elapsed().as_secs_f64() / 1e6
    );
    Ok(())
}

fn compare(args: &Args, duration: f64, seed: u64) -> Result<()> {
    let cfg = base_config(args, seed)?;
    let trace = trace_from_args(args, duration, seed)?;
    let rows = bench::compare_methods(&cfg.model, &trace, seed);
    tables::render_rows(&format!("compare on {}", trace.name), &rows);
    Ok(())
}

fn microbench(args: &Args, duration: f64, seed: u64) -> Result<()> {
    let cfg = base_config(args, seed)?;
    let tps = args.f64_or("tps", 1000.0)?;
    let phase = args.get_or("phase", "decode");
    let trace = match phase {
        "prefill" => synthetic::prefill_microbench(tps, 256, 1024, duration, seed),
        "decode" => synthetic::decode_microbench(tps, duration, seed),
        other => return Err(anyhow!("unknown --phase {other:?}")),
    };
    let r = run(&cfg, &trace, &RunOptions::default());
    println!(
        "{} microbench @ {tps} TPS, {}: P90 TTFT {:.1} ms | P90 TBT {:.1} ms | energy {:.1} kJ",
        phase,
        cfg.method.name(),
        r.slo.ttft_hist.p90() * 1000.0,
        r.slo.tbt_hist.p90() * 1000.0,
        r.total_energy_j / 1e3
    );
    Ok(())
}

/// `greenllm validate`: the paper-closure harness. Replays the paper's
/// Alibaba + Azure settings on a *calibrated* part (defaults to the
/// cited A100 envelope), runs defaultNV and GreenLLM back-to-back, and
/// checks energy savings / extra SLO violations against the `[closure]`
/// tolerance bands. Exits non-zero when the reproduction drifts outside
/// the bands — this is the CI closure gate. See docs/VALIDATION.md.
fn validate_cmd(args: &Args, seed: u64) -> Result<()> {
    let quick = args.flag("quick");
    // Quick mode shrinks the horizon for CI smoke; the full default is
    // long enough for the SLO tails to settle.
    let duration = args.f64_or("duration", if quick { 90.0 } else { 240.0 })?;
    let part = args.get_or("part", "a100");
    if greenllm::gpu::calibrate::part(part).is_none() {
        return Err(anyhow!(
            "unknown --part {part:?}; calibrated parts: {}",
            greenllm::gpu::calibrate::part_names().join(", ")
        ));
    }
    // `[closure]` bands from --config (or defaults), with CLI overrides.
    let cfg = base_config(args, seed)?;
    let model = args.get_or("model", &cfg.model);
    let mut bands = cfg.closure.clone();
    bands.min_energy_savings_pct = args.f64_or("min-savings", bands.min_energy_savings_pct)?;
    bands.max_extra_violations_pct =
        args.f64_or("max-extra-viol", bands.max_extra_violations_pct)?;
    if let Some(path) = args.get("json") {
        ensure_writable(path).map_err(|e| anyhow!(e))?;
    }
    let rep = bench::validate::run_closure(part, model, duration, seed, &bands);
    bench::validate::print_report(&rep);
    // --ctl-stress: informational re-run of the pair under mild
    // control-plane noise with the supervisor armed. Never gates — the
    // exit code below depends only on the clean closure bands.
    let stress = if args.flag("ctl-stress") {
        let rows = bench::validate::run_ctl_stress(part, model, duration, seed);
        bench::validate::print_ctl_stress(&rows);
        Some(rows)
    } else {
        None
    };
    if let Some(path) = args.get("json") {
        use greenllm::util::json::Json;
        let mut doc = rep.to_json();
        if let (Json::Obj(map), Some(rows)) = (&mut doc, &stress) {
            map.insert(
                "ctl_stress".to_string(),
                bench::validate::ctl_stress_json(rows),
            );
        }
        std::fs::write(path, doc.dump()).map_err(|e| anyhow!("closure json {path}: {e}"))?;
        println!("json: wrote {path}");
    }
    if !rep.pass() {
        return Err(anyhow!(
            "paper closure failed on {} of {} workloads (bands: savings >= {:.1}%, \
             extra violations < {:.1} pp)",
            rep.rows.iter().filter(|r| !r.pass()).count(),
            rep.rows.len(),
            bands.min_energy_savings_pct,
            bands.max_extra_violations_pct
        ));
    }
    Ok(())
}

fn matrix_cmd(args: &Args, duration: f64, seed: u64) -> Result<()> {
    use greenllm::bench::matrix::{matrix, MatrixConfig};
    let mut cfg = MatrixConfig {
        model: args.get_or("model", "qwen3-14b").to_string(),
        duration_s: duration,
        seed,
        threads: args.usize_or("threads", 0)?,
        ..MatrixConfig::default()
    };
    if let Some(spec) = args.get("traces") {
        cfg.traces = spec
            .split(',')
            .map(|s| TraceSpec::parse(s).ok_or_else(|| anyhow!("unknown trace {s:?}")))
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(spec) = args.get("methods") {
        cfg.methods = spec
            .split(',')
            .map(|s| Method::parse(s.trim()).ok_or_else(|| anyhow!("unknown method {s:?}")))
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(spec) = args.get("margins") {
        cfg.margins = spec
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow!("bad margin {s:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(spec) = args.get("nodes") {
        cfg.nodes = spec
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .ok()
                    .filter(|&n| n >= 1)
                    .ok_or_else(|| anyhow!("bad node count {s:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(spec) = args.get("lb") {
        cfg.lbs = if spec == "all" {
            LbPolicy::all()
        } else {
            spec.split(',')
                .map(|s| LbPolicy::parse(s).ok_or_else(|| anyhow!("unknown balancer {s:?}")))
                .collect::<Result<Vec<_>>>()?
        };
    }
    if let Some(spec) = args.get("power-cap-w") {
        cfg.power_caps_w = spec
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f64>()
                    .ok()
                    .filter(|c| *c >= 0.0)
                    .ok_or_else(|| anyhow!("bad power cap {s:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(spec) = args.get("shapes") {
        // Validate each shape eagerly so a typo fails before the sweep.
        cfg.shapes = spec
            .split(',')
            .map(|s| {
                let s = s.trim();
                NodeSpec::parse_list(s)
                    .map(|_| s.to_string())
                    .map_err(|e| anyhow!(e))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(spec) = args.get("faults") {
        cfg.faults = spec
            .split(';')
            .map(|s| FaultSpec::parse(s).map_err(|e| anyhow!(e)))
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(spec) = args.get("ctl-faults") {
        cfg.ctl_faults = spec
            .split(';')
            .map(|s| FaultSpec::parse(s).map_err(|e| anyhow!(e)))
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(spec) = args.get("arbiter") {
        cfg.arbiters = if spec == "all" {
            ArbiterStrategy::all()
        } else {
            spec.split(',')
                .map(|s| {
                    ArbiterStrategy::parse(s).ok_or_else(|| anyhow!("unknown arbiter {s:?}"))
                })
                .collect::<Result<Vec<_>>>()?
        };
    }
    if let Some(spec) = args.get("disagg") {
        // Validate every ratio eagerly so a typo fails here, not in a
        // sweep worker thread.
        cfg.disaggs = spec
            .split(',')
            .map(|s| {
                let s = s.trim();
                if s == "off" {
                    Ok(s.to_string())
                } else {
                    PoolRatio::parse(s).map(|_| s.to_string()).map_err(|e| anyhow!(e))
                }
            })
            .collect::<Result<Vec<_>>>()?;
        if cfg.disaggs.iter().any(|d| d != "off") && cfg.nodes.iter().all(|&n| n < 2) {
            return Err(anyhow!(
                "--disagg needs a node count >= 2 somewhere in --nodes to split \
                 into prefill/decode pools"
            ));
        }
    }
    if cfg.traces.is_empty()
        || cfg.methods.is_empty()
        || cfg.margins.is_empty()
        || cfg.nodes.is_empty()
        || cfg.lbs.is_empty()
        || cfg.power_caps_w.is_empty()
        || cfg.shapes.is_empty()
        || cfg.faults.is_empty()
        || cfg.ctl_faults.is_empty()
        || cfg.arbiters.is_empty()
        || cfg.disaggs.is_empty()
    {
        return Err(anyhow!(
            "matrix needs at least one trace, method, margin, node count, balancer, \
             cap, shape, fault spec, ctl-fault spec, arbiter and disagg entry"
        ));
    }
    // Validate every fault plan that will actually run against its node
    // count now, so a bad explicit schedule fails here with a message
    // instead of panicking inside a sweep worker thread. (At 1 node the
    // fault axis collapses to its first entry, mirroring `cells()`; the
    // ctl-fault axis never collapses, and each cell runs the MERGED
    // capacity + control-plane plan, so validate every pairing.)
    for &n in &cfg.nodes {
        let active = if n == 1 {
            &cfg.faults[..cfg.faults.len().min(1)]
        } else {
            &cfg.faults[..]
        };
        for f in active {
            for c in &cfg.ctl_faults {
                f.plan(n, duration)
                    .merged(c.plan(n, duration))
                    .validate(n)
                    .map_err(|e| {
                        anyhow!(
                            "fault spec {:?} + ctl-fault spec {:?} at {n} nodes: {e}",
                            f.name(),
                            c.name()
                        )
                    })?;
            }
        }
    }
    // Fail fast on unwritable artifact paths before the (long) sweep.
    for p in [args.get("json"), args.get("md")].into_iter().flatten() {
        ensure_writable(p).map_err(|e| anyhow!(e))?;
    }
    matrix(&cfg, args.get("json"), args.get("md"));
    Ok(())
}

/// A cluster deployment parsed from flags plus `[cluster]`/`[disagg]`
/// config defaults — everything `cluster` and `report` share before the
/// method loop.
struct ClusterSetup {
    node_cfg: Config,
    nodes: usize,
    lb: LbPolicy,
    cap_w: f64,
    epoch_s: f64,
    arbiter: ArbiterStrategy,
    node_specs: Vec<NodeSpec>,
    faults: FaultPlan,
    pool_ratio: PoolRatio,
    disagg_ratio: Option<PoolRatio>,
    disagg_cfg: Option<DisaggConfig>,
    capacity: Option<CapacityConfig>,
    shed: Option<ShedConfig>,
}

impl ClusterSetup {
    /// Assemble the full deployment for one DVFS method.
    fn ccfg(&self, method: Method) -> ClusterConfig {
        let mut ccfg = ClusterConfig::new(
            self.nodes,
            self.lb,
            Config {
                method,
                ..self.node_cfg.clone()
            },
        )
        .with_node_specs(self.node_specs.clone())
        .with_faults(self.faults.clone())
        .with_arbiter(self.arbiter)
        .with_pool_ratio(self.pool_ratio);
        if self.cap_w > 0.0 {
            ccfg = ccfg.with_power_cap(self.cap_w, self.epoch_s);
        }
        if let Some(d) = self.disagg_cfg {
            ccfg = ccfg.with_disagg(d);
        }
        if let Some(c) = self.capacity {
            ccfg = ccfg.with_capacity(c);
        }
        if let Some(s) = self.shed {
            ccfg = ccfg.with_shed(s);
        }
        ccfg
    }

    fn shape_label(&self) -> String {
        if self.node_specs.is_empty() {
            "uniform".to_string()
        } else {
            self.node_specs
                .iter()
                .map(|s| s.name.as_str())
                .collect::<Vec<_>>()
                .join(",")
        }
    }

    fn cap_label(&self) -> String {
        if self.cap_w > 0.0 {
            format!(
                "{:.0} W / {:.1} s epoch / {}",
                self.cap_w,
                self.epoch_s,
                self.arbiter.name()
            )
        } else {
            "uncapped".into()
        }
    }

    fn fault_label(&self) -> String {
        if self.faults.is_empty() {
            "none".to_string()
        } else {
            self.faults.render()
        }
    }

    fn disagg_label(&self) -> String {
        match self.disagg_ratio {
            Some(r) => format!(
                "{} ({} prefill + {} decode)",
                r.name(),
                r.prefill_count(self.nodes),
                self.nodes - r.prefill_count(self.nodes)
            ),
            None => "off".into(),
        }
    }

    fn elasticity_label(&self) -> String {
        match (&self.capacity, &self.shed) {
            (None, None) => "off".into(),
            (cap, shed) => {
                let mut parts = Vec::new();
                if let Some(c) = cap {
                    parts.push(format!(
                        "autoscale (warm {}, min-live {}, boot {:.0}s, {}..{} backlog)",
                        c.warm, c.min_live, c.boot_s, c.down_backlog, c.up_backlog
                    ));
                }
                if let Some(s) = shed {
                    parts.push(format!(
                        "shed (depth {}, {} retries, {:.1}s backoff)",
                        s.queue_depth, s.max_retries, s.backoff_s
                    ));
                }
                parts.join(" + ")
            }
        }
    }

    /// A fresh flight recorder sized for this deployment.
    fn recorder(&self) -> RefCell<FlightRecorder> {
        RefCell::new(FlightRecorder::new(self.nodes, self.node_cfg.obs.series_cap))
    }
}

/// Parse the shared cluster deployment flags (`--nodes`, `--lb`,
/// `--power-cap-w`, `--node-spec`, `--faults`, `--disagg`, ...) on top of
/// the node config's `[cluster]`/`[disagg]` defaults.
fn cluster_setup(args: &Args, duration: f64, seed: u64) -> Result<ClusterSetup> {
    let node_cfg = base_config(args, seed)?;
    let lb_name = args.get_or("lb", &node_cfg.cluster.lb);
    let lb = LbPolicy::parse(lb_name).ok_or_else(|| anyhow!("unknown balancer {lb_name:?}"))?;
    let cap_w = args.f64_or("power-cap-w", node_cfg.cluster.power_cap_w)?;
    let epoch_s = args.f64_or("power-epoch-s", node_cfg.cluster.power_epoch_s)?;
    let arb_name = args.get_or("arbiter", &node_cfg.cluster.arbiter);
    let arbiter =
        ArbiterStrategy::parse(arb_name).ok_or_else(|| anyhow!("unknown arbiter {arb_name:?}"))?;
    let spec_list = args.get_or("node-spec", &node_cfg.cluster.node_specs);
    let node_specs = NodeSpec::parse_list(spec_list).map_err(|e| anyhow!(e))?;
    // `--node-spec a,b,c` fixes the node count unless --nodes overrides it.
    let default_nodes = if node_specs.is_empty() {
        node_cfg.cluster.nodes
    } else {
        node_specs.len()
    };
    let nodes = args.usize_or("nodes", default_nodes)?;
    let fault_name = args.get_or("faults", &node_cfg.cluster.faults);
    let faults = FaultSpec::parse(fault_name)
        .map_err(|e| anyhow!(e))?
        .plan(nodes, duration);
    faults.validate(nodes).map_err(|e| anyhow!(e))?;
    // Disaggregation: --disagg off|P:D (default from [disagg].ratio). The
    // pool ratio also drives the phase balancer's long-pool split, and
    // --pool-ratio can set it independently of disaggregation.
    let disagg_name = args.get_or("disagg", &node_cfg.disagg.ratio);
    let disagg_ratio = if disagg_name == "off" {
        None
    } else {
        Some(PoolRatio::parse(disagg_name).map_err(|e| anyhow!(e))?)
    };
    if disagg_ratio.is_some() && nodes < 2 {
        return Err(anyhow!(
            "--disagg {disagg_name} needs --nodes >= 2 to split into prefill/decode pools"
        ));
    }
    let pool_ratio = match args.get("pool-ratio") {
        Some(s) => PoolRatio::parse(s).map_err(|e| anyhow!(e))?,
        None => disagg_ratio.unwrap_or_default(),
    };
    let disagg_cfg = disagg_ratio.map(|_| DisaggConfig {
        link: KvLinkModel {
            bytes_per_token: node_cfg.disagg.bytes_per_token,
            gbps: node_cfg.disagg.gbps,
            latency_s: node_cfg.disagg.latency_s,
            pj_per_byte: node_cfg.disagg.pj_per_byte,
        },
        prefill_method: Method::parse(&node_cfg.disagg.prefill_method),
        decode_method: Method::parse(&node_cfg.disagg.decode_method),
    });
    // Elastic capacity: `--capacity` (or `[capacity] enabled = true`)
    // turns the autoscaler on; `--capacity off` overrides an enabling
    // config. Sub-knobs override the `[capacity]` section defaults.
    // Validated here so a bad shape fails with a message, not a panic
    // inside the event loop.
    let cap_sec = &node_cfg.capacity;
    let capacity_on = match args.get("capacity") {
        Some("off") => false,
        Some(_) => true,
        None => args.flag("capacity") || cap_sec.enabled,
    };
    let capacity = if capacity_on {
        let c = CapacityConfig {
            warm: args.usize_or("warm-pool", cap_sec.warm)?,
            min_live: args.usize_or("min-live", cap_sec.min_live)?,
            boot_s: args.f64_or("boot-s", cap_sec.boot_s)?,
            check_epoch_s: args.f64_or("capacity-epoch-s", cap_sec.check_epoch_s)?,
            up_backlog: args.f64_or("up-backlog", cap_sec.up_backlog)?,
            down_backlog: args.f64_or("down-backlog", cap_sec.down_backlog)?,
            down_idle_epochs: args.u64_or("down-idle-epochs", cap_sec.down_idle_epochs as u64)?
                as u32,
            warm_idle_w: args.f64_or("warm-idle-w", cap_sec.warm_idle_w)?,
        };
        c.validate(nodes).map_err(|e| anyhow!(e))?;
        Some(c)
    } else {
        None
    };
    // Overload shedding: same enable/override scheme as --capacity.
    let shed_sec = &node_cfg.shed;
    let shed_on = match args.get("shed") {
        Some("off") => false,
        Some(_) => true,
        None => args.flag("shed") || shed_sec.enabled,
    };
    let shed = if shed_on {
        let s = ShedConfig {
            queue_depth: args.f64_or("shed-depth", shed_sec.queue_depth)?,
            backoff_s: args.f64_or("shed-backoff-s", shed_sec.backoff_s)?,
            max_retries: args.u64_or("shed-retries", shed_sec.max_retries as u64)? as u32,
        };
        s.validate().map_err(|e| anyhow!(e))?;
        Some(s)
    } else {
        None
    };
    Ok(ClusterSetup {
        node_cfg,
        nodes,
        lb,
        cap_w,
        epoch_s,
        arbiter,
        node_specs,
        faults,
        pool_ratio,
        disagg_ratio,
        disagg_cfg,
        capacity,
        shed,
    })
}

/// One line summarising a whole-run latency distribution in milliseconds.
fn dist_line(label: &str, h: &Histogram) -> String {
    format!(
        "{label} p50/p95/p99 {:.0}/{:.0}/{:.0} ms [{:.0}..{:.0} ms, n={}]",
        h.p50() * 1e3,
        h.p95() * 1e3,
        h.p99() * 1e3,
        h.observed_min() * 1e3,
        h.observed_max() * 1e3,
        h.count(),
    )
}

fn cluster_cmd(args: &Args, duration: f64, seed: u64) -> Result<()> {
    use greenllm::util::json::Json;
    let setup = cluster_setup(args, duration, seed)?;
    let nodes = setup.nodes;
    let trace = trace_from_args(args, duration, seed)?;
    println!(
        "cluster: {nodes} nodes ({}), {} requests ({:.1} QPS aggregate), lb {}, cap {}, faults {}, disagg {}, elasticity {}",
        setup.shape_label(),
        trace.requests.len(),
        trace.qps(),
        setup.lb.name(),
        setup.cap_label(),
        setup.fault_label(),
        setup.disagg_label(),
        setup.elasticity_label(),
    );
    let trace_out = args.get("trace-out");
    let json_out = args.get("json");
    // Fail fast on unwritable artifact paths before the (long) runs.
    for p in [trace_out, json_out].into_iter().flatten() {
        ensure_writable(p).map_err(|e| anyhow!(e))?;
    }
    let arrived = trace.requests.len() as u64;
    let mut method_rows: Vec<(String, Json)> = Vec::new();
    for method in [Method::DefaultNv, Method::GreenLlm] {
        let ccfg = setup.ccfg(method);
        // --trace-out records the GreenLLM pass (the paper's policy) and
        // exports it as a Perfetto trace; the baseline pass stays
        // recorder-off so the comparison keeps its zero-cost path.
        let record_this = trace_out.is_some() && method == Method::GreenLlm;
        let frec = setup.recorder();
        let r = if record_this {
            run_cluster_recorded(&ccfg, &trace, &Default::default(), &frec)
        } else {
            run_cluster(&ccfg, &trace, &Default::default())
        };
        let balance = r.balance_label();
        println!(
            "{:<10} energy {:8.1} kJ ({:.2} J/tok) | TTFT {:5.1}% | TBT {:5.1}% | balance {balance}",
            method.name(),
            r.total_energy_j / 1e3,
            r.energy_per_token_j(),
            r.ttft_pass_rate * 100.0,
            r.tbt_pass_rate * 100.0,
        );
        for (i, n) in r.per_node.iter().enumerate() {
            println!(
                "  node{i} ({:<6}): {:5} reqs | {:7.1} kJ | TTFT {:5.1}% | TBT {:5.1}%",
                ccfg.node_spec_name(i),
                r.assignment[i],
                n.total_energy_j / 1e3,
                n.slo.ttft_pass_rate() * 100.0,
                n.slo.tbt_pass_rate() * 100.0,
            );
        }
        if r.fault_events > 0 {
            println!(
                "  chaos: {} fault events | {} requests re-routed | {} tokens wasted",
                r.fault_events, r.rerouted, r.wasted_tokens
            );
        }
        if !r.straggler_nodes.is_empty() {
            println!("  stragglers: degraded nodes {:?}", r.straggler_nodes);
        }
        if r.shed > 0 || r.shed_retries > 0 || r.deferred_arrivals > 0 {
            println!(
                "  shed: {} requests shed | {} re-offers | {} deferred (no routable node)",
                r.shed, r.shed_retries, r.deferred_arrivals
            );
        }
        if r.capacity_provisions > 0 || r.capacity_parks > 0 || r.warm_energy_j > 0.0 {
            println!(
                "  capacity: {} provisions | {} parks | warm-pool idle {:.1} kJ",
                r.capacity_provisions,
                r.capacity_parks,
                r.warm_energy_j / 1e3
            );
        }
        let ctl_active = r.supervisor_fallbacks
            + r.supervisor_reengages
            + r.ctl_dropped_writes
            + r.ctl_delayed_writes
            + r.ctl_missteps
            + r.ctl_suppressed_samples
            > 0;
        if ctl_active {
            println!(
                "  ctl: {} fallbacks / {} reengages | writes {} dropped / {} delayed / {} missteps | {} suppressed samples",
                r.supervisor_fallbacks,
                r.supervisor_reengages,
                r.ctl_dropped_writes,
                r.ctl_delayed_writes,
                r.ctl_missteps,
                r.ctl_suppressed_samples,
            );
        }
        // Counts are conserved under every knob combination: each arrival
        // either completed or was shed. A finished run that violates this
        // lost a request silently — make that a hard error, not a log line.
        if r.completed + r.shed != arrived {
            return Err(anyhow!(
                "conservation violated: {} arrived but {} completed + {} shed",
                arrived,
                r.completed,
                r.shed
            ));
        }
        if let Some(m) = &r.migration {
            println!(
                "  migration: {} handoffs | {:.1} MB KV moved | {:.1} J transfer | {} relays",
                m.count,
                m.kv_bytes / 1e6,
                m.transfer_j,
                m.relays
            );
            for (i, nm) in r.node_migration.iter().enumerate() {
                if *nm != NodeMigration::default() {
                    println!(
                        "    node{i}: {} sends | {} deliveries | {} relays | {} re-prefills",
                        nm.sends, nm.deliveries, nm.relays, nm.re_prefills
                    );
                }
            }
        }
        if let Some(p) = &r.power {
            println!(
                "  power: cap {:.0} W ({}) | peak epoch {:.0} W | {} epochs{}",
                p.cap_w,
                setup.arbiter.name(),
                p.peak_measured_w,
                p.epochs.len(),
                if p.had_infeasible_epoch {
                    " | WARNING: infeasible share epochs"
                } else {
                    ""
                }
            );
        }
        println!(
            "  dist: {} | {}",
            dist_line("TTFT", &r.ttft_hist),
            dist_line("TBT-P95", &r.tbt_hist)
        );
        if json_out.is_some() {
            method_rows.push((
                method.name().to_string(),
                Json::obj([
                    ("arrived", Json::Num(arrived as f64)),
                    ("completed", Json::Num(r.completed as f64)),
                    ("shed", Json::Num(r.shed as f64)),
                    ("shed_retries", Json::Num(r.shed_retries as f64)),
                    ("deferred_arrivals", Json::Num(r.deferred_arrivals as f64)),
                    ("conservation_ok", Json::Bool(r.completed + r.shed == arrived)),
                    ("generated_tokens", Json::Num(r.generated_tokens as f64)),
                    ("total_energy_j", Json::Num(r.total_energy_j)),
                    ("warm_energy_j", Json::Num(r.warm_energy_j)),
                    ("energy_per_token_j", Json::Num(r.energy_per_token_j())),
                    ("ttft_pass_rate", Json::Num(r.ttft_pass_rate)),
                    ("tbt_pass_rate", Json::Num(r.tbt_pass_rate)),
                    ("rerouted", Json::Num(r.rerouted as f64)),
                    ("wasted_tokens", Json::Num(r.wasted_tokens as f64)),
                    ("fault_events", Json::Num(r.fault_events as f64)),
                    ("capacity_provisions", Json::Num(r.capacity_provisions as f64)),
                    ("capacity_parks", Json::Num(r.capacity_parks as f64)),
                    (
                        "straggler_nodes",
                        Json::Arr(
                            r.straggler_nodes
                                .iter()
                                .map(|&n| Json::Num(n as f64))
                                .collect(),
                        ),
                    ),
                    // The ctl-chaos-smoke CI contract: supervisor and
                    // control-plane counters, always present.
                    (
                        "ctl",
                        Json::obj([
                            (
                                "supervisor_fallbacks",
                                Json::Num(r.supervisor_fallbacks as f64),
                            ),
                            (
                                "supervisor_reengages",
                                Json::Num(r.supervisor_reengages as f64),
                            ),
                            ("dropped_writes", Json::Num(r.ctl_dropped_writes as f64)),
                            ("delayed_writes", Json::Num(r.ctl_delayed_writes as f64)),
                            ("missteps", Json::Num(r.ctl_missteps as f64)),
                            (
                                "suppressed_samples",
                                Json::Num(r.ctl_suppressed_samples as f64),
                            ),
                        ]),
                    ),
                ]),
            ));
        }
        if record_this {
            let path = trace_out.unwrap();
            obs::perfetto::write_trace(&frec.borrow(), path)
                .map_err(|e| anyhow!("trace-out {path}: {e}"))?;
            println!("  trace: wrote {path}");
        }
    }
    if let Some(path) = json_out {
        let doc = Json::obj([
            ("nodes", Json::Num(nodes as f64)),
            ("lb", Json::Str(setup.lb.name().to_string())),
            ("faults", Json::Str(setup.fault_label())),
            ("elasticity", Json::Str(setup.elasticity_label())),
            ("methods", Json::obj(method_rows)),
        ]);
        std::fs::write(path, doc.dump()).map_err(|e| anyhow!("cluster json {path}: {e}"))?;
        println!("json: wrote {path}");
    }
    Ok(())
}

/// `greenllm report`: run the configured method once with the flight
/// recorder on, attribute every SLO violation to a dominant cause, and
/// print the per-node attribution tables plus whole-run distributions.
fn report_cmd(args: &Args, duration: f64, seed: u64) -> Result<()> {
    use greenllm::util::json::Json;
    let setup = cluster_setup(args, duration, seed)?;
    let trace = trace_from_args(args, duration, seed)?;
    let method = setup.node_cfg.method;
    let ccfg = setup.ccfg(method);
    println!(
        "report: {} nodes ({}), {} on {} ({} requests), faults {}, disagg {}",
        setup.nodes,
        setup.shape_label(),
        method.name(),
        trace.name,
        trace.requests.len(),
        setup.fault_label(),
        setup.disagg_label(),
    );
    // Fail fast on unwritable artifact paths before the recorded run.
    for p in [args.get("trace-out"), args.get("json")].into_iter().flatten() {
        ensure_writable(p).map_err(|e| anyhow!(e))?;
    }
    let frec = setup.recorder();
    let r = run_cluster_recorded(&ccfg, &trace, &Default::default(), &frec);
    let rec = frec.into_inner();
    rec.span_check(false).map_err(|e| anyhow!("span invariants: {e}"))?;
    let att = obs::attribute(&rec, &setup.node_cfg.slo);
    // The recorder must agree with the per-node SLO trackers: every
    // violation the trackers counted gets exactly one cause.
    let exp_ttft: u64 = r
        .per_node
        .iter()
        .map(|n| n.slo.completed - n.slo.ttft_passes())
        .sum();
    let exp_tbt: u64 = r
        .per_node
        .iter()
        .map(|n| n.slo.tbt_eligible() - n.slo.tbt_passes())
        .sum();
    println!(
        "attributed {}/{exp_ttft} TTFT and {}/{exp_tbt} TBT violations across {} finished requests",
        att.ttft_violations, att.tbt_violations, att.finished
    );
    if att.ttft_violations != exp_ttft || att.tbt_violations != exp_tbt {
        return Err(anyhow!(
            "attribution mismatch: recorder attributed {}+{} violations but the SLO trackers counted {exp_ttft}+{exp_tbt}",
            att.ttft_violations,
            att.tbt_violations,
        ));
    }
    print!("{}", att.render_table());
    println!("{}", dist_line("TTFT", &r.ttft_hist));
    println!("{}", dist_line("TBT-P95", &r.tbt_hist));
    // Whole-run node power distribution from the recorder's time series.
    let mut power = Histogram::new(1.0, 50_000.0, 512);
    for n in 0..rec.nodes() {
        for s in rec.series(n).iter() {
            power.record(s.power_w);
        }
    }
    println!(
        "power: {} samples | p50/p95/p99 {:.0}/{:.0}/{:.0} W | peak {:.0} W",
        power.count(),
        power.p50(),
        power.p95(),
        power.p99(),
        power.observed_max(),
    );
    if let Some(path) = args.get("trace-out") {
        obs::perfetto::write_trace(&rec, path).map_err(|e| anyhow!("trace-out {path}: {e}"))?;
        println!("trace: wrote {path}");
    }
    if let Some(path) = args.get("json") {
        let dist_json = |h: &Histogram| {
            Json::obj([
                ("count", Json::Num(h.count() as f64)),
                ("p50", Json::Num(h.p50())),
                ("p95", Json::Num(h.p95())),
                ("p99", Json::Num(h.p99())),
                ("min", Json::Num(h.observed_min())),
                ("max", Json::Num(h.observed_max())),
            ])
        };
        let doc = Json::obj([
            ("attribution", att.to_json()),
            ("finished", Json::Num(att.finished as f64)),
            ("ttft_s", dist_json(&r.ttft_hist)),
            ("tbt_p95_s", dist_json(&r.tbt_hist)),
            ("power_w", dist_json(&power)),
        ]);
        std::fs::write(path, doc.dump()).map_err(|e| anyhow!("report json {path}: {e}"))?;
        println!("json: wrote {path}");
    }
    Ok(())
}

/// `greenllm trace-check <trace.json>`: re-parse an exported Perfetto
/// trace with the in-repo parser and verify its structural invariants.
fn trace_check_cmd(args: &Args) -> Result<()> {
    use greenllm::util::json::Json;
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("file"))
        .ok_or_else(|| anyhow!("usage: greenllm trace-check <trace.json>"))?;
    let text = std::fs::read_to_string(path).map_err(|e| anyhow!("read {path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| anyhow!("parse {path}: {e}"))?;
    let stats = obs::perfetto::validate_trace(&doc).map_err(|e| anyhow!("{path}: {e}"))?;
    println!(
        "{path}: OK — {} node tracks, {} spans, {} counter samples, {} instants",
        stats.nodes, stats.spans, stats.counters, stats.instants
    );
    Ok(())
}

fn bench_cmd(args: &Args) -> Result<()> {
    use greenllm::bench::perf::{self, GateOutcome};
    use greenllm::util::json::Json;
    let quick = args.flag("quick");
    let mode = if quick { "quick" } else { "full" };
    if args.flag("mem") {
        return bench_mem_cmd(args, quick, mode);
    }
    // Hard guard, not just a comment: a count-alloc build must never
    // produce (or bless) wall numbers — allocator counting inflates
    // them a few percent, which silently widens every later CI gate.
    if greenllm::util::count_alloc::active() {
        return Err(anyhow!(
            "this binary was built with --features count-alloc; wall-time \
             benching would be contaminated by allocator counting. Run \
             `bench --mem` with this build, or rebuild without the feature \
             to measure/bless wall numbers"
        ));
    }
    if let Some(path) = args.get("json") {
        ensure_writable(path).map_err(|e| anyhow!(e))?;
    }
    println!(
        "greenllm bench ({mode} mode, seed {}): single-node replay, \
         4-node cluster + faults, mini-matrix, 32-node sweep",
        perf::BENCH_SEED
    );
    let t0 = std::time::Instant::now();
    let results = perf::run_bench(quick);
    perf::render_table(&results).print();
    println!("total wall {:.1} s", t0.elapsed().as_secs_f64());
    // Gate BEFORE blessing: with --json and --baseline pointing at the
    // same file ("verify then refresh"), the comparison must read the
    // *old* numbers — and a regression must abort before overwriting
    // them — or the gate would silently compare results to themselves.
    let gate_disarmed = std::env::var("GREENLLM_BENCH_SKIP")
        .map(|v| v == "1")
        .unwrap_or(false);
    if let Some(bpath) = args.get("baseline").filter(|_| !gate_disarmed) {
        let max = args.f64_or("max-regress", 25.0)?;
        let text = std::fs::read_to_string(bpath)
            .map_err(|e| anyhow!("baseline {bpath}: {e}"))?;
        let baseline = Json::parse(&text).map_err(|e| anyhow!("baseline {bpath}: {e}"))?;
        match perf::gate(&baseline, mode, &results, max) {
            GateOutcome::Skipped(why) => println!("perf gate skipped: {why}"),
            GateOutcome::Passed(lines) => {
                for l in &lines {
                    println!("perf gate: {l}");
                }
            }
            GateOutcome::Drifted(lines) => {
                for l in &lines {
                    eprintln!("perf gate: {l}");
                }
                return Err(anyhow!(
                    "bench workload drifted vs {bpath} (event counts changed — the \
                     committed baseline describes a different simulator build, so the \
                     wall-time gate is disarmed): re-bless in this change with \
                     `greenllm bench{} --json {bpath}`, or set GREENLLM_BENCH_SKIP=1",
                    if quick { " --quick" } else { "" }
                ));
            }
            GateOutcome::Regressed(lines) => {
                for l in &lines {
                    eprintln!("perf gate: {l}");
                }
                return Err(anyhow!(
                    "perf regression beyond {max:.0}% vs {bpath}; if this runner is \
                     noisy re-run, set GREENLLM_BENCH_SKIP=1, or re-bless with \
                     `greenllm bench{} --json {bpath}`",
                    if quick { " --quick" } else { "" }
                ));
            }
        }
    }
    if gate_disarmed && args.get("baseline").is_some() {
        // Disarms ONLY the gate — an explicitly requested --json bless
        // below still happens (skipping it silently would strand a stale
        // baseline).
        println!("perf gate skipped (GREENLLM_BENCH_SKIP=1)");
    }
    if let Some(path) = args.get("json") {
        let existing = std::fs::read_to_string(path)
            .ok()
            .and_then(|s| Json::parse(&s).ok());
        let merged = perf::merge_into_baseline(existing, mode, &results);
        std::fs::write(path, merged.dump())
            .map_err(|e| anyhow!("bench json write {path}: {e}"))?;
        println!("wrote {path} ({mode} section blessed)");
    }
    Ok(())
}

/// `greenllm bench --mem`: replay each scenario once under the counting
/// allocator, report allocation calls + peak live bytes, optionally
/// record them into the baseline's `memory.<mode>` section. Never
/// wall-gated — allocator counting and wall timing must not mix.
fn bench_mem_cmd(args: &Args, quick: bool, mode: &str) -> Result<()> {
    use greenllm::bench::perf;
    use greenllm::util::json::Json;
    // No memory gate exists (the sections document the footprint
    // trajectory; see docs/PERFORMANCE.md). Refuse rather than let a
    // `--baseline` invocation exit 0 looking like a gate ran.
    if args.get("baseline").is_some() {
        return Err(anyhow!(
            "bench --mem has no regression gate: memory sections are recorded \
             (--json) but never compared. Drop --baseline/--max-regress, or \
             run the wall-time bench (no --mem) to gate"
        ));
    }
    if let Some(path) = args.get("json") {
        ensure_writable(path).map_err(|e| anyhow!(e))?;
    }
    let Some(results) = perf::run_bench_mem(quick) else {
        return Err(anyhow!(
            "bench --mem needs the counting allocator: rebuild with \
             `cargo build --release --features count-alloc`"
        ));
    };
    println!(
        "greenllm bench --mem ({mode} horizons, seed {}): allocation calls \
         and peak live bytes per scenario",
        perf::BENCH_SEED
    );
    perf::render_mem_table(&results).print();
    if let Some(path) = args.get("json") {
        let existing = std::fs::read_to_string(path)
            .ok()
            .and_then(|s| Json::parse(&s).ok());
        let merged = perf::merge_memory_into_baseline(existing, mode, &results);
        std::fs::write(path, merged.dump())
            .map_err(|e| anyhow!("bench json write {path}: {e}"))?;
        println!("wrote {path} (memory.{mode} section blessed)");
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let n = args.usize_or("prompts", 12)?;
    let max_new = args.usize_or("max-new", 16)?;
    let dir = args.get_or("artifacts", "artifacts");
    println!("starting PJRT server from {dir}/ ...");
    let server = ServerHandle::start(ServerConfig {
        artifacts_dir: dir.into(),
        ..Default::default()
    })?;
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| server.submit(&format!("request {i}: optimize my GPU energy"), max_new))
        .collect();
    let mut ttfts = Vec::new();
    let mut tbts = Vec::new();
    let mut tokens = 0usize;
    for rx in rxs {
        let c = rx.recv()?;
        ttfts.push(c.ttft_s);
        tbts.extend(c.tbts);
        tokens += c.tokens.len();
        println!("  #{:<3} ttft {:6.1} ms  {:?}", c.id, c.ttft_s * 1e3, c.text);
    }
    let wall = t0.elapsed().as_secs_f64();
    ttfts.sort_unstable_by(f64::total_cmp); // NaN-safe (stats.rs stance)
    tbts.sort_unstable_by(f64::total_cmp);
    let pct = |v: &[f64], q: f64| {
        if v.is_empty() {
            0.0
        } else {
            v[((q * v.len() as f64) as usize).min(v.len() - 1)] * 1000.0
        }
    };
    println!(
        "served {n} requests, {tokens} tokens in {wall:.2}s ({:.0} tok/s)",
        tokens as f64 / wall
    );
    println!(
        "TTFT p50/p90: {:.1}/{:.1} ms | TBT p50/p95: {:.2}/{:.2} ms",
        pct(&ttfts, 0.5),
        pct(&ttfts, 0.9),
        pct(&tbts, 0.5),
        pct(&tbts, 0.95)
    );
    let stats = server.shutdown()?;
    println!(
        "engine stats: {} batches, {} requests, {} tokens",
        stats.batches, stats.completed, stats.generated_tokens
    );
    Ok(())
}

const HELP: &str = "\
greenllm — SLO-aware dynamic frequency scaling for LLM serving (paper repro)

USAGE: greenllm <command> [flags]

COMMANDS
  replay      replay a trace under one method (--trace --qps --method --model)
  compare     defaultNV vs PrefillSplit vs GreenLLM on one trace
  microbench  phase microbenchmark (--phase prefill|decode --tps N)
  profile     fit + print the latency/power models (Figs. 7-8)
  fig1 fig3a fig3b fig3c fig5 fig7 fig8 fig10 fig11 fig12a fig12b
              regenerate a paper figure
  table3 table4 ablations baselines
              regenerate a paper table
  cluster     event-driven multi-node simulation with online load balancing,
              chaos injection and heterogeneous nodes
              (--nodes N --lb rr|leastwork|jsq|phase|powergrant
               --node-spec dgx,eff,legacy|half|big --power-cap-w W
               --power-epoch-s S --arbiter demand|slo-pressure
               --faults none|onedown|flap|spot|straggler|
                        \"down@40:1,up@80:1,preempt@60:2:15,slow@30:3:2.0,
                         rackdown@50:0-3,ctlnoise@40:1:0.05:0.1:0.05,
                         ctlquiet@80:1,ctlblackout@50-70:1\"
               --supervisor (wrap every node's policy in the fail-safe
               watchdog: SLO-breach streaks, clock flapping and stale
               telemetry trip a pinned high-clock fallback with
               cooldown + probation re-engagement; [ctl] TOML tunes it)
               --disagg off|P:D (prefill/decode pool split with explicit
               KV-transfer stream migration; link model via [disagg] TOML)
               --pool-ratio P:D (phase-balancer long-pool split)
               --capacity [off] (endogenous autoscaler: boots cold nodes on
               backlog, parks idle ones; --warm-pool N --min-live N
               --boot-s S --capacity-epoch-s S --up-backlog F
               --down-backlog F --down-idle-epochs N --warm-idle-w W;
               defaults from [capacity] TOML)
               --shed [off] (graceful overload shedding at ingress with
               bounded retry/backoff; --shed-depth F --shed-backoff-s S
               --shed-retries N; defaults from [shed] TOML;
               completed + shed == arrived is enforced)
               --json out.json (per-method conservation/energy/elasticity
               counters plus the ctl section with supervisor fallback and
               dropped/delayed/misstepped-write counts — the chaos-smoke
               and ctl-chaos-smoke CI contracts)
               --trace-out t.json (Perfetto trace of the GreenLLM pass)
               --trace ...)
  report      flight-recorder post-run analysis: run the configured method
              once with recording on, attribute every TTFT/TBT violation to
              a dominant cause (queueing-wait | low-clock-prefill |
              migration-wire-delay | fault-reroute | decode-clock-undershoot |
              admission-backoff | stale-telemetry | actuation-lag |
              supervisor-fallback)
              and print per-node tables + TTFT/TBT/power distributions
              (same deployment flags as cluster; --trace-out t.json
               --json report.json)
  trace-check re-parse an exported Perfetto trace with the in-repo parser
              and verify its structural invariants (greenllm trace-check
              t.json)
  matrix      scenario matrix: traces x policies x margins x cluster shapes
              x chaos across threads (--traces a,b --methods a,b
               --margins 0.9,1.0 --nodes 1,2,4 --lb all|jsq,phase
               --power-cap-w 0,8000 --shapes uniform,dgx+eff+legacy
               --faults \"none;onedown;flap\" --arbiter all|demand,slo-pressure
               --ctl-faults \"none;ctlnoise@40:1,ctlquiet@80:1;ctlblackout@50-70:0\"
               --disagg off,1:1,1:2,1:3,1:4
               --threads N --json out.json --md out.md;
               the --faults and --ctl-faults axes separate entries with ';'
               because explicit fault plans contain commas; each cell runs
               the merged capacity + control-plane plan, and cells with a
               ctl schedule carry a `ctl` counter section in --json)
  bench       perf-gate harness: fixed-seed hot-path scenarios (incl. the
              32-node cluster sweep) reporting events/s, simulated tok/s
              and wall ms
              (--quick for the CI smoke horizons; --json BENCH_pr4.json to
               bless the baseline; --baseline <file> [--max-regress 25] to
               fail on wall-time regressions; --mem for allocation counts +
               peak bytes — needs a --features count-alloc build;
               see docs/PERFORMANCE.md)
  validate    paper-closure gate: replay the paper's Alibaba + Azure
              settings on a calibrated part (cited latency/power samples,
              not the analytic defaults), compare defaultNV vs GreenLLM,
              and check the deltas against the [closure] tolerance bands;
              exits non-zero on drift
              (--part a100|h100 --quick --json closure.json
               --min-savings 25 --max-extra-viol 3.5 --duration 240;
               --ctl-stress re-runs the pair under mild control-plane
               noise with the supervisor armed and prints the savings
               delta — informational, never gating;
               see docs/VALIDATION.md)
  serve       end-to-end PJRT serving demo (needs `make artifacts`)

FLAGS
  --duration <s>        trace duration (default 300)
  --seed <n>            RNG seed (default 42)
  --model <name>        qwen3-14b | qwen3-30b-moe
  --method <name>       defaultnv | prefillsplit | greenllm | fixed<MHz> |
                        throttle | agft | pitbt
  --trace <name>        alibaba | azure_code5|8 | azure_conv5|8 | sinusoid |
                        bursty | diurnal | multitenant
  --qps <f>             alibaba chat rate
  --prefill-margin <f>  SLO margin factor (Fig. 12)
  --decode-margin <f>   SLO margin factor (Fig. 12)
  --config <path>       TOML config file (see config/greenllm.toml)

ENV
  GREENLLM_CSV_DIR      also write each table/figure as CSV into this dir
";

//! Plain-text table/series rendering for the experiment drivers (no
//! plotting stack offline; figures print as aligned columns + optional
//! CSV for external plotting).

use std::fmt::Write as _;

/// Fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render to an aligned plain-text block.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}  ", c, w = widths[i]);
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + widths.len() * 2;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Print the rendered table to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Render as CSV (naive quoting for comma-bearing cells).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') {
                format!("\"{s}\"")
            } else {
                s.to_string()
            }
        };
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `prec` decimals.
pub fn fmt_f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

/// Format a percentage with one decimal.
pub fn fmt_pct(v: f64) -> String {
    format!("{v:.1}")
}

/// Format seconds as milliseconds with one decimal.
pub fn fmt_ms(v_s: f64) -> String {
    format!("{:.1}", v_s * 1000.0)
}

/// Write a CSV next to stdout output when GREENLLM_CSV_DIR is set.
pub fn maybe_write_csv(name: &str, table: &Table) {
    if let Ok(dir) = std::env::var("GREENLLM_CSV_DIR") {
        let path = std::path::Path::new(&dir).join(format!("{name}.csv"));
        if let Err(e) = std::fs::write(&path, table.to_csv()) {
            eprintln!("csv write {path:?}: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1.00".into()]);
        t.row(&["longer-name".into(), "2".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a "));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new(&["a,b", "c"]);
        t.row(&["x,y".into(), "z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"x,y\",z"));
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_f(1.23456, 3), "1.235");
        assert_eq!(fmt_pct(98.76), "98.8");
        assert_eq!(fmt_ms(0.0834), "83.4");
    }
}

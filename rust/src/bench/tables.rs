//! Tables 3 & 4: energy + SLO pass rates across production-trace replays,
//! three methods, two models.

use crate::bench::report::{fmt_f, fmt_pct, maybe_write_csv, Table};
use crate::bench::{compare_methods, MethodRow};
use crate::workload::alibaba::{self, ChatParams};
use crate::workload::azure::{self, AzureKind, AzureParams};
use crate::workload::request::Trace;

/// The workload set of Table 3 (Qwen3-14B).
pub fn table3_workloads(duration_s: f64, seed: u64) -> Vec<Trace> {
    let mut traces = Vec::new();
    for qps in [1.0, 3.0, 5.0, 8.0, 10.0] {
        traces.push(alibaba::generate(&ChatParams::new(qps, duration_s), seed));
    }
    for (kind, div) in [
        (AzureKind::Code, 5),
        (AzureKind::Code, 8),
        (AzureKind::Conv, 5),
        (AzureKind::Conv, 8),
    ] {
        traces.push(azure::generate(&AzureParams::new(kind, div, duration_s), seed));
    }
    traces
}

/// The workload set of Table 4 (Qwen3-30B-MoE).
pub fn table4_workloads(duration_s: f64, seed: u64) -> Vec<Trace> {
    let mut traces = Vec::new();
    for qps in [1.0, 3.0, 5.0] {
        traces.push(alibaba::generate(&ChatParams::new(qps, duration_s), seed));
    }
    for (kind, div) in [
        (AzureKind::Conv, 5),
        (AzureKind::Conv, 8),
        (AzureKind::Code, 5),
        (AzureKind::Code, 8),
    ] {
        traces.push(azure::generate(&AzureParams::new(kind, div, duration_s), seed));
    }
    traces
}

/// Run one table: all workloads × {defaultNV, PrefillSplit, GreenLLM}.
pub fn run_table(model: &str, traces: &[Trace], seed: u64) -> Vec<MethodRow> {
    let mut rows = Vec::new();
    for trace in traces {
        rows.extend(compare_methods(model, trace, seed));
    }
    rows
}

/// Render comparison rows as an aligned table (and print it).
pub fn render_rows(title: &str, rows: &[MethodRow]) -> Table {
    let mut t = Table::new(&[
        "Workload",
        "Method",
        "Rel.Decode",
        "Rel.Prefill",
        "TTFT(%)",
        "TBT(%)",
        "dEn(%)",
        "Thru(tok/s)",
    ]);
    for r in rows {
        t.row(&[
            r.workload.clone(),
            r.method.name(),
            fmt_f(r.rel_decode, 3),
            fmt_f(r.rel_prefill, 3),
            fmt_pct(r.ttft_pct),
            fmt_pct(r.tbt_pct),
            fmt_f(r.delta_energy_pct, 2),
            fmt_f(r.throughput_tps, 0),
        ]);
    }
    println!("== {title} ==");
    t.print();
    println!();
    t
}

/// Regenerate Table 3 (Qwen3-14B energy + SLO comparison).
pub fn table3(duration_s: f64, seed: u64) -> Vec<MethodRow> {
    let traces = table3_workloads(duration_s, seed);
    let rows = run_table("qwen3-14b", &traces, seed);
    let t = render_rows(
        "Table 3: Energy and SLOs, Qwen3-14B (energies normalized to defaultNV decode)",
        &rows,
    );
    maybe_write_csv("table3", &t);
    rows
}

/// Regenerate Table 4 (Qwen3-30B-MoE energy + SLO comparison).
pub fn table4(duration_s: f64, seed: u64) -> Vec<MethodRow> {
    let traces = table4_workloads(duration_s, seed);
    let rows = run_table("qwen3-30b-moe", &traces, seed);
    let t = render_rows(
        "Table 4: Energy and SLOs, Qwen3-30B-MoE (energies normalized to defaultNV decode)",
        &rows,
    );
    maybe_write_csv("table4", &t);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    #[test]
    fn table3_short_run_has_expected_shape() {
        // 60-second slice of the full table: checks the paper's *ordering*
        // claims, not absolute numbers.
        let traces = vec![
            alibaba::generate(&ChatParams::new(1.0, 60.0), 3),
            azure::generate(&AzureParams::new(AzureKind::Conv, 5, 60.0), 3),
        ];
        let rows = run_table("qwen3-14b", &traces, 3);
        assert_eq!(rows.len(), 6);
        for chunk in rows.chunks(3) {
            let (nv, split, green) = (&chunk[0], &chunk[1], &chunk[2]);
            assert_eq!(nv.method, Method::DefaultNv);
            // PrefillSplit: ≤ ~3 % energy change (paper: routing alone
            // barely moves energy).
            assert!(
                split.delta_energy_pct.abs() < 5.0,
                "{}: split dEn {}",
                split.workload,
                split.delta_energy_pct
            );
            // GreenLLM: decisive savings, mostly from decode.
            assert!(
                green.delta_energy_pct > 10.0,
                "{}: green dEn {}",
                green.workload,
                green.delta_energy_pct
            );
            assert!(green.rel_decode < 0.95);
            // SLO compliance stays high at these light loads (the 60 s
            // slice is controller warm-up territory, so the bound is
            // looser than the 300 s runs asserted in integration tests).
            assert!(
                green.ttft_pct > 85.0 && green.tbt_pct > 85.0,
                "{}: ttft {} tbt {}",
                green.workload,
                green.ttft_pct,
                green.tbt_pct
            );
        }
    }

    #[test]
    fn moe_table_also_saves() {
        let traces = vec![alibaba::generate(&ChatParams::new(1.0, 60.0), 5)];
        let rows = run_table("qwen3-30b-moe", &traces, 5);
        let green = &rows[2];
        assert!(green.delta_energy_pct > 5.0);
    }
}

//! Paper-closure validation harness (`greenllm validate`): replay the
//! paper's Alibaba and Azure evaluation settings on *calibrated* nodes
//! (`gpu::calibrate`), run the default-DVFS baseline and GreenLLM
//! back-to-back, and check the deltas against declared tolerance bands.
//!
//! The paper's headline (§5.2, Tables 3–4): ≈34% energy savings vs the
//! NVIDIA default governor with <3.5% additional SLO violations. This
//! harness asserts a conservative floor (default ≥25% savings, <3.5 pp
//! extra violations, `[closure]` in the config); `docs/VALIDATION.md`
//! documents the remaining gap to the paper's number and how to close it.
//!
//! Everything is machine-readable: [`ClosureReport::to_json`] feeds the
//! CI `validate-smoke` job and `rust/tests/paper_closure.rs`.

use crate::config::{ClosureSection, Config, Method};
use crate::coordinator::engine::{run, RunOptions, RunResult};
use crate::util::json::Json;
use crate::workload::alibaba::{self, ChatParams};
use crate::workload::azure::{self, AzureKind, AzureParams};
use crate::workload::request::Trace;

/// The closure workload set: the paper's light-to-moderate settings where
/// the headline savings are measured (Table 3's Alibaba 1 QPS row and the
/// Azure-code /8 divisor row). Heavier loads shrink savings by design
/// (Fig. 11) and are covered by the matrix/table harnesses instead.
pub fn closure_workloads(duration_s: f64, seed: u64) -> Vec<Trace> {
    vec![
        alibaba::generate(&ChatParams::new(1.0, duration_s), seed),
        azure::generate(&AzureParams::new(AzureKind::Code, 8, duration_s), seed),
    ]
}

/// One workload's baseline-vs-GreenLLM deltas and verdicts.
#[derive(Debug, Clone)]
pub struct ClosureRow {
    /// Workload label.
    pub workload: String,
    /// defaultNV whole-node energy, watt-hours.
    pub nv_energy_wh: f64,
    /// GreenLLM whole-node energy, watt-hours.
    pub green_energy_wh: f64,
    /// Energy savings vs defaultNV, percent (positive = GreenLLM saves).
    pub energy_savings_pct: f64,
    /// defaultNV TTFT SLO pass rate, percent.
    pub nv_ttft_pct: f64,
    /// GreenLLM TTFT SLO pass rate, percent.
    pub green_ttft_pct: f64,
    /// defaultNV TBT SLO pass rate, percent.
    pub nv_tbt_pct: f64,
    /// GreenLLM TBT SLO pass rate, percent.
    pub green_tbt_pct: f64,
    /// Extra SLO violations GreenLLM adds over the baseline, percentage
    /// points, worst of the TTFT and TBT dimensions (negative = GreenLLM
    /// violates *less*).
    pub extra_violations_pp: f64,
    /// Energy delta within the declared band?
    pub pass_energy: bool,
    /// Violation delta within the declared band?
    pub pass_slo: bool,
}

impl ClosureRow {
    /// Both bands hold for this workload.
    pub fn pass(&self) -> bool {
        self.pass_energy && self.pass_slo
    }
}

/// The full closure verdict: per-workload rows + the bands they were
/// judged against.
#[derive(Debug, Clone)]
pub struct ClosureReport {
    /// Calibrated part the replays ran on.
    pub part: String,
    /// Served model.
    pub model: String,
    /// Replay horizon, seconds.
    pub duration_s: f64,
    /// RNG seed of the replays.
    pub seed: u64,
    /// Tolerance bands the rows were judged against.
    pub bands: ClosureSection,
    /// Per-workload results.
    pub rows: Vec<ClosureRow>,
}

impl ClosureReport {
    /// Every workload passes both bands.
    pub fn pass(&self) -> bool {
        !self.rows.is_empty() && self.rows.iter().all(|r| r.pass())
    }

    /// Machine-readable report (the CI contract: `pass` at the top level,
    /// one object per workload under `rows`).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("part", Json::Str(self.part.clone())),
            ("model", Json::Str(self.model.clone())),
            ("duration_s", Json::Num(self.duration_s)),
            ("seed", Json::Num(self.seed as f64)),
            (
                "bands",
                Json::obj([
                    (
                        "min_energy_savings_pct",
                        Json::Num(self.bands.min_energy_savings_pct),
                    ),
                    (
                        "max_extra_violations_pct",
                        Json::Num(self.bands.max_extra_violations_pct),
                    ),
                ]),
            ),
            ("pass", Json::Bool(self.pass())),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj([
                                ("workload", Json::Str(r.workload.clone())),
                                ("nv_energy_wh", Json::Num(r.nv_energy_wh)),
                                ("green_energy_wh", Json::Num(r.green_energy_wh)),
                                ("energy_savings_pct", Json::Num(r.energy_savings_pct)),
                                ("nv_ttft_pct", Json::Num(r.nv_ttft_pct)),
                                ("green_ttft_pct", Json::Num(r.green_ttft_pct)),
                                ("nv_tbt_pct", Json::Num(r.nv_tbt_pct)),
                                ("green_tbt_pct", Json::Num(r.green_tbt_pct)),
                                ("extra_violations_pp", Json::Num(r.extra_violations_pp)),
                                ("pass_energy", Json::Bool(r.pass_energy)),
                                ("pass_slo", Json::Bool(r.pass_slo)),
                                ("pass", Json::Bool(r.pass())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Node config for one closure replay: the calibrated part at its own
/// clock ceiling, everything else the paper's deployment defaults.
fn closure_config(part: &str, model: &str, method: Method, seed: u64) -> Config {
    let mut cfg = Config {
        model: model.to_string(),
        method,
        seed,
        ..Config::default()
    };
    cfg.gpu.part = part.to_string();
    if let Some(p) = crate::gpu::calibrate::part(part) {
        cfg.gpu.max_clock_mhz = p.ladder.max_mhz;
    }
    cfg.validate().unwrap_or_else(|e| panic!("closure config invalid: {e}"));
    cfg
}

fn pct(rate: f64) -> f64 {
    rate * 100.0
}

/// Judge one workload: run defaultNV then GreenLLM on the calibrated
/// part and score the deltas against `bands`.
pub fn closure_row(
    part: &str,
    model: &str,
    trace: &Trace,
    seed: u64,
    bands: &ClosureSection,
) -> ClosureRow {
    let opts = RunOptions::default();
    let nv: RunResult = run(&closure_config(part, model, Method::DefaultNv, seed), trace, &opts);
    let green: RunResult = run(&closure_config(part, model, Method::GreenLlm, seed), trace, &opts);
    let savings = (1.0 - green.total_energy_j / nv.total_energy_j) * 100.0;
    // Extra violations in percentage points: violation% = 100 − pass%.
    let extra_ttft = pct(nv.slo.ttft_pass_rate()) - pct(green.slo.ttft_pass_rate());
    let extra_tbt = pct(nv.slo.tbt_pass_rate()) - pct(green.slo.tbt_pass_rate());
    let extra = extra_ttft.max(extra_tbt);
    ClosureRow {
        workload: trace.name.clone(),
        nv_energy_wh: nv.total_energy_wh(),
        green_energy_wh: green.total_energy_wh(),
        energy_savings_pct: savings,
        nv_ttft_pct: pct(nv.slo.ttft_pass_rate()),
        green_ttft_pct: pct(green.slo.ttft_pass_rate()),
        nv_tbt_pct: pct(nv.slo.tbt_pass_rate()),
        green_tbt_pct: pct(green.slo.tbt_pass_rate()),
        extra_violations_pp: extra,
        pass_energy: savings >= bands.min_energy_savings_pct,
        pass_slo: extra < bands.max_extra_violations_pct,
    }
}

/// Run the whole closure suite on one part and return the report.
pub fn run_closure(
    part: &str,
    model: &str,
    duration_s: f64,
    seed: u64,
    bands: &ClosureSection,
) -> ClosureReport {
    let rows = closure_workloads(duration_s, seed)
        .iter()
        .map(|t| closure_row(part, model, t, seed, bands))
        .collect();
    ClosureReport {
        part: part.to_string(),
        model: model.to_string(),
        duration_s,
        seed,
        bands: bands.clone(),
        rows,
    }
}

/// Print the human-readable closure table (the `greenllm validate`
/// output; the `--json` report carries the same numbers).
pub fn print_report(rep: &ClosureReport) {
    println!(
        "== Paper closure: GreenLLM vs defaultNV on calibrated {} ({}, {:.0} s, seed {}) ==",
        rep.part, rep.model, rep.duration_s, rep.seed
    );
    println!(
        "   bands: energy savings >= {:.1}%  |  extra violations < {:.1} pp",
        rep.bands.min_energy_savings_pct, rep.bands.max_extra_violations_pct
    );
    for r in &rep.rows {
        println!(
            "   {:<22} dEn {:>6.2}%  ({:.1} -> {:.1} Wh)   TTFT {:>5.1}% -> {:>5.1}%   \
             TBT {:>5.1}% -> {:>5.1}%   extra {:+.2} pp   [{}]",
            r.workload,
            r.energy_savings_pct,
            r.nv_energy_wh,
            r.green_energy_wh,
            r.nv_ttft_pct,
            r.green_ttft_pct,
            r.nv_tbt_pct,
            r.green_tbt_pct,
            r.extra_violations_pp,
            if r.pass() { "pass" } else { "FAIL" }
        );
    }
    println!(
        "   verdict: {}",
        if rep.pass() {
            "PASS — reproduction inside the declared bands"
        } else {
            "FAIL — reproduction drifted outside the declared bands"
        }
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closure_workloads_are_the_papers_light_settings() {
        let traces = closure_workloads(30.0, 1);
        assert_eq!(traces.len(), 2);
        assert!(traces[0].name.contains("alibaba"), "{}", traces[0].name);
        assert!(traces[1].name.contains("azure"), "{}", traces[1].name);
    }

    #[test]
    fn report_json_shape_matches_the_ci_contract() {
        let rep = ClosureReport {
            part: "a100".into(),
            model: "qwen3-14b".into(),
            duration_s: 30.0,
            seed: 1,
            bands: ClosureSection::default(),
            rows: vec![ClosureRow {
                workload: "alibaba-1qps".into(),
                nv_energy_wh: 100.0,
                green_energy_wh: 70.0,
                energy_savings_pct: 30.0,
                nv_ttft_pct: 99.0,
                green_ttft_pct: 98.5,
                nv_tbt_pct: 99.0,
                green_tbt_pct: 98.0,
                extra_violations_pp: 1.0,
                pass_energy: true,
                pass_slo: true,
            }],
        };
        assert!(rep.pass());
        let j = rep.to_json();
        assert_eq!(j.path("pass"), Some(&Json::Bool(true)));
        let rows = j.path("rows").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(
            rows[0].path("energy_savings_pct").and_then(Json::as_f64),
            Some(30.0)
        );
        // Round-trips through the in-repo parser.
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn empty_report_never_passes() {
        let rep = ClosureReport {
            part: "a100".into(),
            model: "qwen3-14b".into(),
            duration_s: 0.0,
            seed: 0,
            bands: ClosureSection::default(),
            rows: Vec::new(),
        };
        assert!(!rep.pass(), "an empty suite must not report closure");
    }

    #[test]
    fn row_verdicts_follow_the_bands() {
        let bands = ClosureSection::default();
        // A quick 30 s replay: verdict wiring only (the full-band closure
        // assertion lives in rust/tests/paper_closure.rs at 240 s).
        let trace = &closure_workloads(30.0, 2)[0];
        let row = closure_row("a100", "qwen3-14b", trace, 2, &bands);
        assert_eq!(row.pass(), row.pass_energy && row.pass_slo);
        assert!(row.nv_energy_wh > 0.0 && row.green_energy_wh > 0.0);
        // The baseline parks in its boost band: GreenLLM must never use
        // MORE energy at the paper's light-load setting.
        assert!(row.energy_savings_pct > 0.0, "savings={}", row.energy_savings_pct);
    }
}
